"""Durable mid-task checkpoints: serialize a live ``TaskLifecycle``.

``export_lifecycle`` captures everything the lifecycle's trajectory is a
function of — per-slot ``SlotSnapshot``s (adapter + AdamW moments + step
count + TRUE rank + ragged width), the task-local PRNG key and admission
counter, every ``JobMonitor``'s loss history, batch-stream generator
states/permutations/cursors, phase counters, and the resident
(job, lane) order (insertion order is semantic: it drives eval
iteration, exit order, and lane backfill). ``restore_lifecycle``
rebuilds an equivalent lifecycle on a FRESH executor; because slots are
bit-isolated (the PR 6 migration property), the continued chunk stream
is bitwise identical to the uninterrupted run's tail.

``TaskCheckpointer`` is the service-side driver: installed as the
executor ``ckpt_hook`` it atomically persists the lifecycle every
``every`` chunks under ``state_dir/ckpt/<task>/chunk-%06d.npz``, journals
a ``ckpt`` record, prunes stale snapshots, and (for tests/benchmarks)
can raise ``SimulatedCrash`` after N saves — the moral equivalent of
``kill -9`` at a chunk boundary, since everything already on disk is
fsynced.
"""
from __future__ import annotations

import glob
import logging
import os
import time
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import load_state_tree, save_state_tree
from repro.core.adapter_state import SlotSnapshot
from repro.core.early_exit import ExitDecision, ExitReason

log = logging.getLogger(__name__)

SCHEMA = 1


class SimulatedCrash(RuntimeError):
    """Injected process death (chaos testing): raised at a chunk boundary
    after the checkpoint was durably written, like a pod loss would."""


# ---------------------------------------------------------------------------
# lifecycle <-> state tree
# ---------------------------------------------------------------------------

def _sub_batchers(batcher) -> List[Tuple[str, object]]:
    """A batcher is either a SlotBatcher or a pair-wrapper (DPO) holding
    two of them; return the leaf batchers with stable labels."""
    if hasattr(batcher, "chosen") and hasattr(batcher, "rejected"):
        return [("chosen", batcher.chosen), ("rejected", batcher.rejected)]
    return [("_", batcher)]


def _monitor_state(m) -> Dict:
    exited = None
    if m.exited is not None:
        exited = {"reason": m.exited.reason.value, "step": m.exited.step,
                  "best_val": m.exited.best_val,
                  "best_val_step": m.exited.best_val_step}
    return {"ema_train": m.ema_train, "ema_hist": list(m.ema_hist),
            "val_hist": list(m.val_hist),
            "raw_train_hist": list(m.raw_train_hist),
            "cnt_div": m.cnt_div, "cnt_ovf": m.cnt_ovf,
            "best_val": m.best_val, "best_val_step": m.best_val_step,
            "steps_trained": m.steps_trained, "exited": exited}


def _load_monitor(m, st: Dict) -> None:
    m.ema_train = st["ema_train"]
    m.ema_hist = [float(x) for x in st["ema_hist"]]
    m.val_hist = [float(x) for x in st["val_hist"]]
    m.raw_train_hist = [float(x) for x in st["raw_train_hist"]]
    m.cnt_div = int(st["cnt_div"])
    m.cnt_ovf = int(st["cnt_ovf"])
    m.best_val = float(st["best_val"])
    m.best_val_step = int(st["best_val_step"])
    m.steps_trained = int(st["steps_trained"])
    ex = st["exited"]
    m.exited = None if ex is None else ExitDecision(
        reason=ExitReason(ex["reason"]), step=int(ex["step"]),
        best_val=float(ex["best_val"]),
        best_val_step=int(ex["best_val_step"]))


def export_lifecycle(lc) -> Tuple[Dict, Dict]:
    """``(tree, meta)`` capturing a live (non-done) lifecycle mid-chunk.
    Resident slots are snapshotted via read-only host copies — the device
    state is untouched, so exporting is safe every chunk."""
    assert lc.phase in ("warmup", "continue"), \
        f"cannot export lifecycle in phase {lc.phase!r}"
    snaps: Dict[str, SlotSnapshot] = {}
    resident_order: List[Tuple[str, int]] = []
    for job_id, (lane, slot) in lc.resident.items():
        snaps[job_id] = lc.ex.snapshot(slot)
        resident_order.append((job_id, lane))
    for job_id, snap in lc.snapshots.items():     # rotated-out wave jobs
        snaps[job_id] = snap
    tree: Dict = {
        "prng": np.asarray(lc._key),
        "snap": {j: {"lora": s.lora, "mu": s.mu, "nu": s.nu}
                 for j, s in snaps.items()},
        "best": dict(lc._best_ckpt),
        "perm": {name: {str(z): np.asarray(sb._perm[z])
                        for z in range(sb.Z)}
                 for name, sb in _sub_batchers(lc.batcher)},
    }
    meta: Dict = {
        "schema": SCHEMA,
        "task": lc.task_name,
        "total_steps": lc.total_steps,
        "phase": lc.phase,
        "wave_idx": lc._wave_idx,
        "wave_step": lc._wave_step,
        "cont_step": lc._cont_step,
        "admissions": lc._admissions,
        "queue": list(lc._queue),
        "steps_done": dict(lc.steps_done),
        "resident": resident_order,
        "monitors": {j: _monitor_state(m) for j, m in lc.monitors.items()},
        "snap_meta": {j: {"count": s.count, "rank": s.rank,
                          "b": s.per_adapter_batch, "seq": s.seq_len}
                      for j, s in snaps.items()},
        "batcher": {name: {"rng": [r.bit_generator.state for r in sb._rngs],
                           "cursor": [int(c) for c in sb._cursor],
                           "epochs": [int(e) for e in sb.epochs]}
                    for name, sb in _sub_batchers(lc.batcher)},
        "remaining_steps_bound": lc.remaining_steps_bound(),
    }
    return tree, meta


def restore_lifecycle(ex, task_name: str, jobs: Dict, total_steps: int, *,
                      ee, max_slots: Optional[int], batcher, state):
    """Rebuild a lifecycle from ``(tree, meta)`` onto a fresh executor.

    The lifecycle is constructed normally, then its mutable state is
    overwritten from the checkpoint; residents are re-admitted at their
    exact lanes through the normal ``_admit_job`` restore path (physical
    slot indices may differ — slot isolation makes that invisible)."""
    from repro.core.executor import TaskLifecycle
    tree, meta = state
    assert meta.get("schema") == SCHEMA, \
        f"checkpoint schema {meta.get('schema')} != {SCHEMA}"
    assert meta["task"] == task_name, (meta["task"], task_name)
    assert int(meta["total_steps"]) == int(total_steps)
    assert set(meta["monitors"]) == set(jobs), "job set changed on restore"
    lc = TaskLifecycle(ex, task_name, jobs, total_steps, ee=ee,
                       max_slots=max_slots, batcher=batcher)
    lc._key = jnp.asarray(tree["prng"])
    lc._admissions = int(meta["admissions"])
    lc.phase = meta["phase"]
    lc._wave_idx = int(meta["wave_idx"])
    lc._wave_step = int(meta["wave_step"])
    lc._cont_step = int(meta["cont_step"])
    lc._queue = list(meta["queue"])
    lc.steps_done = {j: int(v) for j, v in meta["steps_done"].items()}
    for j, st in meta["monitors"].items():
        _load_monitor(lc.monitors[j], st)
    lc._best_ckpt = dict(tree.get("best", {}))
    sm = meta["snap_meta"]
    for j, arrs in tree.get("snap", {}).items():
        lc.snapshots[j] = SlotSnapshot(
            job_id=j, lora=arrs["lora"], mu=arrs["mu"], nu=arrs["nu"],
            count=int(sm[j]["count"]), rank=int(sm[j]["rank"]),
            per_adapter_batch=int(sm[j]["b"]), seq_len=int(sm[j]["seq"]))
    for name, sb in _sub_batchers(batcher):
        bm = meta["batcher"][name]
        perms = tree["perm"][name]
        for z in range(sb.Z):
            rng = np.random.default_rng()
            rng.bit_generator.state = bm["rng"][z]
            sb._rngs[z] = rng
            sb._perm[z] = np.asarray(perms[str(z)])
            sb._cursor[z] = int(bm["cursor"][z])
            sb.epochs[z] = int(bm["epochs"][z])
    lc._t0 = time.time()
    for job_id, lane in meta["resident"]:
        lc._admit_job(job_id, lane=int(lane))
    return lc


# ---------------------------------------------------------------------------
# service-side checkpoint driver
# ---------------------------------------------------------------------------

def _safe_name(task: str) -> str:
    return task.replace("/", "_").replace(":", "_")


class TaskCheckpointer:
    """Periodic atomic lifecycle checkpointing under ``state_dir/ckpt/``.

    Installed as ``BatchedExecutor.ckpt_hook``; fires every ``every``
    completed chunks. Keeps the last ``keep`` snapshots per task. If
    ``fail_after[task]`` (or the ``"*"`` wildcard) is set, raises
    ``SimulatedCrash`` once that many saves have landed for the task —
    AFTER the save is durable, mimicking a pod death at a boundary."""

    def __init__(self, state_dir: str, journal=None, every: int = 1,
                 keep: int = 2):
        self.dir = os.path.join(state_dir, "ckpt")
        os.makedirs(self.dir, exist_ok=True)
        self.journal = journal
        self.every = max(int(every), 1)
        self.keep = max(int(keep), 1)
        self.fail_after: Dict[str, int] = {}
        self.saves: Dict[str, int] = {}

    def on_chunk(self, lc, chunk_i: int) -> None:
        if lc.done or chunk_i % self.every != 0:
            return
        tdir = os.path.join(self.dir, _safe_name(lc.task_name))
        path = os.path.join(tdir, f"chunk-{chunk_i:06d}.npz")
        tree, meta = export_lifecycle(lc)
        meta["chunk"] = chunk_i
        save_state_tree(path, tree, meta)
        if self.journal is not None:
            self.journal.append({
                "rec": "ckpt", "task": lc.task_name, "path": path,
                "chunk": chunk_i,
                "remaining_steps_bound": meta["remaining_steps_bound"]})
        self._prune(tdir)
        self.saves[lc.task_name] = self.saves.get(lc.task_name, 0) + 1
        limit = self.fail_after.get(lc.task_name, self.fail_after.get("*"))
        if limit is not None and self.saves[lc.task_name] >= limit:
            raise SimulatedCrash(
                f"injected crash: task {lc.task_name!r} after "
                f"{self.saves[lc.task_name]} checkpoint saves")

    def _prune(self, tdir: str) -> None:
        snaps = sorted(glob.glob(os.path.join(tdir, "chunk-*.npz")))
        for old in snaps[:-self.keep]:
            try:
                os.remove(old)
            except OSError:
                pass

    def latest(self, task: str) -> Optional[str]:
        snaps = sorted(glob.glob(os.path.join(
            self.dir, _safe_name(task), "chunk-*.npz")))
        return snaps[-1] if snaps else None


def load_task_checkpoint(path: str) -> Optional[Tuple[Dict, Dict]]:
    """Load a lifecycle checkpoint, degrading corrupt/stale files to
    ``None`` (requeue-from-zero) instead of raising."""
    try:
        tree, meta = load_state_tree(path)
        if meta.get("schema") != SCHEMA:
            raise ValueError(f"schema {meta.get('schema')} != {SCHEMA}")
        return tree, meta
    except Exception as e:                        # noqa: BLE001
        log.warning("task checkpoint %s unreadable (%s): "
                    "falling back to requeue-from-zero", path, e)
        return None
