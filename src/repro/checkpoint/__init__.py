"""ALTO-JAX subsystem."""
