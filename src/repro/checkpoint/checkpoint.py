"""Pytree checkpointing (npz-based, no external deps).

Used by the early-exit controller's "checkpoint best-val model before
terminating an overfitting job" (paper §5.1 Pattern-2) and by the training
driver for periodic saves. Slot-level saves extract one adapter from the
slot-stacked tree.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _encode(arr: np.ndarray) -> np.ndarray:
    # np.savez cannot serialize ml_dtypes (bfloat16 etc.): store raw bits
    if arr.dtype == jnp.bfloat16:
        return arr.view(np.uint16)
    return arr


def _atomic_savez(path: str, payload: Dict[str, Any]) -> None:
    """Crash-safe npz write: tmp file + fsync + ``os.replace`` so a kill
    mid-write can never leave a truncated artifact under ``path``."""
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        np.savez(f, **payload)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def save_pytree(path: str, tree: Any, meta: Dict | None = None,
                atomic: bool = False) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten_with_paths(tree)
    dtypes = {k: str(v.dtype) for k, v in flat.items()}
    flat = {k: _encode(v) for k, v in flat.items()}
    payload = dict(__meta__=json.dumps(meta or {}),
                   __dtypes__=json.dumps(dtypes), **flat)
    if atomic:
        if not path.endswith(".npz"):
            path = path + ".npz"
        _atomic_savez(path, payload)
    else:
        np.savez(path, **payload)


def load_pytree(path: str, like: Any) -> Tuple[Any, Dict]:
    """Restore into the structure of ``like`` (names must match)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path, allow_pickle=False)
    meta = json.loads(str(data["__meta__"]))
    flat_like = _flatten_with_paths(like)
    leaves, treedef = jax.tree_util.tree_flatten(like)
    restored = []
    paths = jax.tree_util.tree_flatten_with_path(like)[0]
    for (path_k, leaf) in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_k)
        arr = data[key]
        if arr.dtype == np.uint16 and leaf.dtype == jnp.bfloat16:
            arr = arr.view(jnp.bfloat16)
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        restored.append(jnp.asarray(arr, leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, restored), meta


def save_state_tree(path: str, tree: Dict, meta: Dict | None = None) -> None:
    """Free-form nested-dict checkpoint (always atomic).

    Unlike ``save_pytree``, keys may contain ``/`` (job ids do: they are
    ``task/label``) and no ``like`` template is needed to load — leaf
    paths are stored as a JSON array alongside positional arrays. Dict
    insertion order is preserved through a save/load round-trip, which
    the lifecycle restore path relies on (resident order is semantic)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    paths: list = []
    dtypes: list = []
    arrays: Dict[str, np.ndarray] = {}

    def walk(prefix: list, node: Any) -> None:
        if isinstance(node, dict):
            for k, v in node.items():
                walk(prefix + [str(k)], v)
        else:
            arr = np.asarray(node)
            dtypes.append(str(arr.dtype))
            arrays[f"arr_{len(paths)}"] = _encode(arr)
            paths.append(prefix)

    walk([], tree)
    _atomic_savez(path, dict(__meta__=json.dumps(meta or {}),
                             __paths__=json.dumps(paths),
                             __dtypes__=json.dumps(dtypes), **arrays))


def load_state_tree(path: str) -> Tuple[Dict, Dict]:
    """Inverse of ``save_state_tree``: ``(nested host tree, meta)``."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path, allow_pickle=False)
    meta = json.loads(str(data["__meta__"]))
    paths = json.loads(str(data["__paths__"]))
    dtypes = json.loads(str(data["__dtypes__"]))
    tree: Dict = {}
    for i, (p, dt) in enumerate(zip(paths, dtypes)):
        arr = data[f"arr_{i}"]
        if dt == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        node = tree
        for k in p[:-1]:
            node = node.setdefault(k, {})
        node[p[-1]] = arr
    return tree, meta


def extract_slot(lora_tree: Dict, slot: int) -> Dict:
    """Pull one adapter out of a slot-stacked tree: [L,Z,...] -> [L,...]."""
    return jax.tree_util.tree_map(lambda x: x[:, slot], lora_tree)


def insert_slot(lora_tree: Dict, slot: int, adapter: Dict) -> Dict:
    return jax.tree_util.tree_map(
        lambda full, one: full.at[:, slot].set(one), lora_tree, adapter)
