"""Pytree checkpointing (npz-based, no external deps).

Used by the early-exit controller's "checkpoint best-val model before
terminating an overfitting job" (paper §5.1 Pattern-2) and by the training
driver for periodic saves. Slot-level saves extract one adapter from the
slot-stacked tree.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _encode(arr: np.ndarray) -> np.ndarray:
    # np.savez cannot serialize ml_dtypes (bfloat16 etc.): store raw bits
    if arr.dtype == jnp.bfloat16:
        return arr.view(np.uint16)
    return arr


def save_pytree(path: str, tree: Any, meta: Dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten_with_paths(tree)
    dtypes = {k: str(v.dtype) for k, v in flat.items()}
    flat = {k: _encode(v) for k, v in flat.items()}
    np.savez(path, __meta__=json.dumps(meta or {}),
             __dtypes__=json.dumps(dtypes), **flat)


def load_pytree(path: str, like: Any) -> Tuple[Any, Dict]:
    """Restore into the structure of ``like`` (names must match)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path, allow_pickle=False)
    meta = json.loads(str(data["__meta__"]))
    flat_like = _flatten_with_paths(like)
    leaves, treedef = jax.tree_util.tree_flatten(like)
    restored = []
    paths = jax.tree_util.tree_flatten_with_path(like)[0]
    for (path_k, leaf) in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_k)
        arr = data[key]
        if arr.dtype == np.uint16 and leaf.dtype == jnp.bfloat16:
            arr = arr.view(jnp.bfloat16)
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        restored.append(jnp.asarray(arr, leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, restored), meta


def extract_slot(lora_tree: Dict, slot: int) -> Dict:
    """Pull one adapter out of a slot-stacked tree: [L,Z,...] -> [L,...]."""
    return jax.tree_util.tree_map(lambda x: x[:, slot], lora_tree)


def insert_slot(lora_tree: Dict, slot: int, adapter: Dict) -> Dict:
    return jax.tree_util.tree_map(
        lambda full, one: full.at[:, slot].set(one), lora_tree, adapter)
