"""Sharding rules: Adapter Parallelism + tensor/sequence sharding.

The paper's AP (Fig. 8) on a named mesh:
  * adapter slots ``Z`` shard over "data" — adapters, their grads, and their
    optimizer state are RANK-LOCAL on that axis (zero adapter collectives);
  * frozen base weights shard 2-D: one dim over "data" (ZeRO-style — GSPMD
    all-gathers them forward-only, the FSDP all-gather of Fig. 8 with no
    backward reduce-scatter because the base is frozen) and one dim over
    "model" (tensor parallelism);
  * per-adapter batch ``b`` shards over "pod" (multi-pod DP; adapter grads
    psum over "pod" only — 2-way DCN);
  * residual-stream activations sequence-shard over "model" between blocks
    (Megatron-SP style) to bound remat live memory.

All rules are divisibility-aware with ordered fallbacks (e.g. hymba's 25
heads on a 16-way model axis fall back to sharding head_dim).
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

def _axis_size(mesh: Mesh, name: Optional[str]) -> int:
    if name is None:
        return 1
    return mesh.shape[name] if name in mesh.axis_names else 0


def pick_spec(mesh: Mesh, shape: Sequence[int],
              candidates: Sequence[Dict[int, str]]) -> P:
    """First candidate assignment {dim: axis} that divides evenly wins."""
    for cand in candidates:
        ok = True
        spec: List[Optional[str]] = [None] * len(shape)
        for dim, axis in cand.items():
            n = _axis_size(mesh, axis)
            if n == 0 or shape[dim] % n != 0:
                ok = False
                break
            spec[dim] = axis
        if ok:
            while spec and spec[-1] is None:
                spec.pop()
            return P(*spec)
    return P()


def has_pod(mesh: Mesh) -> bool:
    return "pod" in mesh.axis_names


# ---------------------------------------------------------------------------
# Activation constraints (installed via models.shardctx)
# ---------------------------------------------------------------------------

def activation_policy(mesh: Mesh, *, seq_shard: bool = True,
                      opt_level: int = 0, step_kind: str = "train"):
    """Returns policy(x, kind) -> with_sharding_constraint(x, spec).

    opt_level 0 = paper-baseline GSPMD-guided lowering;
    opt_level >= 1 additionally honors:
      * "weight:<name>" — gather the ZeRO('data')-sharded frozen weight
        before use (AP Fig. 8 semantics) instead of letting GSPMD psum
        activation partial sums over the adapter axis;
      * "dims:a,b,..."  — explicit per-dim assignments from the
        sharding-aware attention layouts (each dim dropped independently
        if it does not divide its axis).

    The optimizations are STEP-KIND dependent (§Perf measured, not
    assumed): weight-gather pays off when tokens/device >> weight rows
    (train/prefill) and regresses single-token decode (gathering a full
    weight per layer vs psumming one token); the scan-chunk/remat changes
    target the outer-remat residual stacking that only exists in training.
    Decode steps therefore run the paper baseline at every opt level.
    """
    if step_kind == "decode":
        opt_level = 0
    pod = "pod" if has_pod(mesh) else None

    def weight_spec(name: str, shape) -> Optional[P]:
        for pat, cands in _PARAM_RULES:
            if any(re.search(pat, pre + name)
                   for pre in ("", "moe/", "mamba/")):
                cand = _resolve(cands[0], len(shape))
                spec: List[Optional[str]] = [None] * len(shape)
                for dim, axis in cand.items():
                    if axis == "data":
                        continue       # gathered over the adapter axis
                    n = _axis_size(mesh, axis)
                    if n and shape[dim] % n == 0:
                        spec[dim] = axis
                while spec and spec[-1] is None:
                    spec.pop()
                return P(*spec)
        return P()

    def policy(x: jax.Array, kind: str) -> jax.Array:
        shape = x.shape
        if kind.startswith("weight:"):
            if opt_level < 1:
                return x
            spec = weight_spec(kind.split(":", 1)[1], shape)
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, spec))
        if kind.startswith("dims:"):
            axes = kind.split(":", 1)[1].split(",")
            spec: List = [None] * len(shape)
            for dim, axis in enumerate(axes[:len(shape)]):
                if axis in ("-", ""):
                    continue
                # "a+b" = shard this dim over multiple mesh axes jointly
                names = tuple(a for a in axis.split("+")
                              if _axis_size(mesh, a))
                n = 1
                for a in names:
                    n *= _axis_size(mesh, a)
                if names and n and shape[dim] % n == 0:
                    spec[dim] = names if len(names) > 1 else names[0]
            while spec and spec[-1] is None:
                spec.pop()
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(*spec)))
        if kind == "residual" and x.ndim == 4:          # [Z,b,S,d]
            cands = []
            if seq_shard:
                cands.append({0: "data", 1: pod, 2: "model"})
            cands += [{0: "data", 1: pod}, {0: "data"}]
        elif kind == "attn_qkv" and x.ndim == 5:        # [Z,b,S,H,hd]
            cands = [{0: "data", 1: pod, 3: "model"},
                     {0: "data", 1: pod, 4: "model"},
                     {0: "data", 1: pod}, {0: "data"}]
        elif kind == "ffn_hidden" and x.ndim == 4:      # [Z,b,S,ff]
            cands = [{0: "data", 1: pod, 3: "model"},
                     {0: "data", 1: pod}, {0: "data"}]
        elif kind == "logits":                          # [Z,b,c,V]
            cands = [{0: "data", 1: pod, x.ndim - 1: "model"},
                     {0: "data", x.ndim - 1: "model"}, {0: "data"}]
        elif kind == "moe_expert" and x.ndim == 4:      # [E,G,C,d]
            cands = [{0: "model", 1: "data"}, {0: "model"}, {1: "data"}]
        else:
            return x
        cands = [{d: a for d, a in c.items() if a is not None}
                 for c in cands]
        spec = pick_spec(mesh, shape, cands)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec))

    policy.hints = {
        "model_size": mesh.shape.get("model", 1),
        "opt_level": opt_level,
    }
    if opt_level >= 2 and step_kind == "train":
        # scan-remat + small chunks fight the outer checkpoint's residual
        # stacking — a training-only pathology (regresses fwd-only prefill)
        policy.hints["scan_chunk"] = 32
        policy.hints["scan_opt"] = True
    return policy


# ---------------------------------------------------------------------------
# Parameter / state / batch pspecs
# ---------------------------------------------------------------------------

_PARAM_RULES: List[Tuple[str, List[Dict[int, str]]]] = [
    # path-regex, candidates over the leaf's dims (layer-stacked leaves have
    # a leading L dim; dims below are the WEIGHT dims counted from the END:
    # negative indices are resolved against the actual leaf rank).
    (r"embed$", [{-2: "model", -1: "data"}, {-2: "model"}, {-1: "data"}, {}]),
    (r"lm_head$", [{-2: "data", -1: "model"}, {-1: "model"}, {-2: "data"}, {}]),
    (r"(q_proj|k_proj|v_proj|g_proj|r_proj|in_proj)$",
     [{-2: "data", -1: "model"}, {-1: "model"}, {-2: "data"}, {}]),
    (r"(o_proj|out_proj|down_proj|ffn_v)$",
     [{-2: "model", -1: "data"}, {-2: "model"}, {-1: "data"}, {}]),
    (r"(gate_proj|up_proj|ffn_k)$",
     [{-2: "data", -1: "model"}, {-1: "model"}, {-2: "data"}, {}]),
    (r"moe/(w_gate|w_up)$",                   # [L, E, d, ff]
     [{-3: "model", -2: "data"}, {-3: "model"}, {}]),
    (r"moe/w_down$",                          # [L, E, ff, d]
     [{-3: "model", -2: "data"}, {-3: "model"}, {}]),
    (r"moe/shared/(gate|up)$", [{-2: "data", -1: "model"}, {-1: "model"}, {}]),
    (r"moe/shared/down$", [{-2: "model", -1: "data"}, {-2: "model"}, {}]),
    (r"moe/router$", [{}]),
    (r"mamba/(bc_proj|dt_proj)$", [{-2: "data", -1: "model"}, {-1: "model"}, {}]),
    (r"mamba/conv$", [{-1: "model"}, {}]),
    (r"(w1|w2)$", [{}]),
]


def _leaf_path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def _resolve(cand: Dict[int, str], rank: int) -> Dict[int, str]:
    return {(d if d >= 0 else rank + d): a for d, a in cand.items()}


def base_param_specs(mesh: Mesh, params: Any) -> Any:
    """PartitionSpec tree for the frozen backbone."""

    def spec_of(path, leaf) -> P:
        ps = _leaf_path_str(path)
        for pat, cands in _PARAM_RULES:
            if re.search(pat, ps):
                resolved = [_resolve(c, leaf.ndim) for c in cands]
                return pick_spec(mesh, leaf.shape, resolved)
        return P()   # norms, scalars, small vectors: replicated

    return jax.tree_util.tree_map_with_path(spec_of, params)


def lora_param_specs(mesh: Mesh, lora: Any) -> Any:
    """LoRA leaves are [L, Z, din|r, r|dout]: Z -> "data" ONLY (rank-local
    AP). No other dim is sharded: adapters are small and must stay local."""

    def spec_of(leaf) -> P:
        if leaf.ndim >= 2:
            cand = [{1: "data"}, {}]
            return pick_spec(mesh, leaf.shape, cand)
        return P()

    return jax.tree_util.tree_map(spec_of, lora)


def opt_state_specs(mesh: Mesh, opt_state: Any) -> Any:
    """Optimizer moments follow LoRA params; per-slot counters follow Z."""
    from repro.optim.adamw import AdamWState
    mu = lora_param_specs(mesh, opt_state.mu)
    nu = lora_param_specs(mesh, opt_state.nu)
    count = pick_spec(mesh, opt_state.count.shape, [{0: "data"}, {}])
    return AdamWState(mu=mu, nu=nu, count=count)


def hp_specs(mesh: Mesh, hp: Any) -> Any:
    """SlotHParams [Z] vectors shard over data with the slots."""
    return jax.tree_util.tree_map(
        lambda v: pick_spec(mesh, v.shape, [{0: "data"}, {}]), hp)


def batch_specs(mesh: Mesh, batch: Dict) -> Dict:
    """tokens/labels [Z,b,S]; modal_embeds [Z,b,P,d]; positions [*,S]."""
    pod = "pod" if has_pod(mesh) else None

    def spec_of(path, leaf) -> P:
        ps = _leaf_path_str(path)
        if "positions" in ps:
            return P()
        cands = [{0: "data", 1: pod}, {0: "data"}, {}]
        cands = [{d: a for d, a in c.items() if a is not None}
                 for c in cands]
        return pick_spec(mesh, leaf.shape, cands)

    return jax.tree_util.tree_map_with_path(spec_of, batch)


def cache_specs(mesh: Mesh, cache: Any) -> Any:
    """KV cache [L,Z,b,Sc,KV,hd]: Z->data, b->pod, KV|hd|Sc->model.
    Recurrent states [L,Z,b,...]: Z->data, b->pod."""
    pod = "pod" if has_pod(mesh) else None

    def spec_of(path, leaf) -> P:
        ps = _leaf_path_str(path)
        nd = leaf.ndim
        if ps.endswith("pos") or "k_pos" in ps:
            return P()
        cands: List[Dict[int, str]] = []
        if nd == 6:    # [L,Z,b,Sc,KV,hd]
            cands = [{1: "data", 2: pod, 4: "model"},
                     {1: "data", 2: pod, 5: "model"},
                     {1: "data", 2: pod, 3: "model"},
                     {1: "data", 2: pod}, {1: "data"}, {}]
        elif nd >= 3:  # recurrent states [L,Z,b,...]
            cands = [{1: "data", 2: pod, nd - 1: "model"},
                     {1: "data", 2: pod}, {1: "data"}, {}]
        else:
            cands = [{}]
        cands = [{d: a for d, a in c.items() if a is not None}
                 for c in cands]
        return pick_spec(mesh, leaf.shape, cands)

    return jax.tree_util.tree_map_with_path(spec_of, cache)


def to_named(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P))
