"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before any
device initialization.

Axis semantics (see DESIGN.md §5):
  "pod"   : cross-pod data parallelism over per-adapter batch (DCN)
  "data"  : ADAPTER PARALLELISM — each data-rank owns a disjoint slice of
            the adapter slots Z; adapter params/grads/opt-state never cross
            this axis (the paper's rank-local AP)
  "model" : tensor/sequence sharding of the frozen backbone (ICI)
"""
from __future__ import annotations

from typing import Tuple

import jax

from repro.configs.base import MeshConfig

SINGLE_POD = MeshConfig(shape=(16, 16), axes=("data", "model"))
MULTI_POD = MeshConfig(shape=(2, 16, 16), axes=("pod", "data", "model"))


def abstract_mesh(shape: Tuple[int, ...],
                  axes: Tuple[str, ...]) -> "jax.sharding.AbstractMesh":
    """Version-proof AbstractMesh constructor. jax <= 0.4.x takes a single
    ``((name, size), ...)`` shape tuple; jax >= 0.5 takes positional
    ``(axis_sizes, axis_names)``. Dry-run/spec tests go through here so a
    toolchain bump is a one-line fix."""
    assert len(shape) == len(axes), (shape, axes)
    try:
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(shape), tuple(axes))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    cfg = MULTI_POD if multi_pod else SINGLE_POD
    n = cfg.num_devices
    devices = jax.devices()
    assert len(devices) >= n, (
        f"need {n} devices (run under dryrun.py, which sets "
        f"--xla_force_host_platform_device_count), have {len(devices)}")
    return jax.make_mesh(cfg.shape, cfg.axes, devices=devices[:n])


def make_local_mesh(shape: Tuple[int, ...] = (1, 1),
                    axes: Tuple[str, ...] = ("data", "model")
                    ) -> jax.sharding.Mesh:
    """Tiny mesh over however many devices exist (tests/examples)."""
    return jax.make_mesh(shape, axes)


def mesh_config(mesh: jax.sharding.Mesh) -> MeshConfig:
    return MeshConfig(shape=tuple(mesh.devices.shape),
                      axes=tuple(mesh.axis_names))
