import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

Proves the distribution config is coherent without hardware: for the
single-pod (16x16=256 chip) and multi-pod (2x16x16=512 chip) production
meshes, every assigned architecture x input shape must lower and compile
under pjit with the Adapter-Parallel sharding rules. Captures
``memory_analysis`` (fits-per-device), ``cost_analysis`` (FLOPs/bytes) and
the optimized-HLO collective schedule for the roofline analysis.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch stablelm-3b \
        --shape train_4k [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
Results land in experiments/dryrun/<mesh>/<arch>__<shape>.json.
"""
import argparse
import dataclasses
import functools
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.configs.registry import ASSIGNED, get_arch
from repro.configs.shapes import SHAPES, get_shape
from repro.core import lora as LORA
from repro.launch import partitioning as PT
from repro.launch import steps_dist
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.optim import adamw
from repro.roofline import hlo as HLO

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def _use_ring(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """Ring (sliding-window) caches apply to DECODE shapes only: prefill
    fills a full-length cache (the spec's 'KV cache of seq_len')."""
    if cfg.family == "ssm" or shape.kind != "decode":
        return False
    if cfg.attn_kind == "sliding":
        return True   # hymba: windowed attention is the arch's semantics
    return shape.name == "long_500k" and cfg.long_context_mode == "window"


def abstract_state(cfg: ModelConfig, Z: int) -> Tuple[Any, Any, Any]:
    """ShapeDtypeStruct trees for (params, lora, opt_state)."""
    key = jax.random.PRNGKey(0)
    params = jax.eval_shape(functools.partial(M.init_params, cfg=cfg), key)
    ranks = jnp.full((Z,), min(16, cfg.lora.r_max), jnp.int32)
    lora = jax.eval_shape(
        lambda k: LORA.init_lora_tree(k, cfg, Z, ranks,
                                      M.target_shapes(cfg)), key)
    opt = jax.eval_shape(
        lambda lt: adamw.init_state(lt, Z), lora)
    return params, lora, opt


def input_specs(arch: str, shape_name: str) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this combo."""
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    Z, b = shape.decompose()
    S = shape.seq_len
    out: Dict[str, Any] = {"Z": Z, "b": b, "S": S, "kind": shape.kind}
    if shape.kind in ("train", "prefill"):
        batch = {"tokens": sds((Z, b, S), jnp.int32)}
        if shape.kind == "train":
            batch["labels"] = sds((Z, b, S), jnp.int32)
        if cfg.input_mode == "mixed":
            batch["modal_embeds"] = sds(
                (Z, b, cfg.num_modality_tokens, cfg.d_model), jnp.bfloat16)
        out["batch"] = batch
        if shape.kind == "prefill":
            out["cache"] = jax.eval_shape(
                lambda: M.init_cache(cfg, Z, b, S,
                                     ring=_use_ring(cfg, shape)))
    else:   # decode
        out["tokens"] = sds((Z, b), jnp.int32)
        out["cache"] = jax.eval_shape(
            lambda: M.init_cache(cfg, Z, b, S,
                                 ring=_use_ring(cfg, shape)))
    return out


@dataclasses.dataclass
class DryrunResult:
    arch: str
    shape: str
    mesh: str
    ok: bool
    lower_s: float = 0.0
    compile_s: float = 0.0
    flops: float = 0.0
    hlo_bytes: float = 0.0
    collective_traffic: float = 0.0
    cost_analysis_flops: float = 0.0
    cost_analysis_bytes: float = 0.0
    collectives: Optional[Dict] = None
    memory_per_device: Optional[float] = None
    memory_analysis: str = ""
    error: str = ""

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


def dryrun_one(arch: str, shape_name: str, multi_pod: bool = False,
               *, seq_shard: bool = True, remat: bool = True,
               save: bool = True, verbose: bool = True,
               opt_level: int = 0) -> DryrunResult:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    res = DryrunResult(arch=arch, shape=shape_name, mesh=mesh_name, ok=False)
    try:
        cfg = get_arch(arch)
        shape = get_shape(shape_name)
        mesh = make_production_mesh(multi_pod=multi_pod)
        ndev = mesh.size
        spec = input_specs(arch, shape_name)
        Z, b = spec["Z"], spec["b"]
        params, lora, opt = abstract_state(cfg, Z)

        ns = lambda tree: PT.to_named(mesh, tree)
        p_sh = ns(PT.base_param_specs(mesh, params))
        l_sh = ns(PT.lora_param_specs(mesh, lora))

        if shape.kind == "train":
            step = steps_dist.make_train_step(cfg, mesh, remat=remat,
                                              seq_shard=seq_shard,
                                              opt_level=opt_level)
            o_sh = ns(PT.opt_state_specs(mesh, opt))
            hp = adamw.SlotHParams.broadcast(Z)
            hp_abs = jax.tree_util.tree_map(
                lambda x: sds(x.shape, x.dtype), hp)
            h_sh = ns(PT.hp_specs(mesh, hp_abs))
            vec = sds((Z,), jnp.int32)
            vec_sh = PT.to_named(mesh, PT.pick_spec(
                mesh, (Z,), [{0: "data"}, {}]))
            b_sh = ns(PT.batch_specs(mesh, spec["batch"]))
            jitted = jax.jit(step, in_shardings=(
                p_sh, l_sh, o_sh, h_sh, vec_sh, vec_sh, b_sh))
            args = (params, lora, opt, hp_abs, vec, vec, spec["batch"])
        elif shape.kind == "prefill":
            step = steps_dist.make_prefill_step(cfg, mesh,
                                                opt_level=opt_level)
            c_sh = ns(PT.cache_specs(mesh, spec["cache"]))
            b_sh = ns(PT.batch_specs(mesh, spec["batch"]))
            jitted = jax.jit(step, in_shardings=(p_sh, l_sh, c_sh, b_sh))
            args = (params, lora, spec["cache"], spec["batch"])
        else:
            step = steps_dist.make_serve_step(cfg, mesh,
                                              opt_level=opt_level)
            c_sh = ns(PT.cache_specs(mesh, spec["cache"]))
            t_sh = PT.to_named(mesh, PT.pick_spec(
                mesh, (Z, b), [{0: "data", 1: "pod"}, {0: "data"}, {}]
                if "pod" in mesh.axis_names else [{0: "data"}, {}]))
            jitted = jax.jit(step, in_shardings=(p_sh, l_sh, c_sh, t_sh))
            args = (params, lora, spec["cache"], spec["tokens"])

        t0 = time.time()
        with mesh:
            lowered = jitted.lower(*args)
        res.lower_s = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        res.compile_s = time.time() - t0

        mem = compiled.memory_analysis()
        if mem is not None:
            res.memory_analysis = str(mem)
            for attr in ("temp_size_in_bytes",):
                if hasattr(mem, attr):
                    tmp = getattr(mem, attr)
                    arg = getattr(mem, "argument_size_in_bytes", 0)
                    outb = getattr(mem, "output_size_in_bytes", 0)
                    res.memory_per_device = float(tmp + arg)
        cost = compiled.cost_analysis()
        if cost:
            res.cost_analysis_flops = float(cost.get("flops", 0.0))
            res.cost_analysis_bytes = float(cost.get("bytes accessed", 0.0))
        text = compiled.as_text()
        hl = HLO.analyze(text)
        # trip-count-weighted per-device numbers (see roofline/hlo.py —
        # cost_analysis counts while bodies once)
        res.flops = hl["flops"]
        res.hlo_bytes = 2.0 * hl["bytes_written"]   # write + read per buffer
        res.collectives = hl["collectives"]
        res.collective_traffic = hl["collective_traffic"]
        res.ok = True
        if verbose:
            print(f"[OK] {arch} x {shape_name} x {mesh_name}: "
                  f"lower {res.lower_s:.1f}s compile {res.compile_s:.1f}s "
                  f"flops {res.flops:.3e} bytes {res.hlo_bytes:.3e} "
                  f"coll {res.collective_traffic:.3e}")
            print(f"     memory_analysis: {res.memory_analysis[:200]}")
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        res.error = f"{type(e).__name__}: {e}\n{traceback.format_exc()}"
        if verbose:
            print(f"[FAIL] {arch} x {shape_name} x {mesh_name}: "
                  f"{type(e).__name__}: {str(e)[:300]}")
    if save:
        root = OUT_DIR if opt_level == 0 else OUT_DIR + f"_opt{opt_level}"
        d = os.path.join(root, mesh_name)
        os.makedirs(d, exist_ok=True)
        with open(os.path.join(d, f"{arch}__{shape_name}.json"), "w") as f:
            json.dump(res.to_json(), f, indent=1, default=str)
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ASSIGNED + ["all"])
    ap.add_argument("--shape", default=None,
                    choices=sorted(SHAPES) + ["all"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--opt-level", type=int, default=0,
                    help="0=paper baseline; 1=+weight-gather+attn layouts; "
                         "2=+inner-scan remat & chunk=32 (§Perf)")
    args = ap.parse_args()

    archs = ASSIGNED if (args.all or args.arch in (None, "all")) \
        else [args.arch]
    shapes = sorted(SHAPES) if (args.all or args.shape in (None, "all")) \
        else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) \
        else [args.multi_pod]

    results = []
    for mp in meshes:
        for a in archs:
            for s in shapes:
                results.append(dryrun_one(a, s, mp,
                                          opt_level=args.opt_level))
    ok = sum(r.ok for r in results)
    print(f"\n=== dry-run: {ok}/{len(results)} combos compiled ===")
    if ok < len(results):
        for r in results:
            if not r.ok:
                print(f"  FAILED: {r.arch} x {r.shape} x {r.mesh}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
