"""Distributed step builders: core steps + activation sharding policy.

The sharding policy (launch/partitioning.py) is installed via
models/shardctx for the duration of TRACING, so the same model code runs
unsharded in tests and fully annotated under pjit.
"""
from __future__ import annotations

from typing import Callable

from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.core import steps as S
from repro.launch import partitioning as PT
from repro.models import shardctx


def _wrap(mesh: Mesh, fn: Callable, seq_shard: bool = True,
          opt_level: int = 0, step_kind: str = "train") -> Callable:
    policy = PT.activation_policy(mesh, seq_shard=seq_shard,
                                  opt_level=opt_level, step_kind=step_kind)

    def wrapped(*args, **kw):
        with shardctx.sharding_policy(policy):
            return fn(*args, **kw)

    return wrapped


def make_train_step(cfg: ModelConfig, mesh: Mesh, *, loss_kind="sft",
                    remat: bool = True, seq_shard: bool = True,
                    opt_level: int = 0) -> Callable:
    return _wrap(mesh, S.make_train_step(cfg, loss_kind=loss_kind,
                                         remat=remat), seq_shard, opt_level,
                 "train")


def make_eval_step(cfg: ModelConfig, mesh: Mesh, *, opt_level: int = 0,
                   **kw) -> Callable:
    return _wrap(mesh, S.make_eval_step(cfg, **kw), True, opt_level,
                 "prefill")


def make_prefill_step(cfg: ModelConfig, mesh: Mesh,
                      *, opt_level: int = 0) -> Callable:
    return _wrap(mesh, S.make_prefill_step(cfg), True, opt_level, "prefill")


def make_serve_step(cfg: ModelConfig, mesh: Mesh,
                    *, opt_level: int = 0) -> Callable:
    return _wrap(mesh, S.make_serve_step(cfg), True, opt_level, "decode")
