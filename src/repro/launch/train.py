"""Production training launcher: pjit multi-LoRA training on a real mesh.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b \
        --shape train_4k --steps 10 [--reduced] [--mesh dxm]

On TPU hardware this builds the (data, model) mesh over the real devices
and runs the Adapter-Parallel train step with the production sharding
rules; on this CPU container use ``--reduced`` (tiny variant of the same
architecture, 1x1 mesh) for a functional end-to-end pass. The step function,
sharding rules, and data layout are identical in both modes — only the mesh
and the config dims change.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ASSIGNED, get_arch
from repro.configs.shapes import get_shape
from repro.core import lora as LORA
from repro.data.synthetic import SlotBatcher, make_task_dataset
from repro.launch import partitioning as PT
from repro.launch import steps_dist
from repro.models import model as M
from repro.optim import adamw


def build_mesh(spec: str) -> jax.sharding.Mesh:
    d, m = (int(x) for x in spec.split("x"))
    return jax.make_mesh((d, m), ("data", "model"),
                         devices=jax.devices()[:d * m])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b",
                    choices=ASSIGNED + ["paper-llama-tiny"])
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny variant of the arch (CPU-runnable)")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--rank", type=int, default=8)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    shape = get_shape(args.shape)
    if args.reduced:
        cfg = dataclasses.replace(cfg.reduced(), dtype="float32")
        Z, b, S = 4, 2, 64
    else:
        Z, b = shape.decompose()
        S = shape.seq_len
    mesh = build_mesh(args.mesh)
    print(f"arch={cfg.name} Z={Z} b={b} S={S} "
          f"mesh={dict(mesh.shape)} devices={len(jax.devices())}")

    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    ranks = jnp.full((Z,), min(args.rank, cfg.lora.r_max), jnp.int32)
    lora = LORA.init_lora_tree(key, cfg, Z, ranks, M.target_shapes(cfg))
    opt = adamw.init_state(lora, Z)
    hp = adamw.SlotHParams.broadcast(Z, lr=args.lr)
    active = jnp.ones((Z,), jnp.int32)

    ns = lambda t: PT.to_named(mesh, t)
    p_sh = ns(PT.base_param_specs(mesh, params))
    l_sh = ns(PT.lora_param_specs(mesh, lora))
    o_sh = ns(PT.opt_state_specs(mesh, opt))
    h_sh = ns(PT.hp_specs(mesh, hp))
    v_sh = PT.to_named(mesh, PT.pick_spec(mesh, (Z,), [{0: "data"}, {}]))

    ds = make_task_dataset("launch", cfg.vocab_size, seq_len=S,
                           num_train=max(4 * Z * b, 64), difficulty=0.3)
    batcher = SlotBatcher(ds, Z, b)

    tokens, labels = batcher.next_batch()
    batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
    b_sh = ns(PT.batch_specs(mesh, batch))
    step = jax.jit(steps_dist.make_train_step(cfg, mesh),
                   in_shardings=(p_sh, l_sh, o_sh, h_sh, v_sh, v_sh, b_sh),
                   out_shardings=(l_sh, o_sh, None))
    params = jax.device_put(params, p_sh)
    lora = jax.device_put(lora, l_sh)
    opt = jax.device_put(opt, o_sh)

    with mesh:
        for t in range(args.steps):
            tokens, labels = batcher.next_batch()
            batch = {"tokens": jnp.asarray(tokens),
                     "labels": jnp.asarray(labels)}
            t0 = time.time()
            lora, opt, metrics = step(params, lora, opt, hp, active,
                                      ranks, batch)
            jax.block_until_ready(metrics["per_slot_loss"])
            loss = np.asarray(metrics["per_slot_loss"])
            print(f"step {t:4d}  {time.time() - t0:6.2f}s  "
                  f"loss/slot: {np.array2string(loss, precision=3)}")
    print("done")


if __name__ == "__main__":
    main()
