"""ALTO-JAX subsystem."""
