"""Batched multi-adapter serving driver (decode path).

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-3b \
        --reduced --requests 8 --max-new 16 --seed 3 --ranks 2,4,8

Thin CLI over the serving tier (``repro.serve``): publishes a set of
adapters into an ``AdapterPool`` (per-slot TRUE ranks via ``--ranks``),
then drives prefill + greedy decode for a batch of requests through the
``ServingReplica``/``ServingFrontend`` continuous-batching path — the
same rank-bound serve step the dry-run lowers for decode_32k /
long_500k. ``--ring`` uses the sliding-window ring cache (the long_500k
sub-quadratic path).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ASSIGNED, get_arch
from repro.core import lora as LORA
from repro.data.synthetic import make_task_dataset
from repro.models import model as M
from repro.serve import AdapterPool, ServingFrontend, ServingReplica


def _parse_ranks(spec: str, Z: int, r_max: int) -> list:
    """``--ranks 2,4,8``: one TRUE rank per slot (repeating the last entry
    to fill); empty spec keeps the historical default min(8, r_max)."""
    if not spec:
        return [min(8, r_max)] * Z
    vals = [int(v) for v in spec.split(",") if v]
    assert vals and all(1 <= v <= r_max for v in vals), \
        f"--ranks entries must be in [1, {r_max}]"
    return (vals + [vals[-1]] * Z)[:Z]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b",
                    choices=ASSIGNED + ["paper-llama-tiny"])
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--requests", type=int, default=4,
                    help="requests per adapter slot")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0,
                    help="base-model + adapter init PRNG seed")
    ap.add_argument("--ranks", default="",
                    help="comma-separated per-slot TRUE ranks, e.g. 2,4,8 "
                         "(default: uniform min(8, r_max))")
    ap.add_argument("--ring", action="store_true",
                    help="sliding-window ring cache (long-context mode)")
    ap.add_argument("--mode", default="continuous",
                    choices=["continuous", "round"],
                    help="continuous = per-lane positions, zero join "
                         "barrier; round = legacy epoch batching")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy, the default)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k truncation for sampling (0 = full vocab)")
    ap.add_argument("--sample-seed", type=int, default=0,
                    help="replica-level PRNG seed for sampling")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(cfg.reduced(), dtype="float32")
    Z, b, P = args.slots, args.requests, args.prompt_len
    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(key, cfg)
    ranks = _parse_ranks(args.ranks, Z, cfg.lora.r_max)

    pool = AdapterPool(cfg, Z)
    stack = LORA.init_lora_tree(key, cfg, Z, jnp.asarray(ranks, jnp.int32),
                                M.target_shapes(cfg))
    for z in range(Z):
        adapter = jax.tree_util.tree_map(lambda x: x[:, z], stack)
        pool.publish(f"adapter-{z}", adapter, ranks[z])

    replica = ServingReplica(cfg, params, pool, lanes=b,
                             max_len=P + args.max_new, ring=args.ring,
                             sample_seed=args.sample_seed)
    frontend = ServingFrontend(replica, mode=args.mode)

    ds = make_task_dataset("serve", cfg.vocab_size, seq_len=P,
                           num_train=Z * b, difficulty=0.3,
                           seed=args.seed)
    prompts = ds.train[:Z * b, :P].astype(np.int32).reshape(Z, b, P)
    rids = [[frontend.submit(f"adapter-{z}", prompts[z, i], args.max_new,
                             temperature=args.temperature,
                             top_k=args.top_k, seed=z * b + i)
             for i in range(b)] for z in range(Z)]

    t0 = time.time()
    out = frontend.drain()
    wall = time.time() - t0

    stats = replica
    toks_per_s = stats.total_generated / max(wall, 1e-9)
    print(f"arch={cfg.name} Z={Z} b={b} ranks={ranks} seed={args.seed} "
          f"ring={replica.ring} mode={args.mode} "
          f"temperature={args.temperature} top_k={args.top_k}")
    print(f"served {stats.total_generated} tokens in {wall:.2f}s over "
          f"{stats.total_decode_steps} fused steps "
          f"({toks_per_s:.1f} tok/s aggregate)")
    for z in range(Z):
        print(f"  adapter {z} (rank {ranks[z]}) req 0 continuation: "
              f"{out[rids[z][0]][:12]}")


if __name__ == "__main__":
    main()
