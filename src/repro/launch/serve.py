"""Batched multi-adapter serving driver (decode path).

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-3b \
        --reduced --requests 8 --max-new 16

Loads (or inits) a base model + a slot-stacked adapter set, then serves a
batch of requests through prefill + greedy decode using the same
serve_step the dry-run lowers for decode_32k / long_500k. ``--ring`` uses
the sliding-window ring cache (the long_500k sub-quadratic path).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ASSIGNED, get_arch
from repro.core import lora as LORA
from repro.core.steps import make_prefill_step, make_serve_step
from repro.data.synthetic import make_task_dataset
from repro.models import model as M


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b",
                    choices=ASSIGNED + ["paper-llama-tiny"])
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--requests", type=int, default=4,
                    help="requests per adapter slot")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--ring", action="store_true",
                    help="sliding-window ring cache (long-context mode)")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(cfg.reduced(), dtype="float32")
    Z, b, P = args.slots, args.requests, args.prompt_len
    total = P + args.max_new
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    ranks = jnp.full((Z,), min(8, cfg.lora.r_max), jnp.int32)
    lora = LORA.init_lora_tree(key, cfg, Z, ranks, M.target_shapes(cfg))

    ds = make_task_dataset("serve", cfg.vocab_size, seq_len=P,
                           num_train=Z * b, difficulty=0.3)
    prompts = jnp.asarray(
        ds.train[:Z * b, :P].reshape(Z, b, P).astype(np.int32))

    ring = args.ring and cfg.family != "ssm"
    cache = M.init_cache(cfg, Z, b, total, ring=ring)
    serve = jax.jit(make_serve_step(cfg))

    t0 = time.time()
    # prefill token-by-token through the serve step when using a ring cache
    # (ring writes are per-position); block prefill otherwise
    if ring or cfg.family in ("ssm", "hybrid"):
        logits = None
        for t in range(P):
            logits, cache = serve(params, lora, cache, prompts[:, :, t])
    else:
        prefill = jax.jit(make_prefill_step(cfg))
        logits, cache = prefill(params, lora, cache, {"tokens": prompts})
    t_prefill = time.time() - t0

    out_tokens = [jnp.argmax(logits, axis=-1)]
    t0 = time.time()
    for _ in range(args.max_new - 1):
        logits, cache = serve(params, lora, cache, out_tokens[-1])
        out_tokens.append(jnp.argmax(logits, axis=-1))
    jax.block_until_ready(logits)
    t_decode = time.time() - t0
    gen = np.stack([np.asarray(t) for t in out_tokens], axis=-1)

    toks_per_s = Z * b * (args.max_new - 1) / max(t_decode, 1e-9)
    print(f"arch={cfg.name} Z={Z} b={b} ring={ring}")
    print(f"prefill {P} tokens: {t_prefill:.2f}s; "
          f"decode {args.max_new - 1} steps: {t_decode:.2f}s "
          f"({toks_per_s:.1f} tok/s aggregate)")
    for z in range(Z):
        print(f"  adapter {z} req 0 continuation: {gen[z, 0][:12].tolist()}")


if __name__ == "__main__":
    main()
