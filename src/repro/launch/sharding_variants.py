import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Paper Fig. 13: Adapter Parallelism vs FSDP-style multi-LoRA.

Lowers the SAME train step on the production mesh under two sharding
policies and compares compiled collective traffic + roofline step bound:

  AP   (ours)   : adapter slots Z sharded over "data"; adapter params,
                  grads, optimizer state rank-local (zero adapter
                  collectives over "data").
  FSDP (baseline): adapters REPLICATED over "data" (the paper's "redundant
                  replication"), batch slots still sharded for compute, so
                  every step pays an adapter-gradient all-reduce over
                  "data" plus 16x adapter/optimizer memory.

Run standalone (it owns the 512-device flag):
    PYTHONPATH=src python -m repro.launch.sharding_variants [--arch X]
Writes experiments/ap_vs_fsdp/<arch>__<shape>__<variant>.json.
"""
import argparse
import json

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.configs.shapes import get_shape
from repro.launch import steps_dist
from repro.launch import partitioning as PT
from repro.launch.dryrun import abstract_state, input_specs, sds
from repro.launch.mesh import make_production_mesh
from repro.optim import adamw
from repro.roofline import hlo as HLO

OUT = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                   "experiments", "ap_vs_fsdp")


def lower_variant(arch: str, shape_name: str, variant: str) -> dict:
    cfg = get_arch(arch)
    mesh = make_production_mesh()
    spec = input_specs(arch, shape_name)
    Z = spec["Z"]
    params, lora, opt = abstract_state(cfg, Z)
    ns = lambda t: PT.to_named(mesh, t)

    p_sh = ns(PT.base_param_specs(mesh, params))
    if variant == "ap":
        l_specs = PT.lora_param_specs(mesh, lora)
    elif variant == "fsdp":
        # adapters + optimizer replicated over "data" (paper's FSDP mode)
        l_specs = jax.tree_util.tree_map(
            lambda _: jax.sharding.PartitionSpec(), lora)
    else:
        raise ValueError(variant)
    l_sh = ns(l_specs)
    o_specs = adamw.AdamWState(
        mu=l_specs, nu=jax.tree_util.tree_map(lambda s: s, l_specs),
        count=jax.sharding.PartitionSpec())
    o_sh = ns(o_specs)

    step = steps_dist.make_train_step(cfg, mesh)
    hp = adamw.SlotHParams.broadcast(Z)
    hp_abs = jax.tree_util.tree_map(
        lambda x: sds(x.shape, x.dtype), hp)
    hp_spec = (PT.hp_specs(mesh, hp_abs) if variant == "ap" else
               jax.tree_util.tree_map(
                   lambda _: jax.sharding.PartitionSpec(), hp_abs))
    h_sh = ns(hp_spec)
    vec = sds((Z,), jnp.int32)
    vp = (PT.pick_spec(mesh, (Z,), [{0: "data"}, {}]) if variant == "ap"
          else jax.sharding.PartitionSpec())
    vec_sh = PT.to_named(mesh, vp)
    b_sh = ns(PT.batch_specs(mesh, spec["batch"]))

    # out_shardings pinned: the FSDP baseline must RETURN replicated
    # adapters/optimizer state (otherwise GSPMD silently re-shards the
    # computation into AP and only the 16x memory cost remains)
    jitted = jax.jit(step, in_shardings=(
        p_sh, l_sh, o_sh, h_sh, vec_sh, vec_sh, b_sh),
        out_shardings=(l_sh, o_sh, None))
    with mesh:
        compiled = jitted.lower(
            params, lora, opt, hp_abs, vec, vec, spec["batch"]).compile()
    hl = HLO.analyze(compiled.as_text())
    mem = compiled.memory_analysis()
    rec = {
        "arch": arch, "shape": shape_name, "variant": variant,
        "flops": hl["flops"], "hlo_bytes": 2.0 * hl["bytes_written"],
        "collective_traffic": hl["collective_traffic"],
        "collectives": hl["collectives"],
        "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
    }
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(
            OUT, f"{arch}__{shape_name}__{variant}.json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--shape", default="train_4k")
    args = ap.parse_args()
    for variant in ("ap", "fsdp"):
        r = lower_variant(args.arch, args.shape, variant)
        print(f"{variant}: coll={r['collective_traffic']:.3e} "
              f"bytes={r['hlo_bytes']:.3e} args={r['argument_bytes']:.3e}")


if __name__ == "__main__":
    main()
