"""Pallas TPU kernels: RANK-LOCAL grouped multi-adapter LoRA GEMMs.

The dense kernels (grouped_lora.py) implement rank heterogeneity purely by
zero-masking (paper §A.1): every slot is padded to ``r_max``, so a rank-4
adapter co-located with a rank-64 one pays 16x its true FLOPs and full
``r_max`` VMEM in every grouped GEMM. This module makes rank a first-class
per-slot COMPUTE dimension — the same scalar-prefetch + dead-tile-skip
trick the ragged kernels (ragged.py) apply to token rows, now applied to
the ``r`` axis, and composing with it:

  * two prefetched vectors ride every launch: ``rows: [Z] int32`` (valid
    token rows per slot — PR 4's ragged widths) and ``ranks: [Z] int32``
    (true rank per slot);
  * the ``r`` axis is tiled (``BR``-wide tiles) into its own grid
    dimension; tiles **fully past** ``ranks[z]`` skip the MXU entirely
    under ``@pl.when`` — a rank-4 slot with r_max=64 issues 1 of 8 rank
    tiles per GEMM instead of all 8;
  * the **boundary** rank tile zero-masks A's columns / B's rows on load,
    so correctness never depends on the padded rank region's contents —
    the post-step ``mask_lora_tree`` re-mask is provably redundant on this
    path (the padded region gets zero output and exactly zero gradient;
    tests/test_kernels_ranklocal.py asserts the train-step invariant);
  * all six kernels (fwd S=XA, Y=SB(+base); bwd dS, dX, dA, dB) carry
    both vectors, so batch raggedness and rank locality compose in one
    launch per kernel.

Accumulation note: tiling ``r`` regroups the fp32 contraction of the
S@B / dS@A^T GEMMs, so a full-rank slot inside a MIXED-rank launch is
parity-level (not bitwise) vs the dense kernels. Bitwise equality at
``ranks == r_max`` is delivered one level up: ``ops.ranklocal_grouped_lora``
dispatches concrete full-rank calls to the dense/ragged path (identical
tiling, masks degenerate to identity), exactly as the executor's per-step
dense-vs-ragged dispatch already does for ``rows == T``.

interpret=True is the CPU CI harness, Mosaic is the TPU target.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.grouped_lora import grouped_lora as K

F32 = jnp.float32

BR = 8    # rank-tile width (sublane multiple; r_max is padded to one)


def _row_mask(block: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """Zero rows >= ``valid`` of a (rows, cols) tile."""
    idx = jax.lax.broadcasted_iota(jnp.int32, block.shape, 0)
    return jnp.where(idx < valid, block, jnp.zeros_like(block))


def _col_mask(block: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """Zero columns >= ``valid`` of a (rows, cols) tile."""
    idx = jax.lax.broadcasted_iota(jnp.int32, block.shape, 1)
    return jnp.where(idx < valid, block, jnp.zeros_like(block))


# ---------------------------------------------------------------------------
# forward: S = X @ A          (grid: Z x token-tiles x rank-tiles x K)
# ---------------------------------------------------------------------------

def _xa_kernel(rows_ref, ranks_ref, x_ref, a_ref, s_ref, acc_ref):
    z, m, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    k = pl.program_id(3)
    vrow = rows_ref[z] - m * x_ref.shape[1]
    vr = ranks_ref[z] - j * a_ref.shape[2]

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((vrow > 0) & (vr > 0))       # dead rank/row tiles skip the MXU
    def _acc():
        xm = _row_mask(x_ref[0], vrow)
        am = _col_mask(a_ref[0], vr)
        acc_ref[...] += jnp.dot(xm, am, preferred_element_type=F32)

    @pl.when(k == pl.num_programs(3) - 1)
    def _done():
        s_ref[0] = acc_ref[...].astype(s_ref.dtype)


def xa(x: jnp.ndarray, A: jnp.ndarray, rows: jnp.ndarray,
       ranks: jnp.ndarray, *, bm: int = K.BM, bk: int = K.BK,
       br: int = BR, interpret: bool = False) -> jnp.ndarray:
    """x: [Z,T,din], A: [Z,din,r] -> S [Z,T,r]; rank tiles past ranks[z]
    (and token rows past rows[z]) are skipped and emit zeros."""
    Z, T, din = x.shape
    r = A.shape[2]
    bm, bk, br = min(bm, T), min(bk, din), min(br, r)
    grid = (Z, T // bm, r // br, din // bk)
    return pl.pallas_call(
        _xa_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bm, bk),
                             lambda z, m, j, k, rr, rk: (z, m, k)),
                pl.BlockSpec((1, bk, br),
                             lambda z, m, j, k, rr, rk: (z, k, j)),
            ],
            out_specs=pl.BlockSpec((1, bm, br),
                                   lambda z, m, j, k, rr, rk: (z, m, j)),
            scratch_shapes=[pltpu.VMEM((bm, br), F32)],
        ),
        out_shape=jax.ShapeDtypeStruct((Z, T, r), x.dtype),
        interpret=interpret,
    )(rows.astype(jnp.int32), ranks.astype(jnp.int32), x, A)


# ---------------------------------------------------------------------------
# forward: Y = S @ B * scale (+ Y_base) — rank tiles are the CONTRACTION
# ---------------------------------------------------------------------------

def _sb_kernel(scale_ref, rows_ref, ranks_ref, s_ref, b_ref, y_ref, acc_ref):
    z, m = pl.program_id(0), pl.program_id(1)
    j = pl.program_id(3)
    vrow = rows_ref[z] - m * s_ref.shape[1]
    vr = ranks_ref[z] - j * s_ref.shape[2]

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((vrow > 0) & (vr > 0))
    def _acc():
        sm = _row_mask(s_ref[0], vrow)
        bm_ = _row_mask(b_ref[0], vr)          # B tile rows are the r axis
        acc_ref[...] += jnp.dot(sm, bm_, preferred_element_type=F32)

    @pl.when(j == pl.num_programs(3) - 1)
    def _done():
        y_ref[0] = (acc_ref[...] * scale_ref[z]).astype(y_ref.dtype)


def _sb_add_kernel(scale_ref, rows_ref, ranks_ref, s_ref, b_ref, ybase_ref,
                   y_ref, acc_ref):
    z, m = pl.program_id(0), pl.program_id(1)
    j = pl.program_id(3)
    vrow = rows_ref[z] - m * s_ref.shape[1]
    vr = ranks_ref[z] - j * s_ref.shape[2]

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((vrow > 0) & (vr > 0))
    def _acc():
        sm = _row_mask(s_ref[0], vrow)
        bm_ = _row_mask(b_ref[0], vr)
        acc_ref[...] += jnp.dot(sm, bm_, preferred_element_type=F32)

    @pl.when(j == pl.num_programs(3) - 1)
    def _done():                               # dead slots: base passthrough
        y_ref[0] = (acc_ref[...] * scale_ref[z]
                    + ybase_ref[0].astype(F32)).astype(y_ref.dtype)


def sb_add(s: jnp.ndarray, B: jnp.ndarray, scale: jnp.ndarray,
           rows: jnp.ndarray, ranks: jnp.ndarray, y_base=None, *,
           bm: int = K.BM, bn: int = K.BN, br: int = BR,
           interpret: bool = False) -> jnp.ndarray:
    """s: [Z,T,r], B: [Z,r,dout] -> Y [Z,T,dout]; the r contraction only
    visits rank tiles below ranks[z]."""
    Z, T, r = s.shape
    dout = B.shape[2]
    bm, bn, br = min(bm, T), min(bn, dout), min(br, r)
    grid = (Z, T // bm, dout // bn, r // br)
    in_specs = [
        pl.BlockSpec((1, bm, br), lambda z, m, n, j, sc, rr, rk: (z, m, j)),
        pl.BlockSpec((1, br, bn), lambda z, m, n, j, sc, rr, rk: (z, j, n)),
    ]
    args = [s, B]
    kernel = _sb_kernel
    if y_base is not None:
        in_specs.append(
            pl.BlockSpec((1, bm, bn),
                         lambda z, m, n, j, sc, rr, rk: (z, m, n)))
        args.append(y_base)
        kernel = _sb_add_kernel
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, bm, bn),
                                   lambda z, m, n, j, sc, rr, rk: (z, m, n)),
            scratch_shapes=[pltpu.VMEM((bm, bn), F32)],
        ),
        out_shape=jax.ShapeDtypeStruct((Z, T, dout), s.dtype),
        interpret=interpret,
    )(scale.astype(F32), rows.astype(jnp.int32), ranks.astype(jnp.int32),
      *args)


# ---------------------------------------------------------------------------
# backward: dS = scale * dY @ B^T     (rank tiles are the OUTPUT columns)
# ---------------------------------------------------------------------------

def _ds_kernel(scale_ref, rows_ref, ranks_ref, dy_ref, b_ref, ds_ref,
               acc_ref):
    z, m, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    k = pl.program_id(3)
    vrow = rows_ref[z] - m * dy_ref.shape[1]
    vr = ranks_ref[z] - j * b_ref.shape[1]

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((vrow > 0) & (vr > 0))
    def _acc():
        dym = _row_mask(dy_ref[0], vrow)
        bm_ = _row_mask(b_ref[0], vr)          # B tile rows are the r axis
        acc_ref[...] += jax.lax.dot_general(
            dym, bm_, (((1,), (1,)), ((), ())),
            preferred_element_type=F32)

    @pl.when(k == pl.num_programs(3) - 1)
    def _done():
        ds_ref[0] = (acc_ref[...] * scale_ref[z]).astype(ds_ref.dtype)


def ds(dy: jnp.ndarray, B: jnp.ndarray, scale: jnp.ndarray,
       rows: jnp.ndarray, ranks: jnp.ndarray, *, bm: int = K.BM,
       bk: int = K.BK, br: int = BR, interpret: bool = False) -> jnp.ndarray:
    """dy: [Z,T,dout], B: [Z,r,dout] -> dS [Z,T,r]; columns past ranks[z]
    are exactly zero (their rank tiles never run)."""
    Z, T, dout = dy.shape
    r = B.shape[1]
    bm, bk, br = min(bm, T), min(bk, dout), min(br, r)
    grid = (Z, T // bm, r // br, dout // bk)
    return pl.pallas_call(
        _ds_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bm, bk),
                             lambda z, m, j, k, sc, rr, rk: (z, m, k)),
                pl.BlockSpec((1, br, bk),
                             lambda z, m, j, k, sc, rr, rk: (z, j, k)),
            ],
            out_specs=pl.BlockSpec((1, bm, br),
                                   lambda z, m, j, k, sc, rr, rk: (z, m, j)),
            scratch_shapes=[pltpu.VMEM((bm, br), F32)],
        ),
        out_shape=jax.ShapeDtypeStruct((Z, T, r), dy.dtype),
        interpret=interpret,
    )(scale.astype(F32), rows.astype(jnp.int32), ranks.astype(jnp.int32),
      dy, B)


# ---------------------------------------------------------------------------
# backward: dX = dS @ A^T             (rank tiles are the CONTRACTION)
# ---------------------------------------------------------------------------

def _dx_kernel(rows_ref, ranks_ref, ds_ref, a_ref, dx_ref, acc_ref):
    z, m = pl.program_id(0), pl.program_id(1)
    j = pl.program_id(3)
    vrow = rows_ref[z] - m * ds_ref.shape[1]
    vr = ranks_ref[z] - j * ds_ref.shape[2]

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((vrow > 0) & (vr > 0))
    def _acc():
        dsm = _row_mask(ds_ref[0], vrow)
        am = _col_mask(a_ref[0], vr)           # A tile cols are the r axis
        acc_ref[...] += jax.lax.dot_general(
            dsm, am, (((1,), (1,)), ((), ())),
            preferred_element_type=F32)

    @pl.when(j == pl.num_programs(3) - 1)
    def _done():
        dx_ref[0] = acc_ref[...].astype(dx_ref.dtype)


def dx(ds_: jnp.ndarray, A: jnp.ndarray, rows: jnp.ndarray,
       ranks: jnp.ndarray, *, bm: int = K.BM, bn: int = K.BN,
       br: int = BR, interpret: bool = False) -> jnp.ndarray:
    """ds: [Z,T,r], A: [Z,din,r] -> dX [Z,T,din]; only rank tiles below
    ranks[z] contribute to the contraction."""
    Z, T, r = ds_.shape
    din = A.shape[1]
    bm, bn, br = min(bm, T), min(bn, din), min(br, r)
    grid = (Z, T // bm, din // bn, r // br)
    return pl.pallas_call(
        _dx_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bm, br),
                             lambda z, m, n, j, rr, rk: (z, m, j)),
                pl.BlockSpec((1, bn, br),
                             lambda z, m, n, j, rr, rk: (z, n, j)),
            ],
            out_specs=pl.BlockSpec((1, bm, bn),
                                   lambda z, m, n, j, rr, rk: (z, m, n)),
            scratch_shapes=[pltpu.VMEM((bm, bn), F32)],
        ),
        out_shape=jax.ShapeDtypeStruct((Z, T, din), ds_.dtype),
        interpret=interpret,
    )(rows.astype(jnp.int32), ranks.astype(jnp.int32), ds_, A)


# ---------------------------------------------------------------------------
# backward weight grads: dA = X^T @ dS ; dB = scale * S^T @ dY
# (rank tiles are OUTPUT columns/rows: dead tiles never accumulate, so the
#  padded rank region of the gradients is exactly zero — no re-mask needed)
# ---------------------------------------------------------------------------

def _da_kernel(rows_ref, ranks_ref, x_ref, ds_ref, da_ref, acc_ref):
    z, j = pl.program_id(0), pl.program_id(2)
    t = pl.program_id(3)
    vrow = rows_ref[z] - t * x_ref.shape[1]
    vr = ranks_ref[z] - j * ds_ref.shape[2]

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((vrow > 0) & (vr > 0))
    def _acc():
        xm = _row_mask(x_ref[0], vrow)
        dsm = _col_mask(ds_ref[0], vr)
        acc_ref[...] += jax.lax.dot_general(
            xm, dsm, (((0,), (0,)), ((), ())),
            preferred_element_type=F32)

    @pl.when(t == pl.num_programs(3) - 1)
    def _done():
        da_ref[0] = acc_ref[...]


def da(x: jnp.ndarray, ds_: jnp.ndarray, rows: jnp.ndarray,
       ranks: jnp.ndarray, *, bd: int = K.BN, bt: int = K.BT,
       br: int = BR, interpret: bool = False) -> jnp.ndarray:
    """x: [Z,T,din], ds: [Z,T,r] -> dA [Z,din,r] fp32; columns past
    ranks[z] stay exactly zero."""
    Z, T, din = x.shape
    r = ds_.shape[2]
    bd, bt, br = min(bd, din), min(bt, T), min(br, r)
    grid = (Z, din // bd, r // br, T // bt)
    return pl.pallas_call(
        _da_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bt, bd),
                             lambda z, d, j, t, rr, rk: (z, t, d)),
                pl.BlockSpec((1, bt, br),
                             lambda z, d, j, t, rr, rk: (z, t, j)),
            ],
            out_specs=pl.BlockSpec((1, bd, br),
                                   lambda z, d, j, t, rr, rk: (z, d, j)),
            scratch_shapes=[pltpu.VMEM((bd, br), F32)],
        ),
        out_shape=jax.ShapeDtypeStruct((Z, din, r), F32),
        interpret=interpret,
    )(rows.astype(jnp.int32), ranks.astype(jnp.int32), x, ds_)


def _db_kernel(scale_ref, rows_ref, ranks_ref, s_ref, dy_ref, db_ref,
               acc_ref):
    z, j = pl.program_id(0), pl.program_id(1)
    t = pl.program_id(3)
    vrow = rows_ref[z] - t * s_ref.shape[1]
    vr = ranks_ref[z] - j * s_ref.shape[2]

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((vrow > 0) & (vr > 0))
    def _acc():
        sm = _col_mask(_row_mask(s_ref[0], vrow), vr)
        acc_ref[...] += jax.lax.dot_general(
            sm, dy_ref[0], (((0,), (0,)), ((), ())),
            preferred_element_type=F32)

    @pl.when(t == pl.num_programs(3) - 1)
    def _done():
        db_ref[0] = acc_ref[...] * scale_ref[z]


def db(s: jnp.ndarray, dy: jnp.ndarray, scale: jnp.ndarray,
       rows: jnp.ndarray, ranks: jnp.ndarray, *, bn: int = K.BN,
       bt: int = K.BT, br: int = BR, interpret: bool = False) -> jnp.ndarray:
    """s: [Z,T,r], dy: [Z,T,dout] -> dB [Z,r,dout] fp32; rows past
    ranks[z] stay exactly zero."""
    Z, T, r = s.shape
    dout = dy.shape[2]
    bn, bt, br = min(bn, dout), min(bt, T), min(br, r)
    grid = (Z, r // br, dout // bn, T // bt)
    return pl.pallas_call(
        _db_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bt, br),
                             lambda z, j, n, t, sc, rr, rk: (z, t, j)),
                pl.BlockSpec((1, bt, bn),
                             lambda z, j, n, t, sc, rr, rk: (z, t, n)),
            ],
            out_specs=pl.BlockSpec((1, br, bn),
                                   lambda z, j, n, t, sc, rr, rk: (z, j, n)),
            scratch_shapes=[pltpu.VMEM((br, bn), F32)],
        ),
        out_shape=jax.ShapeDtypeStruct((Z, r, dout), F32),
        interpret=interpret,
    )(scale.astype(F32), rows.astype(jnp.int32), ranks.astype(jnp.int32),
      s, dy)
