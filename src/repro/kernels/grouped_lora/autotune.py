"""Tile-plan autotuning for the grouped-LoRA kernel family.

The rank-local kernels shipped with guessed block constants — ``BR = 8``
against the MXU's 128 lanes, ``BM/BN/BK/BT`` inherited from the dense
kernels — and the ROADMAP flagged them as the remaining rank-depth thread.
This module closes it: a ``TilePlan`` names one candidate block shape
``(BT, BM, BN, BK, BR)``, the autotuner enumerates the sublane/MXU-legal
candidates for a ``(d_in, d_out, r_max, Z, token-bucket)`` key, times each
on the six rank-local kernels (fwd S=XA / Y=SB and the four bwd kernels)
via ``profiler.measure_throughput`` (warmup + median-of-repeats, so
winners aren't picked off compile time or timer noise), and caches the
winner twice: in-process (like ``ops._tile_plan``) and durably through
``ProfileStore.put_spec(..., durable=True)`` so later sessions skip the
sweep.

**The bitwise contract.** Tuned plans must produce outputs bitwise
identical to the default constants (the executor's fused-vs-solo and
migration proofs lean on bit-stable kernels). Tiling a *parallel* grid
dimension only re-partitions independent output tiles — same per-element
contraction, same fp32 accumulation order — but tiling a *contraction*
dimension regroups the fp32 sums. Each block field therefore tunes only
where its axis is parallel:

  * ``bm`` (token rows) and ``bn`` (output features) are parallel in every
    kernel they touch — freely tunable;
  * ``br`` (rank tile) is parallel in xa / ds / da / db (rank is an OUTPUT
    axis there) and is tuned for those four; sb / dx contract over rank,
    so they keep the default ``ranklocal.BR`` grouping;
  * ``bk`` / ``bt`` are pure contraction blocks (d_in/d_out resp. token
    contraction) — candidates pin them to the default grouping. They stay
    in the plan so a future parity-level (TPU, non-bitwise) sweep can
    open them without an interface change.

The sweep *verifies* the contract per candidate — all six kernel outputs
are compared bitwise against the default plan's on the probe operands and
non-identical candidates are discarded — so the winner is bitwise-equal by
construction, not by hope. The default plan always competes, so the tuned
plan is never slower than the default on the probe.

interpret=True times the CPU interpret-mode harness (this container's
hardware); on TPU the same sweep times Mosaic lowerings.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.grouped_lora import grouped_lora as K
from repro.kernels.grouped_lora import ranklocal as RL

_LANE = 128   # MXU lane width: last-dim block unit
_SUB = 8      # fp32 sublane: second-to-last-dim block unit

PLAN_SPEC_VERSION = 1


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """One candidate block shape for the grouped-LoRA kernel family.

    Field roles (see module docstring for the bitwise rationale):
    ``bm`` token-row block, ``bn`` output-feature block, ``bk`` feature
    contraction block, ``bt`` token contraction block (weight grads),
    ``br`` rank tile (applied where rank is an output axis)."""
    bm: int = K.BM
    bn: int = K.BN
    bk: int = K.BK
    bt: int = K.BT
    br: int = RL.BR

    def to_json(self) -> Dict[str, int]:
        return {"version": PLAN_SPEC_VERSION, "bm": self.bm, "bn": self.bn,
                "bk": self.bk, "bt": self.bt, "br": self.br}

    @classmethod
    def from_json(cls, d: Dict) -> Optional["TilePlan"]:
        if not isinstance(d, dict) or d.get("version") != PLAN_SPEC_VERSION:
            return None
        return cls(bm=int(d["bm"]), bn=int(d["bn"]), bk=int(d["bk"]),
                   bt=int(d["bt"]), br=int(d["br"]))


DEFAULT_PLAN = TilePlan()


def token_bucket(tokens: int) -> int:
    """Round a token count up to the next power of two (floor ``_SUB``):
    nearby fused-step widths share one tuned plan instead of sweeping per
    exact T."""
    b = _SUB
    while b < tokens:
        b *= 2
    return b


def plan_key(d_in: int, d_out: int, r_max: int, Z: int,
             tokens: int) -> Tuple:
    """The autotune cache key — flat JSON-representable tuple, shared by
    the in-process cache and the ProfileStore durable-spec layer."""
    return ("tile_plan", PLAN_SPEC_VERSION, int(d_in), int(d_out),
            int(r_max), int(Z), token_bucket(int(tokens)))


def padded_dims(tokens: int, d_in: int, d_out: int,
                r_max: int) -> Tuple[int, int, int, int]:
    """(Tp, dinp, doutp, rp) the ops wrapper pads operands to — blocks
    must divide these, not the raw shapes."""
    from repro.kernels.grouped_lora import ops
    return ops._tile_plan(tokens, d_in, d_out, r_max)


def _divides(block: int, dim: int) -> bool:
    """A block is grid-legal for a dim if it covers it whole (the kernel
    wrappers ``min()`` it down) or divides it exactly — a non-divisor
    below the dim would silently drop tiles (``dim // block`` floors)."""
    return block >= dim or dim % block == 0


def is_legal(plan: TilePlan, tokens: int, d_in: int, d_out: int,
             r_max: int) -> bool:
    """Sublane/MXU legality of a plan for one shape key: every field a
    positive multiple of its axis unit (sublane 8 for token/rank axes,
    lane 128 for feature axes) and grid-exact against the padded dims on
    every axis it tiles (``bn``/``bk`` touch BOTH d_in and d_out)."""
    Tp, dinp, doutp, rp = padded_dims(tokens, d_in, d_out, r_max)
    if min(plan.bm, plan.bn, plan.bk, plan.bt, plan.br) <= 0:
        return False
    if plan.bm % _SUB or plan.bt % _SUB or plan.br % _SUB:
        return False
    if plan.bn % _LANE and plan.bn < min(dinp, doutp):
        return False
    if plan.bk % _LANE and plan.bk < min(dinp, doutp):
        return False
    return (_divides(plan.bm, Tp) and _divides(plan.bt, Tp)
            and _divides(plan.bn, dinp) and _divides(plan.bn, doutp)
            and _divides(plan.bk, dinp) and _divides(plan.bk, doutp)
            and _divides(plan.br, rp))


def _axis_choices(dim: int, unit: int, cap: int) -> List[int]:
    """Unit-multiples that exactly divide ``dim`` (ascending, <= cap),
    plus ``dim`` itself — the one-tile-covers-all candidate."""
    out = [b for b in range(unit, min(dim, cap) + 1, unit)
           if dim % b == 0]
    if dim not in out:
        out.append(dim)
    return out


def candidate_plans(tokens: int, d_in: int, d_out: int, r_max: int,
                    max_candidates: int = 12) -> List[TilePlan]:
    """Legal candidate block shapes for one shape key.

    ``bm`` sweeps sublane-multiple divisors of the padded token dim,
    ``bn`` lane-multiple divisors legal for BOTH feature dims, ``br``
    sublane-multiple divisors of the padded rank dim. ``bk``/``bt`` are
    pinned to the defaults (contraction grouping — the bitwise contract,
    module docstring). The default plan is always candidate 0; the rest
    are evenly subsampled down to ``max_candidates``."""
    Tp, dinp, doutp, rp = padded_dims(tokens, d_in, d_out, r_max)
    bms = _axis_choices(Tp, _SUB, 256)
    brs = _axis_choices(rp, _SUB, 256)
    bns = [b for b in _axis_choices(doutp, _LANE, 1024)
           if _divides(b, dinp)]
    if not bns:
        bns = [K.BN]
    plans: List[TilePlan] = [DEFAULT_PLAN]
    for bm in bms:
        for bn in bns:
            for br in brs:
                p = TilePlan(bm=bm, bn=bn, br=br)
                if p != DEFAULT_PLAN and is_legal(p, tokens, d_in, d_out,
                                                 r_max):
                    plans.append(p)
    if len(plans) > max_candidates:
        rest = plans[1:]
        stride = len(rest) / (max_candidates - 1)
        plans = [plans[0]] + [rest[int(i * stride)]
                              for i in range(max_candidates - 1)]
    return plans


# ---------------------------------------------------------------------------
# The sweep: time each candidate on the six rank-local kernels
# ---------------------------------------------------------------------------

def _probe_operands(Z: int, tokens: int, d_in: int, d_out: int, r_max: int,
                    seed: int = 0):
    """Representative operands: mixed true ranks (so dead rank tiles and
    boundary masks are both exercised) and a ragged row tail."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (Z, tokens, d_in), jnp.float32)
    A = 0.1 * jax.random.normal(ks[1], (Z, d_in, r_max), jnp.float32)
    B = 0.1 * jax.random.normal(ks[2], (Z, r_max, d_out), jnp.float32)
    dy = jax.random.normal(ks[3], (Z, tokens, d_out), jnp.float32)
    scale = jnp.ones((Z,), jnp.float32)
    sweep = [r for r in (r_max // 8, r_max // 4, r_max // 2, r_max) if r > 0]
    ranks = jnp.asarray([max(_SUB, sweep[z % len(sweep)])
                         for z in range(Z)], jnp.int32)
    rows = jnp.asarray([tokens if z % 2 == 0 else max(tokens // 2, 1)
                        for z in range(Z)], jnp.int32)
    return x, A, B, dy, scale, rows, ranks


def six_kernel_step(plan: TilePlan, interpret: bool = True):
    """A jitted function running all six rank-local kernels under one
    plan — the autotuner's unit of timing AND of bitwise comparison.
    ``br`` applies only where rank is an output axis (xa/ds/da/db); the
    rank-contraction kernels (sb/dx) keep the default grouping."""

    def step(x, A, B, dy, scale, rows, ranks):
        s = RL.xa(x, A, rows, ranks, bm=plan.bm, bk=plan.bk, br=plan.br,
                  interpret=interpret)
        y = RL.sb_add(s, B, scale, rows, ranks, bm=plan.bm, bn=plan.bn,
                      br=RL.BR, interpret=interpret)
        ds_ = RL.ds(dy, B, scale, rows, ranks, bm=plan.bm, bk=plan.bk,
                    br=plan.br, interpret=interpret)
        dx_ = RL.dx(ds_, A, rows, ranks, bm=plan.bm, bn=plan.bn, br=RL.BR,
                    interpret=interpret)
        dA_ = RL.da(x, ds_, rows, ranks, bd=plan.bn, bt=plan.bt,
                    br=plan.br, interpret=interpret)
        dB_ = RL.db(s, dy, scale, rows, ranks, bn=plan.bn, bt=plan.bt,
                    br=plan.br, interpret=interpret)
        return s, y, ds_, dx_, dA_, dB_

    return jax.jit(step)


def kernel_family_flops(Z: int, tokens: int, d_in: int, d_out: int,
                        r_max: int) -> float:
    """Dense-equivalent MAC*2 count of the six kernels (normalization for
    throughput reporting; identical across candidates so ratios hold)."""
    fwd = 2.0 * Z * tokens * r_max * (d_in + d_out)
    bwd = 2.0 * fwd      # ds+dx+dA+dB mirror the two fwd GEMMs twice over
    return fwd + bwd


@dataclasses.dataclass
class CandidateTiming:
    plan: TilePlan
    seconds: float
    bitwise_equal_default: bool


@dataclasses.dataclass
class TuneResult:
    """Everything the bench/report layers need from one sweep."""
    key: Tuple
    plan: TilePlan                      # the winner
    default_s: float
    best_s: float
    flops: float
    candidates: List[CandidateTiming]

    @property
    def speedup(self) -> float:
        return self.default_s / max(self.best_s, 1e-12)

    @property
    def default_flops_per_s(self) -> float:
        return self.flops / max(self.default_s, 1e-12)

    @property
    def tuned_flops_per_s(self) -> float:
        return self.flops / max(self.best_s, 1e-12)


def sweep(d_in: int, d_out: int, r_max: int, Z: int = 4,
          tokens: int = 128, *, interpret: bool = True,
          max_candidates: int = 12, iters: int = 2, repeats: int = 3,
          seed: int = 0) -> TuneResult:
    """Time every legal candidate on the six kernels; return the fastest
    bitwise-equal-to-default candidate (the default itself competes, so
    the winner is never slower than default on the probe)."""
    from repro.sched.profiler import measure_throughput
    args = _probe_operands(Z, tokens, d_in, d_out, r_max, seed)
    plans = candidate_plans(tokens, d_in, d_out, r_max, max_candidates)
    baseline = jax.tree_util.tree_map(
        np.asarray, six_kernel_step(DEFAULT_PLAN, interpret)(*args))
    timings: List[CandidateTiming] = []
    default_s = best_s = None
    best: TilePlan = DEFAULT_PLAN
    for plan in plans:
        fn = six_kernel_step(plan, interpret)
        outs = jax.tree_util.tree_map(np.asarray, fn(*args))
        bitwise = all(o.tobytes() == b.tobytes()
                      for o, b in zip(outs, baseline))
        prof = measure_throughput(fn, args, total_batch=Z,
                                  iters=iters, repeats=repeats)
        timings.append(CandidateTiming(plan, prof.step_time_s, bitwise))
        if plan == DEFAULT_PLAN:
            default_s = prof.step_time_s
        if bitwise and (best_s is None or prof.step_time_s < best_s):
            best_s, best = prof.step_time_s, plan
    assert default_s is not None and best_s is not None
    return TuneResult(key=plan_key(d_in, d_out, r_max, Z, tokens),
                      plan=best, default_s=default_s, best_s=best_s,
                      flops=kernel_family_flops(Z, tokens, d_in, d_out,
                                                r_max),
                      candidates=timings)


# ---------------------------------------------------------------------------
# Cached entry point: in-process + ProfileStore-durable winners
# ---------------------------------------------------------------------------

_PLANS: Dict[Tuple, TilePlan] = {}


def clear_plan_cache() -> None:
    """Drop the in-process winner cache (tests)."""
    _PLANS.clear()


def autotune_tile_plan(d_in: int, d_out: int, r_max: int, Z: int = 4,
                       tokens: int = 128, *, interpret: bool = True,
                       store=None, max_candidates: int = 12,
                       iters: int = 2, repeats: int = 3,
                       seed: int = 0) -> TilePlan:
    """The tuned plan for a shape key, cheapest source first: in-process
    cache -> ProfileStore durable spec (a previous session's sweep) ->
    fresh sweep (then persisted through both). ``store`` is a
    ``ProfileStore`` or None (no cross-session persistence)."""
    key = plan_key(d_in, d_out, r_max, Z, tokens)
    hit = _PLANS.get(key)
    if hit is not None:
        return hit
    if store is not None:
        spec = store.get_spec(key)
        plan = TilePlan.from_json(spec) if spec is not None else None
        if plan is not None and is_legal(plan, tokens, d_in, d_out, r_max):
            _PLANS[key] = plan
            return plan
    result = sweep(d_in, d_out, r_max, Z, tokens, interpret=interpret,
                   max_candidates=max_candidates, iters=iters,
                   repeats=repeats, seed=seed)
    _PLANS[key] = result.plan
    if store is not None:
        store.put_spec(key, result.plan.to_json(), durable=True)
    return result.plan


def plan_for(shapes: Sequence[int], *, store=None,
             interpret: bool = True) -> TilePlan:
    """Convenience: ``shapes = (Z, tokens, d_in, d_out, r_max)`` — the
    executor-facing signature."""
    Z, tokens, d_in, d_out, r_max = shapes
    return autotune_tile_plan(d_in, d_out, r_max, Z, tokens,
                              interpret=interpret, store=store)
