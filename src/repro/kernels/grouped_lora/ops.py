"""Differentiable jit wrapper over the grouped-LoRA Pallas kernels.

``grouped_lora(x, A, B, scale, y_base=None)`` == scale*(x@A)@B (+ y_base),
grouped over the leading slot axis, with a custom VJP that reuses the
paper's backward schedule (dS/dX/dA/dB grouped kernels, forward caches S —
paper §6.1 "the forward caches intermediate S to avoid recomputation").

The wrapper pads T / d_in / d_out / r up to tile multiples (zero padding is
exact for every kernel: padded rows/cols of x/A/B are zero and padded
outputs are sliced away) so arbitrary shapes hit the fixed-tile kernels.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.grouped_lora import grouped_lora as K
from repro.kernels.grouped_lora import ragged as R

_LANE = 128   # TPU lane width; last-dim tile multiple
_SUB = 8      # sublane multiple


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def _pad_axis(x: jnp.ndarray, axis: int, to: int) -> jnp.ndarray:
    pad = to - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _tile_plan(T: int, din: int, dout: int, r: int
               ) -> Tuple[int, int, int, int]:
    Tp = _ceil_to(T, min(K.BM, _ceil_to(T, _SUB)))
    Tp = _ceil_to(Tp, _SUB)
    dinp = _ceil_to(din, min(K.BK, _ceil_to(din, _LANE)))
    doutp = _ceil_to(dout, min(K.BN, _ceil_to(dout, _LANE)))
    rp = _ceil_to(r, _SUB)
    return Tp, dinp, doutp, rp


# ---------------------------------------------------------------------------
# core padded implementations (not differentiable; used by fwd/bwd rules)
# ---------------------------------------------------------------------------

def _fwd_impl(x, A, B, scale, y_base, interpret):
    Z, T, din = x.shape
    r, dout = B.shape[1], B.shape[2]
    Tp, dinp, doutp, rp = _tile_plan(T, din, dout, r)
    xp = _pad_axis(_pad_axis(x, 1, Tp), 2, dinp)
    Ap = _pad_axis(_pad_axis(A, 1, dinp), 2, rp).astype(x.dtype)
    Bp = _pad_axis(_pad_axis(B, 1, rp), 2, doutp).astype(x.dtype)
    s = K.xa(xp, Ap, interpret=interpret)
    yb = None
    if y_base is not None:
        yb = _pad_axis(_pad_axis(y_base, 1, Tp), 2, doutp)
    y = K.sb_add(s, Bp, scale, yb, interpret=interpret)
    return y[:, :T, :dout], s[:, :T, :]      # s padded on r only


def _bwd_impl(x, A, B, scale, s, dy, interpret):
    Z, T, din = x.shape
    r, dout = B.shape[1], B.shape[2]
    Tp, dinp, doutp, rp = _tile_plan(T, din, dout, r)
    xp = _pad_axis(_pad_axis(x, 1, Tp), 2, dinp)
    Ap = _pad_axis(_pad_axis(A, 1, dinp), 2, rp).astype(x.dtype)
    Bp = _pad_axis(_pad_axis(B, 1, rp), 2, doutp).astype(x.dtype)
    sp = _pad_axis(s, 1, Tp)
    dyp = _pad_axis(_pad_axis(dy, 1, Tp), 2, doutp).astype(x.dtype)
    ds_ = K.ds(dyp, Bp, scale, interpret=interpret)
    dx_ = K.dx(ds_, Ap, interpret=interpret)
    dA_ = K.da(xp, ds_, interpret=interpret)
    dB_ = K.db(sp, dyp, scale, interpret=interpret)
    return (dx_[:, :T, :din], dA_[:, :din, :r], dB_[:, :r, :dout])


# ---------------------------------------------------------------------------
# custom_vjp variants (cached per (interpret, has_base))
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _make_fn(interpret: bool, has_base: bool):
    if has_base:
        @jax.custom_vjp
        def f(x, A, B, scale, y_base):
            y, _ = _fwd_impl(x, A, B, scale, y_base, interpret)
            return y

        def f_fwd(x, A, B, scale, y_base):
            y, s = _fwd_impl(x, A, B, scale, y_base, interpret)
            return y, (x, A, B, scale, s)

        def f_bwd(res, dy):
            x, A, B, scale, s = res
            dx_, dA_, dB_ = _bwd_impl(x, A, B, scale, s, dy, interpret)
            dscale = jnp.zeros_like(scale)   # scale is a hyperparam
            return dx_, dA_, dB_, dscale, dy

        f.defvjp(f_fwd, f_bwd)
        return f

    @jax.custom_vjp
    def g(x, A, B, scale):
        y, _ = _fwd_impl(x, A, B, scale, None, interpret)
        return y

    def g_fwd(x, A, B, scale):
        y, s = _fwd_impl(x, A, B, scale, None, interpret)
        return y, (x, A, B, scale, s)

    def g_bwd(res, dy):
        x, A, B, scale, s = res
        dx_, dA_, dB_ = _bwd_impl(x, A, B, scale, s, dy, interpret)
        return dx_, dA_, dB_, jnp.zeros_like(scale)

    g.defvjp(g_fwd, g_bwd)
    return g


def grouped_lora(x: jnp.ndarray, A: jnp.ndarray, B: jnp.ndarray,
                 scale: jnp.ndarray,
                 y_base: Optional[jnp.ndarray] = None, *,
                 interpret: bool = False) -> jnp.ndarray:
    """Differentiable grouped LoRA: scale*(x@A)@B (+ y_base).

    x: [Z,T,din]; A: [Z,din,r]; B: [Z,r,dout]; scale: [Z].
    """
    fn = _make_fn(bool(interpret), y_base is not None)
    if y_base is not None:
        return fn(x, A, B, scale, y_base)
    return fn(x, A, B, scale)


# ---------------------------------------------------------------------------
# ragged variant: per-slot token-row counts (heterogeneous batch widths)
# ---------------------------------------------------------------------------

def _ragged_fwd_impl(x, A, B, scale, rows, y_base, interpret):
    Z, T, din = x.shape
    r, dout = B.shape[1], B.shape[2]
    Tp, dinp, doutp, rp = _tile_plan(T, din, dout, r)
    xp = _pad_axis(_pad_axis(x, 1, Tp), 2, dinp)
    Ap = _pad_axis(_pad_axis(A, 1, dinp), 2, rp).astype(x.dtype)
    Bp = _pad_axis(_pad_axis(B, 1, rp), 2, doutp).astype(x.dtype)
    s = R.xa(xp, Ap, rows, interpret=interpret)
    yb = None
    if y_base is not None:
        yb = _pad_axis(_pad_axis(y_base, 1, Tp), 2, doutp)
    y = R.sb_add(s, Bp, scale, rows, yb, interpret=interpret)
    return y[:, :T, :dout], s[:, :T, :]


def _ragged_bwd_impl(x, A, B, scale, rows, s, dy, interpret):
    Z, T, din = x.shape
    r, dout = B.shape[1], B.shape[2]
    Tp, dinp, doutp, rp = _tile_plan(T, din, dout, r)
    xp = _pad_axis(_pad_axis(x, 1, Tp), 2, dinp)
    Ap = _pad_axis(_pad_axis(A, 1, dinp), 2, rp).astype(x.dtype)
    Bp = _pad_axis(_pad_axis(B, 1, rp), 2, doutp).astype(x.dtype)
    sp = _pad_axis(s, 1, Tp)
    dyp = _pad_axis(_pad_axis(dy, 1, Tp), 2, doutp).astype(x.dtype)
    ds_ = R.ds(dyp, Bp, scale, rows, interpret=interpret)
    dx_ = R.dx(ds_, Ap, rows, interpret=interpret)
    dA_ = R.da(xp, ds_, rows, interpret=interpret)
    dB_ = R.db(sp, dyp, scale, rows, interpret=interpret)
    return (dx_[:, :T, :din], dA_[:, :din, :r], dB_[:, :r, :dout])


def _rows_cotangent(rows):
    # integer primal => float0 cotangent (rows carries no gradient)
    return np.zeros(np.shape(rows), jax.dtypes.float0)


@functools.lru_cache(maxsize=None)
def _make_ragged_fn(interpret: bool, has_base: bool):
    if has_base:
        @jax.custom_vjp
        def f(x, A, B, scale, rows, y_base):
            y, _ = _ragged_fwd_impl(x, A, B, scale, rows, y_base, interpret)
            return y

        def f_fwd(x, A, B, scale, rows, y_base):
            y, s = _ragged_fwd_impl(x, A, B, scale, rows, y_base, interpret)
            return y, (x, A, B, scale, rows, s)

        def f_bwd(res, dy):
            x, A, B, scale, rows, s = res
            dx_, dA_, dB_ = _ragged_bwd_impl(x, A, B, scale, rows, s, dy,
                                             interpret)
            return (dx_, dA_, dB_, jnp.zeros_like(scale),
                    _rows_cotangent(rows), dy)

        f.defvjp(f_fwd, f_bwd)
        return f

    @jax.custom_vjp
    def g(x, A, B, scale, rows):
        y, _ = _ragged_fwd_impl(x, A, B, scale, rows, None, interpret)
        return y

    def g_fwd(x, A, B, scale, rows):
        y, s = _ragged_fwd_impl(x, A, B, scale, rows, None, interpret)
        return y, (x, A, B, scale, rows, s)

    def g_bwd(res, dy):
        x, A, B, scale, rows, s = res
        dx_, dA_, dB_ = _ragged_bwd_impl(x, A, B, scale, rows, s, dy,
                                         interpret)
        return (dx_, dA_, dB_, jnp.zeros_like(scale),
                _rows_cotangent(rows))

    g.defvjp(g_fwd, g_bwd)
    return g


def ragged_grouped_lora(x: jnp.ndarray, A: jnp.ndarray, B: jnp.ndarray,
                        scale: jnp.ndarray, rows: jnp.ndarray,
                        y_base: Optional[jnp.ndarray] = None, *,
                        interpret: bool = False) -> jnp.ndarray:
    """Differentiable RAGGED grouped LoRA: slot z applies its adapter to
    only the first ``rows[z]`` token rows of its lane; padded rows get a
    zero delta (y_base passes through) and zero gradients.

    x: [Z,T,din]; A: [Z,din,r]; B: [Z,r,dout]; scale: [Z]; rows: [Z] int.
    ``rows == T`` everywhere reproduces ``grouped_lora`` exactly — the
    executor dispatches dense for homogeneous mixes, ragged otherwise.
    """
    fn = _make_ragged_fn(bool(interpret), y_base is not None)
    if y_base is not None:
        return fn(x, A, B, scale, rows, y_base)
    return fn(x, A, B, scale, rows)
