"""Differentiable jit wrapper over the grouped-LoRA Pallas kernels.

``grouped_lora(x, A, B, scale, y_base=None)`` == scale*(x@A)@B (+ y_base),
grouped over the leading slot axis, with a custom VJP that reuses the
paper's backward schedule (dS/dX/dA/dB grouped kernels, forward caches S —
paper §6.1 "the forward caches intermediate S to avoid recomputation").

The wrapper pads T / d_in / d_out / r up to tile multiples (zero padding is
exact for every kernel: padded rows/cols of x/A/B are zero and padded
outputs are sliced away) so arbitrary shapes hit the fixed-tile kernels.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.grouped_lora import grouped_lora as K
from repro.kernels.grouped_lora import ragged as R
from repro.kernels.grouped_lora import ranklocal as RL
from repro.kernels.grouped_lora.autotune import DEFAULT_PLAN, TilePlan

_LANE = 128   # TPU lane width; last-dim tile multiple
_SUB = 8      # sublane multiple


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def _pad_axis(x: jnp.ndarray, axis: int, to: int) -> jnp.ndarray:
    pad = to - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# cached: the plan is pure shape arithmetic, but every trace of every
# variant recomputes it (fwd + 4-kernel bwd per call site) — repeated
# same-shape calls (one per LoRA target per layer per step) hit the cache
@functools.lru_cache(maxsize=None)
def _tile_plan(T: int, din: int, dout: int, r: int
               ) -> Tuple[int, int, int, int]:
    Tp = _ceil_to(T, min(K.BM, _ceil_to(T, _SUB)))
    Tp = _ceil_to(Tp, _SUB)
    dinp = _ceil_to(din, min(K.BK, _ceil_to(din, _LANE)))
    doutp = _ceil_to(dout, min(K.BN, _ceil_to(dout, _LANE)))
    rp = _ceil_to(r, _SUB)
    return Tp, dinp, doutp, rp


# ---------------------------------------------------------------------------
# core padded implementations (not differentiable; used by fwd/bwd rules)
# ---------------------------------------------------------------------------

def _pad_fwd(x, A, B, y_base):
    """Pad (x, A, B, y_base) to the cached tile plan — shared by the
    dense/ragged/rank-local forward impls (they differ only in which
    kernel set consumes the padded operands)."""
    T, din = x.shape[1], x.shape[2]
    r, dout = B.shape[1], B.shape[2]
    Tp, dinp, doutp, rp = _tile_plan(T, din, dout, r)
    xp = _pad_axis(_pad_axis(x, 1, Tp), 2, dinp)
    Ap = _pad_axis(_pad_axis(A, 1, dinp), 2, rp).astype(x.dtype)
    Bp = _pad_axis(_pad_axis(B, 1, rp), 2, doutp).astype(x.dtype)
    yb = None
    if y_base is not None:
        yb = _pad_axis(_pad_axis(y_base, 1, Tp), 2, doutp)
    return xp, Ap, Bp, yb


def _pad_bwd(x, A, B, s, dy):
    """Pad the backward operands (residual s is padded on r already)."""
    xp, Ap, Bp, _ = _pad_fwd(x, A, B, None)
    sp = _pad_axis(s, 1, xp.shape[1])
    dyp = _pad_axis(_pad_axis(dy, 1, xp.shape[1]), 2,
                    Bp.shape[2]).astype(x.dtype)
    return xp, Ap, Bp, sp, dyp


def _fwd_impl(x, A, B, scale, y_base, interpret, plan=DEFAULT_PLAN):
    T, dout = x.shape[1], B.shape[2]
    xp, Ap, Bp, yb = _pad_fwd(x, A, B, y_base)
    s = K.xa(xp, Ap, bm=plan.bm, bk=plan.bk, interpret=interpret)
    y = K.sb_add(s, Bp, scale, yb, bm=plan.bm, bn=plan.bn,
                 interpret=interpret)
    return y[:, :T, :dout], s[:, :T, :]      # s padded on r only


def _bwd_impl(x, A, B, scale, s, dy, interpret, plan=DEFAULT_PLAN):
    T, din = x.shape[1], x.shape[2]
    r, dout = B.shape[1], B.shape[2]
    xp, Ap, Bp, sp, dyp = _pad_bwd(x, A, B, s, dy)
    ds_ = K.ds(dyp, Bp, scale, bm=plan.bm, bk=plan.bk, interpret=interpret)
    dx_ = K.dx(ds_, Ap, bm=plan.bm, bn=plan.bn, interpret=interpret)
    dA_ = K.da(xp, ds_, bd=plan.bn, bt=plan.bt, interpret=interpret)
    dB_ = K.db(sp, dyp, scale, bn=plan.bn, bt=plan.bt, interpret=interpret)
    return (dx_[:, :T, :din], dA_[:, :din, :r], dB_[:, :r, :dout])


# ---------------------------------------------------------------------------
# custom_vjp variants (cached per (interpret, has_base, plan) — TilePlan is
# frozen/hashable, so tuned plans get their own traced variant and the
# default plan keeps hitting the original cache entries)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _make_fn(interpret: bool, has_base: bool, plan: TilePlan = DEFAULT_PLAN):
    if has_base:
        @jax.custom_vjp
        def f(x, A, B, scale, y_base):
            y, _ = _fwd_impl(x, A, B, scale, y_base, interpret, plan)
            return y

        def f_fwd(x, A, B, scale, y_base):
            y, s = _fwd_impl(x, A, B, scale, y_base, interpret, plan)
            return y, (x, A, B, scale, s)

        def f_bwd(res, dy):
            x, A, B, scale, s = res
            dx_, dA_, dB_ = _bwd_impl(x, A, B, scale, s, dy, interpret, plan)
            dscale = jnp.zeros_like(scale)   # scale is a hyperparam
            return dx_, dA_, dB_, dscale, dy

        f.defvjp(f_fwd, f_bwd)
        return f

    @jax.custom_vjp
    def g(x, A, B, scale):
        y, _ = _fwd_impl(x, A, B, scale, None, interpret, plan)
        return y

    def g_fwd(x, A, B, scale):
        y, s = _fwd_impl(x, A, B, scale, None, interpret, plan)
        return y, (x, A, B, scale, s)

    def g_bwd(res, dy):
        x, A, B, scale, s = res
        dx_, dA_, dB_ = _bwd_impl(x, A, B, scale, s, dy, interpret, plan)
        return dx_, dA_, dB_, jnp.zeros_like(scale)

    g.defvjp(g_fwd, g_bwd)
    return g


def grouped_lora(x: jnp.ndarray, A: jnp.ndarray, B: jnp.ndarray,
                 scale: jnp.ndarray,
                 y_base: Optional[jnp.ndarray] = None, *,
                 interpret: bool = False,
                 plan: Optional[TilePlan] = None) -> jnp.ndarray:
    """Differentiable grouped LoRA: scale*(x@A)@B (+ y_base).

    x: [Z,T,din]; A: [Z,din,r]; B: [Z,r,dout]; scale: [Z].
    ``plan`` (an autotuned ``TilePlan``) overrides the static block
    constants; None keeps the defaults. Tuned plans re-tile only parallel
    grid dims, so outputs are bitwise identical to the default plan.
    """
    fn = _make_fn(bool(interpret), y_base is not None,
                  plan if plan is not None else DEFAULT_PLAN)
    if y_base is not None:
        return fn(x, A, B, scale, y_base)
    return fn(x, A, B, scale)


# ---------------------------------------------------------------------------
# ragged variant: per-slot token-row counts (heterogeneous batch widths)
# ---------------------------------------------------------------------------

def _ragged_fwd_impl(x, A, B, scale, rows, y_base, interpret,
                     plan=DEFAULT_PLAN):
    T, dout = x.shape[1], B.shape[2]
    xp, Ap, Bp, yb = _pad_fwd(x, A, B, y_base)
    s = R.xa(xp, Ap, rows, bm=plan.bm, bk=plan.bk, interpret=interpret)
    y = R.sb_add(s, Bp, scale, rows, yb, bm=plan.bm, bn=plan.bn,
                 interpret=interpret)
    return y[:, :T, :dout], s[:, :T, :]


def _ragged_bwd_impl(x, A, B, scale, rows, s, dy, interpret,
                     plan=DEFAULT_PLAN):
    T, din = x.shape[1], x.shape[2]
    r, dout = B.shape[1], B.shape[2]
    xp, Ap, Bp, sp, dyp = _pad_bwd(x, A, B, s, dy)
    ds_ = R.ds(dyp, Bp, scale, rows, bm=plan.bm, bk=plan.bk,
               interpret=interpret)
    dx_ = R.dx(ds_, Ap, rows, bm=plan.bm, bn=plan.bn, interpret=interpret)
    dA_ = R.da(xp, ds_, rows, bd=plan.bn, bt=plan.bt, interpret=interpret)
    dB_ = R.db(sp, dyp, scale, rows, bn=plan.bn, bt=plan.bt,
               interpret=interpret)
    return (dx_[:, :T, :din], dA_[:, :din, :r], dB_[:, :r, :dout])


def _rows_cotangent(rows):
    # integer primal => float0 cotangent (rows carries no gradient)
    return np.zeros(np.shape(rows), jax.dtypes.float0)


@functools.lru_cache(maxsize=None)
def _make_ragged_fn(interpret: bool, has_base: bool,
                    plan: TilePlan = DEFAULT_PLAN):
    if has_base:
        @jax.custom_vjp
        def f(x, A, B, scale, rows, y_base):
            y, _ = _ragged_fwd_impl(x, A, B, scale, rows, y_base, interpret,
                                    plan)
            return y

        def f_fwd(x, A, B, scale, rows, y_base):
            y, s = _ragged_fwd_impl(x, A, B, scale, rows, y_base, interpret,
                                    plan)
            return y, (x, A, B, scale, rows, s)

        def f_bwd(res, dy):
            x, A, B, scale, rows, s = res
            dx_, dA_, dB_ = _ragged_bwd_impl(x, A, B, scale, rows, s, dy,
                                             interpret, plan)
            return (dx_, dA_, dB_, jnp.zeros_like(scale),
                    _rows_cotangent(rows), dy)

        f.defvjp(f_fwd, f_bwd)
        return f

    @jax.custom_vjp
    def g(x, A, B, scale, rows):
        y, _ = _ragged_fwd_impl(x, A, B, scale, rows, None, interpret, plan)
        return y

    def g_fwd(x, A, B, scale, rows):
        y, s = _ragged_fwd_impl(x, A, B, scale, rows, None, interpret, plan)
        return y, (x, A, B, scale, rows, s)

    def g_bwd(res, dy):
        x, A, B, scale, rows, s = res
        dx_, dA_, dB_ = _ragged_bwd_impl(x, A, B, scale, rows, s, dy,
                                         interpret, plan)
        return (dx_, dA_, dB_, jnp.zeros_like(scale),
                _rows_cotangent(rows))

    g.defvjp(g_fwd, g_bwd)
    return g


def ragged_grouped_lora(x: jnp.ndarray, A: jnp.ndarray, B: jnp.ndarray,
                        scale: jnp.ndarray, rows: jnp.ndarray,
                        y_base: Optional[jnp.ndarray] = None, *,
                        interpret: bool = False,
                        plan: Optional[TilePlan] = None) -> jnp.ndarray:
    """Differentiable RAGGED grouped LoRA: slot z applies its adapter to
    only the first ``rows[z]`` token rows of its lane; padded rows get a
    zero delta (y_base passes through) and zero gradients.

    x: [Z,T,din]; A: [Z,din,r]; B: [Z,r,dout]; scale: [Z]; rows: [Z] int.
    ``rows == T`` everywhere reproduces ``grouped_lora`` exactly — the
    executor dispatches dense for homogeneous mixes, ragged otherwise.
    ``plan`` overrides the static block constants (see ``grouped_lora``).
    """
    fn = _make_ragged_fn(bool(interpret), y_base is not None,
                         plan if plan is not None else DEFAULT_PLAN)
    if y_base is not None:
        return fn(x, A, B, scale, rows, y_base)
    return fn(x, A, B, scale, rows)


# ---------------------------------------------------------------------------
# rank-local variant: per-slot true ranks (composes with ragged rows)
# ---------------------------------------------------------------------------

def _ranklocal_fwd_impl(x, A, B, scale, ranks, rows, y_base, interpret,
                        plan=DEFAULT_PLAN):
    # plan.br applies only where rank is an OUTPUT axis (xa; and ds/da/db
    # below) — sb_add/dx contract over rank, so they keep the default BR
    # grouping to preserve bitwise identity with the static constants.
    T, dout = x.shape[1], B.shape[2]
    xp, Ap, Bp, yb = _pad_fwd(x, A, B, y_base)
    s = RL.xa(xp, Ap, rows, ranks, bm=plan.bm, bk=plan.bk, br=plan.br,
              interpret=interpret)
    y = RL.sb_add(s, Bp, scale, rows, ranks, yb, bm=plan.bm, bn=plan.bn,
                  br=RL.BR, interpret=interpret)
    return y[:, :T, :dout], s[:, :T, :]


def _ranklocal_bwd_impl(x, A, B, scale, ranks, rows, s, dy, interpret,
                        plan=DEFAULT_PLAN):
    T, din = x.shape[1], x.shape[2]
    r, dout = B.shape[1], B.shape[2]
    xp, Ap, Bp, sp, dyp = _pad_bwd(x, A, B, s, dy)
    ds_ = RL.ds(dyp, Bp, scale, rows, ranks, bm=plan.bm, bk=plan.bk,
                br=plan.br, interpret=interpret)
    dx_ = RL.dx(ds_, Ap, rows, ranks, bm=plan.bm, bn=plan.bn, br=RL.BR,
                interpret=interpret)
    dA_ = RL.da(xp, ds_, rows, ranks, bd=plan.bn, bt=plan.bt, br=plan.br,
                interpret=interpret)
    dB_ = RL.db(sp, dyp, scale, rows, ranks, bn=plan.bn, bt=plan.bt,
                br=plan.br, interpret=interpret)
    return (dx_[:, :T, :din], dA_[:, :din, :r], dB_[:, :r, :dout])


@functools.lru_cache(maxsize=None)
def _make_ranklocal_fn(interpret: bool, has_base: bool,
                       plan: TilePlan = DEFAULT_PLAN):
    if has_base:
        @jax.custom_vjp
        def f(x, A, B, scale, ranks, rows, y_base):
            y, _ = _ranklocal_fwd_impl(x, A, B, scale, ranks, rows, y_base,
                                       interpret, plan)
            return y

        def f_fwd(x, A, B, scale, ranks, rows, y_base):
            y, s = _ranklocal_fwd_impl(x, A, B, scale, ranks, rows, y_base,
                                       interpret, plan)
            return y, (x, A, B, scale, ranks, rows, s)

        def f_bwd(res, dy):
            x, A, B, scale, ranks, rows, s = res
            dx_, dA_, dB_ = _ranklocal_bwd_impl(x, A, B, scale, ranks, rows,
                                                s, dy, interpret, plan)
            return (dx_, dA_, dB_, jnp.zeros_like(scale),
                    _rows_cotangent(ranks), _rows_cotangent(rows), dy)

        f.defvjp(f_fwd, f_bwd)
        return f

    @jax.custom_vjp
    def g(x, A, B, scale, ranks, rows):
        y, _ = _ranklocal_fwd_impl(x, A, B, scale, ranks, rows, None,
                                   interpret, plan)
        return y

    def g_fwd(x, A, B, scale, ranks, rows):
        y, s = _ranklocal_fwd_impl(x, A, B, scale, ranks, rows, None,
                                   interpret, plan)
        return y, (x, A, B, scale, ranks, rows, s)

    def g_bwd(res, dy):
        x, A, B, scale, ranks, rows, s = res
        dx_, dA_, dB_ = _ranklocal_bwd_impl(x, A, B, scale, ranks, rows,
                                            s, dy, interpret, plan)
        return (dx_, dA_, dB_, jnp.zeros_like(scale),
                _rows_cotangent(ranks), _rows_cotangent(rows))

    g.defvjp(g_fwd, g_bwd)
    return g


def _concrete_min(v) -> Optional[int]:
    """min(v) when v is host-known (numpy / concrete jax array), else
    None (tracer: the dispatch decision was made outside the trace)."""
    try:
        return int(jnp.min(jnp.asarray(v)))
    except jax.errors.ConcretizationTypeError:
        return None


def ranklocal_grouped_lora(x: jnp.ndarray, A: jnp.ndarray, B: jnp.ndarray,
                           scale: jnp.ndarray, ranks: jnp.ndarray,
                           rows: Optional[jnp.ndarray] = None,
                           y_base: Optional[jnp.ndarray] = None, *,
                           interpret: bool = False,
                           plan: Optional[TilePlan] = None) -> jnp.ndarray:
    """Differentiable RANK-LOCAL grouped LoRA: slot z applies only the
    first ``ranks[z]`` rank columns/rows of its adapter (and, with
    ``rows``, only its first rows[z] token rows). Dead rank tiles skip
    the MXU; the padded rank region gets a zero output and exactly zero
    gradient, so no post-step re-mask is needed on this path.

    x: [Z,T,din]; A: [Z,din,r]; B: [Z,r,dout]; scale/ranks/rows: [Z].
    Concrete ``ranks`` >= r everywhere dispatch to the dense/ragged path
    (identical tiling => bitwise-equal; rank-tiled accumulation would
    only regroup the same fp32 sums), mirroring the executor's per-step
    dense-vs-ragged dispatch. ``plan`` (an autotuned ``TilePlan``)
    overrides the static block constants on whichever path dispatch picks;
    tuned-vs-default outputs are bitwise identical (parallel-dim re-tiling
    only — the autotuner pins every contraction grouping).
    """
    r = A.shape[2]
    cmin = _concrete_min(ranks)
    if cmin is not None and cmin >= r:
        if rows is None:
            return grouped_lora(x, A, B, scale, y_base, interpret=interpret,
                                plan=plan)
        return ragged_grouped_lora(x, A, B, scale, rows, y_base,
                                   interpret=interpret, plan=plan)
    if rows is None:
        rows = jnp.full((x.shape[0],), x.shape[1], jnp.int32)
    fn = _make_ranklocal_fn(bool(interpret), y_base is not None,
                            plan if plan is not None else DEFAULT_PLAN)
    if y_base is not None:
        return fn(x, A, B, scale, ranks, rows, y_base)
    return fn(x, A, B, scale, ranks, rows)
