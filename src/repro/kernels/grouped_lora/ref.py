"""Pure-jnp oracle for the grouped multi-adapter LoRA kernels.

Shapes (slot-stacked, paper §A.1 rank-only padding):
    x:      [Z, T, d_in]
    A:      [Z, d_in, r]      (columns >= true rank are zero)
    B:      [Z, r, d_out]     (rows    >= true rank are zero)
    scale:  [Z]               (alpha / r; paper default alpha=2r => 2.0)
    y_base: [Z, T, d_out]     (frozen-backbone output for the fused add)
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp


def grouped_xa_ref(x: jnp.ndarray, A: jnp.ndarray) -> jnp.ndarray:
    """S_i = X_i @ A_i, fp32 accumulation, result in x.dtype."""
    s = jnp.einsum("ztd,zdr->ztr", x, A,
                   preferred_element_type=jnp.float32)
    return s.astype(x.dtype)


def grouped_sb_add_ref(s: jnp.ndarray, B: jnp.ndarray, scale: jnp.ndarray,
                       y_base: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Y = (S_i @ B_i) * scale_i (+ Y_base), fused epilogue add."""
    y = jnp.einsum("ztr,zro->zto", s, B,
                   preferred_element_type=jnp.float32)
    y = y * scale.astype(jnp.float32)[:, None, None]
    if y_base is not None:
        y = y + y_base.astype(jnp.float32)
    return y.astype(s.dtype)


def grouped_lora_ref(x, A, B, scale, y_base=None) -> jnp.ndarray:
    return grouped_sb_add_ref(grouped_xa_ref(x, A), B, scale, y_base)


def _rows_mask(x: jnp.ndarray, rows: jnp.ndarray) -> jnp.ndarray:
    """[Z,T,*] -> zero every token row t >= rows[z] of slot z's lane."""
    Z, T = x.shape[0], x.shape[1]
    keep = jnp.arange(T)[None, :] < rows[:, None]          # [Z, T]
    return x * keep[:, :, None].astype(x.dtype)


def ragged_lora_ref(x, A, B, scale, rows, y_base=None) -> jnp.ndarray:
    """Ragged oracle: slot z contributes only its first rows[z] token rows;
    padded rows produce a zero delta (y_base passes through)."""
    return grouped_lora_ref(_rows_mask(x, rows), A, B, scale, y_base)


def ragged_lora_bwd_ref(x, A, B, scale, rows, s, dy
                        ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Ragged backward oracle: padded rows receive zero dX and contribute
    nothing to dA/dB (mask dy; x/s pads already produce zero products)."""
    return grouped_lora_bwd_ref(_rows_mask(x, rows), A, B, scale,
                                _rows_mask(s, rows), _rows_mask(dy, rows))


def _ranks_mask_A(A: jnp.ndarray, ranks: jnp.ndarray) -> jnp.ndarray:
    """[Z,din,r] -> zero columns rr >= ranks[z] of slot z's A."""
    keep = jnp.arange(A.shape[2])[None, :] < ranks[:, None]    # [Z, r]
    return jnp.where(keep[:, None, :], A, jnp.zeros((), A.dtype))


def _ranks_mask_B(B: jnp.ndarray, ranks: jnp.ndarray) -> jnp.ndarray:
    """[Z,r,dout] -> zero rows rr >= ranks[z] of slot z's B."""
    keep = jnp.arange(B.shape[1])[None, :] < ranks[:, None]    # [Z, r]
    return jnp.where(keep[:, :, None], B, jnp.zeros((), B.dtype))


def ranklocal_lora_ref(x, A, B, scale, ranks, rows=None,
                       y_base=None) -> jnp.ndarray:
    """Rank-local oracle: slot z uses only its first ranks[z] rank columns
    of A / rank rows of B (and, when ``rows`` is given, only its first
    rows[z] token rows). The padded rank region contributes nothing even
    when it holds garbage."""
    if rows is not None:
        x = _rows_mask(x, rows)
    return grouped_lora_ref(x, _ranks_mask_A(A, ranks),
                            _ranks_mask_B(B, ranks), scale, y_base)


def ranklocal_lora_bwd_ref(x, A, B, scale, ranks, rows, s, dy
                           ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Rank-local backward oracle: the padded rank region of dA/dB is
    exactly zero (dead rank tiles are skipped, never accumulated) and
    padded token rows receive zero dX."""
    if rows is not None:
        x = _rows_mask(x, rows)
        s = _rows_mask(s, rows)
        dy = _rows_mask(dy, rows)
    Am, Bm = _ranks_mask_A(A, ranks), _ranks_mask_B(B, ranks)
    dx, dA, dB = grouped_lora_bwd_ref(x, Am, Bm, scale,
                                      _ranks_mask_A(s, ranks), dy)
    # dA cols / dB rows beyond the true rank never accumulate
    return dx, _ranks_mask_A(dA, ranks), _ranks_mask_B(dB, ranks)


def grouped_lora_bwd_ref(x, A, B, scale, s, dy
                         ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(dX, dA, dB) for Y = scale * (X A) B [+ Y_base].

    dS = scale * dY B^T ; dX = dS A^T ; dA = X^T dS ; dB = scale * S^T dY.
    Weight grads in fp32 (optimizer master dtype), dX in x.dtype.
    """
    dyf = dy.astype(jnp.float32)
    sc = scale.astype(jnp.float32)[:, None, None]
    ds = jnp.einsum("zto,zro->ztr", dyf * sc, B.astype(jnp.float32))
    dx = jnp.einsum("ztr,zdr->ztd", ds, A.astype(jnp.float32))
    dA = jnp.einsum("ztd,ztr->zdr", x.astype(jnp.float32), ds)
    dB = jnp.einsum("ztr,zto->zro", s.astype(jnp.float32), dyf * sc)
    return dx.astype(x.dtype), dA, dB
