"""Pallas TPU kernels: grouped multi-adapter LoRA GEMMs (fwd + bwd).

The paper's Triton kernels re-derived for the TPU memory hierarchy:

  * the GPU schedule-table dispatch (host-built (adapter, block) pairs read
    by thread blocks) becomes a *static* grid with the slot index Z as the
    leading grid dimension — each (z, m, ...) program reads its operands via
    BlockSpec index maps, no host table, no recompilation when adapters swap;
  * rank-only padding (paper §A.1): A/B are padded to r_max; padded columns
    are zero and contribute nothing;
  * the fused base-output addition (paper §A.1) is the epilogue of the
    second GEMM: Y_base tiles are loaded once inside the output loop,
    saving one full HBM read+write of Y;
  * fp32 accumulation in VMEM scratch; K-dim accumulation runs on the
    innermost grid dimension (TPU grid iterates last-dim fastest).

Six kernels, each ONE launch for all Z adapters (paper: O(1) launches/layer):
  fwd:  S = X @ A            (grouped, K-accumulated over d_in)
        Y = S @ B * scale (+ Y_base)   (fused epilogue add)
  bwd:  dS = scale * dY @ B^T          (K-accumulated over d_out)
        dX = dS @ A^T
        dA = X^T @ dS                  (K-accumulated over T)
        dB = scale * S^T @ dY          (K-accumulated over T)

All kernels run under interpret=True on CPU (the correctness harness) and
lower to Mosaic for TPU.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32

# Default VMEM tile sizes (MXU-aligned: multiples of (8,128) fp32 tiles).
BM = 128     # token-block
BK = 512     # contraction block over d_in / d_out
BN = 512     # output-feature block
BT = 128     # token contraction block (weight grads)


# ---------------------------------------------------------------------------
# forward: S = X @ A
# ---------------------------------------------------------------------------

def _xa_kernel(x_ref, a_ref, s_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[0], a_ref[0], preferred_element_type=F32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _done():
        s_ref[0] = acc_ref[...].astype(s_ref.dtype)


def xa(x: jnp.ndarray, A: jnp.ndarray, *, bm: int = BM, bk: int = BK,
       interpret: bool = False) -> jnp.ndarray:
    """x: [Z,T,din], A: [Z,din,r] -> S [Z,T,r] (x.dtype, fp32 accum)."""
    Z, T, din = x.shape
    r = A.shape[2]
    bm, bk = min(bm, T), min(bk, din)
    grid = (Z, T // bm, din // bk)
    return pl.pallas_call(
        _xa_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda z, m, k: (z, m, k)),
            pl.BlockSpec((1, bk, r), lambda z, m, k: (z, k, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, r), lambda z, m, k: (z, m, 0)),
        out_shape=jax.ShapeDtypeStruct((Z, T, r), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, r), F32)],
        interpret=interpret,
    )(x, A)


# ---------------------------------------------------------------------------
# forward: Y = S @ B * scale (+ Y_base)   — fused epilogue add
# ---------------------------------------------------------------------------

def _sb_kernel(scale_ref, s_ref, b_ref, y_ref):
    z = pl.program_id(0)
    acc = jnp.dot(s_ref[0], b_ref[0], preferred_element_type=F32)
    y_ref[0] = (acc * scale_ref[z]).astype(y_ref.dtype)


def _sb_add_kernel(scale_ref, s_ref, b_ref, ybase_ref, y_ref):
    z = pl.program_id(0)
    acc = jnp.dot(s_ref[0], b_ref[0], preferred_element_type=F32)
    acc = acc * scale_ref[z] + ybase_ref[0].astype(F32)
    y_ref[0] = acc.astype(y_ref.dtype)


def sb_add(s: jnp.ndarray, B: jnp.ndarray, scale: jnp.ndarray,
           y_base: Optional[jnp.ndarray] = None, *, bm: int = BM,
           bn: int = BN, interpret: bool = False) -> jnp.ndarray:
    """s: [Z,T,r], B: [Z,r,dout], scale: [Z] fp32 -> Y [Z,T,dout]."""
    Z, T, r = s.shape
    dout = B.shape[2]
    bm, bn = min(bm, T), min(bn, dout)
    grid = (Z, T // bm, dout // bn)
    in_specs = [
        pl.BlockSpec((1, bm, r), lambda z, m, n, sc: (z, m, 0)),
        pl.BlockSpec((1, r, bn), lambda z, m, n, sc: (z, 0, n)),
    ]
    args = [s, B]
    kernel = _sb_kernel
    if y_base is not None:
        in_specs.append(
            pl.BlockSpec((1, bm, bn), lambda z, m, n, sc: (z, m, n)))
        args.append(y_base)
        kernel = _sb_add_kernel
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, bm, bn),
                                   lambda z, m, n, sc: (z, m, n)),
        ),
        out_shape=jax.ShapeDtypeStruct((Z, T, dout), s.dtype),
        interpret=interpret,
    )(scale.astype(F32), *args)


# ---------------------------------------------------------------------------
# backward: dS = scale * dY @ B^T    (accumulate over d_out blocks)
# ---------------------------------------------------------------------------

def _ds_kernel(scale_ref, dy_ref, b_ref, ds_ref, acc_ref):
    z, k = pl.program_id(0), pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        dy_ref[0], b_ref[0], (((1,), (1,)), ((), ())),
        preferred_element_type=F32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _done():
        ds_ref[0] = (acc_ref[...] * scale_ref[z]).astype(ds_ref.dtype)


def ds(dy: jnp.ndarray, B: jnp.ndarray, scale: jnp.ndarray, *, bm: int = BM,
       bk: int = BK, interpret: bool = False) -> jnp.ndarray:
    """dy: [Z,T,dout], B: [Z,r,dout] -> dS [Z,T,r]."""
    Z, T, dout = dy.shape
    r = B.shape[1]
    bm, bk = min(bm, T), min(bk, dout)
    grid = (Z, T // bm, dout // bk)
    return pl.pallas_call(
        _ds_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bm, bk), lambda z, m, k, sc: (z, m, k)),
                pl.BlockSpec((1, r, bk), lambda z, m, k, sc: (z, 0, k)),
            ],
            out_specs=pl.BlockSpec((1, bm, r),
                                   lambda z, m, k, sc: (z, m, 0)),
            scratch_shapes=[pltpu.VMEM((bm, r), F32)],
        ),
        out_shape=jax.ShapeDtypeStruct((Z, T, r), dy.dtype),
        interpret=interpret,
    )(scale.astype(F32), dy, B)


# ---------------------------------------------------------------------------
# backward: dX = dS @ A^T
# ---------------------------------------------------------------------------

def _dx_kernel(ds_ref, a_ref, dx_ref):
    dx_ref[0] = jax.lax.dot_general(
        ds_ref[0], a_ref[0], (((1,), (1,)), ((), ())),
        preferred_element_type=F32).astype(dx_ref.dtype)


def dx(ds_: jnp.ndarray, A: jnp.ndarray, *, bm: int = BM, bn: int = BN,
       interpret: bool = False) -> jnp.ndarray:
    """ds: [Z,T,r], A: [Z,din,r] -> dX [Z,T,din]."""
    Z, T, r = ds_.shape
    din = A.shape[1]
    bm, bn = min(bm, T), min(bn, din)
    grid = (Z, T // bm, din // bn)
    return pl.pallas_call(
        _dx_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, r), lambda z, m, n: (z, m, 0)),
            pl.BlockSpec((1, bn, r), lambda z, m, n: (z, n, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda z, m, n: (z, m, n)),
        out_shape=jax.ShapeDtypeStruct((Z, T, din), ds_.dtype),
        interpret=interpret,
    )(ds_, A)


# ---------------------------------------------------------------------------
# backward weight grads: dA = X^T @ dS ; dB = scale * S^T @ dY
# (accumulate over token blocks; fp32 outputs = optimizer master dtype)
# ---------------------------------------------------------------------------

def _da_kernel(x_ref, ds_ref, da_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0], ds_ref[0], (((0,), (0,)), ((), ())),
        preferred_element_type=F32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _done():
        da_ref[0] = acc_ref[...]


def da(x: jnp.ndarray, ds_: jnp.ndarray, *, bd: int = BN, bt: int = BT,
       interpret: bool = False) -> jnp.ndarray:
    """x: [Z,T,din], ds: [Z,T,r] -> dA [Z,din,r] fp32."""
    Z, T, din = x.shape
    r = ds_.shape[2]
    bd, bt = min(bd, din), min(bt, T)
    grid = (Z, din // bd, T // bt)
    return pl.pallas_call(
        _da_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, bd), lambda z, d, k: (z, k, d)),
            pl.BlockSpec((1, bt, r), lambda z, d, k: (z, k, 0)),
        ],
        out_specs=pl.BlockSpec((1, bd, r), lambda z, d, k: (z, d, 0)),
        out_shape=jax.ShapeDtypeStruct((Z, din, r), F32),
        scratch_shapes=[pltpu.VMEM((bd, r), F32)],
        interpret=interpret,
    )(x, ds_)


def _db_kernel(scale_ref, s_ref, dy_ref, db_ref, acc_ref):
    z, k = pl.program_id(0), pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        s_ref[0], dy_ref[0], (((0,), (0,)), ((), ())),
        preferred_element_type=F32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _done():
        db_ref[0] = acc_ref[...] * scale_ref[z]


def db(s: jnp.ndarray, dy: jnp.ndarray, scale: jnp.ndarray, *, bn: int = BN,
       bt: int = BT, interpret: bool = False) -> jnp.ndarray:
    """s: [Z,T,r], dy: [Z,T,dout] -> dB [Z,r,dout] fp32."""
    Z, T, r = s.shape
    dout = dy.shape[2]
    bn, bt = min(bn, dout), min(bt, T)
    grid = (Z, dout // bn, T // bt)
    return pl.pallas_call(
        _db_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bt, r), lambda z, n, k, sc: (z, k, 0)),
                pl.BlockSpec((1, bt, bn), lambda z, n, k, sc: (z, k, n)),
            ],
            out_specs=pl.BlockSpec((1, r, bn),
                                   lambda z, n, k, sc: (z, 0, n)),
            scratch_shapes=[pltpu.VMEM((r, bn), F32)],
        ),
        out_shape=jax.ShapeDtypeStruct((Z, r, dout), F32),
        interpret=interpret,
    )(scale.astype(F32), s, dy)
