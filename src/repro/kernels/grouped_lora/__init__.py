"""Grouped multi-adapter LoRA kernels (Pallas TPU; interpret-mode on CPU)."""
from repro.kernels.grouped_lora.ops import grouped_lora

__all__ = ["grouped_lora"]
