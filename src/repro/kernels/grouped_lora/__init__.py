"""Grouped multi-adapter LoRA kernels (Pallas TPU; interpret-mode on CPU).

``grouped_lora`` is the dense homogeneous-batch path; ``ragged_grouped_lora``
handles per-slot token-row counts (heterogeneous per-adapter batch sizes).
"""
from repro.kernels.grouped_lora.ops import grouped_lora, ragged_grouped_lora

__all__ = ["grouped_lora", "ragged_grouped_lora"]
