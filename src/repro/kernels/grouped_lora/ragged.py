"""Pallas TPU kernels: RAGGED grouped multi-adapter LoRA GEMMs (fwd + bwd).

The dense kernels (grouped_lora.py) assume every slot contributes the same
number of token rows T — the homogeneous-batch fast case. Heterogeneous
tuning mixes break that: co-located adapters train with *different*
per-adapter batch sizes, so slot z only owns ``rows[z]`` of the T token
rows in its lane (a prefix; the tail is padding). Note the skip applies
to BATCH-width raggedness only: a co-located task with a shorter seq len
pads mid-lane (per sequence), which a single prefix count cannot express
— seq raggedness is handled at the executor/loss layer (label masking),
not here, and pays padded compute.

The ragged path keeps the dense slot-stacked layout ([Z, T, ...], static
shapes => no recompile when widths change) and threads a per-slot row-count
array ``rows: [Z] int32`` through scalar prefetch:

  * tiles **fully past** a slot's row count skip the MXU work entirely
    (``@pl.when`` guard) and emit zeros — a slot with a small batch pays
    only for its own tiles;
  * the **boundary** tile masks padding rows to zero on load, so padded
    rows provably contribute nothing to any output and receive zero
    gradient — the custom VJP built from these kernels is exact;
  * ``rows[z] == T`` for every z degenerates to the dense kernels (the
    masks are all-true and no tile is skipped), which is why the executor
    can dispatch dense-vs-ragged per step without changing results.

Same six-kernel schedule as the dense path, one launch per kernel for all
Z adapters; interpret=True is the CPU CI harness, Mosaic is the TPU target.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.grouped_lora import grouped_lora as K

F32 = jnp.float32


def _row_mask(ref_block: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """Zero rows >= ``valid`` of a (rows, cols) tile (token dim leading)."""
    idx = jax.lax.broadcasted_iota(jnp.int32, ref_block.shape, 0)
    return jnp.where(idx < valid, ref_block, jnp.zeros_like(ref_block))


# ---------------------------------------------------------------------------
# forward: S = X @ A        (token rows masked per slot)
# ---------------------------------------------------------------------------

def _xa_kernel(rows_ref, x_ref, a_ref, s_ref, acc_ref):
    z, m, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    bm = x_ref.shape[1]
    valid = rows_ref[z] - m * bm

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(valid > 0)               # dead tiles skip the MXU entirely
    def _acc():
        xm = _row_mask(x_ref[0], valid)
        acc_ref[...] += jnp.dot(xm, a_ref[0], preferred_element_type=F32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _done():
        s_ref[0] = acc_ref[...].astype(s_ref.dtype)


def xa(x: jnp.ndarray, A: jnp.ndarray, rows: jnp.ndarray, *,
       bm: int = K.BM, bk: int = K.BK, interpret: bool = False
       ) -> jnp.ndarray:
    """x: [Z,T,din], A: [Z,din,r], rows: [Z] -> S [Z,T,r]; rows >= rows[z]
    of slot z's lane are treated as absent (output zeros)."""
    Z, T, din = x.shape
    r = A.shape[2]
    bm, bk = min(bm, T), min(bk, din)
    grid = (Z, T // bm, din // bk)
    return pl.pallas_call(
        _xa_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bm, bk), lambda z, m, k, rr: (z, m, k)),
                pl.BlockSpec((1, bk, r), lambda z, m, k, rr: (z, k, 0)),
            ],
            out_specs=pl.BlockSpec((1, bm, r),
                                   lambda z, m, k, rr: (z, m, 0)),
            scratch_shapes=[pltpu.VMEM((bm, r), F32)],
        ),
        out_shape=jax.ShapeDtypeStruct((Z, T, r), x.dtype),
        interpret=interpret,
    )(rows.astype(jnp.int32), x, A)


# ---------------------------------------------------------------------------
# forward: Y = S @ B * scale (+ Y_base)  — padded rows pass y_base through
# ---------------------------------------------------------------------------

def _sb_kernel(scale_ref, rows_ref, s_ref, b_ref, y_ref):
    z, m = pl.program_id(0), pl.program_id(1)
    valid = rows_ref[z] - m * s_ref.shape[1]

    @pl.when(valid > 0)
    def _():
        sm = _row_mask(s_ref[0], valid)
        y_ref[0] = (jnp.dot(sm, b_ref[0], preferred_element_type=F32)
                    * scale_ref[z]).astype(y_ref.dtype)

    @pl.when(valid <= 0)
    def _dead():
        y_ref[0] = jnp.zeros(y_ref.shape[1:], y_ref.dtype)


def _sb_add_kernel(scale_ref, rows_ref, s_ref, b_ref, ybase_ref, y_ref):
    z, m = pl.program_id(0), pl.program_id(1)
    valid = rows_ref[z] - m * s_ref.shape[1]
    base = ybase_ref[0].astype(F32)

    @pl.when(valid > 0)
    def _():
        sm = _row_mask(s_ref[0], valid)
        acc = jnp.dot(sm, b_ref[0], preferred_element_type=F32)
        y_ref[0] = (acc * scale_ref[z] + base).astype(y_ref.dtype)

    @pl.when(valid <= 0)
    def _dead():                      # delta is zero: backbone passthrough
        y_ref[0] = base.astype(y_ref.dtype)


def sb_add(s: jnp.ndarray, B: jnp.ndarray, scale: jnp.ndarray,
           rows: jnp.ndarray, y_base=None, *, bm: int = K.BM,
           bn: int = K.BN, interpret: bool = False) -> jnp.ndarray:
    """s: [Z,T,r], B: [Z,r,dout], scale/rows: [Z] -> Y [Z,T,dout]."""
    Z, T, r = s.shape
    dout = B.shape[2]
    bm, bn = min(bm, T), min(bn, dout)
    grid = (Z, T // bm, dout // bn)
    in_specs = [
        pl.BlockSpec((1, bm, r), lambda z, m, n, sc, rr: (z, m, 0)),
        pl.BlockSpec((1, r, bn), lambda z, m, n, sc, rr: (z, 0, n)),
    ]
    args = [s, B]
    kernel = _sb_kernel
    if y_base is not None:
        in_specs.append(
            pl.BlockSpec((1, bm, bn), lambda z, m, n, sc, rr: (z, m, n)))
        args.append(y_base)
        kernel = _sb_add_kernel
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, bm, bn),
                                   lambda z, m, n, sc, rr: (z, m, n)),
        ),
        out_shape=jax.ShapeDtypeStruct((Z, T, dout), s.dtype),
        interpret=interpret,
    )(scale.astype(F32), rows.astype(jnp.int32), *args)


# ---------------------------------------------------------------------------
# backward: dS = scale * dY @ B^T   (dY rows masked per slot)
# ---------------------------------------------------------------------------

def _ds_kernel(scale_ref, rows_ref, dy_ref, b_ref, ds_ref, acc_ref):
    z, m, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    valid = rows_ref[z] - m * dy_ref.shape[1]

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(valid > 0)
    def _acc():
        dym = _row_mask(dy_ref[0], valid)
        acc_ref[...] += jax.lax.dot_general(
            dym, b_ref[0], (((1,), (1,)), ((), ())),
            preferred_element_type=F32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _done():
        ds_ref[0] = (acc_ref[...] * scale_ref[z]).astype(ds_ref.dtype)


def ds(dy: jnp.ndarray, B: jnp.ndarray, scale: jnp.ndarray,
       rows: jnp.ndarray, *, bm: int = K.BM, bk: int = K.BK,
       interpret: bool = False) -> jnp.ndarray:
    """dy: [Z,T,dout], B: [Z,r,dout] -> dS [Z,T,r] (padded rows zero)."""
    Z, T, dout = dy.shape
    r = B.shape[1]
    bm, bk = min(bm, T), min(bk, dout)
    grid = (Z, T // bm, dout // bk)
    return pl.pallas_call(
        _ds_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bm, bk), lambda z, m, k, sc, rr: (z, m, k)),
                pl.BlockSpec((1, r, bk), lambda z, m, k, sc, rr: (z, 0, k)),
            ],
            out_specs=pl.BlockSpec((1, bm, r),
                                   lambda z, m, k, sc, rr: (z, m, 0)),
            scratch_shapes=[pltpu.VMEM((bm, r), F32)],
        ),
        out_shape=jax.ShapeDtypeStruct((Z, T, r), dy.dtype),
        interpret=interpret,
    )(scale.astype(F32), rows.astype(jnp.int32), dy, B)


# ---------------------------------------------------------------------------
# backward: dX = dS @ A^T
# ---------------------------------------------------------------------------

def _dx_kernel(rows_ref, ds_ref, a_ref, dx_ref):
    z, m = pl.program_id(0), pl.program_id(1)
    valid = rows_ref[z] - m * ds_ref.shape[1]

    @pl.when(valid > 0)
    def _():
        dsm = _row_mask(ds_ref[0], valid)
        dx_ref[0] = jax.lax.dot_general(
            dsm, a_ref[0], (((1,), (1,)), ((), ())),
            preferred_element_type=F32).astype(dx_ref.dtype)

    @pl.when(valid <= 0)
    def _dead():
        dx_ref[0] = jnp.zeros(dx_ref.shape[1:], dx_ref.dtype)


def dx(ds_: jnp.ndarray, A: jnp.ndarray, rows: jnp.ndarray, *,
       bm: int = K.BM, bn: int = K.BN, interpret: bool = False
       ) -> jnp.ndarray:
    """ds: [Z,T,r], A: [Z,din,r] -> dX [Z,T,din] (padded rows zero)."""
    Z, T, r = ds_.shape
    din = A.shape[1]
    bm, bn = min(bm, T), min(bn, din)
    grid = (Z, T // bm, din // bn)
    return pl.pallas_call(
        _dx_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bm, r), lambda z, m, n, rr: (z, m, 0)),
                pl.BlockSpec((1, bn, r), lambda z, m, n, rr: (z, n, 0)),
            ],
            out_specs=pl.BlockSpec((1, bm, bn),
                                   lambda z, m, n, rr: (z, m, n)),
        ),
        out_shape=jax.ShapeDtypeStruct((Z, T, din), ds_.dtype),
        interpret=interpret,
    )(rows.astype(jnp.int32), ds_, A)


# ---------------------------------------------------------------------------
# backward weight grads: dA = X^T @ dS ; dB = scale * S^T @ dY
# (contraction over token blocks; only a slot's own rows contribute)
# ---------------------------------------------------------------------------

def _da_kernel(rows_ref, x_ref, ds_ref, da_ref, acc_ref):
    z, k = pl.program_id(0), pl.program_id(2)
    valid = rows_ref[z] - k * x_ref.shape[1]

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(valid > 0)
    def _acc():
        xm = _row_mask(x_ref[0], valid)
        acc_ref[...] += jax.lax.dot_general(
            xm, ds_ref[0], (((0,), (0,)), ((), ())),
            preferred_element_type=F32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _done():
        da_ref[0] = acc_ref[...]


def da(x: jnp.ndarray, ds_: jnp.ndarray, rows: jnp.ndarray, *,
       bd: int = K.BN, bt: int = K.BT, interpret: bool = False
       ) -> jnp.ndarray:
    """x: [Z,T,din], ds: [Z,T,r] -> dA [Z,din,r] fp32 (only rows[z] rows
    of slot z contribute)."""
    Z, T, din = x.shape
    r = ds_.shape[2]
    bd, bt = min(bd, din), min(bt, T)
    grid = (Z, din // bd, T // bt)
    return pl.pallas_call(
        _da_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bt, bd), lambda z, d, k, rr: (z, k, d)),
                pl.BlockSpec((1, bt, r), lambda z, d, k, rr: (z, k, 0)),
            ],
            out_specs=pl.BlockSpec((1, bd, r),
                                   lambda z, d, k, rr: (z, d, 0)),
            scratch_shapes=[pltpu.VMEM((bd, r), F32)],
        ),
        out_shape=jax.ShapeDtypeStruct((Z, din, r), F32),
        interpret=interpret,
    )(rows.astype(jnp.int32), x, ds_)


def _db_kernel(scale_ref, rows_ref, s_ref, dy_ref, db_ref, acc_ref):
    z, k = pl.program_id(0), pl.program_id(2)
    valid = rows_ref[z] - k * s_ref.shape[1]

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(valid > 0)
    def _acc():
        sm = _row_mask(s_ref[0], valid)
        acc_ref[...] += jax.lax.dot_general(
            sm, dy_ref[0], (((0,), (0,)), ((), ())),
            preferred_element_type=F32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _done():
        db_ref[0] = acc_ref[...] * scale_ref[z]


def db(s: jnp.ndarray, dy: jnp.ndarray, scale: jnp.ndarray,
       rows: jnp.ndarray, *, bn: int = K.BN, bt: int = K.BT,
       interpret: bool = False) -> jnp.ndarray:
    """s: [Z,T,r], dy: [Z,T,dout] -> dB [Z,r,dout] fp32."""
    Z, T, r = s.shape
    dout = dy.shape[2]
    bn, bt = min(bn, dout), min(bt, T)
    grid = (Z, dout // bn, T // bt)
    return pl.pallas_call(
        _db_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bt, r), lambda z, n, k, sc, rr: (z, k, 0)),
                pl.BlockSpec((1, bt, bn), lambda z, n, k, sc, rr: (z, k, n)),
            ],
            out_specs=pl.BlockSpec((1, r, bn),
                                   lambda z, n, k, sc, rr: (z, 0, n)),
            scratch_shapes=[pltpu.VMEM((r, bn), F32)],
        ),
        out_shape=jax.ShapeDtypeStruct((Z, r, dout), F32),
        interpret=interpret,
    )(scale.astype(F32), rows.astype(jnp.int32), s, dy)
