"""Naive-attention oracle for the flash-attention Pallas kernel.

Layout: fused batch-heads B = Z*b*H; q: [B, Sq, hd]; k,v: [B, Sk, hd].
Causal alignment: query i attends to keys j with j <= i + (Sk - Sq)
(the standard suffix alignment; Sq == Sk is plain causal).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True,
                        window: int = 0) -> jnp.ndarray:
    B, Sq, hd = q.shape
    Sk = k.shape[1]
    scale = hd ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)
    kpos = jnp.arange(Sk)[None, :]
    vis = jnp.ones((Sq, Sk), bool)
    if causal:
        vis &= kpos <= qpos
    if window > 0:
        vis &= kpos > qpos - window
    s = jnp.where(vis, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isfinite(p), p, 0.0)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)
