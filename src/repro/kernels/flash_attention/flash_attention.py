"""Pallas TPU kernel: causal flash attention (fwd), online softmax.

The prefill roofline (post-§Perf) is memory-bound on the attention working
set: the XLA chunked path still materializes [qc, Sk] score tiles in HBM.
This kernel keeps everything per (q-block, k-block) VMEM-resident with the
standard streaming-softmax recurrence:

    m' = max(m, rowmax(S))          S = q k^T * scale + mask
    l' = e^{m-m'} l + rowsum(e^{S-m'})
    acc' = e^{m-m'} acc + e^{S-m'} v

Grid (B, n_q, n_k) — k innermost; running (m, l, acc) live in VMEM scratch
across the k sweep of each (b, i_q) program; the output tile is normalized
and stored at the last k step. Causal masking uses absolute positions with
the suffix alignment (query i sees keys j <= i + Sk - Sq), plus an optional
sliding window; fully-masked rows produce zeros (matching the oracle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG = -1e30

BQ = 256
BK = 512


def _kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
            scale: float, causal: bool, window: int, off: int,
            bq: int, bk: int):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0].astype(F32) * scale
    k = k_ref[0].astype(F32)
    v = v_ref[0].astype(F32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=F32)      # [bq, bk]

    qpos = (pl.program_id(1) * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bk), 0) + off)
    kpos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    vis = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        vis &= kpos <= qpos
    if window > 0:
        vis &= kpos > qpos - window
    s = jnp.where(vis, s, NEG)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)                # [bq]
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(vis, p, 0.0)
    l_s[...] = l_s[...] * alpha + jnp.sum(p, axis=1)
    acc_s[...] = (acc_s[...] * alpha[:, None]
                  + jnp.dot(p, v, preferred_element_type=F32))
    m_s[...] = m_new

    @pl.when(kb == pl.num_programs(2) - 1)
    def _done():
        denom = jnp.maximum(l_s[...], 1e-30)[:, None]
        o_ref[0] = (acc_s[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    bq: int = BQ, bk: int = BK,
                    interpret: bool = False) -> jnp.ndarray:
    """q: [B,Sq,hd]; k,v: [B,Sk,hd] -> [B,Sq,hd]."""
    B, Sq, hd = q.shape
    Sk = k.shape[1]
    bq, bk = min(bq, Sq), min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    kern = functools.partial(
        _kernel, scale=hd ** -0.5, causal=causal, window=window,
        off=Sk - Sq, bq=bq, bk=bk)
    return pl.pallas_call(
        kern,
        grid=(B, Sq // bq, Sk // bk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), F32),          # running max
            pltpu.VMEM((bq,), F32),          # running denom
            pltpu.VMEM((bq, hd), F32),       # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
