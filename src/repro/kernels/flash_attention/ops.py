"""Differentiable wrapper for the flash-attention Pallas kernel (forward =
kernel, backward = XLA autodiff of the oracle — the same split as the
linear-scan kernel; see that module's rationale)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as K
from repro.kernels.flash_attention.ref import flash_attention_ref


@functools.lru_cache(maxsize=None)
def _make(causal: bool, window: int, bq: int, bk: int, interpret: bool):
    @jax.custom_vjp
    def f(q, k, v):
        return K.flash_attention(q, k, v, causal=causal, window=window,
                                 bq=bq, bk=bk, interpret=interpret)

    def fwd(q, k, v):
        return f(q, k, v), (q, k, v)

    def bwd(res, ct):
        q, k, v = res
        _, vjp = jax.vjp(
            lambda q_, k_, v_: flash_attention_ref(
                q_, k_, v_, causal=causal, window=window), q, k, v)
        return vjp(ct)

    f.defvjp(fwd, bwd)
    return f


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    bq: int = K.BQ, bk: int = K.BK,
                    interpret: bool = False) -> jnp.ndarray:
    Sq, Sk = q.shape[1], k.shape[1]
    bq, bk = min(bq, Sq), min(bk, Sk)
    fn = _make(bool(causal), int(window), bq, bk, bool(interpret))
    return fn(q, k, v)
