"""Causal flash-attention Pallas kernel (prefill hot spot)."""
from repro.kernels.flash_attention.ops import flash_attention

__all__ = ["flash_attention"]
