"""Oracle for the chunked linear-scan Pallas kernel: the pure-jnp core in
models/linear_scan.py, flattened to the kernel's [B, S, K/V] layout
(B = Z*b*H fused batch-heads)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.models.linear_scan import chunked_linear_attention


def linear_scan_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    logw: jnp.ndarray, *,
                    bonus: Optional[jnp.ndarray] = None,
                    decay_on_query: bool = False,
                    initial_state: Optional[jnp.ndarray] = None,
                    chunk: int = 32) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """q,k,logw: [B,S,K]; v: [B,S,V]; bonus: [B,K] or None;
    initial_state: [B,K,V]. Returns (y [B,S,V], state [B,K,V] fp32)."""
    B, S, K = q.shape
    V = v.shape[-1]
    # reuse the model core with Z=B, b=1, H=1
    r = lambda x: x[:, None, :, None, :]
    bon = bonus[:1] if bonus is not None else None
    ys, states = [], []
    if bonus is None:
        y, st = chunked_linear_attention(
            q[:, None, :, None, :].reshape(B, 1, S, 1, K),
            k.reshape(B, 1, S, 1, K), v.reshape(B, 1, S, 1, V),
            logw.reshape(B, 1, S, 1, K),
            bonus=None, decay_on_query=decay_on_query,
            initial_state=(initial_state.reshape(B, 1, 1, K, V)
                           if initial_state is not None else None),
            chunk=chunk)
        return y.reshape(B, S, V), st.reshape(B, K, V)
    # per-row bonus: process rows independently (H=1 core expects [H,K])
    for i in range(B):
        y, st = chunked_linear_attention(
            q[i].reshape(1, 1, S, 1, K), k[i].reshape(1, 1, S, 1, K),
            v[i].reshape(1, 1, S, 1, V), logw[i].reshape(1, 1, S, 1, K),
            bonus=bonus[i].reshape(1, K), decay_on_query=decay_on_query,
            initial_state=(initial_state[i].reshape(1, 1, 1, K, V)
                           if initial_state is not None else None),
            chunk=chunk)
        ys.append(y.reshape(S, V))
        states.append(st.reshape(K, V))
    return jnp.stack(ys), jnp.stack(states)
