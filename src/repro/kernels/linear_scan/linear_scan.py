"""Pallas TPU kernel: chunked gated linear scan (RWKV6 / Mamba-SSD core).

The §Perf analysis showed the jnp chunked scan's dominant HBM term is the
exact-log-space pair tensor exp(L_t - L_i) k q of shape [C, C, K]
materialized per chunk. This kernel keeps that tensor (and all chunk
intermediates) VMEM-resident: per grid step, HBM moves only the q/k/v/logw
chunk tiles and the y output tile — bytes drop from O(S·C·K) extra per row
to the O(S·(3K+V)) I/O floor.

Layout: fused batch rows B = Z*b*H. Grid (B, S/C) — the TPU grid iterates
the LAST dimension fastest and sequentially, so the recurrent state lives
in a VMEM scratch carried across chunk steps of the same row (initialized
at chunk==0 from the initial-state tile, written out at the last chunk).

The recurrence (decay_on_query False => RWKV with bonus u; True => SSD):
    S_c   = diag(exp(L_C)) S_{c-1} + (k . exp(L_C - L))^T v
    y     = (q . exp(Lq)) S_{c-1} + P v,   P_ti = sum_K q_t k_i e^{Lq_t-L_i}
All math fp32 in VMEM; pair exponents are differences of cumulative
log-decays => no overflow for arbitrarily strong decay (same numerics as
the jnp core, validated against it in interpret mode).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref,
            y_ref, sout_ref, state, *, decay_on_query: bool,
            use_bonus: bool):
    c = pl.program_id(1)
    C, K = q_ref.shape[1], q_ref.shape[2]

    @pl.when(c == 0)
    def _init():
        state[...] = s0_ref[0]

    q = q_ref[0].astype(F32)
    k = k_ref[0].astype(F32)
    v = v_ref[0].astype(F32)
    lw = lw_ref[0].astype(F32)

    L = jnp.cumsum(lw, axis=0)                    # [C,K] <= 0
    if decay_on_query:
        Lq = L
    else:
        Lq = jnp.concatenate(
            [jnp.zeros((1, K), F32), L[:-1]], axis=0)

    # ---- state contribution (MXU): (q . e^{Lq}) @ S_prev
    S_prev = state[...]
    q_scaled = q * jnp.exp(Lq)
    y = jnp.dot(q_scaled, S_prev, preferred_element_type=F32)

    # ---- intra-chunk pairs, exact log-space, fully VMEM-resident
    t = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    i = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    visible = (t >= i) if decay_on_query else (t > i)
    dd = Lq[:, None, :] - L[None, :, :]           # [C,C,K]
    dd = jnp.where(visible[..., None], dd, NEG_INF)
    P = jnp.sum(q[:, None, :] * k[None, :, :] * jnp.exp(dd), axis=-1)
    if use_bonus:
        diag = jnp.sum(q * u_ref[0].astype(F32) * k, axis=-1)   # [C]
        P = P + jnp.where(t == i, diag[None, :], 0.0)
    y = y + jnp.dot(P, v, preferred_element_type=F32)
    y_ref[0] = y.astype(y_ref.dtype)

    # ---- state update
    L_end = L[-1:, :]                             # [1,K]
    k_scaled = k * jnp.exp(L_end - L)
    new_state = (S_prev * jnp.exp(L_end).T
                 + jax.lax.dot_general(k_scaled, v, (((0,), (0,)), ((), ())),
                                       preferred_element_type=F32))
    state[...] = new_state

    @pl.when(c == pl.num_programs(1) - 1)
    def _done():
        sout_ref[0] = new_state


def linear_scan(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                logw: jnp.ndarray, *,
                bonus: Optional[jnp.ndarray] = None,
                decay_on_query: bool = False,
                initial_state: Optional[jnp.ndarray] = None,
                chunk: int = 32, interpret: bool = False
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """q,k,logw: [B,S,K]; v: [B,S,V]; bonus: [B,K]|None;
    initial_state: [B,K,V] fp32|None. Returns (y [B,S,V], state [B,K,V])."""
    B, S, K = q.shape
    V = v.shape[-1]
    C = min(chunk, S)
    assert S % C == 0, (S, C)
    n = S // C
    if initial_state is None:
        initial_state = jnp.zeros((B, K, V), F32)
    use_bonus = bonus is not None
    if bonus is None:
        bonus = jnp.zeros((B, K), F32)

    kern = functools.partial(_kernel, decay_on_query=decay_on_query,
                             use_bonus=use_bonus)
    y, state = pl.pallas_call(
        kern,
        grid=(B, n),
        in_specs=[
            pl.BlockSpec((1, C, K), lambda b, c: (b, c, 0)),   # q
            pl.BlockSpec((1, C, K), lambda b, c: (b, c, 0)),   # k
            pl.BlockSpec((1, C, V), lambda b, c: (b, c, 0)),   # v
            pl.BlockSpec((1, C, K), lambda b, c: (b, c, 0)),   # logw
            pl.BlockSpec((1, K), lambda b, c: (b, 0)),         # bonus
            pl.BlockSpec((1, K, V), lambda b, c: (b, 0, 0)),   # state0
        ],
        out_specs=[
            pl.BlockSpec((1, C, V), lambda b, c: (b, c, 0)),   # y
            pl.BlockSpec((1, K, V), lambda b, c: (b, 0, 0)),   # state out
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, V), q.dtype),
            jax.ShapeDtypeStruct((B, K, V), F32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), F32)],
        interpret=interpret,
    )(q, k, v, logw, bonus, initial_state.astype(F32))
    return y, state
