"""Differentiable wrapper for the linear-scan Pallas kernel.

Forward runs the VMEM-resident Pallas kernel; the backward falls back to
XLA autodiff of the mathematically identical jnp chunked core (a standard
production split: the hand kernel owns the latency-critical forward/serving
path; training gradients reuse the compiler-verified reference). The two
paths agree to fp32 tolerance (tests/test_kernels_linear_scan.py).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.linear_scan import linear_scan as K
from repro.kernels.linear_scan.ref import linear_scan_ref


@functools.lru_cache(maxsize=None)
def _make(decay_on_query: bool, use_bonus: bool, chunk: int,
          interpret: bool):
    def ref_call(q, k, v, logw, bonus, s0):
        return linear_scan_ref(
            q, k, v, logw, bonus=bonus if use_bonus else None,
            decay_on_query=decay_on_query, initial_state=s0, chunk=chunk)

    @jax.custom_vjp
    def f(q, k, v, logw, bonus, s0):
        return K.linear_scan(
            q, k, v, logw, bonus=bonus if use_bonus else None,
            decay_on_query=decay_on_query, initial_state=s0, chunk=chunk,
            interpret=interpret)

    def fwd(q, k, v, logw, bonus, s0):
        out = f(q, k, v, logw, bonus, s0)
        return out, (q, k, v, logw, bonus, s0)

    def bwd(res, cts):
        q, k, v, logw, bonus, s0 = res
        _, vjp = jax.vjp(lambda *a: ref_call(*a), q, k, v, logw, bonus, s0)
        return vjp(cts)

    f.defvjp(fwd, bwd)
    return f


def linear_scan(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                logw: jnp.ndarray, *,
                bonus: Optional[jnp.ndarray] = None,
                decay_on_query: bool = False,
                initial_state: Optional[jnp.ndarray] = None,
                chunk: int = 32, interpret: bool = False
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B, S, Kd = q.shape
    V = v.shape[-1]
    use_bonus = bonus is not None
    if bonus is None:
        bonus = jnp.zeros((B, Kd), jnp.float32)
    if initial_state is None:
        initial_state = jnp.zeros((B, Kd, V), jnp.float32)
    fn = _make(bool(decay_on_query), use_bonus, int(chunk), bool(interpret))
    return fn(q, k, v, logw, bonus, initial_state)
