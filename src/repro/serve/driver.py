"""Serving replicas as first-class cluster residents.

A serving replica holds GPUs the training planner must plan *around* —
not via a side-channel reservation API, but as an ordinary runtime task:
``ServingReplicaDriver`` implements the ``TaskDriver`` interface with a
finite serving *lease* (``horizon_s`` of virtual time), so
``ElasticClusterRuntime`` owns its GPUs through the normal ``_owner`` /
projected-skyline machinery — replans, utilization accounting and the
"unplaceable pending" guard all see the replica with zero new planner
mechanics. Retiring the replica early is ``runtime.cancel(name)``; the
lease expiring frees the GPUs like any task completion.
"""
from __future__ import annotations

from typing import Any, Optional

from repro.sched.cluster import DriverChunk, TaskDriver
from repro.sched.events import EventKind, ProgressEvent
from repro.sched.inter_task import TaskSpec


def serving_spec(name: str, gpus: int, horizon_s: float,
                 release: float = 0.0) -> TaskSpec:
    """The TaskSpec a serving lease occupies in the plan."""
    assert horizon_s > 0 and gpus >= 1
    return TaskSpec(name=name, duration=horizon_s, gpus=gpus,
                    release=release)


class ServingReplicaDriver(TaskDriver):
    """A serving lease on the virtual timeline.

    Virtual time is decoupled from the replica's wall-clock decode work
    (serving is driven by tenant requests, not by the cluster loop), so
    ``step_chunk`` just burns the lease down in ``chunk_s`` slices and
    reports heartbeats; ``result`` summarizes what the attached frontend
    served. Deterministic for fixed construction, as the runtime's
    static-baseline property requires."""

    def __init__(self, name: str, *, horizon_s: float,
                 chunk_s: float = 60.0, frontend: Any = None):
        assert horizon_s > 0 and chunk_s > 0
        self.name = name
        self.horizon_s = horizon_s
        self.chunk_s = chunk_s
        self.frontend = frontend
        self._remaining = horizon_s
        self._started: Optional[float] = None

    def start(self, now: float) -> None:
        self._started = now

    def step_chunk(self) -> DriverChunk:
        dt = min(self.chunk_s, self._remaining)
        self._remaining -= dt
        done = self._remaining <= 1e-12
        ev = ProgressEvent(kind=EventKind.TASK_PROGRESS, task=self.name,
                           detail="serving_lease")
        return DriverChunk(dt=dt, events=(ev,), done=done)

    def residual_estimate(self) -> float:
        return self._remaining

    def slots_bound(self) -> Optional[int]:
        return None                 # serving slots live outside training

    def result(self) -> Any:
        out = {"kind": "serving_replica", "lease_s": self.horizon_s}
        fe = self.frontend
        if fe is not None:
            out.update(
                served_requests=fe.served_requests,
                publishes=fe.publishes,
                hot_publishes=fe.hot_publishes,
                resident_adapters=sorted(fe.pool.resident()),
                aggregate_tok_s=fe.replica.aggregate_tok_s)
        return out
