"""ServingFrontend: request queueing, per-adapter routing, admission.

The tenant-facing edge of the serving tier. ``submit`` enqueues a decode
request routed by adapter id; ``step_round`` packs the heads of every
resident adapter's queue into one replica round (up to ``lanes``
requests per adapter) and serves it; ``drain`` loops rounds until the
queues are empty. ``publish``/``publish_checkpoint`` admit new adapters
against the §A.3+k2 memory model: a resident adapter's serving working
set is ``lanes x max_len`` tokens plus ``rank x lanes x max_len``
rank-tokens (the rank-local LoRA footprint), and a publish that would
push ``predict_ranked`` past the safety-margined capacity is refused —
the serving-side mirror of training's rank-aware cross-task admission.
"""
from __future__ import annotations

import collections
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.sched.intra_task import MemoryModel
from repro.serve.pool import AdapterPool
from repro.serve.replica import ServeRequest, ServingReplica


class AdmissionError(Exception):
    """Publish or request refused by the frontend's admission checks."""


class ServingFrontend:
    """Queueing + routing + admission over one ``ServingReplica``."""

    def __init__(self, replica: ServingReplica,
                 mem: Optional[MemoryModel] = None):
        self.replica = replica
        self.pool: AdapterPool = replica.pool
        self.mem = mem
        self._queues: Dict[str, Deque[ServeRequest]] = \
            collections.defaultdict(collections.deque)
        self._done: Dict[str, ServeRequest] = {}
        self._next_id = 0
        self.publishes = 0
        self.hot_publishes = 0      # publishes landing mid-decode (hook)
        self.served_requests = 0

    # ------------------------------------------------------------ admission
    def _admission_tokens(self, extra_rank: int) -> Tuple[int, int]:
        lanes, seq = self.replica.lanes, self.replica.max_len
        toks = self.pool.occupied_tokens(lanes, seq) + lanes * seq
        rtoks = self.pool.occupied_rank_tokens(lanes, seq) \
            + extra_rank * lanes * seq
        return toks, rtoks

    def _check_publish(self, rank: int) -> None:
        if not self.pool.free_slots():
            raise AdmissionError("no free adapter slot")
        if self.mem is None:
            return
        rank = self.mem.charged_rank(min(rank, self.pool.r_max))
        toks, rtoks = self._admission_tokens(rank)
        if not self.mem.fits_ranked(toks, rtoks):
            raise AdmissionError(
                f"publish would exceed memory budget: "
                f"{self.mem.predict_ranked(toks, rtoks):.3e} B > "
                f"{self.mem.capacity * self.mem.safety_margin:.3e} B")

    # ------------------------------------------------------------ publishing
    def publish(self, adapter_id: str, adapter: Dict, rank: int,
                meta: Optional[Dict] = None) -> int:
        self._check_publish(rank)
        slot = self.pool.publish(adapter_id, adapter, rank, meta=meta)
        self.publishes += 1
        return slot

    def publish_checkpoint(self, path: str,
                           adapter_id: Optional[str] = None) -> str:
        """Admit an adapter from a durable checkpoint artifact (the
        tune-to-serve path). Returns the adapter id."""
        import json

        # peek rank for admission without mutating the pool
        data = np.load(path if path.endswith(".npz") else path + ".npz",
                       allow_pickle=False)
        meta = json.loads(str(data["__meta__"]))
        self._check_publish(int(meta["rank"]))
        aid, _ = self.pool.publish_checkpoint(path, adapter_id=adapter_id)
        self.publishes += 1
        return aid

    def retire(self, adapter_id: str) -> int:
        assert not self._queues.get(adapter_id), \
            f"adapter {adapter_id!r} has queued requests"
        self._queues.pop(adapter_id, None)
        return self.pool.retire(adapter_id)

    # ------------------------------------------------------------ requests
    def submit(self, adapter_id: str, prompt, max_new: int) -> str:
        """Enqueue a decode request; returns its request id."""
        if adapter_id not in self.pool.resident():
            raise AdmissionError(f"adapter {adapter_id!r} not resident")
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) < 1 or len(prompt) + max_new > self.replica.max_len:
            raise AdmissionError(
                f"prompt({len(prompt)}) + max_new({max_new}) exceeds "
                f"max_len={self.replica.max_len}")
        rid = f"req-{self._next_id}"
        self._next_id += 1
        self._queues[adapter_id].append(
            ServeRequest(request_id=rid, adapter_id=adapter_id,
                         prompt=prompt, max_new=max_new))
        return rid

    def queued(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def step_round(self, on_step: Optional[Callable[[int], None]] = None
                   ) -> int:
        """Serve one round over the head of every adapter's queue (up to
        ``lanes`` requests each). Returns requests completed; 0 = idle."""
        batch: List[ServeRequest] = []
        for adapter_id in list(self._queues):
            if adapter_id not in self.pool.resident():
                continue            # retired with queued work: re-check later
            q = self._queues[adapter_id]
            for _ in range(min(len(q), self.replica.lanes)):
                batch.append(q.popleft())
        if not batch:
            return 0
        hot_before = self.pool.version
        self.replica.serve_round(batch, on_step=on_step)
        if on_step is not None and self.pool.version > hot_before:
            self.hot_publishes += self.pool.version - hot_before
        for r in batch:
            self._done[r.request_id] = r
        self.served_requests += len(batch)
        return len(batch)

    def drain(self, on_step: Optional[Callable[[int], None]] = None
              ) -> Dict[str, List[int]]:
        """Serve rounds until every queue is empty; returns
        ``{request_id: generated tokens}`` for everything completed."""
        while self.queued():
            served = self.step_round(on_step=on_step)
            on_step = None          # hooks fire on the first round only
            if served == 0:
                break               # only retired-adapter queues remain
        return {rid: list(r.tokens) for rid, r in self._done.items()}

    def result(self, request_id: str) -> List[int]:
        assert request_id in self._done, f"request {request_id!r} not done"
        return list(self._done[request_id].tokens)
