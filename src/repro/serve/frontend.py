"""ServingFrontend: request queueing, per-adapter routing, admission.

The tenant-facing edge of the serving tier. ``submit`` enqueues a decode
request routed by adapter id; the frontend then drives the replica in
one of two modes:

**continuous (default).** ``step_continuous`` keeps every lane of the
replica's ``Z x lanes`` grid busy: before each fused decode step it
drains pending batched publishes (``queue_publish`` ->
``AdapterPool.publish_many``), then fills free lanes from the queues —
each join is admission-checked against the §A.3+k2 memory model using
the request's ACTUAL footprint (``prompt_len + max_new`` tokens, times
the adapter's charged rank for rank-tokens) summed over everything in
flight, not the pessimistic ``lanes x max_len`` bound. A request that
doesn't fit right now simply waits; it is re-checked as lanes complete
and release their charge. ``drain`` loops steps until the queues and
lanes are empty and returns per-request results; per-request latency
records accumulate on ``replica.records``.

**round (legacy baseline).** ``step_round``/``drain`` reproduce the PR-7
barrier: the heads of every adapter's queue are packed into one cache
epoch and everything joins/leaves together. Publish admission in this
mode keeps the pessimistic resident-set bound (every resident adapter
charged ``lanes x max_len``), since a round has no per-request charge
tracking.
"""
from __future__ import annotations

import collections
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.sched.intra_task import MemoryModel
from repro.serve.pool import AdapterPool
from repro.serve.replica import ServeRequest, ServingReplica


class AdmissionError(Exception):
    """Publish or request refused by the frontend's admission checks."""


class ServingFrontend:
    """Queueing + routing + admission over one ``ServingReplica``."""

    def __init__(self, replica: ServingReplica,
                 mem: Optional[MemoryModel] = None,
                 mode: str = "continuous"):
        assert mode in ("continuous", "round"), mode
        self.replica = replica
        self.pool: AdapterPool = replica.pool
        self.mem = mem
        self.mode = mode
        self._queues: Dict[str, Deque[ServeRequest]] = \
            collections.defaultdict(collections.deque)
        self._done: Dict[str, ServeRequest] = {}
        self._inflight: Dict[str, Tuple[int, int]] = {}  # rid -> (tok, rtok)
        self._pending_pubs: List[Tuple] = []
        self._next_id = 0
        self.publishes = 0
        self.hot_publishes = 0      # publishes landing mid-decode (hook)
        self.served_requests = 0
        self.deferred_joins = 0     # joins postponed by the memory model

    # ------------------------------------------------------------ admission
    def _admission_tokens(self, extra_rank: int) -> Tuple[int, int]:
        lanes, seq = self.replica.lanes, self.replica.max_len
        toks = self.pool.occupied_tokens(lanes, seq) + lanes * seq
        rtoks = self.pool.occupied_rank_tokens(lanes, seq) \
            + extra_rank * lanes * seq
        return toks, rtoks

    def _check_publish(self, rank: int, pending: int = 0) -> None:
        if len(self.pool.free_slots()) <= pending:
            raise AdmissionError("no free adapter slot")
        if self.mem is None or self.mode == "continuous":
            # continuous mode charges actual per-request footprints at
            # join time instead of reserving lanes x max_len per adapter
            return
        rank = self.mem.charged_rank(min(rank, self.pool.r_max))
        toks, rtoks = self._admission_tokens(rank)
        if not self.mem.fits_ranked(toks, rtoks):
            raise AdmissionError(
                f"publish would exceed memory budget: "
                f"{self.mem.predict_ranked(toks, rtoks):.3e} B > "
                f"{self.mem.capacity * self.mem.safety_margin:.3e} B")

    def _request_footprint(self, r: ServeRequest) -> Tuple[int, int]:
        """Actual serving footprint: the tokens this request will occupy
        in its lane's cache, and the rank-tokens its adapter's charged
        rank multiplies them into."""
        toks = len(r.prompt) + r.max_new
        slot = self.pool.slot_of(r.adapter_id)
        rank = self.pool.slot_rank[slot]
        if self.mem is not None:
            rank = self.mem.charged_rank(rank)
        return toks, rank * toks

    def _can_join(self, r: ServeRequest) -> bool:
        if self.mem is None:
            return True
        toks, rtoks = self._request_footprint(r)
        toks += sum(t for t, _ in self._inflight.values())
        rtoks += sum(rt for _, rt in self._inflight.values())
        return self.mem.fits_ranked(toks, rtoks)

    # ------------------------------------------------------------ publishing
    def publish(self, adapter_id: str, adapter: Dict, rank: int,
                meta: Optional[Dict] = None) -> int:
        self._check_publish(rank)
        slot = self.pool.publish(adapter_id, adapter, rank, meta=meta)
        self.publishes += 1
        return slot

    def queue_publish(self, adapter_id: str, adapter: Dict, rank: int,
                      meta: Optional[Dict] = None) -> None:
        """Defer the publish to the next drain point between decode steps;
        a burst of queued publishes lands as ONE batched
        ``publish_many`` slot update. Admission (free slots, and in round
        mode the memory bound) is checked now, against earlier queued
        publishes too, so a refused publish fails fast at call time."""
        self._check_publish(rank, pending=len(self._pending_pubs))
        self._pending_pubs.append((adapter_id, adapter, rank, meta))

    def _drain_pending_publishes(self) -> int:
        if not self._pending_pubs:
            return 0
        pending, self._pending_pubs = self._pending_pubs, []
        self.pool.publish_many(pending)
        self.publishes += len(pending)
        return len(pending)

    def publish_checkpoint(self, path: str,
                           adapter_id: Optional[str] = None) -> str:
        """Admit an adapter from a durable checkpoint artifact (the
        tune-to-serve path). Returns the adapter id."""
        import json

        # peek rank for admission without mutating the pool
        data = np.load(path if path.endswith(".npz") else path + ".npz",
                       allow_pickle=False)
        meta = json.loads(str(data["__meta__"]))
        self._check_publish(int(meta["rank"]))
        aid, _ = self.pool.publish_checkpoint(path, adapter_id=adapter_id)
        self.publishes += 1
        return aid

    def retire(self, adapter_id: str) -> int:
        assert not self._queues.get(adapter_id), \
            f"adapter {adapter_id!r} has queued requests"
        self._queues.pop(adapter_id, None)
        return self.pool.retire(adapter_id)

    # ------------------------------------------------------------ requests
    def submit(self, adapter_id: str, prompt, max_new: int, *,
               temperature: float = 0.0, top_k: int = 0,
               seed: int = 0) -> str:
        """Enqueue a decode request; returns its request id. Sampling is
        greedy unless ``temperature > 0`` (then optionally ``top_k``-
        truncated; ``seed`` keys the per-request sample stream)."""
        import time as _time

        if adapter_id not in self.pool.resident():
            raise AdmissionError(f"adapter {adapter_id!r} not resident")
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) < 1 or len(prompt) + max_new > self.replica.max_len:
            raise AdmissionError(
                f"prompt({len(prompt)}) + max_new({max_new}) exceeds "
                f"max_len={self.replica.max_len}")
        r = ServeRequest(request_id=f"req-{self._next_id}",
                         adapter_id=adapter_id, prompt=prompt,
                         max_new=max_new, temperature=temperature,
                         top_k=top_k, seed=seed)
        if self.mem is not None and self.mode == "continuous":
            # a request that can never fit even alone is refused up front
            toks, rtoks = self._request_footprint(r)
            if not self.mem.fits_ranked(toks, rtoks):
                raise AdmissionError(
                    f"request footprint {toks} tokens exceeds the memory "
                    f"budget even on an empty replica")
        r.submit_t = _time.perf_counter()
        self._next_id += 1
        self._queues[adapter_id].append(r)
        return r.request_id

    def queued(self) -> int:
        return sum(len(q) for q in self._queues.values())

    # ------------------------------------------------------------ continuous
    def _fill_lanes(self) -> int:
        """Join queued requests into free lanes, round-robin across
        adapters, re-checking the memory model per join. Returns joins."""
        joined = 0
        progress = True
        while progress:
            progress = False
            for adapter_id in list(self._queues):
                q = self._queues[adapter_id]
                if not q or adapter_id not in self.pool.resident():
                    continue
                r = q[0]
                slot = self.pool.slot_of(adapter_id)
                if self.replica.free_lane(slot) is None:
                    continue
                if not self._can_join(r):
                    self.deferred_joins += 1
                    continue        # re-checked as in-flight work completes
                q.popleft()
                ok = self.replica.try_join(r)
                assert ok
                self._inflight[r.request_id] = self._request_footprint(r)
                joined += 1
                progress = True
        return joined

    def step_continuous(self,
                        on_step: Optional[Callable[[int], None]] = None,
                        record_logits: bool = False) -> int:
        """Drain queued publishes, fill free lanes, run one fused decode
        step. Returns requests completed by the step; their lanes (and
        memory charges) free immediately, so the NEXT step can join new
        work — the zero-barrier property."""
        self._drain_pending_publishes()
        self._fill_lanes()
        hot_before = self.pool.version
        done = self.replica.step_continuous(on_step=on_step,
                                            record_logits=record_logits)
        if on_step is not None and self.pool.version > hot_before:
            self.hot_publishes += self.pool.version - hot_before
        for r in done:
            self._inflight.pop(r.request_id, None)
            self._done[r.request_id] = r
        self.served_requests += len(done)
        return len(done)

    # ------------------------------------------------------------ rounds
    def step_round(self, on_step: Optional[Callable[[int], None]] = None
                   ) -> int:
        """Serve one round over the head of every adapter's queue (up to
        ``lanes`` requests each). Returns requests completed; 0 = idle."""
        batch: List[ServeRequest] = []
        for adapter_id in list(self._queues):
            if adapter_id not in self.pool.resident():
                continue            # retired with queued work: re-check later
            q = self._queues[adapter_id]
            for _ in range(min(len(q), self.replica.lanes)):
                batch.append(q.popleft())
        if not batch:
            return 0
        hot_before = self.pool.version
        self.replica.serve_round(batch, on_step=on_step)
        if on_step is not None and self.pool.version > hot_before:
            self.hot_publishes += self.pool.version - hot_before
        for r in batch:
            self._done[r.request_id] = r
        self.served_requests += len(batch)
        return len(batch)

    def drain(self, on_step: Optional[Callable[[int], None]] = None
              ) -> Dict[str, List[int]]:
        """Serve until every queue and lane is empty; returns
        ``{request_id: generated tokens}`` for everything completed."""
        if self.mode == "round":
            while self.queued():
                served = self.step_round(on_step=on_step)
                on_step = None      # hooks fire on the first round only
                if served == 0:
                    break           # only retired-adapter queues remain
            return {rid: list(r.tokens) for rid, r in self._done.items()}
        while self.queued() or self.replica.busy_lanes():
            before = self.replica.busy_lanes()
            self.step_continuous(on_step=on_step)
            on_step = None
            if not self.replica.busy_lanes() and before == 0:
                break               # only retired/unjoinable queues remain
        return {rid: list(r.tokens) for rid, r in self._done.items()}

    def result(self, request_id: str) -> List[int]:
        assert request_id in self._done, f"request {request_id!r} not done"
        return list(self._done[request_id].tokens)
