"""AdapterPool: hot publish/retire of adapters into backbone slots.

The serving-side counterpart of training's ``SlotManager``: one frozen
backbone holds ``Z`` adapter slots, and adapters are published into /
retired from those slots *between decode steps* — no replica restart, no
recompile (slot shapes are static at ``r_max`` capacity; TRUE ranks ride
the same ``slot_ranks`` binding the rank-local training path uses). This
is the rtp-llm ``add_lora``/``lora_ids``-per-forward idiom: the pool's
``lora`` tree + ``ranks`` vector are inputs to every forward, so a
publish is visible on the very next step and resident slots are
untouched bit-for-bit (slot isolation).

Publishes load either from a live adapter tree (``publish``) or from a
durable ``checkpoint/checkpoint.py`` artifact (``publish_checkpoint``)
written by the service's tune-to-serve hook — the crash-safe path.
"""
from __future__ import annotations

import time
import zipfile
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import extract_slot, load_pytree
from repro.configs.base import ModelConfig
from repro.core import lora as LORA
from repro.models import model as M

# Version stamp written into / checked against checkpoint metadata so a
# pool never loads an adapter whose on-disk layout predates the current
# slot-stacked tree format.
SPEC_VERSION = 1


def adapter_template(cfg: ModelConfig) -> Dict:
    """Zero single-adapter tree ``{target: {"A": [L,din,r], "B": ...}}`` —
    the ``like`` structure checkpoint loads restore into."""
    zero = jnp.zeros((1,), jnp.int32)
    lt = LORA.init_lora_tree(jax.random.PRNGKey(0), cfg, 1, zero,
                             M.target_shapes(cfg))
    return extract_slot(lt, 0)


def _mask_adapter(adapter: Dict, rank: int, r_max: int) -> Dict:
    """Zero the padded rank region of a single adapter ([L,din,r] A /
    [L,r,dout] B): published slots keep the training invariant that the
    region beyond the TRUE rank is exactly zero."""
    keep = (jnp.arange(r_max) < rank)

    def mask(name: str, x: jnp.ndarray) -> jnp.ndarray:
        if name == "A":                      # [L, d_in, r]
            return x * keep[None, None, :].astype(x.dtype)
        return x * keep[None, :, None].astype(x.dtype)     # B: [L, r, d_out]

    return {t: {m: mask(m, jnp.asarray(ab[m])) for m in ("A", "B")}
            for t, ab in adapter.items()}


class PoolFull(Exception):
    """Raised by ``publish`` when no free slot is available."""


class CorruptCheckpoint(Exception):
    """Raised by ``publish_checkpoint`` when the artifact on disk cannot
    be read (truncated npz, missing keys, shape mismatch). Deliberately
    distinct from the AssertionError raised for a *valid* artifact with
    mismatched arch/spec_version: startup/recovery paths catch this, log
    a warning, and skip the artifact instead of crashing."""


class AdapterPool:
    """``Z`` hot-swappable adapter slots over one frozen backbone."""

    def __init__(self, cfg: ModelConfig, Z: int):
        assert Z >= 1
        self.cfg = cfg
        self.Z = Z
        self.r_max = cfg.lora.r_max
        self._template = adapter_template(cfg)
        zeros = jnp.zeros((Z,), jnp.int32)
        self.lora = LORA.init_lora_tree(jax.random.PRNGKey(0), cfg, Z,
                                        zeros, M.target_shapes(cfg))
        self.slot_adapter: List[Optional[str]] = [None] * Z
        self.slot_rank: List[int] = [0] * Z
        self.version = 0                       # bumps on publish/retire
        self.publish_latencies_s: List[float] = []
        self._meta: Dict[str, Dict] = {}       # adapter_id -> publish meta
        self._ranks_cache: Optional[jnp.ndarray] = None
        self._ranks_version = -1

    # ------------------------------------------------------------ queries
    @property
    def ranks(self) -> jnp.ndarray:
        """[Z] int32 TRUE ranks (0 = empty slot) — a forward input.
        Cached on device per pool version: the serving hot loop reads
        this every fused step and must not re-upload each time."""
        if self._ranks_version != self.version:
            self._ranks_cache = jnp.asarray(self.slot_rank, jnp.int32)
            self._ranks_version = self.version
        return self._ranks_cache

    def resident(self) -> Dict[str, int]:
        return {a: s for s, a in enumerate(self.slot_adapter)
                if a is not None}

    def slot_of(self, adapter_id: str) -> int:
        res = self.resident()
        assert adapter_id in res, f"adapter {adapter_id!r} not resident"
        return res[adapter_id]

    def free_slots(self) -> List[int]:
        return [s for s, a in enumerate(self.slot_adapter) if a is None]

    def mixed_rank(self) -> bool:
        return any(r != self.r_max for s, r in enumerate(self.slot_rank)
                   if self.slot_adapter[s] is not None)

    def meta_of(self, adapter_id: str) -> Dict:
        return self._meta.get(adapter_id, {})

    def occupied_tokens(self, lanes: int, seq_len: int) -> int:
        """Serving token budget: every resident adapter's lanes decode at
        up to ``seq_len`` positions (§A.3 token-linear accounting)."""
        return len(self.resident()) * lanes * seq_len

    def occupied_rank_tokens(self, lanes: int, seq_len: int) -> int:
        return sum(self.slot_rank[s] for s in self.resident().values()) \
            * lanes * seq_len

    # ------------------------------------------------------------ mutation
    def publish(self, adapter_id: str, adapter: Dict, rank: int,
                slot: Optional[int] = None,
                meta: Optional[Dict] = None) -> int:
        """Insert a single adapter ([L,...] tree) into a free slot; visible
        on the next decode step. Returns the slot index."""
        assert adapter_id not in self.resident(), \
            f"adapter {adapter_id!r} already resident"
        free = self.free_slots()
        if slot is None:
            if not free:
                raise PoolFull(f"no free slot for {adapter_id!r}")
            slot = free[0]
        assert slot in free, f"slot {slot} occupied"
        rank = max(min(int(rank), self.r_max), 1)
        t0 = time.perf_counter()
        self.lora = LORA.slot_update(
            self.lora, slot, _mask_adapter(adapter, rank, self.r_max))
        jax.block_until_ready(self.lora)
        self.publish_latencies_s.append(time.perf_counter() - t0)
        self.slot_adapter[slot] = adapter_id
        self.slot_rank[slot] = rank
        self._meta[adapter_id] = dict(meta or {})
        self.version += 1
        return slot

    def publish_many(self, items: List[Tuple]) -> List[int]:
        """Batched publish: insert N adapters with ONE fused slot update
        per LoRA leaf (``x.at[:, slots].set(stacked)``) instead of N
        sequential ``slot_update`` dispatches — amortizes the device
        round-trip when the frontend drains a burst of pending publishes
        between decode steps. ``items`` is a list of
        ``(adapter_id, adapter, rank)`` or ``(adapter_id, adapter, rank,
        meta)``. Returns the slot indices, in item order."""
        if not items:
            return []
        free = self.free_slots()
        if len(items) > len(free):
            raise PoolFull(
                f"{len(items)} publishes, {len(free)} free slots")
        resident = self.resident()
        norm = []
        for it in items:
            aid, adapter, rank = it[0], it[1], it[2]
            meta = it[3] if len(it) > 3 else None
            assert aid not in resident, f"adapter {aid!r} already resident"
            assert all(aid != o[0] for o in norm), \
                f"adapter {aid!r} listed twice"
            norm.append((aid, adapter,
                         max(min(int(rank), self.r_max), 1), meta))
        slots = free[:len(norm)]
        idx = jnp.asarray(slots, jnp.int32)
        masked = [_mask_adapter(ad, rank, self.r_max)
                  for _, ad, rank, _ in norm]
        t0 = time.perf_counter()

        def upd(old, *news):           # news: one [L, ...] leaf per adapter
            return old.at[:, idx].set(
                jnp.stack([n.astype(old.dtype) for n in news], axis=1))

        self.lora = jax.tree_util.tree_map(upd, self.lora, *masked)
        jax.block_until_ready(self.lora)
        per = (time.perf_counter() - t0) / len(norm)
        for slot, (aid, _, rank, meta) in zip(slots, norm):
            self.publish_latencies_s.append(per)   # amortized per adapter
            self.slot_adapter[slot] = aid
            self.slot_rank[slot] = rank
            self._meta[aid] = dict(meta or {})
        self.version += len(norm)
        return slots

    def publish_checkpoint(self, path: str,
                           adapter_id: Optional[str] = None,
                           slot: Optional[int] = None) -> Tuple[str, int]:
        """Publish from a durable artifact written by ``save_pytree``.
        The checkpoint's meta must carry the TRUE ``rank``, a matching
        ``spec_version``, and (when present) an ``arch`` equal to this
        pool's backbone. Returns ``(adapter_id, slot)``."""
        try:
            adapter, meta = load_pytree(path, self._template)
            rank = int(meta["rank"])
        except (OSError, ValueError, KeyError, zipfile.BadZipFile) as e:
            raise CorruptCheckpoint(
                f"checkpoint {path!r} unreadable: {e}") from e
        ver = meta.get("spec_version")
        assert ver == SPEC_VERSION, \
            f"checkpoint spec_version {ver} != pool {SPEC_VERSION}"
        arch = meta.get("arch")
        assert arch is None or arch == self.cfg.name, \
            f"checkpoint arch {arch!r} != backbone {self.cfg.name!r}"
        aid = adapter_id or meta.get("adapter_id") or path
        s = self.publish(aid, adapter, rank, slot=slot, meta=meta)
        return aid, s

    def retire(self, adapter_id: str) -> int:
        """Zero the adapter's slot and free it; resident slots untouched."""
        slot = self.slot_of(adapter_id)
        self.lora = LORA.zero_slot(self.lora, slot)
        self.slot_adapter[slot] = None
        self.slot_rank[slot] = 0
        self._meta.pop(adapter_id, None)
        self.version += 1
        return slot

    def adapter_at(self, slot: int) -> Dict:
        """Host copy of one slot's adapter ([L,...])."""
        return jax.tree_util.tree_map(lambda x: np.asarray(x[:, slot]),
                                      self.lora)
