"""ServingReplica: continuous-batching decode over one adapter pool.

One frozen backbone serves every resident adapter of an ``AdapterPool``
at once: in-flight requests map to ``(slot, lane)`` coordinates of the
slot-stacked forward — slot = the request's adapter, lane = one of the
replica's ``lanes`` decode streams per slot — so each decode step
advances ``Z x lanes`` streams in a single fused kernel launch. Prefill
and decode both run with the pool's ``ranks`` vector bound via
``LORA.slot_ranks`` (per-slot TRUE ranks, the rank-local grouped-LoRA
path on the Pallas backend; on the jnp backend the full-rank select is
the identity, which keeps fused-vs-solo decode bitwise equal).

Two batching disciplines share the replica:

**Continuous (default drive mode).** The decode cache carries a
PER-LANE position vector (``init_cache(per_lane=True)``: ``pos`` is
``[Z, lanes]``, ring ``k_pos`` is ``[Z, lanes, W]``), so every lane is
its own stream: a request joins the moment a lane in its adapter's slot
frees up — block prefill writes its prompt into its own lane cache at
offsets 0..P-1 (``prefill_lanes``; ring/recurrent families stream the
prompt through the decode step after a lane reset) — and leaves the
moment it has ``max_new`` tokens, freeing the lane for the next
request. The cache is NEVER epoch-reset while any lane is live; idle
lanes are frozen bitwise by the ``active`` mask. Per-request
``RequestRecord`` latency accounting (queue/prefill/decode) replaces
round accounting.

**Round-based (legacy / baseline).** ``serve_round`` keeps the PR-7
behavior — one *global* cache position, so requests only join at a
fresh cache epoch and finished lanes idle (re-feeding their last token)
until the slowest stream drains. It remains the A/B baseline the
continuous mode is benchmarked against (``bench_continuous.py``) and
the greedy bitwise-test path.

Sampling: requests may carry ``temperature``/``top_k`` (continuous mode;
greedy when ``temperature == 0``, the default and the bitwise path).
The sample key is per-lane: ``fold_in(fold_in(PRNGKey(sample_seed),
request.seed), token_index)`` — deterministic under a fixed seed and
independent of WHEN the request joined or which lane it landed on.

Hot ``publish``/``retire`` on the pool between decode steps is sound in
both modes — slot isolation — and is exactly what the serving isolation
tests pin down.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import lora as LORA
from repro.core.steps import (make_join_decode_step, make_lane_prefill_step,
                              make_prefill_step, make_serve_step)
from repro.models import model as M
from repro.serve.pool import AdapterPool


@dataclasses.dataclass
class ServeRequest:
    """One decode request routed to a resident adapter."""
    request_id: str
    adapter_id: str
    prompt: np.ndarray            # [P] int32 token ids, P >= 1
    max_new: int
    temperature: float = 0.0      # 0 => greedy (the bitwise path)
    top_k: int = 0                # 0 => full vocab
    seed: int = 0                 # folded into the per-lane sample key
    tokens: List[int] = dataclasses.field(default_factory=list)
    # lane-lifecycle bookkeeping (filled by the replica / frontend)
    fed: int = 0                  # prompt+generated tokens consumed so far
    submit_t: Optional[float] = None
    join_t: Optional[float] = None
    first_token_t: Optional[float] = None
    done_t: Optional[float] = None

    @property
    def done(self) -> bool:
        return len(self.tokens) >= self.max_new


@dataclasses.dataclass
class RequestRecord:
    """Per-request completion record (continuous mode): the latency
    breakdown that replaces round-level accounting."""
    request_id: str
    adapter_id: str
    prompt_len: int
    new_tokens: int
    queue_s: float                # submit -> lane assignment
    prefill_s: float              # lane assignment -> first token
    decode_s: float               # first token -> completion
    total_s: float                # submit -> completion


@dataclasses.dataclass
class RoundStats:
    """One cache epoch's accounting (round-based mode)."""
    requests: int
    generated: int                # tokens produced this round
    decode_steps: int             # fused step invocations (incl. prefill
                                  # steps when streaming token-by-token)
    wall_s: float
    logits: List[Tuple[int, np.ndarray]]   # (position, [Z,lanes,V]) when
                                           # recording is on


class ServingReplica:
    """Lane scheduler over ``pool.Z`` x ``lanes`` decode streams."""

    def __init__(self, cfg: ModelConfig, params, pool: AdapterPool, *,
                 lanes: int = 4, max_len: int = 64, ring: bool = False,
                 sample_seed: int = 0, join_batch: int = 2,
                 join_wait_steps: int = 1):
        assert lanes >= 1 and max_len >= 2
        self.cfg = cfg
        self.params = params
        self.pool = pool
        self.lanes = lanes
        self.max_len = max_len
        self.ring = ring and cfg.family != "ssm"
        # block prefill writes the whole prompt in one forward; ring caches
        # and recurrent families need per-position writes (launch parity)
        self._block_prefill = (not self.ring
                               and cfg.family not in ("ssm", "hybrid"))
        prefill = make_prefill_step(cfg)
        serve = make_serve_step(cfg)
        lane_prefill = make_lane_prefill_step(cfg)
        join_decode = make_join_decode_step(cfg)

        # every wrapper also returns the fused greedy argmax: the hot
        # per-step host sync then transfers [Z, lanes] int32 instead of
        # dispatching a separate argmax program and fetching full logits
        def ranked_prefill(params, lora, cache, batch, ranks):
            with LORA.slot_ranks(ranks):
                logits, cache = prefill(params, lora, cache, batch)
            return logits, jnp.argmax(logits, axis=-1), cache

        def ranked_decode(params, lora, cache, tokens, ranks):
            with LORA.slot_ranks(ranks):
                logits, cache = serve(params, lora, cache, tokens)
            return logits, jnp.argmax(logits, axis=-1), cache

        def ranked_decode_lanes(params, lora, cache, tokens, active, ranks):
            with LORA.slot_ranks(ranks):
                logits, cache = serve(params, lora, cache, tokens, active)
            return logits, jnp.argmax(logits, axis=-1), cache

        def ranked_lane_prefill(params, lora, cache, tokens, mask, plens,
                                ranks):
            with LORA.slot_ranks(ranks):
                logits, cache = lane_prefill(params, lora, cache, tokens,
                                             mask, plens)
            return logits, jnp.argmax(logits, axis=-1), cache

        def ranked_join_decode(params, lora, cache, tokens, mask, plens,
                               cur, active, ranks):
            with LORA.slot_ranks(ranks):
                return join_decode(params, lora, cache, tokens, mask,
                                   plens, cur, active)

        self._prefill = jax.jit(ranked_prefill)
        self._decode = jax.jit(ranked_decode)
        self._decode_lanes = jax.jit(ranked_decode_lanes)
        self._lane_prefill = jax.jit(ranked_lane_prefill)
        self._join_decode = jax.jit(ranked_join_decode)
        self._reset_lanes = jax.jit(
            lambda cache, mask: M.reset_lanes(cfg, cache, mask))
        self._sample_key = jax.random.PRNGKey(sample_seed)
        self.total_generated = 0
        self.total_decode_steps = 0
        self.total_wall_s = 0.0
        self.rounds = 0
        # continuous-mode state: one live per-lane cache, never epoch-reset
        self._cache: Optional[Dict] = None
        self._cur = np.zeros((pool.Z, lanes), np.int32)
        self._active = np.zeros((pool.Z, lanes), bool)
        self._active_dev: Optional[jnp.ndarray] = None   # device mirror
        self._lane_req: Dict[Tuple[int, int], ServeRequest] = {}
        self._pending_joins: Dict[Tuple[int, int], ServeRequest] = {}
        self._join_step: Dict[Tuple[int, int], int] = {}
        # joins flush when >= join_batch are pending, the oldest has
        # waited join_wait_steps fused steps, or no lane is decoding —
        # merging near-simultaneous arrivals into ONE prefill launch
        self.join_batch = max(join_batch, 1)
        self.join_wait_steps = max(join_wait_steps, 0)
        self.joins = 0
        self.block_prefills = 0     # fused ragged prefill launches
        self.records: List[RequestRecord] = []
        self.step_logits: List[Tuple[int, np.ndarray]] = []

    # ------------------------------------------------------------ lanes
    def busy_lanes(self) -> int:
        return len(self._lane_req) + len(self._pending_joins)

    def free_lane(self, slot: int) -> Optional[int]:
        """First free lane in the slot's row, or None."""
        for lane in range(self.lanes):
            c = (slot, lane)
            if c not in self._lane_req and c not in self._pending_joins:
                return lane
        return None

    def try_join(self, r: ServeRequest) -> bool:
        """Assign the request to a free lane of its adapter's slot; it is
        prefixed (block prefill or lane-reset streaming) right before the
        next fused decode step. Returns False when the row is full."""
        assert len(r.prompt) >= 1
        assert len(r.prompt) + r.max_new <= self.max_len, \
            f"request {r.request_id!r} exceeds max_len={self.max_len}"
        slot = self.pool.slot_of(r.adapter_id)
        lane = self.free_lane(slot)
        if lane is None:
            return False
        r.join_t = time.perf_counter()
        if r.submit_t is None:
            r.submit_t = r.join_t
        self._pending_joins[(slot, lane)] = r
        self._join_step[(slot, lane)] = self.total_decode_steps
        self.joins += 1
        return True

    def _ensure_cache(self) -> None:
        if self._cache is None:
            self._cache = M.init_cache(self.cfg, self.pool.Z, self.lanes,
                                       self.max_len, ring=self.ring,
                                       per_lane=True)

    # ------------------------------------------------------------ sampling
    def _sample(self, r: ServeRequest, greedy_tok: int,
                logits_row: Optional[np.ndarray]) -> int:
        if r.temperature <= 0.0:
            return greedy_tok
        key = jax.random.fold_in(
            jax.random.fold_in(self._sample_key, r.seed), len(r.tokens))
        logits = jnp.asarray(logits_row, jnp.float32) / r.temperature
        if r.top_k and r.top_k < logits.shape[-1]:
            kth = jnp.sort(logits)[-r.top_k]
            logits = jnp.where(logits >= kth, logits, -jnp.inf)
        return int(jax.random.categorical(key, logits))

    # ------------------------------------------------------------ joins
    def _flush_joins(self) -> None:
        """Write pending joiners' prompts into their own lane caches.
        Non-ring attention families block-prefill — ONE fused ragged
        ``prefill_lanes`` launch per step, prompts right-padded to the
        next power of two of the longest joiner (bounds compile count;
        the per-lane ``plens`` keeps padded prefill bitwise identical to
        exact-length); ring/recurrent families reset the lane and stream
        the prompt through decode."""
        pending, self._pending_joins = self._pending_joins, {}
        self._join_step.clear()
        if not pending:
            return
        Z, lanes = self.pool.Z, self.lanes
        block: Dict[Tuple[int, int], ServeRequest] = {}
        stream: Dict[Tuple[int, int], ServeRequest] = {}
        for coord, r in pending.items():
            if self._block_prefill and len(r.prompt) > 1:
                block[coord] = r
            else:
                stream[coord] = r
        if block:
            P = max(len(r.prompt) for r in block.values())
            P = min(1 << (P - 1).bit_length(),     # pow-2 padding bucket
                    self.max_len)                  # (cache cap)
            toks = np.zeros((Z, lanes, P), np.int32)
            mask = np.zeros((Z, lanes), bool)
            plens = np.ones((Z, lanes), np.int32)  # idle rows: index 0
            for (s, lane), r in block.items():
                toks[s, lane, :len(r.prompt)] = r.prompt
                mask[s, lane] = True
                plens[s, lane] = len(r.prompt)
            logits, greedy, self._cache = self._lane_prefill(
                self.params, self.pool.lora, self._cache,
                jnp.asarray(toks), jnp.asarray(mask), jnp.asarray(plens),
                self.pool.ranks)
            self.block_prefills += 1
            nxt = np.asarray(greedy)
            rows = np.asarray(logits) if any(
                r.temperature > 0 for r in block.values()) else None
            for (s, lane), r in block.items():
                tok = self._sample(
                    r, int(nxt[s, lane]),
                    None if rows is None else rows[s, lane])
                r.tokens.append(tok)
                self.total_generated += 1
                r.fed = len(r.prompt)
                r.first_token_t = time.perf_counter()
                self._cur[s, lane] = tok
                self._activate(s, lane, r)
        if stream:
            mask = np.zeros((Z, lanes), bool)
            for (s, lane) in stream:
                mask[s, lane] = True
            self._cache = self._reset_lanes(self._cache, jnp.asarray(mask))
            for (s, lane), r in stream.items():
                r.fed = 0
                self._cur[s, lane] = r.prompt[0]
                self._activate(s, lane, r)

    def _activate(self, slot: int, lane: int, r: ServeRequest) -> None:
        self._lane_req[(slot, lane)] = r
        self._active[slot, lane] = True
        self._active_dev = None

    # ------------------------------------------------------------ decode
    def step_continuous(self, on_step: Optional[Callable[[int], None]] = None,
                        record_logits: bool = False) -> List[ServeRequest]:
        """Flush pending joins, run ONE fused per-lane decode step, and
        return the requests completed by it (their lanes are freed — the
        frontend refills them before the next step). ``on_step(i)`` fires
        before the fused step (hot publish/retire hook, like the round
        path). Completion appends a ``RequestRecord`` to ``records``."""
        t0 = time.perf_counter()
        self._ensure_cache()
        flush_due = bool(self._pending_joins) and (
            not self._lane_req
            or len(self._pending_joins) >= self.join_batch
            or self.total_decode_steps - min(self._join_step.values())
            >= self.join_wait_steps)
        # greedy block-prefillable joiners take the FUSED join+decode
        # program: prefill + first-token argmax + one decode step in a
        # single launch (no host round-trip between prefill and the step
        # consuming the first token); sampled or streaming joiners fall
        # back to the separate flush
        fuse = (flush_due and self._block_prefill
                and all(len(r.prompt) > 1 and r.temperature <= 0.0
                        for r in self._pending_joins.values()))
        if flush_due and not fuse:
            self._flush_joins()
        done: List[ServeRequest] = []
        for coord, r in list(self._lane_req.items()):
            if r.done:                      # block prefill covered max_new=1
                done.append(self._complete(coord, r))
        if fuse:
            joiners, self._pending_joins = self._pending_joins, {}
            self._join_step.clear()
            Z, lanes = self.pool.Z, self.lanes
            P = max(len(r.prompt) for r in joiners.values())
            P = min(1 << (P - 1).bit_length(), self.max_len)
            toks = np.zeros((Z, lanes, P), np.int32)
            mask = np.zeros((Z, lanes), bool)
            plens = np.ones((Z, lanes), np.int32)
            for (s, lane), r in joiners.items():
                toks[s, lane, :len(r.prompt)] = r.prompt
                mask[s, lane] = True
                plens[s, lane] = len(r.prompt)
            if on_step is not None:
                on_step(self.total_decode_steps)
            if self._active_dev is None:
                self._active_dev = jnp.asarray(self._active)
            p_greedy, logits, greedy, self._cache = self._join_decode(
                self.params, self.pool.lora, self._cache,
                jnp.asarray(toks), jnp.asarray(mask), jnp.asarray(plens),
                jnp.asarray(self._cur), self._active_dev, self.pool.ranks)
            self.block_prefills += 1
            p_nxt = np.asarray(p_greedy)
            now = time.perf_counter()
            for (s, lane), r in joiners.items():
                tok = int(p_nxt[s, lane])
                r.tokens.append(tok)
                self.total_generated += 1
                r.fed = len(r.prompt)
                r.first_token_t = now
                self._cur[s, lane] = tok
                self._activate(s, lane, r)
                if r.done:      # max_new == 1: prefill covered it fully
                    done.append(self._complete((s, lane), r))
        else:
            if not self._lane_req:
                self.total_wall_s += time.perf_counter() - t0
                return done
            if on_step is not None:
                on_step(self.total_decode_steps)
            if self._active_dev is None:  # re-upload only on lane churn
                self._active_dev = jnp.asarray(self._active)
            logits, greedy, self._cache = self._decode_lanes(
                self.params, self.pool.lora, self._cache,
                jnp.asarray(self._cur), self._active_dev,
                self.pool.ranks)
        nxt = np.asarray(greedy)
        rows = None
        if record_logits or any(r.temperature > 0
                                for r in self._lane_req.values()):
            rows = np.asarray(logits)
        if record_logits:
            self.step_logits.append((self.total_decode_steps, rows))
        generated = 0
        for (s, lane), r in list(self._lane_req.items()):
            P = len(r.prompt)
            r.fed += 1
            if r.fed < P:                   # still consuming its prompt
                self._cur[s, lane] = r.prompt[r.fed]
                continue
            tok = self._sample(r, int(nxt[s, lane]),
                               None if rows is None else rows[s, lane])
            if r.first_token_t is None:
                r.first_token_t = time.perf_counter()
            r.tokens.append(tok)
            generated += 1
            self._cur[s, lane] = tok
            if r.done:
                done.append(self._complete((s, lane), r))
        self.total_decode_steps += 1
        self.total_generated += generated
        self.total_wall_s += time.perf_counter() - t0
        return done

    def _complete(self, coord: Tuple[int, int],
                  r: ServeRequest) -> ServeRequest:
        r.done_t = time.perf_counter()
        del self._lane_req[coord]
        self._active[coord] = False
        self._active_dev = None
        self.records.append(RequestRecord(
            request_id=r.request_id, adapter_id=r.adapter_id,
            prompt_len=len(r.prompt), new_tokens=len(r.tokens),
            queue_s=r.join_t - r.submit_t,
            prefill_s=r.first_token_t - r.join_t,
            decode_s=r.done_t - r.first_token_t,
            total_s=r.done_t - r.submit_t))
        return r

    # ------------------------------------------------------------ rounds
    def pack(self, requests: List[ServeRequest]
             ) -> Dict[Tuple[int, int], ServeRequest]:
        """Assign requests to (slot, lane); every adapter must be resident
        and get at most ``lanes`` requests in one round."""
        lane_req: Dict[Tuple[int, int], ServeRequest] = {}
        used: Dict[int, int] = {}
        for r in requests:
            s = self.pool.slot_of(r.adapter_id)
            lane = used.get(s, 0)
            assert lane < self.lanes, \
                f"adapter {r.adapter_id!r}: > {self.lanes} requests/round"
            assert len(r.prompt) >= 1
            assert len(r.prompt) + r.max_new <= self.max_len, \
                f"request {r.request_id!r} exceeds max_len={self.max_len}"
            used[s] = lane + 1
            lane_req[(s, lane)] = r
        return lane_req

    def serve_round(self, requests: List[ServeRequest],
                    on_step: Optional[Callable[[int], None]] = None,
                    record_logits: bool = False) -> RoundStats:
        """Drive one cache epoch (round-based baseline): streamed prefill
        + greedy decode until every request has ``max_new`` tokens.
        ``on_step(i)`` fires before the i-th fused step — a hook may hot
        publish/retire adapters on the pool there (visible next step,
        resident slots untouched)."""
        assert requests, "empty round"
        lane_req = self.pack(requests)
        pool = self.pool
        Z, b = pool.Z, self.lanes
        cache = M.init_cache(self.cfg, Z, b, self.max_len, ring=self.ring)
        cur = np.zeros((Z, b), np.int32)
        lens = {len(r.prompt) for r in lane_req.values()}
        logits = None
        logits_log: List[Tuple[int, np.ndarray]] = []
        steps = 0
        t0 = time.perf_counter()
        if self._block_prefill and len(lens) == 1 and min(lens) > 1:
            P0 = lens.pop()
            prompts = np.zeros((Z, b, P0), np.int32)
            for (s, lane), r in lane_req.items():
                prompts[s, lane] = r.prompt
            logits, greedy, cache = self._prefill(
                self.params, pool.lora, cache,
                {"tokens": jnp.asarray(prompts)}, pool.ranks)
            t = P0 - 1                 # logits for position P0-1 in hand
        else:
            for (s, lane), r in lane_req.items():
                cur[s, lane] = r.prompt[0]
            t = -1                     # nothing consumed yet
        generated = 0
        while True:
            if logits is not None:
                nxt = np.asarray(greedy)
                if record_logits:
                    logits_log.append((t, np.asarray(logits)))
                for (s, lane), r in lane_req.items():
                    P = len(r.prompt)
                    if t < P - 1:
                        cur[s, lane] = r.prompt[t + 1]
                    else:
                        tok = int(nxt[s, lane])
                        if not r.done:
                            r.tokens.append(tok)
                            generated += 1
                        cur[s, lane] = tok
                if all(r.done for r in lane_req.values()):
                    break
            if on_step is not None:
                on_step(steps)
            logits, greedy, cache = self._decode(self.params, pool.lora,
                                                 cache, jnp.asarray(cur),
                                                 pool.ranks)
            steps += 1
            t += 1
        jax.block_until_ready(logits)
        wall = time.perf_counter() - t0
        self.total_generated += generated
        self.total_decode_steps += steps
        self.total_wall_s += wall
        self.rounds += 1
        return RoundStats(requests=len(requests), generated=generated,
                          decode_steps=steps, wall_s=wall,
                          logits=logits_log)

    @property
    def aggregate_tok_s(self) -> float:
        return self.total_generated / max(self.total_wall_s, 1e-9)
