"""ServingReplica: continuous-batching decode over one adapter pool.

One frozen backbone serves every resident adapter of an ``AdapterPool``
at once: in-flight requests map to ``(slot, lane)`` coordinates of the
slot-stacked forward — slot = the request's adapter, lane = one of the
replica's ``lanes`` decode streams per slot — so each decode step
advances ``Z x lanes`` streams in a single fused kernel launch. Prefill
and decode both run with the pool's ``ranks`` vector bound via
``LORA.slot_ranks`` (per-slot TRUE ranks, the rank-local grouped-LoRA
path on the Pallas backend; on the jnp backend the full-rank select is
the identity, which keeps fused-vs-solo decode bitwise equal).

Batching is ROUND-based: the decode cache keeps one *global* position
scalar (``model.decode_step`` writes every lane at ``cache["pos"]``), so
requests may only join when a fresh cache epoch starts — an idle lane's
pad-token K/V at earlier positions would otherwise be attended by a
late joiner. Within a round, prompts of different lengths stream
token-by-token through the decode step (a lane still consuming its
prompt feeds prompt tokens; shorter prompts start generating earlier),
finished lanes re-feed their last token (lane caches never cross), and
the cache is reset between rounds. Hot ``publish``/``retire`` on the
pool between decode steps IS sound mid-round — slot isolation — and is
exactly what the serving isolation tests pin down.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import lora as LORA
from repro.core.steps import make_prefill_step, make_serve_step
from repro.models import model as M
from repro.serve.pool import AdapterPool


@dataclasses.dataclass
class ServeRequest:
    """One decode request routed to a resident adapter."""
    request_id: str
    adapter_id: str
    prompt: np.ndarray            # [P] int32 token ids, P >= 1
    max_new: int
    tokens: List[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.tokens) >= self.max_new


@dataclasses.dataclass
class RoundStats:
    """One cache epoch's accounting."""
    requests: int
    generated: int                # tokens produced this round
    decode_steps: int             # fused step invocations (incl. prefill
                                  # steps when streaming token-by-token)
    wall_s: float
    logits: List[Tuple[int, np.ndarray]]   # (position, [Z,lanes,V]) when
                                           # recording is on


class ServingReplica:
    """Round-based continuous batching over ``pool.Z`` x ``lanes`` streams."""

    def __init__(self, cfg: ModelConfig, params, pool: AdapterPool, *,
                 lanes: int = 4, max_len: int = 64, ring: bool = False):
        assert lanes >= 1 and max_len >= 2
        self.cfg = cfg
        self.params = params
        self.pool = pool
        self.lanes = lanes
        self.max_len = max_len
        self.ring = ring and cfg.family != "ssm"
        # block prefill writes the whole prompt in one forward; ring caches
        # and recurrent families need per-position writes (launch parity)
        self._block_prefill = (not self.ring
                               and cfg.family not in ("ssm", "hybrid"))
        prefill = make_prefill_step(cfg)
        serve = make_serve_step(cfg)

        def ranked_prefill(params, lora, cache, batch, ranks):
            with LORA.slot_ranks(ranks):
                return prefill(params, lora, cache, batch)

        def ranked_decode(params, lora, cache, tokens, ranks):
            with LORA.slot_ranks(ranks):
                return serve(params, lora, cache, tokens)

        self._prefill = jax.jit(ranked_prefill)
        self._decode = jax.jit(ranked_decode)
        self.total_generated = 0
        self.total_decode_steps = 0
        self.total_wall_s = 0.0
        self.rounds = 0

    # ------------------------------------------------------------ packing
    def pack(self, requests: List[ServeRequest]
             ) -> Dict[Tuple[int, int], ServeRequest]:
        """Assign requests to (slot, lane); every adapter must be resident
        and get at most ``lanes`` requests in one round."""
        lane_req: Dict[Tuple[int, int], ServeRequest] = {}
        used: Dict[int, int] = {}
        for r in requests:
            s = self.pool.slot_of(r.adapter_id)
            lane = used.get(s, 0)
            assert lane < self.lanes, \
                f"adapter {r.adapter_id!r}: > {self.lanes} requests/round"
            assert len(r.prompt) >= 1
            assert len(r.prompt) + r.max_new <= self.max_len, \
                f"request {r.request_id!r} exceeds max_len={self.max_len}"
            used[s] = lane + 1
            lane_req[(s, lane)] = r
        return lane_req

    # ------------------------------------------------------------ serving
    def serve_round(self, requests: List[ServeRequest],
                    on_step: Optional[Callable[[int], None]] = None,
                    record_logits: bool = False) -> RoundStats:
        """Drive one cache epoch: streamed prefill + greedy decode until
        every request has ``max_new`` tokens. ``on_step(i)`` fires before
        the i-th fused step — a hook may hot publish/retire adapters on
        the pool there (visible next step, resident slots untouched)."""
        assert requests, "empty round"
        lane_req = self.pack(requests)
        pool = self.pool
        Z, b = pool.Z, self.lanes
        cache = M.init_cache(self.cfg, Z, b, self.max_len, ring=self.ring)
        cur = np.zeros((Z, b), np.int32)
        lens = {len(r.prompt) for r in lane_req.values()}
        logits = None
        logits_log: List[Tuple[int, np.ndarray]] = []
        steps = 0
        t0 = time.perf_counter()
        if self._block_prefill and len(lens) == 1 and min(lens) > 1:
            P0 = lens.pop()
            prompts = np.zeros((Z, b, P0), np.int32)
            for (s, lane), r in lane_req.items():
                prompts[s, lane] = r.prompt
            logits, cache = self._prefill(
                self.params, pool.lora, cache,
                {"tokens": jnp.asarray(prompts)}, pool.ranks)
            t = P0 - 1                 # logits for position P0-1 in hand
        else:
            for (s, lane), r in lane_req.items():
                cur[s, lane] = r.prompt[0]
            t = -1                     # nothing consumed yet
        generated = 0
        while True:
            if logits is not None:
                nxt = np.asarray(jnp.argmax(logits, axis=-1))
                if record_logits:
                    logits_log.append((t, np.asarray(logits)))
                for (s, lane), r in lane_req.items():
                    P = len(r.prompt)
                    if t < P - 1:
                        cur[s, lane] = r.prompt[t + 1]
                    else:
                        tok = int(nxt[s, lane])
                        if not r.done:
                            r.tokens.append(tok)
                            generated += 1
                        cur[s, lane] = tok
                if all(r.done for r in lane_req.values()):
                    break
            if on_step is not None:
                on_step(steps)
            logits, cache = self._decode(self.params, pool.lora, cache,
                                         jnp.asarray(cur), pool.ranks)
            steps += 1
            t += 1
        jax.block_until_ready(logits)
        wall = time.perf_counter() - t0
        self.total_generated += generated
        self.total_decode_steps += steps
        self.total_wall_s += wall
        self.rounds += 1
        return RoundStats(requests=len(requests), generated=generated,
                          decode_steps=steps, wall_s=wall,
                          logits=logits_log)

    @property
    def aggregate_tok_s(self) -> float:
        return self.total_generated / max(self.total_wall_s, 1e-9)
