"""Tune-to-serve: the multi-LoRA serving tier on the shared backbone.

``AdapterPool`` (hot publish/retire into backbone slots) +
``ServingReplica`` (round-based continuous batching through the
rank-local decode path) + ``ServingFrontend`` (queueing, routing, §A.3+k2
admission) + ``ServingReplicaDriver`` (the replica as a first-class
cluster resident). See docs/ARCHITECTURE.md "Serving tier".
"""
from repro.serve.driver import ServingReplicaDriver, serving_spec
from repro.serve.frontend import AdmissionError, ServingFrontend
from repro.serve.pool import (SPEC_VERSION, AdapterPool, PoolFull,
                              adapter_template)
from repro.serve.replica import RoundStats, ServeRequest, ServingReplica

__all__ = [
    "AdapterPool", "PoolFull", "SPEC_VERSION", "adapter_template",
    "ServingReplica", "ServeRequest", "RoundStats",
    "ServingFrontend", "AdmissionError",
    "ServingReplicaDriver", "serving_spec",
]
