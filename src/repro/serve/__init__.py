"""Tune-to-serve: the multi-LoRA serving tier on the shared backbone.

``AdapterPool`` (hot publish/retire into backbone slots, batched via
``publish_many``) + ``ServingReplica`` (continuous batching over
per-lane cache positions — requests join/leave mid-decode with zero
barrier — plus the legacy round-based baseline) + ``ServingFrontend``
(queueing, routing, §A.3+k2 admission on actual per-request footprints)
+ ``ServingReplicaDriver`` (the replica as a first-class cluster
resident). See docs/ARCHITECTURE.md "Serving tier".
"""
from repro.serve.driver import ServingReplicaDriver, serving_spec
from repro.serve.frontend import AdmissionError, ServingFrontend
from repro.serve.pool import (SPEC_VERSION, AdapterPool, PoolFull,
                              adapter_template)
from repro.serve.replica import (RequestRecord, RoundStats, ServeRequest,
                                 ServingReplica)

__all__ = [
    "AdapterPool", "PoolFull", "SPEC_VERSION", "adapter_template",
    "ServingReplica", "ServeRequest", "RoundStats", "RequestRecord",
    "ServingFrontend", "AdmissionError",
    "ServingReplicaDriver", "serving_spec",
]
