"""Failure-injecting chaos harness for the virtual cluster.

Two injection layers:

  * ``FaultyTaskDriver`` — wraps any ``TaskDriver`` and fires planned
    ``REPLICA_FAILED`` faults at chosen *task-local work* times: the
    chunk containing the fault point is lost once and re-executed after
    a bounded ``backoff`` (the chunk is billed ``2*dt + backoff``
    virtual seconds), while the wrapped driver's state only ever
    advances on the successful retry — so the loss trajectory is
    bitwise identical to an un-faulted run. Because faults trigger on
    task-local progress (not global cluster time), wrapping the SAME
    drivers into the elastic runtime and into ``execute_static`` charges
    IDENTICAL penalties to both, which is what lets the exact
    elastic <= static theorem survive injection: ``residual_estimate``
    reserves ``chunk_bound + backoff`` per pending fault, keeping
    residuals sound monotone-shrinking upper bounds, and ``chaos_spec``
    inflates the planner duration by the same reserve.

  * ``ElasticClusterRuntime.inject_fault`` (sched/cluster.py) — a
    runtime-level ``POD_KILLED`` at a chosen *virtual cluster* time: the
    pod's driver is suspended at its last chunk boundary and requeued
    with backoff through the PR 6 resume path. Use ``FaultPlan`` +
    ``FaultyTaskDriver`` for property tests (penalties are
    schedule-independent), ``inject_fault`` for end-to-end pod-loss
    drills.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from repro.sched.cluster import DriverChunk, TaskDriver
from repro.sched.events import EventKind, ProgressEvent
from repro.sched.inter_task import TaskSpec

_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class Fault:
    """One injected chunk failure at ``at_progress`` task-local work
    seconds, retried after ``backoff`` seconds."""
    at_progress: float
    backoff: float = 0.0


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Task name -> planned faults (the chaos schedule for a workload)."""
    faults: Dict[str, Tuple[Fault, ...]] = dataclasses.field(
        default_factory=dict)

    def for_task(self, name: str) -> List[Fault]:
        return sorted(self.faults.get(name, ()),
                      key=lambda f: f.at_progress)

    def total(self) -> int:
        return sum(len(v) for v in self.faults.values())


class FaultyTaskDriver(TaskDriver):
    """Deterministic fault wrapper around any ``TaskDriver``.

    ``chunk_bound`` must upper-bound the wrapped driver's single-chunk
    ``dt`` (e.g. ``chunk_steps * step_time_s`` for the simulated driver);
    it is what each not-yet-fired fault reserves in the residual."""

    def __init__(self, name: str, inner: TaskDriver,
                 faults: Sequence[Fault], chunk_bound: float):
        self.name = name
        self.inner = inner
        self.chunk_bound = float(chunk_bound)
        self._faults = sorted(faults, key=lambda f: f.at_progress)
        self._fi = 0                      # next fault to fire
        self._progress = 0.0              # successful task-local work time
        self.faults_injected = 0

    def start(self, now: float) -> None:
        self.inner.start(now)

    def step_chunk(self) -> DriverChunk:
        chunk = self.inner.step_chunk()
        dt = chunk.dt
        extra = 0.0
        events = list(chunk.events)
        # every fault landing inside (progress, progress + dt] loses this
        # chunk once: bill the lost attempt + backoff, then the retry
        # (the inner chunk we already hold) succeeds
        while (self._fi < len(self._faults)
               and self._faults[self._fi].at_progress
               <= self._progress + dt + _EPS):
            f = self._faults[self._fi]
            self._fi += 1
            self.faults_injected += 1
            extra += dt + f.backoff
            events.insert(0, ProgressEvent(
                kind=EventKind.REPLICA_FAILED, task=self.name,
                reason="injected",
                detail=f"at={f.at_progress:.3f} backoff={f.backoff:.3f}"))
        self._progress += dt
        return DriverChunk(dt=dt + extra, events=tuple(events),
                           done=chunk.done)

    def _pending_reserve(self) -> float:
        return sum(self.chunk_bound + f.backoff
                   for f in self._faults[self._fi:])

    def residual_estimate(self) -> float:
        # sound upper bound: the inner residual plus a full reserve for
        # each pending fault. When a fault fires it costs dt + backoff
        # <= chunk_bound + backoff, so the estimate never under-counts
        # and shrinks at least as fast as work completes.
        inner = self.inner.residual_estimate()
        if inner == float("inf"):
            return inner
        return inner + self._pending_reserve()

    def slots_bound(self):
        return self.inner.slots_bound()

    def result(self):
        return self.inner.result()


def chaos_spec(spec: TaskSpec, faults: Sequence[Fault],
               chunk_bound: float) -> TaskSpec:
    """Planner-visible duration for a fault-wrapped task: the base
    duration plus the same per-fault reserve ``residual_estimate``
    charges — keeping spec durations upper bounds under injection."""
    reserve = sum(float(chunk_bound) + f.backoff for f in faults)
    return dataclasses.replace(spec, duration=spec.duration + reserve)
