"""Task profiling: duration estimates + analytic memory accounting.

Paper §7.2: before scheduling, a short profiling run measures throughput
(samples/s); duration = total_samples / throughput. GPU requirement comes
from the base-model size. Results are cached per (arch, b, seq).

On this CPU container, two estimators coexist:
  * ``measure_throughput``: real wall-clock over a few steps of the actual
    jitted train step (used by the engine for the small reference model);
  * ``analytic_step_time``: roofline-based estimate from FLOPs and the
    target-hardware constants (used for production-scale what-if schedules
    and the scheduler benchmarks).

Layer contract: estimates produced here are UPPER BOUNDS that only shrink
as observation replaces analysis (the ProfileStore feedback loop) — the
elastic runtime's adoption rule and the fusion anomaly guard both assume
residual durations never grow, and a replica's projected end must be
recomputed from live residuals whenever a guest departs (eviction,
migration, cancel), never reused from admission time.
"""
from __future__ import annotations

import dataclasses
import json
import logging
import os
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ModelConfig

# TPU v5e target constants (per chip)
PEAK_FLOPS_BF16 = 197e12
HBM_BYTES_PER_S = 819e9
HBM_BYTES = 16 * 1024 ** 3
ICI_BYTES_PER_S = 50e9


def train_step_flops(cfg: ModelConfig, total_batch: int, seq_len: int,
                     lora_rank: int = 0) -> float:
    """~6 * N_active * tokens for fwd+bwd... except base is FROZEN: base
    weights take fwd (2ND) + activation-grad bwd (2ND) but no weight-grad
    pass => 4ND; LoRA params take the full 6ND' (tiny)."""
    tokens = total_batch * seq_len
    n_base = cfg.param_count(active_only=True)
    n_lora = cfg.lora_param_count(lora_rank) if lora_rank else 0
    return (4.0 * n_base + 6.0 * n_lora) * tokens


def analytic_step_time(cfg: ModelConfig, total_batch: int, seq_len: int,
                       chips: int, mfu: float = 0.4,
                       lora_rank: int = 16) -> float:
    """Roofline-style estimate of one train step (seconds)."""
    f = train_step_flops(cfg, total_batch, seq_len, lora_rank)
    compute = f / (chips * PEAK_FLOPS_BF16 * mfu)
    # memory floor: every base weight read at least twice (fwd+bwd)
    bytes_moved = 2 * 2 * cfg.param_count(active_only=True)
    memory = bytes_moved / (chips * HBM_BYTES_PER_S)
    return max(compute, memory)


def fused_step_flops(cfg: ModelConfig, slot_tokens: "Sequence[int]",
                     ranks: "Sequence[int]") -> float:
    """Rank-local fused-step FLOPs for one shared-backbone replica:
    frozen base at 4ND over the total real tokens, plus each slot's LoRA
    GEMMs at its TRUE rank (6 * N_lora(r_z) * tokens_z). Rank-MASKED
    execution charges every slot r_max here — the gap between the two is
    exactly the MXU work the dead rank-tile skip reclaims."""
    total = sum(slot_tokens)
    f = 4.0 * cfg.param_count(active_only=True) * total
    for t, r in zip(slot_tokens, ranks):
        f += 6.0 * cfg.lora_param_count(int(r)) * t
    return f


def fused_step_time(cfg: ModelConfig, slot_tokens: "Sequence[int]",
                    ranks: "Sequence[int]", chips: int,
                    mfu: float = 0.4) -> float:
    """Roofline-style fused-step seconds under rank-local compute (the
    §A.3 rank-aware duration estimate). Pass ``ranks = [r_max] * Z`` for
    the rank-masked baseline."""
    f = fused_step_flops(cfg, slot_tokens, ranks)
    compute = f / (chips * PEAK_FLOPS_BF16 * mfu)
    bytes_moved = 2 * 2 * cfg.param_count(active_only=True)
    memory = bytes_moved / (chips * HBM_BYTES_PER_S)
    return max(compute, memory)


def analytic_peak_memory(cfg: ModelConfig, Z: int, b: int, seq_len: int,
                         chips: int = 1, rank: int = 16) -> float:
    """Bytes per chip: params + adapters/opt + remat activations.

    Linear in total batch B=Z*b (the structure the paper's M_hat fits).
    """
    base = 2 * cfg.param_count() / chips                   # bf16, sharded
    # fp32 master + two fp32 moments per adapter param, Z adapters
    adapters = (4 + 8) * cfg.lora_param_count(rank) * Z / chips
    # remat: residual checkpoints per layer + one layer's working set
    tokens = Z * b * seq_len / chips
    act = 2 * tokens * cfg.d_model * (cfg.num_layers + 6)
    return base + adapters + act


@dataclasses.dataclass
class TaskProfile:
    samples_per_s: float
    step_time_s: float
    peak_memory: float


_CACHE: Dict[Tuple, TaskProfile] = {}


def measure_throughput(step_fn: Callable, args: tuple, total_batch: int,
                       warmup: int = 1, iters: int = 3,
                       repeats: int = 3) -> TaskProfile:
    """Wall-clock a jitted step function (real, CPU-scale models).

    ``warmup`` iterations run first (compile + caches land outside the
    timed region) and the timed loop runs ``repeats`` times, reporting the
    MEDIAN per-step time — a single timing is at the mercy of a GC pause
    or a noisy neighbor, and the autotuner picks tile-plan winners off
    these numbers, so one outlier must not crown a candidate."""
    import jax
    out = None
    for _ in range(max(warmup, 1)):
        out = step_fn(*args)
    jax.block_until_ready(out)
    samples = []
    for _ in range(max(repeats, 1)):
        t0 = time.time()
        for _ in range(iters):
            out = step_fn(*args)
        jax.block_until_ready(out)
        samples.append((time.time() - t0) / iters)
    samples.sort()
    dt = samples[len(samples) // 2] if len(samples) % 2 else (
        samples[len(samples) // 2 - 1] + samples[len(samples) // 2]) / 2
    dt = max(dt, 1e-12)
    return TaskProfile(samples_per_s=total_batch / dt, step_time_s=dt,
                       peak_memory=0.0)


def profile_task(cfg: ModelConfig, Z: int, b: int, seq_len: int,
                 chips: int, *, mfu: float = 0.4, rank: int = 16
                 ) -> TaskProfile:
    """Cached analytic profile for scheduler duration estimates."""
    key = (cfg.name, Z, b, seq_len, chips, mfu, rank)
    if key not in _CACHE:
        st = analytic_step_time(cfg, Z * b, seq_len, chips,
                                mfu=mfu, lora_rank=rank)
        _CACHE[key] = TaskProfile(
            samples_per_s=Z * b / st, step_time_s=st,
            peak_memory=analytic_peak_memory(cfg, Z, b, seq_len, chips,
                                             rank))
    return _CACHE[key]


# --------------------------------------------------------------------------
# Lifecycle duration (re-)estimation (elastic runtime, paper §7.2)
# --------------------------------------------------------------------------

def lifecycle_steps(K: int, Z: int, warmup_steps: int, total_steps: int,
                    survivors: Optional[int] = None) -> int:
    """Worst-case executor steps for the ALTO per-task lifecycle:
    ceil(K/Z) warmup waves, then the survivors packed onto Z slots for the
    remaining budget. ``survivors=None`` means the warmup boundary has not
    been reached yet and no pattern exits are assumed (the scheduler's
    worst case) — but Pattern-3 selection is deterministic, so even the
    worst case retains only ``survivors`` jobs once that count is known."""
    if K <= 0:
        return 0
    Z = max(Z, 1)
    warmup_steps = max(min(warmup_steps, total_steps), 0)
    s = K if survivors is None else max(min(survivors, K), 0)
    waves = -(-K // Z)                      # ceil
    cont_waves = -(-s // Z) if s else 0
    return waves * warmup_steps + cont_waves * (total_steps - warmup_steps)


def residual_duration(steps_remaining: float, step_time_s: float) -> float:
    """Seconds of residual work from an executor-step bound."""
    return max(float(steps_remaining), 0.0) * step_time_s


def reestimate_duration(step_time_s: float, K: int, Z: int,
                        warmup_steps: int, total_steps: int,
                        survivors: int) -> float:
    """Duration re-estimate after the warmup boundary reported ``survivors``
    jobs continuing (warmup-selection drops and divergence exits both lower
    it). The elastic runtime feeds this into residual re-solves so freed
    capacity is reclaimed immediately instead of at the static plan's
    worst-case boundaries."""
    steps = lifecycle_steps(K, Z, warmup_steps, total_steps,
                            survivors=survivors)
    return residual_duration(steps, step_time_s)


# --------------------------------------------------------------------------
# Profiler feedback loop (service sessions, paper §7.2 / ROADMAP item)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class ProfileRecord:
    """Observed execution statistics for one profile key (EMA-smoothed).

    ``wall_token_time_s`` is the per-TOKEN wall time: with ragged slot
    widths two fused steps can differ several-fold in token throughput,
    so per-step wall time alone mis-calibrates duration estimates on
    heterogeneous mixes — tokens are the width-invariant denominator."""
    duration_frac: float      # realized_duration / estimated_duration
    wall_step_time_s: Optional[float] = None  # realized host per-step seconds
    wall_token_time_s: Optional[float] = None  # realized host per-token secs
    observations: int = 0


@dataclasses.dataclass(frozen=True)
class StepObservation:
    """One observed fused step: its real token load, rank-weighted token
    load, wall seconds, and (when the platform reports it) peak memory.
    The raw points — not an EMA — because the fitted cost model
    (``sched/fitted.py``) least-squares (k0, k1, k2) over them, and a
    smoothed scalar cannot recover per-coefficient structure."""
    tokens: float
    rank_tokens: float
    wall_s: float
    peak_memory: Optional[float] = None


MAX_STEP_OBSERVATIONS = 512      # per key; oldest evicted first


class ProfileStore:
    """Session-scoped feedback store closing the profiler loop.

    Four layers:

      * **Observed records** keyed by an arch-level profile key (e.g.
        ``(cfg.name, gpus)``): every completed task reports its realized
        step time and realized/estimated duration ratio. Later admissions
        in the same session consult ``step_time``/``duration_scale`` so
        they are scheduled from observed rather than analytic estimates
        (early exits make worst-case analytic durations systematically
        pessimistic — paper Fig. 9 reports 72-83% sample savings).
      * **Step observations** (``record_step``): raw per-step (tokens,
        rank_tokens, wall_s, peak_memory) points per key, the training
        set for the fitted (k0, k1, k2) step-time / memory models in
        ``sched/fitted.py``. Persisted.
      * **Spec cache** keyed by ``(task_name, early-exit signature)``:
        ``Engine.schedule`` and ``Engine.batched_execution`` profile the
        same tasks back to back; the cache de-duplicates that work. Cache
        entries are versioned — any new observation invalidates previously
        computed specs so feedback takes effect immediately.
      * **Durable specs** (``put_spec(..., durable=True)``): entries that
        are NOT derived from observations — tile-plan autotune winners —
        so they survive version bumps and are JSON-persisted by ``save``
        (later sessions skip the sweep entirely). Durable specs must be
        JSON-representable.
    """

    def __init__(self, ema: float = 0.5):
        assert 0.0 < ema <= 1.0
        self.ema = ema
        self._records: Dict[Tuple, ProfileRecord] = {}
        self._specs: Dict[Tuple, Tuple[int, object]] = {}
        self._durable_specs: Dict[Tuple, object] = {}
        self._steps: Dict[Tuple, List[StepObservation]] = {}
        self._version = 0

    # ---- observed records --------------------------------------------------
    def record(self, key: Tuple, *, realized_duration: float,
               estimated_duration: float,
               wall_step_time_s: Optional[float] = None,
               wall_token_time_s: Optional[float] = None) -> None:
        """Log one completed task. ``realized/estimated`` must both be in
        the session's *virtual* timeline and the estimate must be the
        UNSCALED worst case (recording vs an already-scaled estimate would
        compound the ratio). Wall step/token times are the only host-clock
        quantities; virtual step times are never recorded — for real
        executors the realized virtual step time IS the analytic one, so
        an observation would be circular. Per-token wall time is the
        calibrated quantity for ragged (mixed-width) fused steps."""
        frac = (realized_duration / estimated_duration
                if estimated_duration > 0 else 1.0)
        frac = min(max(frac, 0.0), 1.0)     # estimates are upper bounds

        def ema(new, old):
            if new is None:
                return old
            if old is None:
                return new
            return self.ema * new + (1 - self.ema) * old

        prev = self._records.get(key)
        if prev is None:
            self._records[key] = ProfileRecord(
                duration_frac=frac, wall_step_time_s=wall_step_time_s,
                wall_token_time_s=wall_token_time_s,
                observations=1)
        else:
            self._records[key] = ProfileRecord(
                duration_frac=ema(frac, prev.duration_frac),
                wall_step_time_s=ema(wall_step_time_s,
                                     prev.wall_step_time_s),
                wall_token_time_s=ema(wall_token_time_s,
                                      prev.wall_token_time_s),
                observations=prev.observations + 1)
        self._version += 1                  # invalidates all cached specs

    def wall_step_time(self, key: Tuple) -> Optional[float]:
        """Realized host seconds per executor step (observability; kept
        out of the virtual timeline on purpose)."""
        rec = self._records.get(key)
        return rec.wall_step_time_s if rec is not None else None

    def wall_token_time(self, key: Tuple) -> Optional[float]:
        """Realized host seconds per REAL token trained (padding
        excluded) — width-invariant, so it stays calibrated when fused
        steps mix heterogeneous per-adapter batch sizes."""
        rec = self._records.get(key)
        return rec.wall_token_time_s if rec is not None else None

    def duration_scale(self, key: Tuple) -> float:
        """Multiplier for analytic worst-case durations (1.0 = no data)."""
        rec = self._records.get(key)
        return rec.duration_frac if rec is not None else 1.0

    def scaled_duration(self, key: Tuple, duration: float) -> float:
        """Apply the observed realized/worst-case ratio to an UNSCALED
        worst-case duration (single scaling point for engine + service)."""
        scale = self.duration_scale(key)
        if scale >= 1.0:
            return duration
        return max(duration * scale, 1e-9)

    def observations(self, key: Tuple) -> int:
        rec = self._records.get(key)
        return rec.observations if rec is not None else 0

    # ---- raw step observations (fitted cost model's training set) ----------
    def record_step(self, key: Tuple, *, tokens: float, rank_tokens: float,
                    wall_s: float, peak_memory: Optional[float] = None
                    ) -> None:
        """Log one observed fused step. Unlike ``record``, points are kept
        raw (bounded FIFO per key) — ``sched/fitted.py`` least-squares the
        (k0, k1, k2) step-time and memory models over them, which needs
        the per-point (tokens, rank_tokens) structure an EMA destroys."""
        obs = self._steps.setdefault(key, [])
        obs.append(StepObservation(tokens=float(tokens),
                                   rank_tokens=float(rank_tokens),
                                   wall_s=float(wall_s),
                                   peak_memory=(None if peak_memory is None
                                                else float(peak_memory))))
        if len(obs) > MAX_STEP_OBSERVATIONS:
            del obs[:len(obs) - MAX_STEP_OBSERVATIONS]
        self._version += 1              # fitted specs must re-derive

    def step_observations(self, key: Tuple) -> List[StepObservation]:
        return list(self._steps.get(key, ()))

    def step_observation_count(self, key: Tuple) -> int:
        return len(self._steps.get(key, ()))

    # ---- spec cache --------------------------------------------------------
    def get_spec(self, key: Tuple):
        if key in self._durable_specs:
            return self._durable_specs[key]
        hit = self._specs.get(key)
        if hit is None or hit[0] != self._version:
            return None
        return hit[1]

    def put_spec(self, key: Tuple, spec, durable: bool = False) -> None:
        """Cache a derived spec. ``durable=True`` marks the entry as NOT
        observation-derived (tile-plan autotune winners): it survives
        version bumps and is JSON-persisted by ``save`` — such specs must
        be JSON-representable values."""
        if durable:
            json.dumps(spec)            # fail fast, not at save() time
            self._durable_specs[key] = spec
        else:
            self._specs[key] = (self._version, spec)

    # ---- persistence (service sessions survive process restarts) -----------
    def save(self, path: str) -> None:
        """JSON-persist the observed records, raw step observations, and
        durable specs (the versioned spec cache is derived state tied to
        in-process objects and is not saved). Keys must be
        JSON-representable tuples — which the engine's (arch, gpus) keys
        and the autotuner's plan keys are.

        The write is ATOMIC: the document lands in a same-directory tmp
        file first and is ``os.replace``d into place, so a crash mid-save
        leaves the previous profile intact instead of a truncated JSON the
        next session cannot load."""
        data = {
            "version": 2,
            "ema": self.ema,
            "records": [
                {"key": list(k),
                 "duration_frac": r.duration_frac,
                 "wall_step_time_s": r.wall_step_time_s,
                 "wall_token_time_s": r.wall_token_time_s,
                 "observations": r.observations}
                for k, r in sorted(self._records.items(),
                                   key=lambda kv: repr(kv[0]))],
            "steps": [
                {"key": list(k),
                 "observations": [
                     {"tokens": o.tokens, "rank_tokens": o.rank_tokens,
                      "wall_s": o.wall_s, "peak_memory": o.peak_memory}
                     for o in obs]}
                for k, obs in sorted(self._steps.items(),
                                     key=lambda kv: repr(kv[0]))],
            "durable_specs": [
                {"key": list(k), "spec": spec}
                for k, spec in sorted(self._durable_specs.items(),
                                      key=lambda kv: repr(kv[0]))],
        }
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(data, f, indent=2)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str) -> "ProfileStore":
        """Load a persisted store. A corrupt/truncated file (crash
        mid-write predating the atomic ``save``, disk damage) degrades to
        a FRESH store with a warning — analytic profiles take over —
        rather than refusing to start."""
        try:
            with open(path) as f:
                data = json.load(f)
            store = cls(ema=float(data.get("ema", 0.5)))
            for rec in data.get("records", []):
                store._records[tuple(rec["key"])] = ProfileRecord(
                    duration_frac=float(rec["duration_frac"]),
                    wall_step_time_s=(
                        None if rec.get("wall_step_time_s") is None
                        else float(rec["wall_step_time_s"])),
                    wall_token_time_s=(
                        None if rec.get("wall_token_time_s") is None
                        else float(rec["wall_token_time_s"])),
                    observations=int(rec.get("observations", 1)))
            for entry in data.get("steps", []):
                store._steps[tuple(entry["key"])] = [
                    StepObservation(
                        tokens=float(o["tokens"]),
                        rank_tokens=float(o["rank_tokens"]),
                        wall_s=float(o["wall_s"]),
                        peak_memory=(None if o.get("peak_memory") is None
                                     else float(o["peak_memory"])))
                    for o in entry["observations"]]
            for entry in data.get("durable_specs", []):
                store._durable_specs[tuple(entry["key"])] = entry["spec"]
            return store
        except (OSError, ValueError, KeyError, TypeError) as e:
            logging.getLogger(__name__).warning(
                "profile store %s unreadable (%s): starting fresh", path, e)
            return cls()

    @classmethod
    def load_or_new(cls, path: str) -> "ProfileStore":
        """Load a persisted store, or start fresh if the file is absent."""
        if os.path.exists(path):
            return cls.load(path)
        return cls()


def gpus_for_model(cfg: ModelConfig, hbm_bytes: float = HBM_BYTES,
                   overhead: float = 1.35) -> int:
    """GPU/chip requirement from base-model size (paper §7.2)."""
    need = 2 * cfg.param_count() * overhead
    g = 1
    while g * hbm_bytes * 0.9 < need:
        g *= 2
    return g
