"""Online greedy intra-task scheduler (paper §7.1, §A.3).

Decides how many adapters to co-locate on an executor and when to
admit/evict, under a fitted linear memory model

    M_hat(B) = k0 + k1 * B * L        (B = total batch, L = seq len)

Profiling (paper §A.3 two-phase): (1) binary-search the largest
single-adapter batch B_max that fits; (2) sweep (N, b) grid points with
N*b <= B_max, measure peak memory, fit the regression. On real hardware the
measurement is ``compiled.memory_analysis()``; on this CPU container the
profiler plugs in the analytic accounting from sched/profiler.py (same
linear structure).

Admission policy: admit pending jobs greedily in decreasing batch-size
order while M_hat stays within the safety margin. Slots are RAGGED
(variable-width: the fused step packs per-slot row counts through the
ragged grouped-GEMM path), so mixed batch sizes co-train freely — the
budget is the token-linear memory model, never same-width slot counting.
Cross-task admission (``admit_cross_task``) budgets the same way over
TOKENS (slots * b * seq), letting tasks with different batch sizes and
seq lens share one frozen-backbone replica.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class MemoryModel:
    k0: float                 # bytes at B=0 (params, cache, fixed overhead)
    k1: float                 # bytes per (token of total batch)
    seq_len: int
    capacity: float           # device HBM bytes
    safety_margin: float = 0.9

    def predict(self, total_batch: int) -> float:
        return self.k0 + self.k1 * total_batch * self.seq_len

    def fits(self, total_batch: int) -> bool:
        return self.predict(total_batch) <= self.capacity * self.safety_margin

    def max_batch(self) -> int:
        if self.k1 <= 0:
            return 1 << 20
        return max(int((self.capacity * self.safety_margin - self.k0)
                       / (self.k1 * self.seq_len)), 0)

    # ---- token-denominated interface (ragged slot widths) ------------------
    # M_hat is linear in TOKENS (B * L); when co-located slots disagree on
    # (b, seq), tokens = sum of b_z * seq_z is the sound budget unit — the
    # rows-based interface above assumes the fit-time seq_len throughout.
    def predict_tokens(self, tokens: float) -> float:
        return self.k0 + self.k1 * tokens

    def fits_tokens(self, tokens: float) -> bool:
        return self.predict_tokens(tokens) <= (self.capacity
                                               * self.safety_margin)


def fit_memory_model(points: Sequence[Tuple[int, float]], seq_len: int,
                     capacity: float, safety_margin: float = 0.9
                     ) -> MemoryModel:
    """OLS fit of peak-memory measurements: points = [(total_batch, bytes)]."""
    B = np.asarray([p[0] * seq_len for p in points], np.float64)
    M = np.asarray([p[1] for p in points], np.float64)
    A = np.stack([np.ones_like(B), B], axis=1)
    coef, *_ = np.linalg.lstsq(A, M, rcond=None)
    return MemoryModel(k0=float(coef[0]), k1=float(coef[1]),
                       seq_len=seq_len, capacity=capacity,
                       safety_margin=safety_margin)


@dataclasses.dataclass
class PendingJob:
    job_id: str
    per_adapter_batch: int


class IntraTaskScheduler:
    """Greedy admission/backfill over one executor's slots."""

    def __init__(self, mem: MemoryModel, max_slots: int):
        self.mem = mem
        self.max_slots = max_slots
        self.resident: Dict[str, int] = {}     # job_id -> b

    @property
    def total_batch(self) -> int:
        return sum(self.resident.values())

    def can_admit(self, b: int) -> bool:
        return (len(self.resident) < self.max_slots
                and self.mem.fits(self.total_batch + b))

    def admit_initial(self, queue: List[PendingJob]) -> List[PendingJob]:
        """Greedy decreasing-batch-size admission (paper §A.3). Returns the
        admitted jobs, removing them from ``queue`` in place."""
        admitted: List[PendingJob] = []
        for job in sorted(queue, key=lambda j: -j.per_adapter_batch):
            if self.can_admit(job.per_adapter_batch):
                self.resident[job.job_id] = job.per_adapter_batch
                admitted.append(job)
        for j in admitted:
            queue.remove(j)
        return admitted

    def evict(self, job_id: str) -> None:
        del self.resident[job_id]

    def backfill(self, queue: List[PendingJob]) -> Optional[PendingJob]:
        """Admit the largest pending job the memory-model budget accepts.

        The historical same-batch-size fast path is gone: slots are ragged
        (the fused step packs per-slot row counts through the ragged
        grouped-GEMM path), so homogeneous packing buys nothing — the only
        constraint is the token-linear §A.3 budget."""
        for j in sorted(queue, key=lambda j: -j.per_adapter_batch):
            if self.can_admit(j.per_adapter_batch):
                queue.remove(j)
                self.resident[j.job_id] = j.per_adapter_batch
                return j
        return None


# The executor's per-slot admission/backfill policy is the same object —
# exported under the name the executor layer uses (§A.3 "executor slots").
ExecutorSlots = IntraTaskScheduler


# --------------------------------------------------------------------------
# Cross-task admission (shared-backbone co-location)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ColoRequest:
    """One task's demand on a shared replica: its concurrent-slot upper
    bound, per-adapter batch size, and seq len. ``seq_len=None`` falls
    back to the memory model's fit-time seq len (homogeneous-seq legacy
    callers); M_hat budgets slots * b * seq TOKENS either way."""
    name: str
    slots: int
    per_adapter_batch: int
    seq_len: Optional[int] = None

    def tokens(self, default_seq: int = 1) -> int:
        seq = self.seq_len if self.seq_len else default_seq
        return self.slots * self.per_adapter_batch * seq


def admit_cross_task(resident: Sequence[ColoRequest],
                     pending: Sequence[ColoRequest],
                     capacity_slots: int,
                     mem: Optional[MemoryModel] = None) -> List[str]:
    """§A.3 admission generalized across TASK boundaries: greedily admit
    pending tasks in decreasing per-slot TOKEN width (b * seq; ties broken
    by name for determinism) while the replica's slot capacity holds and
    the fitted memory model M_hat(total tokens) stays inside the safety
    margin. Tasks need NOT share a batch size or seq len — ragged slots
    fuse heterogeneous widths in one step, so the only compatibility the
    key retains is (arch, gpus, loss kind).

    ``resident`` are tasks already co-located on the replica (the host
    included); their ``slots`` should be *current future-use bounds*, so
    capacity freed by early exits is reclaimable the moment it frees.
    Returns the admitted task names, in admission order."""
    default_seq = mem.seq_len if mem is not None else 1
    used_slots = sum(r.slots for r in resident)
    used_tokens = sum(r.tokens(default_seq) for r in resident)
    admitted: List[str] = []

    def width(r: ColoRequest) -> int:
        return r.per_adapter_batch * (r.seq_len if r.seq_len else
                                      default_seq)

    for r in sorted(pending, key=lambda r: (-width(r), r.name)):
        if used_slots + r.slots > capacity_slots:
            continue
        tokens = used_tokens + r.tokens(default_seq)
        if mem is not None and not mem.fits_tokens(tokens):
            continue
        admitted.append(r.name)
        used_slots += r.slots
        used_tokens = tokens
    return admitted
