"""Online greedy intra-task scheduler (paper §7.1, §A.3).

Decides how many adapters to co-locate on an executor and when to
admit/evict, under a fitted linear memory model

    M_hat(B) = k0 + k1 * B * L        (B = total batch, L = seq len)

Profiling (paper §A.3 two-phase): (1) binary-search the largest
single-adapter batch B_max that fits; (2) sweep (N, b) grid points with
N*b <= B_max, measure peak memory, fit the regression. On real hardware the
measurement is ``compiled.memory_analysis()``; on this CPU container the
profiler plugs in the analytic accounting from sched/profiler.py (same
linear structure).

Admission policy: admit pending jobs greedily in decreasing batch-size
order while M_hat stays within the safety margin. Slots are RAGGED
(variable-width: the fused step packs per-slot row counts through the
ragged grouped-GEMM path), so mixed batch sizes co-train freely — the
budget is the token-linear memory model, never same-width slot counting.
Cross-task admission (``admit_cross_task``) budgets the same way over
TOKENS (slots * b * seq), letting tasks with different batch sizes and
seq lens share one frozen-backbone replica.

Rank-aware extension (rank-local grouped GEMM): adapters are also RANK-
heterogeneous, and with the rank-local kernels a slot's compute/memory
footprint scales with its TRUE rank, not the padded r_max. A fitted
``k2`` term budgets rank-weighted FLOP-tokens (b * seq * rank per slot);
requests that don't know their rank are charged r_max — the historical
Z*r_max padded accounting, now the pessimistic fallback rather than the
only option.

Layer contract: this module is the single source of truth for "does this
adapter set fit this replica" — ``MemoryModel.fits_ranked`` (k0 + k1*tokens
+ k2*rank_tokens <= capacity*safety_margin) is the invariant every
admission path checks: intra-task backfill (``ExecutorSlots``), cross-task
fusion (``admit_cross_task``), and — linearized into
``ReplicaState.mem_budget`` — the fusion-aware inter-task planner
(``plan_fused`` in inter_task.py). The three layers budgeting the same
quantity is what makes a plan-level fusion decision realizable at
admission time.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class MemoryModel:
    k0: float                 # bytes at B=0 (params, cache, fixed overhead)
    k1: float                 # bytes per (token of total batch)
    seq_len: int
    capacity: float           # device HBM bytes
    safety_margin: float = 0.9
    # rank-aware extension: the LoRA working set (S/dS activations, adapter
    # + optimizer state) scales with tokens x TRUE rank, not tokens x r_max.
    # k2 = bytes per rank-weighted FLOP-token (b*seq*rank per slot);
    # r_max = the rank a request WITHOUT true-rank information is charged
    # (the historical padded accounting — every slot billed as if r_max).
    k2: float = 0.0
    r_max: int = 0

    def __post_init__(self):
        # a rank-aware model must know what to bill rank-unknown requests:
        # without r_max they would be charged rank 1 (64x UNDER-billed for
        # a padded r_max=64 request) instead of the pessimistic fallback
        assert self.k2 <= 0 or self.r_max > 0, \
            "rank-aware MemoryModel (k2 > 0) requires r_max"

    def predict(self, total_batch: int) -> float:
        return self.k0 + self.k1 * total_batch * self.seq_len

    def fits(self, total_batch: int) -> bool:
        return self.predict(total_batch) <= self.capacity * self.safety_margin

    def max_batch(self) -> int:
        if self.k1 <= 0:
            return 1 << 20
        return max(int((self.capacity * self.safety_margin - self.k0)
                       / (self.k1 * self.seq_len)), 0)

    # ---- token-denominated interface (ragged slot widths) ------------------
    # M_hat is linear in TOKENS (B * L); when co-located slots disagree on
    # (b, seq), tokens = sum of b_z * seq_z is the sound budget unit — the
    # rows-based interface above assumes the fit-time seq_len throughout.
    def predict_tokens(self, tokens: float) -> float:
        return self.k0 + self.k1 * tokens

    def fits_tokens(self, tokens: float) -> bool:
        return self.predict_tokens(tokens) <= (self.capacity
                                               * self.safety_margin)

    # ---- rank-weighted interface (rank-local compute) ----------------------
    # With the rank-local grouped-GEMM path a slot's LoRA footprint is
    # proportional to b*seq*rank at its TRUE rank; ``rank_tokens`` is the
    # sum of that quantity over slots. k2 == 0 recovers the rank-neutral
    # token model exactly (every existing caller is unchanged).
    def predict_ranked(self, tokens: float, rank_tokens: float) -> float:
        return self.k0 + self.k1 * tokens + self.k2 * rank_tokens

    def fits_ranked(self, tokens: float, rank_tokens: float) -> bool:
        return self.predict_ranked(tokens, rank_tokens) <= (
            self.capacity * self.safety_margin)

    def charged_rank(self, lora_rank: Optional[int]) -> int:
        """The rank a request is billed at: its true rank when known,
        else the padded r_max (rank-masked accounting)."""
        if lora_rank:
            return lora_rank
        return self.r_max if self.r_max else 1


def fit_memory_model(points: Sequence[Tuple[int, float]], seq_len: int,
                     capacity: float, safety_margin: float = 0.9
                     ) -> MemoryModel:
    """OLS fit of peak-memory measurements: points = [(total_batch, bytes)]."""
    B = np.asarray([p[0] * seq_len for p in points], np.float64)
    M = np.asarray([p[1] for p in points], np.float64)
    A = np.stack([np.ones_like(B), B], axis=1)
    coef, *_ = np.linalg.lstsq(A, M, rcond=None)
    return MemoryModel(k0=float(coef[0]), k1=float(coef[1]),
                       seq_len=seq_len, capacity=capacity,
                       safety_margin=safety_margin)


@dataclasses.dataclass
class PendingJob:
    job_id: str
    per_adapter_batch: int
    lora_rank: int = 0        # TRUE rank; 0 = unknown (charged at r_max)


class IntraTaskScheduler:
    """Greedy admission/backfill over one executor's slots."""

    def __init__(self, mem: MemoryModel, max_slots: int):
        self.mem = mem
        self.max_slots = max_slots
        self.resident: Dict[str, int] = {}        # job_id -> b
        self.resident_ranks: Dict[str, int] = {}  # job_id -> true rank

    @property
    def total_batch(self) -> int:
        return sum(self.resident.values())

    def _rank_tokens(self) -> float:
        """Resident rank-weighted FLOP-tokens (b * seq * charged rank)."""
        return sum(b * self.mem.seq_len
                   * self.mem.charged_rank(self.resident_ranks.get(j))
                   for j, b in self.resident.items())

    def can_admit(self, b: int, rank: int = 0) -> bool:
        if len(self.resident) >= self.max_slots:
            return False
        if self.mem.k2 <= 0:
            return self.mem.fits(self.total_batch + b)
        rt = self._rank_tokens() + (b * self.mem.seq_len
                                    * self.mem.charged_rank(rank))
        return self.mem.fits_ranked((self.total_batch + b) * self.mem.seq_len,
                                    rt)

    def _admit(self, job: PendingJob) -> None:
        self.resident[job.job_id] = job.per_adapter_batch
        if job.lora_rank:
            self.resident_ranks[job.job_id] = job.lora_rank

    def admit_initial(self, queue: List[PendingJob]) -> List[PendingJob]:
        """Greedy decreasing-batch-size admission (paper §A.3). Returns the
        admitted jobs, removing them from ``queue`` in place."""
        admitted: List[PendingJob] = []
        for job in sorted(queue, key=lambda j: -j.per_adapter_batch):
            if self.can_admit(job.per_adapter_batch, job.lora_rank):
                self._admit(job)
                admitted.append(job)
        for j in admitted:
            queue.remove(j)
        return admitted

    def evict(self, job_id: str) -> None:
        del self.resident[job_id]
        self.resident_ranks.pop(job_id, None)

    def backfill(self, queue: List[PendingJob]) -> Optional[PendingJob]:
        """Admit the largest pending job the memory-model budget accepts.

        The historical same-batch-size fast path is gone: slots are ragged
        (the fused step packs per-slot row counts through the ragged
        grouped-GEMM path), so homogeneous packing buys nothing — the only
        constraint is the (rank-aware) §A.3 budget, which charges each
        job's TRUE rank when it is known instead of the padded r_max."""
        for j in sorted(queue, key=lambda j: -j.per_adapter_batch):
            if self.can_admit(j.per_adapter_batch, j.lora_rank):
                queue.remove(j)
                self._admit(j)
                return j
        return None


# The executor's per-slot admission/backfill policy is the same object —
# exported under the name the executor layer uses (§A.3 "executor slots").
ExecutorSlots = IntraTaskScheduler


# --------------------------------------------------------------------------
# Cross-task admission (shared-backbone co-location)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ColoRequest:
    """One task's demand on a shared replica: its concurrent-slot upper
    bound, per-adapter batch size, seq len, and TRUE adapter rank.
    ``seq_len=None`` falls back to the memory model's fit-time seq len
    (homogeneous-seq legacy callers); ``lora_rank=None`` means the rank is
    unknown and the task is charged at the model's padded r_max — the
    rank-masked accounting the rank-local path replaces. M_hat budgets
    slots * b * seq TOKENS plus k2 * rank-weighted FLOP-tokens."""
    name: str
    slots: int
    per_adapter_batch: int
    seq_len: Optional[int] = None
    lora_rank: Optional[int] = None

    def tokens(self, default_seq: int = 1) -> int:
        seq = self.seq_len if self.seq_len else default_seq
        return self.slots * self.per_adapter_batch * seq

    def rank_tokens(self, default_seq: int = 1, default_rank: int = 1) -> int:
        rank = self.lora_rank if self.lora_rank else default_rank
        return self.tokens(default_seq) * rank


def admit_cross_task(resident: Sequence[ColoRequest],
                     pending: Sequence[ColoRequest],
                     capacity_slots: int,
                     mem: Optional[MemoryModel] = None) -> List[str]:
    """§A.3 admission generalized across TASK boundaries: greedily admit
    pending tasks in decreasing per-slot FLOP-token width (b * seq * rank;
    ties broken by name for determinism) while the replica's slot capacity
    holds and the fitted memory model M_hat stays inside the safety
    margin. Tasks need NOT share a batch size, seq len, or rank — ragged
    slots fuse heterogeneous widths and the rank-local kernels fuse
    heterogeneous ranks in one step, so the only compatibility the key
    retains is (arch, gpus, loss kind).

    A rank-aware model (``mem.k2 > 0``) budgets rank-weighted FLOP-tokens
    at each task's TRUE rank; requests without rank information — and
    every request under a rank-neutral model — are charged the padded
    ``r_max``, which is exactly the historical Z*r_max accounting.

    ``resident`` are tasks already co-located on the replica (the host
    included); their ``slots`` should be *current future-use bounds*, so
    capacity freed by early exits is reclaimable the moment it frees.
    Returns the admitted task names, in admission order."""
    default_seq = mem.seq_len if mem is not None else 1
    default_rank = mem.charged_rank(None) if mem is not None else 1
    ranked = mem is not None and mem.k2 > 0
    used_slots = sum(r.slots for r in resident)
    used_tokens = sum(r.tokens(default_seq) for r in resident)
    used_rtok = sum(r.rank_tokens(default_seq, default_rank)
                    for r in resident)
    admitted: List[str] = []

    def width(r: ColoRequest) -> int:
        w = r.per_adapter_batch * (r.seq_len if r.seq_len else default_seq)
        if ranked:
            w *= r.lora_rank if r.lora_rank else default_rank
        return w

    for r in sorted(pending, key=lambda r: (-width(r), r.name)):
        if used_slots + r.slots > capacity_slots:
            continue
        tokens = used_tokens + r.tokens(default_seq)
        rtok = used_rtok + r.rank_tokens(default_seq, default_rank)
        if mem is not None and not mem.fits_ranked(tokens, rtok):
            continue
        admitted.append(r.name)
        used_slots += r.slots
        used_tokens = tokens
        used_rtok = rtok
    return admitted
