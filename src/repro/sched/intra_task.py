"""Online greedy intra-task scheduler (paper §7.1, §A.3).

Decides how many adapters to co-locate on an executor and when to
admit/evict, under a fitted linear memory model

    M_hat(B) = k0 + k1 * B * L        (B = total batch, L = seq len)

Profiling (paper §A.3 two-phase): (1) binary-search the largest
single-adapter batch B_max that fits; (2) sweep (N, b) grid points with
N*b <= B_max, measure peak memory, fit the regression. On real hardware the
measurement is ``compiled.memory_analysis()``; on this CPU container the
profiler plugs in the analytic accounting from sched/profiler.py (same
linear structure).

Admission policy: group pending jobs by per-adapter batch size, admit
greedily in decreasing batch-size order while M_hat stays within the safety
margin; on exit, backfill preferring the SAME batch size (homogeneous
packing — hits the grouped-GEMM fast path and is required under adapter
parallelism), mixed only when the queue runs dry.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class MemoryModel:
    k0: float                 # bytes at B=0 (params, cache, fixed overhead)
    k1: float                 # bytes per (token of total batch)
    seq_len: int
    capacity: float           # device HBM bytes
    safety_margin: float = 0.9

    def predict(self, total_batch: int) -> float:
        return self.k0 + self.k1 * total_batch * self.seq_len

    def fits(self, total_batch: int) -> bool:
        return self.predict(total_batch) <= self.capacity * self.safety_margin

    def max_batch(self) -> int:
        if self.k1 <= 0:
            return 1 << 20
        return max(int((self.capacity * self.safety_margin - self.k0)
                       / (self.k1 * self.seq_len)), 0)


def fit_memory_model(points: Sequence[Tuple[int, float]], seq_len: int,
                     capacity: float, safety_margin: float = 0.9
                     ) -> MemoryModel:
    """OLS fit of peak-memory measurements: points = [(total_batch, bytes)]."""
    B = np.asarray([p[0] * seq_len for p in points], np.float64)
    M = np.asarray([p[1] for p in points], np.float64)
    A = np.stack([np.ones_like(B), B], axis=1)
    coef, *_ = np.linalg.lstsq(A, M, rcond=None)
    return MemoryModel(k0=float(coef[0]), k1=float(coef[1]),
                       seq_len=seq_len, capacity=capacity,
                       safety_margin=safety_margin)


@dataclasses.dataclass
class PendingJob:
    job_id: str
    per_adapter_batch: int


class IntraTaskScheduler:
    """Greedy admission/backfill over one executor's slots."""

    def __init__(self, mem: MemoryModel, max_slots: int):
        self.mem = mem
        self.max_slots = max_slots
        self.resident: Dict[str, int] = {}     # job_id -> b

    @property
    def total_batch(self) -> int:
        return sum(self.resident.values())

    def can_admit(self, b: int) -> bool:
        return (len(self.resident) < self.max_slots
                and self.mem.fits(self.total_batch + b))

    def admit_initial(self, queue: List[PendingJob]) -> List[PendingJob]:
        """Greedy decreasing-batch-size admission (paper §A.3). Returns the
        admitted jobs, removing them from ``queue`` in place."""
        admitted: List[PendingJob] = []
        for job in sorted(queue, key=lambda j: -j.per_adapter_batch):
            if self.can_admit(job.per_adapter_batch):
                self.resident[job.job_id] = job.per_adapter_batch
                admitted.append(job)
        for j in admitted:
            queue.remove(j)
        return admitted

    def evict(self, job_id: str) -> int:
        return self.resident.pop(job_id)

    def backfill(self, vacated_b: int, queue: List[PendingJob]
                 ) -> Optional[PendingJob]:
        """Prefer a pending job with the SAME batch size; accept a different
        size only if the memory model confirms the mixed packing fits."""
        same = [j for j in queue if j.per_adapter_batch == vacated_b]
        for j in same:
            if self.can_admit(j.per_adapter_batch):
                queue.remove(j)
                self.resident[j.job_id] = j.per_adapter_batch
                return j
        for j in sorted(queue, key=lambda j: -j.per_adapter_batch):
            if self.can_admit(j.per_adapter_batch):
                queue.remove(j)
                self.resident[j.job_id] = j.per_adapter_batch
                return j
        return None


# The executor's per-slot admission/backfill policy is the same object —
# exported under the name the executor layer uses (§A.3 "executor slots").
ExecutorSlots = IntraTaskScheduler


# --------------------------------------------------------------------------
# Cross-task admission (shared-backbone co-location)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ColoRequest:
    """One task's demand on a shared replica: its concurrent-slot upper
    bound and per-adapter batch size (M_hat sees slots * b tokens)."""
    name: str
    slots: int
    per_adapter_batch: int


def admit_cross_task(resident: Sequence[ColoRequest],
                     pending: Sequence[ColoRequest],
                     capacity_slots: int,
                     mem: Optional[MemoryModel] = None) -> List[str]:
    """§A.3 admission generalized across TASK boundaries: greedily admit
    pending tasks in decreasing per-adapter-batch order (ties broken by
    name for determinism) while the replica's slot capacity holds and the
    fitted memory model M_hat(total batch) stays inside the safety margin.

    ``resident`` are tasks already co-located on the replica (the host
    included); their ``slots`` should be *current future-use bounds*, so
    capacity freed by early exits is reclaimable the moment it frees.
    Returns the admitted task names, in admission order."""
    used_slots = sum(r.slots for r in resident)
    used_batch = sum(r.slots * r.per_adapter_batch for r in resident)
    admitted: List[str] = []
    for r in sorted(pending, key=lambda r: (-r.per_adapter_batch, r.name)):
        if used_slots + r.slots > capacity_slots:
            continue
        batch = used_batch + r.slots * r.per_adapter_batch
        if mem is not None and not mem.fits(batch):
            continue
        admitted.append(r.name)
        used_slots += r.slots
        used_batch = batch
    return admitted
