"""Write-ahead event journal for durable crash recovery.

The journal is the service's source of truth across process deaths: an
append-only JSONL stream of records — one ``session`` config record, a
``submit`` record per task submission, every runtime ``ProgressEvent``
(the events.py vocabulary, which includes ``REPLAN`` plan adoptions),
a ``ckpt`` record per durable mid-task snapshot, and a ``serve`` record
per tune-to-serve winner artifact. Each append is flushed + fsynced
before returning, so anything the journal acknowledged survives a
``kill -9``.

Segment rotation is atomic: once ``rotate_every`` records accumulate in
``current.jsonl`` the file is sealed via ``os.replace`` into
``segment-%06d.jsonl`` (then the directory is fsynced) and a fresh
``current.jsonl`` starts. Replay reads sealed segments in order followed
by ``current.jsonl``; a torn final line of the final file (a crash
mid-append) is tolerated silently, while an unparseable line anywhere
else flags that file as corrupt — recovery then degrades to
requeue-from-zero for anything whose state the corrupt span may hide,
rather than crash.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
from typing import Dict, List, Optional


class EventJournal:
    """Append-only fsynced JSONL journal under ``state_dir/journal/``."""

    def __init__(self, state_dir: str, rotate_every: int = 1024,
                 fsync: bool = True):
        assert rotate_every >= 1
        self.dir = os.path.join(state_dir, "journal")
        os.makedirs(self.dir, exist_ok=True)
        self.rotate_every = rotate_every
        self.fsync = fsync
        self._cur = os.path.join(self.dir, "current.jsonl")
        self._n = 0
        if os.path.exists(self._cur):       # reopen: continue appending
            with open(self._cur) as f:
                self._n = sum(1 for line in f if line.strip())
        self._f = open(self._cur, "a")

    def append(self, record: Dict) -> None:
        self._f.write(json.dumps(record, separators=(",", ":")) + "\n")
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())
        self._n += 1
        if self._n >= self.rotate_every:
            self._rotate()

    def _segments(self) -> List[str]:
        return sorted(glob.glob(os.path.join(self.dir, "segment-*.jsonl")))

    def _rotate(self) -> None:
        self._f.close()
        segs = self._segments()
        idx = 1 + (int(os.path.basename(segs[-1])[8:-6]) if segs else 0)
        os.replace(self._cur,
                   os.path.join(self.dir, f"segment-{idx:06d}.jsonl"))
        dfd = os.open(self.dir, os.O_RDONLY)
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)
        self._f = open(self._cur, "a")
        self._n = 0

    def close(self) -> None:
        if not self._f.closed:
            self._f.flush()
            if self.fsync:
                os.fsync(self._f.fileno())
            self._f.close()


# terminal event kinds, by journal string value (avoid importing the enum
# at replay time for records written by any schema revision)
_TERMINAL = frozenset({"task_completed", "task_cancelled"})


@dataclasses.dataclass
class JournalReplay:
    """Parsed journal content plus corruption flags."""
    records: List[Dict]
    corrupt: List[str]          # files with an unparseable non-tail line
    torn_tail: bool             # final line of the final file was torn

    def session(self) -> Optional[Dict]:
        last = None
        for r in self.records:
            if r.get("rec") == "session":
                last = r
        return last

    def submits(self) -> List[Dict]:
        """Submit records, deduped by task name (last submit wins — a
        requeued task re-journals its submission), in first-seen order."""
        by_name: Dict[str, Dict] = {}
        for r in self.records:
            if r.get("rec") == "submit":
                by_name[r["name"]] = r
        return list(by_name.values())

    def terminal_tasks(self) -> frozenset:
        done = set()
        for r in self.records:
            if r.get("rec") == "event" and \
                    r["event"].get("kind") in _TERMINAL:
                done.add(r["event"]["task"])
        return frozenset(done)

    def checkpoints(self) -> Dict[str, Dict]:
        """Latest ``ckpt`` record per task."""
        out: Dict[str, Dict] = {}
        for r in self.records:
            if r.get("rec") == "ckpt":
                out[r["task"]] = r
        return out

    def serves(self) -> Dict[str, str]:
        """Task -> winner artifact path (tune-to-serve records)."""
        return {r["task"]: r["path"] for r in self.records
                if r.get("rec") == "serve"}


def replay_journal(state_dir: str) -> JournalReplay:
    """Parse every sealed segment plus ``current.jsonl``, in order."""
    jdir = os.path.join(state_dir, "journal")
    files = sorted(glob.glob(os.path.join(jdir, "segment-*.jsonl")))
    cur = os.path.join(jdir, "current.jsonl")
    if os.path.exists(cur):
        files.append(cur)
    records: List[Dict] = []
    corrupt: List[str] = []
    torn_tail = False
    for fi, path in enumerate(files):
        with open(path) as f:
            lines = f.read().splitlines()
        for li, line in enumerate(lines):
            if not line.strip():
                continue
            try:
                records.append(json.loads(line))
            except ValueError:
                if fi == len(files) - 1 and li == len(lines) - 1:
                    torn_tail = True        # crash mid-append: expected
                else:
                    corrupt.append(path)
                break                       # stop parsing this file
    return JournalReplay(records=records, corrupt=corrupt,
                         torn_tail=torn_tail)
