"""Profile-fitted cost models: least-squares (k0, k1, k2) from observed steps.

The analytic models in ``profiler.py`` (roofline step time) and
``intra_task.py`` (M_hat memory accounting) derive their coefficients from
FLOP counts and target-hardware constants. This module fits the SAME linear
structures from the raw ``StepObservation`` points ``ProfileStore`` now
accumulates per ``(arch, gpus)`` key —

    step_time(tokens, rank_tokens) = k0 + k1*tokens + k2*rank_tokens
    M_hat(tokens, rank_tokens)     = k0 + k1*tokens + k2*rank_tokens

— so once a session has watched enough real fused steps, admission density
(``admit_cross_task`` / executor backfill / ``plan_fused``) and fused-step
duration budgeting are driven by measured hardware behavior instead of
modeled behavior. The swap lives behind ``fitted=True`` on
``Engine``/``TuningService``; the analytic models remain both the default
and the fallback whenever a key has fewer than ``MIN_OBSERVATIONS`` points
or the fit is degenerate (rank-deficient design, e.g. every observed step
at one width — extrapolating from that would be worse than the roofline).

Coefficients are clamped non-negative by column-drop refit: a negative
``k2`` from collinear data would tell admission that MORE rank is FREE
memory/time, which inverts the §A.3 budget's safety direction. A dropped
column contributes 0 — exactly the rank-neutral/intercept-free special
cases the analytic models already handle.

Fits are cached through the ProfileStore's *versioned* spec cache, which
``record_step`` invalidates — every new observation transparently
re-derives the model on next use, the same freshness contract the engine's
profile specs already rely on.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.sched import profiler
from repro.sched.intra_task import MemoryModel

# Below this many points a 3-coefficient fit chases noise; the analytic
# model is the better estimator. Deliberately larger than the coefficient
# count so the residual is a meaningful generalization signal.
MIN_OBSERVATIONS = 8


def _lstsq_nonneg(X: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Least squares with non-negative coefficients via column-drop refit:
    solve OLS; while any coefficient is negative, zero the most negative
    one, remove its column, and re-solve the rest. (Full NNLS machinery is
    overkill for a 3-column design; this preserves the safety direction —
    see module docstring — at worst by under-using one regressor.)"""
    n = X.shape[1]
    active = list(range(n))
    coef = np.zeros(n)
    while active:
        sol, *_ = np.linalg.lstsq(X[:, active], y, rcond=None)
        if np.all(sol >= 0):
            for i, c in zip(active, sol):
                coef[i] = c
            return coef
        active.pop(int(np.argmin(sol)))
    return coef


def _design(observations: Sequence[profiler.StepObservation]
            ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    X = np.asarray([[1.0, o.tokens, o.rank_tokens] for o in observations],
                   np.float64)
    y = np.asarray([o.wall_s for o in observations], np.float64)
    return X, y, np.asarray([o.peak_memory for o in observations
                             if o.peak_memory is not None], np.float64)


def _degenerate(X: np.ndarray) -> bool:
    """True when the design cannot identify 3 coefficients: fewer distinct
    (tokens, rank_tokens) rows than coefficients, or a numerically
    rank-deficient column space (e.g. rank_tokens a fixed multiple of
    tokens — every step at one rank)."""
    distinct = len({(r[1], r[2]) for r in X.tolist()})
    if distinct < X.shape[1]:
        return True
    return np.linalg.matrix_rank(X, tol=1e-9 * max(np.abs(X).max(), 1.0)) \
        < X.shape[1]


@dataclasses.dataclass(frozen=True)
class FittedStepModel:
    """Fused-step wall time fitted from observed steps:
    ``k0 + k1*tokens + k2*rank_tokens`` seconds. ``k2`` is the per-rank-
    token cost the analytic roofline could only infer from FLOP counts —
    here it is the measured slope, i.e. what the ROADMAP's "fitted k2"
    item asks for."""
    k0: float                 # fixed per-step overhead (s)
    k1: float                 # s per real token (frozen backbone)
    k2: float                 # s per rank-weighted FLOP-token (adapters)
    observations: int
    rms_rel_error: float      # training-set relative RMS residual

    def predict(self, tokens: float, rank_tokens: float) -> float:
        return max(self.k0 + self.k1 * tokens + self.k2 * rank_tokens,
                   1e-12)

    def step_time(self, slot_tokens: Sequence[float],
                  ranks: Sequence[float]) -> float:
        """Drop-in for ``profiler.fused_step_time``'s slot interface."""
        tokens = float(sum(slot_tokens))
        rtok = float(sum(t * r for t, r in zip(slot_tokens, ranks)))
        return self.predict(tokens, rtok)


def fit_step_model(observations: Sequence[profiler.StepObservation],
                   min_observations: int = MIN_OBSERVATIONS
                   ) -> Optional[FittedStepModel]:
    """Least-squares (k0, k1, k2) over raw step observations, or None when
    the data cannot support the fit (the caller falls back to analytic)."""
    if len(observations) < max(min_observations, 3):
        return None
    X, y, _ = _design(observations)
    if _degenerate(X):
        return None
    coef = _lstsq_nonneg(X, y)
    pred = X @ coef
    rel = (pred - y) / np.maximum(np.abs(y), 1e-12)
    return FittedStepModel(k0=float(coef[0]), k1=float(coef[1]),
                           k2=float(coef[2]),
                           observations=len(observations),
                           rms_rel_error=float(np.sqrt(np.mean(rel ** 2))))


def fit_memory_model_ranked(
        observations: Sequence[profiler.StepObservation],
        analytic: MemoryModel,
        min_observations: int = MIN_OBSERVATIONS) -> Optional[MemoryModel]:
    """Fit the rank-aware M_hat (bytes = k0 + k1*tokens + k2*rank_tokens)
    from observed peak memory, keeping the analytic model's capacity /
    safety margin / seq_len / r_max frame (those are device facts, not
    fit targets). None when too few memory-bearing points or degenerate."""
    pts = [o for o in observations if o.peak_memory is not None]
    if len(pts) < max(min_observations, 3):
        return None
    X, _, m = _design(pts)
    if _degenerate(X):
        return None
    coef = _lstsq_nonneg(X, m)
    k2 = float(coef[2])
    if analytic.r_max <= 0:
        # a rank-aware model must know what to bill rank-unknown requests
        # (MemoryModel.__post_init__); without an r_max frame, fold the
        # rank term away rather than under-bill at rank 1
        k2 = 0.0
    return MemoryModel(k0=float(coef[0]), k1=float(coef[1]),
                       seq_len=analytic.seq_len,
                       capacity=analytic.capacity,
                       safety_margin=analytic.safety_margin,
                       k2=k2, r_max=analytic.r_max)


# ---------------------------------------------------------------------------
# Store-backed cached accessors (the fitted=True wiring surface)
# ---------------------------------------------------------------------------

def fitted_step_model(store: profiler.ProfileStore, key: Tuple,
                      min_observations: int = MIN_OBSERVATIONS
                      ) -> Optional[FittedStepModel]:
    """The fitted step model for a profile key, or None below the
    observation guard. Cached in the store's versioned spec cache, so
    every ``record_step`` transparently invalidates and the next call
    re-fits over the grown observation set."""
    cache_key = ("fitted_step", key, min_observations)
    hit = store.get_spec(cache_key)
    if hit is not None:
        return hit if isinstance(hit, FittedStepModel) else None
    model = fit_step_model(store.step_observations(key), min_observations)
    # cache negative results too (False sentinel: None means "cache miss")
    store.put_spec(cache_key, model if model is not None else False)
    return model


def fitted_memory_model(store: profiler.ProfileStore, key: Tuple,
                        analytic: MemoryModel,
                        min_observations: int = MIN_OBSERVATIONS
                        ) -> MemoryModel:
    """The memory model admission should budget against: the fitted
    rank-aware M_hat when the key has enough memory observations, else
    ``analytic`` unchanged. This is the single choke point behind
    ``Engine(fitted=True).memory_model`` — the returned model flows into
    ``ColocationSpec.mem`` and from there into ``admit_cross_task``,
    executor backfill, and (linearized into ``ReplicaState``)
    ``plan_fused``, so all three §A.3 layers budget from the same measured
    coefficients."""
    cache_key = ("fitted_mem", key, min_observations)
    hit = store.get_spec(cache_key)
    if hit is not None:
        return hit if isinstance(hit, MemoryModel) else analytic
    model = fit_memory_model_ranked(store.step_observations(key), analytic,
                                    min_observations)
    store.put_spec(cache_key, model if model is not None else False)
    return model if model is not None else analytic


def fitted_fused_step_time(cfg, slot_tokens: Sequence[float],
                           ranks: Sequence[float], chips: int, *,
                           store: Optional[profiler.ProfileStore] = None,
                           key: Optional[Tuple] = None, mfu: float = 0.4,
                           min_observations: int = MIN_OBSERVATIONS
                           ) -> float:
    """``profiler.fused_step_time`` with the fitted model swapped in when
    the key has enough observations — the analytic roofline otherwise
    (also whenever no store/key is given, so it is a safe drop-in)."""
    model = (fitted_step_model(store, key, min_observations)
             if store is not None and key is not None else None)
    if model is None:
        return profiler.fused_step_time(cfg, slot_tokens, ranks, chips,
                                        mfu=mfu)
    return model.step_time(slot_tokens, ranks)


def observe_fused_step(store: profiler.ProfileStore, key: Tuple, *,
                       slot_tokens: Sequence[float],
                       ranks: Sequence[float], wall_s: float,
                       peak_memory: Optional[float] = None) -> None:
    """Record one fused step in the shape the fitters consume (the
    service's ``_feedback`` hook): collapses per-slot widths/ranks to the
    (tokens, rank_tokens) regressors."""
    tokens = float(sum(slot_tokens))
    rtok = float(sum(t * r for t, r in zip(slot_tokens, ranks)))
    store.record_step(key, tokens=tokens, rank_tokens=rtok, wall_s=wall_s,
                      peak_memory=peak_memory)
