"""Inter-task scheduler: P | size_j | C_max makespan minimization (paper §7.2).

Tasks expose (duration d_i, GPU requirement g_i) before execution — LoRA
tuning's predictability (paper Obs. 3). The paper solves the big-M
disjunctive CP with CP-SAT; offline here, we implement the equivalent
optimization directly:

  * ``list_schedule``: event-driven (skyline) placement of a task order —
    every resource-feasible order maps to a valid concrete-GPU schedule
    (at any start instant, idle >= g_i by the capacity argument).
  * ``branch_and_bound``: DFS over task orders with lower-bound pruning
    (LB = max(longest task, total area / G, sum of d over tasks with
    g_i > G/2)), exploring the space of non-delay schedules. For the
    paper-scale instances (n <= 16) this matches the CP optimum on every
    instance we cross-check by brute force; a node cap degrades gracefully
    to best-found.
  * ``lpt_schedule``: largest-area-first list schedule (fast fallback,
    2-approx-style quality) used for replanning large queues.

Solving is sub-second (paper: "< 1 s for all tested instances"), which is
what makes event-driven replanning viable (§7.2).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    name: str
    duration: float          # estimated d_i (profiled)
    gpus: int                # g_i (from base-model size)
    release: float = 0.0     # r_i: earliest allowed start (dynamic arrival)


@dataclasses.dataclass
class Placement:
    task: TaskSpec
    start: float
    gpu_ids: Tuple[int, ...]

    @property
    def end(self) -> float:
        return self.start + self.task.duration


@dataclasses.dataclass
class Schedule:
    placements: List[Placement]
    makespan: float
    optimal: bool
    solve_time_s: float

    def validate(self, G: int) -> None:
        """No-overlap per GPU + capacity + demand + release satisfied."""
        for p in self.placements:
            assert len(set(p.gpu_ids)) == p.task.gpus, p
            assert all(0 <= g < G for g in p.gpu_ids), p
            assert p.start >= p.task.release - 1e-9, p
        for a, b in itertools.combinations(self.placements, 2):
            if a.start < b.end - 1e-9 and b.start < a.end - 1e-9:
                assert not (set(a.gpu_ids) & set(b.gpu_ids)), (a, b)


def lower_bound(tasks: Sequence[TaskSpec], G: int,
                free_at: Optional[Sequence[float]] = None) -> float:
    """Makespan LB; with ``free_at`` it bounds the residual problem over a
    partially busy cluster (running tasks occupy GPUs until free_at[g])."""
    base = [0.0] * G if free_at is None else list(free_at)
    if not tasks:
        return max(base, default=0.0)
    earliest = min(base)
    area = (sum(base) + sum(t.duration * t.gpus for t in tasks)) / G
    # a task can start no earlier than both its release and the cluster
    longest = max(max(earliest, t.release) + t.duration for t in tasks)
    # tasks needing more than half the cluster can never overlap each other
    big = earliest + sum(t.duration for t in tasks if t.gpus > G / 2)
    return max(area, longest, big, max(base))


def list_schedule(order: Sequence[TaskSpec], G: int,
                  free_at: Optional[Sequence[float]] = None) -> Schedule:
    """Greedy non-delay placement: each task starts at the earliest time
    enough GPUs are free; concrete ids picked from the per-GPU skyline.

    ``free_at`` seeds the per-GPU skyline (residual re-solves over a
    half-busy cluster); defaults to an idle cluster. Tasks with a
    ``release`` (announced future arrivals) never start before it."""
    free_at = [0.0] * G if free_at is None else list(free_at)
    placements: List[Placement] = []
    for t in order:
        # earliest time when >= g GPUs are free: g-th smallest free_at
        times = sorted(range(G), key=lambda g: free_at[g])
        chosen = times[:t.gpus]
        start = max(max(free_at[g] for g in chosen), t.release)
        # better: any set of g GPUs minimizing start; the g earliest-free
        # GPUs minimize the max -> optimal choice for non-delay placement
        for g in chosen:
            free_at[g] = start + t.duration
        placements.append(Placement(t, start, tuple(sorted(chosen))))
    mk = max((p.end for p in placements), default=0.0)
    mk = max(mk, max(free_at, default=0.0))
    return Schedule(placements, mk, optimal=False, solve_time_s=0.0)


def lpt_schedule(tasks: Sequence[TaskSpec], G: int,
                 free_at: Optional[Sequence[float]] = None) -> Schedule:
    """Best of several greedy orders (area, duration, width)."""
    best: Optional[Schedule] = None
    keys = [lambda t: -t.duration * t.gpus,
            lambda t: -t.duration,
            lambda t: (-t.gpus, -t.duration)]
    for key in keys:
        s = list_schedule(sorted(tasks, key=key), G, free_at)
        if best is None or s.makespan < best.makespan - 1e-12:
            best = s
    assert best is not None
    return best


def branch_and_bound(tasks: Sequence[TaskSpec], G: int,
                     node_cap: int = 200_000,
                     time_cap_s: float = 5.0,
                     free_at: Optional[Sequence[float]] = None) -> Schedule:
    """Exact-over-non-delay-orders DFS with LB pruning."""
    t0 = time.time()
    tasks = list(tasks)
    base_free = [0.0] * G if free_at is None else list(free_at)
    n = len(tasks)
    if n == 0:
        return Schedule([], max(base_free, default=0.0), True, 0.0)
    incumbent = lpt_schedule(tasks, G, base_free)
    best_mk = incumbent.makespan
    best_order: Optional[Tuple[int, ...]] = None
    lb_all = lower_bound(tasks, G, base_free)
    if best_mk <= lb_all + 1e-9:
        incumbent.optimal = True
        incumbent.solve_time_s = time.time() - t0
        return incumbent

    nodes = 0
    complete = True
    areas = [t.duration * t.gpus for t in tasks]

    def dfs(order: List[int], free_at: List[float], used_mk: float,
            rem_area: float) -> None:
        nonlocal nodes, best_mk, best_order, complete
        nodes += 1
        if nodes > node_cap or time.time() - t0 > time_cap_s:
            complete = False
            return
        if len(order) == n:
            if used_mk < best_mk - 1e-12:
                best_mk = used_mk
                best_order = tuple(order)
            return
        remaining = [i for i in range(n) if i not in order]
        # LB: remaining area must fit after current per-GPU frontier
        base = sum(free_at)
        lb = max(used_mk,
                 (base + rem_area) / G,
                 max(max(min(free_at), tasks[i].release) + tasks[i].duration
                     for i in remaining))
        if lb >= best_mk - 1e-12:
            return
        # symmetry: skip duplicate (duration,gpus,release) at the same depth
        seen = set()
        # heuristic child order: larger area first
        for i in sorted(remaining, key=lambda j: -areas[j]):
            sig = (tasks[i].duration, tasks[i].gpus, tasks[i].release)
            if sig in seen:
                continue
            seen.add(sig)
            t = tasks[i]
            times = sorted(free_at)
            start = max(times[t.gpus - 1], t.release)
            # apply placement to the g earliest-free GPUs
            new_free = list(free_at)
            idxs = sorted(range(G), key=lambda g: free_at[g])[:t.gpus]
            for g in idxs:
                new_free[g] = start + t.duration
            dfs(order + [i], new_free,
                max(used_mk, start + t.duration), rem_area - areas[i])

    dfs([], list(base_free), max(base_free), float(sum(areas)))
    if best_order is not None:
        sched = list_schedule([tasks[i] for i in best_order], G, base_free)
        sched.optimal = complete or sched.makespan <= lb_all + 1e-9
    else:
        sched = incumbent
        sched.optimal = complete and best_mk <= incumbent.makespan + 1e-12
    sched.solve_time_s = time.time() - t0
    return sched


def solve(tasks: Sequence[TaskSpec], G: int, method: str = "cp",
          free_at: Optional[Sequence[float]] = None) -> Schedule:
    """Entry point. method: "cp" (exact B&B, paper's MILP/CP analogue),
    "lpt" (greedy), "sjf" (shortest-job-first baseline of Fig. 5a)."""
    for t in tasks:
        assert t.gpus <= G, f"{t.name} needs {t.gpus} > {G} GPUs"
    if method == "cp":
        return branch_and_bound(tasks, G, free_at=free_at)
    if method == "lpt":
        return lpt_schedule(tasks, G, free_at)
    if method == "sjf":
        return list_schedule(sorted(tasks, key=lambda t: t.duration), G,
                             free_at)
    raise ValueError(method)


# --------------------------------------------------------------------------
# Residual re-solve + schedule diffing (elastic runtime, paper §7.2)
# --------------------------------------------------------------------------

def solve_residual(tasks: Sequence[TaskSpec], G: int,
                   free_at: Sequence[float], method: str = "cp",
                   bnb_max_n: int = 9) -> Schedule:
    """Re-solve placement of the pending queue over a partially busy
    cluster: ``free_at[g]`` is when GPU g is projected to free up (running
    tasks keep their GPUs — no migration). Exact B&B for small queues,
    LPT fallback beyond ``bnb_max_n`` (replans must stay sub-second so the
    event loop never stalls, paper §7.2)."""
    if method == "cp" and len(tasks) > bnb_max_n:
        method = "lpt"
    return solve(tasks, G, method, free_at=free_at)


@dataclasses.dataclass(frozen=True)
class PlacementDelta:
    task: str
    old_start: Optional[float]
    new_start: Optional[float]
    old_gpus: Tuple[int, ...]
    new_gpus: Tuple[int, ...]

    @property
    def moved_earlier(self) -> bool:
        return (self.old_start is not None and self.new_start is not None
                and self.new_start < self.old_start - 1e-9)


def diff_schedules(old: Schedule, new: Schedule) -> List[PlacementDelta]:
    """Per-task deltas between two plans (replan observability: which
    pending tasks moved earlier / changed GPUs after an event)."""
    old_by = {p.task.name: p for p in old.placements}
    new_by = {p.task.name: p for p in new.placements}
    deltas: List[PlacementDelta] = []
    for name in sorted(set(old_by) | set(new_by)):
        a, b = old_by.get(name), new_by.get(name)
        if (a is not None and b is not None
                and abs(a.start - b.start) < 1e-9 and a.gpu_ids == b.gpu_ids):
            continue
        deltas.append(PlacementDelta(
            task=name,
            old_start=None if a is None else a.start,
            new_start=None if b is None else b.start,
            old_gpus=() if a is None else a.gpu_ids,
            new_gpus=() if b is None else b.gpu_ids))
    return deltas
