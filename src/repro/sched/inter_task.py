"""Inter-task scheduler: P | size_j | C_max makespan minimization (paper §7.2).

Tasks expose (duration d_i, GPU requirement g_i) before execution — LoRA
tuning's predictability (paper Obs. 3). The paper solves the big-M
disjunctive CP with CP-SAT; offline here, we implement the equivalent
optimization directly:

  * ``list_schedule``: event-driven (skyline) placement of a task order —
    every resource-feasible order maps to a valid concrete-GPU schedule
    (at any start instant, idle >= g_i by the capacity argument).
  * ``branch_and_bound``: DFS over task orders with lower-bound pruning
    (LB = max(longest task, total area / G, sum of d over tasks with
    g_i > G/2)), exploring the space of non-delay schedules. For the
    paper-scale instances (n <= 16) this matches the CP optimum on every
    instance we cross-check by brute force; a node cap degrades gracefully
    to best-found.
  * ``lpt_schedule``: largest-area-first list schedule (fast fallback,
    2-approx-style quality) used for replanning large queues.

Solving is sub-second (paper: "< 1 s for all tested instances"), which is
what makes event-driven replanning viable (§7.2).

Fusion-aware planning (co-location as a first-class plan concept): the
solvers above plan in *exclusive-GPU space* — every task occupies its own
g_i GPUs. Since the ragged/rank-local refactors, one frozen-backbone
replica can host adapter slots from several tasks, so the plan vocabulary
is lifted: ``FusionProfile`` describes a task's demand on a shared replica
(fuse key, concurrent slots, per-step tokens, rank-weighted FLOP-tokens)
and ``ReplicaState`` a live replica's capacity (slot headroom plus the
remaining §A.3 + k2 memory budget in bytes). ``plan_fused`` places tasks
*into replica slots* first — greedy decreasing-cost, mirroring cross-task
admission — and hands only the un-fusable remainder to list/LPT/B&B over
the GPU skyline, so the lower bound and the makespan the adoption rule
prices are computed against a plan that SEES co-location instead of
discovering it opportunistically at admission time.

Contract (what callers may rely on): ``plan_fused`` never extends a
replica's projected occupancy (a task fuses only when its whole residual
fits before the replica's projected end and the slot/memory budgets hold),
and its projected makespan is never worse than the exclusive plan over the
same queue — fusing only removes tasks from the GPU skyline. Together with
the runtime's adoption rule this preserves the elastic <= static exclusive
makespan guarantee under fusion-aware planning.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    name: str
    duration: float          # estimated d_i (profiled)
    gpus: int                # g_i (from base-model size)
    release: float = 0.0     # r_i: earliest allowed start (dynamic arrival)


@dataclasses.dataclass
class Placement:
    task: TaskSpec
    start: float
    gpu_ids: Tuple[int, ...]

    @property
    def end(self) -> float:
        return self.start + self.task.duration


@dataclasses.dataclass
class Schedule:
    placements: List[Placement]
    makespan: float
    optimal: bool
    solve_time_s: float

    def validate(self, G: int) -> None:
        """No-overlap per GPU + capacity + demand + release satisfied."""
        for p in self.placements:
            assert len(set(p.gpu_ids)) == p.task.gpus, p
            assert all(0 <= g < G for g in p.gpu_ids), p
            assert p.start >= p.task.release - 1e-9, p
        for a, b in itertools.combinations(self.placements, 2):
            if a.start < b.end - 1e-9 and b.start < a.end - 1e-9:
                assert not (set(a.gpu_ids) & set(b.gpu_ids)), (a, b)


def lower_bound(tasks: Sequence[TaskSpec], G: int,
                free_at: Optional[Sequence[float]] = None) -> float:
    """Makespan LB; with ``free_at`` it bounds the residual problem over a
    partially busy cluster (running tasks occupy GPUs until free_at[g])."""
    base = [0.0] * G if free_at is None else list(free_at)
    if not tasks:
        return max(base, default=0.0)
    earliest = min(base)
    area = (sum(base) + sum(t.duration * t.gpus for t in tasks)) / G
    # a task can start no earlier than both its release and the cluster
    longest = max(max(earliest, t.release) + t.duration for t in tasks)
    # tasks needing more than half the cluster can never overlap each other
    big = earliest + sum(t.duration for t in tasks if t.gpus > G / 2)
    return max(area, longest, big, max(base))


def list_schedule(order: Sequence[TaskSpec], G: int,
                  free_at: Optional[Sequence[float]] = None) -> Schedule:
    """Greedy non-delay placement: each task starts at the earliest time
    enough GPUs are free; concrete ids picked from the per-GPU skyline.

    ``free_at`` seeds the per-GPU skyline (residual re-solves over a
    half-busy cluster); defaults to an idle cluster. Tasks with a
    ``release`` (announced future arrivals) never start before it."""
    free_at = [0.0] * G if free_at is None else list(free_at)
    placements: List[Placement] = []
    for t in order:
        # earliest time when >= g GPUs are free: g-th smallest free_at
        times = sorted(range(G), key=lambda g: free_at[g])
        chosen = times[:t.gpus]
        start = max(max(free_at[g] for g in chosen), t.release)
        # better: any set of g GPUs minimizing start; the g earliest-free
        # GPUs minimize the max -> optimal choice for non-delay placement
        for g in chosen:
            free_at[g] = start + t.duration
        placements.append(Placement(t, start, tuple(sorted(chosen))))
    mk = max((p.end for p in placements), default=0.0)
    mk = max(mk, max(free_at, default=0.0))
    return Schedule(placements, mk, optimal=False, solve_time_s=0.0)


def lpt_schedule(tasks: Sequence[TaskSpec], G: int,
                 free_at: Optional[Sequence[float]] = None) -> Schedule:
    """Best of several greedy orders (area, duration, width)."""
    best: Optional[Schedule] = None
    keys = [lambda t: -t.duration * t.gpus,
            lambda t: -t.duration,
            lambda t: (-t.gpus, -t.duration)]
    for key in keys:
        s = list_schedule(sorted(tasks, key=key), G, free_at)
        if best is None or s.makespan < best.makespan - 1e-12:
            best = s
    assert best is not None
    return best


def branch_and_bound(tasks: Sequence[TaskSpec], G: int,
                     node_cap: int = 200_000,
                     time_cap_s: float = 5.0,
                     free_at: Optional[Sequence[float]] = None) -> Schedule:
    """Exact-over-non-delay-orders DFS with LB pruning."""
    t0 = time.time()
    tasks = list(tasks)
    base_free = [0.0] * G if free_at is None else list(free_at)
    n = len(tasks)
    if n == 0:
        return Schedule([], max(base_free, default=0.0), True, 0.0)
    incumbent = lpt_schedule(tasks, G, base_free)
    best_mk = incumbent.makespan
    best_order: Optional[Tuple[int, ...]] = None
    lb_all = lower_bound(tasks, G, base_free)
    if best_mk <= lb_all + 1e-9:
        incumbent.optimal = True
        incumbent.solve_time_s = time.time() - t0
        return incumbent

    nodes = 0
    complete = True
    areas = [t.duration * t.gpus for t in tasks]

    def dfs(order: List[int], free_at: List[float], used_mk: float,
            rem_area: float) -> None:
        nonlocal nodes, best_mk, best_order, complete
        nodes += 1
        if nodes > node_cap or time.time() - t0 > time_cap_s:
            complete = False
            return
        if len(order) == n:
            if used_mk < best_mk - 1e-12:
                best_mk = used_mk
                best_order = tuple(order)
            return
        remaining = [i for i in range(n) if i not in order]
        # LB: remaining area must fit after current per-GPU frontier
        base = sum(free_at)
        lb = max(used_mk,
                 (base + rem_area) / G,
                 max(max(min(free_at), tasks[i].release) + tasks[i].duration
                     for i in remaining))
        if lb >= best_mk - 1e-12:
            return
        # symmetry: skip duplicate (duration,gpus,release) at the same depth
        seen = set()
        # heuristic child order: larger area first
        for i in sorted(remaining, key=lambda j: -areas[j]):
            sig = (tasks[i].duration, tasks[i].gpus, tasks[i].release)
            if sig in seen:
                continue
            seen.add(sig)
            t = tasks[i]
            times = sorted(free_at)
            start = max(times[t.gpus - 1], t.release)
            # apply placement to the g earliest-free GPUs
            new_free = list(free_at)
            idxs = sorted(range(G), key=lambda g: free_at[g])[:t.gpus]
            for g in idxs:
                new_free[g] = start + t.duration
            dfs(order + [i], new_free,
                max(used_mk, start + t.duration), rem_area - areas[i])

    dfs([], list(base_free), max(base_free), float(sum(areas)))
    if best_order is not None:
        sched = list_schedule([tasks[i] for i in best_order], G, base_free)
        sched.optimal = complete or sched.makespan <= lb_all + 1e-9
    else:
        sched = incumbent
        sched.optimal = complete and best_mk <= incumbent.makespan + 1e-12
    sched.solve_time_s = time.time() - t0
    return sched


def solve(tasks: Sequence[TaskSpec], G: int, method: str = "cp",
          free_at: Optional[Sequence[float]] = None) -> Schedule:
    """Entry point. method: "cp" (exact B&B, paper's MILP/CP analogue),
    "lpt" (greedy), "sjf" (shortest-job-first baseline of Fig. 5a)."""
    for t in tasks:
        assert t.gpus <= G, f"{t.name} needs {t.gpus} > {G} GPUs"
    if method == "cp":
        return branch_and_bound(tasks, G, free_at=free_at)
    if method == "lpt":
        return lpt_schedule(tasks, G, free_at)
    if method == "sjf":
        return list_schedule(sorted(tasks, key=lambda t: t.duration), G,
                             free_at)
    raise ValueError(method)


# --------------------------------------------------------------------------
# Residual re-solve + schedule diffing (elastic runtime, paper §7.2)
# --------------------------------------------------------------------------

def solve_residual(tasks: Sequence[TaskSpec], G: int,
                   free_at: Sequence[float], method: str = "cp",
                   bnb_max_n: int = 9) -> Schedule:
    """Re-solve placement of the pending queue over a partially busy
    cluster: ``free_at[g]`` is when GPU g is projected to free up (running
    tasks keep their GPUs — no migration). Exact B&B for small queues,
    LPT fallback beyond ``bnb_max_n`` (replans must stay sub-second so the
    event loop never stalls, paper §7.2)."""
    if method == "cp" and len(tasks) > bnb_max_n:
        method = "lpt"
    return solve(tasks, G, method, free_at=free_at)


@dataclasses.dataclass(frozen=True)
class PlacementDelta:
    task: str
    old_start: Optional[float]
    new_start: Optional[float]
    old_gpus: Tuple[int, ...]
    new_gpus: Tuple[int, ...]

    @property
    def moved_earlier(self) -> bool:
        return (self.old_start is not None and self.new_start is not None
                and self.new_start < self.old_start - 1e-9)


# --------------------------------------------------------------------------
# Fusion-aware planning: place tasks INTO replica slots (token/rank budgets)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FusionProfile:
    """A task's demand on a shared frozen-backbone replica, in the plan
    vocabulary: tasks whose ``fuse_key`` equals a replica's may be placed
    into that replica's adapter slots instead of onto exclusive GPUs.
    ``slots`` is the task's concurrent-slot upper bound, ``tokens`` its
    per-step token footprint bound (slots * b * seq — what the token-linear
    §A.3 memory model M_hat budgets), and ``rank_tokens`` the rank-weighted
    FLOP-token bound (tokens * true rank — the k2 term; bill r_max when the
    rank is unknown)."""
    fuse_key: Tuple
    slots: int
    tokens: float
    rank_tokens: float = 0.0


@dataclasses.dataclass(frozen=True)
class ReplicaState:
    """A live shared-backbone replica as the planner sees it.

    ``projected_end`` is the absolute virtual time the replica is projected
    to free its GPU set (host residual — refreshed on guest departures so
    the planner never budgets against a stale occupancy), ``slot_headroom``
    the physical adapter slots not claimed by residents' future-use bounds,
    and ``mem_budget`` the remaining §A.3 + k2 memory budget in BYTES:
    capacity * margin - k0 - k1 * resident_tokens - k2 * resident_rank_
    tokens. A candidate with profile p costs ``k1 * p.tokens + k2 *
    p.rank_tokens`` bytes — placing into slots is exactly the
    ``fits_ranked`` admission check, linearized so the solver needs no
    memory-model object."""
    host: str
    fuse_key: Tuple
    gpu_ids: Tuple[int, ...]
    projected_end: float
    slot_headroom: int
    mem_budget: float = float("inf")
    k1: float = 0.0
    k2: float = 0.0

    def fits(self, p: FusionProfile, now: float, duration: float) -> bool:
        """Can ``p`` fuse here without extending the replica? Key match,
        whole residual inside the projected occupancy, slot headroom, and
        the linearized memory budget."""
        if p.fuse_key != self.fuse_key:
            return False
        if now + duration > self.projected_end + 1e-9:
            return False
        if p.slots > self.slot_headroom:
            return False
        return self.cost(p) <= self.mem_budget + 1e-9

    def cost(self, p: FusionProfile) -> float:
        return self.k1 * p.tokens + self.k2 * p.rank_tokens


@dataclasses.dataclass
class FusedSchedule(Schedule):
    """A Schedule whose vocabulary includes co-location: ``fused`` maps
    task name -> host replica for tasks placed INTO replica slots (they
    start at plan time and have no exclusive placement); ``placements``
    covers only the exclusive remainder. ``makespan`` accounts for both:
    max over exclusive ends and fused-host projected ends."""
    fused: Dict[str, str] = dataclasses.field(default_factory=dict)

    def validate_fused(self, G: int,
                       replicas: Sequence[ReplicaState]) -> None:
        """Exclusive part validates as usual; every fused task's host must
        be a known replica and no task may appear in both parts."""
        self.validate(G)
        by_host = {r.host: r for r in replicas}
        placed = {p.task.name for p in self.placements}
        for name, host in self.fused.items():
            assert host in by_host, (name, host)
            assert name not in placed, f"{name} both fused and placed"


def lower_bound_fused(tasks: Sequence[TaskSpec], G: int,
                      free_at: Sequence[float],
                      replicas: Sequence[ReplicaState],
                      profiles: Dict[str, FusionProfile],
                      now: float = 0.0) -> float:
    """Fusion-aware makespan lower bound. A task that could fuse into SOME
    replica (individually — ignoring contention) may cost zero exclusive
    GPU area and finish by that replica's projected end, so only the
    provably un-fusable tasks contribute to the exclusive-space bound;
    every fusable task still bounds from below via min(replica end it fits,
    its exclusive completion). Sound by construction: every feasible
    fusion-aware plan is feasible for this relaxation."""
    exclusive: List[TaskSpec] = []
    floor = max(now, 0.0)
    for t in tasks:
        p = profiles.get(t.name)
        hosts = [r for r in replicas
                 if p is not None and t.release <= now + 1e-9
                 and r.fits(p, now, t.duration)]
        if not hosts:
            exclusive.append(t)
        else:
            # finishes no earlier than its own duration, wherever it lands
            floor = max(floor, max(now, t.release) + t.duration)
    return max(lower_bound(exclusive, G, free_at), floor)


def plan_fused(tasks: Sequence[TaskSpec], G: int,
               free_at: Sequence[float],
               replicas: Sequence[ReplicaState],
               profiles: Dict[str, FusionProfile],
               now: float = 0.0, method: str = "cp",
               bnb_max_n: int = 9) -> FusedSchedule:
    """Fusion-aware residual solve: place tasks INTO replica slots first,
    then solve the exclusive remainder over the GPU skyline.

    Fusion assignment is greedy decreasing memory-cost (ties by name),
    mirroring ``admit_cross_task``'s decreasing-width order; each
    assignment decrements the replica's slot headroom and linearized
    memory budget so contention is respected. Only tasks already released
    (``release <= now``) fuse — a future arrival has no driver to attach.
    The remainder goes through ``solve_residual`` (exact B&B for small
    queues, LPT beyond ``bnb_max_n``).

    The projected makespan of the returned plan is never worse than the
    exclusive plan over the same queue: fused tasks leave the GPU skyline
    untouched and never extend a replica's projected occupancy."""
    budgets = {r.host: [r.slot_headroom, r.mem_budget] for r in replicas}
    fused: Dict[str, str] = {}
    def width(t: TaskSpec) -> float:
        p = profiles.get(t.name)
        return p.tokens + p.rank_tokens if p is not None else 0.0

    order = sorted(tasks, key=lambda t: (-width(t), t.name))
    for t in order:
        p = profiles.get(t.name)
        if p is None or t.release > now + 1e-9:
            continue
        for r in sorted(replicas, key=lambda r: r.projected_end):
            slots_left, mem_left = budgets[r.host]
            trial = dataclasses.replace(r, slot_headroom=slots_left,
                                        mem_budget=mem_left)
            if trial.fits(p, now, t.duration):
                fused[t.name] = r.host
                budgets[r.host][0] -= p.slots
                budgets[r.host][1] -= r.cost(p)
                break
    rest = [t for t in tasks if t.name not in fused]
    sched = solve_residual(rest, G, free_at, method, bnb_max_n)
    mk = sched.makespan
    for name, host in fused.items():
        mk = max(mk, next(r.projected_end for r in replicas
                          if r.host == host))
    return FusedSchedule(sched.placements, mk, sched.optimal,
                         sched.solve_time_s, fused=fused)


def diff_schedules(old: Schedule, new: Schedule) -> List[PlacementDelta]:
    """Per-task deltas between two plans (replan observability: which
    pending tasks moved earlier / changed GPUs after an event)."""
    old_by = {p.task.name: p for p in old.placements}
    new_by = {p.task.name: p for p in new.placements}
    deltas: List[PlacementDelta] = []
    for name in sorted(set(old_by) | set(new_by)):
        a, b = old_by.get(name), new_by.get(name)
        if (a is not None and b is not None
                and abs(a.start - b.start) < 1e-9 and a.gpu_ids == b.gpu_ids):
            continue
        deltas.append(PlacementDelta(
            task=name,
            old_start=None if a is None else a.start,
            new_start=None if b is None else b.start,
            old_gpus=() if a is None else a.gpu_ids,
            new_gpus=() if b is None else b.gpu_ids))
    return deltas
