"""Event-driven inter-task replanning (paper §7.2 "Event-driven replanning").

Two layers live here:

  * ``ProgressEvent``/``EventKind``: the event vocabulary shared by the
    chunked executor (core/executor.py), the elastic cluster runtime
    (sched/cluster.py), and the engine. Every lifecycle transition that can
    shrink a task's residual duration — warmup-selection drops, divergence
    and overfitting exits, per-job completions, task completion — is one of
    these events, which is what makes replanning event-driven rather than
    poll-driven. Placement transitions are events too: ``TASK_FUSED`` (a
    pending task co-located onto a live replica), ``TASK_PREEMPTED`` (a
    guest evicted back to the pending queue, its live adapter state
    suspended bit-exactly), and ``TASK_MIGRATED`` (a guest moved onto a
    different replica mid-task). Contract: the event log is the *complete*
    audit trail of every capacity decision the runtime makes — a consumer
    replaying starts/fusions/preemptions/migrations/completions can
    reconstruct GPU ownership at any virtual time.
  * ``ClusterSimulator``: the original coarse (task-granularity)
    discrete-event simulator over the same solver the engine uses, kept for
    the scheduler benchmarks (Figs. 5/12). The elastic runtime in
    sched/cluster.py supersedes it for engine execution: it sees *intra*-task
    events, not just completions.
"""
from __future__ import annotations

import dataclasses
import enum
import heapq
from typing import Dict, List, Optional, Tuple

from repro.sched.inter_task import TaskSpec, solve


class EventKind(enum.Enum):
    """Lifecycle transitions a running task reports to the runtime."""
    TASK_SUBMITTED = "task_submitted"
    TASK_ARRIVED = "task_arrived"           # dynamic admission into a live loop
    TASK_STARTED = "task_started"
    WARMUP_SELECTION = "warmup_selection"   # Pattern-3 drops at the boundary
    JOB_EXITED = "job_exited"               # divergence / overfit / budget
    TASK_PROGRESS = "task_progress"         # chunk heartbeat (no shrink)
    TASK_FUSED = "task_fused"               # co-located onto a live replica
    TASK_PREEMPTED = "task_preempted"       # guest evicted back to the queue
    TASK_MIGRATED = "task_migrated"         # guest moved to another replica
    TASK_COMPLETED = "task_completed"
    TASK_CANCELLED = "task_cancelled"       # tenant cancel (frees capacity)
    REPLAN = "replan"                       # runtime re-solved the queue
    ADAPTER_PUBLISHED = "adapter_published"  # winner pushed to serving tier
    REPLICA_FAILED = "replica_failed"       # injected chunk failure (chaos)
    POD_KILLED = "pod_killed"               # pod loss: task requeued w/ backoff
    TASK_RECOVERED = "task_recovered"       # restored from durable state

# Kinds that can shrink a task's residual duration and therefore trigger
# a replan of the pending queue.
SHRINK_KINDS = frozenset({EventKind.WARMUP_SELECTION, EventKind.JOB_EXITED,
                          EventKind.TASK_COMPLETED, EventKind.TASK_CANCELLED})

# Terminal kinds for a task (the service's handle-state transitions).
TERMINAL_KINDS = frozenset({EventKind.TASK_COMPLETED,
                            EventKind.TASK_CANCELLED})


@dataclasses.dataclass(frozen=True)
class ProgressEvent:
    kind: EventKind
    task: str
    time: float = 0.0            # virtual cluster time (runtime fills this)
    job: str = ""                # job id for JOB_EXITED
    reason: str = ""             # exit reason / replan outcome
    step: int = 0                # executor step at which it fired
    dropped: Tuple[str, ...] = ()  # job ids dropped at warmup selection
    detail: str = ""

    def shrinks(self) -> bool:
        return self.kind in SHRINK_KINDS

    def stamped(self, time: float) -> "ProgressEvent":
        return dataclasses.replace(self, time=time)


def event_to_json(event: ProgressEvent) -> Dict:
    """JSON-able dict form of a ``ProgressEvent`` (journal line payload)."""
    d = dataclasses.asdict(event)
    d["kind"] = event.kind.value
    d["dropped"] = list(event.dropped)
    return d


def event_from_json(d: Dict) -> ProgressEvent:
    """Inverse of ``event_to_json`` (journal replay)."""
    return ProgressEvent(
        kind=EventKind(d["kind"]), task=d["task"],
        time=float(d.get("time", 0.0)), job=d.get("job", ""),
        reason=d.get("reason", ""), step=int(d.get("step", 0)),
        dropped=tuple(d.get("dropped", ())), detail=d.get("detail", ""))


@dataclasses.dataclass
class TaskRun:
    spec: TaskSpec
    submit_time: float
    start_time: Optional[float] = None
    end_time: Optional[float] = None
    gpu_ids: Tuple[int, ...] = ()
    actual_duration: Optional[float] = None   # may be < spec.duration (EE)


class ClusterSimulator:
    """Discrete-event multi-tenant cluster with replanning."""

    def __init__(self, G: int, method: str = "cp"):
        self.G = G
        self.method = method
        self.now = 0.0
        self.free: List[int] = list(range(G))
        self.pending: List[TaskRun] = []
        self.running: List[Tuple[float, TaskRun]] = []     # (end, run) heap
        self.done: List[TaskRun] = []
        self.replans = 0

    # ---- events -------------------------------------------------------------
    def submit(self, spec: TaskSpec, actual_duration: Optional[float] = None,
               at: Optional[float] = None) -> TaskRun:
        if at is not None:
            self.now = max(self.now, at)
        run = TaskRun(spec=spec, submit_time=self.now,
                      actual_duration=(actual_duration
                                       if actual_duration is not None
                                       else spec.duration))
        self.pending.append(run)
        self._replan()
        return run

    def _complete(self, run: TaskRun) -> None:
        self.free.extend(run.gpu_ids)
        self.done.append(run)
        self._replan()

    def _replan(self) -> None:
        """Greedy dispatch of the solver's next-start decisions at t=now:
        solve over pending (capacity = whole cluster), then start every task
        the plan places at relative time 0 on currently free GPUs."""
        if not self.pending:
            return
        self.replans += 1
        plan = solve([r.spec for r in self.pending], self.G, self.method)
        by_name: Dict[str, TaskRun] = {}
        for r in self.pending:
            by_name.setdefault(r.spec.name, r)
        started = []
        for p in sorted(plan.placements, key=lambda p: p.start):
            if p.start > 1e-9:
                break
            run = by_name[p.task.name]
            if len(self.free) < run.spec.gpus:
                continue
            ids = tuple(self.free[:run.spec.gpus])
            self.free = self.free[run.spec.gpus:]
            run.start_time = self.now
            run.gpu_ids = ids
            run.end_time = self.now + run.actual_duration
            heapq.heappush(self.running, (run.end_time, id(run), run))
            started.append(run)
        for r in started:
            self.pending.remove(r)

    # ---- clock --------------------------------------------------------------
    def run_until_idle(self) -> float:
        """Advance until all tasks complete. Returns makespan."""
        while self.running or self.pending:
            if not self.running:
                # pending but nothing running => couldn't place (shouldn't
                # happen when g_i <= G); force a replan
                self._replan()
                if not self.running:
                    raise RuntimeError("deadlocked pending tasks")
            end, _, run = heapq.heappop(self.running)
            self.now = end
            self._complete(run)
        return max((r.end_time or 0.0) for r in self.done) if self.done else 0.0
