"""ALTO-JAX subsystem."""
