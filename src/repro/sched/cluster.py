"""Elastic cluster runtime: event-driven execution of an inter-task Schedule.

The static engine path executes a precomputed Schedule literally: when a
task's jobs exit early its GPUs idle until the worst-case plan says
otherwise. This runtime closes that gap (paper §7.2 "event-driven
replanning"): it executes the Schedule as an event loop over a simulated
G-GPU cluster, stepping each running task's driver in bounded chunks.
Whenever a chunk surfaces a shrink event (warmup-selection drop,
divergence/overfit exit, completion), the runtime

  1. re-estimates the residual ``TaskSpec`` of every running task from its
     driver's ``residual_estimate()`` (observed survivor counts),
  2. re-solves placement of the pending queue over the projected per-GPU
     skyline (``branch_and_bound`` for small queues, ``lpt_schedule``
     fallback — ``solve_residual``), and
  3. admits newly-placeable tasks immediately instead of at their static
     start times.

Anomaly safety: greedy replanning under shrinking durations is vulnerable
to Graham list-scheduling anomalies (a "better" plan under estimates can
realize worse). The runtime therefore only *adopts* a re-solved plan when
it starts every pending task no later than the task's static planned start
(``s_j``). Together with non-delay dispatch this yields the hard guarantee

    realized start(j) <= s_j  for every task j
    => elastic makespan = max_j(start_j + actual_j)
                       <= max_j(s_j + actual_j) = static makespan

on every instance whose actual durations never exceed the estimates — which
holds structurally for ALTO tasks, where events only remove work.

Drivers decouple the runtime from what a "task" is:

  * ``BatchedExecutor.run_task_chunks`` wrapped in ``ExecutorTaskDriver``
    (the engine's real training path), and
  * ``SimulatedTaskDriver`` (same lifecycle, virtual time only) for
    benchmarks and property tests.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.early_exit import EarlyExitConfig
from repro.sched.events import EventKind, ProgressEvent
from repro.sched.inter_task import (Placement, Schedule, TaskSpec,
                                    diff_schedules, solve, solve_residual)

_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class DriverChunk:
    """One bounded slice of task progress in virtual time."""
    dt: float                              # virtual seconds consumed
    events: Tuple[ProgressEvent, ...] = ()
    done: bool = False


class TaskDriver:
    """Interface the runtime steps. Implementations must be deterministic
    for a fixed construction (the same driver replayed standalone must
    produce the same chunk sequence — the static baseline depends on it)."""

    def start(self, now: float) -> None:          # pragma: no cover
        raise NotImplementedError

    def step_chunk(self) -> DriverChunk:          # pragma: no cover
        raise NotImplementedError

    def residual_estimate(self) -> float:         # pragma: no cover
        """Upper bound (seconds) on remaining work; must shrink over time."""
        raise NotImplementedError

    def result(self) -> Any:
        return None


@dataclasses.dataclass
class _Running:
    spec: TaskSpec
    driver: TaskDriver
    gpu_ids: Tuple[int, ...]
    start: float
    local_time: float
    residual: float
    zero_chunks: int = 0
    saw_completed: bool = False


@dataclasses.dataclass
class RuntimeReport:
    makespan: float
    realized: Schedule                 # actual placements (validates vs G)
    events: List[ProgressEvent]
    replans: int
    plans_adopted: int
    plans_rejected: int
    gpu_busy: List[float]
    utilization: float
    results: Dict[str, Any]
    task_starts: Dict[str, float]
    task_ends: Dict[str, float]

    def per_gpu_utilization(self) -> List[float]:
        mk = max(self.makespan, _EPS)
        return [b / mk for b in self.gpu_busy]


class ElasticClusterRuntime:
    """Event loop over a simulated G-GPU cluster (see module docstring)."""

    def __init__(self, G: int, method: str = "cp", bnb_max_n: int = 9,
                 validate: bool = True, max_zero_chunks: int = 10_000):
        self.G = G
        self.method = method
        self.bnb_max_n = bnb_max_n
        self.validate = validate
        self.max_zero_chunks = max_zero_chunks
        self._submitted: List[Tuple[TaskSpec, Callable[[], TaskDriver]]] = []

    def submit(self, spec: TaskSpec,
               driver_factory: Callable[[], TaskDriver]) -> None:
        assert spec.gpus <= self.G, f"{spec.name} needs {spec.gpus} > {self.G}"
        self._submitted.append((spec, driver_factory))

    # ------------------------------------------------------------------ run
    def run(self, initial: Optional[Schedule] = None) -> RuntimeReport:
        specs = [s for s, _ in self._submitted]
        names = [s.name for s in specs]
        assert len(set(names)) == len(names), "duplicate task names"
        static = initial if initial is not None else solve(
            specs, self.G, self.method)
        if self.validate:
            static.validate(self.G)
        by_name = {s.name: (s, f) for s, f in self._submitted}
        assert set(p.task.name for p in static.placements) == set(names), \
            "schedule does not cover the submitted task set"

        # static planned starts = the per-task admission bounds (anomaly
        # safety) and the incumbent pending plan
        s_bound = {p.task.name: p.start for p in static.placements}
        plan: Dict[str, Tuple[float, Tuple[int, ...]]] = {
            p.task.name: (p.start, p.gpu_ids) for p in static.placements}

        owner: List[Optional[str]] = [None] * self.G
        running: Dict[str, _Running] = {}
        pending = set(names)
        heap: List[Tuple[float, str]] = []
        events: List[ProgressEvent] = []
        results: Dict[str, Any] = {}
        task_starts: Dict[str, float] = {}
        task_ends: Dict[str, float] = {}
        realized: List[Placement] = []
        gpu_busy = [0.0] * self.G
        replans = adopted = rejected = 0

        for name in sorted(pending):
            events.append(ProgressEvent(
                kind=EventKind.TASK_SUBMITTED, task=name, time=0.0))

        def proj_skyline(T: float) -> List[float]:
            """Per-GPU projected free time: running tasks keep their GPUs
            until local_time + residual; free GPUs are free at T."""
            sky = [T] * self.G
            for r in running.values():
                end = max(r.local_time + r.residual, T)
                for g in r.gpu_ids:
                    sky[g] = end
            return sky

        def replan(T: float) -> None:
            nonlocal replans, adopted, rejected
            if not pending:
                return
            replans += 1
            resid = [dataclasses.replace(
                by_name[n][0], duration=max(plan_resid(n), _EPS))
                for n in sorted(pending)]
            cand = solve_residual(resid, self.G, proj_skyline(T),
                                  self.method, self.bnb_max_n)
            if self.validate:
                cand.validate(self.G)
            ok = all(p.start <= s_bound[p.task.name] + _EPS
                     for p in cand.placements)
            if ok:
                old = Schedule(
                    [Placement(by_name[n][0], plan[n][0], plan[n][1])
                     for n in sorted(pending)], 0.0, False, 0.0)
                moved = sum(d.moved_earlier
                            for d in diff_schedules(old, cand))
                for p in cand.placements:
                    plan[p.task.name] = (p.start, p.gpu_ids)
                adopted += 1
                events.append(ProgressEvent(
                    kind=EventKind.REPLAN, task="", time=T,
                    reason="adopted", detail=f"moved_earlier={moved}"))
            else:
                rejected += 1
                events.append(ProgressEvent(
                    kind=EventKind.REPLAN, task="", time=T,
                    reason="rejected", detail="would delay past static start"))

        def plan_resid(name: str) -> float:
            # pending tasks have done no work: residual = estimated duration
            return by_name[name][0].duration

        def admit(T: float) -> None:
            """Start every pending task whose planned GPUs are free, in
            planned-start order; earlier-planned tasks reserve their GPUs
            so later tasks cannot cause priority inversion."""
            reserved: set = set()
            for name in sorted(pending,
                               key=lambda n: (plan[n][0], n)):
                gpus = plan[name][1]
                if any(owner[g] is not None for g in gpus) or \
                        (set(gpus) & reserved):
                    reserved.update(gpus)
                    continue
                spec, factory = by_name[name]
                driver = factory()
                driver.start(T)
                run = _Running(spec=spec, driver=driver, gpu_ids=gpus,
                               start=T, local_time=T,
                               residual=spec.duration)
                running[name] = run
                pending.discard(name)
                for g in gpus:
                    owner[g] = name
                task_starts[name] = T
                heapq.heappush(heap, (run.local_time, name))
                events.append(ProgressEvent(
                    kind=EventKind.TASK_STARTED, task=name, time=T,
                    detail=f"gpus={','.join(map(str, gpus))}"))

        admit(0.0)
        if pending and not running:
            raise RuntimeError("no task placeable at t=0 "
                               "(schedule/capacity mismatch)")

        while heap:
            _, name = heapq.heappop(heap)
            run = running.get(name)
            if run is None:
                continue
            chunk = run.driver.step_chunk()
            if chunk.dt <= 0 and not chunk.done:
                run.zero_chunks += 1
                if run.zero_chunks > self.max_zero_chunks:
                    raise RuntimeError(f"task {name} stopped progressing")
            else:
                run.zero_chunks = 0
            run.local_time += chunk.dt
            T = run.local_time
            # residual upper bounds must be non-increasing in projected-end
            # terms: clamp so local_time + residual never grows
            est = run.driver.residual_estimate()
            run.residual = max(0.0, min(est, run.residual - chunk.dt))
            for e in chunk.events:
                events.append(e.stamped(T))
                if e.kind is EventKind.TASK_COMPLETED:
                    run.saw_completed = True
            shrink = any(e.shrinks() for e in chunk.events)
            if chunk.done:
                del running[name]
                for g in run.gpu_ids:
                    owner[g] = None
                    gpu_busy[g] += T - run.start
                task_ends[name] = T
                results[name] = run.driver.result()
                realized.append(Placement(
                    dataclasses.replace(run.spec, duration=T - run.start),
                    run.start, run.gpu_ids))
                if not run.saw_completed:
                    events.append(ProgressEvent(
                        kind=EventKind.TASK_COMPLETED, task=name, time=T))
                replan(T)
                admit(T)
            else:
                if shrink:
                    replan(T)
                    admit(T)
                heapq.heappush(heap, (run.local_time, name))

        assert not pending, f"unstarted tasks: {sorted(pending)}"
        makespan = max(task_ends.values(), default=0.0)
        schedule = Schedule(realized, makespan, optimal=False,
                            solve_time_s=0.0)
        if self.validate:
            schedule.validate(self.G)
        util = (sum(gpu_busy) / (self.G * makespan)) if makespan > 0 else 0.0
        return RuntimeReport(
            makespan=makespan, realized=schedule, events=events,
            replans=replans, plans_adopted=adopted, plans_rejected=rejected,
            gpu_busy=gpu_busy, utilization=util, results=results,
            task_starts=task_starts, task_ends=task_ends)


# --------------------------------------------------------------------------
# Static baseline: the same drivers, starts pinned to the precomputed plan
# --------------------------------------------------------------------------

@dataclasses.dataclass
class StaticReport:
    makespan: float
    realized: Schedule
    gpu_busy: List[float]
    utilization: float
    results: Dict[str, Any]
    task_starts: Dict[str, float]
    task_ends: Dict[str, float]

    def per_gpu_utilization(self) -> List[float]:
        mk = max(self.makespan, _EPS)
        return [b / mk for b in self.gpu_busy]


def execute_static(schedule: Schedule, G: int,
                   factories: Dict[str, Callable[[], TaskDriver]],
                   validate: bool = True) -> StaticReport:
    """Execute a Schedule literally: every task starts at its planned start
    (GPUs idle in between), actual durations come from draining the same
    drivers the elastic runtime would step. This is the A/B baseline the
    benchmarks compare against."""
    if validate:
        schedule.validate(G)
    realized: List[Placement] = []
    gpu_busy = [0.0] * G
    results: Dict[str, Any] = {}
    starts: Dict[str, float] = {}
    ends: Dict[str, float] = {}
    for p in schedule.placements:
        name = p.task.name
        driver = factories[name]()
        driver.start(p.start)
        dur = 0.0
        while True:
            chunk = driver.step_chunk()
            dur += chunk.dt
            if chunk.done:
                break
        results[name] = driver.result()
        starts[name] = p.start
        ends[name] = p.start + dur
        for g in p.gpu_ids:
            gpu_busy[g] += dur
        realized.append(Placement(
            dataclasses.replace(p.task, duration=dur), p.start, p.gpu_ids))
    makespan = max(ends.values(), default=0.0)
    sched = Schedule(realized, makespan, optimal=False, solve_time_s=0.0)
    if validate:
        sched.validate(G)
    util = (sum(gpu_busy) / (G * makespan)) if makespan > 0 else 0.0
    return StaticReport(makespan=makespan, realized=sched, gpu_busy=gpu_busy,
                        utilization=util, results=results,
                        task_starts=starts, task_ends=ends)


# --------------------------------------------------------------------------
# Simulated driver: the executor lifecycle in virtual time (no training)
# --------------------------------------------------------------------------

class SimulatedTaskDriver(TaskDriver):
    """Replays the BatchedExecutor lifecycle — warmup waves with rotation,
    Pattern-3 selection at the warmup boundary, continue-training with
    early exits and slot backfill — in virtual time. ``exit_step[j]`` makes
    job j exit (divergence/overfit stand-in) once it has trained that many
    steps; jobs without an entry train to ``total_steps``. Deterministic
    for fixed arguments, as the static baseline requires."""

    def __init__(self, name: str, *, K: int, Z: int, total_steps: int,
                 warmup_steps: int, step_time_s: float,
                 select_ratio: float = 0.25,
                 exit_step: Optional[Dict[int, int]] = None,
                 chunk_steps: int = 5):
        assert K >= 1 and Z >= 1 and total_steps >= 1
        self.name = name
        self.K = K
        self.Z = Z
        self.total_steps = total_steps
        self.warmup_steps = max(min(warmup_steps, total_steps), 1)
        self.step_time_s = step_time_s
        self.select_ratio = select_ratio
        self.exit_step = dict(exit_step or {})
        self.chunk_steps = max(chunk_steps, 1)
        # single source of truth for the Pattern-3 rounding rule: the same
        # EarlyExitConfig.top_k the real executor's warmup_select uses
        self.top_k = EarlyExitConfig(select_ratio=select_ratio).top_k(K)
        # lifecycle state
        self._trained = [0] * K
        self._exited: Dict[int, str] = {}
        self._waves = [list(range(i, min(i + Z, K)))
                       for i in range(0, K, Z)]
        self._wave_idx = 0
        self._wave_left = self.warmup_steps
        self._phase = "warmup"
        self._active: List[int] = []
        self._queue: List[int] = []
        self._done = False

    # -- helpers -----------------------------------------------------------
    def _alive(self, jobs: Sequence[int]) -> List[int]:
        return [j for j in jobs if j not in self._exited]

    def start(self, now: float) -> None:
        pass

    def _job_events(self, jobs: Sequence[int]) -> List[ProgressEvent]:
        out = []
        for j in jobs:
            tgt = self.exit_step.get(j)
            if tgt is not None and self._trained[j] >= tgt \
                    and j not in self._exited:
                self._exited[j] = "diverging"
                out.append(ProgressEvent(
                    kind=EventKind.JOB_EXITED, task=self.name,
                    job=f"{self.name}/j{j}", reason="diverging",
                    step=self._trained[j]))
            elif self._trained[j] >= self.total_steps \
                    and j not in self._exited:
                self._exited[j] = "completed"
                out.append(ProgressEvent(
                    kind=EventKind.JOB_EXITED, task=self.name,
                    job=f"{self.name}/j{j}", reason="completed",
                    step=self._trained[j]))
        return out

    def step_chunk(self) -> DriverChunk:
        assert not self._done
        ev: List[ProgressEvent] = []
        if self._phase == "warmup":
            wave = self._alive(self._waves[self._wave_idx])
            n = min(self.chunk_steps, self._wave_left)
            self._wave_left -= n
            for j in wave:
                self._trained[j] += n
            ev += self._job_events(wave)
            if self._wave_left == 0:
                self._wave_idx += 1
                self._wave_left = self.warmup_steps
                if self._wave_idx >= len(self._waves):
                    ev += self._select()
            return DriverChunk(dt=n * self.step_time_s, events=tuple(ev))
        # continue phase
        self._active = self._alive(self._active)
        while len(self._active) < self.Z and self._queue:
            self._active.append(self._queue.pop(0))
        if not self._active:
            self._done = True
            ev.append(ProgressEvent(
                kind=EventKind.TASK_COMPLETED, task=self.name))
            return DriverChunk(dt=0.0, events=tuple(ev), done=True)
        # clamp the chunk to the next per-job event boundary (budget or
        # early exit) so no job overshoots total_steps — the real executor
        # evicts at the exact step, and the worst-case duration estimate
        # must stay an upper bound on the realized duration
        n = self.chunk_steps
        for j in self._active:
            nxt = min(self.exit_step.get(j, self.total_steps),
                      self.total_steps)
            n = min(n, max(nxt - self._trained[j], 1))
        for j in self._active:
            self._trained[j] += n
        ev += self._job_events(self._active)
        self._active = self._alive(self._active)
        return DriverChunk(dt=n * self.step_time_s, events=tuple(ev))

    def _select(self) -> List[ProgressEvent]:
        self._phase = "continue"
        alive = self._alive(range(self.K))
        kept, dropped = alive[:self.top_k], alive[self.top_k:]
        for j in dropped:
            self._exited[j] = "underperforming"
        self._active = kept[:self.Z]
        self._queue = kept[self.Z:]
        if dropped:
            return [ProgressEvent(
                kind=EventKind.WARMUP_SELECTION, task=self.name,
                reason="underperforming", step=self.warmup_steps,
                dropped=tuple(f"{self.name}/j{j}" for j in dropped))]
        return []

    def residual_estimate(self) -> float:
        if self._done:
            return 0.0
        cont_budget = self.total_steps - self.warmup_steps
        if self._phase == "warmup":
            waves_left = len(self._waves) - self._wave_idx - 1
            surv = min(self.top_k, self.K - sum(
                1 for r in self._exited.values() if r != "completed"))
            surv = max(surv, 0)
            cont = -(-surv // self.Z) * cont_budget if surv else 0
            steps = self._wave_left + waves_left * self.warmup_steps + cont
        else:
            alive = self._alive(self._active) + self._alive(self._queue)
            if not alive:
                steps = 0
            else:
                rem = max(self.total_steps - self._trained[j] for j in alive)
                steps = -(-len(alive) // self.Z) * max(rem, 0)
        return steps * self.step_time_s

    def result(self) -> Dict[str, Any]:
        return {"task": self.name,
                "steps_trained": int(sum(self._trained)),
                "exit_reasons": {f"{self.name}/j{j}": r
                                 for j, r in sorted(self._exited.items())}}


def sim_task_spec(name: str, *, K: int, Z: int, total_steps: int,
                  warmup_steps: int, step_time_s: float, gpus: int,
                  select_ratio: float = 0.25) -> TaskSpec:
    """Worst-case (no pattern exits) duration estimate for a simulated
    task — identical to what the profiler computes for real tasks."""
    from repro.sched import profiler
    warmup = max(min(warmup_steps, total_steps), 1)
    top_k = EarlyExitConfig(select_ratio=select_ratio).top_k(K)
    steps = profiler.lifecycle_steps(K, Z, warmup, total_steps,
                                     survivors=top_k)
    return TaskSpec(name=name, duration=steps * step_time_s, gpus=gpus)


# --------------------------------------------------------------------------
# Real-executor driver (engine integration)
# --------------------------------------------------------------------------

class ExecutorTaskDriver(TaskDriver):
    """Wraps BatchedExecutor.run_task_chunks: chunk steps convert to
    virtual seconds via the profiled step time, and each ChunkReport's
    remaining_steps_bound provides the residual estimate.

    Training is drained eagerly at ``start()`` and the chunk/event timeline
    replayed to the runtime. Tasks don't interact and cluster time is
    virtual, so the replay is observationally identical to live stepping —
    but only ONE executor (slot params, optimizer state, snapshots) is
    resident at a time instead of one per concurrently-scheduled task."""

    def __init__(self, name: str, executor, jobs, total_steps: int,
                 step_time_s: float):
        self.name = name
        self.executor = executor
        self.jobs = jobs
        self.total_steps = total_steps
        self.step_time_s = step_time_s
        self._chunks: List[DriverChunk] = []
        self._bounds: List[int] = []
        self._result = None
        self._last_bound: Optional[int] = None

    def start(self, now: float) -> None:
        gen = self.executor.run_task_chunks(
            self.name, self.jobs, self.total_steps)
        while True:
            try:
                report = next(gen)
            except StopIteration as fin:
                self._result = fin.value
                break
            self._chunks.append(DriverChunk(
                dt=report.steps_executed * self.step_time_s,
                events=report.events, done=False))
            self._bounds.append(report.remaining_steps_bound)
        assert self._chunks, "executor produced no chunks"
        # completion events ride the final chunk so the runtime replans
        # exactly once, with the GPUs actually freed
        self._chunks[-1] = dataclasses.replace(self._chunks[-1], done=True)
        self.executor = None            # release slot/opt state eagerly

    def step_chunk(self) -> DriverChunk:
        assert self._chunks is not None and self._chunks, "start() not called"
        chunk = self._chunks.pop(0)
        self._last_bound = self._bounds.pop(0)
        return chunk

    def residual_estimate(self) -> float:
        if self._last_bound is None:        # not stepped yet: no information
            return float("inf")             # runtime clamps to spec duration
        return self._last_bound * self.step_time_s

    def result(self):
        return self._result
