"""Elastic cluster runtime: event-driven execution of an inter-task Schedule.

The static engine path executes a precomputed Schedule literally: when a
task's jobs exit early its GPUs idle until the worst-case plan says
otherwise. This runtime closes that gap (paper §7.2 "event-driven
replanning"): it executes the Schedule as an event loop over a simulated
G-GPU cluster, stepping each running task's driver in bounded chunks.
Whenever a chunk surfaces a shrink event (warmup-selection drop,
divergence/overfit exit, completion), the runtime

  1. re-estimates the residual ``TaskSpec`` of every running task from its
     driver's ``residual_estimate()`` (observed survivor counts),
  2. re-solves placement of the pending queue over the projected per-GPU
     skyline (``branch_and_bound`` for small queues, ``lpt_schedule``
     fallback — ``solve_residual``), and
  3. admits newly-placeable tasks immediately instead of at their static
     start times.

Anomaly safety: greedy replanning under shrinking durations is vulnerable
to Graham list-scheduling anomalies (a "better" plan under estimates can
realize worse). With ``delay_delta=None`` (the default, and what the
batch-mode engine path uses) the runtime only *adopts* a re-solved plan
when it starts every pending task no later than the task's incumbent
planned start (``s_j``). Together with non-delay dispatch this yields the
hard guarantee

    realized start(j) <= s_j  for every task j
    => elastic makespan = max_j(start_j + actual_j)
                       <= max_j(s_j + actual_j) = static makespan

on every instance whose actual durations never exceed the estimates — which
holds structurally for ALTO tasks, where events only remove work.

Service sessions (dynamic arrivals) instead use the **bounded-delay
adoption rule** (``delay_delta=δ``): a candidate plan that delays some
pending task past its incumbent bound by ``max_delay`` is adopted only if
its projected makespan beats the regret fallback's by at least
``δ * max_delay``; otherwise the fallback — incumbent placements untouched,
new arrivals appended over the projected skyline — is adopted. Every unit
of promised delay is therefore bought by at least δ units of projected
makespan win, and a task's bound moves only when that price was paid, so
the plan's projected makespan is non-increasing between arrivals and the
session never does worse than the never-delay (anomaly-safe) policy by
more than the sum of bought delays — each of which shrank the projection
by δ× more than it cost.

Fusion-aware planning (``fusion_planning=True``) lifts co-location from an
admission-time backstop to a plan decision: every replan solves with
``plan_fused`` over live ``ReplicaState`` projections (slot headroom +
linearized SS A.3+k2 memory budgets), so the solver itself decides which
queued tasks ride replica slots and which get exclusive GPUs. Adopted
fusion assignments are re-checked against live capacity when applied
(``_apply_planned_fusions``) — capacity drift makes them stale, never
unsound. With ``migrate=True`` the runtime also runs the reverse move:
a guest whose residual extends its replica past the host's own projected
end is migrated to another same-fuse-key replica or preempted back to the
queue (``TASK_MIGRATED`` / ``TASK_PREEMPTED``), but ONLY when the new
placement is projected to complete the guest no later than staying put —
so the fusion-time occupancy bound, and with it elastic <= static,
survives every move. Preempted/migrated drivers keep their internal
progress (the virtual-time analogue of the SlotSnapshot suspend/resume
primitive in core/adapter_state.py, whose restore is bit-exact), which is
why a migrated task's losses are bitwise identical to a never-migrated
run's.

The runtime is an incremental *session*: ``begin()`` opens the event loop,
``step()`` advances it by one event (an arrival, a cancellation, or one
driver chunk), ``submit(..., at=...)`` and ``cancel(...)`` may be called
while the loop is live, and ``report()`` snapshots the state at idle.
``run()`` keeps the original one-shot semantics (begin, drain, report).

Drivers decouple the runtime from what a "task" is:

  * ``BatchedExecutor.run_task_chunks`` wrapped in ``ExecutorTaskDriver``
    (the engine's real training path), and
  * ``SimulatedTaskDriver`` (same lifecycle, virtual time only) for
    benchmarks and property tests.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.early_exit import EarlyExitConfig
from repro.sched.events import EventKind, ProgressEvent
from repro.sched.inter_task import (FusionProfile, Placement, ReplicaState,
                                    Schedule, TaskSpec, diff_schedules,
                                    lpt_schedule, plan_fused, solve,
                                    solve_residual)
from repro.sched.intra_task import (ColoRequest, MemoryModel,
                                    admit_cross_task)

_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class DriverChunk:
    """One bounded slice of task progress in virtual time."""
    dt: float                              # virtual seconds consumed
    events: Tuple[ProgressEvent, ...] = ()
    done: bool = False


@dataclasses.dataclass(frozen=True)
class ColocationSpec:
    """A task's shared-backbone co-location profile.

    Tasks with EQUAL ``fuse_key`` may share one frozen-backbone replica.
    Since slots went ragged the key carries only what the fused step
    genuinely requires — (arch, GPU demand, loss kind); per-adapter batch
    size and seq len are PER-SLOT properties now, so heterogeneous widths
    fuse freely and instead enter admission as a token budget:
    ``per_adapter_batch`` x ``seq_len`` is the task's per-slot token
    width, the replica hosting the task has ``replica_slots`` physical
    adapter slots, the task itself needs at most ``slots_needed`` of them
    concurrently, and ``mem`` is the replica's fitted §A.3 memory model
    (token-linear, safety-margin bounded) that ragged cross-task
    admission checks ``admit_cross_task`` against."""
    fuse_key: Tuple
    per_adapter_batch: int
    slots_needed: int
    replica_slots: int
    mem: Optional[MemoryModel] = None
    seq_len: Optional[int] = None      # None => memory model's fit seq
    lora_rank: Optional[int] = None    # TRUE rank; None => charged r_max


class TaskDriver:
    """Interface the runtime steps. Implementations must be deterministic
    for a fixed construction (the same driver replayed standalone must
    produce the same chunk sequence — the static baseline depends on it)."""

    def start(self, now: float) -> None:          # pragma: no cover
        raise NotImplementedError

    def step_chunk(self) -> DriverChunk:          # pragma: no cover
        raise NotImplementedError

    def residual_estimate(self) -> float:         # pragma: no cover
        """Upper bound (seconds) on remaining work; must shrink over time."""
        raise NotImplementedError

    def slots_bound(self) -> Optional[int]:
        """Monotone upper bound on the task's future concurrent adapter-
        slot use, or None if unknown. Cross-task admission uses it to
        reclaim replica capacity the moment survivors free it."""
        return None

    def result(self) -> Any:
        return None


# --------------------------------------------------------------------------
# Co-located replica: several task timelines multiplexed on one GPU set
# --------------------------------------------------------------------------

@dataclasses.dataclass
class _Hosted:
    driver: TaskDriver
    colo: Optional[ColocationSpec]
    offset: float                     # replica-local time at attach
    elapsed: float = 0.0              # own-timeline seconds consumed
    done: bool = False
    end: Optional[float] = None       # replica-local completion time

    @property
    def clock(self) -> float:
        return self.offset + self.elapsed


class ColocatedReplicaDriver(TaskDriver):
    """One frozen-backbone replica hosting adapter slots from SEVERAL
    tasks, multiplexed behind the ordinary ``TaskDriver`` interface.

    The replica owns ONE GPU set (the host task's). Each hosted task
    keeps its own timeline; ``step_chunk`` always advances the lagging
    timeline and reports the movement of the replica-wide frontier
    (max over task clocks), so concurrent tasks consume wall-clock once —
    the fused grouped-GEMM utilization win the paper claims. Per-task
    residuals, completion times, and results stay individually
    addressable (``residual_of`` / ``end_of`` / ``result_of``), and every
    event a hosted task emits already carries its own task attribution.

    Soundness: the runtime only attaches a task whose residual fits
    inside the replica's current projected end (and whose incumbent start
    bound has not passed), so attaching never extends the projected
    occupancy — the elastic <= static argument survives co-location."""

    def __init__(self, name: str, driver: TaskDriver,
                 colo: Optional[ColocationSpec], elapsed: float = 0.0):
        self.name = name
        self._subs: Dict[str, _Hosted] = {
            name: _Hosted(driver, colo, 0.0, elapsed)}
        self._frontier = elapsed

    # ---- capacity ----------------------------------------------------------
    @property
    def capacity(self) -> Optional[ColocationSpec]:
        return self._subs[self.name].colo

    def _bound_of(self, h: _Hosted) -> int:
        b = h.driver.slots_bound()
        if b is not None:
            return b
        return h.colo.slots_needed if h.colo is not None else 0

    def resident_requests(self) -> List[ColoRequest]:
        """Live tasks' current demand on the replica (for cross-task
        admission): shrinking slot bounds reclaim freed capacity. Demand
        is token-denominated (slots x b x seq) — co-located tasks may
        have different widths (ragged slots)."""
        return [ColoRequest(n, self._bound_of(h),
                            h.colo.per_adapter_batch if h.colo else 0,
                            h.colo.seq_len if h.colo else None,
                            h.colo.lora_rank if h.colo else None)
                for n, h in sorted(self._subs.items()) if not h.done]

    # ---- membership --------------------------------------------------------
    def attach(self, name: str, driver: TaskDriver,
               colo: Optional[ColocationSpec]) -> None:
        assert name not in self._subs, f"{name} already hosted"
        self._subs[name] = _Hosted(driver, colo, self._frontier)

    def cancel_hosted(self, name: str) -> None:
        h = self._subs[name]
        h.done = True
        h.end = h.clock

    def detach(self, name: str) -> TaskDriver:
        """Remove a LIVE hosted guest for preemption/migration and return
        its driver with all internal progress intact — the virtual-time
        analogue of a SlotSnapshot suspend. The driver can be re-attached
        to another replica or resumed exclusively; either way it continues
        from exactly where it stopped. The replica owner cannot detach
        (its GPU set IS the replica)."""
        assert name != self.name, "cannot detach the replica owner"
        h = self._subs.pop(name)
        assert not h.done, f"{name} already finished on this replica"
        return h.driver

    def sub_names(self) -> List[str]:
        return list(self._subs)

    def hosted_names(self) -> List[str]:
        return [n for n in self._subs if n != self.name]

    def end_of(self, name: str) -> Optional[float]:
        """Replica-local completion time (absolute = replica start + this)."""
        return self._subs[name].end

    def result_of(self, name: str) -> Any:
        h = self._subs[name]
        return h.driver.result() if h.done else None

    def result(self) -> Any:
        return self.result_of(self.name)

    # ---- TaskDriver --------------------------------------------------------
    def start(self, now: float) -> None:
        self._subs[self.name].driver.start(now)

    def step_chunk(self) -> DriverChunk:
        """Advance the lagging task timeline one chunk; return the
        frontier movement. Zero-progress catch-up chunks are absorbed
        internally so the runtime's stall detector never trips on a
        long-lagging timeline."""
        start = self._frontier
        events: List[ProgressEvent] = []
        spins = 0
        while True:
            live = [(h.clock, n) for n, h in sorted(self._subs.items())
                    if not h.done]
            if not live:
                return DriverChunk(dt=self._frontier - start,
                                   events=tuple(events), done=True)
            spins += 1
            if spins > 10_000:
                # a sub-driver is emitting empty zero-dt chunks: hand a
                # zero chunk back so the runtime's stall detector sees it
                return DriverChunk(dt=self._frontier - start,
                                   events=tuple(events), done=False)
            _, pick = min(live)
            h = self._subs[pick]
            chunk = h.driver.step_chunk()
            h.elapsed += chunk.dt
            events.extend(chunk.events)
            if chunk.done:
                h.done = True
                h.end = h.clock
            self._frontier = max(self._frontier, h.clock)
            if all(s.done for s in self._subs.values()):
                return DriverChunk(dt=self._frontier - start,
                                   events=tuple(events), done=True)
            if self._frontier > start + _EPS or events:
                return DriverChunk(dt=self._frontier - start,
                                   events=tuple(events), done=False)

    def residual_estimate(self) -> float:
        ends = [h.clock + h.driver.residual_estimate()
                for h in self._subs.values() if not h.done]
        if not ends:
            return 0.0
        return max(max(ends) - self._frontier, 0.0)

    def residual_of(self, name: str) -> float:
        h = self._subs[name]
        return 0.0 if h.done else h.driver.residual_estimate()

    def slots_bound(self) -> Optional[int]:
        return sum(self._bound_of(h) for h in self._subs.values()
                   if not h.done)


@dataclasses.dataclass
class _Running:
    spec: TaskSpec
    driver: TaskDriver
    gpu_ids: Tuple[int, ...]
    start: float
    local_time: float
    residual: float
    zero_chunks: int = 0
    saw_completed: bool = False


@dataclasses.dataclass
class RuntimeReport:
    makespan: float
    realized: Schedule                 # actual placements (validates vs G)
    events: List[ProgressEvent]
    replans: int
    plans_adopted: int
    plans_rejected: int
    gpu_busy: List[float]
    utilization: float
    results: Dict[str, Any]
    task_starts: Dict[str, float]
    task_ends: Dict[str, float]
    cancelled: Tuple[str, ...] = ()
    colocated: Dict[str, str] = dataclasses.field(default_factory=dict)
    preemptions: int = 0
    migrations: int = 0
    pod_kills: int = 0

    def per_gpu_utilization(self) -> List[float]:
        mk = max(self.makespan, _EPS)
        return [b / mk for b in self.gpu_busy]


@dataclasses.dataclass(frozen=True)
class _Submission:
    spec: TaskSpec
    factory: Callable[[], TaskDriver]
    at: float
    colo: Optional[ColocationSpec] = None


@dataclasses.dataclass
class _Suspended:
    """A preempted guest between placements: the detached driver keeps its
    internal progress, ``residual`` is the remaining virtual duration the
    solver plans with until the task is re-placed."""
    driver: TaskDriver
    residual: float


class ElasticClusterRuntime:
    """Incremental event-loop session over a simulated G-GPU cluster (see
    module docstring). ``run()`` is the one-shot batch entry; the service
    drives ``begin()``/``step()`` directly and injects ``submit(at=...)``
    arrivals and ``cancel()`` requests while the loop is live."""

    def __init__(self, G: int, method: str = "cp", bnb_max_n: int = 9,
                 validate: bool = True, max_zero_chunks: int = 10_000,
                 delay_delta: Optional[float] = None,
                 colocate: bool = False,
                 fusion_planning: bool = False,
                 migrate: bool = False):
        self.G = G
        self.method = method
        self.bnb_max_n = bnb_max_n
        self.validate = validate
        self.max_zero_chunks = max_zero_chunks
        self.delay_delta = delay_delta
        # fusion_planning: replans solve with plan_fused — co-location is a
        # first-class plan decision (replica slots with token/rank budgets),
        # not just an opportunistic backstop at admission. Implies colocate.
        # migrate: each replan may first evict or migrate a live guest whose
        # residual now extends its replica past the host's own projected end
        # (the host queue regrew relative to the shrunken replica).
        self.fusion_planning = fusion_planning
        self.migrate = migrate
        self.colocate = colocate or fusion_planning
        self.now = 0.0
        self._subs: List[_Submission] = []
        self._by_name: Dict[str, _Submission] = {}
        self._live = False
        self._seq = 0

    # ---------------------------------------------------------- admission
    def submit(self, spec: TaskSpec,
               driver_factory: Callable[[], TaskDriver],
               at: float = 0.0,
               colo: Optional[ColocationSpec] = None) -> None:
        """Queue a task. Before ``begin()`` this only records it (duplicate
        names surface at ``begin``, preserving batch semantics); on a live
        session it becomes an arrival event at virtual time ``at`` (clamped
        to now) that the next ``step()`` admits into the running loop.
        ``colo`` marks the task fusable: when the session runs with
        ``colocate=True``, a pending fusable task may be co-located onto a
        live same-``fuse_key`` replica instead of waiting for free GPUs."""
        assert spec.gpus <= self.G, f"{spec.name} needs {spec.gpus} > {self.G}"
        if not self._live:
            sub = _Submission(spec, driver_factory, max(at, 0.0), colo)
            self._subs.append(sub)
            return
        name = spec.name
        assert name not in self._by_name, f"duplicate task name {name}"
        at = max(at, self.now)
        sub = _Submission(dataclasses.replace(spec, release=at),
                          driver_factory, at, colo)
        self._by_name[name] = sub           # _subs was consumed by begin()
        self._future[name] = at
        self._push_ctrl(at, "arrive", name)

    def cancel(self, name: str, at: Optional[float] = None) -> bool:
        """Schedule cancellation of a task at virtual time ``at`` (default:
        now). Cancelling a running task frees its GPUs and triggers a
        replan; a pending / not-yet-arrived task is simply withdrawn.
        Returns False when the task is already terminal."""
        assert self._live, "cancel() requires a live session (begin/run)"
        assert name in self._by_name, f"unknown task {name}"
        if name in self._results or name in self._cancel_set:
            return False
        at = self.now if at is None else max(at, self.now)
        self._push_ctrl(at, "cancel", name)
        return True

    def inject_fault(self, name: str, at: Optional[float] = None,
                     backoff: float = 0.0) -> None:
        """Chaos injection: kill the pod running ``name`` at virtual time
        ``at``. The task's driver is suspended at its last completed chunk
        boundary (chunks are atomic — the virtual-time analogue of a
        durable checkpoint), its GPUs are freed, and it rejoins the
        pending queue after ``backoff`` seconds, resuming its suspended
        driver through the PR 6 re-admission path. Killing a fused guest
        kills its host replica (the pod), suspending every tenant with
        it. Killing a task that is not running is a no-op at fire time."""
        assert self._live, "inject_fault() requires a live session"
        assert name in self._by_name, f"unknown task {name}"
        at = self.now if at is None else max(at, self.now)
        self._fault_backoffs.setdefault(name, []).append(float(backoff))
        self._push_ctrl(at, "podkill", name)

    def _push_ctrl(self, at: float, kind: str, name: str) -> None:
        self._seq += 1
        heapq.heappush(self._ctrl, (at, self._seq, kind, name))

    # ---------------------------------------------------------- session
    def begin(self, initial: Optional[Schedule] = None) -> None:
        """Open the event loop: plan + admit the t<=0 batch, queue future
        arrivals. ``initial`` (batch mode) supplies the static plan whose
        starts become the anomaly-safety bounds."""
        assert not self._live, "session already live"
        names = [s.spec.name for s in self._subs]
        assert len(set(names)) == len(names), "duplicate task names"
        self._by_name = {s.spec.name: s for s in self._subs}
        batch = [s for s in self._subs if s.at <= 0.0]
        future = [s for s in self._subs if s.at > 0.0]

        self._owner: List[Optional[str]] = [None] * self.G
        self._running: Dict[str, _Running] = {}
        self._pending = {s.spec.name for s in batch}
        self._heap: List[Tuple[float, str]] = []
        self._ctrl: List[Tuple[float, int, str, str]] = []
        self._future: Dict[str, float] = {}
        self._events: List[ProgressEvent] = []
        self._results: Dict[str, Any] = {}
        self._task_starts: Dict[str, float] = {}
        self._task_ends: Dict[str, float] = {}
        self._realized: List[Placement] = []
        self._gpu_busy = [0.0] * self.G
        self._replans = self._adopted = self._rejected = 0
        self._cancel_set: set = set()
        self._bounds: Dict[str, float] = {}
        self._plan: Dict[str, Tuple[float, Tuple[int, ...]]] = {}
        self._hosted: Dict[str, str] = {}        # fused task -> host task
        self._planned_fusions: Dict[str, str] = {}   # plan-level task -> host
        self._suspended: Dict[str, _Suspended] = {}  # preempted guests
        self._preempted_n = 0
        self._migrated_n = 0
        self._fault_backoffs: Dict[str, List[float]] = {}
        self._pod_kills = 0
        self.now = 0.0
        self._live = True

        if batch:
            static = initial if initial is not None else solve(
                [s.spec for s in batch], self.G, self.method)
            if self.validate:
                static.validate(self.G)
            assert (set(p.task.name for p in static.placements)
                    == self._pending), \
                "schedule does not cover the submitted task set"
            # static planned starts = the per-task admission bounds (anomaly
            # safety) and the incumbent pending plan
            for p in static.placements:
                self._bounds[p.task.name] = p.start
                self._plan[p.task.name] = (p.start, p.gpu_ids)
        else:
            assert initial is None or not initial.placements

        for name in sorted(self._pending):
            self._events.append(ProgressEvent(
                kind=EventKind.TASK_SUBMITTED, task=name, time=0.0))
        for s in future:
            self._future[s.spec.name] = s.at
            self._by_name[s.spec.name] = dataclasses.replace(
                s, spec=dataclasses.replace(s.spec, release=s.at))
            self._push_ctrl(s.at, "arrive", s.spec.name)

        self._admit(0.0)
        if self._pending and not self._running:
            raise RuntimeError("no task placeable at t=0 "
                               "(schedule/capacity mismatch)")

    def idle(self) -> bool:
        return (self._live and not self._running and not self._ctrl
                and not self._pending)

    def step(self) -> bool:
        """Advance the loop by one event: the earliest of the next control
        event (arrival / cancellation) and the next driver chunk. Returns
        False once the session is idle."""
        assert self._live, "begin() not called"
        next_chunk = self._peek_chunk()
        next_ctrl = self._ctrl[0][0] if self._ctrl else None
        if next_chunk is None and next_ctrl is None:
            if self._pending:
                # defensive: re-solve and admit whatever is admissible
                self._replan(self.now)
                self._admit(self.now)
                if self._pending and not self._running:
                    raise RuntimeError(
                        f"unplaceable pending tasks: {sorted(self._pending)}")
                return True
            return False
        if next_ctrl is not None and (next_chunk is None
                                      or next_ctrl <= next_chunk):
            at, _, kind, name = heapq.heappop(self._ctrl)
            self._process_ctrl(max(at, self.now), kind, name)
        else:
            self._step_chunk()
        return True

    def _peek_chunk(self) -> Optional[float]:
        while self._heap and self._heap[0][1] not in self._running:
            heapq.heappop(self._heap)        # stale (completed / cancelled)
        return self._heap[0][0] if self._heap else None

    # ---------------------------------------------------------- internals
    def _process_ctrl(self, T: float, kind: str, name: str) -> None:
        self.now = max(self.now, T)
        if kind == "arrive":
            if name in self._cancel_set:
                return                       # cancelled before arrival
            self._future.pop(name, None)
            self._pending.add(name)
            spec = self._by_name[name].spec
            self._events.append(ProgressEvent(
                kind=EventKind.TASK_ARRIVED, task=name, time=T,
                detail=f"gpus={spec.gpus} d={spec.duration:.3f}"))
            self._replan(T)
            self._admit(T)
            return
        if kind == "podkill":
            self._pod_kill(T, name)
            return
        # cancel
        if name in self._results or name in self._cancel_set:
            return
        self._cancel_set.add(name)
        self._events.append(ProgressEvent(
            kind=EventKind.TASK_CANCELLED, task=name, time=T))
        run = self._running.pop(name, None)
        if run is not None:
            for g in run.gpu_ids:
                self._owner[g] = None
                self._gpu_busy[g] += T - run.start
            self._task_ends[name] = T
            self._realized.append(Placement(
                dataclasses.replace(run.spec, duration=T - run.start),
                run.start, run.gpu_ids))
            if isinstance(run.driver, ColocatedReplicaDriver):
                # cancelling the replica owner drops every hosted task's
                # slots with it — cancel the unfinished tenants FIRST
                # (their backbone is gone; harvesting them would report
                # truncated runs as completions and poison the profiler
                # feedback), then record the already-finished ones
                for sub in run.driver.hosted_names():
                    if sub in self._task_ends or sub in self._cancel_set:
                        continue
                    if run.driver.end_of(sub) is None:
                        self._cancel_set.add(sub)
                        self._task_ends[sub] = T
                        self._events.append(ProgressEvent(
                            kind=EventKind.TASK_CANCELLED, task=sub, time=T,
                            detail=f"host {name} cancelled"))
                self._harvest_replica(run, T)
        elif name in self._hosted:
            host = self._hosted.pop(name)
            hrun = self._running.get(host)
            if hrun is not None and isinstance(hrun.driver,
                                               ColocatedReplicaDriver):
                hrun.driver.cancel_hosted(name)
                # BUGFIX: the host's projected end must be revalidated the
                # moment a guest departs — the stale pre-departure residual
                # would keep the skyline and the fusion anomaly guard
                # checking admissions against occupancy the replica no
                # longer has
                self._refresh_residual(hrun)
            self._task_ends[name] = T
        else:
            self._pending.discard(name)
            self._future.pop(name, None)
        self._plan.pop(name, None)
        self._bounds.pop(name, None)
        self._planned_fusions.pop(name, None)
        self._suspended.pop(name, None)
        self._replan(T)
        self._admit(T)

    def _pod_kill(self, T: float, name: str) -> None:
        """Execute an injected pod loss (``inject_fault``): suspend the
        running driver at its last chunk boundary, free and bill its
        GPUs, and requeue the task after its backoff. Driver progress is
        never lost — chunks are atomic, so the kill lands exactly at the
        boundary the in-flight work last committed (the wasted wall time
        between boundary and kill is the recomputed-work cost)."""
        backoffs = self._fault_backoffs.get(name, [])
        backoff = backoffs.pop(0) if backoffs else 0.0
        target = self._hosted.get(name, name)    # a guest dies with its pod
        run = self._running.get(target)
        if run is None or target in self._cancel_set:
            return                                # nothing running: no pod
        Tk = max(T, run.local_time)  # task clock may lead global time
        self.now = max(self.now, Tk)
        self._pod_kills += 1
        self._events.append(ProgressEvent(
            kind=EventKind.POD_KILLED, task=target, time=Tk,
            detail=f"backoff={backoff:.3f}"))
        for g in run.gpu_ids:
            self._owner[g] = None
            self._gpu_busy[g] += Tk - run.start
        self._realized.append(Placement(
            dataclasses.replace(run.spec, duration=Tk - run.start),
            run.start, run.gpu_ids))
        del self._running[target]
        # suspend the WHOLE driver (a replica keeps its guests: all
        # tenants resume together when the pod is re-placed)
        est = run.driver.residual_estimate()
        residual = max(0.0, min(est, run.residual))
        self._suspended[target] = _Suspended(driver=run.driver,
                                             residual=residual)
        self._plan.pop(target, None)
        self._bounds.pop(target, None)
        re_at = Tk + backoff
        sub = self._by_name[target]
        self._by_name[target] = dataclasses.replace(
            sub, spec=dataclasses.replace(
                sub.spec, duration=max(residual, _EPS), release=re_at),
            at=re_at)
        self._future[target] = re_at
        self._push_ctrl(re_at, "arrive", target)
        self._replan(Tk)
        self._admit(Tk)

    def _step_chunk(self) -> None:
        _, name = heapq.heappop(self._heap)
        run = self._running.get(name)
        if run is None:
            return
        chunk = run.driver.step_chunk()
        if chunk.dt <= 0 and not chunk.done:
            run.zero_chunks += 1
            if run.zero_chunks > self.max_zero_chunks:
                raise RuntimeError(f"task {name} stopped progressing")
        else:
            run.zero_chunks = 0
        run.local_time += chunk.dt
        T = run.local_time
        self.now = max(self.now, T)
        # residual upper bounds must be non-increasing in projected-end
        # terms: clamp so local_time + residual never grows
        est = run.driver.residual_estimate()
        run.residual = max(0.0, min(est, run.residual - chunk.dt))
        for e in chunk.events:
            self._events.append(e.stamped(T))
            if e.kind is EventKind.TASK_COMPLETED:
                if e.task == name or not e.task:
                    run.saw_completed = True
                elif e.task in self._hosted:
                    # a co-located task finished inside the replica: its
                    # result is final now even though the replica (and its
                    # GPU set) keeps running the other tenants
                    self._record_hosted_end(run, e.task)
        if isinstance(run.driver, ColocatedReplicaDriver):
            # hosted timelines can finish without a TASK_COMPLETED event
            # riding the same chunk (real executors emit it one chunk
            # early); sweep for freshly-finished tenants either way
            for sub in run.driver.hosted_names():
                if run.driver.end_of(sub) is not None:
                    self._record_hosted_end(run, sub)
        shrink = any(e.shrinks() for e in chunk.events)
        if chunk.done:
            del self._running[name]
            self._plan.pop(name, None)      # a long-lived session must not
            self._bounds.pop(name, None)    # accumulate finished tasks
            for g in run.gpu_ids:
                self._owner[g] = None
                self._gpu_busy[g] += T - run.start
            if isinstance(run.driver, ColocatedReplicaDriver):
                self._harvest_replica(run, T)
            else:
                self._task_ends[name] = T
                self._results[name] = run.driver.result()
            self._realized.append(Placement(
                dataclasses.replace(run.spec, duration=T - run.start),
                run.start, run.gpu_ids))
            if not run.saw_completed:
                self._events.append(ProgressEvent(
                    kind=EventKind.TASK_COMPLETED, task=name, time=T))
            self._replan(T)
            self._admit(T)
        else:
            if shrink:
                self._replan(T)
                self._admit(T)
            elif self.migrate and isinstance(run.driver,
                                             ColocatedReplicaDriver):
                # a replica's own chunk boundary is where its local clock
                # catches up to global time — the only moment a migration
                # deferred on clock skew can fire without delaying the guest
                self._rebalance(T)
            heapq.heappush(self._heap, (run.local_time, name))

    def _record_hosted_end(self, run: "_Running", sub: str) -> None:
        if sub in self._task_ends or sub in self._cancel_set:
            return
        w = run.driver
        assert isinstance(w, ColocatedReplicaDriver)
        end = w.end_of(sub)
        if end is None:
            return
        self._task_ends[sub] = run.start + end
        self._results[sub] = w.result_of(sub)

    def _harvest_replica(self, run: "_Running", T: float) -> None:
        """Record completion times/results of every task a finishing (or
        cancelled) replica hosted, the owner included. Per-task ends are
        the tasks' OWN completion points on the replica timeline — the
        replica's GPU occupancy (run.start..T) is what gpu_busy bills."""
        w = run.driver
        assert isinstance(w, ColocatedReplicaDriver)
        for sub in w.sub_names():
            if sub in self._cancel_set or sub in self._task_ends:
                continue
            end = w.end_of(sub)
            self._task_ends[sub] = run.start + end if end is not None else T
            self._results[sub] = w.result_of(sub)

    def _proj_skyline(self, T: float) -> List[float]:
        """Per-GPU projected free time: running tasks keep their GPUs
        until local_time + residual; free GPUs are free at T."""
        sky = [T] * self.G
        for r in self._running.values():
            end = max(r.local_time + r.residual, T)
            for g in r.gpu_ids:
                sky[g] = end
        return sky

    def _refresh_residual(self, run: "_Running") -> None:
        """Recompute a run's projected-end residual from its driver after a
        guest departure (cancel / preemption / migration). Clamped to never
        grow: the projected end stays monotone non-increasing, which the
        elastic <= static argument relies on."""
        run.residual = max(0.0, min(run.driver.residual_estimate(),
                                    run.residual))

    def _plan_resid(self, name: str) -> float:
        # preempted tasks resume mid-flight: residual = what remains;
        # never-started pending tasks have done no work: full duration
        sus = self._suspended.get(name)
        if sus is not None:
            return sus.residual
        return self._by_name[name].spec.duration

    def _guest_driver(self, name: str, T: float) -> TaskDriver:
        """Driver for a task entering execution: a preempted guest resumes
        its suspended driver (progress intact — the bitwise-determinism
        contract), a fresh task constructs and starts one."""
        sus = self._suspended.pop(name, None)
        if sus is not None:
            return sus.driver
        driver = self._by_name[name].factory()
        driver.start(T)
        return driver

    def _resident_requests_of(self, name: str,
                              run: "_Running") -> List[ColoRequest]:
        """Current admission-relevant demand of a run, replica or not."""
        if isinstance(run.driver, ColocatedReplicaDriver):
            return run.driver.resident_requests()
        c = self._by_name[name].colo
        b = run.driver.slots_bound()
        slots = b if b is not None else (c.slots_needed if c else 0)
        return [ColoRequest(name, slots,
                            c.per_adapter_batch if c else 0,
                            c.seq_len if c else None,
                            c.lora_rank if c else None)]

    def _replica_states(self, T: float) -> List[ReplicaState]:
        """Project every running fusable task as a planner ReplicaState:
        projected end from the live residual, slot headroom from resident
        slot bounds, and the remaining SS A.3+k2 memory budget linearized to
        (bytes, k1, k2) so plan_fused's cost() check equals fits_ranked."""
        reps: List[ReplicaState] = []
        for host in sorted(self._running):
            run = self._running[host]
            cap = self._by_name[host].colo
            if cap is None:
                continue
            res = self._resident_requests_of(host, run)
            used_slots = sum(r.slots for r in res)
            if cap.mem is not None:
                m = cap.mem
                seq = m.seq_len
                rank = m.charged_rank(None)
                tok = sum(r.tokens(seq) for r in res)
                rtok = sum(r.rank_tokens(seq, rank) for r in res)
                budget = (m.capacity * m.safety_margin - m.k0
                          - m.k1 * tok - m.k2 * rtok)
                k1, k2 = m.k1, m.k2
            else:
                budget, k1, k2 = float("inf"), 0.0, 0.0
            reps.append(ReplicaState(
                host=host, fuse_key=cap.fuse_key, gpu_ids=run.gpu_ids,
                projected_end=run.local_time + run.residual,
                slot_headroom=max(cap.replica_slots - used_slots, 0),
                mem_budget=budget, k1=k1, k2=k2))
        return reps

    def _fusion_profiles(self, queue: List[str],
                         T: float) -> Dict[str, FusionProfile]:
        """FusionProfile per fusable queued task, mirroring the ColoRequest
        the admission gate will re-check at apply time. Tasks whose
        incumbent start bound has already passed are excluded — fusing
        them now would break the bound promise, exactly the _try_fuse
        guard, evaluated at plan time."""
        out: Dict[str, FusionProfile] = {}
        for n in queue:
            c = self._by_name[n].colo
            if c is None:
                continue
            bound = self._bounds.get(n)
            if bound is not None and T > bound + _EPS:
                continue
            seq = c.seq_len or (c.mem.seq_len if c.mem is not None else 1)
            rank = c.lora_rank or (c.mem.charged_rank(None)
                                   if c.mem is not None else 1)
            tokens = float(c.slots_needed * c.per_adapter_batch * seq)
            out[n] = FusionProfile(fuse_key=c.fuse_key,
                                   slots=c.slots_needed, tokens=tokens,
                                   rank_tokens=tokens * rank)
        return out

    def _queue_spec(self, name: str, T: float) -> TaskSpec:
        spec = self._by_name[name].spec
        release = self._future.get(name, min(spec.release, T))
        return dataclasses.replace(
            spec, duration=max(self._plan_resid(name), _EPS),
            release=release)

    def _fallback_plan(self, queue: List[str], sky: List[float]
                       ) -> Tuple[Dict[str, Tuple[float, Tuple[int, ...]]],
                                  float]:
        """Regret fallback: incumbent placements untouched, unplanned names
        (new arrivals) appended over the incumbent-reserved skyline.
        Returns (plan entries for unplanned names, projected makespan)."""
        free = list(sky)
        mk = max(free, default=0.0)
        known = sorted((n for n in queue if n in self._plan),
                       key=lambda n: (self._plan[n][0], n))
        for n in known:
            start, gpus = self._plan[n]
            s = max(start, max(free[g] for g in gpus))
            end = s + max(self._plan_resid(n), _EPS)
            for g in gpus:
                free[g] = end
            mk = max(mk, end)
        new = [self._queue_spec(n, mk) for n in sorted(queue)
               if n not in self._plan]
        entries: Dict[str, Tuple[float, Tuple[int, ...]]] = {}
        if new:
            tail = lpt_schedule(new, self.G, free)
            for p in tail.placements:
                entries[p.task.name] = (p.start, p.gpu_ids)
            mk = max(mk, tail.makespan)
        return entries, mk

    def _replan(self, T: float) -> None:
        """Re-solve placement of the queue (arrived-pending plus announced
        future arrivals, release-constrained) over the projected skyline,
        then run the adoption rule: strict (never delay past a bound) when
        ``delay_delta`` is None, bounded-delay otherwise."""
        if self.migrate and self._running:
            self._rebalance(T)
        queue = sorted(self._pending) + sorted(self._future)
        if not queue:
            return
        self._replans += 1
        sky = self._proj_skyline(T)
        resid = [self._queue_spec(n, T) for n in queue]
        if self.fusion_planning:
            cand: Schedule = plan_fused(
                resid, self.G, sky, self._replica_states(T),
                self._fusion_profiles(queue, T), now=T,
                method=self.method, bnb_max_n=self.bnb_max_n)
        else:
            cand = solve_residual(resid, self.G, sky, self.method,
                                  self.bnb_max_n)
        if self.validate:
            cand.validate(self.G)
        delays = {p.task.name: p.start - self._bounds[p.task.name]
                  for p in cand.placements if p.task.name in self._bounds}
        max_delay = max(delays.values(), default=0.0)
        if max_delay <= _EPS:
            self._adopt(cand, T, reason="adopted")
            return
        # the fallback replay is only needed to price a delaying plan or to
        # place first-time names; strict batch mode with a fully planned
        # queue skips it entirely
        unplanned = any(n not in self._plan and n not in self._planned_fusions
                        for n in queue)
        if self.delay_delta is None and not unplanned:
            self._rejected += 1
            self._events.append(ProgressEvent(
                kind=EventKind.REPLAN, task="", time=T, reason="rejected",
                detail="would delay past static start"))
            return
        fb_entries, fb_mk = self._fallback_plan(queue, sky)
        win = fb_mk - cand.makespan
        if (self.delay_delta is not None
                and win >= self.delay_delta * max_delay - _EPS):
            self._adopt(cand, T, reason="adopted_bounded_delay",
                        detail=f"win={win:.3f} max_delay={max_delay:.3f}")
            return
        # regret fallback: keep incumbent entries, append new arrivals;
        # incumbent fusion assignments survive only while still applicable
        self._plan.update(fb_entries)
        for n, (start, _) in fb_entries.items():
            self._bounds.setdefault(n, start)
        self._planned_fusions = {
            n: h for n, h in self._planned_fusions.items()
            if n in self._pending and h in self._running}
        self._rejected += 1
        detail = ("would delay past static start" if self.delay_delta is None
                  else f"win={win:.3f} < delta*max_delay="
                       f"{self.delay_delta * max_delay:.3f}")
        self._events.append(ProgressEvent(
            kind=EventKind.REPLAN, task="", time=T, reason="rejected",
            detail=detail))

    def _adopt(self, cand: Schedule, T: float, reason: str,
               detail: str = "") -> None:
        old = Schedule(
            [Placement(self._by_name[n].spec, self._plan[n][0],
                       self._plan[n][1])
             for n in sorted(self._plan)], 0.0, False, 0.0)
        moved = sum(d.moved_earlier for d in diff_schedules(old, cand))
        # fusion-aware candidates assign some tasks to replica slots rather
        # than exclusive GPUs: those get a fusion assignment (applied at the
        # next _admit, re-checked against live capacity) instead of a plan
        # entry. Their bounds stay — fusing never starts past a bound.
        fused = dict(getattr(cand, "fused", {}) or {})
        for n in fused:
            self._plan.pop(n, None)
        self._planned_fusions = fused
        for p in cand.placements:
            name = p.task.name
            self._plan[name] = (p.start, p.gpu_ids)
            # a bound moves later only when the bounded-delay rule paid for
            # it; first-time names (arrivals) get their planned start
            prev = self._bounds.get(name)
            self._bounds[name] = p.start if prev is None else max(prev,
                                                                  p.start)
        self._adopted += 1
        self._events.append(ProgressEvent(
            kind=EventKind.REPLAN, task="", time=T, reason=reason,
            detail=detail or f"moved_earlier={moved}"))

    def _admit(self, T: float) -> None:
        """Start every pending task whose planned GPUs are free, in
        planned-start order; earlier-planned tasks reserve their GPUs
        so later tasks cannot cause priority inversion. With
        ``colocate=True``, tasks still pending afterwards (i.e. waiting
        for GPUs) are offered to live same-fuse-key replicas — the
        fuse-vs-exclusive decision: immediately placeable tasks place
        exclusively, blocked fusable tasks fuse."""
        if self.fusion_planning and self._planned_fusions:
            stale = self._apply_planned_fusions(T)
            if stale:
                # live capacity moved under the plan (host finished, budget
                # taken): drop the stale assignments and re-solve so those
                # names get exclusive placements (or a fresh fusion)
                self._replan(T)
        reserved: set = set()
        placeable = [n for n in self._pending if n in self._plan]
        for name in sorted(placeable, key=lambda n: (self._plan[n][0], n)):
            gpus = self._plan[name][1]
            if any(self._owner[g] is not None for g in gpus) or \
                    (set(gpus) & reserved):
                reserved.update(gpus)
                continue
            sub = self._by_name[name]
            resumed = name in self._suspended
            residual = max(self._plan_resid(name), _EPS)
            driver = self._guest_driver(name, T)
            run = _Running(spec=sub.spec, driver=driver, gpu_ids=gpus,
                           start=T, local_time=T, residual=residual)
            self._running[name] = run
            self._pending.discard(name)
            for g in gpus:
                self._owner[g] = name
            self._task_starts.setdefault(name, T)
            heapq.heappush(self._heap, (run.local_time, name))
            self._events.append(ProgressEvent(
                kind=EventKind.TASK_STARTED, task=name, time=T,
                detail=("resumed " if resumed else "")
                + f"gpus={','.join(map(str, gpus))}"))
        if self.colocate and self._pending and self._running:
            if self._try_fuse(T):
                # fused tasks left the queue: re-solve what remains and
                # admit anything the smaller plan makes placeable (the
                # recursion terminates — fusing strictly shrinks pending)
                self._replan(T)
                self._admit(T)

    def _try_fuse(self, T: float) -> bool:
        """Co-locate pending fusable tasks onto live replicas. A task may
        fuse onto a replica when (a) their fuse keys match (width-free
        since slots went ragged: arch/gpus/loss — mixed batch sizes and
        seq lens fuse), (b) §A.3 cross-task admission accepts it (slot
        headroom + token-linear memory model, greedy decreasing
        token-width across all pending small tasks), and
        (c) soundness: the task's residual fits inside the replica's
        projected end and the replica clock has not passed the task's
        incumbent start bound — so fusing never extends the replica's
        occupancy nor starts anyone later than the plan promised."""
        cands = [n for n in sorted(self._pending)
                 if self._by_name[n].colo is not None]
        fused_any = False
        for host in sorted(self._running):
            if not cands:
                break
            run = self._running[host]
            cap = self._by_name[host].colo
            if cap is None:
                continue
            ok = []
            for n in cands:
                c = self._by_name[n].colo
                if c.fuse_key != cap.fuse_key:
                    continue
                if self._plan_resid(n) > run.residual + _EPS:
                    continue                 # would extend the replica
                bound = self._bounds.get(n)
                if bound is not None and run.local_time > bound + _EPS:
                    continue                 # would start later than promised
                ok.append(n)
            if not ok:
                continue
            if not isinstance(run.driver, ColocatedReplicaDriver):
                run.driver = ColocatedReplicaDriver(
                    host, run.driver, cap,
                    elapsed=run.local_time - run.start)
            w = run.driver
            admitted = admit_cross_task(
                w.resident_requests(),
                [ColoRequest(n, self._by_name[n].colo.slots_needed,
                             self._by_name[n].colo.per_adapter_batch,
                             self._by_name[n].colo.seq_len,
                             self._by_name[n].colo.lora_rank)
                 for n in ok],
                cap.replica_slots, cap.mem)
            for n in admitted:
                self._fuse_attach(n, host, w, T)
                cands.remove(n)
                fused_any = True
        return fused_any

    def _fuse_attach(self, name: str, host: str,
                     w: ColocatedReplicaDriver, T: float) -> None:
        """Attach a pending task as a guest on a live replica. Preempted
        guests re-fuse with their suspended driver (progress intact)."""
        driver = self._guest_driver(name, T)
        w.attach(name, driver, self._by_name[name].colo)
        self._pending.discard(name)
        self._plan.pop(name, None)
        self._bounds.pop(name, None)
        self._planned_fusions.pop(name, None)
        self._hosted[name] = host
        self._task_starts.setdefault(name, T)
        self._events.append(ProgressEvent(
            kind=EventKind.TASK_FUSED, task=name, time=T,
            detail=f"host={host}"))

    def _apply_planned_fusions(self, T: float) -> List[str]:
        """Realize the adopted plan's fusion assignments against LIVE
        capacity. Every soundness guard the opportunistic path enforces is
        re-checked here (the plan was computed against projections that may
        have drifted): fuse-key match, residual fits inside the replica's
        post-refresh projected end, incumbent bound not passed, SS A.3+k2
        cross-task admission. Returns the assignments that no longer hold,
        which the caller drops and re-solves."""
        stale: List[str] = []
        for name in sorted(n for n in self._planned_fusions
                           if n in self._pending):
            host = self._planned_fusions[name]
            run = self._running.get(host)
            c = self._by_name[name].colo
            cap = self._by_name[host].colo if host in self._by_name else None
            if run is None or c is None or cap is None \
                    or c.fuse_key != cap.fuse_key:
                stale.append(name)
                continue
            if self._plan_resid(name) > run.residual + _EPS:
                stale.append(name)
                continue
            bound = self._bounds.get(name)
            if bound is not None and run.local_time > bound + _EPS:
                stale.append(name)
                continue
            if not isinstance(run.driver, ColocatedReplicaDriver):
                run.driver = ColocatedReplicaDriver(
                    host, run.driver, cap,
                    elapsed=run.local_time - run.start)
            w = run.driver
            req = ColoRequest(name, c.slots_needed, c.per_adapter_batch,
                              c.seq_len, c.lora_rank)
            if name not in admit_cross_task(w.resident_requests(), [req],
                                            cap.replica_slots, cap.mem):
                stale.append(name)
                continue
            self._fuse_attach(name, host, w, T)
        for n in stale:
            self._planned_fusions.pop(n, None)
        return stale

    # ------------------------------------------------------- rebalancing
    def _rebalance(self, T: float) -> None:
        """Slot-level preemption/migration: when a host's own queue regrew
        relative to the shrunken replica, a guest whose residual extends
        the replica past the host's OWN projected end is (a) migrated onto
        another same-fuse-key replica that completes it no later, or
        (b) preempted back to the pending queue when an exclusive restart
        completes it no later than staying put. Both moves free the
        replica's GPUs at the host's own end for the waiting queue without
        ever delaying the moved guest past its in-place projection, so the
        fusion-time bound (<= static makespan) survives every move. Runs
        only under queue pressure — with nothing waiting, an extended
        replica harms nobody."""
        if not (self._pending or self._future):
            return
        for host in sorted(self._running):
            run = self._running.get(host)
            if run is None or not isinstance(run.driver,
                                             ColocatedReplicaDriver):
                continue
            w = run.driver
            host_end = run.local_time + w.residual_of(host)
            for guest in sorted(w.hosted_names()):
                if w.end_of(guest) is not None:
                    continue                    # already finished in place
                g_res = w.residual_of(guest)
                stay_end = run.local_time + g_res
                if stay_end <= host_end + _EPS:
                    continue                    # guest doesn't extend replica
                dest = self._find_migration_dest(host, guest, g_res,
                                                 stay_end)
                if dest is not None:
                    self._migrate_guest(guest, host, dest, T)
                    continue
                if self._find_migration_dest(host, guest, g_res, stay_end,
                                             ignore_skew=True) is not None:
                    # a destination is viable except that its local clock
                    # runs ahead of the host's (chunk skew) — the no-delay
                    # guard will pass at the host's next chunk boundary, so
                    # hold the guest rather than preempt (preemption only
                    # reorders work on the same GPUs; migration removes it)
                    continue
                self._maybe_preempt(guest, host, run, g_res, stay_end, T)

    def _find_migration_dest(self, host: str, guest: str, g_res: float,
                             stay_end: float, *,
                             ignore_skew: bool = False) -> Optional[str]:
        """A live replica that can absorb the guest without extending its
        own occupancy, without delaying the guest past its in-place
        projection, and without the guest overhanging the destination
        owner's own end (else the move would just re-trigger there).
        ``ignore_skew`` drops the no-delay guard, answering "would a
        destination accept the guest once the clocks align?"."""
        c = self._by_name[guest].colo
        if c is None:
            return None
        for dest in sorted(self._running):
            if dest == host:
                continue
            drun = self._running[dest]
            cap = self._by_name[dest].colo
            if cap is None or cap.fuse_key != c.fuse_key:
                continue
            if g_res > drun.residual + _EPS:
                continue                 # would extend the destination
            if not ignore_skew and drun.local_time + g_res > stay_end + _EPS:
                continue                 # would delay the guest
            if isinstance(drun.driver, ColocatedReplicaDriver):
                if g_res > drun.driver.residual_of(dest) + _EPS:
                    continue             # would overhang the dest owner
                res = drun.driver.resident_requests()
            else:
                res = self._resident_requests_of(dest, drun)
            req = ColoRequest(guest, c.slots_needed, c.per_adapter_batch,
                              c.seq_len, c.lora_rank)
            if guest in admit_cross_task(res, [req], cap.replica_slots,
                                         cap.mem):
                return dest
        return None

    def _migrate_guest(self, guest: str, host: str, dest: str,
                       T: float) -> None:
        run = self._running[host]
        assert isinstance(run.driver, ColocatedReplicaDriver)
        driver = run.driver.detach(guest)
        self._refresh_residual(run)          # post-departure projected end
        drun = self._running[dest]
        cap = self._by_name[dest].colo
        if not isinstance(drun.driver, ColocatedReplicaDriver):
            drun.driver = ColocatedReplicaDriver(
                dest, drun.driver, cap,
                elapsed=drun.local_time - drun.start)
        drun.driver.attach(guest, driver, self._by_name[guest].colo)
        self._hosted[guest] = dest
        self._migrated_n += 1
        self._events.append(ProgressEvent(
            kind=EventKind.TASK_MIGRATED, task=guest, time=T,
            detail=f"{host}->{dest}"))

    def _maybe_preempt(self, guest: str, host: str, run: "_Running",
                       g_res: float, stay_end: float, T: float) -> None:
        """Evict the guest back to the queue only when an exclusive restart
        completes it no later than staying put (typically: GPUs freed since
        it fused). The evicted guest leaves with an incumbent plan entry at
        its projected restart, so subsequent replans can only move it
        earlier (strict mode) or must pay for any delay (bounded mode)."""
        w = run.driver
        assert isinstance(w, ColocatedReplicaDriver)
        sky = self._proj_skyline(T)
        # source GPUs free when the replica's REMAINING residents end
        others = [w.residual_of(x) for x in w.sub_names()
                  if x != guest and w.end_of(x) is None]
        rem_end = run.local_time + max(others, default=0.0)
        for g in run.gpu_ids:
            sky[g] = max(rem_end, T)
        gpus = self._by_name[guest].spec.gpus
        if gpus > len(sky):
            return
        order = sorted(range(self.G), key=lambda g: (sky[g], g))
        ids = tuple(sorted(order[:gpus]))
        start = max(max(sky[g] for g in ids), T)
        if start + g_res > stay_end + _EPS:
            return                           # restart would delay the guest
        driver = w.detach(guest)
        self._refresh_residual(run)
        self._hosted.pop(guest, None)
        self._suspended[guest] = _Suspended(driver=driver, residual=g_res)
        self._pending.add(guest)
        self._plan[guest] = (start, ids)
        self._bounds[guest] = start
        self._preempted_n += 1
        self._events.append(ProgressEvent(
            kind=EventKind.TASK_PREEMPTED, task=guest, time=T,
            detail=f"host={host} residual={g_res:.3f}"))

    # ---------------------------------------------------------- observability
    def annotate(self, event: ProgressEvent) -> None:
        """Append an out-of-band audit event (stamped at the current
        virtual time) to the log — e.g. the service's tune-to-serve hook
        recording an ``ADAPTER_PUBLISHED`` alongside the capacity trail."""
        self._events.append(event.stamped(self.now))

    @property
    def event_log(self) -> List[ProgressEvent]:
        return self._events

    @property
    def results_map(self) -> Dict[str, Any]:
        return self._results

    @property
    def task_start_times(self) -> Dict[str, float]:
        return self._task_starts

    @property
    def task_end_times(self) -> Dict[str, float]:
        return self._task_ends

    def is_cancelled(self, name: str) -> bool:
        return name in self._cancel_set

    # ---------------------------------------------------------- reporting
    def report(self) -> RuntimeReport:
        """Snapshot the session at idle (all admitted work drained)."""
        assert self._live, "begin() not called"
        assert not self._pending, f"unstarted tasks: {sorted(self._pending)}"
        makespan = max(self._task_ends.values(), default=0.0)
        schedule = Schedule(list(self._realized), makespan, optimal=False,
                            solve_time_s=0.0)
        if self.validate:
            schedule.validate(self.G)
        util = (sum(self._gpu_busy) / (self.G * makespan)
                if makespan > 0 else 0.0)
        return RuntimeReport(
            makespan=makespan, realized=schedule, events=list(self._events),
            replans=self._replans, plans_adopted=self._adopted,
            plans_rejected=self._rejected, gpu_busy=list(self._gpu_busy),
            utilization=util, results=dict(self._results),
            task_starts=dict(self._task_starts),
            task_ends=dict(self._task_ends),
            cancelled=tuple(sorted(self._cancel_set)),
            colocated=dict(self._hosted),
            preemptions=self._preempted_n,
            migrations=self._migrated_n,
            pod_kills=self._pod_kills)

    # ------------------------------------------------------------------ run
    def run(self, initial: Optional[Schedule] = None) -> RuntimeReport:
        """One-shot batch semantics: open the session, drain it, report."""
        self.begin(initial)
        while self.step():
            pass
        return self.report()


# --------------------------------------------------------------------------
# Static baseline: the same drivers, starts pinned to the precomputed plan
# --------------------------------------------------------------------------

@dataclasses.dataclass
class StaticReport:
    makespan: float
    realized: Schedule
    gpu_busy: List[float]
    utilization: float
    results: Dict[str, Any]
    task_starts: Dict[str, float]
    task_ends: Dict[str, float]

    def per_gpu_utilization(self) -> List[float]:
        mk = max(self.makespan, _EPS)
        return [b / mk for b in self.gpu_busy]


def execute_static(schedule: Schedule, G: int,
                   factories: Dict[str, Callable[[], TaskDriver]],
                   validate: bool = True) -> StaticReport:
    """Execute a Schedule literally: every task starts at its planned start
    (GPUs idle in between), actual durations come from draining the same
    drivers the elastic runtime would step. This is the A/B baseline the
    benchmarks compare against."""
    if validate:
        schedule.validate(G)
    realized: List[Placement] = []
    gpu_busy = [0.0] * G
    results: Dict[str, Any] = {}
    starts: Dict[str, float] = {}
    ends: Dict[str, float] = {}
    for p in schedule.placements:
        name = p.task.name
        driver = factories[name]()
        driver.start(p.start)
        dur = 0.0
        while True:
            chunk = driver.step_chunk()
            dur += chunk.dt
            if chunk.done:
                break
        results[name] = driver.result()
        starts[name] = p.start
        ends[name] = p.start + dur
        for g in p.gpu_ids:
            gpu_busy[g] += dur
        realized.append(Placement(
            dataclasses.replace(p.task, duration=dur), p.start, p.gpu_ids))
    makespan = max(ends.values(), default=0.0)
    sched = Schedule(realized, makespan, optimal=False, solve_time_s=0.0)
    if validate:
        sched.validate(G)
    util = (sum(gpu_busy) / (G * makespan)) if makespan > 0 else 0.0
    return StaticReport(makespan=makespan, realized=sched, gpu_busy=gpu_busy,
                        utilization=util, results=results,
                        task_starts=starts, task_ends=ends)


# --------------------------------------------------------------------------
# Simulated driver: the executor lifecycle in virtual time (no training)
# --------------------------------------------------------------------------

class SimulatedTaskDriver(TaskDriver):
    """Replays the BatchedExecutor lifecycle — warmup waves with rotation,
    Pattern-3 selection at the warmup boundary, continue-training with
    early exits and slot backfill — in virtual time. ``exit_step[j]`` makes
    job j exit (divergence/overfit stand-in) once it has trained that many
    steps; jobs without an entry train to ``total_steps``. Deterministic
    for fixed arguments, as the static baseline requires."""

    def __init__(self, name: str, *, K: int, Z: int, total_steps: int,
                 warmup_steps: int, step_time_s: float,
                 select_ratio: float = 0.25,
                 exit_step: Optional[Dict[int, int]] = None,
                 chunk_steps: int = 5):
        assert K >= 1 and Z >= 1 and total_steps >= 1
        self.name = name
        self.K = K
        self.Z = Z
        self.total_steps = total_steps
        self.warmup_steps = max(min(warmup_steps, total_steps), 1)
        self.step_time_s = step_time_s
        self.select_ratio = select_ratio
        self.exit_step = dict(exit_step or {})
        self.chunk_steps = max(chunk_steps, 1)
        # single source of truth for the Pattern-3 rounding rule: the same
        # EarlyExitConfig.top_k the real executor's warmup_select uses
        self.top_k = EarlyExitConfig(select_ratio=select_ratio).top_k(K)
        # lifecycle state
        self._trained = [0] * K
        self._exited: Dict[int, str] = {}
        self._waves = [list(range(i, min(i + Z, K)))
                       for i in range(0, K, Z)]
        self._wave_idx = 0
        self._wave_left = self.warmup_steps
        self._phase = "warmup"
        self._active: List[int] = []
        self._queue: List[int] = []
        self._done = False

    # -- helpers -----------------------------------------------------------
    def _alive(self, jobs: Sequence[int]) -> List[int]:
        return [j for j in jobs if j not in self._exited]

    def start(self, now: float) -> None:
        pass

    def _job_events(self, jobs: Sequence[int]) -> List[ProgressEvent]:
        out = []
        for j in jobs:
            tgt = self.exit_step.get(j)
            if tgt is not None and self._trained[j] >= tgt \
                    and j not in self._exited:
                self._exited[j] = "diverging"
                out.append(ProgressEvent(
                    kind=EventKind.JOB_EXITED, task=self.name,
                    job=f"{self.name}/j{j}", reason="diverging",
                    step=self._trained[j]))
            elif self._trained[j] >= self.total_steps \
                    and j not in self._exited:
                self._exited[j] = "completed"
                out.append(ProgressEvent(
                    kind=EventKind.JOB_EXITED, task=self.name,
                    job=f"{self.name}/j{j}", reason="completed",
                    step=self._trained[j]))
        return out

    def step_chunk(self) -> DriverChunk:
        assert not self._done
        ev: List[ProgressEvent] = []
        if self._phase == "warmup":
            wave = self._alive(self._waves[self._wave_idx])
            n = min(self.chunk_steps, self._wave_left)
            self._wave_left -= n
            for j in wave:
                self._trained[j] += n
            ev += self._job_events(wave)
            if self._wave_left == 0:
                self._wave_idx += 1
                self._wave_left = self.warmup_steps
                if self._wave_idx >= len(self._waves):
                    ev += self._select()
            return DriverChunk(dt=n * self.step_time_s, events=tuple(ev))
        # continue phase
        self._active = self._alive(self._active)
        while len(self._active) < self.Z and self._queue:
            self._active.append(self._queue.pop(0))
        if not self._active:
            self._done = True
            ev.append(ProgressEvent(
                kind=EventKind.TASK_COMPLETED, task=self.name))
            return DriverChunk(dt=0.0, events=tuple(ev), done=True)
        # clamp the chunk to the next per-job event boundary (budget or
        # early exit) so no job overshoots total_steps — the real executor
        # evicts at the exact step, and the worst-case duration estimate
        # must stay an upper bound on the realized duration
        n = self.chunk_steps
        for j in self._active:
            nxt = min(self.exit_step.get(j, self.total_steps),
                      self.total_steps)
            n = min(n, max(nxt - self._trained[j], 1))
        for j in self._active:
            self._trained[j] += n
        ev += self._job_events(self._active)
        self._active = self._alive(self._active)
        return DriverChunk(dt=n * self.step_time_s, events=tuple(ev))

    def _select(self) -> List[ProgressEvent]:
        self._phase = "continue"
        alive = self._alive(range(self.K))
        kept, dropped = alive[:self.top_k], alive[self.top_k:]
        for j in dropped:
            self._exited[j] = "underperforming"
        self._active = kept[:self.Z]
        self._queue = kept[self.Z:]
        if dropped:
            return [ProgressEvent(
                kind=EventKind.WARMUP_SELECTION, task=self.name,
                reason="underperforming", step=self.warmup_steps,
                dropped=tuple(f"{self.name}/j{j}" for j in dropped))]
        return []

    def residual_estimate(self) -> float:
        if self._done:
            return 0.0
        cont_budget = self.total_steps - self.warmup_steps
        if self._phase == "warmup":
            waves_left = len(self._waves) - self._wave_idx - 1
            surv = min(self.top_k, self.K - sum(
                1 for r in self._exited.values() if r != "completed"))
            surv = max(surv, 0)
            cont = -(-surv // self.Z) * cont_budget if surv else 0
            steps = self._wave_left + waves_left * self.warmup_steps + cont
        else:
            alive = self._alive(self._active) + self._alive(self._queue)
            if not alive:
                steps = 0
            else:
                rem = max(self.total_steps - self._trained[j] for j in alive)
                steps = -(-len(alive) // self.Z) * max(rem, 0)
        return steps * self.step_time_s

    def slots_bound(self) -> Optional[int]:
        """Upper bound on future concurrent slot use — shrinks as waves
        drain and jobs exit, which is the capacity cross-task co-location
        reclaims."""
        if self._done:
            return 0
        cont = min(self.Z, self.top_k)
        if self._phase == "warmup":
            alive_waves = [len(self._alive(w))
                           for w in self._waves[self._wave_idx:]]
            return max(alive_waves + [cont])
        return min(self.Z,
                   len(self._alive(self._active) + self._alive(self._queue)))

    def result(self) -> Dict[str, Any]:
        return {"task": self.name,
                "steps_trained": int(sum(self._trained)),
                "exit_reasons": {f"{self.name}/j{j}": r
                                 for j, r in sorted(self._exited.items())}}


def sim_task_spec(name: str, *, K: int, Z: int, total_steps: int,
                  warmup_steps: int, step_time_s: float, gpus: int,
                  select_ratio: float = 0.25) -> TaskSpec:
    """Worst-case (no pattern exits) duration estimate for a simulated
    task — identical to what the profiler computes for real tasks."""
    from repro.sched import profiler
    warmup = max(min(warmup_steps, total_steps), 1)
    top_k = EarlyExitConfig(select_ratio=select_ratio).top_k(K)
    steps = profiler.lifecycle_steps(K, Z, warmup, total_steps,
                                     survivors=top_k)
    return TaskSpec(name=name, duration=steps * step_time_s, gpus=gpus)


def sim_colo_spec(fuse_key: Tuple, *, K: int, Z: int,
                  per_adapter_batch: int = 4,
                  replica_slots: Optional[int] = None,
                  mem: Optional[MemoryModel] = None,
                  seq_len: Optional[int] = None,
                  lora_rank: Optional[int] = None) -> ColocationSpec:
    """ColocationSpec for a simulated task: it needs at most min(Z, K)
    concurrent slots, and a replica it hosts exposes ``replica_slots``
    physical slots (defaults to its own Z). ``fuse_key`` is the caller's
    choice — ragged admission only needs (arch, gpus, loss)-level keys;
    width enters through per_adapter_batch/seq_len token accounting and
    ``lora_rank`` (the task's true adapter rank) through the rank-aware
    FLOP-token budget; ``lora_rank=None`` is charged at r_max."""
    return ColocationSpec(
        fuse_key=fuse_key, per_adapter_batch=per_adapter_batch,
        slots_needed=min(Z, K),
        replica_slots=replica_slots if replica_slots is not None else Z,
        mem=mem, seq_len=seq_len, lora_rank=lora_rank)


# --------------------------------------------------------------------------
# Real-executor driver (engine integration)
# --------------------------------------------------------------------------

class ExecutorTaskDriver(TaskDriver):
    """Wraps BatchedExecutor.run_task_chunks: chunk steps convert to
    virtual seconds via the profiled step time, and each ChunkReport's
    remaining_steps_bound provides the residual estimate.

    Training is drained eagerly at ``start()`` and the chunk/event timeline
    replayed to the runtime. Tasks don't interact and cluster time is
    virtual, so the replay is observationally identical to live stepping —
    but only ONE executor (slot params, optimizer state, snapshots) is
    resident at a time instead of one per concurrently-scheduled task."""

    def __init__(self, name: str, executor, jobs, total_steps: int,
                 step_time_s: float, resume_state=None, start_chunk: int = 0):
        self.name = name
        self.executor = executor
        self.jobs = jobs
        self.total_steps = total_steps
        self.step_time_s = step_time_s
        # durable-recovery path: a (tree, meta) lifecycle checkpoint from
        # checkpoint/taskstate.py — start() then continues the task from
        # its exact saved step instead of from zero
        self.resume_state = resume_state
        self.start_chunk = start_chunk
        self._chunks: List[DriverChunk] = []
        self._bounds: List[int] = []
        self._slot_bounds: List[int] = []
        self._result = None
        self._last_bound: Optional[int] = None
        self._last_slots: Optional[int] = None
        self._wall_s = 0.0
        self._steps = 0
        self._tokens = 0

    def start(self, now: float) -> None:
        if self.resume_state is not None:
            gen = self.executor.resume_task_chunks(
                self.name, self.jobs, self.total_steps, self.resume_state,
                start_chunk=self.start_chunk)
        else:
            gen = self.executor.run_task_chunks(
                self.name, self.jobs, self.total_steps)
        while True:
            try:
                report = next(gen)
            except StopIteration as fin:
                self._result = fin.value
                break
            self._chunks.append(DriverChunk(
                dt=report.steps_executed * self.step_time_s,
                events=report.events, done=False))
            self._bounds.append(report.remaining_steps_bound)
            self._slot_bounds.append(report.slots_bound)
            self._wall_s += report.wall_time_s
            self._steps += report.steps_executed
            self._tokens += report.tokens_executed
        assert self._chunks, "executor produced no chunks"
        # completion events ride the final chunk so the runtime replans
        # exactly once, with the GPUs actually freed
        self._chunks[-1] = dataclasses.replace(self._chunks[-1], done=True)
        self.executor = None            # release slot/opt state eagerly

    def step_chunk(self) -> DriverChunk:
        assert self._chunks is not None and self._chunks, "start() not called"
        chunk = self._chunks.pop(0)
        self._last_bound = self._bounds.pop(0)
        self._last_slots = self._slot_bounds.pop(0)
        return chunk

    def residual_estimate(self) -> float:
        if self._last_bound is None:        # not stepped yet: no information
            return float("inf")             # runtime clamps to spec duration
        return self._last_bound * self.step_time_s

    def slots_bound(self) -> Optional[int]:
        return self._last_slots

    def observed_wall_step_s(self) -> Optional[float]:
        """Realized host seconds per executor step (profiler feedback)."""
        return self._wall_s / self._steps if self._steps else None

    def observed_wall_token_s(self) -> Optional[float]:
        """Realized host seconds per REAL token (padding excluded). With
        ragged slot widths this is the calibrated feedback quantity — two
        chunks with equal step counts can differ 4x in token throughput,
        so per-step wall time alone would mis-estimate heterogeneous
        mixes."""
        return self._wall_s / self._tokens if self._tokens else None

    def result(self):
        return self._result
