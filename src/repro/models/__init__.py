"""Model zoo substrate: unified multi-adapter decoder over 6 families."""
