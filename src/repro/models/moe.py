"""Mixture-of-Experts with grouped GShard-style capacity dispatch.

TPU-native token-choice routing: tokens are split into groups (so the
one-hot dispatch/combine tensors stay [G, s, E, c] with small per-group
capacity ``c`` instead of an infeasible [T, E, C]); expert weights are
sharded on the "model" mesh axis (expert parallelism) and GSPMD inserts the
all-to-all at the dispatch/combine einsums. Routed experts are FROZEN under
ALTO (LoRA attaches to attention projections for MoE archs); the router and
experts still run in fwd/bwd, and the load-balance auxiliary loss is
reported so early-exit sees honest training dynamics.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.common import he_init, swiglu
from repro.models.shardctx import constrain, get_hint


def pick_group_size(num_tokens: int, lo: int = 128, hi: int = 4096) -> int:
    """Largest power-of-two group size in [lo, hi] dividing num_tokens."""
    g = 1
    t = num_tokens
    while t % 2 == 0 and g < hi:
        g *= 2
        t //= 2
    if g < lo:
        return num_tokens if num_tokens <= hi else g
    return min(g, hi)


def init_moe_params(key, d_model: int, moe: MoEConfig, dtype) -> Dict:
    ks = jax.random.split(key, 5)
    E, ff = moe.num_experts, moe.d_ff_expert
    p = {
        "router": he_init(ks[0], (d_model, E), d_model, jnp.float32),
        "w_gate": he_init(ks[1], (E, d_model, ff), d_model, dtype),
        "w_up": he_init(ks[2], (E, d_model, ff), d_model, dtype),
        "w_down": he_init(ks[3], (E, ff, d_model), ff, dtype),
    }
    if moe.num_shared_experts:
        ffs = moe.d_ff_shared * moe.num_shared_experts
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "gate": he_init(kk[0], (d_model, ffs), d_model, dtype),
            "up": he_init(kk[1], (d_model, ffs), d_model, dtype),
            "down": he_init(kk[2], (ffs, d_model), ffs, dtype),
        }
    return p


def moe_block(x: jnp.ndarray, params: Dict, moe: MoEConfig
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [Z, b, S, d] -> (out [Z, b, S, d], aux_loss scalar fp32).

    Grouped token-choice top-k with static per-group capacity.
    """
    Z, b, S, d = x.shape
    dt = x.dtype
    E, k = moe.num_experts, moe.top_k
    T = Z * b * S
    s = pick_group_size(T)
    G = T // s
    if s <= 64:
        # tiny groups (decode steps, smoke tests): lossless capacity so the
        # decode path is numerically identical to the full-sequence path
        cap = s * k
    else:
        cap = max(int(moe.capacity_factor * s * k / E), 1)

    xt = x.reshape(G, s, d)
    if get_hint("opt_level", 0) >= 1:
        # groups factor as (Z-blocks, b-blocks, seq-chunks): shard G over
        # the data AND pod axes jointly so the [G,s,d] token slab (20 GiB
        # at production shapes) never replicates
        xt = constrain(xt, "dims:data+pod")
    logits = jnp.einsum("gsd,de->gse", xt.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)                      # [G,s,E]
    gate_vals, expert_idx = jax.lax.top_k(probs, k)              # [G,s,k]
    # normalize selected gates (token-choice convention)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # ---- load-balance auxiliary loss (per group, averaged)
    me = jnp.mean(probs, axis=1)                                 # [G,E]
    onehot_top1 = jax.nn.one_hot(expert_idx[..., 0], E)
    ce = jnp.mean(onehot_top1, axis=1)                           # [G,E]
    aux = E * jnp.mean(jnp.sum(me * ce, axis=-1))

    # ---- position within expert (capacity enforcement), per k-choice
    sel = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)         # [G,s,k,E]
    # flatten (s,k) in priority order: earlier tokens & lower k first
    sel_flat = sel.reshape(G, s * k, E)
    pos = jnp.cumsum(sel_flat, axis=1) - sel_flat                # [G,s*k,E]
    pos = jnp.sum(pos * sel_flat, axis=-1).reshape(G, s, k)      # [G,s,k]
    keep = pos < cap
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    # ---- dispatch / combine one-hots  [G, s, k, E, cap] -> reduce k
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, cap), cap)      # [G,s,k,cap]
    dispatch = jnp.einsum("gske,gskc->gsec",
                          sel.astype(jnp.float32), pos_oh)       # [G,s,E,cap]
    combine = jnp.einsum("gsk,gske,gskc->gsec", gate_vals,
                         sel.astype(jnp.float32), pos_oh)
    if get_hint("opt_level", 0) >= 1:
        # the one-hot dispatch/combine tensors are the MoE peak-memory term
        # at production token counts: shard groups over data+pod and
        # experts over "model" so no device holds a [G,s,E,cap] slab (§Perf)
        dispatch = constrain(dispatch, "dims:data+pod,-,model")
        combine = constrain(combine, "dims:data+pod,-,model")

    w_gate = constrain(params["w_gate"], "weight:w_gate")
    w_up = constrain(params["w_up"], "weight:w_up")
    w_down = constrain(params["w_down"], "weight:w_down")
    expert_in = jnp.einsum("gsec,gsd->egcd", dispatch.astype(dt), xt)
    expert_in = constrain(expert_in, "moe_expert")
    h = swiglu(jnp.einsum("egcd,edf->egcf", expert_in, w_gate),
               jnp.einsum("egcd,edf->egcf", expert_in, w_up))
    expert_out = jnp.einsum("egcf,efd->egcd", h, w_down)
    expert_out = constrain(expert_out, "moe_expert")
    out = jnp.einsum("gsec,egcd->gsd", combine.astype(dt), expert_out)
    if get_hint("opt_level", 0) >= 1:
        out = constrain(out, "dims:data+pod")

    if "shared" in params:
        sh = params["shared"]
        hs = swiglu(
            jnp.einsum("gsd,df->gsf", xt,
                       constrain(sh["gate"], "weight:shared/gate")),
            jnp.einsum("gsd,df->gsf", xt,
                       constrain(sh["up"], "weight:shared/up")))
        out = out + jnp.einsum("gsf,fd->gsd", hs,
                               constrain(sh["down"], "weight:shared/down"))

    return out.reshape(Z, b, S, d), aux.astype(jnp.float32)
