"""RWKV-6 "Finch" block: time-mix with data-dependent decay + channel-mix.

[arXiv:2404.05892] Per-layer structure:
  time-mix : token-shift lerp feeds r/k/v/g projections and a *data-dependent*
             per-channel decay w_t = exp(-exp(w0 + tanh(x w1) w2)); the WKV
             recurrence runs through the shared chunked linear-scan core with
             current-token bonus ``u``; output gated by silu(g) and per-head
             group-norm, then o_proj.
  channel-mix: token-shift lerp, squared-ReLU MLP (ffn_k -> relu^2 -> ffn_v).

LoRA targets: r/k/v/g/o projections + ffn_k/ffn_v (the "all projections"
rule of the paper, translated to the attention-free family).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.lora import proj
from repro.models.common import he_init, normal_init, silu
from repro.models.linear_scan import (chunked_linear_attention,
                                      linear_attention_decode_step)

DECAY_LORA_DIM = 64


def rwkv_target_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, int]]:
    d = cfg.d_model
    return {
        "r_proj": (d, d), "k_proj": (d, d), "v_proj": (d, d),
        "g_proj": (d, d), "o_proj": (d, d),
        "ffn_k": (d, cfg.d_ff), "ffn_v": (cfg.d_ff, d),
    }


def init_rwkv_layer(key, cfg: ModelConfig, dtype) -> Dict:
    d, ff = cfg.d_model, cfg.d_ff
    H = cfg.num_heads
    hs = cfg.ssm.head_size
    ks = jax.random.split(key, 12)
    return {
        "tm_norm": jnp.ones((d,), jnp.float32),
        "cm_norm": jnp.ones((d,), jnp.float32),
        # token-shift mix coefficients (per-channel, for r/k/v/g/w and ffn)
        "mu": 0.5 * jnp.ones((5, d), jnp.float32),
        "mu_ffn": 0.5 * jnp.ones((d,), jnp.float32),
        "r_proj": he_init(ks[0], (d, d), d, dtype),
        "k_proj": he_init(ks[1], (d, d), d, dtype),
        "v_proj": he_init(ks[2], (d, d), d, dtype),
        "g_proj": he_init(ks[3], (d, d), d, dtype),
        "o_proj": he_init(ks[4], (d, d), d, dtype),
        # data-dependent decay: w0 + tanh(x w1) w2  (low-rank, fp32)
        "w0": -1.0 + normal_init(ks[5], (d,), 0.3, jnp.float32),
        "w1": normal_init(ks[6], (d, DECAY_LORA_DIM), 0.02, jnp.float32),
        "w2": normal_init(ks[7], (DECAY_LORA_DIM, d), 0.02, jnp.float32),
        "u": normal_init(ks[8], (H, hs), 0.3, jnp.float32),   # bonus
        "ln_x": jnp.ones((d,), jnp.float32),                  # per-head norm
        "ffn_k": he_init(ks[9], (d, ff), d, dtype),
        "ffn_v": he_init(ks[10], (ff, d), ff, dtype),
    }


def _token_shift(x: jnp.ndarray, prev: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Shifted-by-one sequence: [Z,b,S,d] -> prev token at each position."""
    shifted = jnp.pad(x, [(0, 0), (0, 0), (1, 0), (0, 0)])[:, :, :-1]
    if prev is not None:   # decode continuation: position 0 = carried state
        shifted = shifted.at[:, :, 0].set(prev)
    return shifted


def _heads(x: jnp.ndarray, H: int, hs: int) -> jnp.ndarray:
    return x.reshape(*x.shape[:-1], H, hs)


def rwkv_time_mix(x: jnp.ndarray, p: Dict, lora: Dict, cfg: ModelConfig, *,
                  prev_x: Optional[jnp.ndarray] = None,
                  state: Optional[jnp.ndarray] = None,
                  scale=2.0) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Time-mix over a sequence. x: [Z,b,S,d].

    returns (out, final_wkv_state [Z,b,H,hs,hs], last_x [Z,b,d])
    """
    Z, b, S, d = x.shape
    H, hs = cfg.num_heads, cfg.ssm.head_size
    xx = _token_shift(x, prev_x)
    mu = p["mu"].astype(x.dtype)
    xr, xk, xv, xg, xw = (x + (xx - x) * mu[i] for i in range(5))

    lp = lambda t: (lora[t]["A"], lora[t]["B"]) if t in lora else None
    r = _heads(proj(xr, p["r_proj"], lp("r_proj"), scale, name="r_proj"), H, hs)
    k = _heads(proj(xk, p["k_proj"], lp("k_proj"), scale, name="k_proj"), H, hs)
    v = _heads(proj(xv, p["v_proj"], lp("v_proj"), scale, name="v_proj"), H, hs)
    g = proj(xg, p["g_proj"], lp("g_proj"), scale, name="g_proj")

    # data-dependent decay (fp32): logw = -exp(w0 + tanh(xw w1) w2) in (-inf,0)
    xwf = xw.astype(jnp.float32)
    dd = jnp.tanh(xwf @ p["w1"]) @ p["w2"]
    logw = -jnp.exp(jnp.clip(p["w0"] + dd, -8.0, 4.0))
    logw = _heads(logw, H, hs)

    if S == 1 and state is not None:
        y, new_state = linear_attention_decode_step(
            r[:, :, 0], k[:, :, 0], v[:, :, 0], logw[:, :, 0], state,
            bonus=p["u"], decay_on_query=False)
        y = y[:, :, None]
    else:
        y, new_state = chunked_linear_attention(
            r, k, v, logw, bonus=p["u"], decay_on_query=False,
            initial_state=state, chunk=cfg.ssm.chunk_size)

    # per-head group norm, gate, output projection
    yf = y.astype(jnp.float32)
    mean = jnp.mean(yf, axis=-1, keepdims=True)
    var = jnp.var(yf, axis=-1, keepdims=True)
    yn = (yf - mean) * jax.lax.rsqrt(var + 1e-5)
    yn = (yn.reshape(Z, b, S, d) * p["ln_x"]).astype(x.dtype)
    out = proj(yn * silu(g), p["o_proj"], lp("o_proj"), scale, name="o_proj")
    return out, new_state, x[:, :, -1]


def rwkv_channel_mix(x: jnp.ndarray, p: Dict, lora: Dict, cfg: ModelConfig, *,
                     prev_x: Optional[jnp.ndarray] = None,
                     scale=2.0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    xx = _token_shift(x, prev_x)
    xk = x + (xx - x) * p["mu_ffn"].astype(x.dtype)
    lp = lambda t: (lora[t]["A"], lora[t]["B"]) if t in lora else None
    k = proj(xk, p["ffn_k"], lp("ffn_k"), scale, name="ffn_k")
    k = jnp.square(jax.nn.relu(k))
    return proj(k, p["ffn_v"], lp("ffn_v"), scale, name="ffn_v"), x[:, :, -1]
