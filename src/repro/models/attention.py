"""GQA attention: chunked (flash-style) causal training path + decode path.

The training/prefill path never materializes the full [Sq, Sk] score matrix:
it scans over query chunks, computing fp32 softmax per chunk.

Sharding-aware layout selection (opt_level >= 1, driven by shardctx hints):
GSPMD produces pathological reshards when q is head-sharded while k falls
back to head-dim sharding (GQA with KV % model_axis != 0) — fp32 score
tensors get all-gathered/psummed across the model axis. We pick ONE
consistent layout per (H, KV, mesh):

  grouped  KV % m == 0 : grouped-query einsum, KV sharded everywhere;
                         scores/probs fully local.
  repeat   H  % m == 0 : repeat KV to H, shard H everywhere; probs local
                         (costs G x KV memory, sharded /m).
  kshard   otherwise   : shard Sk (keys/values/probs); distributed softmax
                         (tiny max/denominator psums) + one out-psum per
                         chunk — ring-attention-style.

Baseline (opt_level 0) keeps the original grouped einsum with generic
constraints, reproducing the paper-faithful-but-unoptimized lowering.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import causal_mask_bias
from repro.models.shardctx import constrain, get_hint


def _gqa_scores(q, k):
    """q: [Z,b,qc,KV,G,hd], k: [Z,b,Sk,KV,hd] -> [Z,b,KV,G,qc,Sk] fp32."""
    return jnp.einsum("zbqkgh,zbskh->zbkgqs", q, k,
                      preferred_element_type=jnp.float32)


def _gqa_combine(p, v):
    """p: [Z,b,KV,G,qc,Sk], v: [Z,b,Sk,KV,hd] -> [Z,b,qc,KV,G,hd]."""
    return jnp.einsum("zbkgqs,zbskh->zbqkgh", p.astype(v.dtype), v)


def _softmax_chunk(scores: jnp.ndarray, bias: jnp.ndarray) -> jnp.ndarray:
    s = scores + bias
    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.maximum(m, -1e30)  # rows that are fully masked
    e = jnp.exp(s - m)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    return e / jnp.maximum(denom, 1e-30)


def _dims(x, *axes):
    """Constrain with an explicit per-dim axis assignment (policy-checked
    divisibility; silently drops non-dividing axes)."""
    return constrain(x, "dims:" + ",".join(a or "-" for a in axes))


def _pick_mode(H: int, KV: int) -> str:
    if get_hint("opt_level", 0) < 1:
        return "baseline"
    m = get_hint("model_size", 0) or 0
    if m <= 1:
        return "baseline"
    if KV % m == 0:
        return "grouped"
    if H % m == 0:
        return "repeat"
    return "kshard"


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              q_pos: jnp.ndarray, k_pos: jnp.ndarray, *,
              window: int = 0, q_chunk: int = 512,
              kv_valid_len: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Causal GQA attention.

    q:      [Z, b, Sq, H, hd]
    q_pos:  [Sq] — or [Z, b, Sq] for PER-LANE positions (each (Z, b)
            decode stream carries its own absolute position, the
            continuous-batching cache layout)
    k, v:   [Z, b, Sk, KV, hd]   (H = KV * G)
    k_pos:  [Sk] absolute positions, or [Z, b, Sk] per lane (ring caches
            whose lanes wrap independently)
    window: sliding window size (0 = full causal)
    kv_valid_len: optional scalar — or [Z, b] per lane — keys at
            index >= len are masked
    returns [Z, b, Sq, H, hd]

    When any of q_pos / k_pos / kv_valid_len carries lane dims the bias
    is built per lane ([Z, b, Sq, Sk]) so an idle or freshly-joined
    lane's stale K/V is never visible to that lane's queries — and lanes
    never read each other's K/V at all (the batch dims are independent).
    """
    Z, b, Sq, H, hd = q.shape
    KV = k.shape[3]
    assert H % KV == 0, (H, KV)
    G = H // KV
    scale = hd ** -0.5

    # hand-kernel path: contiguous causal training/prefill (q_pos/k_pos are
    # plain suffix-aligned ranges, no partially filled cache)
    from repro.models import backend as BK
    if (BK.use_pallas() and Sq > 1 and kv_valid_len is None
            and Sq == q_pos.shape[0] and k.shape[2] == k_pos.shape[0]
            and k.shape[2] == Sq):   # pure causal (no longer/ring cache)
        from repro.kernels.flash_attention import ops as FA
        Sk = k.shape[2]
        kk = jnp.repeat(k, G, axis=3) if G > 1 else k
        vv = jnp.repeat(v, G, axis=3) if G > 1 else v
        qf = q.transpose(0, 1, 3, 2, 4).reshape(Z * b * H, Sq, hd)
        kf = kk.transpose(0, 1, 3, 2, 4).reshape(Z * b * H, Sk, hd)
        vf = vv.transpose(0, 1, 3, 2, 4).reshape(Z * b * H, Sk, hd)
        bq = min(256, Sq)
        while Sq % bq:
            bq //= 2
        bk = min(512, Sk)
        while Sk % bk:
            bk //= 2
        out = FA.flash_attention(qf, kf, vf, causal=True, window=window,
                                 bq=bq, bk=bk,
                                 interpret=BK.interpret_mode())
        return out.reshape(Z, b, H, Sq, hd).transpose(0, 1, 3, 2, 4)

    mode = _pick_mode(H, KV)
    kv_index = jnp.arange(k.shape[2], dtype=jnp.int32)
    vlen = None if kv_valid_len is None else jnp.asarray(kv_valid_len)

    def bias_for(pos_c):
        bias = causal_mask_bias(pos_c, k_pos, window)
        if vlen is not None:
            if vlen.ndim:                       # per-lane [Z, b]
                bias = bias + jnp.where(
                    kv_index < vlen[..., None, None], 0.0, -jnp.inf)
            else:
                bias = bias + jnp.where(kv_index[None, :] < vlen,
                                        0.0, -jnp.inf)
        return bias

    def headed(bias, n_head_dims):
        """Insert broadcast head dims into a per-lane [Z, b, Sq, Sk] bias
        so it lines up with [Z, b, <heads...>, Sq, Sk] scores; a plain
        [Sq, Sk] bias already broadcasts from the trailing dims."""
        if bias.ndim == 2:
            return bias
        for _ in range(n_head_dims):
            bias = bias[:, :, None]
        return bias

    if mode == "repeat":
        k = _dims(jnp.repeat(k, G, axis=3), "data", "pod", None, "model")
        v = _dims(jnp.repeat(v, G, axis=3), "data", "pod", None, "model")
        q = _dims(q * scale, "data", "pod", None, "model")

        def chunk_attn(q_c, pos_c):
            scores = jnp.einsum("zbqhd,zbshd->zbhqs", q_c, k,
                                preferred_element_type=jnp.float32)
            scores = _dims(scores, "data", "pod", "model")
            p = _softmax_chunk(scores, headed(bias_for(pos_c), 1))
            out = jnp.einsum("zbhqs,zbshd->zbqhd", p.astype(v.dtype), v)
            return _dims(out, "data", "pod", None, "model")

        reshape_out = False
    elif mode == "kshard":
        # shard keys/values (and therefore scores/probs) along Sk
        k = _dims(k, "data", "pod", "model")
        v = _dims(v, "data", "pod", "model")
        q = _dims(q * scale, "data", "pod")   # replicated over model
        q = q.reshape(Z, b, Sq, KV, G, hd)

        def chunk_attn(q_c, pos_c):
            scores = _gqa_scores(q_c, k)
            scores = _dims(scores, "data", "pod", None, None, None, "model")
            p = _softmax_chunk(scores, headed(bias_for(pos_c), 2))
            out = _gqa_combine(p, v)          # psum over model (Sk shards)
            return _dims(out, "data", "pod")

        reshape_out = True
    else:
        # grouped (baseline + opt grouped): KV-sharded when it divides
        q = (q * scale).reshape(Z, b, Sq, KV, G, hd)
        if mode == "grouped":
            q = _dims(q, "data", "pod", None, "model")
            k = _dims(k, "data", "pod", None, "model")
            v = _dims(v, "data", "pod", None, "model")

        def chunk_attn(q_c, pos_c):
            scores = _gqa_scores(q_c, k)
            if mode == "grouped":
                scores = _dims(scores, "data", "pod", "model")
            p = _softmax_chunk(scores, headed(bias_for(pos_c), 2))
            return _gqa_combine(p, v)

        reshape_out = True

    if Sq <= q_chunk:
        out = chunk_attn(q, q_pos)
    else:
        assert q_pos.ndim == 1, "per-lane q_pos is single-chunk (decode)"
        assert Sq % q_chunk == 0, (Sq, q_chunk)
        n = Sq // q_chunk
        qs = jnp.moveaxis(
            q.reshape(Z, b, n, q_chunk, *q.shape[3:]), 2, 0)
        ps = q_pos.reshape(n, q_chunk)

        def body(_, inp):
            q_c, pos_c = inp
            return None, chunk_attn(q_c, pos_c)

        if get_hint("opt_level", 0) >= 2:
            # don't stack per-chunk fp32 score tensors as scan residuals —
            # recompute them in the backward (flash-attention semantics)
            body = jax.checkpoint(body, prevent_cse=False)
        _, outs = jax.lax.scan(body, None, (qs, ps))
        out = jnp.moveaxis(outs, 0, 2)
        out = out.reshape(Z, b, Sq, *out.shape[4:])

    if reshape_out:
        out = out.reshape(Z, b, Sq, H, hd)
    if mode == "baseline":
        out = constrain(out, "attn_qkv")
    return out
