"""Sharding-hint context.

Model code is written once; distribution is injected by the launcher through
this context. ``constrain(x, kind)`` applies a
``jax.lax.with_sharding_constraint`` chosen by the active policy (or is a
no-op in single-device tests). Policies are divisibility-aware: a constraint
whose sharded dim does not divide by the mesh axis size silently degrades to
replicated on that dim (e.g. hymba's 25 heads on a 16-way model axis).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Callable, Optional

import jax

_state = threading.local()


def _policy() -> Optional[Callable]:
    return getattr(_state, "policy", None)


def get_hint(name: str, default=None):
    """Policy-supplied tracing hints (e.g. 'model_size', 'opt_level')."""
    hints = getattr(_state, "hints", None)
    if hints is None:
        return default
    return hints.get(name, default)


def constrain(x: jax.Array, kind: str) -> jax.Array:
    """Annotate activation ``x`` with the sharding for logical role ``kind``.

    kinds used by the model code:
      residual      [Z, b, S, d]  residual stream between blocks
      attn_qkv      [Z, b, S, H, hd] per-head projections
      attn_out      [Z, b, S, d]
      ffn_hidden    [Z, b, S, ff]
      logits        [Z, b, S, V]
      moe_expert    [E, G, C, d]  expert-major dispatched tokens
      kv_cache      [Z, b, S, kv, hd]
      linear_state  [Z, b, H, K, V] recurrent state
    """
    p = _policy()
    if p is None:
        return x
    return p(x, kind)


@contextlib.contextmanager
def sharding_policy(policy: Callable, hints: Optional[dict] = None):
    """Install ``policy(x, kind) -> x`` for the duration of the context."""
    prev = _policy()
    prev_hints = getattr(_state, "hints", None)
    _state.policy = policy
    _state.hints = hints or getattr(policy, "hints", None)
    try:
        yield
    finally:
        _state.policy = prev
        _state.hints = prev_hints
