"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE.

M-RoPE [arXiv:2409.12191]: the head_dim/2 rotary frequencies are split into
three sections (temporal, height, width); each section consumes the matching
component of a 3-part position id. Text tokens carry (t,t,t) so M-RoPE
degrades exactly to RoPE on text.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.configs.base import RoPEConfig


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies, shape [head_dim // 2] (fp32)."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def rope_angles(positions: jnp.ndarray, head_dim: int,
                cfg: RoPEConfig) -> jnp.ndarray:
    """Rotation angles.

    positions: [..., S] int for RoPE, or [3, ..., S] for M-RoPE.
    returns angles [..., S, head_dim // 2] fp32.
    """
    inv = rope_freqs(head_dim, cfg.theta)
    if not cfg.is_mrope:
        return positions[..., None].astype(jnp.float32) * inv
    sections = cfg.mrope_sections
    assert positions.shape[0] == 3, "M-RoPE expects [3, ..., S] positions"
    assert sum(sections) == head_dim // 2, (sections, head_dim)
    parts = []
    off = 0
    for comp in range(3):
        sec = sections[comp]
        ang = positions[comp][..., None].astype(jnp.float32) * inv[off:off + sec]
        parts.append(ang)
        off += sec
    return jnp.concatenate(parts, axis=-1)


def apply_rope(x: jnp.ndarray, angles: jnp.ndarray) -> jnp.ndarray:
    """Rotate pairs. x: [..., S, H, hd]; angles: [..., S, hd//2]."""
    dt = x.dtype
    half = x.shape[-1] // 2
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:].astype(jnp.float32)
    c = jnp.cos(angles)[..., None, :]   # broadcast over heads
    s = jnp.sin(angles)[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return out.astype(dt)


def text_positions(batch_shape: Tuple[int, ...], seq_len: int,
                   cfg: RoPEConfig, offset=0) -> jnp.ndarray:
    """Default positions: arange for RoPE; (t,t,t) stack for M-RoPE."""
    pos = jnp.arange(seq_len, dtype=jnp.int32) + offset
    pos = jnp.broadcast_to(pos, (*batch_shape, seq_len))
    if cfg.is_mrope:
        pos = jnp.broadcast_to(pos[None], (3, *batch_shape, seq_len))
    return pos
