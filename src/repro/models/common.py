"""Shared low-level model components: norms, init, dtype policy."""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm in fp32 accumulation, cast back to input dtype."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dt)


def normal_init(key, shape, scale: float, dtype) -> jax.Array:
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def he_init(key, shape, fan_in: int, dtype) -> jax.Array:
    return normal_init(key, shape, 1.0 / np.sqrt(max(fan_in, 1)), dtype)


def split_keys(key, n: int) -> Sequence[jax.Array]:
    return jax.random.split(key, n)


def silu(x):
    return x * jax.nn.sigmoid(x)


def swiglu(gate, up):
    return silu(gate) * up


def causal_mask_bias(q_pos: jax.Array, k_pos: jax.Array,
                     window: int = 0) -> jax.Array:
    """Additive attention bias: 0 where visible, -inf where masked.

    q_pos: [..., Sq] absolute query positions
    k_pos: [..., Sk] absolute key positions
    window: 0 => full causal; >0 => sliding window of that many positions
    """
    q = q_pos[..., :, None]
    k = k_pos[..., None, :]
    visible = k <= q
    if window > 0:
        visible &= k > (q - window)
    return jnp.where(visible, 0.0, -jnp.inf).astype(jnp.float32)
