"""Compute-backend selection for the model's hot paths.

"jnp" (default) — pure-XLA reference paths (what pjit/GSPMD distributes).
"pallas" / "pallas_interpret" — hand kernels for the hot spots:
  * attention (training/prefill causal path) -> kernels.flash_attention
  * chunked linear scan (RWKV6/Mamba)        -> kernels.linear_scan
LoRA projections have their own switch in core.lora (grouped_lora kernels).

On this CPU container only "pallas_interpret" executes; on TPU "pallas"
lowers to Mosaic. Model-level equivalence between backends is tested in
tests/test_kernel_backends.py.
"""
from __future__ import annotations

import contextlib
import threading

_state = threading.local()

BACKENDS = ("jnp", "pallas", "pallas_interpret")


def get_backend() -> str:
    return getattr(_state, "name", "jnp")


def set_backend(name: str) -> None:
    assert name in BACKENDS, name
    _state.name = name


def interpret_mode() -> bool:
    return get_backend() == "pallas_interpret"


def use_pallas() -> bool:
    return get_backend() != "jnp"


@contextlib.contextmanager
def backend(name: str):
    prev = get_backend()
    set_backend(name)
    try:
        yield
    finally:
        set_backend(prev)
