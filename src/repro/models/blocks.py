"""Per-family transformer blocks with multi-adapter LoRA hooks.

Every block operates on slot-major activations ``x: [Z, b, S, d]`` (Z =
adapter slots). Base weights are slot-shared and FROZEN; LoRA pairs are
slot-stacked. ``mode``:
  "train"/"prefill": full-sequence causal; optionally fills a KV cache.
  "decode": S == 1, consumes + updates cache/state.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.lora import proj
from repro.models.attention import attention
from repro.models.common import he_init, rms_norm, swiglu
from repro.models.mamba import (init_mamba_params, mamba_block,
                                mamba_target_shapes)
from repro.models.moe import init_moe_params, moe_block
from repro.models.rope import apply_rope
from repro.models.rwkv import (init_rwkv_layer, rwkv_channel_mix,
                               rwkv_target_shapes, rwkv_time_mix)
from repro.models.shardctx import constrain, get_hint


# ---------------------------------------------------------------------------
# Target shapes (for LoRA init)
# ---------------------------------------------------------------------------

def attn_target_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, int]]:
    d = cfg.d_model
    return {
        "q_proj": (d, cfg.q_dim), "k_proj": (d, cfg.kv_dim),
        "v_proj": (d, cfg.kv_dim), "o_proj": (cfg.q_dim, d),
    }


def mlp_target_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, int]]:
    d = cfg.d_model
    return {"gate_proj": (d, cfg.d_ff), "up_proj": (d, cfg.d_ff),
            "down_proj": (cfg.d_ff, d)}


def target_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, int]]:
    if cfg.family == "ssm":
        return rwkv_target_shapes(cfg)
    shapes = dict(attn_target_shapes(cfg))
    if cfg.family == "hybrid":
        shapes.update(mamba_target_shapes(cfg))
        shapes.update(mlp_target_shapes(cfg))
    elif cfg.is_moe:
        pass  # experts frozen; attention-only LoRA (cfg.lora.targets governs)
    else:
        shapes.update(mlp_target_shapes(cfg))
    return shapes


# ---------------------------------------------------------------------------
# Init (one layer; model.py stacks over L)
# ---------------------------------------------------------------------------

def init_layer_params(key, cfg: ModelConfig, dtype) -> Dict:
    if cfg.family == "ssm":
        return init_rwkv_layer(key, cfg, dtype)
    d = cfg.d_model
    ks = jax.random.split(key, 10)
    p: Dict[str, Any] = {
        "attn_norm": jnp.ones((d,), jnp.float32),
        "mlp_norm": jnp.ones((d,), jnp.float32),
        "q_proj": he_init(ks[0], (d, cfg.q_dim), d, dtype),
        "k_proj": he_init(ks[1], (d, cfg.kv_dim), d, dtype),
        "v_proj": he_init(ks[2], (d, cfg.kv_dim), d, dtype),
        "o_proj": he_init(ks[3], (cfg.q_dim, d), cfg.q_dim, dtype),
    }
    if cfg.is_moe:
        p["moe"] = init_moe_params(ks[4], d, cfg.moe, dtype)
    else:
        p["gate_proj"] = he_init(ks[5], (d, cfg.d_ff), d, dtype)
        p["up_proj"] = he_init(ks[6], (d, cfg.d_ff), d, dtype)
        p["down_proj"] = he_init(ks[7], (cfg.d_ff, d), cfg.d_ff, dtype)
    if cfg.family == "hybrid":
        p["mamba"] = init_mamba_params(ks[8], cfg, dtype)
        p["branch_norm_attn"] = jnp.ones((d,), jnp.float32)
        p["branch_norm_ssm"] = jnp.ones((d,), jnp.float32)
    return p


# ---------------------------------------------------------------------------
# Attention sublayer (shared by dense / moe / hybrid)
# ---------------------------------------------------------------------------

def _lp(lora: Dict, t: str):
    return (lora[t]["A"], lora[t]["B"]) if t in lora else None


def attn_sublayer(x: jnp.ndarray, p: Dict, lora: Dict, cfg: ModelConfig,
                  angles: jnp.ndarray, q_pos: jnp.ndarray, *,
                  cache: Optional[Dict] = None,
                  k_pos: Optional[jnp.ndarray] = None,
                  kv_valid_len: Optional[jnp.ndarray] = None,
                  write_index: Optional[jnp.ndarray] = None,
                  window: int = 0, scale=2.0,
                  ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """x: [Z,b,S,d] (normed). Returns (attn_out, new_cache)."""
    Z, b, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim

    q = proj(x, p["q_proj"], _lp(lora, "q_proj"), scale, name="q_proj").reshape(Z, b, S, H, hd)
    k = proj(x, p["k_proj"], _lp(lora, "k_proj"), scale, name="k_proj").reshape(Z, b, S, KV, hd)
    v = proj(x, p["v_proj"], _lp(lora, "v_proj"), scale, name="v_proj").reshape(Z, b, S, KV, hd)
    if S > 1 and get_hint("opt_level", 0) >= 2:
        # keep q/k/v SEQUENCE-sharded through the (token-local) projections
        # and rope; attention re-constrains to its head-sharded layout, so
        # the S->head reshard moves the narrow per-head tensors (an
        # all-to-all) instead of all-gathering the d_model-wide residual
        q = constrain(q, "dims:data,pod,model")
        k = constrain(k, "dims:data,pod,model")
        v = constrain(v, "dims:data,pod,model")
    q = constrain(apply_rope(q, angles), "attn_qkv")
    k = apply_rope(k, angles)

    new_cache = None
    if cache is not None and write_index is not None:
        if getattr(write_index, "ndim", 0) == 2:
            # per-lane decode write: each (Z, b) stream scatters its one
            # new K/V row at its OWN index (continuous batching — lanes
            # at different positions advance in the same fused step)
            assert S == 1, "per-lane cache writes are decode-only"
            Sc = cache["k"].shape[2]
            sel = (jnp.arange(Sc, dtype=jnp.int32)[None, None, :]
                   == write_index[..., None])[..., None, None]
            ck = jnp.where(sel, k.astype(cache["k"].dtype), cache["k"])
            cv = jnp.where(sel, v.astype(cache["v"].dtype), cache["v"])
        else:
            # global-position decode / cache-filling prefill: every lane
            # writes the same slice starting at write_index
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), write_index, axis=2)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), write_index, axis=2)
        new_cache = {"k": ck, "v": cv}
        k_all, v_all = ck, cv
        kp = k_pos if k_pos is not None else jnp.arange(
            ck.shape[2], dtype=jnp.int32)
    else:
        k_all, v_all = k, v
        kp = k_pos if k_pos is not None else q_pos

    out = attention(q, k_all, v_all, q_pos, kp, window=window,
                    q_chunk=cfg_q_chunk(cfg, S),
                    kv_valid_len=kv_valid_len)
    out = out.reshape(Z, b, S, H * hd)
    return proj(out, p["o_proj"], _lp(lora, "o_proj"), scale, name="o_proj"), new_cache


def cfg_q_chunk(cfg: ModelConfig, S: int) -> int:
    if S <= 512:
        return S
    for c in (512, 256, 128):
        if S % c == 0:
            return c
    return S


def mlp_sublayer(x: jnp.ndarray, p: Dict, lora: Dict, scale=2.0) -> jnp.ndarray:
    h = swiglu(proj(x, p["gate_proj"], _lp(lora, "gate_proj"), scale,
                    name="gate_proj"),
               proj(x, p["up_proj"], _lp(lora, "up_proj"), scale,
                    name="up_proj"))
    h = constrain(h, "ffn_hidden")
    return proj(h, p["down_proj"], _lp(lora, "down_proj"), scale,
                name="down_proj")


# ---------------------------------------------------------------------------
# Full blocks. Signature:
#   block(cfg, x, vars, ctx) -> (x', aux_loss fp32 scalar, new_layer_state)
# ``ctx`` carries rope angles, positions, cache slices, window, mode.
# ---------------------------------------------------------------------------

def transformer_block(cfg: ModelConfig, x: jnp.ndarray, lvars: Dict,
                      ctx: Dict) -> Tuple[jnp.ndarray, jnp.ndarray, Any]:
    p, lora = lvars["base"], lvars.get("lora", {})
    scale = cfg.lora.scale_for_rank(0)
    window = ctx.get("window", 0)
    state = ctx.get("layer_state")
    cache = state.get("attn") if isinstance(state, dict) else None

    h = rms_norm(x, p["attn_norm"], cfg.norm_eps)
    new_state: Dict[str, Any] = {}
    aux = jnp.zeros((), jnp.float32)

    if cfg.family == "hybrid":
        attn_out, new_cache = attn_sublayer(
            h, p, lora, cfg, ctx["angles"], ctx["q_pos"], cache=cache,
            k_pos=ctx.get("k_pos"), kv_valid_len=ctx.get("kv_valid_len"),
            write_index=ctx.get("write_index"), window=window, scale=scale)
        ssm_out, new_mamba = mamba_block(
            h, p["mamba"], lora, cfg,
            state=(state.get("mamba") if isinstance(state, dict) else None),
            scale=scale)
        # Hymba: mean of per-branch-normed outputs
        attn_out = rms_norm(attn_out, p["branch_norm_attn"], cfg.norm_eps)
        ssm_out = rms_norm(ssm_out, p["branch_norm_ssm"], cfg.norm_eps)
        x = x + 0.5 * (attn_out + ssm_out)
        new_state["mamba"] = new_mamba
        if new_cache is not None:
            new_state["attn"] = new_cache
    else:
        attn_out, new_cache = attn_sublayer(
            h, p, lora, cfg, ctx["angles"], ctx["q_pos"], cache=cache,
            k_pos=ctx.get("k_pos"), kv_valid_len=ctx.get("kv_valid_len"),
            write_index=ctx.get("write_index"), window=window, scale=scale)
        # constrain the delta BEFORE the add: the row-parallel o_proj's
        # partial sums then lower as reduce-scatter (Megatron-SP), not
        # all-reduce + slice
        x = x + constrain(attn_out, "residual")
        if new_cache is not None:
            new_state["attn"] = new_cache

    x = constrain(x, "residual")
    h = rms_norm(x, p["mlp_norm"], cfg.norm_eps)
    if cfg.is_moe:
        moe_out, aux = moe_block(h, p["moe"], cfg.moe)
        x = x + moe_out
    else:
        x = x + constrain(mlp_sublayer(h, p, lora, scale), "residual")
    x = constrain(x, "residual")
    return x, aux, (new_state if new_state else None)


def rwkv_block(cfg: ModelConfig, x: jnp.ndarray, lvars: Dict,
               ctx: Dict) -> Tuple[jnp.ndarray, jnp.ndarray, Any]:
    p, lora = lvars["base"], lvars.get("lora", {})
    scale = cfg.lora.scale_for_rank(0)
    state = ctx.get("layer_state")
    state = state if isinstance(state, dict) else {}
    # pre-norms (RWKV uses LN; we use RMS for uniformity); token-shift
    # states carry the *normed* stream so decode continuation is exact.
    xn = rms_norm(x, p["tm_norm"], cfg.norm_eps)
    tm_out, wkv_state, tm_last = rwkv_time_mix(
        xn, p, lora, cfg, prev_x=state.get("tm_x"), state=state.get("wkv"),
        scale=scale)
    x = constrain(x + tm_out, "residual")
    xn = rms_norm(x, p["cm_norm"], cfg.norm_eps)
    cm_out, cm_last = rwkv_channel_mix(
        xn, p, lora, cfg, prev_x=state.get("cm_x"), scale=scale)
    x = constrain(x + cm_out, "residual")
    new_state = {"wkv": wkv_state, "tm_x": tm_last, "cm_x": cm_last}
    return x, jnp.zeros((), jnp.float32), new_state


def apply_block(cfg: ModelConfig, x, lvars, ctx):
    if cfg.family == "ssm":
        return rwkv_block(cfg, x, lvars, ctx)
    return transformer_block(cfg, x, lvars, ctx)
