"""Chunked linear-attention / gated-SSM scan core.

One numerical core serves both RWKV-6 (data-dependent per-channel decay with
current-token bonus ``u``) and Mamba-2/SSD (scalar-per-step decay, no bonus).

Recurrence (per head; state S maps key-dim K -> value-dim V):

    S_t = diag(w_t) S_{t-1} + k_t^T v_t             w_t = exp(logw_t) in (0,1]
    y_t = q_t S_{t-1} + (q_t . u) k_t v_t           (decay_on_query=False; RWKV)
    y_t = q_t S_t                                    (decay_on_query=True; SSD)

Chunked evaluation: the sequence is split into chunks of length C; the
inter-chunk state term and the state update are MXU matmuls with decay
factors exp(L_t) <= 1 (L = within-chunk cumulative log-decay, always <= 0 so
no overflow); the intra-chunk pair term is computed *exactly* in log space
via per-pair decay differences (a [C, C, K] einsum), which is numerically
stable for arbitrarily strong decay — no clamping, no approximation. All
internal math is fp32.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.shardctx import get_hint

NEG_INF = -1e30


def chunked_linear_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, logw: jnp.ndarray, *,
    bonus: Optional[jnp.ndarray] = None,
    decay_on_query: bool = False,
    initial_state: Optional[jnp.ndarray] = None,
    chunk: int = 32,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """q,k,logw: [Z,b,S,H,K]; v: [Z,b,S,H,V]; bonus: [H,K] or None.

    returns (y: [Z,b,S,H,V], final_state: [Z,b,H,K,V]) — y in q.dtype,
    state fp32.
    """
    Z, b, S, H, K = q.shape
    V = v.shape[-1]
    dt = q.dtype
    # perf hints (§Perf): override chunk size; remat the per-chunk body so
    # the outer-layer checkpoint does NOT stack the [C,C,K] pair tensors of
    # every chunk as scan residuals (the dominant memory term in the
    # baseline rwkv6/hymba train rooflines).
    C = int(get_hint("scan_chunk", 0) or chunk)
    remat_chunk = get_hint("opt_level", 0) >= 2
    C = min(C, S)
    while S % C:
        C -= 1
    n = S // C

    # hand-kernel path (kernels/linear_scan): VMEM-resident pair tensors
    from repro.models import backend as BK
    if BK.use_pallas():
        from repro.kernels.linear_scan import ops as LSK
        Bf = Z * b * H
        to_rows = lambda x, d: x.transpose(0, 1, 3, 2, 4).reshape(Bf, S, d)
        bon = (jnp.broadcast_to(bonus[None, None], (Z, b, H, K))
               .reshape(Bf, K) if bonus is not None else None)
        s0 = (initial_state.reshape(Bf, K, V)
              if initial_state is not None else None)
        y, st = LSK.linear_scan(
            to_rows(q, K), to_rows(k, K), to_rows(v, V), to_rows(logw, K),
            bonus=bon, decay_on_query=decay_on_query, initial_state=s0,
            chunk=C, interpret=BK.interpret_mode())
        y = y.reshape(Z, b, H, S, V).transpose(0, 1, 3, 2, 4)
        return y.astype(dt), st.reshape(Z, b, H, K, V)

    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    lw = logw.astype(jnp.float32)

    # [n, Z, b, H, C, K/V] chunk-major, head-major layouts
    def to_chunks(x, d):
        return jnp.moveaxis(
            x.reshape(Z, b, n, C, H, d), (2, 4), (0, 3))

    qc, kc, lc = to_chunks(qf, K), to_chunks(kf, K), to_chunks(lw, K)
    vc = to_chunks(vf, V)

    if initial_state is None:
        S0 = jnp.zeros((Z, b, H, K, V), jnp.float32)
    else:
        S0 = initial_state.astype(jnp.float32)

    # intra-chunk causal mask: strict lower for RWKV (bonus handles diag),
    # inclusive lower for SSD
    t_idx = jnp.arange(C)
    if decay_on_query:
        pair_visible = t_idx[:, None] >= t_idx[None, :]
    else:
        pair_visible = t_idx[:, None] > t_idx[None, :]

    def step(state, inp):
        qb, kb, vb, lb = inp              # [Z,b,H,C,K], v: [...,C,V]
        L = jnp.cumsum(lb, axis=-2)       # [Z,b,H,C,K], <= 0, decreasing
        if decay_on_query:
            Lq = L                        # decay through token t inclusive
        else:
            Lq = jnp.pad(L, [(0, 0)] * 3 + [(1, 0), (0, 0)])[..., :-1, :]
        # ---- state contribution: (q . exp(Lq)) @ S_prev  (exp <= 1)
        q_scaled = qb * jnp.exp(Lq)
        y_state = jnp.einsum("zbhck,zbhkv->zbhcv", q_scaled, state)
        # ---- intra-chunk pairs, exact log-space:
        # P[t,i] = sum_K q[t]k[i]exp(Lq[t]-L[i]) over visible (t,i)
        dd = Lq[..., :, None, :] - L[..., None, :, :]   # [Z,b,H,C,C,K]
        dd = jnp.where(pair_visible[..., None], dd, NEG_INF)
        P = jnp.einsum("zbhtk,zbhik,zbhtik->zbhti",
                       qb, kb, jnp.exp(dd))
        if bonus is not None:
            diag = jnp.einsum("zbhck,hk,zbhck->zbhc", qb,
                              bonus.astype(jnp.float32), kb)
            P = P + diag[..., None] * jnp.eye(C, dtype=jnp.float32)
        y_intra = jnp.einsum("zbhti,zbhiv->zbhtv", P, vb)
        # ---- state update: S' = exp(L_C) . S + sum_i (k_i exp(L_C - L_i)) v_i
        L_end = L[..., -1:, :]                           # [Z,b,H,1,K]
        k_scaled = kb * jnp.exp(L_end - L)               # exp <= 1
        new_state = (state * jnp.exp(L_end.squeeze(-2))[..., None]
                     + jnp.einsum("zbhck,zbhcv->zbhkv", k_scaled, vb))
        return new_state, y_state + y_intra

    if remat_chunk:
        step = jax.checkpoint(step, prevent_cse=False)
    final_state, ys = jax.lax.scan(step, S0, (qc, kc, vc, lc))
    # ys: [n, Z, b, H, C, V] -> [Z, b, S, H, V]
    y = jnp.moveaxis(ys, (0, 3), (2, 4)).reshape(Z, b, S, H, V)
    return y.astype(dt), final_state


def linear_attention_decode_step(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, logw: jnp.ndarray,
    state: jnp.ndarray, *, bonus: Optional[jnp.ndarray] = None,
    decay_on_query: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token recurrent step.

    q,k,logw: [Z,b,H,K]; v: [Z,b,H,V]; state: [Z,b,H,K,V] fp32.
    returns (y [Z,b,H,V] in q.dtype, new_state fp32).
    """
    dt = q.dtype
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    w = jnp.exp(logw.astype(jnp.float32))
    if decay_on_query:
        new_state = state * w[..., None] + kf[..., :, None] * vf[..., None, :]
        y = jnp.einsum("zbhk,zbhkv->zbhv", qf, new_state)
    else:
        y = jnp.einsum("zbhk,zbhkv->zbhv", qf, state)
        if bonus is not None:
            y = y + jnp.einsum("zbhk,hk,zbhk,zbhv->zbhv",
                               qf, bonus.astype(jnp.float32), kf, vf)
        new_state = state * w[..., None] + kf[..., :, None] * vf[..., None, :]
    return y.astype(dt), new_state


def reference_linear_attention(q, k, v, logw, *, bonus=None,
                               decay_on_query=False, initial_state=None):
    """O(S) step-by-step oracle (used by tests to validate the chunked path)."""
    Z, b, S, H, K = q.shape
    V = v.shape[-1]
    state = (jnp.zeros((Z, b, H, K, V), jnp.float32)
             if initial_state is None else initial_state.astype(jnp.float32))
    ys = []
    for t in range(S):
        y, state = linear_attention_decode_step(
            q[:, :, t], k[:, :, t], v[:, :, t], logw[:, :, t], state,
            bonus=bonus, decay_on_query=decay_on_query)
        ys.append(y)
    return jnp.stack(ys, axis=2), state
