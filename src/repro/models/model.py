"""Unified multi-adapter decoder: init / forward / prefill / decode.

All entry points are pure functions of (cfg, params, lora, inputs) and are
safe under ``jax.eval_shape`` (the multi-pod dry-run lowers them with
ShapeDtypeStructs only). Layers are stacked on a leading L axis and executed
with ``lax.scan`` (+ per-layer remat in training) so HLO size and compile
time stay bounded for 80-layer configs.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN_NONE, ATTN_SLIDING, ModelConfig
from repro.models import blocks as B
from repro.models.common import dtype_of, he_init, normal_init, rms_norm
from repro.models.mamba import mamba_dims
from repro.models.rope import rope_angles, text_positions
from repro.models.shardctx import constrain

RING_INIT_POS = -(1 << 30)    # ring-cache slots start far in the past


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_params(key: jax.Array, cfg: ModelConfig) -> Dict:
    dtype = dtype_of(cfg.dtype)
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    layers = [B.init_layer_params(k, cfg, dtype) for k in layer_keys]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *layers)
    params = {
        "embed": normal_init(k_emb, (cfg.vocab_size, cfg.d_model),
                             0.02, dtype),
        "layers": stacked,
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = he_init(
            k_head, (cfg.d_model, cfg.vocab_size), cfg.d_model, dtype)
    return params


def target_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, int]]:
    return B.target_shapes(cfg)


# ---------------------------------------------------------------------------
# Shared pieces
# ---------------------------------------------------------------------------

def _train_window(cfg: ModelConfig) -> int:
    return cfg.sliding_window if cfg.attn_kind == ATTN_SLIDING else 0


def _embed(cfg: ModelConfig, params: Dict, tokens: jnp.ndarray,
           modal_embeds: Optional[jnp.ndarray]) -> jnp.ndarray:
    x = params["embed"][tokens]                      # [Z,b,S,d]
    if modal_embeds is not None:
        P = modal_embeds.shape[2]
        x = jax.lax.dynamic_update_slice_in_dim(
            x, modal_embeds.astype(x.dtype), 0, axis=2)
    return constrain(x, "residual")


def _angles(cfg: ModelConfig, positions: jnp.ndarray) -> Optional[jnp.ndarray]:
    if cfg.attn_kind == ATTN_NONE:
        return None
    return rope_angles(positions, cfg.resolved_head_dim, cfg.rope)


def _unembed(cfg: ModelConfig, params: Dict, x: jnp.ndarray) -> jnp.ndarray:
    W = params["lm_head"] if not cfg.tie_embeddings else params["embed"].T
    W = constrain(W, "weight:lm_head")
    logits = jnp.einsum("z...d,dv->z...v", x, W)
    return constrain(logits, "logits")


def _scan_layers(cfg: ModelConfig, x: jnp.ndarray, params: Dict, lora: Dict,
                 ctx: Dict, layer_states: Any = None, *, remat: bool,
                 need_state: bool) -> Tuple[jnp.ndarray, jnp.ndarray, Any]:
    """Scan the stacked layers. Returns (x, aux_sum, new_states|None)."""

    def body(carry, xs):
        base, lora_slice, state = xs
        c = dict(ctx)
        c["layer_state"] = state
        c["need_state"] = need_state
        xb, aux, new_state = B.apply_block(
            cfg, carry, {"base": base, "lora": lora_slice}, c)
        if not need_state:
            new_state = None
        return xb, (aux, new_state)

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)

    L = cfg.num_layers
    if layer_states is None:
        layer_states = _none_states(L)
    xs = (params["layers"], _broadcast_lora(lora, L), layer_states)
    x, (auxs, new_states) = jax.lax.scan(body, x, xs)
    return x, jnp.sum(auxs), (new_states if need_state else None)


def _none_states(L: int):
    # a scan xs leaf of Nones: use a dummy zero array per layer
    return jnp.zeros((L,), jnp.int32)


def _broadcast_lora(lora: Dict, L: int) -> Dict:
    return lora if lora else {}


# layer_state of None is encoded by the dummy int array; blocks treat any
# non-dict layer_state as "no state".
def _decode_ctx_state(state):
    return state if isinstance(state, dict) else None


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params: Dict, lora: Dict, tokens: jnp.ndarray,
            *, positions: Optional[jnp.ndarray] = None,
            modal_embeds: Optional[jnp.ndarray] = None,
            cache: Optional[Dict] = None, remat: bool = True
            ) -> Tuple[jnp.ndarray, jnp.ndarray, Optional[Dict]]:
    """Full-sequence causal forward.

    tokens: [Z, b, S] int32. Returns (final_hidden [Z,b,S,d] (pre-unembed,
    post-final-norm), moe_aux scalar, new_cache or None).

    With ``cache`` given (prefill), per-layer K/V are written at index 0 and
    the filled cache is returned (decode can continue from it).
    """
    Z, b, S = tokens.shape
    x = _embed(cfg, params, tokens, modal_embeds)
    if positions is None:
        positions = text_positions((), S, cfg.rope)
    ctx: Dict[str, Any] = {
        "angles": _angles(cfg, positions),
        "q_pos": jnp.arange(S, dtype=jnp.int32),
        "window": _train_window(cfg),
    }
    layer_states = None
    need_state = cache is not None
    if cache is not None:
        ctx["write_index"] = jnp.array(0, jnp.int32)
        layer_states = cache["layers"]
        need_state = True
    x, aux, new_states = _scan_layers(
        cfg, x, params, lora, ctx, layer_states,
        remat=remat and cache is None, need_state=need_state)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    new_cache = None
    if cache is not None:
        per_lane = getattr(cache["pos"], "ndim", 0) == 2
        new_pos = (jnp.full_like(cache["pos"], S) if per_lane
                   else jnp.array(S, jnp.int32))
        new_cache = {"layers": new_states, "pos": new_pos}
        if "k_pos" in cache:
            kp = jnp.arange(cache["k_pos"].shape[-1], dtype=jnp.int32)
            new_cache["k_pos"] = (
                jnp.broadcast_to(kp, cache["k_pos"].shape) if per_lane
                else kp)
    return x, aux, new_cache


# ---------------------------------------------------------------------------
# Losses (chunked over sequence so [*, S, V] logits are never materialized)
# ---------------------------------------------------------------------------

def per_slot_xent(cfg: ModelConfig, params: Dict, hidden: jnp.ndarray,
                  labels: jnp.ndarray, chunk: int = 512
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """hidden: [Z,b,S,d]; labels: [Z,b,S] int32 (-1 = ignore).

    Returns (sum_nll [Z] fp32, token_count [Z] fp32).
    """
    Z, b, S, d = hidden.shape
    W = (params["lm_head"] if not cfg.tie_embeddings
         else params["embed"].T)
    W = constrain(W, "weight:lm_head")
    c = min(chunk, S)
    while S % c:
        c -= 1
    n = S // c
    hs = jnp.moveaxis(hidden.reshape(Z, b, n, c, d), 2, 0)
    ls = jnp.moveaxis(labels.reshape(Z, b, n, c), 2, 0)

    def body(acc, xs):
        h, lab = xs
        logits = jnp.einsum("zbcd,dv->zbcv", h, W).astype(jnp.float32)
        logits = constrain(logits, "logits")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lab, 0)[..., None], axis=-1)[..., 0]
        mask = (lab >= 0).astype(jnp.float32)
        nll = (lse - gold) * mask
        s, cnt = acc
        return (s + jnp.sum(nll, axis=(1, 2)),
                cnt + jnp.sum(mask, axis=(1, 2))), None

    (s, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((Z,), jnp.float32), jnp.zeros((Z,), jnp.float32)),
        (hs, ls))
    return s, cnt


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, Z: int, bsz: int, max_len: int, *,
               ring: bool = False, per_lane: bool = False) -> Dict:
    """Build a decode cache. ``ring=True`` => sliding-window ring buffer of
    size cfg.sliding_window (sub-quadratic long-context decode).

    ``per_lane=True`` => the decode position is a ``[Z, bsz]`` vector (and
    the ring ``k_pos`` a ``[Z, bsz, Sc]`` tensor): every (slot, lane)
    stream advances independently, so requests can join and leave
    mid-decode with no epoch barrier (true continuous batching)."""
    dtype = dtype_of(cfg.dtype)
    L = cfg.num_layers
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    Sc = cfg.sliding_window if ring else max_len

    def attn_state():
        return {"k": jnp.zeros((L, Z, bsz, Sc, KV, hd), dtype),
                "v": jnp.zeros((L, Z, bsz, Sc, KV, hd), dtype)}

    if cfg.family == "ssm":
        H, hs = cfg.num_heads, cfg.ssm.head_size
        layers = {"wkv": jnp.zeros((L, Z, bsz, H, hs, hs), jnp.float32),
                  "tm_x": jnp.zeros((L, Z, bsz, cfg.d_model), dtype),
                  "cm_x": jnp.zeros((L, Z, bsz, cfg.d_model), dtype)}
    elif cfg.family == "hybrid":
        inner, H, hs = mamba_dims(cfg)
        layers = {
            "attn": attn_state(),
            "mamba": {
                "conv": jnp.zeros((L, Z, bsz, cfg.ssm.conv_width - 1, inner),
                                  jnp.float32),
                "ssm": jnp.zeros((L, Z, bsz, H, cfg.ssm.state_size, hs),
                                 jnp.float32),
            },
        }
    else:
        layers = {"attn": attn_state()}

    if per_lane:
        pos = jnp.zeros((Z, bsz), jnp.int32)
    else:
        pos = jnp.array(0, jnp.int32)
    cache: Dict[str, Any] = {"layers": layers, "pos": pos}
    if ring and cfg.family not in ("ssm",):
        kp = jnp.full((Sc,), RING_INIT_POS, jnp.int32)
        cache["k_pos"] = (jnp.broadcast_to(kp, (Z, bsz, Sc)) if per_lane
                         else kp)
    return cache


def _where_lanes(mask: jnp.ndarray, new_tree, old_tree, lead: int = 1):
    """Per-lane tree select: take ``new`` where ``mask`` ([Z, b] bool),
    keep ``old`` elsewhere. ``lead`` = leading dims before the (Z, b)
    axes (1 for [L, Z, b, ...] layer-state leaves, 0 for [Z, b, ...]).
    Untouched lanes stay bitwise identical (jnp.where is a select)."""

    def sel(n, o):
        m = mask.reshape((1,) * lead + mask.shape
                         + (1,) * (n.ndim - lead - mask.ndim))
        return jnp.where(m, n, o)

    return jax.tree_util.tree_map(sel, new_tree, old_tree)


def decode_step(cfg: ModelConfig, params: Dict, lora: Dict, cache: Dict,
                tokens: jnp.ndarray,
                active: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, Dict]:
    """One decode step. tokens: [Z, b] int32 -> (logits [Z,b,V], cache').

    With a GLOBAL position cache (``cache["pos"]`` scalar) every lane
    writes/reads at the same position — the historical round-batching
    path. With a PER-LANE cache (``pos`` is [Z, b]) each (slot, lane)
    stream carries its own position: K/V writes scatter at each lane's
    own index, RoPE angles and the causal bias are built per lane, and a
    lane never sees keys beyond its own position — so neighbors mid-join
    or mid-retirement cannot perturb it. ``active`` ([Z, b] bool,
    per-lane caches only) freezes idle lanes: their cache, position and
    recurrent state stay bitwise untouched while live lanes advance."""
    Z, bsz = tokens.shape
    pos = cache["pos"]
    per_lane = getattr(pos, "ndim", 0) == 2
    assert active is None or per_lane, "active mask needs a per-lane cache"
    x = _embed(cfg, params, tokens[:, :, None], None)
    if per_lane:
        positions = pos[..., None]                     # [Z, b, 1]
        if cfg.rope.is_mrope:
            positions = jnp.broadcast_to(positions, (3, Z, bsz, 1))
    else:
        positions = text_positions((), 1, cfg.rope, offset=pos)

    ring = "k_pos" in cache
    ctx: Dict[str, Any] = {
        "angles": _angles(cfg, positions),
        "q_pos": pos[..., None] if per_lane else pos[None],
    }
    new_kpos = None
    if cfg.family != "ssm":
        if ring:
            W = cfg.sliding_window
            widx = jnp.mod(pos, W)
            if per_lane:
                sel = jnp.arange(W, dtype=jnp.int32)[None, None, :] \
                    == widx[..., None]                 # [Z, b, W]
                new_kpos = jnp.where(sel, pos[..., None], cache["k_pos"])
                if active is not None:
                    new_kpos = jnp.where(active[..., None], new_kpos,
                                         cache["k_pos"])
            else:
                new_kpos = jax.lax.dynamic_update_index_in_dim(
                    cache["k_pos"], pos, widx, axis=0)
            ctx.update(write_index=widx, k_pos=new_kpos, window=W)
        else:
            ctx.update(write_index=pos,
                       kv_valid_len=pos + 1,
                       window=_train_window(cfg))

    x, aux, new_states = _scan_layers(
        cfg, x, params, lora, ctx, cache["layers"],
        remat=False, need_state=True)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _unembed(cfg, params, x[:, :, 0])
    new_pos = pos + 1
    if active is not None:
        new_states = _where_lanes(active, new_states, cache["layers"])
        new_pos = jnp.where(active, new_pos, pos)
    new_cache = {"layers": new_states, "pos": new_pos}
    if new_kpos is not None:
        new_cache["k_pos"] = new_kpos
    return logits, new_cache


# ---------------------------------------------------------------------------
# Lane lifecycle (continuous batching over a per-lane cache)
# ---------------------------------------------------------------------------

def reset_lanes(cfg: ModelConfig, cache: Dict,
                lane_mask: jnp.ndarray) -> Dict:
    """Return a cache with the masked lanes reset to the just-initialized
    state (pos 0, zero K/V and recurrent state, ring slots pushed to the
    far past) — a fresh request can join those lanes of a LIVE cache.
    Unmasked lanes are bitwise untouched."""
    assert cache["pos"].ndim == 2, "reset_lanes needs a per-lane cache"
    layers = _where_lanes(
        lane_mask,
        jax.tree_util.tree_map(jnp.zeros_like, cache["layers"]),
        cache["layers"])
    out: Dict[str, Any] = {
        "layers": layers,
        "pos": jnp.where(lane_mask, 0, cache["pos"]).astype(jnp.int32),
    }
    if "k_pos" in cache:
        out["k_pos"] = jnp.where(lane_mask[..., None],
                                 jnp.int32(RING_INIT_POS), cache["k_pos"])
    return out


def prefill_lanes(cfg: ModelConfig, params: Dict, lora: Dict, cache: Dict,
                  tokens: jnp.ndarray, lane_mask: jnp.ndarray,
                  plens: Optional[jnp.ndarray] = None
                  ) -> Tuple[jnp.ndarray, Dict]:
    """Block-prefill a subset of lanes of a LIVE per-lane cache.

    tokens: [Z, b, P] int32 (rows of non-joining lanes are ignored);
    lane_mask: [Z, b] bool. The joining lanes' prompts are written into
    their own lane caches at offsets 0..P-1 and their positions set to P
    while every other lane — mid-decode or idle — stays bitwise
    untouched. Returns (last-token logits [Z, b, V], merged cache).

    ``plens`` ([Z, b] int32) serves RAGGED joins in one launch: each
    joining lane's true prompt length, with ``tokens`` right-padded to
    the common P. A lane's position is set to its own length and its
    logits taken at ``plens - 1``. The padded tail beyond a lane's
    length writes garbage K/V at indices >= len — harmless: causality
    masks index i until the lane's position reaches i, and decode
    rewrites index i (write-before-read) on the very step it first
    becomes visible, so padded prefill stays bitwise identical to an
    exact-length one.

    Non-ring attention families only (ring and recurrent families join
    by streaming the prompt through ``decode_step``)."""
    assert cache["pos"].ndim == 2, "prefill_lanes needs a per-lane cache"
    assert "k_pos" not in cache and cfg.family not in ("ssm", "hybrid"), \
        "block lane prefill supports non-ring attention caches only"
    Z, b, P = tokens.shape
    work = reset_lanes(cfg, cache, lane_mask)
    # forward writes ALL lanes at 0..P-1; only joining lanes are merged
    x = _embed(cfg, params, tokens, None)
    positions = text_positions((), P, cfg.rope)
    ctx: Dict[str, Any] = {
        "angles": _angles(cfg, positions),
        "q_pos": jnp.arange(P, dtype=jnp.int32),
        "window": _train_window(cfg),
        "write_index": jnp.array(0, jnp.int32),
    }
    x, _, new_states = _scan_layers(
        cfg, x, params, lora, ctx, work["layers"],
        remat=False, need_state=True)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if plens is None:
        last = x[:, :, -1]
        new_pos = jnp.full_like(cache["pos"], P)
    else:
        idx = (plens.astype(jnp.int32) - 1)[:, :, None, None]
        last = jnp.take_along_axis(
            x, jnp.broadcast_to(idx, (Z, b, 1, x.shape[-1])), axis=2
        )[:, :, 0]
        new_pos = plens.astype(jnp.int32)
    logits = _unembed(cfg, params, last)
    merged = {
        "layers": _where_lanes(lane_mask, new_states, cache["layers"]),
        "pos": jnp.where(lane_mask, new_pos, cache["pos"]).astype(jnp.int32),
    }
    return logits, merged
