"""Mamba-2 (SSD) style selective SSM branch — used by the Hymba hybrid block.

Per-head scalar data-dependent decay a_t = exp(-dt_t * exp(A_log)); B/C
projections shared across heads (state_size N per head); dt-scaled input;
causal depthwise conv front; silu(z) output gate; D skip. The recurrence
runs through the shared chunked linear-scan core (decay_on_query=True).

Decode carries (conv_buffer [Z,b,W-1,inner], ssm_state [Z,b,H,N,hs]).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.lora import proj
from repro.models.common import he_init, normal_init, silu
from repro.models.linear_scan import (chunked_linear_attention,
                                      linear_attention_decode_step)


def mamba_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    inner = cfg.ssm.expand * cfg.d_model
    hs = cfg.ssm.head_size
    H = inner // hs
    return inner, H, hs


def mamba_target_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, int]]:
    inner, _, _ = mamba_dims(cfg)
    return {"in_proj": (cfg.d_model, 2 * inner)}


def init_mamba_params(key, cfg: ModelConfig, dtype) -> Dict:
    d = cfg.d_model
    inner, H, hs = mamba_dims(cfg)
    N = cfg.ssm.state_size
    W = cfg.ssm.conv_width
    ks = jax.random.split(key, 6)
    return {
        "in_proj": he_init(ks[0], (d, 2 * inner), d, dtype),
        "conv": normal_init(ks[1], (W, inner), 0.2, jnp.float32),
        "bc_proj": he_init(ks[2], (inner, 2 * N), inner, dtype),
        "dt_proj": he_init(ks[3], (inner, H), inner, jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": normal_init(ks[4], (H,), 0.5, jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "out_proj": he_init(ks[5], (inner, d), inner, dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray,
                 buffer: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Depthwise causal conv. x: [Z,b,S,inner]; w: [W, inner]."""
    W = w.shape[0]
    if buffer is None:
        pad = jnp.zeros((*x.shape[:2], W - 1, x.shape[-1]), x.dtype)
    else:
        pad = buffer.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=2)
    out = sum(xp[:, :, i:i + x.shape[2]] * w[i].astype(x.dtype)
              for i in range(W))
    return silu(out)


def mamba_block(x: jnp.ndarray, p: Dict, lora: Dict, cfg: ModelConfig, *,
                state: Optional[Dict] = None, scale=2.0
                ) -> Tuple[jnp.ndarray, Dict]:
    """x: [Z,b,S,d] -> (out [Z,b,S,d], new_state {conv, ssm})."""
    Z, b, S, d = x.shape
    inner, H, hs = mamba_dims(cfg)
    N = cfg.ssm.state_size
    Wd = cfg.ssm.conv_width

    lp = lambda t: (lora[t]["A"], lora[t]["B"]) if t in lora else None
    xz = proj(x, p["in_proj"], lp("in_proj"), scale, name="in_proj")
    xt, z = jnp.split(xz, 2, axis=-1)

    conv_buf = state["conv"] if state is not None else None
    xc = _causal_conv(xt, p["conv"], conv_buf)
    if conv_buf is None:
        stream = jnp.pad(xt, [(0, 0), (0, 0), (Wd - 1, 0), (0, 0)])
    else:
        stream = jnp.concatenate([conv_buf.astype(xt.dtype), xt], axis=2)
    new_conv = stream[:, :, -(Wd - 1):].astype(jnp.float32)

    bc = proj(xc, p["bc_proj"], None, name="bc_proj")                     # [Z,b,S,2N] frozen
    Bm, Cm = jnp.split(bc.astype(jnp.float32), 2, axis=-1)
    dt = jax.nn.softplus(xc.astype(jnp.float32) @ p["dt_proj"]
                         + p["dt_bias"])                  # [Z,b,S,H]
    logw = -dt * jnp.exp(p["A_log"])                      # [Z,b,S,H] < 0

    v = xc.reshape(Z, b, S, H, hs) * dt[..., None].astype(xc.dtype)
    q = jnp.broadcast_to(Cm[..., None, :], (Z, b, S, H, N)).astype(xc.dtype)
    k = jnp.broadcast_to(Bm[..., None, :], (Z, b, S, H, N)).astype(xc.dtype)
    lw = jnp.broadcast_to(logw[..., None], (Z, b, S, H, N))

    ssm_state = state["ssm"] if state is not None else None
    if S == 1 and ssm_state is not None:
        y, new_ssm = linear_attention_decode_step(
            q[:, :, 0], k[:, :, 0], v[:, :, 0], lw[:, :, 0], ssm_state,
            decay_on_query=True)
        y = y[:, :, None]
    else:
        y, new_ssm = chunked_linear_attention(
            q, k, v, lw, decay_on_query=True, initial_state=ssm_state,
            chunk=cfg.ssm.chunk_size)

    y = y + xc.reshape(Z, b, S, H, hs) * p["D"][:, None].astype(xc.dtype)
    y = y.reshape(Z, b, S, inner) * silu(z)
    out = proj(y, p["out_proj"], None, name="out_proj")                    # frozen out proj
    return out, {"conv": new_conv, "ssm": new_ssm}


def init_mamba_state(cfg: ModelConfig, Z: int, b: int) -> Dict:
    inner, H, hs = mamba_dims(cfg)
    return {
        "conv": jnp.zeros((Z, b, cfg.ssm.conv_width - 1, inner), jnp.float32),
        "ssm": jnp.zeros((Z, b, H, cfg.ssm.state_size, hs), jnp.float32),
    }
