"""ALTO-JAX subsystem."""
