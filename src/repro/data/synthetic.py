"""Synthetic task datasets with realistic LoRA-tuning loss dynamics.

No network access in this environment, so the paper's GSM8K/Tulu-3/
OpenThoughts3 are replaced by synthetic language-modeling *task families*
with controllable difficulty. Each task is a random order-1 Markov chain
over the model vocabulary with a task-specific low-entropy structure: a
model genuinely reduces loss by learning the transition matrix, a too-high
learning rate genuinely diverges, and a small dataset with multi-epoch
training genuinely overfits (train keeps dropping, val rises) — exactly the
three redundancy patterns of paper §3 Obs. 1, produced by the *dynamics*
rather than scripted.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass
class TaskDataset:
    """One fine-tuning task's data: train/val token arrays."""
    name: str
    train: np.ndarray           # [N_train, S+1] int32
    val: np.ndarray             # [N_val, S+1] int32
    vocab_size: int
    seed: int

    @property
    def num_train(self) -> int:
        return len(self.train)


def make_task_dataset(name: str, vocab_size: int, seq_len: int,
                      num_train: int = 512, num_val: int = 64,
                      difficulty: float = 0.5, seed: int = 0) -> TaskDataset:
    """Sample a Markov-chain language task.

    ``difficulty`` in [0,1]: 0 => near-deterministic transitions (easy,
    fast-learnable), 1 => near-uniform (hard, high irreducible loss).
    """
    rng = np.random.default_rng(seed)
    V = vocab_size
    # sparse peaked transition structure over a vocabulary subset
    active = max(min(V, 256), 2)
    concentration = 0.05 + 4.0 * difficulty
    probs = rng.dirichlet(np.full(active, concentration), size=active)

    def sample(n: int, rng_) -> np.ndarray:
        out = np.empty((n, seq_len + 1), np.int32)
        state = rng_.integers(0, active, size=n)
        out[:, 0] = state
        # vectorized chain sampling
        cum = np.cumsum(probs, axis=1)
        for t in range(1, seq_len + 1):
            u = rng_.random(n)
            state = (u[:, None] < cum[state]).argmax(axis=1)
            out[:, t] = state
        return out

    train = sample(num_train, np.random.default_rng(seed + 1))
    val = sample(num_val, np.random.default_rng(seed + 2))
    return TaskDataset(name=name, train=train, val=val, vocab_size=V,
                       seed=seed)


class SlotBatcher:
    """Per-slot epoch-cycling batch streams, stacked to [Z, b, S].

    Each slot has its own cursor/shuffle (independent jobs). ``b`` is the
    slot's DEFAULT per-adapter batch size; ragged executors instead draw
    per-lane via ``lane_batch_dict(lane, n)`` with the occupying job's own
    width (paper §A.1 generalized to heterogeneous batch grouping). A
    lane's stream depends only on its own draw history — never on which
    other lanes exist or what they draw — which is what keeps a task's
    batches identical whether it runs alone or co-located.
    """

    def __init__(self, ds: TaskDataset, Z: int, per_adapter_batch: int,
                 seed: int = 0):
        self.ds = ds
        self.Z = Z
        self.b = per_adapter_batch
        self._rngs = [np.random.default_rng(seed * 1000 + z)
                      for z in range(Z)]
        self._perm = [self._rngs[z].permutation(ds.num_train)
                      for z in range(Z)]
        self._cursor = [0] * Z
        self.epochs = [0] * Z

    @property
    def seq_len(self) -> int:
        return self.ds.train.shape[1] - 1

    def reset_slot(self, z: int, seed: Optional[int] = None) -> None:
        if seed is not None:
            self._rngs[z] = np.random.default_rng(seed)
        self._perm[z] = self._rngs[z].permutation(self.ds.num_train)
        self._cursor[z] = 0
        self.epochs[z] = 0

    def take(self, z: int, n: int) -> np.ndarray:
        """Draw n rows from lane z's stream (epoch-cycling): [n, S+1]."""
        idx = []
        while len(idx) < n:
            grab = min(n - len(idx), self.ds.num_train - self._cursor[z])
            idx.extend(self._perm[z][self._cursor[z]:self._cursor[z] + grab])
            self._cursor[z] += grab
            if self._cursor[z] >= self.ds.num_train:
                self._perm[z] = self._rngs[z].permutation(self.ds.num_train)
                self._cursor[z] = 0
                self.epochs[z] += 1
        return self.ds.train[np.asarray(idx)]

    def _slot_batch(self, z: int) -> np.ndarray:
        return self.take(z, self.b)

    def lane_batch_dict(self, lane: int, n: int) -> dict:
        """One lane's ragged draw: {tokens [n,S], labels [n,S]}."""
        rows = self.take(lane, n)
        return {"tokens": rows[:, :-1].astype(np.int32),
                "labels": rows[:, 1:].astype(np.int32)}

    def next_batch(self) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (tokens [Z,b,S], labels [Z,b,S])."""
        rows = np.stack([self._slot_batch(z) for z in range(self.Z)])
        return rows[:, :, :-1].astype(np.int32), rows[:, :, 1:].astype(np.int32)

    def val_batch(self, max_rows: int = 64) -> Tuple[np.ndarray, np.ndarray]:
        """Validation batch, same rows for every slot: [Z, n, S] x2."""
        rows = self.ds.val[:max_rows]
        n = (len(rows) // self.b) * self.b or len(rows)
        rows = rows[:max(n, 1)]
        stacked = np.broadcast_to(
            rows[None], (self.Z, *rows.shape)).copy()
        return (stacked[:, :, :-1].astype(np.int32),
                stacked[:, :, 1:].astype(np.int32))

    # dict interfaces (shared with the DPO pair batcher)
    def next_batch_dict(self) -> dict:
        t, l = self.next_batch()
        return {"tokens": t, "labels": l}

    def val_batch_dict(self, max_rows: int = 64) -> dict:
        t, l = self.val_batch(max_rows)
        return {"tokens": t, "labels": l}


class PairSlotBatcher:
    """Preference-pair batches for DPO (paper §8.2 RL end-to-end).

    'Chosen' sequences come from the task's low-entropy chain; 'rejected'
    from a higher-entropy (noisier) chain over the same vocabulary — a
    synthetic preference structure a DPO adapter genuinely learns to
    separate."""

    def __init__(self, chosen: TaskDataset, rejected: TaskDataset, Z: int,
                 per_adapter_batch: int, seed: int = 0):
        self.chosen = SlotBatcher(chosen, Z, per_adapter_batch, seed=seed)
        self.rejected = SlotBatcher(rejected, Z, per_adapter_batch,
                                    seed=seed + 7)
        self.Z, self.b = Z, per_adapter_batch
        self.epochs = self.chosen.epochs

    @property
    def seq_len(self) -> int:
        return self.chosen.seq_len

    def reset_slot(self, z: int, seed=None) -> None:
        self.chosen.reset_slot(z, seed)
        self.rejected.reset_slot(z, seed)

    def lane_batch_dict(self, lane: int, n: int) -> dict:
        c = self.chosen.lane_batch_dict(lane, n)
        r = self.rejected.lane_batch_dict(lane, n)
        return {"tokens_chosen": c["tokens"], "labels_chosen": c["labels"],
                "tokens_rejected": r["tokens"],
                "labels_rejected": r["labels"]}

    def next_batch_dict(self) -> dict:
        tc, lc = self.chosen.next_batch()
        tr, lr = self.rejected.next_batch()
        return {"tokens_chosen": tc, "labels_chosen": lc,
                "tokens_rejected": tr, "labels_rejected": lr}

    def val_batch_dict(self, max_rows: int = 64) -> dict:
        tc, lc = self.chosen.val_batch(max_rows)
        tr, lr = self.rejected.val_batch(max_rows)
        n = min(tc.shape[1], tr.shape[1])
        return {"tokens_chosen": tc[:, :n], "labels_chosen": lc[:, :n],
                "tokens_rejected": tr[:, :n], "labels_rejected": lr[:, :n]}
