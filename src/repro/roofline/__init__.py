"""ALTO-JAX subsystem."""
