"""Optimized-HLO analysis: trip-count-weighted FLOPs, bytes, collectives.

``compiled.cost_analysis()`` counts each while-loop body ONCE, so for
scan-over-layers programs it understates FLOPs by ~num_layers x. This module
parses the optimized HLO text into a computation graph, propagates execution
multipliers through while bodies (``known_trip_count``), fusions, and
called computations, and derives:

  * flops        — 2*M*N*K over every `dot` (trip-weighted)
  * bytes_written — sum of result bytes over materializing ops
                   (trip-weighted; HBM traffic ~ 2x this: one write + one
                   read per buffer)
  * collectives  — per-kind counts + ring-model per-device traffic:
        all-gather / all-to-all / reduce-scatter: (n-1)/n * bytes
        all-reduce: 2 (n-1)/n * bytes
        collective-permute: bytes
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "u1": 1, "s1": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*?)\s+([a-z][a-z0-9\-]*)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")
_CALLEE_RE = re.compile(
    r"(?:to_apply|body|condition|calls)=(?:\{)?%?([\w\.\-]+)")
_TRIP_RE = re.compile(
    r'known_trip_count["=:]+\{?"?n"?[:=]+"?(\d+)"?\}?')

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")


def parse_shape(s: str) -> Tuple[str, Tuple[int, ...]]:
    m = _SHAPE_RE.search(s)
    if not m:
        return "", ()
    dt, dims = m.groups()
    return dt, tuple(int(d) for d in dims.split(",") if d)


def shape_bytes(s: str) -> int:
    """Bytes of a (possibly tuple) shape string."""
    total = 0
    for m in _SHAPE_RE.finditer(s):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    result_type: str
    line: str
    comp: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op] = dataclasses.field(default_factory=list)


class HloModule:
    def __init__(self, text: str):
        self.comps: Dict[str, Computation] = {}
        self.entry: Optional[str] = None
        self.op_shapes: Dict[str, str] = {}
        self._parse(text)
        self.mult: Dict[str, float] = {}
        self._propagate()

    # ------------------------------------------------------------- parsing
    def _parse(self, text: str) -> None:
        cur: Optional[Computation] = None
        for raw in text.splitlines():
            if raw and not raw[0].isspace():
                m = _COMP_RE.match(raw)
                if m:
                    cur = Computation(m.group(1))
                    self.comps[cur.name] = cur
                    if raw.startswith("ENTRY"):
                        self.entry = cur.name
                continue
            if cur is None:
                continue
            m = _OP_RE.match(raw)
            if not m:
                continue
            name, rtype, kind = m.groups()
            op = Op(name=name, kind=kind, result_type=rtype,
                    line=raw.strip(), comp=cur.name)
            cur.ops.append(op)
            self.op_shapes[name] = rtype
        if self.entry is None and self.comps:
            # heuristically: computation that nobody calls
            called = set()
            for c in self.comps.values():
                for op in c.ops:
                    called.update(_CALLEE_RE.findall(op.line))
            for name in self.comps:
                if name not in called:
                    self.entry = name
        assert self.entry is not None, "no ENTRY computation found"

    # ------------------------------------------------- multiplier propagation
    def _propagate(self) -> None:
        mult: Dict[str, float] = {c: 0.0 for c in self.comps}
        mult[self.entry] = 1.0
        # topological-ish fixed point (call graphs are acyclic in HLO)
        for _ in range(len(self.comps)):
            changed = False
            new = {c: 0.0 for c in self.comps}
            new[self.entry] = 1.0
            for cname, comp in self.comps.items():
                w = mult.get(cname, 0.0)
                if w == 0.0:
                    continue
                for op in comp.ops:
                    callees = _CALLEE_RE.findall(op.line)
                    if not callees:
                        continue
                    trip = 1.0
                    if op.kind == "while":
                        t = _TRIP_RE.search(op.line)
                        trip = float(t.group(1)) if t else 1.0
                    for callee in callees:
                        if callee in new:
                            new[callee] += w * trip
            for c in self.comps:
                if abs(new[c] - mult[c]) > 1e-9:
                    changed = True
            mult = new
            if not changed:
                break
        self.mult = mult

    def _w(self, op: Op) -> float:
        return self.mult.get(op.comp, 0.0)

    # ------------------------------------------------------------- queries
    def dot_flops(self) -> float:
        total = 0.0
        for comp in self.comps.values():
            for op in comp.ops:
                if op.kind not in ("dot",):
                    continue
                w = self._w(op)
                if w == 0.0:
                    continue
                _, rdims = parse_shape(op.result_type)
                lhs = re.search(r"\(%([\w\.\-]+)", op.line)
                k = 1
                cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
                if lhs and cm and lhs.group(1) in self.op_shapes:
                    _, ldims = parse_shape(self.op_shapes[lhs.group(1)])
                    for d in cm.group(1).split(","):
                        if d and int(d) < len(ldims):
                            k *= ldims[int(d)]
                n = 1
                for d in rdims:
                    n *= d
                total += w * 2.0 * n * k
        return total

    def bytes_written(self) -> float:
        """Trip-weighted result bytes of materializing ops (fusion outputs,
        dots, copies, convolutions, parameters excluded)."""
        # ops that materialize an HBM buffer on TPU (bare elementwise /
        # layout ops — convert, broadcast, transpose, etc. — fuse away)
        mat = ("fusion", "dot", "copy", "convolution", "scatter", "gather",
               "dynamic-update-slice", "dynamic-slice", "concatenate",
               "reduce")
        total = 0.0
        for comp in self.comps.values():
            for op in comp.ops:
                if op.kind in mat or op.kind.startswith("wrapped"):
                    total += self._w(op) * shape_bytes(op.result_type)
        return total

    def collectives(self) -> List["CollectiveOp"]:
        out: List[CollectiveOp] = []
        for comp in self.comps.values():
            for op in comp.ops:
                base = op.kind.replace("-start", "")
                if base not in COLLECTIVE_KINDS:
                    continue
                if op.kind.endswith("-done"):
                    continue
                w = self._w(op)
                if w == 0.0:
                    continue
                rb = shape_bytes(op.result_type)
                grp = _group_size(op.line)
                out.append(CollectiveOp(
                    kind=base, result_bytes=rb, group_size=grp,
                    trip_count=w, traffic_bytes=_traffic(base, rb, grp) * w,
                    line=op.line[:200]))
        return out


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    result_bytes: int
    group_size: int
    trip_count: float
    traffic_bytes: float
    line: str


def _group_size(line: str) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:   # iota list format [num_groups, group_size]
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return max(len([x for x in m.group(1).split(",") if x.strip()]), 1)
    return 2


def _traffic(kind: str, result_bytes: int, group: int) -> float:
    frac = (group - 1) / max(group, 1)
    if kind == "all-reduce":
        return 2.0 * frac * result_bytes
    if kind == "collective-permute":
        return float(result_bytes)
    return frac * result_bytes


def top_bytes(mod: "HloModule", n: int = 12) -> List[Tuple[float, str, str]]:
    """Largest trip-weighted materializing ops: [(bytes, kind, shape)]."""
    mat = ("fusion", "dot", "copy", "convolution", "scatter", "gather",
           "dynamic-update-slice", "dynamic-slice", "concatenate", "reduce")
    rows = []
    for comp in mod.comps.values():
        for op in comp.ops:
            if op.kind in mat:
                b = mod._w(op) * shape_bytes(op.result_type)
                if b > 0:
                    rows.append((b, op.kind, op.result_type[:80]))
    rows.sort(key=lambda r: -r[0])
    return rows[:n]


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def analyze(hlo_text: str) -> Dict:
    mod = HloModule(hlo_text)
    colls = mod.collectives()
    return {
        "flops": mod.dot_flops(),
        "bytes_written": mod.bytes_written(),
        "collective_traffic": sum(c.traffic_bytes for c in colls),
        "collectives": summarize(colls),
    }


def summarize(ops: List[CollectiveOp]) -> Dict[str, Dict[str, float]]:
    out: Dict[str, Dict[str, float]] = {}
    for op in ops:
        d = out.setdefault(op.kind, {"count": 0.0, "traffic_bytes": 0.0,
                                     "result_bytes": 0.0})
        d["count"] += op.trip_count
        d["traffic_bytes"] += op.traffic_bytes
        d["result_bytes"] += op.result_bytes * op.trip_count
    return out


def parse_collectives(hlo_text: str, num_devices: int = 0
                      ) -> List[CollectiveOp]:
    return HloModule(hlo_text).collectives()


def total_traffic(ops: List[CollectiveOp]) -> float:
    return sum(op.traffic_bytes for op in ops)
