"""Three-term roofline from the compiled dry-run artifact (TPU v5e target).

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

Sources: trip-count-weighted HLO parsing (roofline/hlo.py) for FLOPs and
collective bytes (``cost_analysis`` counts loop bodies once — see hlo.py);
memory bytes = 2x trip-weighted materialized result bytes (one write + one
read per HBM buffer). All terms are PER-DEVICE per step: the parsed HLO is
the per-device partitioned program, so no further division by chips.

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (and ~25 GB/s/link DCN for the cross-pod axis).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Sequence, Tuple

from repro.configs.base import ModelConfig, ShapeConfig
from repro.configs.registry import get_arch
from repro.configs.shapes import get_shape

PEAK_FLOPS = 197e12            # bf16 per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link
DCN_BW = 25e9                  # cross-pod


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float          # useful 6ND-style flops (global)
    hlo_flops: float            # per-device, trip-weighted
    hlo_bytes: float            # per-device traffic estimate
    collective_bytes: float     # per-device
    chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_lb(self) -> float:
        """Roofline step-time lower bound (no overlap assumption)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs * chips): how much compiled compute is
        useful — catches remat/redundancy waste."""
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu_bound(self) -> float:
        """Best-achievable MFU at this roofline: useful flops / peak over
        the binding term."""
        t = self.step_time_lb
        if t <= 0:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS * t)

    def row(self) -> str:
        return (f"{self.arch:24s} {self.shape:12s} {self.mesh:10s} "
                f"{self.compute_s:9.4f} {self.memory_s:9.4f} "
                f"{self.collective_s:10.4f} {self.dominant:10s} "
                f"{self.useful_flops_ratio:6.3f} {self.mfu_bound:6.3f}")


def model_flops(cfg: ModelConfig, shape: ShapeConfig,
                lora_rank: int = 16) -> float:
    """Useful FLOPs per step: training 4ND (frozen base: fwd + act-grad
    only) + 6N_lora*D; prefill 2ND; decode 2N per token * batch."""
    n_active = cfg.param_count(active_only=True)
    n_lora = cfg.lora_param_count(lora_rank)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return (4.0 * n_active + 6.0 * n_lora) * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * (n_active + n_lora) * tokens
    # decode: one token per sequence
    return 2.0 * (n_active + n_lora) * shape.global_batch


@dataclasses.dataclass
class RankLocalSavings:
    """Adapter-GEMM FLOP/byte accounting for one slot stack, true-rank
    (rank-local kernels: dead rank tiles skip) vs r_max-padded (the
    historical zero-masked execution, every slot billed at r_max).

    FLOPs: 6 * N_lora(r) * tokens per slot (fwd XA/SB + bwd dS/dX/dA/dB).
    Bytes (estimate): adapter params 8B/param (bf16 fwd read + bwd read +
    fp32 grad write) plus the rank-scaled S/dS activations (~8B per
    token*rank per adapter site). Arithmetic intensity = FLOPs/byte —
    padding inflates both axes, so the savings report shows how much MXU
    work AND HBM traffic true-rank compute reclaims per config."""
    arch: str
    r_max: int
    ranks: Tuple[int, ...]
    tokens_per_slot: int
    flops_true: float
    flops_padded: float
    bytes_true: float
    bytes_padded: float

    @property
    def flop_saving(self) -> float:
        return self.flops_padded / self.flops_true if self.flops_true else 0.0

    @property
    def byte_saving(self) -> float:
        return self.bytes_padded / self.bytes_true if self.bytes_true else 0.0

    @property
    def intensity_true(self) -> float:
        return self.flops_true / self.bytes_true if self.bytes_true else 0.0

    @property
    def intensity_padded(self) -> float:
        return (self.flops_padded / self.bytes_padded
                if self.bytes_padded else 0.0)

    def row(self) -> str:
        rk = ",".join(map(str, self.ranks))
        return (f"{self.arch:24s} r_max={self.r_max:<3d} ranks=[{rk:20s}] "
                f"flops x{self.flop_saving:5.2f} bytes x{self.byte_saving:5.2f} "
                f"AI {self.intensity_padded:6.1f}->{self.intensity_true:6.1f}")


def _adapter_gemm_accounting(cfg: ModelConfig, rank: int,
                             tokens: int) -> Tuple[float, float]:
    """(FLOPs, bytes) of one adapter's six grouped GEMMs at ``rank``."""
    n = cfg.lora_param_count(rank)
    flops = 6.0 * n * tokens
    sites = len(cfg.lora.targets) * cfg.num_layers
    bytes_ = 8.0 * n + 8.0 * tokens * rank * sites
    return flops, bytes_


def ranklocal_savings(cfg: ModelConfig, ranks: Sequence[int],
                      tokens_per_slot: int = 4096,
                      r_max: int = 0) -> RankLocalSavings:
    """Rank-local vs r_max-padded adapter arithmetic for a slot stack
    with per-slot true ranks ``ranks`` (each slot trains
    ``tokens_per_slot`` tokens per step)."""
    r_max = r_max or cfg.lora.r_max
    ft = fp = bt = bp = 0.0
    for r in ranks:
        f, b = _adapter_gemm_accounting(cfg, min(int(r), r_max),
                                        tokens_per_slot)
        ft += f
        bt += b
        f, b = _adapter_gemm_accounting(cfg, r_max, tokens_per_slot)
        fp += f
        bp += b
    return RankLocalSavings(
        arch=cfg.name, r_max=r_max, ranks=tuple(int(r) for r in ranks),
        tokens_per_slot=tokens_per_slot, flops_true=ft, flops_padded=fp,
        bytes_true=bt, bytes_padded=bp)


def from_dryrun(d: Dict) -> Roofline:
    """Build the roofline from a dryrun JSON record (analyzer fields)."""
    chips = 512 if d["mesh"] == "pod2x16x16" else 256
    cfg = get_arch(d["arch"])
    shape = get_shape(d["shape"])
    flops = d["flops"]
    bytes_ = d["hlo_bytes"]
    coll = d["collective_traffic"]
    return Roofline(
        arch=d["arch"], shape=d["shape"], mesh=d["mesh"],
        compute_s=flops / PEAK_FLOPS,
        memory_s=bytes_ / HBM_BW,
        collective_s=coll / ICI_BW,
        model_flops=model_flops(cfg, shape),
        hlo_flops=flops, hlo_bytes=bytes_, collective_bytes=coll,
        chips=chips)


HEADER = (f"{'arch':24s} {'shape':12s} {'mesh':10s} "
          f"{'compute_s':>9s} {'memory_s':>9s} {'collect_s':>10s} "
          f"{'dominant':10s} {'useful':>6s} {'MFU<=':>6s}")


def load_all(dryrun_dir: str) -> Dict[str, Roofline]:
    out = {}
    for mesh_name in sorted(os.listdir(dryrun_dir)):
        mdir = os.path.join(dryrun_dir, mesh_name)
        if not os.path.isdir(mdir):
            continue
        for fn in sorted(os.listdir(mdir)):
            if not fn.endswith(".json"):
                continue
            with open(os.path.join(mdir, fn)) as f:
                d = json.load(f)
            if not d.get("ok"):
                continue
            r = from_dryrun(d)
            out[f"{r.arch}|{r.shape}|{r.mesh}"] = r
    return out
