"""Roofline report CLI: load dry-run artifacts, print the baseline table,
nominate hillclimb candidates.

    PYTHONPATH=src python -m repro.roofline.report [--mesh pod16x16] [--md]
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List

from repro.roofline.analysis import (HEADER, Roofline, load_all,
                                     ranklocal_savings)
from repro.sched.profiler import PEAK_FLOPS_BF16

DEFAULT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")
_REPO_ROOT = os.path.join(os.path.dirname(__file__), "..", "..", "..")
DEFAULT_AUTOTUNE = os.path.join(_REPO_ROOT, "BENCH_autotune.json")

# the rank-sweep tuning mix the rank-local bench trains (r = 4..64)
RANK_SWEEP = (4, 8, 16, 32, 64)


def print_ranklocal(archs: List[str], tokens_per_slot: int = 4096,
                    md: bool = False) -> None:
    """Rank-local FLOP/byte savings per config: the adapter-GEMM work the
    dead rank-tile skip reclaims vs r_max-padded execution on the
    rank-sweep mix, and the arithmetic-intensity shift that comes with
    it."""
    from repro.configs.registry import get_arch
    rows = [ranklocal_savings(get_arch(a), RANK_SWEEP, tokens_per_slot)
            for a in archs]
    print("\nRank-local adapter savings (true-rank vs r_max-padded, "
          f"ranks={list(RANK_SWEEP)}, {tokens_per_slot} tok/slot):")
    if md:
        print("| arch | r_max | flops saved | bytes saved | AI padded | "
              "AI true |")
        print("|---|---|---|---|---|---|")
        for r in rows:
            print(f"| {r.arch} | {r.r_max} | x{r.flop_saving:.2f} | "
                  f"x{r.byte_saving:.2f} | {r.intensity_padded:.1f} | "
                  f"{r.intensity_true:.1f} |")
    else:
        for r in rows:
            print("  " + r.row())


def print_autotune_gap(path: str, md: bool = False,
                       mfu: float = 0.4) -> None:
    """Tuned-vs-default-vs-ceiling gap per autotuned shape key, from the
    bench artifact (``benchmarks/bench_autotune.py`` -> BENCH_autotune.json).
    Three columns of headroom: what the tile-plan autotuner already
    reclaimed over the static constants (tuned/default), and what remains
    between the tuned kernels and the roofline ceiling (the target MFU
    fraction of peak MXU throughput) — the gap left for Mosaic-level
    tuning to chase. Harness note: the artifact's timings come from
    whatever backend produced it (interpret mode on this CPU container, so
    absolute ceiling gaps are astronomical; the tuned/default ratio is the
    portable signal)."""
    if not os.path.exists(path):
        print(f"\n(no autotune artifact at {path}; run "
              "benchmarks/bench_autotune.py to populate the gap section)")
        return
    with open(path) as f:
        bench = json.load(f)
    ceiling = PEAK_FLOPS_BF16 * mfu
    sweeps = bench.get("kernel_sweeps", [])
    print(f"\nTile-plan autotune gap (ceiling = {mfu:.0%} of peak MXU, "
          f"{ceiling/1e12:.1f} TFLOP/s; backend: "
          f"{bench.get('backend', 'unknown')}):")
    if md:
        print("| key | default GF/s | tuned GF/s | tuned/default | "
              "bitwise | x to ceiling |")
        print("|---|---|---|---|---|---|")
    for s in sweeps:
        key = (f"d{s['d_in']}x{s['d_out']} r{s['r_max']} Z{s['Z']} "
               f"T{s['tokens']}")
        dflt = s["default_flops_per_s"]
        tuned = s["tuned_flops_per_s"]
        gap = ceiling / max(tuned, 1e-12)
        if md:
            print(f"| {key} | {dflt/1e9:.3f} | {tuned/1e9:.3f} | "
                  f"x{s['speedup']:.2f} | {s['bitwise_equal']} | "
                  f"x{gap:.3g} |")
        else:
            print(f"  {key:28s} default {dflt/1e9:8.3f} GF/s  tuned "
                  f"{tuned/1e9:8.3f} GF/s  x{s['speedup']:.2f}  "
                  f"bitwise={s['bitwise_equal']}  ceiling-gap x{gap:.3g}")
    fit = bench.get("fitted_model")
    if fit:
        print(f"  fitted step model: rel err {fit['fitted_rel_error']:.4f} "
              f"vs analytic {fit['analytic_rel_error']:.4f} on "
              f"{fit['heldout_points']} held-out points "
              f"({fit['observations']} training observations)")


def pick_hillclimb(rows: List[Roofline]) -> Dict[str, Roofline]:
    """The three §Perf pairs, chosen among compute-carrying shapes
    (train/prefill — decode MFU is intrinsically ~0 and would always win):
      * worst roofline fraction: lowest bounded MFU,
      * most collective-bound: largest absolute collective term,
      * paper-representative: the multi-LoRA train_4k with the largest
        model (the paper's AP setting at production scale).
    Ties across categories resolve to distinct pairs."""
    big = [r for r in rows if r.shape in ("train_4k", "prefill_32k")]
    rep = max((r for r in big if r.shape == "train_4k"),
              key=lambda r: r.model_flops)
    coll = max((r for r in big if (r.arch, r.shape) !=
                (rep.arch, rep.shape)), key=lambda r: r.collective_s)
    taken = {(rep.arch, rep.shape), (coll.arch, coll.shape)}
    rest = [r for r in big if (r.arch, r.shape) not in taken]
    # prefer a pair whose dominant term differs from the two collective
    # picks, so the three hillclimbs exercise different bottlenecks
    diverse = [r for r in rest if r.dominant not in (rep.dominant,
                                                     coll.dominant)]
    worst = min(diverse or rest, key=lambda r: r.mfu_bound)
    return {"worst-roofline": worst, "most-collective-bound": coll,
            "paper-representative": rep}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=DEFAULT_DIR)
    ap.add_argument("--mesh", default="pod16x16",
                    help="mesh for the main table (roofline is single-pod)")
    ap.add_argument("--md", action="store_true", help="markdown output")
    ap.add_argument("--autotune", default=DEFAULT_AUTOTUNE,
                    help="BENCH_autotune.json for the tuned-vs-default-vs-"
                         "ceiling gap section")
    args = ap.parse_args()

    rl = load_all(args.dir)
    rows = sorted((r for r in rl.values() if r.mesh == args.mesh),
                  key=lambda r: (r.arch, r.shape))
    if args.md:
        print("| arch | shape | compute_s | memory_s | collective_s | "
              "dominant | useful | MFU<= |")
        print("|---|---|---|---|---|---|---|---|")
        for r in rows:
            print(f"| {r.arch} | {r.shape} | {r.compute_s:.4f} | "
                  f"{r.memory_s:.4f} | {r.collective_s:.4f} | {r.dominant} |"
                  f" {r.useful_flops_ratio:.3f} | {r.mfu_bound:.3f} |")
    else:
        print(HEADER)
        for r in rows:
            print(r.row())
    print(f"\n{len(rows)} combos on {args.mesh} "
          f"(+{sum(1 for r in rl.values() if r.mesh != args.mesh)} on the "
          f"other mesh)")
    picks = pick_hillclimb(rows)
    print("\nHillclimb candidates (§Perf):")
    for why, r in picks.items():
        print(f"  {why:24s} -> {r.arch} x {r.shape} "
              f"(dominant={r.dominant}, MFU<={r.mfu_bound:.3f})")
    print_ranklocal(sorted({r.arch for r in rows}), md=args.md)
    print_autotune_gap(args.autotune, md=args.md)


if __name__ == "__main__":
    main()
