"""Per-slot losses for multi-adapter training.

The structural invariant that makes ALTO's slot training sound: the total
backward loss is a SUM of per-slot means (masked by ``active``), and slot
z's loss depends only on adapter z (the base is frozen), so each adapter's
gradient is exactly what it would be if trained alone — co-location changes
throughput, not optimization. (Verified by tests/test_isolation.py.)
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M


def sft_loss(cfg: ModelConfig, params: Dict, lora: Dict, batch: Dict,
             active: jnp.ndarray, remat: bool = True
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (total scalar for backward, per-slot mean NLL [Z] fp32)."""
    h, aux, _ = M.forward(
        cfg, params, lora, batch["tokens"],
        positions=batch.get("positions"),
        modal_embeds=batch.get("modal_embeds"), remat=remat)
    nll_sum, cnt = M.per_slot_xent(cfg, params, h, batch["labels"])
    per_slot = nll_sum / jnp.maximum(cnt, 1.0)
    total = jnp.sum(per_slot * active.astype(jnp.float32))
    if cfg.is_moe:
        total = total + cfg.moe.router_aux_weight * aux
    return total, per_slot


def dpo_loss(cfg: ModelConfig, params: Dict, lora: Dict, batch: Dict,
             active: jnp.ndarray, beta: float = 0.1, remat: bool = True
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Direct Preference Optimization over (chosen, rejected) pairs.

    batch: tokens_chosen/labels_chosen/tokens_rejected/labels_rejected,
    each [Z, b, S]. The REFERENCE policy is the frozen base model — the
    LoRA-free forward — so no reference copy is ever materialized (the
    TPU-native analogue of the paper's DPO setup).

    Returns (total scalar, per-slot mean -log sigmoid margin [Z]).
    """
    def seq_logp(lora_tree, tokens, labels):
        h, _, _ = M.forward(cfg, params, lora_tree, tokens, remat=remat)
        nll_sum, cnt = M.per_slot_xent(cfg, params, h, labels)
        return -nll_sum   # sum log p per slot

    lp_c = seq_logp(lora, batch["tokens_chosen"], batch["labels_chosen"])
    lp_r = seq_logp(lora, batch["tokens_rejected"], batch["labels_rejected"])
    # reference = base model (empty adapter set)
    ref_c = seq_logp({}, batch["tokens_chosen"], batch["labels_chosen"])
    ref_r = seq_logp({}, batch["tokens_rejected"], batch["labels_rejected"])
    margin = beta * ((lp_c - ref_c) - (lp_r - ref_r))
    per_slot = -jnp.log(jnp.clip(jnp.asarray(
        1.0 / (1.0 + jnp.exp(-margin)), jnp.float32), 1e-12, 1.0))
    total = jnp.sum(per_slot * active.astype(jnp.float32))
    return total, per_slot


def dpo_reward_accuracy(margin_per_slot: jnp.ndarray) -> jnp.ndarray:
    return (margin_per_slot > 0).astype(jnp.float32)
