"""ALTO engine: the declarative LoRA-as-a-Service API (paper Listing 1).

    import repro.core.engine as alto
    engine = alto.Engine(strategy="adapter_parallel", total_gpus=8)
    tasks = [alto.Task(model="paper-llama-tiny", num_gpus=1,
                       dataset=..., search_space={...})]
    early_exit = alto.EarlyExit(warmup_ratio=0.05)
    schedule = engine.schedule(tasks, method="cp")
    best = engine.batched_execution(tasks, schedule, early_exit)

The engine profiles each task (duration d_i, GPU need g_i), computes the
inter-task placement, instantiates one BatchedExecutor per task hosting
multiple jobs on a shared base-model replica, monitors loss trajectories,
and returns the best adapter per task — all transparently to the user.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Dict, List, Optional, Sequence, Union

import jax

from repro.configs.base import ModelConfig, TrainConfig
from repro.configs.registry import get_arch
from repro.core.early_exit import EarlyExitConfig
from repro.core.executor import BatchedExecutor, TaskResult
from repro.data.synthetic import TaskDataset, make_task_dataset
from repro.models import model as M
from repro.sched import fitted as fitted_models
from repro.sched import profiler
from repro.sched.cluster import ColocationSpec, ExecutorTaskDriver
from repro.sched.events import ProgressEvent
from repro.sched.inter_task import Schedule, TaskSpec, solve
from repro.sched.intra_task import fit_memory_model

EarlyExit = EarlyExitConfig     # paper-API alias


@dataclasses.dataclass
class Task:
    """One user task: base model x dataset x hyperparameter search space."""
    model: Union[str, ModelConfig]
    dataset: Union[str, TaskDataset]
    search_space: Dict[str, List]
    num_gpus: int = 1
    max_steps: int = 60
    num_slots: int = 0              # 0 => memory-model-driven (paper §A.3)
    seed: int = 0
    name: str = ""
    loss_kind: str = "sft"
    device_memory: float = 16 * 2 ** 30   # HBM per device (v5e default)

    def model_config(self) -> ModelConfig:
        return (self.model if isinstance(self.model, ModelConfig)
                else get_arch(self.model))

    def resolved_dataset(self) -> TaskDataset:
        if isinstance(self.dataset, TaskDataset):
            return self.dataset
        cfg = self.model_config()
        return make_task_dataset(self.dataset, cfg.vocab_size, seq_len=64,
                                 seed=self.seed)

    def jobs(self) -> Dict[str, TrainConfig]:
        """Expand the search space into one job per configuration."""
        keys = sorted(self.search_space)
        out: Dict[str, TrainConfig] = {}
        for combo in itertools.product(*(self.search_space[k] for k in keys)):
            kw = dict(zip(keys, combo))
            tc = TrainConfig(
                learning_rate=kw.get("lr", 1e-4),
                lora_rank=kw.get("rank", 16),
                per_adapter_batch=kw.get("batch_size", 4),
                weight_decay=kw.get("wd", 0.01),
                max_steps=self.max_steps,
                seed=kw.get("seed", self.seed))
            out[f"{self.task_name}/{tc.label()}"] = tc
        return out

    @property
    def task_name(self) -> str:
        if self.name:
            return self.name
        m = self.model if isinstance(self.model, str) else self.model.name
        d = self.dataset if isinstance(self.dataset, str) else self.dataset.name
        return f"{m}:{d}"


@dataclasses.dataclass
class EngineReport:
    task_results: Dict[str, TaskResult]
    schedule: Schedule
    makespan_estimate: float
    wall_time_s: float
    # execution observability — populated on BOTH paths (static fills
    # utilization from the plan's area and has zero replans / no events)
    execution: str = "static"
    virtual_makespan: Optional[float] = None
    utilization: float = 0.0
    replans: int = 0
    events: List[ProgressEvent] = dataclasses.field(default_factory=list)


class Engine:
    def __init__(self, strategy: str = "adapter_parallel",
                 total_gpus: int = 8, eval_every: int = 5,
                 profile_store: Optional[profiler.ProfileStore] = None,
                 fitted: bool = False):
        assert strategy in ("adapter_parallel", "single_gpu")
        self.strategy = strategy
        self.total_gpus = total_gpus
        self.eval_every = eval_every
        # fitted=True: admission budgets (memory_model -> ColocationSpec.mem
        # -> admit_cross_task / backfill / plan_fused) swap to the
        # profile-fitted (k0, k1, k2) models in sched/fitted.py once the
        # ProfileStore holds enough step observations for the profile key;
        # the analytic models stay the fallback below the guard.
        self.fitted = fitted
        self.profile_store = (profile_store if profile_store is not None
                              else profiler.ProfileStore())
        self._param_cache: Dict[str, Dict] = {}
        self._dataset_cache: Dict[str, TaskDataset] = {}
        self._mem_cache: Dict[str, object] = {}

    def _dataset(self, task: "Task") -> TaskDataset:
        """Resolve a task's dataset once per engine (profiling, slot
        sizing, and execution all need it; generation is deterministic)."""
        if task.task_name not in self._dataset_cache:
            self._dataset_cache[task.task_name] = task.resolved_dataset()
        return self._dataset_cache[task.task_name]

    # ---- intra-task slot sizing (paper §A.3 memory model) -------------------
    def memory_model(self, task: Task):
        """Fitted M_hat(B) = k0 + k1*B*L from analytic profile points (the
        CPU stand-in for torch.cuda.max_memory_reserved sweeps). Shared by
        slot sizing, the executor's backfill policy, and cross-task
        co-location admission."""
        key = task.task_name
        if key not in self._mem_cache:
            cfg = task.model_config()
            jobs = task.jobs()
            bsz = max(tc.per_adapter_batch for tc in jobs.values())
            ds = self._dataset(task)
            seq = ds.train.shape[1] - 1
            pts = [(z * bsz, profiler.analytic_peak_memory(
                cfg, z, bsz, seq, task.num_gpus)) for z in (1, 2, 4, 8)]
            self._mem_cache[key] = fit_memory_model(
                pts, seq, capacity=task.device_memory)
        mem = self._mem_cache[key]
        if self.fitted:
            # swap in the profile-fitted rank-aware M_hat once the store
            # has enough observed steps for this (arch, gpus); r_max frames
            # the fit so rank-unknown requests stay pessimistically billed.
            # (Not memoized here: fitted.py caches through the store's
            # versioned spec cache, which record_step invalidates.)
            frame = dataclasses.replace(
                mem, r_max=task.model_config().lora.r_max)
            return fitted_models.fitted_memory_model(
                self.profile_store, self.profile_key(task), frame)
        return mem

    def pick_slots(self, task: Task) -> int:
        """Admit the largest slot count whose total batch fits the memory
        model's safety margin (bounded by the search-space size)."""
        if task.num_slots:
            return task.num_slots
        jobs = task.jobs()
        bsz = max(tc.per_adapter_batch for tc in jobs.values())
        max_total = self.memory_model(task).max_batch()
        z = max(min(max_total // max(bsz, 1), len(jobs), 16), 1)
        return int(z)

    def colocation_spec(self, task: Task) -> ColocationSpec:
        """How this task fuses onto a shared frozen-backbone replica.

        The fuse key carries only what the fused step genuinely requires
        — (arch, GPU demand, loss kind). Per-adapter batch size and seq
        len are NOT in the key anymore: slots are ragged, so tasks with
        different widths co-train in one step and the widths instead
        enter §A.3 admission as a token budget (b x seq per slot, checked
        against the replica's token-linear memory model). The replica's
        physical slot capacity is the memory model's bound (NOT capped by
        this task's own search-space size — a small task's replica has
        room for co-tenants)."""
        cfg = task.model_config()
        jobs = task.jobs()
        bsz = max(tc.per_adapter_batch for tc in jobs.values())
        ds = self._dataset(task)
        seq = ds.train.shape[1] - 1
        mem = self.memory_model(task)
        replica = max(min(mem.max_batch() // max(bsz, 1), 16), 1)
        return ColocationSpec(
            fuse_key=(cfg.name, task.num_gpus, task.loss_kind),
            per_adapter_batch=bsz,
            slots_needed=self.pick_slots(task),
            replica_slots=int(replica),
            mem=mem, seq_len=seq,
            lora_rank=self.task_rank(task))

    # ---- profiling + inter-task scheduling ---------------------------------
    def profile_key(self, task: Task) -> tuple:
        """ProfileStore key: feedback generalizes across tasks that share a
        base model and GPU demand (what step time and lifecycle shrink
        actually depend on)."""
        return (task.model_config().name, task.num_gpus)

    def task_rank(self, task: Task) -> int:
        """The task's widest TRUE adapter rank (max over its search-space
        jobs, capped at r_max) — the rank its duration estimates and its
        rank-aware admission charge are billed at."""
        cfg = task.model_config()
        return max(min(tc.lora_rank, cfg.lora.r_max)
                   for tc in task.jobs().values())

    def profiled_step_time(self, task: Task) -> float:
        """Analytic per-step seconds driving the virtual timeline. Kept
        analytic on purpose: for real executors the realized virtual step
        time IS this value, so "observing" it would be circular, and wall
        step times live on a different clock (`ProfileStore.
        wall_step_time`). Duration feedback flows through the store's
        realized/worst-case ratio instead. Rank-aware: the LoRA term is
        billed at the task's true rank (rank-local kernels skip the
        padded rank tiles), not r_max."""
        cfg = task.model_config()
        jobs = task.jobs()
        bsz = max(tc.per_adapter_batch for tc in jobs.values())
        Z = self.pick_slots(task)
        ds = self._dataset(task)
        return profiler.profile_task(cfg, Z, bsz, ds.train.shape[1] - 1,
                                     task.num_gpus,
                                     rank=self.task_rank(task)).step_time_s

    def profile_raw(self, task: Task,
                    early_exit: EarlyExitConfig = EarlyExitConfig()
                    ) -> TaskSpec:
        """Worst-case TaskSpec (no duration feedback), analytic step time.
        Cached per (task name, early-exit config) in the ProfileStore so
        schedule() and batched_execution() profile each task once."""
        cache_key = (task.task_name, early_exit, "raw")
        hit = self.profile_store.get_spec(cache_key)
        if hit is not None:
            return hit
        jobs = task.jobs()
        Z = self.pick_slots(task)
        # duration: warmup waves for all K + full budget for the retained
        # top-k survivors (the scheduler's worst case: no pattern exits;
        # Pattern-3 selection is deterministic so it IS the worst case).
        # Pass the same early_exit here and to batched_execution — the
        # elastic runtime treats this duration as the residual upper bound.
        K = len(jobs)
        warmup = early_exit.warmup_steps(task.max_steps)
        steps = profiler.lifecycle_steps(K, Z, warmup, task.max_steps,
                                         survivors=early_exit.top_k(K))
        dur = profiler.residual_duration(steps, self.profiled_step_time(task))
        spec = TaskSpec(name=task.task_name, duration=max(dur, 1e-9),
                        gpus=task.num_gpus)
        self.profile_store.put_spec(cache_key, spec)
        return spec

    def profile(self, task: Task,
                early_exit: EarlyExitConfig = EarlyExitConfig()) -> TaskSpec:
        """TaskSpec for the inter-task solver: the worst case scaled by the
        ProfileStore's observed realized/worst-case ratio, so later
        schedules in a session use feedback instead of the analytic
        estimate."""
        raw = self.profile_raw(task, early_exit)
        scaled = self.profile_store.scaled_duration(
            self.profile_key(task), raw.duration)
        if scaled == raw.duration:
            return raw
        return dataclasses.replace(raw, duration=scaled)

    def schedule(self, tasks: Sequence[Task], method: str = "cp",
                 early_exit: EarlyExitConfig = EarlyExitConfig()
                 ) -> Schedule:
        specs = [self.profile(t, early_exit) for t in tasks]
        sched = solve(specs, self.total_gpus, method)
        sched.validate(self.total_gpus)
        return sched

    # ---- execution ----------------------------------------------------------
    def _base_params(self, cfg: ModelConfig, seed: int = 0) -> Dict:
        if cfg.name not in self._param_cache:
            self._param_cache[cfg.name] = M.init_params(
                jax.random.PRNGKey(seed), cfg)
        return self._param_cache[cfg.name]

    def _make_executor(self, task: Task,
                       early_exit: EarlyExitConfig) -> BatchedExecutor:
        cfg = task.model_config()
        jobs = task.jobs()
        Z = self.pick_slots(task)
        bsz = max(tc.per_adapter_batch for tc in jobs.values())
        return BatchedExecutor(
            cfg, self._base_params(cfg, task.seed),
            self._dataset(task), Z=Z, per_adapter_batch=bsz,
            ee=early_exit, eval_every=self.eval_every, seed=task.seed,
            loss_kind=task.loss_kind, mem_model=self.memory_model(task))

    def executor_driver_factory(self, task: Task,
                                early_exit: EarlyExitConfig):
        """Driver factory for the elastic runtime / tuning service: wraps a
        freshly built BatchedExecutor in an ExecutorTaskDriver converting
        executor steps to virtual seconds at the profiled step time."""
        def factory():
            return ExecutorTaskDriver(
                task.task_name, self._make_executor(task, early_exit),
                task.jobs(), task.max_steps, self.profiled_step_time(task))
        return factory

    def resumed_driver_factory(self, task: Task,
                               early_exit: EarlyExitConfig, state,
                               start_chunk: int = 0):
        """Driver factory continuing a task from a durable mid-task
        checkpoint (``checkpoint/taskstate.py`` ``(tree, meta)`` state):
        the fresh executor's lifecycle is restored to the saved step
        before any chunk runs, so the replayed chunk stream is the
        uninterrupted run's tail, bitwise."""
        def factory():
            return ExecutorTaskDriver(
                task.task_name, self._make_executor(task, early_exit),
                task.jobs(), task.max_steps, self.profiled_step_time(task),
                resume_state=state, start_chunk=start_chunk)
        return factory

    def batched_execution(self, tasks: Sequence[Task], schedule: Schedule,
                          early_exit: EarlyExitConfig = EarlyExitConfig(),
                          strategy: str = "elastic") -> EngineReport:
        """Execute every task and return best adapters.

        Since the service redesign this is a thin wrapper over a one-shot
        ``TuningService`` session: every task is submitted at t=0 and the
        session is drained to idle. strategy="elastic" (default) runs the
        event loop with the strict anomaly-safe adoption rule
        (delay_delta=None), preserving the elastic<=static makespan
        guarantee; strategy="static" keeps the precomputed plan for A/B:
        tasks run to completion in schedule start order and the makespan
        estimate is the plan's worst case.

        Single-host note: training is sequential on this container either
        way; the strategies differ in the *virtual cluster timeline*
        (admission order, virtual makespan, utilization accounting), which
        is what the cluster benchmarks compare.
        """
        assert strategy in ("elastic", "static"), strategy
        t0 = time.time()
        by_name = {t.task_name: t for t in tasks}
        if strategy == "static":
            results: Dict[str, TaskResult] = {}
            for placement in sorted(schedule.placements,
                                    key=lambda p: p.start):
                task = by_name[placement.task.name]
                ex = self._make_executor(task, early_exit)
                results[task.task_name] = ex.run_task(
                    task.task_name, task.jobs(), task.max_steps)
            area = sum(p.task.duration * p.task.gpus
                       for p in schedule.placements)
            util = (area / (self.total_gpus * schedule.makespan)
                    if schedule.makespan > 0 else 0.0)
            return EngineReport(
                task_results=results, schedule=schedule,
                makespan_estimate=schedule.makespan,
                wall_time_s=time.time() - t0,
                execution="static", virtual_makespan=schedule.makespan,
                utilization=util)

        from repro.core.service import TuningService
        # colocate=False: the batch A/B contract is exclusive placement
        # under the strict adoption rule; shared-replica fusion is the
        # service path's lever (TuningService defaults it on)
        service = TuningService(engine=self, delay_delta=None,
                                colocate=False)
        for placement in schedule.placements:
            task = by_name[placement.task.name]
            # The schedule may have been solved under a different
            # EarlyExitConfig than the one now executing (warmup/selection
            # shape the lifecycle). Seed the runtime's residual estimate
            # with the worst case of both so it stays a true upper bound —
            # otherwise the replanner would project GPUs free too early.
            # (raw: the service applies the feedback scale exactly once)
            exec_spec = self.profile_raw(task, early_exit)
            spec = dataclasses.replace(
                placement.task,
                duration=max(placement.task.duration, exec_spec.duration))
            service.submit(task, at=0.0, early_exit=early_exit, spec=spec)
        report = service.run_until_idle(initial=schedule)
        return EngineReport(
            task_results=dict(report.task_results), schedule=schedule,
            makespan_estimate=schedule.makespan,
            wall_time_s=time.time() - t0,
            execution="elastic", virtual_makespan=report.makespan,
            utilization=report.utilization, replans=report.replans,
            events=report.events)
