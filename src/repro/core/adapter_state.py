"""Slot-stacked adapter runtime state + host-side slot management.

Fixed ``Z`` device slots hold adapters with static shapes (r_max-padded), so
the early-exit controller can admit/evict/rotate jobs with pure functional
array updates — never a recompile. Rotated-out jobs are snapshotted to host
(params + optimizer moments + step count) and restored bit-exactly when
they continue training (paper §5.2: survivors "carry over their optimizer
states and loss histories").

Layer contract — SlotSnapshot bit-exactness: ``snapshot()`` followed by
``restore()`` reproduces the job's device state exactly (adapter params,
AdamW moments, step count, slot width/rank), on ANY slot of ANY same-shape
replica. Together with task-local lifecycle state (lane-indexed batch
streams, monitors, init keys) this is the primitive that makes slot-level
preemption and cross-replica migration invisible to the loss trajectory:
a migrated job's subsequent losses are bitwise identical to never moving
(tests/test_lora_isolation.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TrainConfig
from repro.core import lora as LORA
from repro.optim import adamw


@dataclasses.dataclass
class SlotSnapshot:
    """Host copy of one job's device state (for warmup rotation).

    ``per_adapter_batch``/``seq_len`` record the job's slot WIDTH — slots
    are ragged (variable-width) since co-located tasks may train with
    different batch sizes — so a restore re-establishes the exact same
    token footprint the job had before rotation."""
    job_id: str
    lora: Dict                    # [L, ...] single-adapter tree
    mu: Dict
    nu: Dict
    count: int
    rank: int
    per_adapter_batch: int = 0
    seq_len: int = 0


def _x_slot(tree: Dict, slot: int) -> Dict:
    return jax.tree_util.tree_map(lambda x: np.asarray(x[:, slot]), tree)


def _i_slot(tree: Dict, slot: int, sub: Dict) -> Dict:
    return jax.tree_util.tree_map(
        lambda full, one: full.at[:, slot].set(jnp.asarray(one)), tree, sub)


class SlotManager:
    """Owns the device arrays for one executor's Z adapter slots.

    Slots are tagged with the *task* that owns them (``slot_tasks``) so one
    frozen-backbone replica can host adapter slots belonging to different
    tasks concurrently (cross-task co-location): the shared executor
    attributes per-slot losses, checkpoints, and evictions to the owning
    task's lifecycle through these tags.

    Slot WIDTH is a per-slot property (``slot_b``/``slot_seq``): co-located
    tasks may train with different per-adapter batch sizes and seq lens
    (ragged slots). The executor packs each slot's own (b, seq) rows into
    its lane and routes per-slot token-row counts to the ragged grouped-
    GEMM path; ``slot_tokens`` is what admission budgets against."""

    def __init__(self, cfg: ModelConfig, Z: int,
                 target_shapes: Dict, key: jax.Array):
        self.cfg = cfg
        self.Z = Z
        self.target_shapes = target_shapes
        self.ranks = jnp.zeros((Z,), jnp.int32)
        self.active = jnp.zeros((Z,), jnp.int32)
        self.hp = adamw.SlotHParams.broadcast(Z)
        self.lora = LORA.init_lora_tree(
            key, cfg, Z, jnp.zeros((Z,), jnp.int32), target_shapes)
        self.opt_state = adamw.init_state(self.lora, Z)
        self.slot_jobs: List[Optional[str]] = [None] * Z
        self.slot_tasks: List[Optional[str]] = [None] * Z
        self.slot_b: List[int] = [0] * Z        # per-slot batch width
        self.slot_seq: List[int] = [0] * Z      # per-slot seq len
        # host mirror of ``ranks``: the per-step rank-local dispatch and
        # the §A.3 rank accounting must not sync a device array
        self.slot_rank: List[int] = [0] * Z

    # ---- admission ---------------------------------------------------------
    def admit(self, slot: int, job_id: str, tc: TrainConfig,
              key: jax.Array, task: Optional[str] = None,
              b: int = 0, seq: int = 0) -> None:
        """Fresh job into a slot: new init, zeroed moments, job's hparams,
        and the job's own (b, seq) width."""
        assert self.slot_jobs[slot] is None, f"slot {slot} occupied"
        rank = min(tc.lora_rank, self.cfg.lora.r_max)
        one = LORA.init_lora_tree(
            key, self.cfg, 1, jnp.array([rank]), self.target_shapes)
        sub = jax.tree_util.tree_map(lambda x: x[:, 0], one)
        self.lora = _i_slot(self.lora, slot, sub)
        self.opt_state = adamw.reset_slot(self.opt_state, slot)
        self.ranks = self.ranks.at[slot].set(rank)
        self.active = self.active.at[slot].set(1)
        self.hp = self.hp.replace_slot(
            slot, lr=tc.learning_rate, wd=tc.weight_decay,
            beta1=tc.beta1, beta2=tc.beta2, grad_clip=tc.grad_clip)
        self.slot_jobs[slot] = job_id
        self.slot_tasks[slot] = task
        self.slot_b[slot] = b or tc.per_adapter_batch
        self.slot_seq[slot] = seq
        self.slot_rank[slot] = rank

    def restore(self, slot: int, snap: SlotSnapshot, tc: TrainConfig,
                task: Optional[str] = None) -> None:
        """Rotate a snapshotted job back in (bit-exact continuation,
        including its slot width)."""
        assert self.slot_jobs[slot] is None, f"slot {slot} occupied"
        self.lora = _i_slot(self.lora, slot, snap.lora)
        mu = _i_slot(self.opt_state.mu, slot, snap.mu)
        nu = _i_slot(self.opt_state.nu, slot, snap.nu)
        cnt = self.opt_state.count.at[slot].set(snap.count)
        self.opt_state = adamw.AdamWState(mu, nu, cnt)
        self.ranks = self.ranks.at[slot].set(snap.rank)
        self.active = self.active.at[slot].set(1)
        self.hp = self.hp.replace_slot(
            slot, lr=tc.learning_rate, wd=tc.weight_decay,
            beta1=tc.beta1, beta2=tc.beta2, grad_clip=tc.grad_clip)
        self.slot_jobs[slot] = snap.job_id
        self.slot_tasks[slot] = task
        self.slot_b[slot] = snap.per_adapter_batch or tc.per_adapter_batch
        self.slot_seq[slot] = snap.seq_len
        self.slot_rank[slot] = snap.rank

    # ---- eviction ----------------------------------------------------------
    def snapshot(self, slot: int) -> SlotSnapshot:
        job_id = self.slot_jobs[slot]
        assert job_id is not None
        return SlotSnapshot(
            job_id=job_id,
            lora=_x_slot(self.lora, slot),
            mu=_x_slot(self.opt_state.mu, slot),
            nu=_x_slot(self.opt_state.nu, slot),
            count=int(self.opt_state.count[slot]),
            rank=int(self.ranks[slot]),
            per_adapter_batch=self.slot_b[slot],
            seq_len=self.slot_seq[slot],
        )

    def evict(self, slot: int) -> None:
        """Drop a job: zero params + moments, deactivate (paper §5.2:
        'evicted adapters' parameters and optimizer states are discarded')."""
        self.lora = LORA.zero_slot(self.lora, slot)
        self.opt_state = adamw.reset_slot(self.opt_state, slot)
        self.active = self.active.at[slot].set(0)
        self.ranks = self.ranks.at[slot].set(0)
        self.slot_jobs[slot] = None
        self.slot_tasks[slot] = None
        self.slot_b[slot] = 0
        self.slot_seq[slot] = 0
        self.slot_rank[slot] = 0

    # ---- queries -----------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, j in enumerate(self.slot_jobs) if j is None]

    def slot_tokens(self, slot: int) -> int:
        """Token footprint of one slot per fused step (b * seq)."""
        return self.slot_b[slot] * max(self.slot_seq[slot], 1)

    def occupied_tokens(self) -> int:
        """Total tokens per fused step across occupied slots — the ragged
        quantity the §A.3 memory model budgets (M_hat is token-linear)."""
        return sum(self.slot_tokens(i) for i, j in
                   enumerate(self.slot_jobs) if j is not None)

    def mixed_rank(self, r_max: int) -> bool:
        """True iff some occupied slot's true rank is below r_max — the
        executor's per-step dispatch predicate for the rank-local LoRA
        path (a homogeneous full-rank mix has no dead rank tile to skip
        and stays on the bitwise-identical dense/ragged path)."""
        return any(j is not None and self.slot_rank[i] < r_max
                   for i, j in enumerate(self.slot_jobs))

    def occupied_rank_tokens(self) -> int:
        """Total rank-weighted FLOP-tokens per fused step (sum of
        b_z * seq_z * rank_z over occupied slots) — what the rank-aware
        §A.3 budget charges instead of tokens * r_max."""
        return sum(self.slot_tokens(i) * self.slot_rank[i]
                   for i, j in enumerate(self.slot_jobs) if j is not None)

    def occupied(self) -> Dict[str, int]:
        return {j: i for i, j in enumerate(self.slot_jobs) if j is not None}

    def occupied_of(self, task: Optional[str]) -> Dict[str, int]:
        """{job_id: slot} for the slots tagged with ``task``."""
        return {j: i for i, j in enumerate(self.slot_jobs)
                if j is not None and self.slot_tasks[i] == task}

    def adapter_of(self, job_id: str) -> Dict:
        slot = self.occupied()[job_id]
        return _x_slot(self.lora, slot)

    def adapter_at(self, slot: int) -> Dict:
        """Host copy of one slot's adapter params (task-tag agnostic — the
        shared executor addresses slots by index, never by job id, so
        co-located tasks may reuse job names without colliding)."""
        assert self.slot_jobs[slot] is not None, f"slot {slot} empty"
        return _x_slot(self.lora, slot)

    def adapters_of(self, task: Optional[str]) -> Dict[str, Dict]:
        """{job_id: [L, ...] adapter sub-tree} for one task's (possibly
        non-contiguous) slots on a shared executor."""
        occ = self.occupied_of(task)
        if not occ:
            return {}
        jobs = sorted(occ)
        stacked = LORA.gather_slots(self.lora, [occ[j] for j in jobs])
        return {j: jax.tree_util.tree_map(
                    lambda x, i=i: np.asarray(x[:, i]), stacked)
                for i, j in enumerate(jobs)}
