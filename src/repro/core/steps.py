"""Step builders: train / eval / prefill / serve.

These pure functions are what both the local engine (jax.jit) and the
multi-pod launcher (pjit with shardings, launch/train.py) compile. The base
model ``params`` is a frozen (non-differentiated) input; gradients flow only
through the slot-stacked LoRA tree.
"""
from __future__ import annotations

import contextlib
import functools
from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import lora as LORA
from repro.core import losses as LS
from repro.core.lora import mask_lora_tree
from repro.models import model as M
from repro.optim import adamw


def make_train_step(cfg: ModelConfig, *, loss_kind: str = "sft",
                    remat: bool = True) -> Callable:
    """train_step(params, lora, opt_state, hp, active, ranks, batch)
    -> (lora', opt_state', metrics{per_slot_loss[Z], grad_norm[Z]}).

    ``batch`` may carry ``slot_rows`` ([Z] int32, valid token rows per
    slot in flattened b*seq units): ragged slot widths — LoRA deltas are
    then computed over only each slot's own rows (the ragged grouped-GEMM
    path; zero delta and zero gradient on padding rows). It may also carry
    ``slot_ranks`` ([Z] int32, per-slot TRUE adapter ranks from the
    executor's SlotManager): LoRA deltas then confine each slot to its
    first ranks[z] rank rows/columns (the rank-local grouped-GEMM path —
    dead rank tiles skip the MXU, the padded rank region gets exactly
    zero gradient, and the post-step rank re-mask is redundant)."""
    loss_fn_inner = {"sft": LS.sft_loss, "dpo": LS.dpo_loss}[loss_kind]

    def train_step(params, lora, opt_state, hp: adamw.SlotHParams,
                   active: jnp.ndarray, ranks: jnp.ndarray, batch: Dict):
        batch = dict(batch)
        slot_rows = batch.pop("slot_rows", None)
        slot_ranks = batch.pop("slot_ranks", None)

        def loss_fn(lora_):
            total, per_slot = loss_fn_inner(cfg, params, lora_, batch,
                                            active, remat=remat)
            return total, per_slot

        with contextlib.ExitStack() as ctx:
            if slot_rows is not None:
                ctx.enter_context(LORA.ragged_rows(slot_rows))
            if slot_ranks is not None:
                ctx.enter_context(LORA.slot_ranks(slot_ranks))
            (_, per_slot), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(lora)
        norms = adamw.per_slot_global_norm(grads)
        masker = functools.partial(mask_lora_tree, ranks=ranks,
                                   r_max=cfg.lora.r_max)
        new_lora, new_opt = adamw.apply_updates(
            lora, grads, opt_state, hp, active,
            rank_masker=lambda t: masker(t))
        metrics = {"per_slot_loss": per_slot, "grad_norm": norms}
        return new_lora, new_opt, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, *, loss_kind: str = "sft") -> Callable:
    """eval_step(params, lora, active, batch) -> per-slot val loss [Z].

    ``batch`` may carry ``slot_ranks`` like the train step (eval rides the
    same rank-local LoRA path as training on mixed-rank replicas)."""
    loss_fn_inner = {"sft": LS.sft_loss, "dpo": LS.dpo_loss}[loss_kind]

    def eval_step(params, lora, active, batch):
        batch = dict(batch)
        slot_ranks = batch.pop("slot_ranks", None)
        ctx = (LORA.slot_ranks(slot_ranks) if slot_ranks is not None
               else contextlib.nullcontext())
        with ctx:
            _, per_slot = loss_fn_inner(cfg, params, lora, batch, active,
                                        remat=False)
        return per_slot

    return eval_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    """prefill_step(params, lora, batch) -> (last-token logits, cache)."""

    def prefill_step(params, lora, cache, batch):
        h, _, new_cache = M.forward(
            cfg, params, lora, batch["tokens"],
            positions=batch.get("positions"),
            modal_embeds=batch.get("modal_embeds"),
            cache=cache, remat=False)
        logits = M._unembed(cfg, params, h[:, :, -1])
        return logits, new_cache

    return prefill_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    """serve_step(params, lora, cache, tokens[Z,b], active=None)
    -> (logits, cache').

    ``active`` ([Z, b] bool) is the per-lane continuous-batching mask:
    inactive lanes neither write their cache nor advance their position
    (idle lanes stay bitwise frozen while live lanes decode). Requires a
    per-lane cache (``init_cache(..., per_lane=True)``)."""

    def serve_step(params, lora, cache, tokens, active=None):
        return M.decode_step(cfg, params, lora, cache, tokens,
                             active=active)

    return serve_step


def make_lane_prefill_step(cfg: ModelConfig) -> Callable:
    """lane_prefill(params, lora, cache, tokens[Z,b,P], lane_mask[Z,b],
    plens[Z,b]) -> (last-token logits, cache') — block prefill of a
    subset of lanes of a live per-lane cache (ragged prompt lengths via
    ``plens``, tokens right-padded to P); every other lane bitwise
    untouched."""

    def lane_prefill(params, lora, cache, tokens, lane_mask, plens):
        return M.prefill_lanes(cfg, params, lora, cache, tokens,
                               lane_mask, plens)

    return lane_prefill


def make_join_decode_step(cfg: ModelConfig) -> Callable:
    """join_decode(params, lora, cache, tokens[Z,b,P], lane_mask[Z,b],
    plens[Z,b], cur[Z,b], active[Z,b]) -> (prefill_greedy, logits,
    decode_greedy, cache') — block-prefill the masked lanes AND run one
    fused decode step over (active | joined) lanes in a SINGLE launch.

    Each joiner's first token is its greedy prefill argmax, chosen
    on-device and fed straight into the decode — no host round-trip
    between the prefill and the step that consumes its first token.
    Greedy joiners only (a sampled first token needs the host)."""

    def join_decode(params, lora, cache, tokens, lane_mask, plens, cur,
                    active):
        p_logits, cache = M.prefill_lanes(cfg, params, lora, cache,
                                          tokens, lane_mask, plens)
        p_greedy = jnp.argmax(p_logits, axis=-1)
        cur = jnp.where(lane_mask, p_greedy.astype(cur.dtype), cur)
        live = jnp.logical_or(active, lane_mask)
        logits, cache = M.decode_step(cfg, params, lora, cache, cur,
                                      active=live)
        return p_greedy, logits, jnp.argmax(logits, axis=-1), cache

    return join_decode
