"""Shared-backbone multi-task executor: Z adapter slots, many lifecycles.

Implements the full per-task ALTO lifecycle (paper §4-§6) on top of a
slot-multiplexing shared executor (paper's central claim: concurrent
tuning jobs over one frozen backbone expose optimizations single-job
designs cannot):

  * ``SharedBackboneExecutor`` owns the frozen params, the ``SlotManager``
    (Z slot-stacked adapters), and the jitted train/eval steps. Slots are
    tagged with the task that owns them, so adapter slots belonging to
    *different tasks* can be co-located on one backbone replica — the
    fused grouped-GEMM path trains them all in a single step, and slot
    isolation (tests/test_lora_isolation.py) guarantees each task's
    losses are bitwise identical to running alone.
  * ``TaskLifecycle`` is the per-task state machine — warmup with
    rotation, Pattern-3 selection at the warmup boundary, continue-
    training with online divergence/overfit detection and slot backfill —
    that admits and evicts slots *through* the executor. All of its
    decisions (batch streams, init keys, eval points) are task-local, so
    a lifecycle behaves identically whether it runs alone or co-located —
    and, via ``suspend()``/``resume()`` (SlotSnapshot per resident job +
    exact lane restoration), identically across a MID-TASK move to a
    different replica: migration is invisible to the loss trajectory.
  * ``run_colocated`` drives several lifecycles over one executor with a
    cross-task admission gate (slot headroom + the §A.3 memory model) —
    pending small tasks backfill capacity the moment survivors free it.
  * ``BatchedExecutor`` keeps the original single-task API (one task, Z
    slots) as a thin wrapper: one executor, one lifecycle.

Slots are RAGGED (variable-width): each slot carries its own
(per-adapter batch, seq len), so one replica can fuse tasks with
*different* batch sizes in a single step. ``_assemble`` packs each slot's
own rows into a [Z, b_cap, seq_cap] lane buffer (label padding = -1 =>
masked out of every loss and gradient) and dispatches dense (all resident
slots full-width — the homogeneous fast case, no padding, no masks) vs
ragged (per-slot token-row counts ride the batch as ``slot_rows`` and
route the LoRA projections through the ragged grouped-GEMM kernels).
The kernel-level dead-tile skip covers BATCH raggedness (whole missing
rows); a shorter-seq guest is exact via label masking but pays padded
compute for its seq-pad columns (mid-lane padding is inexpressible as a
row-prefix count). Admission budgets *tokens* (sum of b_z * seq_z), not
same-width slot counts — the §A.3 memory model M_hat is token-linear, so
heterogeneous widths share one replica soundly.

The executor is shape-static at CAPACITY: (Z, b_cap, seq_cap) never
changes, so every admit/evict — at any width — is an array update, not a
recompile.

Lifecycle (unchanged from the paper):

  1. WARMUP with rotation: all K candidate jobs get ``warmup_steps`` of
     training, cycling through the task's slot allocation in waves;
     online pattern detection (divergence) is live during warmup; rotated
     jobs carry exact optimizer state via host snapshots.
  2. SELECTION at the warmup boundary: survivors ranked by val loss,
     top ceil(25% * K) continue (underperformance exits).
  3. CONTINUE-TRAINING: survivors train to their step budget with online
     divergence + overfitting detection; overfit exits checkpoint their
     best-val adapter; freed slots are BACKFILLED from the pending queue
     via the §A.3 admission policy (memory-model token budget; ragged
     slots need no width matching — ``sched/intra_task.py``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TrainConfig
from repro.core import steps as STEPS
from repro.core.adapter_state import SlotManager, SlotSnapshot
from repro.core.early_exit import (EarlyExitConfig, ExitDecision, ExitReason,
                                   JobMonitor, warmup_select)
from repro.data.synthetic import SlotBatcher, TaskDataset
from repro.models import model as M
from repro.sched.events import EventKind, ProgressEvent
from repro.sched.intra_task import ExecutorSlots, MemoryModel, PendingJob


@dataclasses.dataclass(frozen=True)
class ChunkReport:
    """One bounded slice of a task's execution (elastic runtime unit).

    The elastic cluster runtime (sched/cluster.py) interleaves many tasks
    by stepping each executor one chunk at a time; ``steps_executed``
    converts to virtual cluster time via the profiled step time, and
    ``events`` carries every lifecycle transition that fired inside the
    chunk (exits, selection, completion) so the runtime can replan.
    ``task`` attributes the chunk to its lifecycle (co-located replicas
    interleave chunks of several tasks), and ``slots_bound`` is a
    monotone upper bound on the task's future concurrent slot use — the
    quantity cross-task admission reclaims as survivors exit.
    ``tokens_executed`` counts the REAL tokens trained inside the chunk
    (padding excluded): with ragged slot widths, wall time per token —
    not per step — is the calibrated profiler-feedback quantity, and
    ``slot_tokens`` exposes each slot's per-step token footprint at flush
    time (0 = slot free)."""
    steps_executed: int
    events: Tuple[ProgressEvent, ...]
    phase: str
    remaining_steps_bound: int
    wall_time_s: float = 0.0     # realized host seconds (profiler feedback)
    task: str = ""
    slots_in_use: int = 0
    slots_bound: int = 0
    tokens_executed: int = 0     # real (non-padding) tokens in the chunk
    slot_tokens: Tuple[int, ...] = ()   # per-slot b*seq at flush (0 = free)
    slot_ranks: Tuple[int, ...] = ()    # per-slot TRUE rank at flush (0=free)


@dataclasses.dataclass
class JobResult:
    job_id: str
    config: TrainConfig
    best_val: float
    best_val_step: int
    exit_reason: Optional[ExitReason]
    steps_trained: int
    samples_trained: int
    adapter: Optional[Dict] = None          # best checkpoint (winner only)


@dataclasses.dataclass
class TaskResult:
    task_name: str
    best_job: Optional[str]     # None iff every job diverged (best_val=inf)
    best_val: float
    job_results: Dict[str, JobResult]
    wall_time_s: float
    total_samples: int
    samples_saved_frac: float
    exit_counts: Dict[str, int]


# ---------------------------------------------------------------------------
# Shared backbone executor
# ---------------------------------------------------------------------------

class SharedBackboneExecutor:
    """One frozen-backbone replica: Z adapter slots shared by N tasks.

    Owns the device state and the fused train/eval steps; task lifecycles
    admit/evict slots through it and receive per-slot losses back.
    Resident tasks must share the loss kind and fit within the replica's
    (b_cap, seq_cap) lane capacity — but NOT each other's widths: slots
    are ragged, so adapters with different per-adapter batch sizes (and
    seq lens) train in the same fused step. Homogeneous full-width mixes
    dispatch the dense path (bit-identical to the pre-ragged executor);
    anything else packs per-slot rows and rides the ragged grouped-GEMM
    kernels."""

    def __init__(self, cfg: ModelConfig, params: Dict, *, Z: int,
                 per_adapter_batch: int, eval_every: int = 5, seed: int = 0,
                 loss_kind: str = "sft",
                 mem_model: Optional[MemoryModel] = None,
                 seq_cap: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.Z = Z
        self.b_cap = per_adapter_batch     # lane capacity, NOT a shared width
        self.seq_cap = seq_cap             # None => max over resident slots
        self.eval_every = eval_every
        self.loss_kind = loss_kind
        self.mem = mem_model
        key = jax.random.PRNGKey(seed)
        self.key, k_slots = jax.random.split(key)
        self.slots = SlotManager(cfg, Z, M.target_shapes(cfg), k_slots)
        self._train_step = jax.jit(
            STEPS.make_train_step(cfg, loss_kind=loss_kind))
        self._eval_step = jax.jit(
            STEPS.make_eval_step(cfg, loss_kind=loss_kind))
        self._lifecycles: Dict[str, "TaskLifecycle"] = {}
        self._wall = 0.0
        self._tokens = 0

    @property
    def b(self) -> int:
        """Deprecated alias: the lane CAPACITY (max slot width), kept for
        construction-time call sites; per-slot widths live in SlotManager."""
        return self.b_cap

    # ---- task registry -----------------------------------------------------
    def add_task(self, lc: "TaskLifecycle") -> None:
        assert lc.task_name not in self._lifecycles, lc.task_name
        self._lifecycles[lc.task_name] = lc

    def remove_task(self, task_name: str) -> None:
        self._lifecycles.pop(task_name, None)

    def resident_tasks(self) -> List["TaskLifecycle"]:
        """Lifecycles with at least one occupied slot, registration order."""
        return [lc for lc in self._lifecycles.values() if lc.resident]

    def slot_headroom(self) -> int:
        """Physical slots not claimed by any registered task's future-use
        bound (what cross-task admission may hand to a new task)."""
        return self.Z - sum(lc.slots_bound() for lc in
                            self._lifecycles.values())

    def can_admit_task(self, lc: "TaskLifecycle") -> bool:
        """Cross-task admission gate: slot headroom plus the §A.3 memory
        model over the TOKEN budget (sum of per-slot b*seq) — ragged slots
        mean same-width slot counting under-/over-charges; M_hat is
        token-linear, so tokens are the sound budget unit. A rank-aware
        model (k2 > 0) additionally budgets rank-weighted FLOP-tokens
        (b*seq*rank per slot at each job's TRUE rank, not Z*r_max), so
        low-rank guests pack denser than padded accounting would allow."""
        if lc.slots_bound() > self.slot_headroom():
            return False
        if self.mem is None:
            return True
        tokens = sum(x.tokens_bound() for x in self._lifecycles.values())
        rtok = sum(x.rank_tokens_bound() for x in self._lifecycles.values())
        return self.mem.fits_ranked(tokens + lc.tokens_bound(),
                                    rtok + lc.rank_tokens_bound())

    # ---- slot ops (called by lifecycles) -----------------------------------
    def acquire_slot(self) -> int:
        free = self.slots.free_slots()
        assert free, "no free slot (admission gate violated)"
        return free[0]

    def admit(self, slot: int, task: str, job_id: str, tc: TrainConfig,
              key: jax.Array, b: int = 0, seq: int = 0) -> None:
        assert not b or b <= self.b_cap, f"slot width {b} > b_cap"
        self.slots.admit(slot, job_id, tc, key, task=task, b=b, seq=seq)

    def restore(self, slot: int, task: str, snap: SlotSnapshot,
                tc: TrainConfig) -> None:
        self.slots.restore(slot, snap, tc, task=task)

    def evict(self, slot: int) -> None:
        self.slots.evict(slot)

    def snapshot(self, slot: int) -> SlotSnapshot:
        return self.slots.snapshot(slot)

    def adapter_at(self, slot: int) -> Dict:
        return self.slots.adapter_at(slot)

    # ---- fused stepping ----------------------------------------------------
    def _resolved_seq_cap(self) -> int:
        if self.seq_cap is not None:
            return self.seq_cap
        occ = [self.slots.slot_seq[i] for i in range(self.Z)
               if self.slots.slot_jobs[i] is not None]
        cap = max(occ, default=0)
        assert cap > 0, "no resident slot carries a seq len"
        return cap

    def _assemble(self) -> Tuple[Dict[str, jnp.ndarray], np.ndarray,
                                 bool, int]:
        """One fused [Z, b_cap, seq_cap] batch with RAGGED slot packing.

        Each resident job's lane draws its OWN (b, seq) rows from its
        task's batcher, scattered into the job's physical slot; the lane
        tail is padding (tokens 0, labels -1 => masked out of loss and
        gradient). Every resident job's stream advances exactly one step
        at its own width — task-local determinism, independent of
        co-tenants. Returns (batch, slot_rows, dense, real_tokens):
        ``slot_rows[z]`` is slot z's valid token-row count in flattened
        b*seq units (the ragged grouped-GEMM group sizes), ``dense`` is
        True iff every resident slot is full-width (the homogeneous fast
        case — no padding, identical to the pre-ragged dense step), and
        ``real_tokens`` counts actual (non-padding) tokens this step."""
        S_cap = self._resolved_seq_cap()
        bufs: Dict[str, np.ndarray] = {}
        slot_rows = np.zeros((self.Z,), np.int32)
        dense = True
        tokens = 0
        for lc in self.resident_tasks():
            for job, (lane, slot) in lc.resident.items():
                rows = lc.lane_batch_dict(job)
                b_j = self.slots.slot_b[slot]
                s_j = self.slots.slot_seq[slot] or S_cap
                for k, arr in rows.items():
                    assert arr.shape[0] <= self.b_cap \
                        and arr.shape[1] <= S_cap, \
                        f"task {lc.task_name} rows exceed lane capacity"
                    if k not in bufs:
                        fill = -1 if k.startswith("labels") else 0
                        bufs[k] = np.full(
                            (self.Z, self.b_cap, S_cap) + arr.shape[2:],
                            fill, arr.dtype)
                    bufs[k][slot, :arr.shape[0], :arr.shape[1]] = arr
                slot_rows[slot] = b_j * S_cap
                tokens += b_j * s_j
                if b_j != self.b_cap or s_j != S_cap:
                    dense = False
        return ({k: jnp.asarray(v) for k, v in bufs.items()},
                slot_rows, dense, tokens)

    def run_steps(self, n: int) -> None:
        """Train all active slots for n fused steps; dispatch per-slot
        losses to the owning lifecycles' monitors. Dense vs ragged is
        decided per step: a homogeneous full-width mix never pays the
        masking path, a mixed-width mix threads ``slot_rows`` through the
        batch into the ragged grouped-GEMM kernels."""
        t0 = time.time()
        for _ in range(n):
            batch, slot_rows, dense, tokens = self._assemble()
            if not dense:
                batch["slot_rows"] = jnp.asarray(slot_rows)
            if self.slots.mixed_rank(self.cfg.lora.r_max):
                # some resident rank < r_max: route LoRA through the
                # rank-local kernels (dead rank tiles skip the MXU); a
                # homogeneous full-rank mix stays on the dense path,
                # which the rank-local ops reproduce bitwise
                batch["slot_ranks"] = self.slots.ranks
            self.slots.lora, self.slots.opt_state, metrics = self._train_step(
                self.params, self.slots.lora, self.slots.opt_state,
                self.slots.hp, self.slots.active, self.slots.ranks, batch)
            self._tokens += tokens
            per_loss = np.asarray(metrics["per_slot_loss"])
            for lc in self.resident_tasks():
                for job, (_, slot) in lc.resident.items():
                    lc.observe_train(job, float(per_loss[slot]))
        # accumulate actual train/eval host time only — flush-to-flush
        # deltas would also bill time the coordinator spent suspended
        self._wall += time.time() - t0

    def eval_task(self, lc: "TaskLifecycle") -> np.ndarray:
        """Per-slot val losses for ``lc``'s dataset (broadcast to all Z
        slots; slot isolation makes foreign-slot entries meaningless to
        this task and identical-to-solo for its own)."""
        t0 = time.time()
        rows = lc.batcher.val_batch_dict()
        batch = {k: jnp.asarray(np.broadcast_to(
                     v[0][None], (self.Z,) + v.shape[1:]))
                 for k, v in rows.items()}
        if self.slots.mixed_rank(self.cfg.lora.r_max):
            batch["slot_ranks"] = self.slots.ranks
        val = np.asarray(self._eval_step(
            self.params, self.slots.lora, self.slots.active, batch))
        self._wall += time.time() - t0
        return val

    def take_wall(self) -> float:
        wall, self._wall = self._wall, 0.0
        return wall

    def take_tokens(self) -> int:
        """Real (non-padding) tokens trained since the last flush — the
        per-token profiler-feedback denominator for ragged widths."""
        tok, self._tokens = self._tokens, 0
        return tok

    def slot_token_widths(self) -> Tuple[int, ...]:
        """Per-slot tokens per fused step (b_z * seq_z; 0 = free slot)."""
        return tuple(
            self.slots.slot_tokens(i)
            if self.slots.slot_jobs[i] is not None else 0
            for i in range(self.Z))

    def slot_rank_vector(self) -> Tuple[int, ...]:
        """Per-slot TRUE adapter ranks (0 = free slot) — the rank-local
        observability twin of ``slot_token_widths``."""
        return tuple(self.slots.slot_rank)


# ---------------------------------------------------------------------------
# Per-task lifecycle state machine
# ---------------------------------------------------------------------------

class TaskLifecycle:
    """Warmup-rotation -> selection -> continue/backfill for ONE task,
    admitting/evicting slots through a (possibly shared) executor.

    Everything the lifecycle does is a function of its own construction
    arguments — batch streams, init keys, and eval points are task-local
    (lane-indexed, not physical-slot-indexed) — so its loss trajectory is
    bitwise identical whether the executor hosts it alone or co-located
    with other tasks (the loss-isolation property, tested in
    tests/test_lora_isolation.py). One caveat: on the PALLAS backend a
    full-rank task gains a low-rank co-tenant flips from the dense to the
    rank-local kernels, whose rank-tiled fp32 accumulation is parity-level
    (not bitwise) vs dense — the jnp path (what the engine/service jit
    today) masks with a full-rank-identity select and stays bitwise."""

    def __init__(self, ex: SharedBackboneExecutor, task_name: str,
                 jobs: Dict[str, TrainConfig], total_steps: int, *,
                 ee: EarlyExitConfig = EarlyExitConfig(),
                 max_slots: Optional[int] = None,
                 batcher=None, dataset: Optional[TaskDataset] = None,
                 seed: int = 0):
        assert jobs, f"task {task_name} has no jobs"
        self.ex = ex
        self.task_name = task_name
        self.jobs = dict(jobs)
        self.total_steps = total_steps
        self.ee = ee
        self.m = min(max_slots or ex.Z, ex.Z)     # this task's slot budget
        if batcher is None:
            assert dataset is not None, "need a batcher or a dataset"
            batcher = SlotBatcher(dataset, self.m, ex.b_cap, seed=seed)
        self.batcher = batcher
        # this task's seq len: a per-slot property on the shared executor
        # (co-tenants may differ; lanes are padded to the replica seq cap)
        self.seq_len = int(getattr(batcher, "seq_len", 0) or
                           (dataset.train.shape[1] - 1 if dataset is not None
                            else 0))
        assert self.seq_len > 0, f"task {task_name}: unknown seq len"
        self.K = len(jobs)
        self.warmup_steps = ee.warmup_steps(total_steps)
        self._key = jax.random.PRNGKey(seed)
        self._admissions = 0
        self.monitors: Dict[str, JobMonitor] = {
            j: JobMonitor(ee, j) for j in jobs}
        self.snapshots: Dict[str, SlotSnapshot] = {}
        self._best_ckpt: Dict[str, Dict] = {}
        self.steps_done: Dict[str, int] = {}
        self.resident: Dict[str, Tuple[int, int]] = {}   # job -> (lane, slot)
        self._free_lanes: List[int] = list(range(self.m))
        self._queue: List[str] = []
        # §A.3 admission/backfill policy over this task's slot budget; the
        # executor-level memory model bounds the *replica*, this instance
        # bounds the task's own allocation
        self._policy = ExecutorSlots(
            ex.mem if ex.mem is not None else _PERMISSIVE_MEM, self.m)
        job_ids = list(self.jobs)
        self._waves: List[List[str]] = [job_ids[i:i + self.m]
                                        for i in range(0, self.K, self.m)]
        self._wave_idx = 0
        self._wave_step = 0
        self._cont_step = 0
        self.phase = "idle"
        self._events: List[ProgressEvent] = []
        self._t0 = 0.0
        self._result: Optional[TaskResult] = None
        self._sus: Optional[List[Tuple[str, int]]] = None  # suspended (job, lane)
        self._sus_eval_every = 0
        self._b_cap = ex.b_cap             # cached caps: capacity queries
        self._r_max = ex.cfg.lora.r_max    # stay answerable while suspended

    # ---- helpers -----------------------------------------------------------
    def _next_key(self) -> jax.Array:
        # fold_in(admission counter): per-job init keys depend only on this
        # task's own admission history, never on co-tenant interleaving
        self._admissions += 1
        return jax.random.fold_in(self._key, self._admissions)

    def job_width(self, job_id: str) -> int:
        """The job's OWN per-adapter batch size, capped at the replica's
        lane capacity — slots are ragged, so every job trains at its own
        width instead of the executor-wide maximum. (Caps are cached so
        capacity queries stay answerable while the task is suspended
        between replicas.)"""
        b = self.jobs[job_id].per_adapter_batch or self._b_cap
        return max(min(b, self._b_cap), 1)

    def job_rank(self, job_id: str) -> int:
        """The job's TRUE adapter rank (capped at r_max) — what the
        rank-local kernels compute at and the rank-aware §A.3 budget
        charges, instead of the padded r_max."""
        return max(min(self.jobs[job_id].lora_rank, self._r_max), 1)

    def lane_batch_dict(self, job_id: str) -> Dict[str, np.ndarray]:
        """One fused-step draw for a resident job: its lane's stream
        advanced by its own width (task-local, co-tenant independent)."""
        lane, _ = self.resident[job_id]
        return self.batcher.lane_batch_dict(lane, self.job_width(job_id))

    def _admit_job(self, job_id: str, lane: Optional[int] = None) -> None:
        if lane is None:
            lane = self._free_lanes.pop(0)
        else:
            self._free_lanes.remove(lane)     # exact lane (resume/migration)
        slot = self.ex.acquire_slot()
        tc = self.jobs[job_id]
        if job_id in self.snapshots:
            self.ex.restore(slot, self.task_name,
                            self.snapshots.pop(job_id), tc)
        else:
            self.ex.admit(slot, self.task_name, job_id, tc, self._next_key(),
                          b=self.job_width(job_id), seq=self.seq_len)
        self.resident[job_id] = (lane, slot)
        self._policy.resident[job_id] = self.job_width(job_id)
        self._policy.resident_ranks[job_id] = self.job_rank(job_id)

    def _evict_job(self, job_id: str) -> None:
        lane, slot = self.resident.pop(job_id)
        self.ex.evict(slot)
        self._free_lanes.append(lane)
        self._free_lanes.sort()
        self._policy.evict(job_id)

    def observe_train(self, job_id: str, loss: float) -> None:
        self.monitors[job_id].observe_train(loss)
        self.steps_done[job_id] = self.steps_done.get(job_id, 0) + 1

    # ---- suspend / resume (slot-level migration primitive) -----------------
    def suspend(self) -> None:
        """Detach this task from its executor mid-flight: snapshot every
        resident job bit-exactly (``SlotSnapshot`` carries adapter +
        optimizer moments + step count + slot geometry) and release the
        slots. All decision state — batcher lane streams, monitors, phase
        counters, init keys — is task-local and stays in this object, so
        ``resume()`` on another replica continues the loss trajectory
        exactly where it stopped."""
        assert self.phase in ("warmup", "continue"), \
            f"cannot suspend lifecycle in phase {self.phase!r}"
        assert self._sus is None, "already suspended"
        self._sus = []
        for job_id in sorted(self.resident):
            lane, slot = self.resident[job_id]
            self.snapshots[job_id] = self.ex.snapshot(slot)
            self._sus.append((job_id, lane))
            self._evict_job(job_id)
        self._sus_eval_every = self.ex.eval_every
        self.ex.remove_task(self.task_name)
        self.ex = None

    def resume(self, ex: SharedBackboneExecutor) -> None:
        """Re-attach a suspended lifecycle to ``ex`` (typically a different
        replica with a different resident mix). Physical slot indices may
        differ from the old host — that is the point — but lanes are
        restored EXACTLY: lanes index this task's batch streams, and
        lane-exact restoration is what makes the post-migration trajectory
        bitwise identical to a never-migrated run. The caller is
        responsible for the cross-task admission gate
        (``ex.can_admit_task``); eval cadence must match the old host
        (eval points are defined on the task-local step grid)."""
        assert self._sus is not None, "resume() requires a suspended task"
        assert ex.eval_every == self._sus_eval_every, \
            "resume requires the old host's eval cadence"
        assert ex.b_cap == self._b_cap and ex.cfg.lora.r_max == self._r_max, \
            "resume requires a same-shape replica (lane width / r_max)"
        self.ex = ex
        ex.add_task(self)
        for job_id, lane in self._sus:
            self._admit_job(job_id, lane=lane)
        self._sus = None

    @property
    def done(self) -> bool:
        return self.phase == "done"

    def drain_events(self) -> Tuple[ProgressEvent, ...]:
        ev, self._events = tuple(self._events), []
        return ev

    # ---- capacity observability (cross-task admission) ---------------------
    def slots_in_use(self) -> int:
        return len(self.resident)

    def slots_bound(self) -> int:
        """Monotone upper bound on future concurrent slot use. Shrinks as
        warmup waves drain and survivors exit — the freed capacity the
        cross-task admission path reclaims for pending small tasks."""
        if self.phase == "done":
            return 0
        if self.phase in ("idle", "warmup"):
            alive_waves = [len([j for j in w if self.monitors[j].exited
                                is None])
                           for w in self._waves[self._wave_idx:]]
            cont = min(self.m, self.ee.top_k(self.K))
            return max(alive_waves + [cont, len(self.resident)])
        return min(self.m, len(self.resident) + len(self._queue))

    def width_bound(self) -> int:
        """Upper bound on the widest slot this task will still occupy
        (max per-adapter batch over non-exited jobs; shrinks as wide jobs
        exit)."""
        alive = [self.job_width(j) for j in self.jobs
                 if self.monitors[j].exited is None]
        return max(alive, default=0)

    def tokens_bound(self) -> int:
        """Monotone upper bound on this task's per-step TOKEN footprint
        (slots x widest remaining width x seq len) — what the ragged
        cross-task admission gate budgets against the §A.3 memory model
        instead of same-width slot counts."""
        return self.slots_bound() * self.width_bound() * self.seq_len

    def rank_bound(self) -> int:
        """Upper bound on the highest TRUE rank this task will still
        train (max over non-exited jobs; shrinks as high-rank jobs
        exit)."""
        alive = [self.job_rank(j) for j in self.jobs
                 if self.monitors[j].exited is None]
        return max(alive, default=0)

    def rank_tokens_bound(self) -> int:
        """Monotone upper bound on this task's per-step rank-weighted
        FLOP-token footprint (tokens_bound x highest remaining rank) —
        the rank-aware §A.3 budget unit. Charging true ranks instead of
        r_max is what lets mixed-rank guests pack denser."""
        return self.tokens_bound() * self.rank_bound()

    def remaining_steps_bound(self) -> int:
        """Upper bound on executor steps left in this lifecycle, assuming
        no further pattern exits (the residual d_i the elastic runtime
        plans with; shrinks monotonically as events fire)."""
        m = max(self.m, 1)
        cont_budget = self.total_steps - self.warmup_steps
        if self.phase in ("idle", "warmup"):
            survivors = self.ee.top_k(self.K)
            cont = -(-survivors // m) * cont_budget
            waves_left = max(len(self._waves) - self._wave_idx - 1, 0)
            in_wave = (self.warmup_steps - self._wave_step
                       if self.phase == "warmup" else
                       len(self._waves) and self.warmup_steps)
            return in_wave + waves_left * self.warmup_steps + cont
        if self.phase == "continue":
            alive = list(self.resident) + list(self._queue)
            rem = [max(self.total_steps - self.steps_done.get(j, 0), 0)
                   for j in alive]
            if not rem:
                return 0
            return -(-len(rem) // m) * max(rem)
        return 0

    # ---- phase machine -----------------------------------------------------
    def begin(self) -> None:
        assert self.phase == "idle"
        self._t0 = time.time()
        self.phase = "warmup"
        self._start_wave()

    def _start_wave(self) -> None:
        for job_id in self._waves[self._wave_idx]:
            self._admit_job(job_id)
        self._wave_step = 0

    def steps_until_boundary(self) -> int:
        """Steps to this task's next decision point (eval-grid point, wave
        end, or the nearest resident job's budget). Always >= 1 for a
        non-done lifecycle; the coordinator steps the executor by the min
        across co-located tasks so no task overshoots its boundary."""
        ev = self.ex.eval_every
        if self.phase == "warmup":
            to_eval = ev - (self._wave_step % ev)
            return min(self.warmup_steps - self._wave_step, to_eval)
        if self.phase == "continue":
            to_eval = ev - (self._cont_step % ev)
            to_budget = min(
                (self.total_steps - self.steps_done.get(j, 0)
                 for j in self.resident), default=to_eval)
            return max(min(to_eval, to_budget), 1)
        return 1 << 30

    def on_steps(self, n: int) -> None:
        """Advance the task-local clock after the executor trained n fused
        steps; process any boundary that landed. Eval points are defined on
        the task's OWN step grid (every ``eval_every`` phase steps, wave
        ends, budget hits) — a co-tenant's smaller chunk never adds an
        eval, which is what keeps co-located loss histories identical to
        solo ones."""
        if self.phase == "warmup":
            self._wave_step += n
            if (self._wave_step % self.ex.eval_every == 0
                    or self._wave_step >= self.warmup_steps):
                self._eval_and_detect()
            if self._wave_step >= self.warmup_steps:
                self._end_wave()
        elif self.phase == "continue":
            self._cont_step += n
            at_budget = any(self.steps_done.get(j, 0) >= self.total_steps
                            for j in self.resident)
            if self._cont_step % self.ex.eval_every == 0 or at_budget:
                self._eval_and_detect()
            self._settle_continue()

    # ---- warmup ------------------------------------------------------------
    def _end_wave(self) -> None:
        # snapshot+rotate out whatever survived this wave
        for job_id in list(self.resident):
            lane, slot = self.resident[job_id]
            self.snapshots[job_id] = self.ex.snapshot(slot)
            self._evict_job(job_id)
        self._wave_idx += 1
        if self._wave_idx < len(self._waves):
            self._start_wave()
        else:
            self._select_and_continue()

    def _select_and_continue(self) -> None:
        # Pattern-3 selection at the warmup boundary (underperformance)
        kept, dropped = warmup_select(self.monitors, self.ee,
                                      num_candidates=self.K)
        for j in dropped:
            self.monitors[j]._exit(ExitReason.UNDERPERFORMING,
                                   self.steps_done.get(j, self.warmup_steps))
            self.snapshots.pop(j, None)
        if dropped:
            self._events.append(ProgressEvent(
                kind=EventKind.WARMUP_SELECTION, task=self.task_name,
                reason=ExitReason.UNDERPERFORMING.value,
                step=self.warmup_steps, dropped=tuple(dropped)))
        self.phase = "continue"
        self._cont_step = 0
        self._queue = list(kept)
        # §A.3 greedy decreasing-batch-size initial admission (stable sort:
        # a homogeneous-batch queue keeps its val-loss ranking)
        pending = [PendingJob(j, self.job_width(j), self.job_rank(j))
                   for j in self._queue]
        for pj in self._policy.admit_initial(pending):
            self._policy.evict(pj.job_id)            # _admit_job re-adds
            self._queue.remove(pj.job_id)
            self._admit_job(pj.job_id)
        self._settle_continue()

    # ---- continue ----------------------------------------------------------
    def _backfill(self) -> None:
        """§A.3 backfill into freed capacity: pure memory-model budget —
        ragged slots removed the same-batch-size constraint (any width
        that fits the token budget co-trains in the fused step)."""
        if not self._queue or not self._free_lanes:
            return
        pending = [PendingJob(j, self.job_width(j), self.job_rank(j))
                   for j in self._queue]
        pick = self._policy.backfill(pending)
        if pick is None:
            return
        self._policy.evict(pick.job_id)              # _admit_job re-adds
        self._queue.remove(pick.job_id)
        self._admit_job(pick.job_id)

    def _exit_job(self, job_id: str, decision: ExitDecision) -> None:
        self._events.append(ProgressEvent(
            kind=EventKind.JOB_EXITED, task=self.task_name, job=job_id,
            reason=decision.reason.value, step=decision.step))
        self._evict_job(job_id)
        if self.phase == "continue":
            self._backfill()

    def _eval_and_detect(self) -> None:
        if not self.resident:
            return
        val = self.ex.eval_task(self)
        for job_id, (_, slot) in list(self.resident.items()):
            mon = self.monitors[job_id]
            prev_best = mon.best_val
            decision = mon.observe_val(float(val[slot]),
                                       self.steps_done.get(job_id, 0))
            # checkpoint best-val adapter (cheap: host copy of one slot)
            if mon.val_hist[-1] <= prev_best:
                self._best_ckpt[job_id] = self.ex.adapter_at(slot)
            if decision is not None:
                self._exit_job(job_id, decision)

    def _settle_continue(self) -> None:
        """Complete at-budget jobs (possibly newly backfilled ones, who may
        arrive already at budget when warmup == total budget) and finish
        the task once queue + slots drain."""
        changed = True
        while changed:
            changed = False
            for job_id in list(self.resident):
                if self.steps_done.get(job_id, 0) >= self.total_steps:
                    self.monitors[job_id]._exit(
                        ExitReason.COMPLETED, self.steps_done[job_id])
                    self._events.append(ProgressEvent(
                        kind=EventKind.JOB_EXITED, task=self.task_name,
                        job=job_id, reason=ExitReason.COMPLETED.value,
                        step=self.steps_done[job_id]))
                    self._evict_job(job_id)
                    self._backfill()
                    changed = True
        if not self.resident and not self._queue:
            self._finish()

    # ---- results -----------------------------------------------------------
    def _finish(self) -> None:
        self.phase = "done"
        results: Dict[str, JobResult] = {}
        for job_id, tc in self.jobs.items():
            mon = self.monitors[job_id]
            results[job_id] = JobResult(
                job_id=job_id, config=tc, best_val=mon.best_val,
                best_val_step=mon.best_val_step,
                exit_reason=(mon.exited.reason if mon.exited else None),
                steps_trained=mon.steps_trained,
                samples_trained=mon.steps_trained * self.job_width(job_id))
        finite = {j: r for j, r in results.items()
                  if np.isfinite(r.best_val)}
        # all jobs can diverge (every val loss inf/nan): report an empty
        # winner instead of crashing — the tenant sees best_job=None
        best_job: Optional[str] = (
            min(finite, key=lambda j: finite[j].best_val) if finite else None)
        best_val = results[best_job].best_val if best_job else float("inf")
        if best_job is not None:
            results[best_job].adapter = self._best_ckpt.get(best_job)
        total_samples = sum(r.samples_trained for r in results.values())
        full_samples = sum(self.total_steps * self.job_width(j)
                           for j in self.jobs)
        exit_counts: Dict[str, int] = {}
        for r in results.values():
            if r.exit_reason is not None:
                exit_counts[r.exit_reason.value] = (
                    exit_counts.get(r.exit_reason.value, 0) + 1)
        self._events.append(ProgressEvent(
            kind=EventKind.TASK_COMPLETED, task=self.task_name,
            detail=f"best={best_job}"))
        self._result = TaskResult(
            task_name=self.task_name, best_job=best_job, best_val=best_val,
            job_results=results, wall_time_s=time.time() - self._t0,
            total_samples=total_samples,
            samples_saved_frac=1.0 - total_samples / max(full_samples, 1),
            exit_counts=exit_counts)

    def result(self) -> TaskResult:
        assert self._result is not None, "lifecycle not finished"
        return self._result


_PERMISSIVE_MEM = MemoryModel(k0=0.0, k1=0.0, seq_len=1,
                              capacity=float("inf"))


# ---------------------------------------------------------------------------
# Coordinators
# ---------------------------------------------------------------------------

def run_colocated(ex: SharedBackboneExecutor,
                  lifecycles: Sequence[TaskLifecycle],
                  ) -> Dict[str, TaskResult]:
    """Drive several task lifecycles over ONE shared executor.

    Tasks are admitted in order the moment the cross-task gate (slot
    headroom + memory model, ``can_admit_task``) accepts them — a pending
    small task starts as soon as survivors of the running tasks free
    enough capacity, instead of waiting for a whole replica. The fused
    executor steps by the min boundary across resident tasks, so every
    task hits its own eval grid exactly as it would alone."""
    waiting = list(lifecycles)
    live: List[TaskLifecycle] = []
    results: Dict[str, TaskResult] = {}
    guard = 10 + 20 * sum(
        lc.total_steps * max(lc.K, 1) for lc in lifecycles)

    def try_admit() -> None:
        for lc in list(waiting):
            if ex.can_admit_task(lc):
                ex.add_task(lc)
                lc.begin()
                waiting.remove(lc)
                live.append(lc)

    try_admit()
    while (waiting or live) and guard > 0:
        for lc in list(live):
            if lc.done:
                results[lc.task_name] = lc.result()
                ex.remove_task(lc.task_name)
                live.remove(lc)
        try_admit()
        if not live:
            if waiting:
                raise RuntimeError(
                    f"unplaceable tasks: {[lc.task_name for lc in waiting]}")
            break
        n = min(lc.steps_until_boundary() for lc in live)
        n = max(min(n, ex.eval_every), 1)
        ex.run_steps(n)
        guard -= n
        for lc in live:
            lc.on_steps(n)
    assert guard > 0, "colocated coordinator stopped progressing"
    return results


class BatchedExecutor:
    """Single-task compatibility wrapper: one SharedBackboneExecutor, one
    TaskLifecycle, the original run_task / run_task_chunks API."""

    def __init__(self, cfg: ModelConfig, params: Dict, dataset: TaskDataset,
                 *, Z: int, per_adapter_batch: int,
                 ee: EarlyExitConfig = EarlyExitConfig(),
                 eval_every: int = 5, seed: int = 0,
                 loss_kind: str = "sft", batcher=None,
                 mem_model: Optional[MemoryModel] = None,
                 seq_cap: Optional[int] = None):
        if seq_cap is None and dataset is not None:
            seq_cap = dataset.train.shape[1] - 1
        self.backbone = SharedBackboneExecutor(
            cfg, params, Z=Z, per_adapter_batch=per_adapter_batch,
            eval_every=eval_every, seed=seed, loss_kind=loss_kind,
            mem_model=mem_model, seq_cap=seq_cap)
        self.cfg = cfg
        self.dataset = dataset
        self.Z = Z
        self.b = per_adapter_batch
        self.ee = ee
        self.eval_every = eval_every
        self.seed = seed
        self._batcher = batcher
        self.slots = self.backbone.slots      # compat: direct slot access
        # Optional durability hook, called as ``ckpt_hook(lc, chunk_i)``
        # after every completed chunk while the lifecycle is still live —
        # the service installs a checkpointer here (checkpoint/taskstate).
        self.ckpt_hook = None

    # ------------------------------------------------------------------ run
    def run_task(self, task_name: str, jobs: Dict[str, TrainConfig],
                 total_steps: int) -> TaskResult:
        """Run the full lifecycle to completion (static path)."""
        gen = self.run_task_chunks(task_name, jobs, total_steps)
        while True:
            try:
                next(gen)
            except StopIteration as done:
                return done.value

    def run_task_chunks(self, task_name: str, jobs: Dict[str, TrainConfig],
                        total_steps: int):
        """Generator form of the lifecycle: yields a ChunkReport after every
        bounded chunk (<= eval_every steps) so the elastic cluster runtime
        can interleave many tasks and replan on the events each chunk
        surfaces. ``return``s the TaskResult (StopIteration.value)."""
        ex = self.backbone
        batcher = (self._batcher if self._batcher is not None
                   else SlotBatcher(self.dataset, self.Z, self.b,
                                    seed=self.seed))
        lc = TaskLifecycle(ex, task_name, jobs, total_steps, ee=self.ee,
                           max_slots=self.Z, batcher=batcher, seed=self.seed)
        ex.add_task(lc)
        ex.take_wall()
        lc.begin()
        return (yield from self._drive_chunks(lc, 0))

    def resume_task_chunks(self, task_name: str,
                           jobs: Dict[str, TrainConfig], total_steps: int,
                           state, start_chunk: int = 0):
        """``run_task_chunks`` continued from a durable mid-task checkpoint
        (``checkpoint/taskstate.py`` state). The restored lifecycle picks
        up at its exact step — batch-stream cursors, PRNG key, monitors,
        optimizer moments and per-slot rank/width all come from the
        snapshot — so the remaining chunk stream is bitwise identical to
        the uninterrupted run's tail."""
        from repro.checkpoint.taskstate import restore_lifecycle
        ex = self.backbone
        batcher = (self._batcher if self._batcher is not None
                   else SlotBatcher(self.dataset, self.Z, self.b,
                                    seed=self.seed))
        lc = restore_lifecycle(ex, task_name, jobs, total_steps, ee=self.ee,
                               max_slots=self.Z, batcher=batcher, state=state)
        ex.add_task(lc)
        ex.take_wall()
        ex.take_tokens()
        return (yield from self._drive_chunks(lc, start_chunk))

    def _drive_chunks(self, lc: TaskLifecycle, chunk_i: int):
        ex = self.backbone
        guard = 10 + 20 * lc.total_steps * max(len(lc.jobs), 1)
        while not lc.done and guard > 0:
            n = max(min(lc.steps_until_boundary(), self.eval_every), 1)
            ex.run_steps(n)
            guard -= n
            lc.on_steps(n)
            chunk_i += 1
            if self.ckpt_hook is not None and not lc.done:
                self.ckpt_hook(lc, chunk_i)
            yield self._flush(lc, n)
        assert guard > 0, f"task {lc.task_name} stopped progressing"
        yield self._flush(lc, 0)
        ex.remove_task(lc.task_name)
        return lc.result()

    def _flush(self, lc: TaskLifecycle, steps: int) -> ChunkReport:
        return ChunkReport(
            steps_executed=steps, events=lc.drain_events(), phase=lc.phase,
            remaining_steps_bound=lc.remaining_steps_bound(),
            wall_time_s=self.backbone.take_wall(), task=lc.task_name,
            slots_in_use=lc.slots_in_use(), slots_bound=lc.slots_bound(),
            tokens_executed=self.backbone.take_tokens(),
            slot_tokens=self.backbone.slot_token_widths(),
            slot_ranks=self.backbone.slot_rank_vector())
