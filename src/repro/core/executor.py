"""Batched multi-LoRA executor: one task, Z concurrent adapter slots.

Implements the full per-task ALTO lifecycle (paper §4-§6):

  1. WARMUP with rotation: all K candidate jobs get ``warmup_steps`` of
     training, cycling through the Z device slots in waves when K > Z;
     online pattern detection (divergence) is live during warmup; rotated
     jobs carry exact optimizer state via host snapshots.
  2. SELECTION at the warmup boundary: survivors ranked by val loss,
     top ceil(25% * K) continue (underperformance exits).
  3. CONTINUE-TRAINING: survivors train to their step budget with online
     divergence + overfitting detection; overfit exits checkpoint their
     best-val adapter; freed slots are BACKFILLED from the pending queue
     (intra-task online scheduling, §7.1) via the admission policy.

The executor is shape-static: (Z, per-adapter batch, seq) never changes, so
every admit/evict is an array update, not a recompile.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TrainConfig
from repro.core import steps as STEPS
from repro.core.adapter_state import SlotManager, SlotSnapshot
from repro.core.early_exit import (EarlyExitConfig, ExitDecision, ExitReason,
                                   JobMonitor, warmup_select)
from repro.data.synthetic import SlotBatcher, TaskDataset
from repro.models import model as M


@dataclasses.dataclass
class JobResult:
    job_id: str
    config: TrainConfig
    best_val: float
    best_val_step: int
    exit_reason: Optional[ExitReason]
    steps_trained: int
    samples_trained: int
    adapter: Optional[Dict] = None          # best checkpoint (winner only)


@dataclasses.dataclass
class TaskResult:
    task_name: str
    best_job: str
    best_val: float
    job_results: Dict[str, JobResult]
    wall_time_s: float
    total_samples: int
    samples_saved_frac: float
    exit_counts: Dict[str, int]


class BatchedExecutor:
    def __init__(self, cfg: ModelConfig, params: Dict, dataset: TaskDataset,
                 *, Z: int, per_adapter_batch: int,
                 ee: EarlyExitConfig = EarlyExitConfig(),
                 eval_every: int = 5, seed: int = 0,
                 loss_kind: str = "sft", batcher=None):
        self.cfg = cfg
        self.params = params
        self.dataset = dataset
        self.Z = Z
        self.b = per_adapter_batch
        self.ee = ee
        self.eval_every = eval_every
        key = jax.random.PRNGKey(seed)
        self.key, k_slots = jax.random.split(key)
        self.slots = SlotManager(cfg, Z, M.target_shapes(cfg), k_slots)
        # custom batcher (e.g. PairSlotBatcher for DPO) or token LM default
        self.batcher = batcher if batcher is not None else SlotBatcher(
            dataset, Z, per_adapter_batch, seed=seed)
        self._train_step = jax.jit(
            STEPS.make_train_step(cfg, loss_kind=loss_kind))
        self._eval_step = jax.jit(
            STEPS.make_eval_step(cfg, loss_kind=loss_kind))
        self.monitors: Dict[str, JobMonitor] = {}
        self.snapshots: Dict[str, SlotSnapshot] = {}
        self._best_ckpt: Dict[str, Dict] = {}
        self._queue: List[Tuple[str, TrainConfig]] = []
        self._budget: Optional[int] = None

    def _next_key(self) -> jax.Array:
        self.key, k = jax.random.split(self.key)
        return k

    # ------------------------------------------------------------------ util
    def _run_steps(self, n: int, step_offset: Dict[str, int]) -> None:
        """Train all active slots for n steps, with eval/pattern checks."""
        for i in range(n):
            batch = {k: jnp.asarray(v)
                     for k, v in self.batcher.next_batch_dict().items()}
            self.slots.lora, self.slots.opt_state, metrics = self._train_step(
                self.params, self.slots.lora, self.slots.opt_state,
                self.slots.hp, self.slots.active, self.slots.ranks, batch)
            per_loss = np.asarray(metrics["per_slot_loss"])
            for job, slot in self.slots.occupied().items():
                self.monitors[job].observe_train(float(per_loss[slot]))
                step_offset[job] = step_offset.get(job, 0) + 1
            if (i + 1) % self.eval_every == 0 or i == n - 1:
                self._eval_and_detect(step_offset)
            if self._budget is not None:
                for job, slot in list(self.slots.occupied().items()):
                    if step_offset.get(job, 0) >= self._budget:
                        self.monitors[job]._exit(
                            ExitReason.COMPLETED, step_offset[job])
                        self.slots.evict(slot)
                        self._backfill(slot)

    def _eval_and_detect(self, step_offset: Dict[str, int]) -> None:
        batch = {k: jnp.asarray(v)
                 for k, v in self.batcher.val_batch_dict().items()}
        val = np.asarray(self._eval_step(
            self.params, self.slots.lora, self.slots.active, batch))
        for job, slot in list(self.slots.occupied().items()):
            mon = self.monitors[job]
            prev_best = mon.best_val
            decision = mon.observe_val(float(val[slot]), step_offset[job])
            # checkpoint best-val adapter (cheap: host copy of one slot)
            if mon.val_hist[-1] <= prev_best:
                self._best_ckpt[job] = self.slots.adapter_of(job)
            if decision is not None:
                self._exit_job(job, slot, decision)

    def _exit_job(self, job: str, slot: int, decision: ExitDecision) -> None:
        self.slots.evict(slot)
        self._backfill(slot)

    def _backfill(self, slot: int) -> None:
        """Intra-task online admission: prefer same-batch-size pending jobs
        (homogeneous packing is structural here — one executor, one b)."""
        if self._queue:
            job_id, tc = self._queue.pop(0)
            if job_id in self.snapshots:
                self.slots.restore(slot, self.snapshots.pop(job_id), tc)
            else:
                self.slots.admit(slot, job_id, tc, self._next_key())

    # ------------------------------------------------------------------ run
    def run_task(self, task_name: str, jobs: Dict[str, TrainConfig],
                 total_steps: int) -> TaskResult:
        t0 = time.time()
        K = len(jobs)
        warmup = self.ee.warmup_steps(total_steps)
        self.monitors = {j: JobMonitor(self.ee, j) for j in jobs}
        self._best_ckpt: Dict[str, Dict] = {}
        self._queue: List[Tuple[str, TrainConfig]] = []
        job_items = list(jobs.items())

        # ---- phase 1: warmup waves (rotation when K > Z)
        waves = [job_items[i:i + self.Z] for i in range(0, K, self.Z)]
        steps_done: Dict[str, int] = {}
        for wave in waves:
            for s, (job_id, tc) in enumerate(wave):
                self.slots.admit(s, job_id, tc, self._next_key())
            self._queue = []
            self._run_steps(warmup, steps_done)
            # snapshot+rotate out whatever survived this wave
            for job_id, slot in list(self.slots.occupied().items()):
                self.snapshots[job_id] = self.slots.snapshot(slot)
                self.slots.evict(slot)

        # ---- phase 2: warmup-boundary selection (underperformance)
        kept, dropped = warmup_select(self.monitors, self.ee,
                                      num_candidates=K)
        for j in dropped:
            self.monitors[j]._exit(ExitReason.UNDERPERFORMING,
                                   steps_done.get(j, warmup))
            self.snapshots.pop(j, None)

        # ---- phase 3: continue-training with online detection + backfill
        self._budget = total_steps
        self._queue = [(j, jobs[j]) for j in kept]
        for slot in self.slots.free_slots():
            if not self._queue:
                break
            self._backfill(slot)
        guard = 10 * total_steps * max(len(kept) // max(self.Z, 1), 1) + 10
        while self.slots.occupied() and guard > 0:
            chunk = self.eval_every
            self._run_steps(chunk, steps_done)
            guard -= chunk
        self._budget = None
        for job_id, slot in list(self.slots.occupied().items()):
            self.monitors[job_id]._exit(
                ExitReason.COMPLETED, steps_done.get(job_id, total_steps))
            self.slots.evict(slot)

        # ---- results
        results: Dict[str, JobResult] = {}
        for job_id, tc in jobs.items():
            mon = self.monitors[job_id]
            results[job_id] = JobResult(
                job_id=job_id, config=tc, best_val=mon.best_val,
                best_val_step=mon.best_val_step,
                exit_reason=(mon.exited.reason if mon.exited else None),
                steps_trained=mon.steps_trained,
                samples_trained=mon.steps_trained * self.b)
        finite = {j: r for j, r in results.items()
                  if np.isfinite(r.best_val)}
        best_job = min(finite, key=lambda j: finite[j].best_val)
        results[best_job].adapter = self._best_ckpt.get(best_job)
        total_samples = sum(r.samples_trained for r in results.values())
        full_samples = K * total_steps * self.b
        exit_counts: Dict[str, int] = {}
        for r in results.values():
            if r.exit_reason is not None:
                exit_counts[r.exit_reason.value] = (
                    exit_counts.get(r.exit_reason.value, 0) + 1)
        return TaskResult(
            task_name=task_name, best_job=best_job,
            best_val=results[best_job].best_val, job_results=results,
            wall_time_s=time.time() - t0, total_samples=total_samples,
            samples_saved_frac=1.0 - total_samples / max(full_samples, 1),
            exit_counts=exit_counts)
