"""Batched multi-LoRA executor: one task, Z concurrent adapter slots.

Implements the full per-task ALTO lifecycle (paper §4-§6):

  1. WARMUP with rotation: all K candidate jobs get ``warmup_steps`` of
     training, cycling through the Z device slots in waves when K > Z;
     online pattern detection (divergence) is live during warmup; rotated
     jobs carry exact optimizer state via host snapshots.
  2. SELECTION at the warmup boundary: survivors ranked by val loss,
     top ceil(25% * K) continue (underperformance exits).
  3. CONTINUE-TRAINING: survivors train to their step budget with online
     divergence + overfitting detection; overfit exits checkpoint their
     best-val adapter; freed slots are BACKFILLED from the pending queue
     (intra-task online scheduling, §7.1) via the admission policy.

The executor is shape-static: (Z, per-adapter batch, seq) never changes, so
every admit/evict is an array update, not a recompile.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TrainConfig
from repro.core import steps as STEPS
from repro.core.adapter_state import SlotManager, SlotSnapshot
from repro.core.early_exit import (EarlyExitConfig, ExitDecision, ExitReason,
                                   JobMonitor, warmup_select)
from repro.data.synthetic import SlotBatcher, TaskDataset
from repro.models import model as M
from repro.sched.events import EventKind, ProgressEvent


@dataclasses.dataclass(frozen=True)
class ChunkReport:
    """One bounded slice of a task's execution (elastic runtime unit).

    The elastic cluster runtime (sched/cluster.py) interleaves many tasks
    by stepping each executor one chunk at a time; ``steps_executed``
    converts to virtual cluster time via the profiled step time, and
    ``events`` carries every lifecycle transition that fired inside the
    chunk (exits, selection, completion) so the runtime can replan."""
    steps_executed: int
    events: Tuple[ProgressEvent, ...]
    phase: str
    remaining_steps_bound: int
    wall_time_s: float = 0.0     # realized host seconds (profiler feedback)


@dataclasses.dataclass
class JobResult:
    job_id: str
    config: TrainConfig
    best_val: float
    best_val_step: int
    exit_reason: Optional[ExitReason]
    steps_trained: int
    samples_trained: int
    adapter: Optional[Dict] = None          # best checkpoint (winner only)


@dataclasses.dataclass
class TaskResult:
    task_name: str
    best_job: str
    best_val: float
    job_results: Dict[str, JobResult]
    wall_time_s: float
    total_samples: int
    samples_saved_frac: float
    exit_counts: Dict[str, int]


class BatchedExecutor:
    def __init__(self, cfg: ModelConfig, params: Dict, dataset: TaskDataset,
                 *, Z: int, per_adapter_batch: int,
                 ee: EarlyExitConfig = EarlyExitConfig(),
                 eval_every: int = 5, seed: int = 0,
                 loss_kind: str = "sft", batcher=None):
        self.cfg = cfg
        self.params = params
        self.dataset = dataset
        self.Z = Z
        self.b = per_adapter_batch
        self.ee = ee
        self.eval_every = eval_every
        key = jax.random.PRNGKey(seed)
        self.key, k_slots = jax.random.split(key)
        self.slots = SlotManager(cfg, Z, M.target_shapes(cfg), k_slots)
        # custom batcher (e.g. PairSlotBatcher for DPO) or token LM default
        self.batcher = batcher if batcher is not None else SlotBatcher(
            dataset, Z, per_adapter_batch, seed=seed)
        self._train_step = jax.jit(
            STEPS.make_train_step(cfg, loss_kind=loss_kind))
        self._eval_step = jax.jit(
            STEPS.make_eval_step(cfg, loss_kind=loss_kind))
        self.monitors: Dict[str, JobMonitor] = {}
        self.snapshots: Dict[str, SlotSnapshot] = {}
        self._best_ckpt: Dict[str, Dict] = {}
        self._queue: List[Tuple[str, TrainConfig]] = []
        self._budget: Optional[int] = None
        # chunked-execution state (see run_task_chunks)
        self._chunk_wall = 0.0
        self._chunk_events: List[ProgressEvent] = []
        self._task_name = ""
        self._phase = "idle"
        self._K = 0
        self._total_steps = 0
        self._warmup_steps = 0
        self._waves_left = 0
        self._steps_left_in_wave = 0
        self._steps_done: Dict[str, int] = {}

    def _next_key(self) -> jax.Array:
        self.key, k = jax.random.split(self.key)
        return k

    # ------------------------------------------------------------------ util
    def _run_steps(self, n: int, step_offset: Dict[str, int]) -> None:
        """Train all active slots for n steps, with eval/pattern checks."""
        t0 = time.time()
        for i in range(n):
            batch = {k: jnp.asarray(v)
                     for k, v in self.batcher.next_batch_dict().items()}
            self.slots.lora, self.slots.opt_state, metrics = self._train_step(
                self.params, self.slots.lora, self.slots.opt_state,
                self.slots.hp, self.slots.active, self.slots.ranks, batch)
            per_loss = np.asarray(metrics["per_slot_loss"])
            for job, slot in self.slots.occupied().items():
                self.monitors[job].observe_train(float(per_loss[slot]))
                step_offset[job] = step_offset.get(job, 0) + 1
            if (i + 1) % self.eval_every == 0 or i == n - 1:
                self._eval_and_detect(step_offset)
            if self._budget is not None:
                for job, slot in list(self.slots.occupied().items()):
                    if step_offset.get(job, 0) >= self._budget:
                        self.monitors[job]._exit(
                            ExitReason.COMPLETED, step_offset[job])
                        self._chunk_events.append(ProgressEvent(
                            kind=EventKind.JOB_EXITED, task=self._task_name,
                            job=job, reason=ExitReason.COMPLETED.value,
                            step=step_offset[job]))
                        self.slots.evict(slot)
                        self._backfill(slot)
        # accumulate actual train/eval host time only — flush-to-flush
        # deltas would also bill time the generator spent suspended while
        # other tasks' chunks executed
        self._chunk_wall += time.time() - t0

    def _eval_and_detect(self, step_offset: Dict[str, int]) -> None:
        batch = {k: jnp.asarray(v)
                 for k, v in self.batcher.val_batch_dict().items()}
        val = np.asarray(self._eval_step(
            self.params, self.slots.lora, self.slots.active, batch))
        for job, slot in list(self.slots.occupied().items()):
            mon = self.monitors[job]
            prev_best = mon.best_val
            decision = mon.observe_val(float(val[slot]), step_offset[job])
            # checkpoint best-val adapter (cheap: host copy of one slot)
            if mon.val_hist[-1] <= prev_best:
                self._best_ckpt[job] = self.slots.adapter_of(job)
            if decision is not None:
                self._exit_job(job, slot, decision)

    def _exit_job(self, job: str, slot: int, decision: ExitDecision) -> None:
        self._chunk_events.append(ProgressEvent(
            kind=EventKind.JOB_EXITED, task=self._task_name, job=job,
            reason=decision.reason.value, step=decision.step))
        self.slots.evict(slot)
        self._backfill(slot)

    def _backfill(self, slot: int) -> None:
        """Intra-task online admission: prefer same-batch-size pending jobs
        (homogeneous packing is structural here — one executor, one b)."""
        if self._queue:
            job_id, tc = self._queue.pop(0)
            if job_id in self.snapshots:
                self.slots.restore(slot, self.snapshots.pop(job_id), tc)
            else:
                self.slots.admit(slot, job_id, tc, self._next_key())

    # ------------------------------------------------------------------ run
    def run_task(self, task_name: str, jobs: Dict[str, TrainConfig],
                 total_steps: int) -> TaskResult:
        """Run the full lifecycle to completion (static path)."""
        gen = self.run_task_chunks(task_name, jobs, total_steps)
        while True:
            try:
                next(gen)
            except StopIteration as done:
                return done.value

    def remaining_steps_bound(self) -> int:
        """Upper bound on executor steps left in the current lifecycle,
        assuming no further pattern exits (the residual d_i the elastic
        runtime plans with; shrinks monotonically as events fire)."""
        Z = max(self.Z, 1)
        cont_budget = self._total_steps - self._warmup_steps
        if self._phase == "warmup":
            survivors = self.ee.top_k(self._K)
            cont = -(-survivors // Z) * cont_budget
            return (self._steps_left_in_wave
                    + self._waves_left * self._warmup_steps + cont)
        if self._phase == "continue":
            alive = list(self.slots.occupied()) + [j for j, _ in self._queue]
            rem = [max(self._total_steps - self._steps_done.get(j, 0), 0)
                   for j in alive]
            if not rem:
                return 0
            return -(-len(rem) // Z) * max(rem)
        return 0

    def _flush_chunk(self, steps: int) -> ChunkReport:
        events, self._chunk_events = tuple(self._chunk_events), []
        wall, self._chunk_wall = self._chunk_wall, 0.0
        return ChunkReport(steps_executed=steps, events=events,
                           phase=self._phase,
                           remaining_steps_bound=self.remaining_steps_bound(),
                           wall_time_s=wall)

    def run_task_chunks(self, task_name: str, jobs: Dict[str, TrainConfig],
                        total_steps: int):
        """Generator form of the lifecycle: yields a ChunkReport after every
        bounded chunk (<= eval_every steps) so the elastic cluster runtime
        can interleave many tasks and replan on the events each chunk
        surfaces. ``return``s the TaskResult (StopIteration.value)."""
        t0 = time.time()
        self._chunk_wall = 0.0
        K = len(jobs)
        warmup = self.ee.warmup_steps(total_steps)
        self.monitors = {j: JobMonitor(self.ee, j) for j in jobs}
        self._best_ckpt = {}
        self._queue = []
        self._chunk_events = []
        self._task_name = task_name
        self._K = K
        self._total_steps = total_steps
        self._warmup_steps = warmup
        job_items = list(jobs.items())

        # ---- phase 1: warmup waves (rotation when K > Z)
        waves = [job_items[i:i + self.Z] for i in range(0, K, self.Z)]
        steps_done: Dict[str, int] = {}
        self._steps_done = steps_done
        self._phase = "warmup"
        self._waves_left = len(waves)
        for wave in waves:
            for s, (job_id, tc) in enumerate(wave):
                self.slots.admit(s, job_id, tc, self._next_key())
            self._queue = []
            self._waves_left -= 1
            rem = warmup
            while rem > 0:
                # eval_every-aligned slices reproduce run_task's eval points
                n = min(self.eval_every, rem)
                self._steps_left_in_wave = rem
                self._run_steps(n, steps_done)
                rem -= n
                self._steps_left_in_wave = rem
                yield self._flush_chunk(n)
            # snapshot+rotate out whatever survived this wave
            for job_id, slot in list(self.slots.occupied().items()):
                self.snapshots[job_id] = self.slots.snapshot(slot)
                self.slots.evict(slot)

        # ---- phase 2: warmup-boundary selection (underperformance)
        kept, dropped = warmup_select(self.monitors, self.ee,
                                      num_candidates=K)
        for j in dropped:
            self.monitors[j]._exit(ExitReason.UNDERPERFORMING,
                                   steps_done.get(j, warmup))
            self.snapshots.pop(j, None)
        self._phase = "continue"
        if dropped:
            self._chunk_events.append(ProgressEvent(
                kind=EventKind.WARMUP_SELECTION, task=task_name,
                reason=ExitReason.UNDERPERFORMING.value,
                step=warmup, dropped=tuple(dropped)))

        # ---- phase 3: continue-training with online detection + backfill
        self._budget = total_steps
        self._queue = [(j, jobs[j]) for j in kept]
        for slot in self.slots.free_slots():
            if not self._queue:
                break
            self._backfill(slot)
        yield self._flush_chunk(0)
        guard = 10 * total_steps * max(len(kept) // max(self.Z, 1), 1) + 10
        while self.slots.occupied() and guard > 0:
            # jobs already at budget (warmup == total_steps) complete
            # without training another step
            for job, slot in list(self.slots.occupied().items()):
                if steps_done.get(job, 0) >= total_steps:
                    self.monitors[job]._exit(
                        ExitReason.COMPLETED, steps_done[job])
                    self._chunk_events.append(ProgressEvent(
                        kind=EventKind.JOB_EXITED, task=task_name, job=job,
                        reason=ExitReason.COMPLETED.value,
                        step=steps_done[job]))
                    self.slots.evict(slot)
                    self._backfill(slot)
            if not self.slots.occupied():
                yield self._flush_chunk(0)
                break
            # clamp to the occupied jobs' remaining budget so the realized
            # step count never exceeds the profiler's worst-case estimate
            # (no ghost steps on empty slots after the last eviction)
            rem = max(total_steps - steps_done.get(j, 0)
                      for j in self.slots.occupied())
            chunk = min(self.eval_every, rem)
            self._run_steps(chunk, steps_done)
            guard -= chunk
            yield self._flush_chunk(chunk)
        self._budget = None
        for job_id, slot in list(self.slots.occupied().items()):
            self.monitors[job_id]._exit(
                ExitReason.COMPLETED, steps_done.get(job_id, total_steps))
            self.slots.evict(slot)
        self._phase = "done"

        # ---- results
        results: Dict[str, JobResult] = {}
        for job_id, tc in jobs.items():
            mon = self.monitors[job_id]
            results[job_id] = JobResult(
                job_id=job_id, config=tc, best_val=mon.best_val,
                best_val_step=mon.best_val_step,
                exit_reason=(mon.exited.reason if mon.exited else None),
                steps_trained=mon.steps_trained,
                samples_trained=mon.steps_trained * self.b)
        finite = {j: r for j, r in results.items()
                  if np.isfinite(r.best_val)}
        best_job = min(finite, key=lambda j: finite[j].best_val)
        results[best_job].adapter = self._best_ckpt.get(best_job)
        total_samples = sum(r.samples_trained for r in results.values())
        full_samples = K * total_steps * self.b
        exit_counts: Dict[str, int] = {}
        for r in results.values():
            if r.exit_reason is not None:
                exit_counts[r.exit_reason.value] = (
                    exit_counts.get(r.exit_reason.value, 0) + 1)
        self._chunk_events.append(ProgressEvent(
            kind=EventKind.TASK_COMPLETED, task=task_name,
            detail=f"best={best_job}"))
        yield self._flush_chunk(0)
        return TaskResult(
            task_name=task_name, best_job=best_job,
            best_val=results[best_job].best_val, job_results=results,
            wall_time_s=time.time() - t0, total_samples=total_samples,
            samples_saved_frac=1.0 - total_samples / max(full_samples, 1),
            exit_counts=exit_counts)
