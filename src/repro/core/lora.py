"""Multi-adapter (slot-stacked) LoRA: the ALTO workload unit.

All adapters for one executor live in slot-stacked tensors with a leading
``Z`` axis (paper §A.1 rank-only padding):

    A: [Z, d_in, r_max]     B: [Z, r_max, d_out]

Per-slot true ranks are expressed by zeroing columns/rows beyond ``r_i``
(``rank_mask``); the padded region provably contributes zero to the output
and receives zero gradient (B's padded rows are zero ⇒ dS pads are zero ⇒
dA pads are zero), and the optimizer additionally re-masks after each update.
Under a ``slot_ranks`` binding the ranks become a COMPUTE dimension instead:
the rank-local grouped-GEMM kernels skip dead rank tiles outright and the
re-mask is provably redundant (the padded region's gradient is exactly zero
by construction, not by cancellation).

``lora_delta`` dispatches between the pure-jnp path (the mathematical
reference; used under pjit/GSPMD where XLA fuses it) and the Pallas grouped
kernel (``repro.kernels.grouped_lora``) — the paper's fused grouped GEMM,
validated in interpret mode on CPU and targeted at TPU VMEM/MXU.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

_backend = threading.local()

BACKENDS = ("jnp", "pallas", "pallas_interpret")


def set_backend(name: str) -> None:
    assert name in BACKENDS, name
    _backend.name = name


def get_backend() -> str:
    return getattr(_backend, "name", "jnp")


@contextlib.contextmanager
def backend(name: str):
    prev = get_backend()
    set_backend(name)
    try:
        yield
    finally:
        set_backend(prev)


# ---------------------------------------------------------------------------
# Ragged slot widths (heterogeneous per-adapter batch sizes)
# ---------------------------------------------------------------------------
#
# When co-located adapters train with different batch widths, slot z only
# owns the first ``rows[z]`` token rows of its [T = b_max*seq] lane.
# ``ragged_rows`` binds the per-slot row counts for the duration of a trace
# (the executor's fused train step sets it from the batch it packed); every
# ``lora_delta`` inside the trace then masks/skips the padded rows — the
# jnp path by zeroing them, the Pallas path via the ragged grouped-GEMM
# kernels that skip dead tiles outright.

@contextlib.contextmanager
def ragged_rows(rows: Optional[jnp.ndarray]):
    """Bind per-slot valid token-row counts ([Z] int32, in flattened
    lead-dims units) for lora_delta calls traced under this context."""
    prev = getattr(_backend, "rows", None)
    _backend.rows = rows
    try:
        yield
    finally:
        _backend.rows = prev


def get_ragged_rows() -> Optional[jnp.ndarray]:
    return getattr(_backend, "rows", None)


def _apply_row_mask(x: jnp.ndarray, rows: jnp.ndarray) -> jnp.ndarray:
    """Zero token rows >= rows[z]; row index runs over the flattened
    non-feature lead dims (b*seq for [Z, b, S, d] activations)."""
    Z = x.shape[0]
    n = 1
    for d in x.shape[1:-1]:
        n *= d
    idx = jnp.arange(n).reshape((1,) + x.shape[1:-1])
    keep = idx < rows.reshape((Z,) + (1,) * (x.ndim - 2))
    return jnp.where(keep[..., None], x, jnp.zeros((), x.dtype))


# ---------------------------------------------------------------------------
# Rank-local slot ranks (per-slot true-rank compute)
# ---------------------------------------------------------------------------
#
# Rank heterogeneity was historically pure zero-masking: every slot padded
# to r_max, so a rank-4 adapter co-located with a rank-64 one paid 16x its
# true FLOPs in every grouped GEMM. ``slot_ranks`` binds the per-slot TRUE
# ranks for the duration of a trace (the executor's fused step sets it
# from SlotManager state whenever a resident slot's rank is below r_max);
# every ``lora_delta`` inside the trace then confines slot z's compute to
# its first ranks[z] rank rows/columns — the jnp path by masking A/B (so
# correctness never leans on the padded region being zero), the Pallas
# path via the rank-local grouped-GEMM kernels whose dead rank tiles skip
# the MXU outright. Composes with ``ragged_rows``.

@contextlib.contextmanager
def slot_ranks(ranks: Optional[jnp.ndarray]):
    """Bind per-slot true ranks ([Z] int32) for lora_delta calls traced
    under this context."""
    prev = getattr(_backend, "ranks", None)
    _backend.ranks = ranks
    try:
        yield
    finally:
        _backend.ranks = prev


def get_slot_ranks() -> Optional[jnp.ndarray]:
    return getattr(_backend, "ranks", None)


def _apply_rank_masks(A: jnp.ndarray, B: jnp.ndarray, ranks: jnp.ndarray
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Zero A's columns / B's rows at indices >= ranks[z]. For a
    full-rank slot the select is the identity, which keeps fused-vs-solo
    loss histories bitwise equal across the bind/no-bind dispatch."""
    keep = jnp.arange(A.shape[-1])[None, :] < ranks[:, None]     # [Z, r]
    Am = jnp.where(keep[:, None, :], A, jnp.zeros((), A.dtype))
    Bm = jnp.where(keep[:, :, None], B, jnp.zeros((), B.dtype))
    return Am, Bm


# ---------------------------------------------------------------------------
# Application
# ---------------------------------------------------------------------------

def lora_delta(x: jnp.ndarray, A: jnp.ndarray, B: jnp.ndarray,
               scale: jnp.ndarray | float) -> jnp.ndarray:
    """scale * (x @ A) @ B, grouped over the leading slot axis.

    x: [Z, ..., d_in]; A: [Z, d_in, r]; B: [Z, r, d_out]; scale: [] or [Z].
    Under a ``ragged_rows`` binding, slot z's delta is computed over only
    its first rows[z] token rows (zero delta + zero grads on the pad).
    """
    name = get_backend()
    rows = get_ragged_rows()
    ranks = get_slot_ranks()
    if name == "jnp":
        if rows is not None:
            x = _apply_row_mask(x, rows)
        if ranks is not None:
            A, B = _apply_rank_masks(A, B, ranks)
        return _lora_delta_jnp(x, A, B, scale)
    from repro.kernels.grouped_lora import ops as kops
    lead = x.shape[:-1]
    Z = x.shape[0]
    xt = x.reshape(Z, -1, x.shape[-1])
    interpret = (name == "pallas_interpret")
    if ranks is not None:
        y = kops.ranklocal_grouped_lora(
            xt, A, B, _scale_vec(scale, Z, x.dtype), ranks, rows=rows,
            interpret=interpret)
    elif rows is not None:
        y = kops.ragged_grouped_lora(xt, A, B, _scale_vec(scale, Z, x.dtype),
                                     rows, interpret=interpret)
    else:
        y = kops.grouped_lora(xt, A, B, _scale_vec(scale, Z, x.dtype),
                              interpret=interpret)
    return y.reshape(*lead, B.shape[-1])


def _scale_vec(scale, Z: int, dtype) -> jnp.ndarray:
    s = jnp.asarray(scale, jnp.float32)
    if s.ndim == 0:
        s = jnp.broadcast_to(s, (Z,))
    return s


def _lora_delta_jnp(x, A, B, scale):
    dt = x.dtype
    s = jnp.einsum("z...d,zdr->z...r", x, A.astype(dt))
    y = jnp.einsum("z...r,zro->z...o", s, B.astype(dt))
    sv = _scale_vec(scale, x.shape[0], dt)
    sv = sv.reshape((x.shape[0],) + (1,) * (y.ndim - 1))
    return y * sv.astype(dt)


def proj(x: jnp.ndarray, W: jnp.ndarray,
         lora_pair: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
         scale: jnp.ndarray | float = 2.0,
         name: Optional[str] = None) -> jnp.ndarray:
    """Frozen base projection + optional grouped LoRA residual.

    x: [Z, ..., d_in]; W: [d_in, d_out] (frozen, slot-shared). ``name``
    lets the sharding policy gather the ZeRO-sharded frozen weight over the
    adapter ("data") axis before use — the paper's Fig. 8 FSDP all-gather,
    instead of GSPMD's default activation-psum (§Perf opt_level >= 1).
    """
    from repro.models.shardctx import constrain
    if name is not None:
        W = constrain(W, f"weight:{name}")
    y = jnp.einsum("z...d,do->z...o", x, W)
    if lora_pair is not None:
        A, B = lora_pair
        y = y + lora_delta(x, A, B, scale)
    return y


# ---------------------------------------------------------------------------
# Initialization / masking
# ---------------------------------------------------------------------------

def rank_mask(ranks: jnp.ndarray, r_max: int) -> jnp.ndarray:
    """[Z] int ranks -> [Z, r_max] float {0,1} mask."""
    return (jnp.arange(r_max)[None, :] < ranks[:, None]).astype(jnp.float32)


def init_slot_lora(key: jax.Array, d_in: int, d_out: int, r_max: int, Z: int,
                   ranks: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """LoRA init: A ~ N(0, 1/r_max) (rank-masked), B = 0. fp32 master."""
    A = jax.random.normal(key, (Z, d_in, r_max), jnp.float32)
    A = A * (r_max ** -0.5) * rank_mask(ranks, r_max)[:, None, :]
    B = jnp.zeros((Z, r_max, d_out), jnp.float32)
    return A, B


def init_lora_tree(key: jax.Array, cfg: ModelConfig, Z: int,
                   ranks: jnp.ndarray,
                   target_shapes: Dict[str, Tuple[int, int]],
                   num_layers: Optional[int] = None) -> Dict:
    """Stacked-over-layers LoRA tree: {target: {"A": [L,Z,din,r], "B": ...}}.

    Only targets present in ``target_shapes`` AND ``cfg.lora.targets`` get
    adapters (paper: all attention + MLP projections; per-family sets differ).
    """
    L = num_layers if num_layers is not None else cfg.num_layers
    r = cfg.lora.r_max
    tree: Dict[str, Dict[str, jnp.ndarray]] = {}
    targets = [t for t in cfg.lora.targets if t in target_shapes]
    keys = jax.random.split(key, max(len(targets) * L, 1))
    i = 0
    for t in targets:
        d_in, d_out = target_shapes[t]
        As, Bs = [], []
        for _ in range(L):
            A, B = init_slot_lora(keys[i], d_in, d_out, r, Z, ranks)
            As.append(A)
            Bs.append(B)
            i += 1
        tree[t] = {"A": jnp.stack(As), "B": jnp.stack(Bs)}
    return tree


def mask_lora_tree(tree: Dict, ranks: jnp.ndarray, r_max: int) -> Dict:
    """Re-apply rank masks to a stacked LoRA tree (post-optimizer-step)."""
    m = rank_mask(ranks, r_max)  # [Z, r]

    def mask_leaf(path_is_A: bool, x: jnp.ndarray) -> jnp.ndarray:
        if path_is_A:   # [L, Z, d_in, r]
            return x * m[None, :, None, :]
        return x * m[None, :, :, None]   # B: [L, Z, r, d_out]

    return {t: {"A": mask_leaf(True, ab["A"]), "B": mask_leaf(False, ab["B"])}
            for t, ab in tree.items()}


def slot_update(tree: Dict, slot: int, new_tree_slot: Dict) -> Dict:
    """Functionally replace one slot's adapter params (early-exit swap-in)."""
    def upd(old: jnp.ndarray, new: jnp.ndarray) -> jnp.ndarray:
        return old.at[:, slot].set(new)
    return jax.tree_util.tree_map(upd, tree, new_tree_slot)


def gather_slots(tree: Dict, slots: "list[int]") -> Dict:
    """Extract a sub-tree of the given slots (leading Z axis becomes
    len(slots)). Used to address one TASK's adapters inside a shared
    multi-task executor — slots co-located on one backbone need not be
    contiguous."""
    import numpy as _np
    idx = _np.asarray(slots, _np.int32)
    return jax.tree_util.tree_map(lambda x: x[:, idx], tree)


def zero_slot(tree: Dict, slot: int) -> Dict:
    """Zero a slot's adapter params (eviction)."""
    def z(x: jnp.ndarray) -> jnp.ndarray:
        return x.at[:, slot].set(0.0)
    return jax.tree_util.tree_map(z, tree)
