"""ALTO as a long-lived tuning service (paper §4: LoRA-tuning-as-a-service).

The batch ``Engine`` API hands over a closed task list and waits for one
terminal report. ``TuningService`` is the multi-tenant redesign: tenants
``submit(task, at=...)`` at any virtual time — including while the cluster
is mid-execution — and get back a ``TaskHandle`` with ``status()``,
``result()``, ``cancel()``, and a per-task event ``stream()``. The service
owns an ``ElasticClusterRuntime`` session (``sched/cluster.py``) that
admits arrivals into the running event loop, re-solves residual placement
around them (release-constrained), and applies the bounded-delay plan
adoption rule.

    svc = TuningService(total_gpus=8)
    h = svc.submit(task_a)                       # t = 0
    h2 = svc.submit(task_b, at=120.0)            # arrives mid-session
    h2.cancel(at=300.0)                          # tenant withdraws
    best = h.result()                            # drives the loop to done
    report = svc.run_until_idle()

The service also closes the profiler feedback loop (ROADMAP item): every
completed task records its realized duration, virtual step time, and wall
step time into a ``ProfileStore`` shared with the engine's profiler, so
later admissions in the same session are scheduled from observed rather
than analytic estimates.

Time is *virtual cluster time* (the same timeline the elastic runtime and
benchmarks use): ``submit``/``cancel`` enqueue events, and the loop only
advances when driven via ``run_until_idle()``, ``handle.result()``, or
``handle.stream()``. On this single-host container training executes
sequentially either way, so the virtual timeline is observationally
identical to live stepping — which is what makes the service testable.
"""
from __future__ import annotations

import dataclasses
import enum
import json
import logging
import os
import threading
import time
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

from repro.core.early_exit import EarlyExitConfig
from repro.sched import profiler
from repro.sched.cluster import (ColocationSpec, ElasticClusterRuntime,
                                 RuntimeReport, TaskDriver)
from repro.sched.events import EventKind, ProgressEvent, event_to_json
from repro.sched.inter_task import Schedule, TaskSpec

_log = logging.getLogger(__name__)


def _task_record(task, early_exit: EarlyExitConfig) -> Optional[Dict]:
    """JSON-able description of an ``engine.Task`` for the journal, or
    ``None`` when the task is not serializable (in-memory ModelConfig /
    TaskDataset objects) — recovery then needs the task re-supplied via
    ``recover(tasks=...)``."""
    if not isinstance(task.model, str) or not isinstance(task.dataset, str):
        return None
    rec = {"model": task.model, "dataset": task.dataset,
           "search_space": task.search_space, "num_gpus": task.num_gpus,
           "max_steps": task.max_steps, "num_slots": task.num_slots,
           "seed": task.seed, "name": task.name,
           "loss_kind": task.loss_kind,
           "device_memory": task.device_memory,
           "early_exit": dataclasses.asdict(early_exit)}
    try:
        json.dumps(rec)
    except (TypeError, ValueError):
        return None
    return rec


def _task_from_record(rec: Dict) -> Tuple[Any, EarlyExitConfig]:
    from repro.core.engine import Task
    task = Task(model=rec["model"], dataset=rec["dataset"],
                search_space={k: list(v)
                              for k, v in rec["search_space"].items()},
                num_gpus=int(rec["num_gpus"]),
                max_steps=int(rec["max_steps"]),
                num_slots=int(rec["num_slots"]), seed=int(rec["seed"]),
                name=rec["name"], loss_kind=rec["loss_kind"],
                device_memory=int(rec["device_memory"]))
    return task, EarlyExitConfig(**rec["early_exit"])


class ServiceLoop:
    """Handle for the wall-clock background pump (``run_forever``)."""

    def __init__(self, thread: threading.Thread, stop: threading.Event):
        self._thread = thread
        self._stop = stop

    @property
    def alive(self) -> bool:
        return self._thread.is_alive()

    def stop(self, timeout: Optional[float] = 10.0) -> None:
        self._stop.set()
        self._thread.join(timeout)


class TaskState(enum.Enum):
    PENDING = "pending"        # submitted, not yet started (or not arrived)
    RUNNING = "running"
    COMPLETED = "completed"
    CANCELLED = "cancelled"

    @property
    def terminal(self) -> bool:
        return self in (TaskState.COMPLETED, TaskState.CANCELLED)


class TaskCancelled(Exception):
    """Raised by ``TaskHandle.result()`` when the task was cancelled."""


class QuotaExceeded(Exception):
    """Raised by ``TuningService.submit`` when a tenant's concurrent
    (non-terminal) task count would exceed ``max_tasks_per_tenant``."""


@dataclasses.dataclass(frozen=True)
class TaskStatus:
    name: str
    state: TaskState
    submitted_at: float
    started_at: Optional[float]
    finished_at: Optional[float]
    now: float                 # virtual cluster time of this snapshot


@dataclasses.dataclass
class _TaskMeta:
    spec: TaskSpec               # as admitted (feedback scale applied)
    unscaled_duration: float     # worst-case estimate feedback records vs
    submitted_at: float
    profile_key: Optional[Tuple]
    driver: Optional[TaskDriver] = None
    tenant: str = "default"
    colo: Optional[ColocationSpec] = None   # fuse key for serve metadata


class TaskHandle:
    """Tenant-side view of one submitted task."""

    def __init__(self, service: "TuningService", name: str):
        self._svc = service
        self.name = name

    def status(self) -> TaskStatus:
        return self._svc.status(self.name)

    def events(self) -> List[ProgressEvent]:
        """Events recorded so far for this task (does not drive the loop)."""
        return [e for e in self._svc._runtime_events()
                if e.task == self.name]

    def stream(self) -> Iterator[ProgressEvent]:
        """Yield this task's events as they fire, driving the service loop
        until the task reaches a terminal state."""
        seen = 0
        while True:
            evs = self._svc._runtime_events()
            for e in evs[seen:]:
                if e.task == self.name:
                    yield e
            seen = len(evs)
            if self.status().state.terminal or not self._svc._step():
                break
        for e in self._svc._runtime_events()[seen:]:
            if e.task == self.name:
                yield e

    def result(self) -> Any:
        """Drive the service until this task is terminal; return its result
        (a ``TaskResult`` for engine tasks, the driver result otherwise).
        Raises ``TaskCancelled`` if the task was cancelled."""
        self._svc._drive(lambda: self.status().state.terminal)
        st = self.status().state
        if st is TaskState.CANCELLED:
            raise TaskCancelled(self.name)
        return self._svc._results()[self.name]

    def cancel(self, at: Optional[float] = None) -> bool:
        return self._svc.cancel(self.name, at=at)


@dataclasses.dataclass
class ServiceReport:
    """Terminal report of one service session (superset of the runtime's)."""
    task_results: Dict[str, Any]
    makespan: float
    utilization: float
    replans: int
    plans_adopted: int
    plans_rejected: int
    events: List[ProgressEvent]
    cancelled: Tuple[str, ...]
    task_starts: Dict[str, float]
    task_ends: Dict[str, float]
    runtime: RuntimeReport
    colocated: Dict[str, str] = dataclasses.field(default_factory=dict)
    preemptions: int = 0
    migrations: int = 0


class TuningService:
    """Long-lived multi-tenant LoRA tuning service (see module docstring).

    ``delay_delta`` tunes plan adoption: ``None`` keeps the strict
    anomaly-safe rule (never start a task later than its incumbent bound —
    what batch mode uses for the elastic<=static guarantee); a float δ
    enables the bounded-delay rule (accept a delaying plan only when the
    projected makespan win is at least δ·max_delay, regret fallback
    otherwise), which is the right trade once arrivals make strictness
    systematically conservative.

    ``fusion_planning`` (default on) makes co-location a plan decision:
    every replan solves with fusion-aware placement (replica slots with
    token/rank budgets) instead of relying solely on opportunistic fusion
    at admission; ``migrate`` (default on) additionally lets the runtime
    evict or migrate a live guest whose replica regrew under it, moves
    that never delay the guest past its in-place projection.

    ``fitted=True`` swaps admission budgeting (the engine's memory model,
    hence ``admit_cross_task``/backfill/``plan_fused``) onto the
    profile-fitted (k0, k1, k2) cost models in ``sched/fitted.py`` once
    enough fused-step observations accumulate for a profile key —
    ``_feedback`` records one raw ``StepObservation`` per completed task
    either way, so a session budgets analytically until measurement can
    take over.
    """

    def __init__(self, total_gpus: Optional[int] = None,
                 strategy: Optional[str] = None,
                 eval_every: Optional[int] = None,
                 method: str = "cp", delay_delta: Optional[float] = 2.0,
                 profile_store: Optional[profiler.ProfileStore] = None,
                 engine=None, colocate: bool = True,
                 fusion_planning: bool = True, migrate: bool = True,
                 profile_path: Optional[str] = None,
                 max_tasks_per_tenant: Optional[int] = None,
                 serve_dir: Optional[str] = None,
                 fitted: Optional[bool] = None,
                 state_dir: Optional[str] = None,
                 ckpt_every: int = 1):
        if profile_store is None and profile_path is not None:
            # persistence across sessions (ROADMAP service hardening):
            # feedback observed by earlier service processes seeds this one
            profile_store = profiler.ProfileStore.load_or_new(profile_path)
        if engine is None:
            from repro.core.engine import Engine
            engine = Engine(strategy=strategy or "adapter_parallel",
                            total_gpus=total_gpus or 8,
                            eval_every=eval_every or 5,
                            profile_store=profile_store,
                            fitted=bool(fitted))
        else:
            # an explicit engine carries its own configuration; reject
            # conflicting explicit args instead of silently ignoring them
            if total_gpus is not None and total_gpus != engine.total_gpus:
                raise ValueError(f"total_gpus={total_gpus} conflicts with "
                                 f"engine.total_gpus={engine.total_gpus}")
            if strategy is not None and strategy != engine.strategy:
                raise ValueError("strategy conflicts with engine.strategy")
            if eval_every is not None and eval_every != engine.eval_every:
                raise ValueError("eval_every conflicts with "
                                 "engine.eval_every")
            if fitted is not None and bool(fitted) != engine.fitted:
                raise ValueError("fitted conflicts with engine.fitted")
        self.engine = engine
        self.profile_store = engine.profile_store
        self.total_gpus = engine.total_gpus
        self.profile_path = profile_path
        self._runtime = ElasticClusterRuntime(
            engine.total_gpus, method=method, delay_delta=delay_delta,
            colocate=colocate, fusion_planning=colocate and fusion_planning,
            migrate=colocate and migrate)
        self.max_tasks_per_tenant = max_tasks_per_tenant
        # tune-to-serve: completed tasks' winning adapters are checkpointed
        # under serve_dir and auto-published to an attached serving frontend
        self.serve_dir = serve_dir
        self.serving: Optional[Any] = None
        self._ckpt_paths: Dict[str, str] = {}
        self._meta: Dict[str, _TaskMeta] = {}
        self._handles: Dict[str, TaskHandle] = {}
        self._recorded: set = set()
        self._fb_seen = 0
        self._pre_cancels: List[Tuple[str, Optional[float]]] = []
        # durability (crash recovery): a write-ahead event journal plus an
        # in-flight SlotSnapshot checkpointer installed on every engine
        # executor the service creates. Both live under state_dir.
        self.state_dir = state_dir
        self.ckpt_every = int(ckpt_every)
        self._journal = None
        self._ckpt = None
        if state_dir is not None:
            from repro.checkpoint.taskstate import TaskCheckpointer
            from repro.sched.journal import EventJournal
            self._journal = EventJournal(state_dir)
            self._ckpt = TaskCheckpointer(state_dir, journal=self._journal,
                                          every=self.ckpt_every)
            self._journal.append({
                "rec": "session", "total_gpus": engine.total_gpus,
                "strategy": engine.strategy,
                "eval_every": engine.eval_every,
                "ckpt_every": self.ckpt_every, "serve_dir": serve_dir})
        self._jrn_seen = 0
        # wall-clock driving: submit/cancel/step are serialized under this
        # lock so tenants can call into the service while run_forever pumps
        self._lock = threading.RLock()
        self._loop: Optional[ServiceLoop] = None
        # TASK_RECOVERED / republish audit events buffered until the
        # runtime session is live (annotate() needs a running event loop)
        self._pending_annotations: List[ProgressEvent] = []

    # ------------------------------------------------------------ admission
    def active_tasks_of(self, tenant: str) -> int:
        """Number of this tenant's non-terminal (pending/running) tasks."""
        return sum(1 for name, meta in self._meta.items()
                   if meta.tenant == tenant
                   and not self.status(name).state.terminal)

    def _check_quota(self, tenant: str) -> None:
        quota = self.max_tasks_per_tenant
        if quota is None:
            return
        active = self.active_tasks_of(tenant)
        if active >= quota:
            raise QuotaExceeded(
                f"tenant {tenant!r} has {active} active tasks "
                f"(max_tasks_per_tenant={quota})")

    def submit(self, task, at: float = 0.0,
               early_exit: EarlyExitConfig = EarlyExitConfig(),
               spec: Optional[TaskSpec] = None,
               tenant: str = "default") -> TaskHandle:
        """Submit an ``engine.Task`` at virtual time ``at``. Profiling
        consults the session's ``ProfileStore``, so durations reflect any
        feedback already observed. ``spec`` overrides the profiled spec
        with a worst-case estimate that is used verbatim (the engine's
        batch wrapper relies on it staying a true residual upper bound for
        the elastic<=static guarantee); profiled submissions apply the
        feedback scale exactly once, in ``submit_spec``. ``tenant``
        attributes the task for the per-tenant concurrency quota
        (``max_tasks_per_tenant``): a submission that would push the
        tenant past its quota raises ``QuotaExceeded`` before anything is
        admitted."""
        explicit = spec is not None
        if spec is None:
            spec = self.engine.profile_raw(task, early_exit)
        factory = self.engine.executor_driver_factory(task, early_exit)
        return self.submit_spec(
            spec, factory, at=at, profile_key=self.engine.profile_key(task),
            scale_duration=not explicit,
            colo=self.engine.colocation_spec(task), tenant=tenant,
            _journal_task=_task_record(task, early_exit),
            _journal_kind="engine")

    def submit_spec(self, spec: TaskSpec,
                    driver_factory: Callable[[], TaskDriver],
                    at: float = 0.0, profile_key: Optional[Tuple] = None,
                    scale_duration: bool = True,
                    colo: Optional[ColocationSpec] = None,
                    tenant: str = "default",
                    _journal_task: Optional[Dict] = None,
                    _journal_kind: Optional[str] = None) -> TaskHandle:
        """Low-level admission: any ``TaskDriver`` factory (simulated
        drivers for benchmarks / property tests). When ``profile_key`` is
        given and ``scale_duration`` is on, the estimated duration is
        rescaled by the store's observed realized/estimated ratio for that
        key — the feedback loop. Feedback is always *recorded* against the
        unscaled estimate so the ratio never compounds. ``colo`` marks the
        task fusable: instead of waiting for free GPUs, a small pending
        task is routed onto a live shared-backbone replica with the same
        fuse key the moment cross-task admission accepts it — since the
        ragged refactor the key is width-free (arch/gpus/loss), so mixed
        batch-size submissions land on live replicas too."""
        with self._lock:
            name = spec.name
            assert name not in self._meta, f"duplicate task name {name}"
            self._check_quota(tenant)
            unscaled = spec.duration
            if profile_key is not None and scale_duration:
                spec = dataclasses.replace(
                    spec, duration=self.profile_store.scaled_duration(
                        profile_key, spec.duration))
            meta = _TaskMeta(spec=spec, unscaled_duration=unscaled,
                             submitted_at=max(at, self.now),
                             profile_key=profile_key, tenant=tenant,
                             colo=colo)

            def wrapped() -> TaskDriver:
                drv = driver_factory()
                meta.driver = drv        # kept for wall-time feedback
                # chunk-boundary SlotSnapshot checkpointing: engine drivers
                # expose their BatchedExecutor's hook; simulated drivers
                # don't and simply skip durability
                ex = getattr(drv, "executor", None)
                if (self._ckpt is not None and ex is not None
                        and hasattr(ex, "ckpt_hook")):
                    ex.ckpt_hook = self._ckpt.on_chunk
                return drv

            if self._journal is not None:
                # write-ahead: the submission is durable before the runtime
                # ever sees it, so a crash mid-admission still requeues it
                self._journal.append({
                    "rec": "submit", "name": name, "at": float(at),
                    "tenant": tenant,
                    "kind": _journal_kind or (
                        "engine" if _journal_task is not None else "driver"),
                    "spec": {"name": spec.name,
                             "duration": float(spec.duration),
                             "gpus": int(spec.gpus),
                             "release": float(spec.release)},
                    "unscaled_duration": float(unscaled),
                    "task": _journal_task})
            self._runtime.submit(spec, wrapped, at=at, colo=colo)
            self._meta[name] = meta
            handle = TaskHandle(self, name)
            self._handles[name] = handle
            return handle

    def attach_serving(self, frontend, *, name: str = "serve/replica-0",
                       gpus: int = 1, horizon_s: float = 3600.0,
                       chunk_s: float = 60.0, at: float = 0.0) -> TaskHandle:
        """Admit a serving replica as a first-class cluster resident: the
        replica's GPUs enter the planner's ownership / projected-skyline
        accounting as an ordinary task holding a finite serving lease
        (``horizon_s`` virtual seconds; retire early via the handle's
        ``cancel()``). Also registers ``frontend`` as the tune-to-serve
        target: every completed task's winning adapter is auto-published
        to it (from the durable ``serve_dir`` artifact when configured)."""
        from repro.serve.driver import ServingReplicaDriver, serving_spec
        spec = serving_spec(name, gpus, horizon_s, release=at)
        handle = self.submit_spec(
            spec,
            lambda: ServingReplicaDriver(name, horizon_s=horizon_s,
                                         chunk_s=chunk_s, frontend=frontend),
            at=at, profile_key=None, scale_duration=False)
        self.serving = frontend
        return handle

    def cancel(self, name: str, at: Optional[float] = None) -> bool:
        with self._lock:
            assert name in self._meta, f"unknown task {name}"
            if not self._runtime._live:
                # session not started: queue the cancellation — beginning
                # the loop here would lock out a later
                # run_until_idle(initial=...)
                self._pre_cancels.append((name, at))
                return True
            return self._runtime.cancel(name, at=at)

    # ------------------------------------------------------------ the loop
    @property
    def now(self) -> float:
        return self._runtime.now

    def _ensure_live(self, initial: Optional[Schedule] = None) -> None:
        if not self._runtime._live:
            self._runtime.begin(initial)
            pre, self._pre_cancels = self._pre_cancels, []
            for name, at in pre:
                self._runtime.cancel(name, at=at)
            notes, self._pending_annotations = self._pending_annotations, []
            for e in notes:
                self._runtime.annotate(e)
        else:
            assert initial is None, "session already live"

    def _step(self) -> bool:
        with self._lock:
            self._ensure_live()
            more = self._runtime.step()
            self._feedback()
            self._journal_events()
            return more

    def _journal_events(self) -> None:
        """Append runtime events (arrivals, replans/adoptions, progress,
        completions, pod kills) to the write-ahead journal, once each."""
        if self._journal is None:
            return
        evs = self._runtime_events()
        for e in evs[self._jrn_seen:]:
            self._journal.append({"rec": "event", "event": event_to_json(e)})
        self._jrn_seen = len(evs)

    def _drive(self, done: Callable[[], bool]) -> None:
        self._ensure_live()
        while not done() and self._step():
            pass

    def run_until_idle(self, initial: Optional[Schedule] = None
                       ) -> ServiceReport:
        """Drain every admitted task (arrivals included) and report.
        The session stays open: later ``submit``s re-activate the loop."""
        self._ensure_live(initial)
        while self._step():
            pass
        rt = self._runtime.report()
        if self.profile_path is not None:
            self.profile_store.save(self.profile_path)
        return ServiceReport(
            task_results=dict(rt.results), makespan=rt.makespan,
            utilization=rt.utilization, replans=rt.replans,
            plans_adopted=rt.plans_adopted,
            plans_rejected=rt.plans_rejected, events=list(rt.events),
            cancelled=rt.cancelled, task_starts=dict(rt.task_starts),
            task_ends=dict(rt.task_ends), runtime=rt,
            colocated=dict(rt.colocated),
            preemptions=rt.preemptions, migrations=rt.migrations)

    def save_profile(self, path: Optional[str] = None) -> None:
        """Persist the session's ProfileStore (feedback survives process
        restarts; ``profile_path`` sessions also save automatically at
        every ``run_until_idle``)."""
        target = path or self.profile_path
        assert target, "no profile path configured"
        self.profile_store.save(target)

    def run_forever(self, poll_s: float = 0.05,
                    stall_timeout_s: float = 30.0) -> ServiceLoop:
        """Wall-clock driver: a daemon thread pumps ``step()`` on real
        time so submissions execute as they arrive instead of waiting for
        an explicit ``run_until_idle()``. Virtual cluster time still
        advances by profiled durations (it is the planning clock), while
        wall-clock step observations keep flowing into the ProfileStore
        through the usual ``_feedback`` path; checkpoints fire at the same
        chunk boundaries as in batch driving. A stall watchdog logs a
        warning when the runtime is busy but no event has fired within
        ``stall_timeout_s`` real seconds. Returns a ``ServiceLoop``
        handle — call ``.stop()`` to drain out."""
        assert self._loop is None or not self._loop.alive, \
            "service loop already running"
        stop = threading.Event()

        def pump() -> None:
            seen = 0
            last_change = time.monotonic()
            idle_saved = True
            while not stop.is_set():
                try:
                    with self._lock:
                        more = self._step()
                        busy = not self._runtime.idle()
                        n = len(self._runtime_events())
                except Exception:
                    _log.exception("service loop crashed")
                    return
                nowm = time.monotonic()
                if n != seen:
                    seen, last_change = n, nowm
                elif busy and nowm - last_change > stall_timeout_s:
                    _log.warning(
                        "service stall: no event for %.1fs "
                        "(virtual now=%.3f)", nowm - last_change, self.now)
                    last_change = nowm
                if more:
                    idle_saved = False
                else:
                    if not idle_saved and self.profile_path is not None:
                        with self._lock:
                            self.profile_store.save(self.profile_path)
                        idle_saved = True
                    stop.wait(poll_s)

        t = threading.Thread(target=pump, name="tuning-service-loop",
                             daemon=True)
        t.start()
        self._loop = ServiceLoop(t, stop)
        return self._loop

    # ------------------------------------------------------------ recovery
    @classmethod
    def recover(cls, state_dir: str, *, tasks=None, factories=None,
                engine=None, serve_frontend=None,
                **service_kw) -> "TuningService":
        """Rebuild a service from a crashed session's ``state_dir``.

        Replays the write-ahead journal: every journaled submission
        without a terminal (completed/cancelled) event is re-admitted —
        from its latest durable ``SlotSnapshot`` checkpoint when one
        loads cleanly (the task resumes mid-flight, bitwise), and from
        zero otherwise. Corrupt journal segments or checkpoints degrade
        to requeue-from-zero with a warning rather than failing recovery.
        Winner artifacts under ``serve_dir`` are re-published to
        ``serve_frontend`` when given. Engine tasks whose record was not
        serializable must be re-supplied via ``tasks`` (``Task`` or
        ``(Task, EarlyExitConfig)`` entries, matched by ``task_name``);
        plain driver submissions (benchmark simulations,
        serving leases) need a fresh factory in ``factories`` or are
        skipped. Emits one ``TASK_RECOVERED`` audit event per re-admitted
        task once the new session goes live."""
        from repro.checkpoint.taskstate import load_task_checkpoint
        from repro.sched.journal import replay_journal
        rep = replay_journal(state_dir)
        session = rep.session() or {}
        kw = dict(service_kw)
        if engine is None:
            for k in ("total_gpus", "strategy", "eval_every"):
                if session.get(k) is not None:
                    kw.setdefault(k, session[k])
        kw.setdefault("serve_dir", session.get("serve_dir"))
        kw.setdefault("ckpt_every", int(session.get("ckpt_every") or 1))
        svc = cls(engine=engine, state_dir=state_dir, **kw)
        ckpts = rep.checkpoints()
        if rep.corrupt:
            # a corrupt segment may have swallowed completions or newer
            # checkpoint records: distrust all snapshots, requeue from zero
            _log.warning("journal under %s has %d corrupt segment line(s);"
                         " recovering by requeue-from-zero", state_dir,
                         len(rep.corrupt))
            ckpts = {}
        terminal = rep.terminal_tasks()
        task_by_name: Dict[str, Tuple[Any, Optional[EarlyExitConfig]]] = {}
        for t in (tasks or []):
            task, ee = t if isinstance(t, tuple) else (t, None)
            task_by_name[task.task_name] = (task, ee)
        factories = dict(factories or {})
        for sub in rep.submits():
            name = sub["name"]
            if name in terminal:
                continue
            state = None
            ck = ckpts.get(name)
            if ck is not None:
                state = load_task_checkpoint(ck["path"])  # None if corrupt
            if sub.get("kind") == "engine":
                trec = sub.get("task")
                if name in task_by_name:
                    task, ee = task_by_name[name]
                    if ee is None:
                        ee = (EarlyExitConfig(**trec["early_exit"]) if trec
                              else EarlyExitConfig())
                elif trec is not None:
                    task, ee = _task_from_record(trec)
                else:
                    _log.warning("task %r was submitted with in-memory "
                                 "model/dataset and is not in tasks=: "
                                 "skipped", name)
                    continue
                if state is not None:
                    tree_meta = state[1]
                    chunk = int(tree_meta.get("chunk", 0))
                    # residual spec: remaining-steps bound at profiled
                    # step time stays a true upper bound for the planner
                    dur = (max(int(tree_meta["remaining_steps_bound"]), 1)
                           * svc.engine.profiled_step_time(task))
                    spec = dataclasses.replace(
                        svc.engine.profile_raw(task, ee), duration=dur)
                    svc.submit_spec(
                        spec,
                        svc.engine.resumed_driver_factory(
                            task, ee, state, start_chunk=chunk),
                        at=0.0, profile_key=svc.engine.profile_key(task),
                        scale_duration=False,
                        colo=svc.engine.colocation_spec(task),
                        _journal_task=trec, _journal_kind="engine")
                    reason, detail = "resumed", f"chunk={chunk}"
                else:
                    svc.submit(task, at=0.0, early_exit=ee)
                    reason, detail = "requeued", "from step 0"
            else:
                fac = factories.get(name)
                if fac is None:
                    _log.warning("driver task %r has no recovery factory: "
                                 "skipped", name)
                    continue
                sp = sub["spec"]
                svc.submit_spec(
                    TaskSpec(name=name, duration=float(sp["duration"]),
                             gpus=int(sp["gpus"]), release=0.0),
                    fac, at=0.0, scale_duration=False)
                reason, detail = "requeued", "driver task from zero"
            svc._pending_annotations.append(ProgressEvent(
                kind=EventKind.TASK_RECOVERED, task=name, reason=reason,
                detail=detail))
        if serve_frontend is not None:
            svc.republish_served(serve_frontend)
        return svc

    def republish_served(self, frontend) -> List[str]:
        """Crash recovery of the serving tier: re-publish every winner
        artifact under ``serve_dir`` to ``frontend`` (publishes load from
        disk, never live executor state). Corrupt or rejected artifacts
        are skipped with a warning. Returns the published adapter ids."""
        import glob
        import zipfile

        from repro.serve.frontend import AdmissionError
        from repro.serve.pool import CorruptCheckpoint, PoolFull
        self.serving = frontend
        published: List[str] = []
        if self.serve_dir is None:
            return published
        for path in sorted(glob.glob(os.path.join(self.serve_dir,
                                                  "*.npz"))):
            try:
                aid = frontend.publish_checkpoint(path)
                published.append(aid)
                self._ckpt_paths.setdefault(aid, path)
                self._pending_annotations.append(ProgressEvent(
                    kind=EventKind.ADAPTER_PUBLISHED, task=aid,
                    reason="republished", detail=f"from={path}"))
            except (CorruptCheckpoint, OSError, ValueError, KeyError,
                    zipfile.BadZipFile) as e:
                # the frontend's admission peek reads the artifact before
                # the pool does, so truncation can surface as a raw
                # zip/KeyError there rather than as CorruptCheckpoint
                _log.warning("serve artifact %s unreadable: %s", path, e)
            except AssertionError as e:
                # arch/spec_version mismatch or already resident
                _log.warning("serve artifact %s rejected: %s", path, e)
            except (AdmissionError, PoolFull) as e:
                _log.warning("serve artifact %s refused: %s", path, e)
        return published

    # ------------------------------------------------------------ feedback
    def _feedback(self) -> None:
        """Record realized durations/step times of newly finished tasks
        into the ProfileStore (the profiler feedback loop)."""
        ends = self._runtime.task_end_times
        if len(ends) == self._fb_seen:      # no new completions: stay O(1)
            return
        self._fb_seen = len(ends)
        starts = self._runtime.task_start_times
        for name, end in ends.items():
            if name in self._recorded or self._runtime.is_cancelled(name):
                continue
            self._recorded.add(name)
            meta = self._meta[name]
            self._tune_to_serve(name, meta)
            if meta.profile_key is None:
                continue
            wall = wall_tok = None
            if meta.driver is not None:
                obs = getattr(meta.driver, "observed_wall_step_s", None)
                wall = obs() if callable(obs) else None
                # per-token wall time: the calibrated quantity once fused
                # steps mix heterogeneous slot widths (ragged co-location)
                obs_t = getattr(meta.driver, "observed_wall_token_s", None)
                wall_tok = obs_t() if callable(obs_t) else None
            self.profile_store.record(
                meta.profile_key,
                realized_duration=end - starts[name],
                estimated_duration=meta.unscaled_duration,
                wall_step_time_s=wall,
                wall_token_time_s=wall_tok)
            # raw step observation: the training set for the fitted
            # (k0, k1, k2) step-time/memory models (sched/fitted.py).
            # Always recorded (cheap, FIFO-capped per key); consumed only
            # under fitted=True. Peak memory uses the admission model's
            # rank-aware prediction — the CPU container's stand-in for
            # the platform's measured peak, same framing as profiling.
            if wall is not None and meta.colo is not None:
                colo = meta.colo
                tokens = float(colo.slots_needed * colo.per_adapter_batch
                               * colo.seq_len)
                rank = colo.lora_rank or (
                    colo.mem.charged_rank(None) if colo.mem else 1)
                peak = (colo.mem.predict_ranked(tokens, tokens * rank)
                        if colo.mem is not None else None)
                self.profile_store.record_step(
                    meta.profile_key, tokens=tokens,
                    rank_tokens=tokens * rank, wall_s=wall,
                    peak_memory=peak)

    # ------------------------------------------------------- tune-to-serve
    def _tune_to_serve(self, name: str, meta: _TaskMeta) -> None:
        """On task completion: checkpoint the winning adapter to a durable
        artifact under ``serve_dir`` (rank + fuse key + spec version in the
        metadata) and auto-publish it to the attached serving frontend —
        publish loads from the artifact, not live executor state, so a
        killed pod can replay its serve set from disk."""
        if self.serve_dir is None and self.serving is None:
            return
        res = self._results().get(name)
        best_job = getattr(res, "best_job", None)
        if best_job is None:
            return
        jr = res.job_results.get(best_job)
        if jr is None or getattr(jr, "adapter", None) is None:
            return
        from repro.serve.pool import SPEC_VERSION
        rank = int(jr.config.lora_rank)
        fuse_key = list(meta.colo.fuse_key) if meta.colo is not None else None
        path = None
        if self.serve_dir is not None:
            from repro.checkpoint.checkpoint import save_pytree
            path = os.path.join(self.serve_dir,
                                name.replace("/", "_") + ".npz")
            # atomic (tmp + fsync + os.replace): a crash mid-write never
            # leaves a truncated winner artifact under serve_dir
            save_pytree(path, jr.adapter, meta={
                "adapter_id": name, "task": name, "job": best_job,
                "rank": rank,
                "arch": fuse_key[0] if fuse_key else None,
                "fuse_key": fuse_key, "spec_version": SPEC_VERSION,
                "best_val": float(res.best_val)}, atomic=True)
            self._ckpt_paths[name] = path
            if self._journal is not None:
                self._journal.append({"rec": "serve", "task": name,
                                      "path": path})
        if self.serving is None:
            return
        from repro.serve.frontend import AdmissionError
        from repro.serve.pool import CorruptCheckpoint, PoolFull
        try:
            if path is not None:
                self.serving.publish_checkpoint(path, adapter_id=name)
            else:
                self.serving.publish(name, jr.adapter, rank,
                                     meta={"task": name, "job": best_job})
            reason, detail = "published", (
                f"rank={rank} slot={self.serving.pool.slot_of(name)}"
                + (" from=checkpoint" if path else " from=live"))
        except (AdmissionError, PoolFull, CorruptCheckpoint) as e:
            reason, detail = "refused", str(e)   # artifact still on disk
        self._runtime.annotate(ProgressEvent(
            kind=EventKind.ADAPTER_PUBLISHED, task=name, job=best_job,
            reason=reason, detail=detail))

    # ------------------------------------------------------------ status
    def status(self, name: str) -> TaskStatus:
        assert name in self._meta, f"unknown task {name}"
        meta = self._meta[name]
        rt = self._runtime
        started = rt.task_start_times.get(name) if rt._live else None
        ended = rt.task_end_times.get(name) if rt._live else None
        if rt._live and rt.is_cancelled(name):
            state = TaskState.CANCELLED
        elif ended is not None:
            state = TaskState.COMPLETED
        elif started is not None:
            state = TaskState.RUNNING
        else:
            state = TaskState.PENDING
        return TaskStatus(name=name, state=state,
                          submitted_at=meta.submitted_at,
                          started_at=started, finished_at=ended,
                          now=self.now)

    def handles(self) -> List[TaskHandle]:
        return list(self._handles.values())

    def _runtime_events(self) -> List[ProgressEvent]:
        return self._runtime.event_log if self._runtime._live else []

    def _results(self) -> Dict[str, Any]:
        return self._runtime.results_map
