"""Loss-aware early exit (paper §5, Algorithm 1).

Host-side controller over per-job loss trajectories:

  Pattern-1 Divergence: linear-regression slopes over the last ``w`` EMA'd
    train losses AND raw val losses both >= tau_slope for p_div consecutive
    evaluation steps -> EXIT(diverging). Patience resets when either slope
    drops below tau_slope.
  Pattern-2 Overfitting: gap ratio g = (val - ema_train)/ema_train >
    tau_gap for p_ovf consecutive evaluation steps -> checkpoint best-val
    model, EXIT(overfitting). Transient fluctuations reset the counter.
  Pattern-3 Underperformance: at the warmup boundary, rank survivors by
    val loss, keep top ceil(select_ratio * K) -> others EXIT(underperforming).

Defaults mirror the paper's evaluation: w=2, p=2, tau_gap=0.1,
tau_slope=0.001, warmup 5% of total steps, 25% selection ratio.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


class ExitReason(enum.Enum):
    DIVERGING = "diverging"
    OVERFITTING = "overfitting"
    UNDERPERFORMING = "underperforming"
    COMPLETED = "completed"


@dataclasses.dataclass(frozen=True)
class EarlyExitConfig:
    ema_alpha: float = 0.3
    window: int = 2                 # w
    tau_slope: float = 0.001
    tau_gap: float = 0.1
    patience_div: int = 2           # p_div
    patience_ovf: int = 2           # p_ovf
    warmup_ratio: float = 0.05
    select_ratio: float = 0.25
    enabled: bool = True

    def warmup_steps(self, total_steps: int) -> int:
        return max(int(math.ceil(self.warmup_ratio * total_steps)), 1)

    def top_k(self, num_candidates: int) -> int:
        return max(int(math.ceil(self.select_ratio * num_candidates)), 1)


def linreg_slope(ys: Sequence[float]) -> float:
    """OLS slope of ys against 0..n-1 (n>=2)."""
    n = len(ys)
    if n < 2:
        return 0.0
    x = np.arange(n, dtype=np.float64)
    y = np.asarray(ys, np.float64)
    xm, ym = x.mean(), y.mean()
    denom = np.sum((x - xm) ** 2)
    return float(np.sum((x - xm) * (y - ym)) / max(denom, 1e-12))


@dataclasses.dataclass
class ExitDecision:
    reason: ExitReason
    step: int
    best_val: float
    best_val_step: int


class JobMonitor:
    """Per-job loss-trajectory state (Algorithm 1 lines 1-14)."""

    def __init__(self, cfg: EarlyExitConfig, job_id: str):
        self.cfg = cfg
        self.job_id = job_id
        self.ema_train: Optional[float] = None
        self.ema_hist: List[float] = []       # EMA'd train losses at evals
        self.val_hist: List[float] = []
        self.raw_train_hist: List[float] = []
        self.cnt_div = 0
        self.cnt_ovf = 0
        self.best_val = float("inf")
        self.best_val_step = -1
        self.steps_trained = 0
        self.exited: Optional[ExitDecision] = None

    # ---- observations ----------------------------------------------------
    def observe_train(self, loss: float) -> None:
        self.steps_trained += 1
        self.raw_train_hist.append(float(loss))
        a = self.cfg.ema_alpha
        if self.ema_train is None or not math.isfinite(self.ema_train):
            self.ema_train = float(loss)
        else:
            self.ema_train = a * float(loss) + (1 - a) * self.ema_train

    def observe_val(self, val_loss: float, step: int
                    ) -> Optional[ExitDecision]:
        """Record an evaluation point and run pattern detection."""
        v = float(val_loss)
        self.val_hist.append(v)
        self.ema_hist.append(self.ema_train if self.ema_train is not None
                             else v)
        if v < self.best_val:
            self.best_val = v
            self.best_val_step = step
        if not self.cfg.enabled:
            return None
        # non-finite loss = immediate divergence exit
        if not math.isfinite(v) or not math.isfinite(self.ema_hist[-1]):
            return self._exit(ExitReason.DIVERGING, step)
        d = self._detect_divergence(step)
        if d is not None:
            return d
        return self._detect_overfitting(step)

    # ---- Pattern 1: divergence -------------------------------------------
    def _detect_divergence(self, step: int) -> Optional[ExitDecision]:
        w = self.cfg.window
        if len(self.ema_hist) >= w and len(self.val_hist) >= w:
            s_train = linreg_slope(self.ema_hist[-w:])
            s_val = linreg_slope(self.val_hist[-w:])
            if s_train >= self.cfg.tau_slope and s_val >= self.cfg.tau_slope:
                self.cnt_div += 1
            else:
                self.cnt_div = 0
            if self.cnt_div >= self.cfg.patience_div:
                return self._exit(ExitReason.DIVERGING, step)
        return None

    # ---- Pattern 2: overfitting --------------------------------------------
    def _detect_overfitting(self, step: int) -> Optional[ExitDecision]:
        ema = self.ema_hist[-1]
        g = (self.val_hist[-1] - ema) / max(abs(ema), 1e-12)
        if g > self.cfg.tau_gap:
            self.cnt_ovf += 1
        else:
            self.cnt_ovf = 0
        if self.cnt_ovf >= self.cfg.patience_ovf:
            return self._exit(ExitReason.OVERFITTING, step)
        return None

    def _exit(self, reason: ExitReason, step: int) -> ExitDecision:
        self.exited = ExitDecision(reason, step, self.best_val,
                                   self.best_val_step)
        return self.exited


def warmup_select(monitors: Dict[str, JobMonitor], cfg: EarlyExitConfig,
                  num_candidates: Optional[int] = None
                  ) -> Tuple[List[str], List[str]]:
    """Pattern-3 at the warmup boundary: rank surviving jobs by latest val
    loss, keep top ceil(select_ratio * K). Returns (kept, evicted) ids."""
    alive = {j: m for j, m in monitors.items()
             if m.exited is None and m.val_hist}
    k = cfg.top_k(num_candidates if num_candidates is not None
                  else len(alive))
    ranked = sorted(alive, key=lambda j: alive[j].val_hist[-1])
    return ranked[:k], ranked[k:]
