"""musicgen-medium — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284] 48L d_model=1536 24H (kv=24 => MHA) d_ff=6144 vocab=2048.
The EnCodec conv codec frontend is the allowed STUB: ``input_specs()``
provides precomputed codebook token ids / frame embeddings of the right
shape; this config is the transformer backbone that consumes them.
"""
from repro.configs.base import AUDIO, ModelConfig, RoPEConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family=AUDIO,
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    rope=RoPEConfig(theta=10_000.0),
    long_context_mode="window",
    sliding_window=8192,
    input_mode="tokens",          # EnCodec discrete codes
    citation="arXiv:2306.05284 (MusicGen)",
    notes="EnCodec frontend stubbed; backbone decodes audio codebook tokens",
)
