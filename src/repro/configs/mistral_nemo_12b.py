"""mistral-nemo-12b — Mistral-NeMo dense decoder, 128k context.

[hf:mistralai/Mistral-Nemo-Base-2407] 40L d_model=5120 32H (GQA kv=8)
head_dim=128 (q_dim 4096 != d_model) d_ff=14336 vocab=131072.
"""
from repro.configs.base import DENSE, ModelConfig, RoPEConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family=DENSE,
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope=RoPEConfig(theta=1_000_000.0),
    long_context_mode="window",
    sliding_window=8192,
    citation="hf:mistralai/Mistral-Nemo-Base-2407",
    notes="head_dim=128 decoupled from d_model/num_heads",
)
