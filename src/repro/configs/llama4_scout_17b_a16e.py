"""llama4-scout-17b-a16e — Llama 4 Scout MoE (16 experts, top-1 + shared).

[hf:meta-llama/Llama-4-Scout-17B-16E] 48L d_model=5120 40H (GQA kv=8)
head_dim=128, d_ff=8192 per routed expert, 16 experts top-1 with an
always-on shared expert, vocab=202048. Early-fusion multimodal in the
original; here the language backbone (text tokens) is modeled, with MoE in
every layer (routed top-1 + shared).
"""
from repro.configs.base import MOE, LoRAConfig, ModelConfig, MoEConfig, RoPEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family=MOE,
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    rope=RoPEConfig(theta=500_000.0),
    moe=MoEConfig(num_experts=16, top_k=1, d_ff_expert=8192,
                  num_shared_experts=1, d_ff_shared=8192,
                  capacity_factor=1.5),
    lora=LoRAConfig(targets=("q_proj", "k_proj", "v_proj", "o_proj")),
    citation="hf:meta-llama/Llama-4-Scout-17B-16E",
    notes="top-1 routing + shared expert; expert-parallel all-to-all",
)
