"""Config system for the ALTO-JAX framework.

Dataclass-based, flat, explicitly versioned. Every assigned architecture is a
``ModelConfig`` instance in its own module under ``repro/configs``; input
shapes are ``ShapeConfig`` instances in ``repro/configs/shapes.py``; the
registry in ``repro/configs/registry.py`` resolves ``--arch`` / ``--shape``
strings.

Design rules:
  * No config object ever touches jax device state at import time.
  * Reduced ("smoke") variants are derived from the full config via
    ``reduced()`` so smoke tests always exercise the same code path as the
    production config.
  * ``global_batch = num_slots (Z) * per_adapter_batch (b)`` — the ALTO
    decomposition. ``ShapeConfig.decompose`` picks (Z, b) given a model.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Architecture families
# ---------------------------------------------------------------------------
DENSE = "dense"
MOE = "moe"
SSM = "ssm"
HYBRID = "hybrid"
AUDIO = "audio"
VLM = "vlm"

FAMILIES = (DENSE, MOE, SSM, HYBRID, AUDIO, VLM)

# Attention kinds
ATTN_FULL = "full"          # full causal attention
ATTN_SLIDING = "sliding"    # sliding-window causal attention
ATTN_NONE = "none"          # attention-free (pure SSM / RWKV)


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration."""
    num_experts: int
    top_k: int
    d_ff_expert: int                 # per-expert hidden dim
    num_shared_experts: int = 0      # always-on shared expert(s)
    d_ff_shared: int = 0             # hidden dim of the shared expert path
    capacity_factor: float = 1.25    # GShard-style expert capacity
    router_aux_weight: float = 0.01  # load-balance auxiliary loss weight
    moe_every: int = 1               # apply MoE every k-th layer (1 = all)

    def validate(self) -> None:
        assert 1 <= self.top_k <= self.num_experts
        assert self.d_ff_expert > 0
        assert self.moe_every >= 1


@dataclass(frozen=True)
class SSMConfig:
    """State-space / RWKV recurrent block configuration."""
    state_size: int = 16          # per-head recurrent state (Mamba N / RWKV hd)
    head_size: int = 64           # recurrent head width (RWKV6 uses 64)
    expand: int = 2               # Mamba expansion factor
    conv_width: int = 4           # short conv width (Mamba)
    chunk_size: int = 128         # chunked-scan block length
    dt_rank: int = 0              # 0 -> ceil(d_model/16) at build time


@dataclass(frozen=True)
class RoPEConfig:
    theta: float = 10_000.0
    # M-RoPE (Qwen2-VL): dims of head_dim allotted to (temporal, height, width)
    mrope_sections: Optional[Tuple[int, int, int]] = None

    @property
    def is_mrope(self) -> bool:
        return self.mrope_sections is not None


@dataclass(frozen=True)
class LoRAConfig:
    """Multi-adapter LoRA configuration (the ALTO workload unit).

    ``r_max`` is the slot-stacked padded rank (paper §A.1 rank-only padding);
    per-slot true ranks live in the runtime adapter state, not the config.
    """
    r_max: int = 64
    # which projections carry adapters (paper: all attn + MLP projections)
    targets: Tuple[str, ...] = (
        "q_proj", "k_proj", "v_proj", "o_proj", "gate_proj", "up_proj",
        "down_proj",
    )
    alpha_over_r: float = 2.0     # paper: alpha = 2r
    dropout: float = 0.0

    def scale_for_rank(self, r: int) -> float:
        return self.alpha_over_r  # alpha/r with alpha = alpha_over_r * r


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description for the unified decoder stack."""
    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                      # 0 -> d_model // num_heads
    attn_kind: str = ATTN_FULL
    sliding_window: int = 4096             # used when attn_kind == sliding
    # long-context decode policy: "window" (dense w/ sliding window cache),
    # "recurrent" (SSM state), "hybrid" (ssm state + window cache)
    long_context_mode: str = "window"
    rope: RoPEConfig = field(default_factory=RoPEConfig)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    lora: LoRAConfig = field(default_factory=LoRAConfig)
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # input modality: "tokens" | "embeddings" | "mixed" (tokens + stub
    # modality embeddings merged at prefix positions)
    input_mode: str = "tokens"
    num_modality_tokens: int = 0           # prefix positions fed by the stub
    citation: str = ""
    notes: str = ""
    dtype: str = "bfloat16"

    # ---- derived ---------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.resolved_head_dim

    @property
    def is_moe(self) -> bool:
        return self.moe is not None

    @property
    def is_recurrent(self) -> bool:
        return self.family in (SSM, HYBRID)

    def validate(self) -> None:
        assert self.family in FAMILIES, self.family
        assert self.num_layers >= 1
        if self.attn_kind != ATTN_NONE:
            assert self.num_heads % max(self.num_kv_heads, 1) == 0, (
                "GQA requires num_heads divisible by num_kv_heads")
        if self.moe is not None:
            self.moe.validate()
        if self.family in (SSM, HYBRID):
            assert self.ssm is not None
        if self.input_mode == "mixed":
            assert self.num_modality_tokens > 0

    # ---- parameter accounting (used by scheduler memory model + roofline)
    def param_count(self, active_only: bool = False) -> int:
        """Approximate backbone parameter count (embeddings included once)."""
        d, hd = self.d_model, self.resolved_head_dim
        attn = d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
        if self.attn_kind == ATTN_NONE:
            attn = 0
        if self.moe is not None:
            e = self.moe.top_k if active_only else self.moe.num_experts
            ffn = 3 * d * self.moe.d_ff_expert * e
            if self.moe.num_shared_experts:
                ffn += 3 * d * self.moe.d_ff_shared * self.moe.num_shared_experts
            dense_layers = 0
            if self.moe.moe_every > 1:
                n_moe = self.num_layers // self.moe.moe_every
                dense_layers = self.num_layers - n_moe
                ffn = ffn * n_moe / max(self.num_layers, 1)
                ffn += 3 * d * self.d_ff * dense_layers / max(self.num_layers, 1)
            ffn += d * self.moe.num_experts  # router
        else:
            ffn = 3 * d * self.d_ff
        ssm = 0
        if self.ssm is not None:
            # in/out/x-proj + conv + dt (rough; exact per-arch detail in model)
            inner = self.ssm.expand * d
            ssm = d * inner * 2 + inner * d + inner * (
                self.ssm.state_size * 2 + self.ssm.conv_width + 1)
        per_layer = attn + ffn + ssm + 2 * d  # + norms
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return int(self.num_layers * per_layer + emb + d)

    def lora_param_count(self, rank: int) -> int:
        """Trainable params of ONE adapter at ``rank`` over ``lora.targets``."""
        d, hd = self.d_model, self.resolved_head_dim
        sizes = {
            "q_proj": (d, self.q_dim), "k_proj": (d, self.kv_dim),
            "v_proj": (d, self.kv_dim), "o_proj": (self.q_dim, d),
            "gate_proj": (d, self.d_ff), "up_proj": (d, self.d_ff),
            "down_proj": (self.d_ff, d),
        }
        if self.moe is not None:
            ff = self.moe.d_ff_shared or self.moe.d_ff_expert
            sizes.update({"gate_proj": (d, ff), "up_proj": (d, ff),
                          "down_proj": (ff, d)})
        total = 0
        for t in self.lora.targets:
            if t not in sizes:
                continue
            din, dout = sizes[t]
            total += rank * (din + dout)
        return int(self.num_layers * total)

    # ---- reduced variant for smoke tests ---------------------------------
    def reduced(self, num_layers: int = 2, d_model: int = 256,
                vocab: int = 512) -> "ModelConfig":
        """Same family/code path, tiny dims (CPU-runnable smoke variant)."""
        hd = 32
        heads = max(d_model // hd, 2)
        kv = max(1, min(self.num_kv_heads, heads))
        while heads % kv:
            kv -= 1
        moe = None
        if self.moe is not None:
            moe = replace(self.moe, num_experts=min(4, self.moe.num_experts),
                          top_k=min(self.moe.top_k, 2),
                          d_ff_expert=d_model, d_ff_shared=(
                              d_model if self.moe.num_shared_experts else 0))
        ssm = None
        if self.ssm is not None:
            ssm = replace(self.ssm, head_size=hd, chunk_size=16)
        mrope = self.rope.mrope_sections
        if mrope is not None:
            # keep 3 sections summing to hd//2
            mrope = (hd // 4, hd // 8, hd // 8)
        return replace(
            self, num_layers=num_layers, d_model=d_model, num_heads=heads,
            num_kv_heads=kv, head_dim=hd, d_ff=2 * d_model, vocab_size=vocab,
            sliding_window=min(self.sliding_window, 64),
            rope=replace(self.rope, mrope_sections=mrope),
            moe=moe, ssm=ssm,
            lora=replace(self.lora, r_max=8),
            num_modality_tokens=min(self.num_modality_tokens, 8),
        )


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------
KIND_TRAIN = "train"
KIND_PREFILL = "prefill"
KIND_DECODE = "decode"


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode
    # preferred (Z, b) decomposition; 0 -> auto
    num_slots: int = 0
    per_adapter_batch: int = 0

    def decompose(self) -> Tuple[int, int]:
        """global_batch = Z * b (ALTO slots x per-adapter batch)."""
        if self.num_slots:
            z = self.num_slots
            b = self.per_adapter_batch or (self.global_batch // z)
        else:
            z = min(64, self.global_batch)
            b = self.global_batch // z
        assert z * b == self.global_batch, (
            f"{self.name}: {z}*{b} != {self.global_batch}")
        return z, b

    @property
    def is_decode(self) -> bool:
        return self.kind == KIND_DECODE


@dataclass(frozen=True)
class MeshConfig:
    """Logical mesh description (built by launch/mesh.py)."""
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


@dataclass(frozen=True)
class TrainConfig:
    """Per-job training hyperparameters (one point in the search space)."""
    learning_rate: float = 1e-4
    weight_decay: float = 0.01
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    lora_rank: int = 16
    per_adapter_batch: int = 4
    max_steps: int = 100
    warmup_steps: int = 0
    grad_clip: float = 1.0
    seed: int = 0

    def label(self) -> str:
        return (f"lr{self.learning_rate:g}_r{self.lora_rank}"
                f"_b{self.per_adapter_batch}_s{self.seed}")


def asdict(cfg) -> dict:
    return dataclasses.asdict(cfg)
