"""qwen2-vl-72b — Qwen2-VL 72B language backbone with M-RoPE.

[arXiv:2409.12191] 80L d_model=8192 64H (GQA kv=8) head_dim=128 d_ff=29568
vocab=152064. M-RoPE: rotary dims split into (temporal, height, width)
sections over 3-component position ids. The ViT vision encoder + projector
is the allowed STUB: ``input_specs()`` provides precomputed patch embeddings
merged at image-token prefix positions (dynamic-resolution is represented by
the stub's patch count).
"""
from repro.configs.base import VLM, ModelConfig, RoPEConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family=VLM,
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    rope=RoPEConfig(theta=1_000_000.0, mrope_sections=(16, 24, 24)),
    long_context_mode="window",
    sliding_window=8192,
    input_mode="mixed",
    num_modality_tokens=256,       # stub patch-embedding prefix length
    citation="arXiv:2409.12191 (Qwen2-VL)",
    notes="M-RoPE (t,h,w) sections; vision tower stubbed as patch embeddings",
)
