"""stablelm-3b — StableLM-family dense decoder.

[hf:stabilityai/stablelm-2-1_6b] (assigned dims) 32L d_model=2560 32H
(GQA kv=32 => MHA) d_ff=6912 vocab=50304.
"""
from repro.configs.base import DENSE, ModelConfig, RoPEConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family=DENSE,
    num_layers=32,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=6912,
    vocab_size=50304,
    rope=RoPEConfig(theta=10_000.0),
    long_context_mode="window",   # long_500k uses sliding-window decode
    sliding_window=8192,
    citation="hf:stabilityai/stablelm-2-1_6b",
)
