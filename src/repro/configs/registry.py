"""Architecture registry: resolves ``--arch <id>`` strings to ModelConfigs."""
from __future__ import annotations

from typing import Dict, List

from repro.configs import (glm4_9b, granite_8b, granite_moe_1b_a400m,
                           hymba_1p5b, llama4_scout_17b_a16e,
                           mistral_nemo_12b, musicgen_medium,
                           paper_llama_tiny, qwen2_vl_72b, rwkv6_3b,
                           stablelm_3b)
from repro.configs.base import ModelConfig

_MODULES = (
    rwkv6_3b, granite_moe_1b_a400m, stablelm_3b, mistral_nemo_12b,
    hymba_1p5b, llama4_scout_17b_a16e, musicgen_medium, qwen2_vl_72b,
    granite_8b, glm4_9b, paper_llama_tiny,
)

ARCHS: Dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}

# The 10 assigned architectures (excludes the paper-reference tiny model).
ASSIGNED: List[str] = [
    "rwkv6-3b", "granite-moe-1b-a400m", "stablelm-3b", "mistral-nemo-12b",
    "hymba-1.5b", "llama4-scout-17b-a16e", "musicgen-medium", "qwen2-vl-72b",
    "granite-8b", "glm4-9b",
]


def get_arch(name: str) -> ModelConfig:
    try:
        cfg = ARCHS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    cfg.validate()
    return cfg


def list_archs() -> List[str]:
    return sorted(ARCHS)
