"""rwkv6-3b — RWKV-6 "Finch": attention-free, data-dependent decay.

[arXiv:2404.05892] 32L d_model=2560 d_ff=8960 vocab=65536, head_size=64
(40 recurrent heads). LoRA attaches to the time-mix (r/k/v/g/o) and
channel-mix projections; ALTO's grouped-LoRA + AP apply unchanged.
`long_500k` decodes natively with O(1) recurrent state.
"""
from repro.configs.base import (ATTN_NONE, SSM, LoRAConfig, ModelConfig,
                                SSMConfig)

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family=SSM,
    num_layers=32,
    d_model=2560,
    num_heads=40,            # 2560 / head_size 64
    num_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    attn_kind=ATTN_NONE,
    long_context_mode="recurrent",
    ssm=SSMConfig(state_size=64, head_size=64, chunk_size=128),
    lora=LoRAConfig(targets=(
        "r_proj", "k_proj", "v_proj", "g_proj", "o_proj",
        "ffn_k", "ffn_v")),
    citation="arXiv:2404.05892 (RWKV-6 Finch)",
    notes="data-dependent decay w_t; wkv chunked scan; token-shift mixing",
)
