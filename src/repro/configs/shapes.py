"""The four assigned input shapes.

``train_4k``    training step, 4096 x 256
``prefill_32k`` inference prefill, 32768 x 32
``decode_32k``  inference decode: ONE new token against a 32k KV cache
``long_500k``   long-context decode: ONE token against 512k state
                (sub-quadratic paths only: recurrent state or sliding window)
"""
from __future__ import annotations

from repro.configs.base import (KIND_DECODE, KIND_PREFILL, KIND_TRAIN,
                                ShapeConfig)

TRAIN_4K = ShapeConfig(
    name="train_4k", seq_len=4_096, global_batch=256, kind=KIND_TRAIN,
    num_slots=64, per_adapter_batch=4)   # paper: 60-64 concurrent configs

PREFILL_32K = ShapeConfig(
    name="prefill_32k", seq_len=32_768, global_batch=32, kind=KIND_PREFILL,
    num_slots=16, per_adapter_batch=2)

DECODE_32K = ShapeConfig(
    name="decode_32k", seq_len=32_768, global_batch=128, kind=KIND_DECODE,
    num_slots=16, per_adapter_batch=8)

LONG_500K = ShapeConfig(
    name="long_500k", seq_len=524_288, global_batch=1, kind=KIND_DECODE,
    num_slots=1, per_adapter_batch=1)

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def get_shape(name: str) -> ShapeConfig:
    try:
        return SHAPES[name]
    except KeyError:
        raise KeyError(f"unknown shape {name!r}; have {sorted(SHAPES)}")
