"""hymba-1.5b — NVIDIA Hymba hybrid-head decoder.

[arXiv:2411.13676] 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16. Parallel attention + Mamba heads within each layer; outputs
fused (mean of normed branch outputs). Attention uses sliding window in most
layers (global in a few) per the paper; SSM branch gives sub-quadratic
long-context decode.
"""
from repro.configs.base import (ATTN_SLIDING, HYBRID, LoRAConfig, ModelConfig,
                                RoPEConfig, SSMConfig)

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family=HYBRID,
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    attn_kind=ATTN_SLIDING,
    sliding_window=1024,
    long_context_mode="hybrid",   # ssm state + windowed attention cache
    rope=RoPEConfig(theta=10_000.0),
    ssm=SSMConfig(state_size=16, head_size=64, expand=2, chunk_size=128),
    lora=LoRAConfig(targets=("in_proj", "q_proj", "k_proj", "v_proj",
                             "o_proj", "gate_proj", "up_proj", "down_proj")),
    citation="arXiv:2411.13676 (Hymba)",
    notes="parallel attn+mamba heads sharing in/out projections",
)
