"""glm4-9b — GLM-4 9B dense decoder with extreme GQA (kv=2).

[hf:THUDM/glm-4-9b] 40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552.
kv_heads=2 < model-axis size stresses the KV sharding rules (KV replicated
or sequence-sharded on the model axis).
"""
from repro.configs.base import DENSE, ModelConfig, RoPEConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family=DENSE,
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=151552,
    rope=RoPEConfig(theta=10_000.0),
    long_context_mode="window",
    sliding_window=8192,
    citation="hf:THUDM/glm-4-9b",
)
