"""Config system: dataclasses, assigned architectures, input shapes."""
from repro.configs.base import (LoRAConfig, MeshConfig, ModelConfig,
                                MoEConfig, RoPEConfig, ShapeConfig, SSMConfig,
                                TrainConfig)

__all__ = [
    "LoRAConfig", "MeshConfig", "ModelConfig", "MoEConfig", "RoPEConfig",
    "ShapeConfig", "SSMConfig", "TrainConfig",
]
