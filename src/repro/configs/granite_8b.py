"""granite-8b — IBM Granite Code 8B (llama-arch dense).

[arXiv:2405.04324] 36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152.
"""
from repro.configs.base import DENSE, ModelConfig, RoPEConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family=DENSE,
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=49152,
    rope=RoPEConfig(theta=10_000_000.0),
    long_context_mode="window",
    sliding_window=8192,
    citation="arXiv:2405.04324 (Granite Code Models)",
)
