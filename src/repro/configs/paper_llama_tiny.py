"""paper-llama-tiny — ~100M Llama-style model for end-to-end runnable examples.

This is the in-repo analogue of the paper's single-GPU models (Llama-3.1-8B
class), scaled to ~100M params so a few hundred real training steps run on
CPU. It is the config used by the end-to-end driver (examples/) and the
kernel microbenchmark (paper Table 2 uses Llama-3.2-1B similarly scaled).
"""
from repro.configs.base import DENSE, LoRAConfig, ModelConfig, RoPEConfig

CONFIG = ModelConfig(
    name="paper-llama-tiny",
    family=DENSE,
    num_layers=8,
    d_model=512,
    num_heads=8,
    num_kv_heads=4,
    head_dim=64,
    d_ff=1536,
    vocab_size=8192,
    rope=RoPEConfig(theta=10_000.0),
    long_context_mode="window",
    sliding_window=1024,
    lora=LoRAConfig(r_max=32),
    citation="paper §8.1 (scaled-down Llama-class reference model)",
)
