"""granite-moe-1b-a400m — IBM Granite 3.0 1B-A400M MoE.

[hf:ibm-granite/granite-3.0-1b-a400m-base] 24L d_model=1024 16H (GQA kv=8)
d_ff=512 per expert, 32 experts top-8, vocab=49155.
"""
from repro.configs.base import MOE, LoRAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family=MOE,
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    moe=MoEConfig(num_experts=32, top_k=8, d_ff_expert=512,
                  capacity_factor=1.25),
    lora=LoRAConfig(targets=("q_proj", "k_proj", "v_proj", "o_proj")),
    tie_embeddings=True,
    citation="hf:ibm-granite/granite-3.0-1b-a400m-base",
    notes="32 experts top-8; expert FFNs frozen, LoRA on attention projections",
)
