"""ALTO-JAX subsystem."""
