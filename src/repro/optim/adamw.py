"""AdamW over slot-stacked LoRA trees with PER-SLOT hyperparameters.

Every adapter slot trains under its own (lr, wd) — the ALTO tuning unit —
so the hyperparameters are [Z] vectors broadcast onto [L, Z, ...] leaves.
Per-slot global-norm gradient clipping keeps one diverging job from
touching its neighbours. Rank masks are re-applied after every update so
rank-padded regions stay identically zero (paper §A.1).

(The paper uses paged AdamW 8-bit; host-paged optimizer state is a CUDA-UVM
mechanism with no TPU analogue — plain fp32-state AdamW here, see DESIGN.md
§8.)
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class SlotHParams(NamedTuple):
    """Per-slot hyperparameters, each [Z] fp32."""
    lr: jnp.ndarray
    wd: jnp.ndarray
    beta1: jnp.ndarray
    beta2: jnp.ndarray
    grad_clip: jnp.ndarray      # 0 => no clipping

    @staticmethod
    def broadcast(Z: int, lr=1e-4, wd=0.01, beta1=0.9, beta2=0.999,
                  grad_clip=1.0) -> "SlotHParams":
        f = lambda v: jnp.full((Z,), v, jnp.float32)
        return SlotHParams(f(lr), f(wd), f(beta1), f(beta2), f(grad_clip))

    def replace_slot(self, slot: int, **kw) -> "SlotHParams":
        d = self._asdict()
        for k, v in kw.items():
            d[k] = d[k].at[slot].set(v)
        return SlotHParams(**d)


class AdamWState(NamedTuple):
    mu: Dict
    nu: Dict
    count: jnp.ndarray          # [Z] per-slot step counts


def init_state(lora_tree: Dict, Z: int) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda x: jnp.zeros_like(x, jnp.float32), lora_tree)
    return AdamWState(mu=zeros,
                      nu=jax.tree_util.tree_map(jnp.copy, zeros),
                      count=jnp.zeros((Z,), jnp.int32))


def _bshape(v: jnp.ndarray, leaf: jnp.ndarray) -> jnp.ndarray:
    """Reshape [Z] vector to broadcast over [L, Z, ...] leaves."""
    return v.reshape((1, -1) + (1,) * (leaf.ndim - 2))


def per_slot_global_norm(grads: Dict) -> jnp.ndarray:
    """[Z] fp32 global grad norm per slot across all leaves."""
    sq = jax.tree_util.tree_map(
        lambda g: jnp.sum(jnp.square(g.astype(jnp.float32)),
                          axis=tuple(i for i in range(g.ndim) if i != 1)),
        grads)
    total = jax.tree_util.tree_reduce(
        lambda a, b: a + b, sq, jnp.zeros(()))
    return jnp.sqrt(jnp.maximum(total, 0.0))


def apply_updates(params: Dict, grads: Dict, state: AdamWState,
                  hp: SlotHParams, active: jnp.ndarray,
                  rank_masker=None, eps: float = 1e-8
                  ) -> Tuple[Dict, AdamWState]:
    """One AdamW step. ``active``: [Z] {0,1} — inactive slots are frozen.

    ``rank_masker``: optional fn(tree) -> tree re-applying rank masks.
    """
    norms = per_slot_global_norm(grads)
    clip = jnp.where(
        (hp.grad_clip > 0) & (norms > hp.grad_clip),
        hp.grad_clip / jnp.maximum(norms, 1e-12), 1.0)      # [Z]
    act = active.astype(jnp.float32)
    new_count = state.count + active.astype(jnp.int32)
    t = jnp.maximum(new_count, 1).astype(jnp.float32)       # [Z]
    bc1 = 1.0 - hp.beta1 ** t
    bc2 = 1.0 - hp.beta2 ** t

    def upd(p, g, m, n):
        gf = g.astype(jnp.float32) * _bshape(clip * act, p)
        b1, b2 = _bshape(hp.beta1, p), _bshape(hp.beta2, p)
        a = _bshape(act, p)
        m2 = (b1 * m + (1 - b1) * gf) * a + m * (1 - a)
        n2 = (b2 * n + (1 - b2) * jnp.square(gf)) * a + n * (1 - a)
        mhat = m2 / _bshape(bc1, p)
        nhat = n2 / _bshape(bc2, p)
        step = mhat / (jnp.sqrt(nhat) + eps) + _bshape(hp.wd, p) * p
        p2 = p - _bshape(hp.lr * act, p) * step
        return p2, m2, n2

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.mu)
    flat_n = jax.tree_util.tree_leaves(state.nu)
    out_p, out_m, out_n = [], [], []
    for p, g, m, n in zip(flat_p, flat_g, flat_m, flat_n):
        p2, m2, n2 = upd(p, g, m, n)
        out_p.append(p2)
        out_m.append(m2)
        out_n.append(n2)
    new_params = jax.tree_util.tree_unflatten(treedef, out_p)
    if rank_masker is not None:
        new_params = rank_masker(new_params)
    return new_params, AdamWState(
        mu=jax.tree_util.tree_unflatten(treedef, out_m),
        nu=jax.tree_util.tree_unflatten(treedef, out_n),
        count=new_count)


def reset_slot(state: AdamWState, slot: int) -> AdamWState:
    """Zero a slot's optimizer state (eviction / swap-in)."""
    z = jax.tree_util.tree_map(lambda x: x.at[:, slot].set(0.0), state.mu)
    n = jax.tree_util.tree_map(lambda x: x.at[:, slot].set(0.0), state.nu)
    return AdamWState(mu=z, nu=n, count=state.count.at[slot].set(0))
