"""ALTO reproduction: adaptive LoRA tuning and orchestration (JAX/Pallas)."""

__version__ = "0.1.0"
