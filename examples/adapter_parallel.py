import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""Adapter Parallelism on a REAL 8-device mesh (8 faked CPU host devices).

    PYTHONPATH=src python examples/adapter_parallel.py

Runs genuine multi-device pjit training: mesh (data=4, model=2), 4 adapter
slots sharded one-per-data-rank (the paper's AP), frozen backbone sharded
over the model axis. Trains 30 steps, prints per-slot losses (each slot has
a different lr; the crazy one diverges), and proves the AP claim by parsing
the compiled HLO: adapter-gradient tensors appear in NO collective op.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.core import lora as LORA
from repro.data.synthetic import SlotBatcher, make_task_dataset
from repro.launch import partitioning as PT
from repro.launch import steps_dist
from repro.models import model as M
from repro.optim import adamw
from repro.roofline import hlo as HLO


def main() -> None:
    assert len(jax.devices()) == 8, jax.devices()
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = dataclasses.replace(
        get_arch("paper-llama-tiny").reduced(num_layers=2, d_model=128,
                                             vocab=512), dtype="float32")
    Z, b, S = 4, 4, 32
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    ranks = jnp.array([8, 8, 4, 4])
    lora = LORA.init_lora_tree(key, cfg, Z, ranks, M.target_shapes(cfg))
    opt = adamw.init_state(lora, Z)
    # one lr per slot — slot 3 gets a diverging lr
    hp = adamw.SlotHParams.broadcast(Z, lr=3e-3, grad_clip=0.0)
    for slot, lr in enumerate([3e-3, 1e-3, 1e-2, 300.0]):
        hp = hp.replace_slot(slot, lr=lr)
    active = jnp.ones((Z,), jnp.int32)

    ns = lambda t: PT.to_named(mesh, t)
    p_sh = ns(PT.base_param_specs(mesh, params))
    l_sh = ns(PT.lora_param_specs(mesh, lora))
    o_sh = ns(PT.opt_state_specs(mesh, opt))
    h_sh = ns(PT.hp_specs(mesh, jax.tree_util.tree_map(lambda x: x, hp)))
    v_sh = PT.to_named(mesh, PT.pick_spec(mesh, (Z,), [{0: "data"}, {}]))

    ds = make_task_dataset("ap-demo", cfg.vocab_size, seq_len=S,
                           num_train=64, difficulty=0.25)
    batcher = SlotBatcher(ds, Z, b)
    tokens_np, labels_np = batcher.next_batch()
    batch = {"tokens": jnp.asarray(tokens_np),
             "labels": jnp.asarray(labels_np)}
    b_sh = ns(PT.batch_specs(mesh, batch))

    step = jax.jit(steps_dist.make_train_step(cfg, mesh),
                   in_shardings=(p_sh, l_sh, o_sh, h_sh, v_sh, v_sh, b_sh),
                   out_shardings=(l_sh, o_sh, None))

    # device placement
    put = lambda t, sh: jax.device_put(t, sh)
    params = put(params, p_sh)
    lora = put(lora, l_sh)
    opt = put(opt, o_sh)

    print(f"mesh: {dict(mesh.shape)}; slots Z={Z} sharded over 'data' "
          f"(1 adapter per data-rank), backbone over 'model'")
    with mesh:
        lowered = step.lower(params, lora, opt, hp, active, ranks, batch)
        compiled = lowered.compile()
        # --- the AP claim, verified on the compiled program: no adapter-
        # shaped tensor (last dim == r_max) crosses the DATA axis. (Small
        # model-axis all-reduces of adapter grads are expected: they are
        # sequence-parallel partial sums, Megatron-SP style — the paper's
        # claim is about the adapter/data axis, where FSDP would pay a
        # full adapter-grad all-reduce.)
        colls = HLO.parse_collectives(compiled.as_text())
        summary = HLO.summarize(colls)
        print("collectives in the compiled step:",
              {k: int(v['count']) for k, v in summary.items()} or "none")
        r_max = cfg.lora.r_max
        model_size = mesh.shape["model"]
        adapter_over_data = [
            c for c in colls
            if HLO.parse_shape(c.line.split("=", 1)[1])[1][-1:] == (r_max,)
            and c.group_size > model_size]
        assert not adapter_over_data, adapter_over_data
        print("adapter-shaped tensors crossing the data axis: 0  "
              "(AP invariant holds: adapter grads are data-rank-local)")
        for t in range(30):
            tokens_np, labels_np = batcher.next_batch()
            batch = {"tokens": jnp.asarray(tokens_np),
                     "labels": jnp.asarray(labels_np)}
            lora, opt, metrics = step(params, lora, opt, hp, active,
                                      ranks, batch)
            if t % 5 == 0 or t == 29:
                losses = np.asarray(metrics["per_slot_loss"])
                print(f"step {t:3d}  per-slot loss: "
                      + "  ".join(f"{v:8.3f}" for v in losses))
    losses = np.asarray(metrics["per_slot_loss"])
    assert losses[0] < 6.5 and losses[1] < 6.5, "healthy slots learn"
    print("\nslot 3 (lr=300, no clip) diverged as expected:",
          not np.isfinite(losses[3]) or losses[3] > losses[0])

    # --- semantics preservation: the §Perf optimization ladder (opt_level
    # 2: weight gathering, attention re-layout, chunk remat) must compute
    # the SAME math — compare one step's per-slot losses on real devices.
    step_opt = jax.jit(
        steps_dist.make_train_step(cfg, mesh, opt_level=2),
        in_shardings=(p_sh, l_sh, o_sh, h_sh, v_sh, v_sh, b_sh),
        out_shardings=(l_sh, o_sh, None))
    with mesh:
        _, _, m0 = step(params, lora, opt, hp, active, ranks, batch)
        _, _, m2 = step_opt(params, lora, opt, hp, active, ranks, batch)
    l0 = np.asarray(m0["per_slot_loss"])[:3]   # skip the diverged slot
    l2 = np.asarray(m2["per_slot_loss"])[:3]
    np.testing.assert_allclose(l0, l2, rtol=2e-4, atol=2e-4)
    print(f"opt_level 0 vs 2 per-slot losses match to {np.abs(l0-l2).max():.2e}"
          f" (same math, different schedule)")


if __name__ == "__main__":
    main()
