"""Quickstart: the ALTO API from paper Listing 1, runnable on CPU.

    PYTHONPATH=src python examples/quickstart.py

Submits one LoRA tuning task (a search space over lr x rank) for a tiny
Llama-class model, lets the engine schedule + batch-execute it with
loss-aware early exit, and prints the winning adapter's configuration.
"""
import dataclasses


from repro.configs.registry import get_arch
from repro.core import engine as alto
from repro.data.synthetic import make_task_dataset


def main() -> None:
    # 1. Initialize engine
    engine = alto.Engine(strategy="adapter_parallel", total_gpus=4)

    # 2. Define the task: base model x dataset x search space
    cfg = dataclasses.replace(
        get_arch("paper-llama-tiny").reduced(num_layers=2, d_model=128,
                                             vocab=512),
        dtype="float32")
    dataset = make_task_dataset("math/toy-gsm", cfg.vocab_size, seq_len=32,
                                num_train=96, num_val=24, difficulty=0.25)
    task = alto.Task(
        model=cfg, dataset=dataset, num_gpus=1, max_steps=40, num_slots=4,
        search_space={"lr": [1e-3, 3e-3, 1e-2, 10.0],
                      "rank": [4, 8],
                      "batch_size": [4]})

    # 3. Set early-exit strategy, schedule and execute
    early_exit = alto.EarlyExit(warmup_ratio=0.10, select_ratio=0.25)
    schedule = engine.schedule([task], method="cp", early_exit=early_exit)
    print(f"schedule: makespan={schedule.makespan:.1f}s "
          f"(optimal={schedule.optimal}, "
          f"solved in {schedule.solve_time_s * 1e3:.0f}ms)")
    report = engine.batched_execution([task], schedule, early_exit)

    # 4. Inspect the result
    result = next(iter(report.task_results.values()))
    print(f"\nbest adapter: {result.best_job}")
    print(f"best val loss: {result.best_val:.4f}")
    print(f"samples saved by early exit: "
          f"{result.samples_saved_frac * 100:.1f}%")
    print(f"exit reasons: {result.exit_counts}")
    best = result.job_results[result.best_job]
    print(f"winning config: lr={best.config.learning_rate:g} "
          f"rank={best.config.lora_rank}")
    assert best.adapter is not None, "winner ships with its best checkpoint"
    print("adapter tensors:", sorted(best.adapter))


if __name__ == "__main__":
    main()
