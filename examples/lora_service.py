"""Multi-tenant LoRA-as-a-Service demo: heterogeneous tasks, inter-task
scheduling, event-driven replanning (paper §4/§7).

    PYTHONPATH=src python examples/lora_service.py

Three tenants submit tasks over DIFFERENT model families (dense, SSM, MoE)
with different GPU needs and search spaces. The engine profiles each,
solves the makespan-optimal placement, executes, and also replays the
placement through the event-driven cluster simulator to show early-exit
GPU reclamation. A final section runs the same tenants through the
long-lived ``TuningService`` API with staggered arrivals and a
cancellation."""
import dataclasses
import zlib

from repro.configs.registry import get_arch
from repro.core import engine as alto
from repro.core.service import TaskCancelled, TuningService
from repro.data.synthetic import make_task_dataset
from repro.sched.events import ClusterSimulator


def tiny(arch: str, vocab=512):
    return dataclasses.replace(
        get_arch(arch).reduced(num_layers=2, d_model=128, vocab=vocab),
        dtype="float32")


def main() -> None:
    engine = alto.Engine(strategy="adapter_parallel", total_gpus=8)

    tenants = [
        ("tenant-a/dense-chat", tiny("stablelm-3b"), 2,
         {"lr": [1e-3, 1e-2], "rank": [4, 8]}),
        ("tenant-b/rwkv-code", tiny("rwkv6-3b"), 1,
         {"lr": [3e-3, 30.0], "rank": [4]}),
        ("tenant-c/moe-legal", tiny("granite-moe-1b-a400m"), 4,
         {"lr": [1e-3, 3e-3], "rank": [4]}),
    ]
    tasks = []
    for name, cfg, gpus, space in tenants:
        # stable digest, NOT hash(): string hashing is randomized per
        # process (PYTHONHASHSEED), which would make the demo data differ
        # across runs
        ds = make_task_dataset(name, cfg.vocab_size, seq_len=32,
                               num_train=64, num_val=16, difficulty=0.3,
                               seed=zlib.crc32(name.encode()) % 1000)
        tasks.append(alto.Task(model=cfg, dataset=ds, num_gpus=gpus,
                               max_steps=25, num_slots=2, name=name,
                               search_space=space))

    early_exit = alto.EarlyExit(warmup_ratio=0.15, select_ratio=0.5)
    schedule = engine.schedule(tasks, method="cp", early_exit=early_exit)
    print("=== inter-task schedule (makespan-optimal) ===")
    for p in sorted(schedule.placements, key=lambda p: p.start):
        print(f"  t={p.start:8.1f}s  {p.task.name:24s} "
              f"gpus={list(p.gpu_ids)}  d={p.task.duration:.1f}s")
    print(f"makespan estimate: {schedule.makespan:.1f}s "
          f"(optimal={schedule.optimal})")

    report = engine.batched_execution(tasks, schedule, early_exit)
    print("\n=== task results ===")
    for name, tr in report.task_results.items():
        print(f"  {name:24s} best={tr.best_job.split('/')[-1]:24s} "
              f"val={tr.best_val:.4f} saved={tr.samples_saved_frac:.0%} "
              f"exits={tr.exit_counts}")

    # event-driven replanning with early-exit-shortened durations
    print("\n=== event-driven replanning (early exits reclaim GPUs) ===")
    sim = ClusterSimulator(G=8, method="cp")
    for p in schedule.placements:
        tr = report.task_results[p.task.name]
        factor = 1.0 - tr.samples_saved_frac
        sim.submit(p.task, actual_duration=p.task.duration * factor)
    mk = sim.run_until_idle()
    print(f"  static plan makespan : {schedule.makespan:.1f}s")
    print(f"  replanned (with EE)  : {mk:.1f}s  "
          f"({schedule.makespan / max(mk, 1e-9):.2f}x shorter, "
          f"{sim.replans} replans)")

    # ---- the long-lived service API: staggered arrivals + a cancel -------
    print("\n=== TuningService: dynamic arrivals (submit/status/cancel) ===")
    svc = TuningService(total_gpus=8)
    arrivals = [0.0, 15.0, 40.0]
    handles = []
    for (task, at) in zip(tasks, arrivals):
        t = dataclasses.replace(task, name=f"{task.task_name}/svc")
        handles.append(svc.submit(t, at=at, early_exit=early_exit))
    handles[-1].cancel(at=20.0)   # tenant-c withdraws before its arrival
    report = svc.run_until_idle()
    for h in handles:
        st = h.status()
        try:
            best = h.result().best_job.split("/")[-1]
        except TaskCancelled:
            best = "(cancelled)"
        print(f"  {h.name:28s} {st.state.value:9s} "
              f"start={st.started_at if st.started_at is not None else '-'} "
              f"best={best}")
    print(f"  service makespan={report.makespan:.1f}s "
          f"util={report.utilization:.0%} replans={report.replans}")
    for (task, _) in zip(tasks, arrivals):
        key = svc.engine.profile_key(task)
        wall = svc.profile_store.wall_step_time(key)
        if wall is not None:
            print(f"  observed wall step time {key[0]:24s} {wall:.2f}s "
                  f"(scale {svc.profile_store.duration_scale(key):.2f})")


if __name__ == "__main__":
    main()
