"""End-to-end driver: tune a ~100M-param Llama-class model with ALTO.

    PYTHONPATH=src python examples/train_100m.py [--steps 300] [--small]

The full run trains a 12L/768d (~98M param) model for a few hundred steps
across an 8-config search space with batched multi-LoRA execution and
loss-aware early exit, then greedy-decodes a few tokens from the winning
adapter through the serve path. ``--small`` shrinks the model for a quick
functional pass (~2 min).
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LoRAConfig, ModelConfig, TrainConfig
from repro.core.early_exit import EarlyExitConfig
from repro.core.executor import BatchedExecutor
from repro.core.steps import make_serve_step
from repro.checkpoint.checkpoint import insert_slot, save_pytree
from repro.core import lora as LORA
from repro.data.synthetic import make_task_dataset
from repro.models import model as M


def model_100m(small: bool) -> ModelConfig:
    if small:
        return ModelConfig(
            name="llama-10m", family="dense", num_layers=2, d_model=256,
            num_heads=4, num_kv_heads=2, d_ff=512, vocab_size=2048,
            dtype="float32", lora=LoRAConfig(r_max=16))
    return ModelConfig(
        name="llama-100m", family="dense", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=4, d_ff=2048, vocab_size=8192,
        dtype="float32", lora=LoRAConfig(r_max=16))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = model_100m(args.small)
    if args.small:
        args.steps = min(args.steps, 60)
    print(f"model: {cfg.name} ({cfg.param_count() / 1e6:.1f}M params)")
    ds = make_task_dataset("domain-corpus", cfg.vocab_size,
                           seq_len=args.seq, num_train=256, num_val=32,
                           difficulty=0.3)
    t0 = time.time()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    ex = BatchedExecutor(
        cfg, params, ds, Z=4, per_adapter_batch=2,
        ee=EarlyExitConfig(warmup_ratio=0.05, select_ratio=0.25),
        eval_every=10, seed=0)
    jobs = {}
    for lr in (3e-4, 1e-3, 3e-3, 1e-2):
        for rank in (8, 16):
            jobs[f"lr{lr:g}_r{rank}"] = TrainConfig(
                learning_rate=lr, lora_rank=rank, max_steps=args.steps)
    res = ex.run_task("train-100m", jobs, args.steps)
    print(f"\ntuning finished in {time.time() - t0:.0f}s")
    print(f"best: {res.best_job} val={res.best_val:.4f}")
    print(f"samples saved by early exit: {res.samples_saved_frac:.0%} "
          f"exits={res.exit_counts}")
    for j, r in sorted(res.job_results.items()):
        print(f"  {j:16s} best_val={r.best_val:7.4f} "
              f"steps={r.steps_trained:4d} exit={r.exit_reason}")

    # ---- serve the winning adapter: greedy-decode a few tokens
    best = res.job_results[res.best_job]
    rank = best.config.lora_rank
    Z = 1
    lora = LORA.init_lora_tree(jax.random.PRNGKey(1), cfg, Z,
                               jnp.array([rank]), M.target_shapes(cfg))
    lora = insert_slot(lora, 0, best.adapter)
    save_pytree("experiments/train_100m_best_adapter.npz", best.adapter,
                {"job": res.best_job, "val": res.best_val})
    serve = jax.jit(make_serve_step(cfg))
    cache = M.init_cache(cfg, Z, 1, 64)
    prompt = jnp.asarray(ds.val[:1, :8]).reshape(1, 1, 8)
    for t in range(8):
        logits, cache = serve(params, lora, cache, prompt[:, :, t])
    toks = [int(jnp.argmax(logits[0, 0]))]
    for _ in range(8):
        logits, cache = serve(params, lora, cache,
                              jnp.asarray([[toks[-1]]], jnp.int32))
        toks.append(int(jnp.argmax(logits[0, 0])))
    print(f"\ngreedy continuation of val prompt: {toks}")
    print("adapter checkpoint: experiments/train_100m_best_adapter.npz")


if __name__ == "__main__":
    main()
