"""Static vs elastic execution of heterogeneous multi-task workloads.

Paper §7.2: early-exit frees GPU capacity that the scheduler *reclaims* via
event-driven replanning. This benchmark quantifies that claim end to end:
the same workload — mixed model configs, mixed K (search-space sizes),
mixed loss kinds — is executed twice through sched/cluster.py:

  * static: the precomputed makespan-optimal plan, starts pinned (a task's
    GPUs idle from its early finish until the plan's next start), and
  * elastic: the ElasticClusterRuntime, which replans the pending queue on
    every shrink event and admits tasks the moment capacity frees.

Emits BENCH_cluster.json with both makespans, per-GPU utilization for both
strategies, and replanning counters. ``--smoke`` runs a 4-task instance
(CI artifact job); the default is the 8-task paper-scale mix.
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

from repro.configs.registry import get_arch
from repro.core.early_exit import EarlyExitConfig
from repro.sched import profiler
from repro.sched.cluster import (ElasticClusterRuntime, SimulatedTaskDriver,
                                 execute_static, sim_task_spec)
from repro.sched.events import EventKind
from repro.sched.inter_task import solve

# (arch, gpus, loss_kind) mix — heterogeneous base models as in paper §8.2
FULL_MIX = [("qwen2-vl-72b", 4, "sft"), ("glm4-9b", 2, "sft"),
            ("granite-8b", 2, "dpo"), ("stablelm-3b", 1, "sft"),
            ("rwkv6-3b", 1, "sft"), ("mistral-nemo-12b", 2, "dpo"),
            ("llama4-scout-17b-a16e", 4, "sft"), ("hymba-1.5b", 1, "sft")]
SMOKE_MIX = FULL_MIX[:4]


def build_workload(mix, seed: int = 0):
    """One (spec, driver-factory) pair per task: mixed K, mixed exit
    patterns, per-arch analytic step times."""
    rng = np.random.default_rng(seed)
    tasks = []
    for i, (arch, gpus, loss_kind) in enumerate(mix):
        cfg = get_arch(arch)
        Z = int(rng.choice([2, 4, 8]))
        K = int(rng.integers(8, 48))                    # mixed search sizes
        prof = profiler.profile_task(cfg, Z, 4, 1024, gpus)
        step_time = prof.step_time_s
        # users size step budgets to a wall-time target, so the mix stays
        # contended: invert the worst-case lifecycle for the target.
        # With warm = r*total: steps = waves*r*total + cont_waves*(1-r)*total
        target_s = float(rng.uniform(200.0, 600.0))
        r = 0.05
        waves = -(-K // Z)
        cont_waves = -(-EarlyExitConfig().top_k(K) // Z)
        total = int(target_s / step_time / (waves * r + cont_waves * (1 - r)))
        total = max(min(total, 100_000), 20)
        warm = max(int(round(r * total)), 1)
        # exit pattern (paper Fig. 9: 72-83% sample savings). Two styles:
        #   early-converging — every job overfits/diverges well before
        #   budget, so the whole task finishes early (big shrink);
        #   scattered — a random subset diverges, the rest run to budget.
        if rng.random() < 0.5:
            lo, hi = sorted(rng.uniform(0.15, 0.7, size=2))
            exits = {j: max(int(rng.uniform(lo, hi) * total), warm + 1)
                     for j in range(K)}
        else:
            n_exits = int(rng.integers(0, max(K // 2, 1)))
            exits = {int(j): int(rng.integers(1, total))
                     for j in rng.choice(K, size=n_exits, replace=False)}
        name = f"{arch}-{loss_kind}-{i}"
        spec = sim_task_spec(name, K=K, Z=Z, total_steps=total,
                             warmup_steps=warm, step_time_s=step_time,
                             gpus=gpus)

        def factory(name=name, K=K, Z=Z, total=total, warm=warm,
                    step_time=step_time, exits=exits):
            return SimulatedTaskDriver(name, K=K, Z=Z, total_steps=total,
                                       warmup_steps=warm,
                                       step_time_s=step_time,
                                       exit_step=exits)

        tasks.append((spec, factory,
                      {"arch": arch, "gpus": gpus, "loss_kind": loss_kind,
                       "K": K, "total_steps": total, "Z": Z,
                       "early_exits": len(exits)}))
    return tasks


def run(mix, G: int, seed: int = 0) -> dict:
    tasks = build_workload(mix, seed)
    specs = [s for s, _, _ in tasks]
    factories = {s.name: f for s, f, _ in tasks}
    plan = solve(specs, G, "cp")
    plan.validate(G)

    static = execute_static(plan, G, factories)
    runtime = ElasticClusterRuntime(G)
    for spec, factory, _ in tasks:
        runtime.submit(spec, factory)
    elastic = runtime.run(initial=plan)
    assert elastic.makespan <= static.makespan + 1e-9, \
        "elastic regressed past the static plan"

    kinds = {}
    for e in elastic.events:
        kinds[e.kind.value] = kinds.get(e.kind.value, 0) + 1
    return {
        "G": G,
        "seed": seed,
        "num_tasks": len(tasks),
        "tasks": [dict(meta, name=s.name,
                       est_duration_s=round(s.duration, 4))
                  for s, _, meta in tasks],
        "plan": {"makespan": plan.makespan, "optimal": plan.optimal,
                 "solve_time_s": plan.solve_time_s},
        "static": {
            "makespan_s": static.makespan,
            "utilization": static.utilization,
            "per_gpu_utilization": static.per_gpu_utilization(),
            "per_gpu_busy_s": static.gpu_busy,
        },
        "elastic": {
            "makespan_s": elastic.makespan,
            "utilization": elastic.utilization,
            "per_gpu_utilization": elastic.per_gpu_utilization(),
            "per_gpu_busy_s": elastic.gpu_busy,
            "replans": elastic.replans,
            "plans_adopted": elastic.plans_adopted,
            "plans_rejected": elastic.plans_rejected,
            "events": kinds,
            "shrink_events": sum(
                1 for e in elastic.events
                if e.kind in (EventKind.JOB_EXITED,
                              EventKind.WARMUP_SELECTION)),
        },
        "speedup": static.makespan / max(elastic.makespan, 1e-12),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small 4-task instance (CI)")
    ap.add_argument("--gpus", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_cluster.json")
    args = ap.parse_args(argv)

    mix = SMOKE_MIX if args.smoke else FULL_MIX
    result = run(mix, args.gpus, args.seed)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"static makespan : {result['static']['makespan_s']:.3f}s "
          f"(util {result['static']['utilization']:.2%})")
    print(f"elastic makespan: {result['elastic']['makespan_s']:.3f}s "
          f"(util {result['elastic']['utilization']:.2%})")
    print(f"speedup         : {result['speedup']:.2f}x "
          f"({result['elastic']['replans']} replans, "
          f"{result['elastic']['shrink_events']} shrink events)")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
