"""Paper Fig. 11: DPO (RLHF) end-to-end — ALTO's early exit on preference
training, with reward accuracy preserved.

Real tiny-model DPO runs (frozen base = reference policy, so no reference
copy is materialized): ALTO (batched + EE) vs Batched-only over the same
search space. Reports speedup and best preference (reward) accuracy for
both — the paper's claim is that early exit keeps the same accuracy
(76.2% there) at ~2.7x fewer samples."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs.base import TrainConfig
from repro.configs.registry import get_arch
from repro.core.early_exit import EarlyExitConfig
from repro.core.executor import BatchedExecutor
from repro.core.losses import dpo_loss
from repro.data.synthetic import PairSlotBatcher, make_task_dataset
from repro.checkpoint.checkpoint import insert_slot
from repro.core import lora as LORA
from repro.models import model as M

STEPS = 24


def build():
    cfg = dataclasses.replace(
        get_arch("paper-llama-tiny").reduced(num_layers=2, d_model=128,
                                             vocab=256), dtype="float32")
    chosen = make_task_dataset("pref-chosen", cfg.vocab_size, seq_len=24,
                               num_train=48, num_val=16, difficulty=0.1)
    rejected = make_task_dataset("pref-rejected", cfg.vocab_size, seq_len=24,
                                 num_train=48, num_val=16, difficulty=0.9,
                                 seed=5)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    jobs = {f"lr{lr:g}_r{r}": TrainConfig(learning_rate=lr, lora_rank=r,
                                          max_steps=STEPS)
            for lr in (3e-3, 1e-2, 3e-2) for r in (4, 8)}
    return cfg, chosen, rejected, params, jobs


def reward_accuracy(cfg, params, adapter, rank, chosen, rejected):
    """Fraction of val pairs where the adapter prefers 'chosen'."""
    lora = LORA.init_lora_tree(jax.random.PRNGKey(1), cfg, 1,
                               jnp.array([rank]), M.target_shapes(cfg))
    lora = insert_slot(lora, 0, adapter)
    n = min(len(chosen.val), len(rejected.val))
    batch = {
        "tokens_chosen": jnp.asarray(chosen.val[:n, :-1])[None],
        "labels_chosen": jnp.asarray(chosen.val[:n, 1:])[None],
        "tokens_rejected": jnp.asarray(rejected.val[:n, :-1])[None],
        "labels_rejected": jnp.asarray(rejected.val[:n, 1:])[None],
    }
    _, per = dpo_loss(cfg, params, lora, batch,
                      jnp.ones((1,), jnp.int32), remat=False)
    # per-slot loss < log 2 <=> positive mean margin (preference learned)
    return float(per[0]) < float(np.log(2.0))


def run() -> None:
    cfg, chosen, rejected, params, jobs = build()
    results = {}
    for ee_on in (True, False):
        ee = (EarlyExitConfig(warmup_ratio=0.2, select_ratio=0.34)
              if ee_on else EarlyExitConfig(enabled=False, select_ratio=1.0,
                                            warmup_ratio=0.05))
        batcher = PairSlotBatcher(chosen, rejected, Z=3,
                                  per_adapter_batch=4, seed=0)
        ex = BatchedExecutor(cfg, params, chosen, Z=3, per_adapter_batch=4,
                             ee=ee, eval_every=2, seed=0,
                             loss_kind="dpo", batcher=batcher)
        results[ee_on] = ex.run_task("dpo", dict(jobs), STEPS)
    alto, batched = results[True], results[False]
    speedup = batched.total_samples / max(alto.total_samples, 1)
    emit("fig11/alto_dpo", alto.wall_time_s,
         f"best_val={alto.best_val:.4f};sample_speedup={speedup:.2f}x")
    emit("fig11/batched_dpo", batched.wall_time_s,
         f"best_val={batched.best_val:.4f}")
    best = alto.job_results[alto.best_job]
    prefers = reward_accuracy(cfg, params, best.adapter,
                              best.config.lora_rank, chosen, rejected)
    emit("fig11/alto_best_prefers_chosen", 0.0, str(prefers))


if __name__ == "__main__":
    run()
