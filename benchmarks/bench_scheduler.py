"""Paper Fig. 5 + Fig. 12: inter-task scheduling and component ablation.

Fig. 5: SJF vs makespan-aware CP on a heterogeneous task mix.
Fig. 12: 8-GPU makespan ablation over B / B+S / B+EE / B+S+EE, using the
paper's §8.2 task mix (11 tasks: 70B-class needing 4 GPUs, 32B-class 2,
7-8B-class 1) with durations from the analytic profiler and early-exit
shortening measured by the executor benchmark (72-83% sample savings =>
~0.3x duration)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.configs.registry import get_arch
from repro.sched import profiler
from repro.sched.events import ClusterSimulator
from repro.sched.inter_task import TaskSpec, solve

EE_FACTOR = 0.28      # measured sample-savings factor (bench_early_exit)


def paper_task_mix():
    """11 heterogeneous tasks (paper §8.2 inter-task setting)."""
    mixes = [("qwen2-vl-72b", 4), ("glm4-9b", 2), ("granite-8b", 2),
             ("stablelm-3b", 1), ("rwkv6-3b", 1), ("hymba-1.5b", 1),
             ("musicgen-medium", 1), ("granite-moe-1b-a400m", 1),
             ("mistral-nemo-12b", 2), ("llama4-scout-17b-a16e", 4),
             ("granite-8b", 1)]
    rng = np.random.default_rng(0)
    tasks = []
    for i, (arch, g) in enumerate(mixes):
        cfg = get_arch(arch)
        prof = profiler.profile_task(cfg, Z=8, b=4, seq_len=1024, chips=g)
        K = int(rng.integers(24, 64))          # configs in the search space
        steps = int(rng.integers(50, 200))
        dur = K * steps * prof.step_time_s
        tasks.append(TaskSpec(f"{arch}-{i}", dur, g))
    return tasks


def run() -> None:
    tasks = paper_task_mix()
    G = 8
    # ---- Fig 5: SJF vs CP (static makespan)
    for method in ("sjf", "lpt", "cp"):
        s = solve(tasks, G, method)
        emit(f"fig5/{method}_makespan", s.makespan,
             f"optimal={s.optimal};solve_s={s.solve_time_s:.3f}")
    # ---- Fig 12 ablation via the event-driven simulator
    variants = {
        "B": ("sjf", 1.0),           # batched only, naive order
        "B+S": ("cp", 1.0),          # + makespan-aware scheduler
        "B+EE": ("sjf", EE_FACTOR),  # + early exit (shorter actuals)
        "B+S+EE": ("cp", EE_FACTOR),
    }
    base = None
    for name, (method, factor) in variants.items():
        sim = ClusterSimulator(G=G, method=method)
        for t in tasks:
            sim.submit(t, actual_duration=t.duration * factor)
        mk = sim.run_until_idle()
        if base is None:
            base = mk
        emit(f"fig12/{name}_makespan", mk,
             f"reduction_vs_B={base / mk:.2f}x;replans={sim.replans}")


if __name__ == "__main__":
    run()
