"""Continuous batching (per-lane positions) vs the round barrier.

PR 7's serving tier batched at ROUND granularity: one global cache
position, so every request in a round joins at a fresh cache epoch and a
finished lane idles (re-feeding its last token) until the slowest stream
drains. With per-lane decode positions the replica is a lane scheduler:
a request joins the moment a lane frees, mid-decode, with zero barrier.
This bench pins the claim on a heterogeneous-length Poisson trace:

1. **Aggregate decode throughput.** The same arrival trace (prompt
   lengths and decode budgets drawn heterogeneously, arrivals
   step-indexed by a Poisson process so both modes see an identical,
   deterministic workload) is served round-based and continuously. The
   GATED metric is tokens per fused decode step — the utilization a
   batching discipline actually controls, and the one that transfers
   to accelerator-grade backends where a fused step costs the same in
   either mode. Ragged lengths are exactly where the barrier hurts:
   round mode pads every lane to its round's slowest stream, so
   continuous must clear >= 1.2x tokens/step. Measured wall-clock
   tok/s for both modes is reported alongside (``wall_speedup``,
   informative: on a dispatch-bound CPU host it is the same win
   discounted by per-launch overhead and host noise, so it is NOT
   asserted on).

2. **Per-request latency.** p50/p95 of submit->completion latency per
   mode, in fused steps (deterministic) and wall seconds (measured):
   continuous cuts the queue-behind-the-barrier term, which shows up
   hardest in the tail.

3. **Bitwise join isolation.** Mid-decode joins (block prefill into a
   freed lane while residents decode) leave a resident lane's logits
   bit-for-bit identical to a solo run — the zero-barrier path changes
   scheduling, never numerics.

Emits BENCH_continuous.json. ``--smoke`` shrinks the trace (CI) but
keeps it heterogeneous so the speedup gate still binds. Wall repeats
are interleaved round/continuous so ambient host drift hits both modes
alike.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from typing import Dict

import jax
import numpy as np

from repro.configs.registry import get_arch
from repro.models import model as M
from repro.serve import (AdapterPool, ServeRequest, ServingFrontend,
                         ServingReplica)

RANK_CYCLE = (2, 4, 8)        # mixed TRUE ranks across the adapter set


def build_cfg():
    cfg = get_arch("paper-llama-tiny").reduced(num_layers=2, d_model=64,
                                               vocab=128)
    return dataclasses.replace(cfg, dtype="float32")


def make_adapters(cfg, n: int, seed: int):
    """n noisy adapters ([L,...] trees) with ranks cycling RANK_CYCLE."""
    pool = AdapterPool(cfg, 1)
    ranks, adapters = [], []
    keys = jax.random.split(jax.random.PRNGKey(seed + 1), n)
    for i in range(n):
        r = min(RANK_CYCLE[i % len(RANK_CYCLE)], cfg.lora.r_max)
        sub = jax.random.split(keys[i], 64)
        k_iter = iter(range(64))
        adapter = jax.tree_util.tree_map(
            lambda x: 0.1 * jax.random.normal(
                sub[next(k_iter)], x[:, 0].shape, x.dtype),
            pool.lora)
        ranks.append(r)
        adapters.append(adapter)
    return adapters, ranks


def make_trace(cfg, n_req: int, n_adapters: int, seed: int, smoke: bool):
    """Deterministic heterogeneous trace: (arrival_step, adapter index,
    prompt, max_new). Arrival steps are a Poisson-increment process over
    the FUSED STEP index — both modes replay the identical schedule, so
    the comparison is scheduling discipline only."""
    rng = np.random.default_rng(seed + 7)
    p_lo, p_hi = 3, 10
    n_lo, n_hi = (3, 12) if smoke else (6, 24)
    step = 0
    trace = []
    for i in range(n_req):
        step += int(rng.poisson(1.0))
        P = int(rng.integers(p_lo, p_hi + 1))
        prompt = rng.integers(0, cfg.vocab_size, size=P).astype(np.int32)
        trace.append((step, int(rng.integers(0, n_adapters)), prompt,
                      int(rng.integers(n_lo, n_hi + 1))))
    return trace, p_hi + n_hi


def _reset(rep: ServingReplica) -> None:
    rep.total_generated = 0
    rep.total_decode_steps = 0
    rep.total_wall_s = 0.0
    rep.rounds = 0
    rep.joins = 0
    rep.block_prefills = 0
    rep.records.clear()


def _replay(rep, fe, trace, mode):
    """Feed arrivals keyed on the fused-step clock; returns
    {trace index: (tokens, latency_steps, latency_s)}."""
    by_rid, i = {}, 0
    sub_step, done_step, done_t = {}, {}, {}
    while True:
        step = rep.total_decode_steps
        while i < len(trace) and trace[i][0] <= step:
            rid = fe.submit(f"adapter-{trace[i][1]}", trace[i][2],
                            trace[i][3])
            by_rid[rid] = i
            sub_step[rid] = step
            i += 1
        if (not fe.queued() and not rep.busy_lanes()
                and i < len(trace)):
            # idle gap in the trace: fast-forward to the next arrival
            nxt = trace[i][0]
            while i < len(trace) and trace[i][0] == nxt:
                rid = fe.submit(f"adapter-{trace[i][1]}", trace[i][2],
                                trace[i][3])
                by_rid[rid] = i
                sub_step[rid] = rep.total_decode_steps
                i += 1
        if not fe.queued() and not rep.busy_lanes():
            break
        before = set(fe._done)
        fe.step_round() if mode == "round" else fe.step_continuous()
        now = time.perf_counter()
        for rid in set(fe._done) - before:
            done_step[rid] = rep.total_decode_steps
            done_t[rid] = now
    out = {}
    for rid, ti in by_rid.items():
        r = fe._done[rid]
        out[ti] = (list(r.tokens), done_step[rid] - sub_step[rid],
                   done_t[rid] - r.submit_t)
    return out


def run_trace(cfg, params, adapters, ranks, trace, lanes, max_len,
              repeats) -> Dict[str, dict]:
    """Replay the trace round-based AND continuously, repeats
    INTERLEAVED (ambient host drift hits both modes alike); wall stats
    are the best repeat per mode, step stats are deterministic. Returns
    {mode: stats} with per-request token streams (both modes must emit
    identical greedy tokens per request)."""
    state = {}
    for mode in ("round", "continuous"):
        pool = AdapterPool(cfg, len(adapters))
        for z, (ad, r) in enumerate(zip(adapters, ranks)):
            pool.publish(f"adapter-{z}", ad, r)
        rep = ServingReplica(cfg, params, pool, lanes=lanes,
                             max_len=max_len)
        fe = ServingFrontend(rep, mode=mode)
        # warm-up: every distinct prompt length (each compiles its own
        # prefill shape), untimed; max_new=3 so the plain decode program
        # compiles too (a fused join+decode covers the first 2 tokens)
        for P in sorted({len(p) for _, _, p, _ in trace}):
            fe.submit("adapter-0", trace[0][2][:1].repeat(P), 3)
            fe.drain()
        state[mode] = (rep, fe)
    best: Dict[str, dict] = {}
    for _ in range(repeats):
        for mode, (rep, fe) in state.items():
            _reset(rep)
            served = _replay(rep, fe, trace, mode)
            lat_steps = np.asarray([s for _, s, _ in served.values()])
            lat_wall = np.asarray([w for _, _, w in served.values()])
            if mode not in best or rep.total_wall_s < best[mode]["wall_s"]:
                best[mode] = {
                    "wall_s": rep.total_wall_s,
                    "generated": rep.total_generated,
                    "decode_steps": rep.total_decode_steps,
                    "tok_per_step": rep.total_generated
                    / max(rep.total_decode_steps, 1),
                    "aggregate_tok_s": rep.aggregate_tok_s,
                    "latency_p50_steps": float(np.percentile(lat_steps, 50)),
                    "latency_p95_steps": float(np.percentile(lat_steps, 95)),
                    "latency_p50_s": float(np.percentile(lat_wall, 50)),
                    "latency_p95_s": float(np.percentile(lat_wall, 95)),
                    "_tokens": {ti: toks for ti, (toks, _, _)
                                in served.items()},
                }
    for mode, (rep, fe) in state.items():
        best[mode]["requests"] = len(trace)
        best[mode]["repeats"] = repeats
        if mode == "round":
            best[mode]["rounds"] = rep.rounds
        else:
            best[mode]["joins"] = rep.joins
            best[mode]["block_prefills"] = rep.block_prefills
    return best


def run_bitwise_join(cfg, params, adapters, ranks, lanes, max_len) -> dict:
    """A resident lane's logits with vs without a mid-decode join of its
    neighbors must be bitwise identical (per-lane isolation)."""
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (6, 4, 7)]

    def run(join):
        pool = AdapterPool(cfg, len(adapters))
        for z, (ad, r) in enumerate(zip(adapters, ranks)):
            pool.publish(f"adapter-{z}", ad, r)
        rep = ServingReplica(cfg, params, pool, lanes=lanes,
                             max_len=max_len)
        resident = ServeRequest("res", "adapter-0", prompts[0], 10)
        assert rep.try_join(resident)
        while not resident.done:
            if join and rep.total_decode_steps == 3:
                for z in (0, 1):
                    rep.try_join(ServeRequest(f"j{z}", f"adapter-{z}",
                                              prompts[z + 1], 6))
            rep.step_continuous(record_logits=True)
        return (list(resident.tokens),
                [lg[0, 0] for _, lg in rep.step_logits])

    toks_solo, log_solo = run(False)
    toks_join, log_join = run(True)
    tokens_ok = toks_solo == toks_join
    logits_ok = all((a == b).all()
                    for a, b in zip(log_solo, log_join))
    assert tokens_ok and logits_ok, \
        "mid-decode join moved a resident lane's stream"
    return {"mid_join_resident_tokens_identical": bool(tokens_ok),
            "mid_join_resident_logits_identical": bool(logits_ok),
            "compared_positions": len(log_solo)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small trace (CI); stays heterogeneous")
    ap.add_argument("--requests", type=int, default=None,
                    help="trace length (default 24 smoke / 64 full)")
    ap.add_argument("--adapters", type=int, default=4)
    ap.add_argument("--lanes", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeats", type=int, default=5,
                    help="measured repeats per mode; best wall wins")
    ap.add_argument("--out", default="BENCH_continuous.json")
    args = ap.parse_args(argv)

    n_req = args.requests or (24 if args.smoke else 64)
    cfg = build_cfg()
    params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    adapters, ranks = make_adapters(cfg, args.adapters, args.seed)
    trace, max_len = make_trace(cfg, n_req, args.adapters, args.seed,
                                args.smoke)

    both = run_trace(cfg, params, adapters, ranks, trace, args.lanes,
                     max_len, args.repeats)
    rnd, cont = both["round"], both["continuous"]
    assert rnd.pop("_tokens") == cont.pop("_tokens"), \
        "continuous greedy tokens differ from the round baseline"
    assert rnd["generated"] == cont["generated"]
    # gate: step-normalized aggregate decode throughput (deterministic)
    speedup = cont["tok_per_step"] / max(rnd["tok_per_step"], 1e-12)
    assert speedup >= 1.2, \
        f"continuous speedup {speedup:.2f}x < 1.2x on the ragged trace"
    wall_speedup = (cont["aggregate_tok_s"]
                    / max(rnd["aggregate_tok_s"], 1e-12))

    bitwise = run_bitwise_join(cfg, params, adapters, ranks, args.lanes,
                               max_len)
    result = {
        "config": {"arch": cfg.name, "requests": n_req,
                   "adapters": args.adapters, "lanes": args.lanes,
                   "ranks": ranks, "max_len": max_len, "seed": args.seed,
                   "smoke": bool(args.smoke)},
        "round": rnd,
        "continuous": cont,
        "speedup": speedup,
        "wall_speedup": wall_speedup,
        "latency_p95_step_ratio": rnd["latency_p95_steps"]
        / max(cont["latency_p95_steps"], 1e-12),
        "latency_p95_wall_ratio": rnd["latency_p95_s"]
        / max(cont["latency_p95_s"], 1e-12),
        "bitwise": bitwise,
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"round     : {rnd['tok_per_step']:.2f} tok/step "
          f"({rnd['decode_steps']} steps / {rnd['rounds']} rounds, "
          f"{rnd['aggregate_tok_s']:.0f} tok/s), "
          f"p95 {rnd['latency_p95_steps']:.0f} steps "
          f"/ {rnd['latency_p95_s'] * 1e3:.1f}ms")
    print(f"continuous: {cont['tok_per_step']:.2f} tok/step "
          f"({cont['decode_steps']} steps / {cont['joins']} joins, "
          f"{cont['aggregate_tok_s']:.0f} tok/s), "
          f"p95 {cont['latency_p95_steps']:.0f} steps "
          f"/ {cont['latency_p95_s'] * 1e3:.1f}ms")
    print(f"speedup   : {speedup:.2f}x tokens/step (gated), "
          f"{wall_speedup:.2f}x wall tok/s (measured), p95 latency "
          f"{result['latency_p95_step_ratio']:.2f}x fewer steps")
    print(f"bitwise   : resident unchanged across mid-decode join "
          f"({bitwise['compared_positions']} positions)")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
