"""Paper Fig. 15 + Fig. 14: early-exit sample savings per pattern and
quality preservation, on a real (tiny-model) hyperparameter sweep.

Runs the BatchedExecutor twice over the same 12-config search space
(including genuinely diverging LRs and an overfit-prone setup): once with
early exit enabled, once without. Reports samples saved per detector and
the best-val ratio with/without early exit (paper: savings 72-83%, ratio
~1.0)."""
from __future__ import annotations

import dataclasses

import jax

from benchmarks.common import emit
from repro.configs.base import TrainConfig
from repro.configs.registry import get_arch
from repro.core.early_exit import EarlyExitConfig
from repro.core.executor import BatchedExecutor
from repro.data.synthetic import make_task_dataset
from repro.models import model as M

STEPS = 40


def build():
    cfg = dataclasses.replace(
        get_arch("paper-llama-tiny").reduced(num_layers=2, d_model=128,
                                             vocab=256),
        dtype="float32")
    ds = make_task_dataset("bench", cfg.vocab_size, seq_len=32,
                           num_train=48, num_val=16, difficulty=0.25)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    jobs = {}
    for lr in (1e-3, 3e-3, 1e-2, 3e-2, 1.0, 30.0):
        for rank in (4, 8):
            tc = TrainConfig(learning_rate=lr, lora_rank=rank,
                             max_steps=STEPS,
                             grad_clip=0.0 if lr >= 1.0 else 1.0)
            jobs[f"lr{lr:g}_r{rank}"] = tc
    return cfg, ds, params, jobs


def run() -> None:
    cfg, ds, params, jobs = build()
    results = {}
    for ee_on in (True, False):
        ee = EarlyExitConfig(warmup_ratio=0.15, select_ratio=0.34,
                             enabled=ee_on) if ee_on else \
            EarlyExitConfig(enabled=False, warmup_ratio=0.15,
                            select_ratio=1.0)
        ex = BatchedExecutor(cfg, params, ds, Z=4, per_adapter_batch=4,
                             ee=ee, eval_every=2, seed=0)
        results[ee_on] = ex.run_task("bench", dict(jobs), STEPS)
    with_ee, without = results[True], results[False]
    emit("fig15/samples_saved_frac", with_ee.wall_time_s,
         f"{with_ee.samples_saved_frac:.3f}")
    for reason, count in sorted(with_ee.exit_counts.items()):
        emit(f"fig15/exits_{reason}", 0.0, str(count))
    ratio = with_ee.best_val / max(without.best_val, 1e-12)
    emit("fig15/best_val_ratio_w_vs_wo", 0.0, f"{ratio:.4f}")
    emit("fig14/best_val_with_ee", with_ee.wall_time_s,
         f"{with_ee.best_val:.4f}")
    emit("fig14/best_val_without_ee", without.wall_time_s,
         f"{without.best_val:.4f}")
    speedup = without.total_samples / max(with_ee.total_samples, 1)
    emit("fig15/sample_speedup", 0.0, f"{speedup:.2f}x")


if __name__ == "__main__":
    run()
