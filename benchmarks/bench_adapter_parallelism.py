"""Paper Fig. 13: Adapter Parallelism (AP) vs FSDP multi-LoRA, from the
compiled production-mesh artifacts (this container cannot wall-clock 256
chips; the comparison is the roofline step bound + collective traffic +
per-device memory of the two compiled programs).

The variant lowering runs in a subprocess because it needs the 512-device
host platform (benchmarks themselves stay on 1 device).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit
from repro.roofline.analysis import HBM_BW, ICI_BW, PEAK_FLOPS

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                   "ap_vs_fsdp")
ARCH, SHAPE = "stablelm-3b", "train_4k"


def ensure_artifacts() -> None:
    need = [f"{ARCH}__{SHAPE}__{v}.json" for v in ("ap", "fsdp")]
    if all(os.path.exists(os.path.join(OUT, n)) for n in need):
        return
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    subprocess.run(
        [sys.executable, "-m", "repro.launch.sharding_variants",
         "--arch", ARCH, "--shape", SHAPE],
        check=True, env=env, timeout=900)


def step_bound(rec: dict) -> float:
    return max(rec["flops"] / PEAK_FLOPS, rec["hlo_bytes"] / HBM_BW,
               rec["collective_traffic"] / ICI_BW)


def run() -> None:
    ensure_artifacts()
    recs = {}
    for v in ("ap", "fsdp"):
        with open(os.path.join(OUT, f"{ARCH}__{SHAPE}__{v}.json")) as f:
            recs[v] = json.load(f)
    ap_t, fs_t = step_bound(recs["ap"]), step_bound(recs["fsdp"])
    HBM = 16 * 2 ** 30
    for v, rec in recs.items():
        fits = rec["argument_bytes"] + rec["temp_bytes"] <= HBM
        emit(f"fig13/{v}_step_bound", step_bound(rec),
             f"coll_bytes={rec['collective_traffic']:.3e};"
             f"arg_bytes={rec['argument_bytes']:.3e};fits_hbm={fits}")
    emit("fig13/ap_speedup_vs_fsdp", 0.0,
         f"{fs_t / ap_t:.2f}x_step_bound;"
         f"adapter_mem_ratio="
         f"{recs['fsdp']['argument_bytes'] / max(recs['ap']['argument_bytes'], 1):.1f}x;"
         f"fsdp_oom_at_Z64_r64="
         f"{recs['fsdp']['argument_bytes'] > HBM}")


if __name__ == "__main__":
    run()
