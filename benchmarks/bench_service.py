"""Service mode vs batch mode on a dynamic arrival trace, with and without
profiler feedback.

Paper §4 frames ALTO as LoRA-tuning-as-a-service: tenants submit tasks
continuously, not as one closed batch. This benchmark replays a
Poisson-ish arrival trace over the heterogeneous 8-task mix of
``bench_cluster`` through three policies:

  * batch: wait until the LAST arrival, solve the full-hindsight static
    plan, execute it literally (what the batch Engine API forces a
    multi-tenant operator into);
  * service/analytic: ``TuningService`` admits each task the moment it
    arrives, re-solving residual placement around it (bounded-delay
    adoption); durations come from the analytic worst-case profile;
  * service/fed-back: same trace, but the ``ProfileStore`` carries the
    realized durations observed in the analytic session — later (and
    repeated-arch) admissions are scheduled from observed estimates, so
    the planned schedule demonstrably deviates from the analytic one.

Emits BENCH_service.json with makespans, utilizations, per-task estimated
durations and realized starts for both service sessions, and a deviation
summary. ``--smoke`` runs the 4-task instance (CI artifact job).
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

from bench_cluster import FULL_MIX, SMOKE_MIX, build_workload

from repro.core.service import TuningService
from repro.sched import profiler
from repro.sched.cluster import execute_static
from repro.sched.events import EventKind
from repro.sched.inter_task import solve


def poisson_arrivals(specs, rng, load: float = 0.35):
    """Cumulative exponential gaps, scaled so the whole trace arrives
    within ~``load`` of the mean task duration (keeps the cluster
    contended — tenants trickle in while earlier tasks still run)."""
    mean_d = float(np.mean([s.duration for s in specs]))
    gap = load * mean_d / max(len(specs) - 1, 1)
    ats = np.concatenate([[0.0], np.cumsum(rng.exponential(gap,
                                                           len(specs) - 1))])
    return [float(a) for a in ats]


def run_service(tasks, arrivals, G: int, store, *, use_feedback: bool,
                delay_delta: float = 2.0):
    """One service session over the arrival trace. ``use_feedback=False``
    schedules every admission from the unscaled analytic worst case (the
    true analytic baseline) while still *recording* realized durations
    into ``store``; ``use_feedback=True`` scales admissions by the store's
    observed ratios."""
    svc = TuningService(total_gpus=G, delay_delta=delay_delta,
                        profile_store=store)
    for (spec, factory, meta), at in zip(tasks, arrivals):
        svc.submit_spec(spec, factory, at=at,
                        profile_key=(meta["arch"], meta["gpus"]),
                        scale_duration=use_feedback)
    report = svc.run_until_idle()
    est = {s.name: svc._meta[s.name].spec.duration for s, _, _ in tasks}
    return {
        "makespan_s": report.makespan,
        "utilization": report.utilization,
        "replans": report.replans,
        "plans_adopted": report.plans_adopted,
        "plans_rejected": report.plans_rejected,
        "arrival_events": sum(1 for e in report.events
                              if e.kind is EventKind.TASK_ARRIVED),
        "est_durations": {k: round(v, 4) for k, v in est.items()},
        "task_starts": {k: round(v, 4)
                        for k, v in report.task_starts.items()},
        "task_ends": {k: round(v, 4) for k, v in report.task_ends.items()},
    }


def run(mix, G: int, seed: int = 0) -> dict:
    tasks = build_workload(mix, seed)
    specs = [s for s, _, _ in tasks]
    factories = {s.name: f for s, f, _ in tasks}
    rng = np.random.default_rng(seed + 1)
    arrivals = poisson_arrivals(specs, rng)
    t_last = max(arrivals)

    # batch: wait for the full task set, then the static hindsight plan
    plan = solve(specs, G, "cp")
    static = execute_static(plan, G, factories)
    batch_mk = t_last + static.makespan
    batch_util = sum(static.gpu_busy) / (G * batch_mk)

    store = profiler.ProfileStore()
    analytic = run_service(tasks, arrivals, G, store, use_feedback=False)
    fedback = run_service([(s, f, m) for s, f, m in tasks],
                          arrivals, G, store, use_feedback=True)

    assert analytic["utilization"] >= batch_util - 1e-9, \
        "service mode regressed below batch utilization"
    moved = [n for n in analytic["task_starts"]
             if abs(analytic["task_starts"][n]
                    - fedback["task_starts"].get(n, -1.0)) > 1e-6]
    shrunk = [n for n in analytic["est_durations"]
              if fedback["est_durations"][n]
              < analytic["est_durations"][n] - 1e-9]
    assert shrunk, "profiler feedback did not change any duration estimate"

    return {
        "G": G,
        "seed": seed,
        "num_tasks": len(tasks),
        "arrivals": {s.name: round(a, 4)
                     for (s, _, _), a in zip(tasks, arrivals)},
        "t_last": round(t_last, 4),
        "tasks": [dict(meta, name=s.name,
                       est_duration_s=round(s.duration, 4))
                  for s, _, meta in tasks],
        "batch": {"makespan_s": batch_mk, "utilization": batch_util,
                  "hindsight_plan_makespan_s": static.makespan},
        "service_analytic": analytic,
        "service_fedback": fedback,
        "feedback_deviation": {
            "tasks_with_shrunk_estimate": shrunk,
            "tasks_with_moved_start": moved,
            "max_estimate_shrink_frac": max(
                (1.0 - fedback["est_durations"][n]
                 / analytic["est_durations"][n]) for n in shrunk),
        },
        "speedup_vs_batch": batch_mk / max(analytic["makespan_s"], 1e-12),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small 4-task instance (CI)")
    ap.add_argument("--gpus", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_service.json")
    args = ap.parse_args(argv)

    mix = SMOKE_MIX if args.smoke else FULL_MIX
    result = run(mix, args.gpus, args.seed)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    b, a, fb = (result["batch"], result["service_analytic"],
                result["service_fedback"])
    print(f"batch (wait for all)    : {b['makespan_s']:.3f}s "
          f"(util {b['utilization']:.2%})")
    print(f"service (analytic)      : {a['makespan_s']:.3f}s "
          f"(util {a['utilization']:.2%}, {a['replans']} replans)")
    print(f"service (fed-back)      : {fb['makespan_s']:.3f}s "
          f"(util {fb['utilization']:.2%}, {fb['replans']} replans)")
    dev = result["feedback_deviation"]
    print(f"feedback deviation      : {len(dev['tasks_with_shrunk_estimate'])}"
          f" estimates shrunk (max {dev['max_estimate_shrink_frac']:.0%}), "
          f"{len(dev['tasks_with_moved_start'])} starts moved")
    print(f"speedup vs batch        : {result['speedup_vs_batch']:.2f}x")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
