"""Paper Table 2: fused grouped-LoRA kernels vs per-adapter loops.

Three executions of the same multi-adapter LoRA training workload
(Llama-1B-class layer scaled to CPU size; 16 adapters, ranks 16/32/64
mixed, per-adapter BS in {1,2,4}):

  Fused      — slot-stacked grouped path (ONE grouped GEMM pair; the
               jnp einsum form that XLA compiles exactly like our Pallas
               schedule, O(1) launches)
  PerAdapter — the "PyTorch" baseline: base GEMM on the full batch, LoRA
               path looped per adapter (3N kernel launches)
  Sequential — each adapter trained alone (base GEMM not amortized)

Reported: wall time per fwd+bwd, and speedups (paper: 1.36-1.91x over
PyTorch, 2.5-5.1x over Sequential; gains grow as per-adapter BS shrinks).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit

Z = 16
S = 128
D_IN = 512
D_OUT = 1024
R_MAX = 64


def make_inputs(b, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (Z, b * S, D_IN), jnp.float32)
    A = 0.1 * jax.random.normal(ks[1], (Z, D_IN, R_MAX), jnp.float32)
    B = 0.1 * jax.random.normal(ks[2], (Z, R_MAX, D_OUT), jnp.float32)
    W = 0.1 * jax.random.normal(ks[3], (D_IN, D_OUT), jnp.float32)
    ranks = jnp.asarray([16, 32, 64] * (Z // 3) + [16] * (Z % 3))
    mask = (jnp.arange(R_MAX)[None] < ranks[:, None]).astype(jnp.float32)
    return x, A * mask[:, None, :], B * mask[:, :, None], W


def fused_step(x, A, B, W):
    def loss(AB):
        A_, B_ = AB
        y = jnp.einsum("ztd,do->zto", x, W)
        s = jnp.einsum("ztd,zdr->ztr", x, A_)
        y = y + 2.0 * jnp.einsum("ztr,zro->zto", s, B_)
        return jnp.sum(y * y)
    g = jax.grad(loss)((A, B))
    return g


def per_adapter_step(x, A, B, W):
    def loss(AB):
        A_, B_ = AB
        y = jnp.einsum("ztd,do->zto", x, W)       # base amortized
        outs = []
        for z in range(Z):                         # 2 launches per adapter
            s = x[z] @ A_[z]
            outs.append(y[z] + 2.0 * (s @ B_[z]))
        return sum(jnp.sum(o * o) for o in outs)
    return jax.grad(loss)((A, B))


def sequential_step(x, A, B, W):
    def loss(AB):
        A_, B_ = AB
        total = 0.0
        for z in range(Z):                         # base NOT amortized
            y = x[z] @ W
            s = x[z] @ A_[z]
            total = total + jnp.sum((y + 2.0 * (s @ B_[z])) ** 2)
        return total
    return jax.grad(loss)((A, B))


def run() -> None:
    for b in (1, 2, 4):
        x, A, B, W = make_inputs(b)
        fused = timeit(jax.jit(fused_step), x, A, B, W)
        per = timeit(jax.jit(per_adapter_step), x, A, B, W)
        seq = timeit(jax.jit(sequential_step), x, A, B, W)
        emit(f"table2/fused_bs{b}", fused,
             f"speedup_vs_peradapter={per / fused:.2f}x")
        emit(f"table2/peradapter_bs{b}", per, "")
        emit(f"table2/sequential_bs{b}", seq,
             f"fused_speedup_vs_sequential={seq / fused:.2f}x")


if __name__ == "__main__":
    run()
