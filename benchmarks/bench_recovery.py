"""Crash recovery cost: checkpoint resume vs restart-from-zero, plus a
chaos drill.

Section A (real engine): run one heterogeneous tuning task (ragged
widths, mixed TRUE ranks, more jobs than slots) three ways — an
uninterrupted reference, a run killed mid-flight after a fixed number of
durable ``SlotSnapshot`` checkpoints (``SimulatedCrash``), and a
``TuningService.recover`` session resumed from the dead run's
``state_dir``. Reports whether the recovered result is bitwise identical
to the reference (same ``best_job``, bit-identical ``best_val``), the
fraction of training steps the resume recomputed versus a from-zero
restart, and the wall times of both paths.

Section B (chaos drill, virtual cluster): a fault-injected simulated
workload where both the elastic runtime and the static baseline wrap the
SAME deterministic ``FaultyTaskDriver`` plans — checks every injected
fault was survived and elastic <= static held under injection — plus one
runtime-level ``inject_fault`` pod kill that requeues through the
suspend/resume path.

Emits BENCH_recovery.json. ``--smoke`` shrinks the task (CI artifact
job); the schema assertions CI applies are: ``recovered_bitwise`` true,
``recompute_frac < 0.5``, and at least one injected fault survived.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import shutil
import sys
import tempfile
import time

import numpy as np

from repro.checkpoint.taskstate import SimulatedCrash
from repro.configs.registry import get_arch
from repro.core import engine as alto
from repro.core.early_exit import EarlyExitConfig
from repro.core.service import TuningService
from repro.data.synthetic import make_task_dataset
from repro.sched.chaos import Fault, FaultPlan, FaultyTaskDriver, chaos_spec
from repro.sched.cluster import (ElasticClusterRuntime, SimulatedTaskDriver,
                                 execute_static, sim_task_spec)
from repro.sched.events import EventKind
from repro.sched.inter_task import solve

EE = EarlyExitConfig(warmup_ratio=0.2, select_ratio=0.5)
CHUNK_STEPS = 5                      # SimulatedTaskDriver default


def build_task(smoke: bool):
    cfg = dataclasses.replace(
        get_arch("paper-llama-tiny").reduced(num_layers=2, d_model=128,
                                             vocab=256),
        dtype="float32")
    ds = make_task_dataset("rec", cfg.vocab_size, seq_len=32, num_train=64,
                           num_val=16, difficulty=0.2)

    def mk():
        return alto.Task(model=cfg, dataset=ds, num_gpus=2,
                         max_steps=10 if smoke else 20, num_slots=2,
                         name="tenant-r",
                         search_space={"lr": [1e-3, 3e-3], "rank": [4, 8],
                                       "batch_size": [2, 4]})
    return mk


def bench_recovery(smoke: bool):
    mk = build_task(smoke)
    work = tempfile.mkdtemp(prefix="bench_recovery_")
    try:
        # uninterrupted reference (and the restart-from-zero cost model:
        # a crash without checkpoints re-pays this entire run)
        t0 = time.perf_counter()
        svc0 = TuningService(total_gpus=4, eval_every=2)
        res0 = svc0.submit(mk(), early_exit=EE).result()
        restart_wall = time.perf_counter() - t0
        drv0 = svc0._meta["tenant-r"].driver
        full_steps = drv0._steps
        # chunks are eval_every steps each (the checkpoint cadence)
        chunks_total = full_steps // 2

        # killed run: durable checkpoint every chunk, die ~60% through
        sd = os.path.join(work, "state")
        fail_after = max(int(0.6 * chunks_total), 1)
        svc1 = TuningService(total_gpus=4, eval_every=2, state_dir=sd,
                             ckpt_every=1)
        svc1._ckpt.fail_after["*"] = fail_after
        h1 = svc1.submit(mk(), early_exit=EE)
        crashed = False
        try:
            h1.result()
        except SimulatedCrash:
            crashed = True
        assert crashed, "fault injection never fired"
        saves = svc1._ckpt.saves["tenant-r"]

        # recover from the dead session's state_dir
        t1 = time.perf_counter()
        svc2 = TuningService.recover(sd, tasks=[(mk(), EE)])
        rep = svc2.run_until_idle()
        recovery_wall = time.perf_counter() - t1
        res2 = rep.task_results["tenant-r"]
        resumed_steps = svc2._meta["tenant-r"].driver._steps
        recovered = [e for e in rep.events
                     if e.kind is EventKind.TASK_RECOVERED]
        return {
            "recovered_bitwise": (res2.best_job == res0.best_job
                                  and float(res2.best_val)
                                  == float(res0.best_val)),
            "best_job_identical": res2.best_job == res0.best_job,
            "best_val": float(res0.best_val),
            "recompute_frac": resumed_steps / max(full_steps, 1),
            "resumed_steps": int(resumed_steps),
            "full_steps": int(full_steps),
            "checkpoints_written": int(saves),
            "crashed_after_chunks": int(fail_after),
            "chunks_total": int(chunks_total),
            "recovery_wall_s": round(recovery_wall, 3),
            "restart_wall_s": round(restart_wall, 3),
            "recovery_speedup": round(restart_wall
                                      / max(recovery_wall, 1e-9), 3),
            "task_recovered_events": [
                {"task": e.task, "reason": e.reason, "detail": e.detail}
                for e in recovered],
        }
    finally:
        shutil.rmtree(work, ignore_errors=True)


def bench_chaos(seed: int, G: int = 4):
    rng = np.random.default_rng(seed)
    defs = [dict(K=8, Z=4, total=60, warm=4, step_time=0.02, gpus=2),
            dict(K=6, Z=2, total=40, warm=3, step_time=0.03, gpus=1),
            dict(K=12, Z=4, total=80, warm=5, step_time=0.01, gpus=4),
            dict(K=4, Z=2, total=50, warm=2, step_time=0.025, gpus=2)]
    plan_faults = FaultPlan(faults={
        f"t{i}": tuple(
            Fault(at_progress=float(rng.uniform(
                0.0, kw["total"] * kw["step_time"])),
                  backoff=float(rng.uniform(0.0, 0.5)))
            for _ in range(int(rng.integers(1, 3))))
        for i, kw in enumerate(defs) if i % 2 == 0})

    def build_tasks():
        tasks = []
        for i, kw in enumerate(defs):
            name = f"t{i}"
            cb = CHUNK_STEPS * kw["step_time"]
            faults = plan_faults.for_task(name)
            spec = chaos_spec(
                sim_task_spec(name, K=kw["K"], Z=kw["Z"],
                              total_steps=kw["total"],
                              warmup_steps=kw["warm"],
                              step_time_s=kw["step_time"],
                              gpus=kw["gpus"]),
                faults, cb)

            def factory(name=name, kw=kw, faults=faults, cb=cb):
                inner = SimulatedTaskDriver(
                    name, K=kw["K"], Z=kw["Z"], total_steps=kw["total"],
                    warmup_steps=kw["warm"], step_time_s=kw["step_time"])
                return FaultyTaskDriver(name, inner, faults, cb)
            tasks.append((spec, factory))
        return tasks

    tasks = build_tasks()
    specs = [s for s, _ in tasks]
    plan = solve(specs, G, "cp")
    static = execute_static(plan, G, {s.name: f for s, f in tasks})
    rt = ElasticClusterRuntime(G)
    for s, f in build_tasks():
        rt.submit(s, f)
    elastic = rt.run(initial=plan)
    injected = sum(1 for e in elastic.events
                   if e.kind is EventKind.REPLICA_FAILED)
    survived = set(elastic.results) == {s.name for s, _ in tasks}

    # runtime-level pod kill: suspend + bounded-backoff requeue. Kill t0
    # halfway through its fault-free execution window (taken from a
    # baseline run, since the planned start depends on the solver).
    def build_plain():
        rt = ElasticClusterRuntime(G)
        for i, kw in enumerate(defs):
            name = f"t{i}"
            spec = sim_task_spec(name, K=kw["K"], Z=kw["Z"],
                                 total_steps=kw["total"],
                                 warmup_steps=kw["warm"],
                                 step_time_s=kw["step_time"],
                                 gpus=kw["gpus"])

            def factory(name=name, kw=kw):
                return SimulatedTaskDriver(
                    name, K=kw["K"], Z=kw["Z"], total_steps=kw["total"],
                    warmup_steps=kw["warm"], step_time_s=kw["step_time"])
            rt.submit(spec, factory)
        return rt

    base = build_plain().run()
    rt2 = build_plain()
    rt2.begin()
    rt2.inject_fault("t0", at=0.5 * (base.task_starts["t0"]
                                     + base.task_ends["t0"]), backoff=0.3)
    while rt2.step():
        pass
    rep2 = rt2.report()
    return {
        "faults_planned": plan_faults.total(),
        "faults_injected": int(injected),
        "all_tasks_survived": bool(survived),
        "elastic_makespan_s": round(elastic.makespan, 4),
        "static_makespan_s": round(static.makespan, 4),
        "elastic_le_static": elastic.makespan <= static.makespan + 1e-9,
        "pod_kills": int(rep2.pod_kills),
        "pod_kill_all_completed": len(rep2.results) == len(defs),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="smaller task (CI artifact job)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_recovery.json")
    args = ap.parse_args(argv)

    rec = bench_recovery(args.smoke)
    chaos = bench_chaos(args.seed)
    result = {"config": {"smoke": args.smoke, "seed": args.seed,
                         "gpus": 4, "eval_every": 2, "ckpt_every": 1},
              "recovery": rec, "chaos": chaos}
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"recovered bitwise       : {rec['recovered_bitwise']}")
    print(f"recompute fraction      : {rec['recompute_frac']:.2f} "
          f"({rec['resumed_steps']}/{rec['full_steps']} steps)")
    print(f"recovery vs restart     : {rec['recovery_wall_s']:.2f}s vs "
          f"{rec['restart_wall_s']:.2f}s "
          f"({rec['recovery_speedup']:.2f}x)")
    print(f"chaos faults survived   : {chaos['faults_injected']} "
          f"(elastic <= static: {chaos['elastic_le_static']})")
    print(f"pod kills recovered     : {chaos['pod_kills']}")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
