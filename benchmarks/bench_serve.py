"""Fused multi-LoRA serving vs per-adapter replicas, with hot publish.

The tune-to-serve tier decodes every resident adapter of an
``AdapterPool`` in ONE fused step (``Z x lanes`` streams through the
rank-bound serve step), where the classic deployment spins up one
replica per adapter and pays a full launch + step sequence each. This
bench pins down the serving-side claim:

1. **Aggregate throughput.** N adapters with mixed TRUE ranks, each with
   ``lanes`` requests: a fused pool (Z = N + 1, one slot kept free)
   serves them in one round, vs a per-adapter baseline that reuses ONE
   Z=1 replica — retire/publish between adapters, so the jit cache stays
   warm and the baseline pays no recompiles, only the N-fold step
   serialization. Both modes get an untimed warm-up round first; both
   must emit identical token counts. Fused must be >= 2x at N >= 8.

2. **Hot publish mid-decode.** During the fused round an (N+1)-th
   adapter is published via the ``on_step`` hook — between two fused
   decode steps, no replica restart — and its requests are served in the
   next round. Publish latency is its own headline metric (percentiles
   over every publish in the run); both modes' decode tok/s exclude
   publish time (the fused wall INCLUDING its in-round publish is still
   reported as ``wall_s``).

3. **Bitwise isolation.** A fused round's per-slot logits and greedy
   tokens must equal a solo run of the same adapter in the same-Z pool
   (slot isolation on the jnp backend) — serving fidelity is exact, not
   approximate.

Emits BENCH_serve.json. ``--smoke`` shortens prompts + decode lengths
(CI artifact) but keeps N >= 8 so the speedup gate still binds. The
backbone is dispatch-bound tiny on purpose: the fused win IS the
per-step launch amortization (one fused step serves N+1 adapters), the
regime small-batch multi-LoRA decode lives in.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys

import jax
import numpy as np

from repro.configs.registry import get_arch
from repro.data.synthetic import make_task_dataset
from repro.models import model as M
from repro.serve import (AdapterPool, ServeRequest, ServingFrontend,
                         ServingReplica)

RANK_CYCLE = (2, 4, 8)        # mixed TRUE ranks across the adapter set
HOT_STEP = 2                  # fused decode step before which the hot
                              # publish lands


def build_cfg():
    cfg = get_arch("paper-llama-tiny").reduced(num_layers=2, d_model=64,
                                               vocab=128)
    return dataclasses.replace(cfg, dtype="float32")


def make_adapters(cfg, n: int, seed: int):
    """n noisy adapters ([L,...] trees) with ranks cycling RANK_CYCLE —
    nonzero B so the LoRA delta actually moves logits."""
    pool = AdapterPool(cfg, 1)
    ranks, adapters = [], []
    keys = jax.random.split(jax.random.PRNGKey(seed + 1), n)
    for i in range(n):
        r = min(RANK_CYCLE[i % len(RANK_CYCLE)], cfg.lora.r_max)
        sub = jax.random.split(keys[i], 64)
        k_iter = iter(range(64))
        adapter = jax.tree_util.tree_map(
            lambda x: 0.1 * jax.random.normal(
                sub[next(k_iter)], x[:, 0].shape, x.dtype),
            pool.lora)
        ranks.append(r)
        adapters.append(adapter)
    return adapters, ranks


def _reset(rep: ServingReplica) -> None:
    rep.total_generated = 0
    rep.total_decode_steps = 0
    rep.total_wall_s = 0.0
    rep.rounds = 0


def run_fused(cfg, params, adapters, ranks, prompts, lanes, max_new,
              repeats) -> dict:
    """All N adapters in one Z=N+1 pool; the (N+1)-th hot-published
    mid-decode via the on_step hook, served in the following round. The
    workload is measured ``repeats`` times (retiring the hot adapter in
    between so every repeat hot-publishes it again); the best repeat is
    the headline (min wall filters scheduler noise on shared hosts)."""
    n = len(adapters) - 1                      # last adapter is the hot one
    pool = AdapterPool(cfg, n + 1)
    rep = ServingReplica(cfg, params, pool, lanes=lanes,
                         max_len=prompts.shape[-1] + max_new)
    fe = ServingFrontend(rep)
    for z in range(n):
        fe.publish(f"adapter-{z}", adapters[z], ranks[z])

    # warm-up (compiles prefill + decode for the round shapes), untimed
    for i in range(lanes):
        fe.submit("adapter-0", prompts[0, i], max_new)
    fe.step_round()

    def hook(step: int) -> None:
        if step == HOT_STEP:
            fe.publish(f"adapter-{n}", adapters[n], ranks[n])

    best = None
    for _ in range(repeats):
        _reset(rep)
        for z in range(n):
            for i in range(lanes):
                fe.submit(f"adapter-{z}", prompts[z, i], max_new)
        n_pub = len(pool.publish_latencies_s)
        fe.step_round(on_step=hook)            # hot publish inside the round
        hot_s = pool.publish_latencies_s[n_pub]
        for i in range(lanes):
            fe.submit(f"adapter-{n}", prompts[n, i], max_new)
        fe.step_round()
        # decode tok/s excludes the in-round publish (publish latency is
        # its own metric below); the wall including it is still reported
        decode_s = rep.total_wall_s - hot_s
        if best is None or decode_s < best["_decode_s"]:
            best = {"_decode_s": decode_s,
                    "generated": rep.total_generated,
                    "decode_steps": rep.total_decode_steps,
                    "rounds": rep.rounds,
                    "wall_s": rep.total_wall_s,
                    "hot_publish_s": hot_s}
        fe.retire(f"adapter-{n}")              # next repeat re-publishes it
    assert fe.hot_publishes >= repeats, "hot publish hook never landed"
    decode_s = best.pop("_decode_s")
    best["aggregate_tok_s"] = best["generated"] / max(decode_s, 1e-9)
    best.update(pool_slots=pool.Z, hot_publishes=fe.hot_publishes,
                repeats=repeats,
                _latencies=list(pool.publish_latencies_s))
    return best


def run_per_adapter(cfg, params, adapters, ranks, prompts, lanes,
                    max_new, repeats) -> dict:
    """Classic deployment: one adapter resident at a time on a Z=1
    replica. The replica object is REUSED (retire/publish between
    adapters) so the baseline keeps a warm jit cache and pays only the
    N-fold step serialization, not recompiles; its publishes happen
    BETWEEN rounds and are excluded from its decode wall (generous to
    the baseline). Best of ``repeats``, like the fused mode."""
    pool = AdapterPool(cfg, 1)
    rep = ServingReplica(cfg, params, pool, lanes=lanes,
                         max_len=prompts.shape[-1] + max_new)
    fe = ServingFrontend(rep)

    fe.publish("warm", adapters[0], ranks[0])
    for i in range(lanes):
        fe.submit("warm", prompts[0, i], max_new)
    fe.step_round()
    fe.retire("warm")

    best = None
    for _ in range(repeats):
        _reset(rep)
        for z in range(len(adapters)):
            fe.publish(f"adapter-{z}", adapters[z], ranks[z])
            for i in range(lanes):
                fe.submit(f"adapter-{z}", prompts[z, i], max_new)
            fe.step_round()
            fe.retire(f"adapter-{z}")
        if best is None or rep.total_wall_s < best["wall_s"]:
            best = {"aggregate_tok_s": rep.aggregate_tok_s,
                    "generated": rep.total_generated,
                    "decode_steps": rep.total_decode_steps,
                    "rounds": rep.rounds,
                    "wall_s": rep.total_wall_s}
    best.update(repeats=repeats, _latencies=list(pool.publish_latencies_s))
    return best


def run_bitwise(cfg, params, adapters, ranks, prompts, lanes,
                max_new) -> dict:
    """Fused round vs same-Z solo round for adapter 0: slot-0 logits at
    every consumed position and the greedy tokens must be identical."""
    n = len(adapters)
    max_len = prompts.shape[-1] + max_new

    def run(publish_slots):
        pool = AdapterPool(cfg, n)
        rep = ServingReplica(cfg, params, pool, lanes=lanes,
                             max_len=max_len)
        reqs = []
        for z in publish_slots:
            pool.publish(f"adapter-{z}", adapters[z], ranks[z], slot=z)
            for i in range(lanes):
                reqs.append(ServeRequest(request_id=f"{z}-{i}",
                                         adapter_id=f"adapter-{z}",
                                         prompt=prompts[z, i],
                                         max_new=max_new))
        stats = rep.serve_round(reqs, record_logits=True)
        toks = {r.request_id: list(r.tokens) for r in reqs}
        return stats, toks

    fused_stats, fused_toks = run(range(n))
    solo_stats, solo_toks = run([0])
    toks_ok = all(fused_toks[f"0-{i}"] == solo_toks[f"0-{i}"]
                  for i in range(lanes))
    logits_ok = (len(fused_stats.logits) == len(solo_stats.logits)
                 and all(tf == ts and (lf[0] == ls[0]).all()
                         for (tf, lf), (ts, ls)
                         in zip(fused_stats.logits, solo_stats.logits)))
    assert toks_ok, "fused greedy tokens differ from solo"
    assert logits_ok, "fused slot-0 logits differ from solo"
    return {"fused_vs_solo_tokens_identical": bool(toks_ok),
            "fused_vs_solo_logits_identical": bool(logits_ok),
            "compared_positions": len(fused_stats.logits)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small instance (CI); keeps N >= 8")
    ap.add_argument("--adapters", type=int, default=8,
                    help="N tuned adapters (plus one hot-published)")
    ap.add_argument("--lanes", type=int, default=None,
                    help="decode streams per adapter (default 2)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeats", type=int, default=5,
                    help="measured repeats per mode; best wall wins")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    n = args.adapters
    lanes = args.lanes or 2
    P, max_new = (6, 16) if args.smoke else (8, 24)
    cfg = build_cfg()
    params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    adapters, ranks = make_adapters(cfg, n + 1, args.seed)
    ds = make_task_dataset("bench-serve", cfg.vocab_size, seq_len=P,
                           num_train=(n + 1) * lanes, difficulty=0.3,
                           seed=args.seed)
    prompts = ds.train[:(n + 1) * lanes, :P].astype(np.int32) \
        .reshape(n + 1, lanes, P)

    fused = run_fused(cfg, params, adapters, ranks, prompts, lanes, max_new,
                      args.repeats)
    base = run_per_adapter(cfg, params, adapters, ranks, prompts, lanes,
                           max_new, args.repeats)
    assert fused["generated"] == base["generated"], \
        "fused and per-adapter modes served different token counts"
    speedup = fused["aggregate_tok_s"] / max(base["aggregate_tok_s"], 1e-12)
    if n >= 8:
        assert speedup >= 2.0, \
            f"fused serving speedup {speedup:.2f}x < 2x at N={n}"

    lat = np.asarray(fused.pop("_latencies") + base.pop("_latencies"))
    bitwise = run_bitwise(cfg, params, adapters[:min(n, 3) + 1],
                          ranks[:min(n, 3) + 1], prompts, lanes, max_new)

    result = {
        "config": {"arch": cfg.name, "adapters": n, "lanes": lanes,
                   "prompt_len": P, "max_new": max_new,
                   "ranks": ranks[:-1], "hot_rank": ranks[-1],
                   "seed": args.seed, "smoke": bool(args.smoke)},
        "fused": fused,
        "per_adapter": base,
        "speedup": speedup,
        "publish_latency_s": {
            "count": int(lat.size),
            "p50": float(np.percentile(lat, 50)),
            "p95": float(np.percentile(lat, 95)),
            "max": float(lat.max()),
        },
        "bitwise": bitwise,
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"fused      : {fused['aggregate_tok_s']:.1f} tok/s over "
          f"{fused['rounds']} rounds / {fused['decode_steps']} steps "
          f"({fused['hot_publishes']} hot publish)")
    print(f"per-adapter: {base['aggregate_tok_s']:.1f} tok/s over "
          f"{base['rounds']} rounds / {base['decode_steps']} steps")
    print(f"speedup    : {speedup:.2f}x aggregate decode (N={n}, "
          f"lanes={lanes})")
    print(f"publish    : p50 {result['publish_latency_s']['p50'] * 1e3:.2f}ms "
          f"p95 {result['publish_latency_s']['p95'] * 1e3:.2f}ms "
          f"over {lat.size} publishes")
    print("bitwise    : fused slot-0 == solo "
          f"({bitwise['compared_positions']} positions)")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
