"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME]

Emits ``name,us_per_call,derived`` CSV rows. Mapping to the paper:
  bench_kernels             Table 2  (fused grouped GEMM vs loops)
  bench_adapter_parallelism Fig. 13  (AP vs FSDP, compiled artifacts)
  bench_early_exit          Figs. 14/15 (savings per pattern, quality)
  bench_warmup_sensitivity  Figs. 7/16  (warmup ranking reliability)
  bench_scheduler           Figs. 5/12  (SJF vs CP; B/S/EE ablation)
  bench_e2e_speedup         Figs. 9/11  (end-to-end ALTO speedup)
  bench_roofline            §Roofline   (per-arch dry-run terms)
"""
from __future__ import annotations

import argparse
import sys
import traceback

BENCHES = [
    "bench_kernels",
    "bench_warmup_sensitivity",
    "bench_scheduler",
    "bench_early_exit",
    "bench_e2e_speedup",
    "bench_dpo",
    "bench_adapter_parallelism",
    "bench_roofline",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failed = []
    for name in BENCHES:
        if args.only and args.only != name:
            continue
        print(f"# --- {name} ---")
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            mod.run()
        except Exception:  # noqa: BLE001 — keep the harness going
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"# FAILED: {failed}")
        sys.exit(1)
    print("# all benchmarks complete")


if __name__ == "__main__":
    main()
