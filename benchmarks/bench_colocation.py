"""Cross-task co-location vs exclusive placement on a heterogeneous mix.

The paper's central systems claim: concurrent tuning jobs over a SHARED
frozen backbone expose optimizations single-job designs cannot — the
fused grouped GEMM can co-locate surviving adapters from *different
tasks* to reclaim freed capacity (mLoRA-style multiplexing). This bench
quantifies the claim end to end, in two parts:

1. **Cluster A/B (virtual time).** A heterogeneous small-task mix — one
   long fusable host task, exclusive hog tasks pinning the remaining
   GPUs, and a stream of small same-fuse-key tasks — is executed through
   the elastic runtime twice: ``colocate=False`` (exclusive placement:
   small tasks queue for free GPUs) and ``colocate=True`` (pending small
   tasks fuse onto the live host replica the moment §A.3 cross-task
   admission accepts them). Reported: makespan, effective cluster
   utilization (identical per-task work area over G x makespan — the
   same work, delivered in less GPU-time), replica occupancy, and the
   fused-task map. Per-task results must be identical in both runs
   (co-location changes *when* work runs, never *what* it computes).

2. **Isolation check (real training).** Two small tasks run on one real
   ``SharedBackboneExecutor`` — co-located — and each alone; per-task
   best-val losses must match exactly (the loss-isolation property the
   tentpole relies on, tests/test_lora_isolation.py proves bitwise).

Emits BENCH_colocation.json. ``--smoke`` shrinks the mix (CI artifact).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys

import jax
import numpy as np

from repro.configs.base import TrainConfig
from repro.configs.registry import get_arch
from repro.core.early_exit import EarlyExitConfig
from repro.core.executor import (SharedBackboneExecutor, TaskLifecycle,
                                 run_colocated)
from repro.data.synthetic import SlotBatcher, make_task_dataset
from repro.models import model as M
from repro.sched import profiler
from repro.sched.cluster import (ElasticClusterRuntime, SimulatedTaskDriver,
                                 execute_static, sim_colo_spec,
                                 sim_task_spec)
from repro.sched.events import EventKind
from repro.sched.inter_task import solve

FUSE_ARCH = "stablelm-3b"          # the shared-backbone family (1 GPU)
HOG_MIX = [("glm4-9b", 2), ("granite-8b", 1)]


def build_workload(num_small: int, seed: int = 0):
    """(spec, factory, colo) triples: one fusable host, exclusive hogs,
    and a stream of small fusable tasks that exclusive placement must
    queue behind busy GPUs. ``seed`` jitters the budgets (small-task
    sizes, host length) so robustness of the speedup is checkable."""
    rng = np.random.default_rng(seed)
    cfg = get_arch(FUSE_ARCH)
    st_host = profiler.profile_task(cfg, 8, 4, 1024, 1).step_time_s
    st_small = profiler.profile_task(cfg, 2, 4, 1024, 1).step_time_s
    fuse_key = (FUSE_ARCH, 1, 4, 1024, "sft")
    tasks = []

    def sim(name, *, K, Z, total, warm, step_time, gpus, colo):
        spec = sim_task_spec(name, K=K, Z=Z, total_steps=total,
                             warmup_steps=warm, step_time_s=step_time,
                             gpus=gpus)

        def factory(name=name, K=K, Z=Z, total=total, warm=warm,
                    step_time=step_time):
            return SimulatedTaskDriver(name, K=K, Z=Z, total_steps=total,
                                       warmup_steps=warm,
                                       step_time_s=step_time)
        return (spec, factory, colo)

    # host: Z=8 slots; Pattern-3 keeps top 2 of 8, so 6 replica slots
    # free the moment the warmup boundary passes
    host_total = int(rng.integers(1100, 1400))
    host_warm = host_total // 20
    host = sim("host", K=8, Z=8, total=host_total, warm=host_warm,
               step_time=st_host, gpus=1,
               colo=sim_colo_spec(fuse_key, K=8, Z=8))
    tasks.append(host)
    host_dur = host[0].duration
    # hogs: other archs, exclusive, pin the remaining GPUs until just
    # before the host ends — exclusive small tasks must queue behind them
    for arch, gpus in HOG_MIX:
        hcfg = get_arch(arch)
        st = profiler.profile_task(hcfg, 4, 4, 1024, gpus).step_time_s
        warm = 50
        # K=16 on Z=4: lifecycle steps = 3*warm + total (4 waves + top-4
        # continue); invert for a duration ~0.97x the host's
        total = max(int(0.97 * host_dur / st) - 3 * warm, warm + 10)
        tasks.append(sim(f"hog-{arch}", K=16, Z=4, total=total, warm=warm,
                         step_time=st, gpus=gpus, colo=None))
    # small tasks: same fuse key, short budgets — the co-location payload
    for i in range(num_small):
        total = int(rng.integers(350, 850))
        tasks.append(sim(f"small-{i}", K=2, Z=2, total=total,
                         warm=max(total // 20, 1), step_time=st_small,
                         gpus=1, colo=sim_colo_spec(fuse_key, K=2, Z=2)))
    return tasks


def run_cluster(tasks, G: int) -> dict:
    specs = [s for s, _, _ in tasks]
    plan = solve(specs, G, "cp")
    plan.validate(G)
    static = execute_static(plan, G, {s.name: f for s, f, _ in tasks})

    out = {}
    for mode, colocate in (("exclusive", False), ("colocated", True)):
        rt = ElasticClusterRuntime(G, colocate=colocate)
        for s, f, c in tasks:
            rt.submit(s, f, colo=c)
        rep = rt.run(initial=plan)
        assert rep.makespan <= static.makespan + 1e-9, \
            f"{mode} elastic regressed past the static plan"
        out[mode] = rep

    excl, colo = out["exclusive"], out["colocated"]
    # identical work, attributed identically, in both runs
    assert excl.results == colo.results, "co-location changed task results"
    assert colo.colocated, "no task fused — workload does not exercise " \
        "co-location"
    assert colo.makespan < excl.makespan - 1e-9, \
        "co-location did not improve the makespan"

    # effective utilization: the same per-task work area (realized solo
    # durations x gpus, taken from the exclusive run) over G x makespan —
    # how densely each strategy packs identical work
    area = sum((excl.task_ends[s.name] - excl.task_starts[s.name]) * s.gpus
               for s, _, _ in tasks)

    def report(rep) -> dict:
        return {
            "makespan_s": rep.makespan,
            "utilization_effective": area / (len(rep.gpu_busy)
                                             * rep.makespan),
            "gpu_occupancy": rep.utilization,
            "replans": rep.replans,
            "task_starts": {k: round(v, 4)
                            for k, v in rep.task_starts.items()},
            "task_ends": {k: round(v, 4) for k, v in rep.task_ends.items()},
            "fused_tasks": dict(rep.colocated),
            "fuse_events": sum(1 for e in rep.events
                               if e.kind is EventKind.TASK_FUSED),
        }

    excl_r, colo_r = report(excl), report(colo)
    assert colo_r["utilization_effective"] > \
        excl_r["utilization_effective"] + 1e-9, \
        "co-location did not lift effective utilization"
    return {
        "G": G,
        "num_tasks": len(tasks),
        "tasks": [{"name": s.name, "gpus": s.gpus,
                   "est_duration_s": round(s.duration, 4),
                   "fusable": c is not None} for s, _, c in tasks],
        "static_plan_makespan_s": static.makespan,
        "exclusive": excl_r,
        "colocated": colo_r,
        "speedup": excl.makespan / max(colo.makespan, 1e-12),
    }


def run_isolation_check() -> dict:
    """Real training: two tasks fused on one SharedBackboneExecutor vs
    each alone — per-task best-val losses must be identical."""
    cfg = dataclasses.replace(
        get_arch("paper-llama-tiny").reduced(num_layers=2, d_model=64,
                                             vocab=128), dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    datasets = {
        "A": make_task_dataset("col-a", cfg.vocab_size, seq_len=16,
                               num_train=32, num_val=8, difficulty=0.2,
                               seed=1),
        "B": make_task_dataset("col-b", cfg.vocab_size, seq_len=16,
                               num_train=32, num_val=8, difficulty=0.6,
                               seed=2),
    }

    seeds = {"A": 3, "B": 4}     # per task, not per position: a task's
                                 # streams/keys must not depend on tenancy

    def run(names):
        ex = SharedBackboneExecutor(cfg, params, Z=4, per_adapter_batch=2,
                                    eval_every=2, seed=0)
        lcs = []
        for name in names:
            jobs = {f"{name}/j{k}": TrainConfig(
                learning_rate=lr, lora_rank=4, max_steps=8)
                for k, lr in enumerate((3e-3, 1e-3))}
            lcs.append(TaskLifecycle(
                ex, name, jobs, 8,
                ee=EarlyExitConfig(warmup_ratio=0.25, select_ratio=1.0),
                max_slots=2,
                batcher=SlotBatcher(datasets[name], 2, 2,
                                    seed=seeds[name]),
                seed=seeds[name]))
        return run_colocated(ex, lcs)

    fused = run(["A", "B"])
    solo = {name: run([name])[name] for name in ("A", "B")}
    out = {}
    for name in ("A", "B"):
        identical = fused[name].best_val == solo[name].best_val
        out[name] = {"solo_best_val": solo[name].best_val,
                     "fused_best_val": fused[name].best_val,
                     "identical": identical}
        assert identical, f"co-location perturbed task {name}'s losses"
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small instance (CI)")
    ap.add_argument("--gpus", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_colocation.json")
    args = ap.parse_args(argv)

    tasks = build_workload(num_small=6 if args.smoke else 12,
                           seed=args.seed)
    result = run_cluster(tasks, args.gpus)
    result["isolation"] = run_isolation_check()

    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    e, c = result["exclusive"], result["colocated"]
    print(f"exclusive makespan : {e['makespan_s']:.3f}s "
          f"(eff util {e['utilization_effective']:.2%})")
    print(f"colocated makespan : {c['makespan_s']:.3f}s "
          f"(eff util {c['utilization_effective']:.2%}, "
          f"{c['fuse_events']} tasks fused onto "
          f"{len(set(c['fused_tasks'].values()))} replica(s))")
    print(f"speedup            : {result['speedup']:.2f}x")
    iso = result["isolation"]
    print("isolation          : " + ", ".join(
        f"{n} best_val {v['fused_best_val']:.4f} "
        f"({'identical' if v['identical'] else 'DIFFERS'})"
        for n, v in iso.items()))
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
