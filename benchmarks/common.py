"""Benchmark utilities: timing + CSV emission."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax

ROWS: List[Tuple[str, float, str]] = []


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median-of-iters wall time (seconds) of a jitted callable."""
    out = None
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, seconds: float, derived: str = "") -> None:
    """Print one CSV row: name,us_per_call,derived."""
    ROWS.append((name, seconds * 1e6, derived))
    print(f"{name},{seconds * 1e6:.1f},{derived}")
