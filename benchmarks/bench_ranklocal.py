"""Rank-local grouped GEMM vs rank-masked execution on a RANK-SWEEP mix.

Rank is the single most-tuned LoRA hyperparameter, so a tuning workload
naturally sweeps r = 4..64 — but the zero-masked (§A.1 padded) execution
bills every slot at r_max: a rank-4 adapter co-located with a rank-64 one
pays 16x its true FLOPs in all six grouped GEMMs, and the §A.3 memory
model budgets replicas as if every slot were r_max wide. The rank-local
path makes rank a per-slot compute dimension (dead rank tiles skip the
MXU) and the §A.3 budget rank-aware (rank-weighted FLOP-tokens at TRUE
ranks). This bench quantifies both effects:

1. **Cluster A/B/C (virtual time).** One long fusable host, exclusive hog
   tasks pinning the remaining GPUs, and a stream of small fusable tasks
   sweeping ranks {4, 8, 16, 32, 64}, run three ways: ``exclusive`` (no
   fusion), ``rankmasked`` (fusion with every task CHARGED r_max by the
   memory model and STEPPED at r_max cost — the padded execution), and
   ``ranklocal`` (true-rank §A.3 charges + true-rank step times). Task
   results must be identical in all three; rank-local must beat
   rank-masked on makespan AND effective utilization.

2. **Isolation check (real training).** Tasks with DIFFERENT true ranks
   fused on one real ``SharedBackboneExecutor`` vs each alone: loss
   histories bitwise identical, best-vals equal.

3. **Kernel check.** Concrete full-rank rank-local calls bitwise-equal
   the dense kernels; wall-time of the interpret-mode fwd+VJP on a
   mixed-rank stack is reported for observability (interpret mode runs
   the grid as a host loop, so treat it as a smoke signal, not a TPU
   projection), alongside the adapter-GEMM FLOP ratio from the roofline
   accounting (the MXU work the dead-tile skip reclaims).

Emits BENCH_ranklocal.json. ``--smoke`` shrinks the mix (CI artifact).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.configs.registry import get_arch
from repro.core.early_exit import EarlyExitConfig
from repro.core.executor import (SharedBackboneExecutor, TaskLifecycle,
                                 run_colocated)
from repro.data.synthetic import SlotBatcher, make_task_dataset
from repro.kernels.grouped_lora import ops as kops
from repro.models import model as M
from repro.roofline.analysis import ranklocal_savings
from repro.sched import profiler
from repro.sched.cluster import (ElasticClusterRuntime, SimulatedTaskDriver,
                                 execute_static, sim_colo_spec,
                                 sim_task_spec)
from repro.sched.events import EventKind
from repro.sched.inter_task import solve
from repro.sched.intra_task import MemoryModel

FUSE_ARCH = "stablelm-3b"          # the shared-backbone family (1 GPU)
HOG_MIX = [("glm4-9b", 2), ("granite-8b", 1)]
SEQ = 1024
R_MAX = 64
RANK_SWEEP = (4, 8, 16, 32, 64)    # the rank-sweep payload, cycling
HOST_RANK = 16
RELAXED_KEY = (FUSE_ARCH, 1, "sft")

# replica memory model: token term + rank-weighted FLOP-token term (k2 =
# one token-equivalent per 8 rank units, so a rank-8 slot doubles its
# token charge and a rank-64 slot pays 9x). Rank-masked mode charges
# every request r_max=64 — the padded §A.3 accounting this PR replaces —
# under which the host replica can carry at most ONE guest at a time,
# while true-rank charges fit the whole rank sweep concurrently.
MEM = MemoryModel(k0=0.0, k1=1.0, seq_len=SEQ, capacity=150_000,
                  safety_margin=0.9, k2=1.0 / 8, r_max=R_MAX)


def step_time(cfg, Z: int, b: int, rank: int, gpus: int) -> float:
    """Fused-step seconds with every slot at ``rank`` (the §A.3 rank-aware
    cost model; rank-masked execution bills r_max)."""
    return profiler.fused_step_time(cfg, [b * SEQ] * Z, [rank] * Z, gpus)


def build_workload(num_small: int, seed: int = 0):
    """(spec, factory, colo, true_rank) tuples with RELAXED width-free
    keys; ``run_cluster`` rewrites rank charges + step times per mode."""
    rng = np.random.default_rng(seed)
    cfg = get_arch(FUSE_ARCH)
    tasks = []

    def sim(name, *, K, Z, total, warm, st, gpus, colo, rank):
        spec = sim_task_spec(name, K=K, Z=Z, total_steps=total,
                             warmup_steps=warm, step_time_s=st, gpus=gpus)

        def factory(name=name, K=K, Z=Z, total=total, warm=warm, st=st):
            return SimulatedTaskDriver(name, K=K, Z=Z, total_steps=total,
                                       warmup_steps=warm, step_time_s=st)
        return (spec, factory, colo, rank)

    # host: Z=8 slots at rank 16; Pattern-3 keeps top 2 of 8
    st_host = step_time(cfg, 8, 4, HOST_RANK, 1)
    host_total = int(rng.integers(1100, 1400))
    host = sim("host", K=8, Z=8, total=host_total, warm=host_total // 20,
               st=st_host, gpus=1, rank=HOST_RANK,
               colo=sim_colo_spec(RELAXED_KEY, K=8, Z=8,
                                  per_adapter_batch=4, seq_len=SEQ,
                                  replica_slots=16, mem=MEM,
                                  lora_rank=HOST_RANK))
    tasks.append(host)
    host_dur = host[0].duration
    # hogs: other archs, exclusive, pin the remaining GPUs
    for arch, gpus in HOG_MIX:
        hcfg = get_arch(arch)
        st = profiler.profile_task(hcfg, 4, 4, SEQ, gpus).step_time_s
        warm = 50
        total = max(int(0.97 * host_dur / st) - 3 * warm, warm + 10)
        tasks.append(sim(f"hog-{arch}", K=16, Z=4, total=total, warm=warm,
                         st=st, gpus=gpus, colo=None, rank=0))
    # small tasks: the rank sweep — uniform width, heterogeneous TRUE
    # rank. Each runs ~1/4 of the host's lifetime: under true-rank
    # charges the whole sweep co-trains inside the host window, while
    # r_max-masked charges serialize the replica to ONE guest at a time,
    # spilling the rest past the hogs onto the exclusive tail.
    for i in range(num_small):
        r = RANK_SWEEP[i % len(RANK_SWEEP)]
        total = int(rng.integers(2300, 3100))
        tasks.append(sim(f"small-r{r}-{i}", K=2, Z=2, total=total,
                         warm=max(total // 20, 1),
                         st=step_time(cfg, 2, 2, r, 1), gpus=1, rank=r,
                         colo=sim_colo_spec(RELAXED_KEY, K=2, Z=2,
                                            per_adapter_batch=2, seq_len=SEQ,
                                            lora_rank=r)))
    return tasks


def _with_mode(tasks, mode: str):
    """exclusive: drop colo; rankmasked: strip true ranks (every request
    billed r_max) and step at r_max cost; ranklocal: as built (true-rank
    charges + true-rank step times). Exclusive also steps at r_max cost —
    it IS the padded execution, just unfused."""
    cfg = get_arch(FUSE_ARCH)
    out = []
    for spec, factory, colo, rank in tasks:
        if colo is not None:
            if mode == "ranklocal":
                out.append((spec, factory, colo))
                continue
            # padded execution: r_max step time for host + smalls
            st = step_time(cfg, colo.slots_needed, colo.per_adapter_batch,
                           R_MAX, 1)

            def factory_masked(st=st, f=factory):
                drv = f()
                drv.step_time_s = st
                return drv
            steps_spec = spec.duration / factory().step_time_s
            spec = dataclasses.replace(spec, duration=steps_spec * st)
            colo = None if mode == "exclusive" else dataclasses.replace(
                colo, lora_rank=None)
            out.append((spec, factory_masked, colo))
        else:
            out.append((spec, factory, colo))
    return out


def _solo_area(tasks_mode) -> float:
    """Sum of (solo realized duration x gpus) under this mode's step
    times — the work area effective utilization normalizes."""
    area = 0.0
    for spec, factory, _ in tasks_mode:
        drv = factory()
        drv.start(0.0)
        dur = 0.0
        while True:
            chunk = drv.step_chunk()
            dur += chunk.dt
            if chunk.done:
                break
        area += dur * spec.gpus
    return area


def run_cluster(tasks, G: int) -> dict:
    out = {}
    areas = {}
    for mode in ("exclusive", "rankmasked", "ranklocal"):
        tm = _with_mode(tasks, mode)
        specs = [s for s, _, _ in tm]
        plan = solve(specs, G, "cp")
        plan.validate(G)
        static = execute_static(plan, G, {s.name: f for s, f, _ in tm})
        rt = ElasticClusterRuntime(G, colocate=(mode != "exclusive"))
        for s, f, c in tm:
            rt.submit(s, f, colo=c)
        rep = rt.run(initial=plan)
        assert rep.makespan <= static.makespan + 1e-9, \
            f"{mode} elastic regressed past the static plan"
        out[mode] = rep
        areas[mode] = _solo_area(tm)
        if mode == "exclusive":
            static_mk = static.makespan

    excl, mask, local = out["exclusive"], out["rankmasked"], out["ranklocal"]
    # identical work, attributed identically, across all three strategies
    assert excl.results == mask.results == local.results, \
        "rank budgeting strategy changed task results"
    assert local.colocated, "ranklocal mode fused nothing"
    extra = {n for n in local.colocated if n not in mask.colocated}
    assert extra, "no extra low-rank guest fused — the rank budget is idle"
    assert local.makespan < mask.makespan - 1e-9, \
        "rank-local did not beat rank-masked execution"
    assert mask.makespan <= excl.makespan + 1e-9

    def report(mode, rep) -> dict:
        return {
            "makespan_s": rep.makespan,
            "utilization_effective": areas[mode] / (len(rep.gpu_busy)
                                                    * rep.makespan),
            "gpu_occupancy": rep.utilization,
            "replans": rep.replans,
            "fused_tasks": dict(rep.colocated),
            "fuse_events": sum(1 for e in rep.events
                               if e.kind is EventKind.TASK_FUSED),
            "task_starts": {k: round(v, 4)
                            for k, v in rep.task_starts.items()},
            "task_ends": {k: round(v, 4) for k, v in rep.task_ends.items()},
        }

    excl_r = report("exclusive", excl)
    mask_r = report("rankmasked", mask)
    local_r = report("ranklocal", local)
    assert local_r["utilization_effective"] > \
        mask_r["utilization_effective"] + 1e-9, \
        "rank-local did not lift effective utilization past rank-masked"
    cfg = get_arch(FUSE_ARCH)
    st_masked = step_time(cfg, 2, 2, R_MAX, 1)
    return {
        "G": G,
        "num_tasks": len(tasks),
        "tasks": [{"name": s.name, "gpus": s.gpus,
                   "est_duration_s": round(s.duration, 4),
                   "lora_rank": (r if c is not None else None),
                   "fusable": c is not None}
                  for s, _, c, r in tasks],
        "static_plan_makespan_s": static_mk,
        "exclusive": excl_r,
        "rankmasked": mask_r,
        "ranklocal": local_r,
        "speedup_vs_exclusive": excl.makespan / max(local.makespan, 1e-12),
        "speedup_vs_rankmasked": mask.makespan / max(local.makespan, 1e-12),
        "step_time": {
            "small_rankmasked_s": st_masked,
            "small_by_rank_s": {r: step_time(cfg, 2, 2, r, 1)
                                for r in RANK_SWEEP},
        },
        "adapter_flops_speedup": ranklocal_savings(
            cfg, RANK_SWEEP, tokens_per_slot=2 * SEQ).flop_saving,
    }


def run_isolation_check() -> dict:
    """Real training: tasks with DIFFERENT true ranks (2/4 vs full-rank
    8/8 on an r_max=8 reduced model) fused on one SharedBackboneExecutor
    vs each alone — loss histories bitwise identical, best-vals equal
    (the full-rank host flips dense -> rank-local dispatch and must not
    move a bit)."""
    cfg = dataclasses.replace(
        get_arch("paper-llama-tiny").reduced(num_layers=2, d_model=64,
                                             vocab=128), dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    ranks = {"A": (2, 4), "B": (8, 8)}
    seeds = {"A": 3, "B": 4}
    datasets = {
        "A": make_task_dataset("rl-a", cfg.vocab_size, seq_len=16,
                               num_train=32, num_val=8, difficulty=0.2,
                               seed=1),
        "B": make_task_dataset("rl-b", cfg.vocab_size, seq_len=16,
                               num_train=32, num_val=8, difficulty=0.6,
                               seed=2),
    }

    def run(names):
        ex = SharedBackboneExecutor(cfg, params, Z=4, per_adapter_batch=2,
                                    eval_every=2, seed=0)
        lcs = []
        for name in names:
            jobs = {f"{name}/j{k}": TrainConfig(
                learning_rate=lr, lora_rank=rk, max_steps=8,
                per_adapter_batch=2)
                for k, (lr, rk) in enumerate(zip((3e-3, 1e-3),
                                                 ranks[name]))}
            lcs.append(TaskLifecycle(
                ex, name, jobs, 8,
                ee=EarlyExitConfig(warmup_ratio=0.25, select_ratio=1.0),
                max_slots=2,
                batcher=SlotBatcher(datasets[name], 2, 2,
                                    seed=seeds[name]),
                seed=seeds[name]))
        results = run_colocated(ex, lcs)
        hists = {lc.task_name: {j: (tuple(m.val_hist),
                                    tuple(m.raw_train_hist))
                                for j, m in lc.monitors.items()}
                 for lc in lcs}
        return results, hists

    fused, fused_h = run(["A", "B"])
    out = {}
    for name in ("A", "B"):
        solo, solo_h = run([name])
        bitwise = fused_h[name] == solo_h[name]
        identical = fused[name].best_val == solo[name].best_val
        out[name] = {"ranks": list(ranks[name]),
                     "solo_best_val": solo[name].best_val,
                     "fused_best_val": fused[name].best_val,
                     "losses_bitwise_identical": bitwise,
                     "best_val_identical": identical}
        assert bitwise, f"different-rank guest perturbed {name}'s losses"
        assert identical, f"rank-local fusion changed task {name}'s best-val"
    return out


def run_kernel_check(smoke: bool) -> dict:
    """ranks==r_max bitwise vs dense, plus interpret-mode wall time of a
    mixed-rank fwd+VJP (observability only — interpret mode runs the grid
    on host)."""
    Z, T, d, r_max = 4, (64 if smoke else 128), (128 if smoke else 256), 64
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (Z, T, d))
    A = 0.1 * jax.random.normal(ks[1], (Z, d, r_max))
    B = 0.1 * jax.random.normal(ks[2], (Z, r_max, d))
    scale = jnp.ones((Z,))
    ranks = jnp.asarray([4, 8, 16, 64], jnp.int32)
    full = jnp.full((Z,), r_max, jnp.int32)
    dense = kops.grouped_lora(x, A, B, scale, interpret=True)
    rl_full = kops.ranklocal_grouped_lora(x, A, B, scale, full,
                                          interpret=True)
    bitwise = bool((np.asarray(dense) == np.asarray(rl_full)).all())
    assert bitwise, "ranks==r_max is not bitwise-equal to the dense path"

    def bench(fn, iters=2):
        g = jax.jit(jax.grad(lambda a, b: jnp.sum(fn(a, b) ** 2),
                             argnums=(0, 1)))
        out = g(A, B)
        jax.block_until_ready(out)
        t0 = time.time()
        for _ in range(iters):
            out = g(A, B)
        jax.block_until_ready(out)
        return (time.time() - t0) / iters

    t_dense = bench(lambda a, b: kops.grouped_lora(x, a, b, scale,
                                                   interpret=True))
    t_local = bench(lambda a, b: kops.ranklocal_grouped_lora(
        x, a, b, scale, ranks, interpret=True))
    return {"full_rank_bitwise_equal_dense": bitwise,
            "interpret_fwd_vjp_dense_s": t_dense,
            "interpret_fwd_vjp_ranklocal_s": t_local,
            "mixed_ranks": [int(v) for v in ranks]}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small instance (CI)")
    ap.add_argument("--gpus", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_ranklocal.json")
    args = ap.parse_args(argv)

    # the cluster phase is virtual-time (cheap) and the rank-masked
    # serialization only binds once the sweep outgrows the host window,
    # so smoke keeps the full 10-task sweep and shrinks the real-training
    # and kernel phases instead
    tasks = build_workload(num_small=10, seed=args.seed)
    result = run_cluster(tasks, args.gpus)
    result["isolation"] = run_isolation_check()
    result["kernel"] = run_kernel_check(args.smoke)

    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    for mode in ("exclusive", "rankmasked", "ranklocal"):
        r = result[mode]
        print(f"{mode:10s} makespan : {r['makespan_s']:.3f}s "
              f"(eff util {r['utilization_effective']:.2%}, "
              f"{r['fuse_events']} fused)")
    print(f"speedup vs rankmasked: {result['speedup_vs_rankmasked']:.2f}x "
          f"(vs exclusive {result['speedup_vs_exclusive']:.2f}x); "
          f"adapter flops x{result['adapter_flops_speedup']:.2f}")
    iso = result["isolation"]
    print("isolation            : " + ", ".join(
        f"{n}(r={v['ranks']}) best_val {v['fused_best_val']:.4f} "
        f"({'bitwise' if v['losses_bitwise_identical'] else 'DIFFERS'})"
        for n, v in iso.items()))
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
