"""§Roofline: emit the per-(arch x shape) roofline terms from the dry-run
artifacts as CSV (the full table lives in EXPERIMENTS.md)."""
from __future__ import annotations

import os

from benchmarks.common import emit
from repro.roofline.analysis import load_all

DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def run() -> None:
    if not os.path.isdir(DIR):
        emit("roofline/missing", 0.0,
             "run: PYTHONPATH=src python -m repro.launch.dryrun --all")
        return
    rows = load_all(DIR)
    for key in sorted(rows):
        r = rows[key]
        if r.mesh != "pod16x16":
            continue
        emit(f"roofline/{r.arch}/{r.shape}", r.step_time_lb,
             f"dominant={r.dominant};compute={r.compute_s:.4f};"
             f"memory={r.memory_s:.4f};collective={r.collective_s:.4f};"
             f"useful={r.useful_flops_ratio:.3f}")


if __name__ == "__main__":
    run()
