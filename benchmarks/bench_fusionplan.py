"""Fusion-aware planning + slot-level migration vs opportunistic fusion.

Opportunistic cross-task fusion (PR 3) attaches a pending task to a live
replica the moment admission accepts it — but once fused, the guest is
pinned: when the host's own jobs all early-exit, the collapsed replica
keeps its GPUs busy for the lone guest while the arrival queue regrows
behind them. Fusion-AWARE planning makes co-location a first-class
placement decision (the solver assigns tasks to replica slots under the
token-/rank-budget capacities of §A.3 + the k2 memory model) and adds
slot-level preemption/migration: a guest pinning a collapsed replica is
moved — via the bit-exact ``SlotSnapshot`` primitive — onto a same-key
sibling replica with headroom, freeing the host's GPUs for the queue.

Two parts:

1. **Cluster A/B (virtual time).** A regrowing-queue mix: one collapsing
   host replica (every kept job exits right after warmup selection), one
   long-lived spine replica with headroom only after its own selection,
   a guest fused onto the collapsing host, and a stream of exclusive
   arrivals that need the host's GPUs. Executed twice through the
   elastic runtime: ``colocate=True`` only (opportunistic fusion — the
   guest pins the collapsed host) and ``fusion_planning=True,
   migrate=True`` (the guest migrates to the spine at the collapse,
   releasing the GPUs to the queue). Reported: makespans, effective
   utilization, migration events, speedup (asserted >= 1.1x). Per-task
   results must be identical in both runs.

2. **Migration bitwise check (real training).** A task mid-training on
   one ``SharedBackboneExecutor`` is suspended (``SlotSnapshot`` per
   resident job), restored on a second executor already hosting a
   different resident mix (different physical slots), and trained to
   completion — its loss histories and best-val result must be bitwise
   identical to never migrating.

Emits BENCH_fusionplan.json. ``--smoke`` shrinks the mix (CI artifact).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys

import jax
import numpy as np

from repro.configs.base import TrainConfig
from repro.configs.registry import get_arch
from repro.core.early_exit import EarlyExitConfig
from repro.core.executor import (SharedBackboneExecutor, TaskLifecycle,
                                 run_colocated)
from repro.data.synthetic import SlotBatcher, make_task_dataset
from repro.models import model as M
from repro.sched import profiler
from repro.sched.cluster import (ElasticClusterRuntime, SimulatedTaskDriver,
                                 execute_static, sim_colo_spec,
                                 sim_task_spec)
from repro.sched.events import EventKind
from repro.sched.inter_task import solve

FUSE_ARCH = "stablelm-3b"


def build_workload(num_stream: int, seed: int = 0):
    """(spec, factory, colo, release) quadruples — the regrowing-queue
    mix described in the module docstring. ``seed`` jitters budgets so
    robustness of the speedup is checkable."""
    rng = np.random.default_rng(seed)
    cfg = get_arch(FUSE_ARCH)
    st = profiler.profile_task(cfg, 8, 4, 1024, 2).step_time_s
    fuse_key = (FUSE_ARCH, 2, 4, 1024, "sft")
    tasks = []

    def sim(name, *, K, Z, total, warm, gpus, colo, release=0.0, exits=None):
        spec = sim_task_spec(name, K=K, Z=Z, total_steps=total,
                             warmup_steps=warm, step_time_s=st, gpus=gpus)
        if release:
            spec = dataclasses.replace(spec, release=release)

        def factory(name=name, K=K, Z=Z, total=total, warm=warm,
                    exits=exits):
            return SimulatedTaskDriver(name, K=K, Z=Z, total_steps=total,
                                       warmup_steps=warm, step_time_s=st,
                                       exit_step=dict(exits or {}))
        return (spec, factory, colo, release)

    total = int(rng.integers(750, 900))
    warm = total // 10
    # spine: lives the whole run; replica_slots == Z means NO headroom
    # until its own warmup selection frees slots — the guest cannot fuse
    # here at t=0, only migrate here later
    tasks.append(sim("spine", K=8, Z=4, total=total, warm=warm, gpus=2,
                     colo=sim_colo_spec(fuse_key, K=8, Z=4,
                                        replica_slots=4)))
    # host: every kept job exits right after warmup selection — the
    # replica collapses to just its guest at ~(2*warm+1) steps
    tasks.append(sim("host", K=8, Z=4, total=total, warm=warm, gpus=2,
                     exits={j: warm + 1 for j in range(8)},
                     colo=sim_colo_spec(fuse_key, K=8, Z=4,
                                        replica_slots=8)))
    # guest: fuses onto the host at t=0; outlives the collapse by far
    guest_total = int(rng.integers(550, 650))
    tasks.append(sim("guest", K=2, Z=2, total=guest_total,
                     warm=guest_total // 10, gpus=2,
                     colo=sim_colo_spec(fuse_key, K=2, Z=2)))
    # the regrowing queue: exclusive arrivals that need the host's GPUs
    for i in range(num_stream):
        stream_total = int(rng.integers(180, 220))
        tasks.append(sim(f"stream-{i}", K=2, Z=2, total=stream_total,
                         warm=stream_total // 10, gpus=2, colo=None,
                         release=(i + 1) * 5 * st))
    return tasks


def run_cluster(tasks, G: int) -> dict:
    specs = [s for s, _, _, _ in tasks]
    plan = solve(specs, G, "cp")
    plan.validate(G)
    static = execute_static(plan, G, {s.name: f for s, f, _, _ in tasks})

    out = {}
    modes = (("exclusive", dict()),
             ("opportunistic", dict(colocate=True)),
             ("fusion_aware", dict(fusion_planning=True, migrate=True)))
    for mode, kw in modes:
        rt = ElasticClusterRuntime(G, delay_delta=2.0, **kw)
        for s, f, c, rel in tasks:
            rt.submit(s, f, at=rel, colo=c)
        # arrivals are announced via release times, so the full-knowledge
        # static plan stays the yardstick even though the session itself
        # plans incrementally (no ``initial`` covers future arrivals)
        rep = rt.run()
        assert rep.makespan <= static.makespan + 1e-9, \
            f"{mode} elastic regressed past the static plan"
        out[mode] = rep

    excl, opp, fa = (out["exclusive"], out["opportunistic"],
                     out["fusion_aware"])
    # identical work, attributed identically, in all three runs
    assert excl.results == opp.results == fa.results, \
        "placement strategy changed task results"
    assert fa.migrations >= 1, "no guest migrated — workload does not " \
        "exercise fusion-aware rebalancing"

    # per-task work area from the exclusive run (realized solo durations
    # x gpus): how densely each strategy packs identical work
    area = sum((excl.task_ends[s.name] - excl.task_starts[s.name]) * s.gpus
               for s, _, _, _ in tasks)

    def report(rep) -> dict:
        return {
            "makespan_s": rep.makespan,
            "utilization_effective": area / (len(rep.gpu_busy)
                                             * rep.makespan),
            "gpu_occupancy": rep.utilization,
            "replans": rep.replans,
            "preemptions": rep.preemptions,
            "migrations": rep.migrations,
            "task_starts": {k: round(v, 4)
                            for k, v in rep.task_starts.items()},
            "task_ends": {k: round(v, 4) for k, v in rep.task_ends.items()},
            "fused_tasks": dict(rep.colocated),
            "migrate_events": [e.detail for e in rep.events
                               if e.kind is EventKind.TASK_MIGRATED],
        }

    speedup = opp.makespan / max(fa.makespan, 1e-12)
    assert speedup >= 1.1, \
        f"fusion-aware planning+migration speedup {speedup:.3f} < 1.1x"
    return {
        "G": G,
        "num_tasks": len(tasks),
        "tasks": [{"name": s.name, "gpus": s.gpus,
                   "release_s": round(rel, 4),
                   "est_duration_s": round(s.duration, 4),
                   "fusable": c is not None}
                  for s, _, c, rel in tasks],
        "static_plan_makespan_s": static.makespan,
        "exclusive": report(excl),
        "opportunistic": report(opp),
        "fusion_aware": report(fa),
        "speedup_vs_exclusive": excl.makespan / max(fa.makespan, 1e-12),
        "speedup": speedup,
    }


def run_migration_check() -> dict:
    """Real training: suspend a mid-flight task on replica 1, restore it
    on replica 2 (different resident mix, different physical slots), and
    compare against never migrating — bitwise."""
    cfg = dataclasses.replace(
        get_arch("paper-llama-tiny").reduced(num_layers=2, d_model=64,
                                             vocab=128), dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    ds = {name: make_task_dataset(f"mig-{name}", cfg.vocab_size, seq_len=16,
                                  num_train=32, num_val=8, difficulty=diff,
                                  seed=sd)
          for name, diff, sd in (("A", 0.2, 1), ("B", 0.6, 2),
                                 ("C", 0.4, 3))}
    seeds = {"A": 3, "B": 4, "C": 5}

    def make_ex():
        return SharedBackboneExecutor(cfg, params, Z=4, per_adapter_batch=2,
                                      eval_every=2, seed=0)

    def lifecycle(ex, name):
        jobs = {f"{name}/j{k}": TrainConfig(learning_rate=lr, lora_rank=rk,
                                            max_steps=8)
                for k, (lr, rk) in enumerate(zip((3e-3, 1e-3), (4, 8)))}
        return TaskLifecycle(
            ex, name, jobs, 8,
            ee=EarlyExitConfig(warmup_ratio=0.25, select_ratio=1.0),
            max_slots=2, batcher=SlotBatcher(ds[name], 2, 2,
                                             seed=seeds[name]),
            seed=seeds[name])

    def drive(ex, lcs, steps=None):
        done = 0
        while any(not lc.done for lc in lcs):
            live = [lc for lc in lcs if not lc.done]
            n = max(min(min(lc.steps_until_boundary() for lc in live),
                        ex.eval_every), 1)
            ex.run_steps(n)
            for lc in live:
                lc.on_steps(n)
            done += n
            if steps is not None and done >= steps:
                return

    def hists(lc):
        return {j: (tuple(m.val_hist), tuple(m.raw_train_hist))
                for j, m in lc.monitors.items()}

    # solo baseline: A never migrates
    ex0 = make_ex()
    a0, b0 = lifecycle(ex0, "A"), lifecycle(ex0, "B")
    run_colocated(ex0, [a0, b0])

    # migration run: A moves mid-continue from replica 1 to replica 2
    ex1, ex2 = make_ex(), make_ex()
    A, B, C = lifecycle(ex1, "A"), lifecycle(ex1, "B"), lifecycle(ex2, "C")
    ex2.add_task(C)
    C.begin()
    drive(ex2, [C], steps=4)
    ex1.add_task(A)
    ex1.add_task(B)
    A.begin()
    B.begin()
    drive(ex1, [A, B], steps=4)
    A.suspend()
    assert ex2.can_admit_task(A)
    A.resume(ex2)
    drive(ex2, [A, C])
    drive(ex1, [B])

    bitwise = hists(A) == hists(a0)
    best_val = A.result().best_val == a0.result().best_val
    assert bitwise and best_val, "migration perturbed the task's losses"
    return {"solo_best_val": a0.result().best_val,
            "migrated_best_val": A.result().best_val,
            "losses_bitwise_identical": bitwise,
            "best_val_identical": best_val}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small instance (CI)")
    ap.add_argument("--gpus", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_fusionplan.json")
    args = ap.parse_args(argv)

    tasks = build_workload(num_stream=3 if args.smoke else 6,
                           seed=args.seed)
    result = run_cluster(tasks, args.gpus)
    result["migration_bitwise"] = run_migration_check()

    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    o, fa = result["opportunistic"], result["fusion_aware"]
    e = result["exclusive"]
    print(f"exclusive makespan    : {e['makespan_s']:.3f}s "
          f"(eff util {e['utilization_effective']:.2%})")
    print(f"opportunistic makespan: {o['makespan_s']:.3f}s "
          f"(eff util {o['utilization_effective']:.2%})")
    print(f"fusion-aware makespan : {fa['makespan_s']:.3f}s "
          f"(eff util {fa['utilization_effective']:.2%}, "
          f"{fa['migrations']} migration(s), "
          f"{fa['preemptions']} preemption(s))")
    print(f"speedup               : {result['speedup']:.2f}x")
    mig = result["migration_bitwise"]
    print(f"migration bitwise     : best_val {mig['migrated_best_val']:.4f} "
          f"({'identical' if mig['losses_bitwise_identical'] else 'DIFFERS'}"
          ")")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
