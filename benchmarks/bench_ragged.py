"""Ragged co-location vs same-key-only fusion on a MIXED-batch-size mix.

PR 3's cross-task co-location fused only tasks whose fuse key matched the
host exactly — per-adapter batch size (and seq len) baked in — so a
heterogeneous tuning mix (the paper's core workload) mostly fell back to
exclusive replicas. Ragged slots relax the key to (arch, gpus, loss) and
admit guests over the §A.3 TOKEN budget instead of same-width slot
counts: adapters with different batch sizes train in one fused step via
the ragged grouped-GEMM path. This bench quantifies the relaxation, in
two parts:

1. **Cluster A/B/C (virtual time).** One long fusable host (b=4),
   exclusive hog tasks pinning the remaining GPUs, and a stream of small
   fusable tasks with MIXED widths (b in {8, 4, 2}) run through the
   elastic runtime three ways: ``exclusive`` (no fusion), ``samekey``
   (PR3 rule: fuse keys embed (b, seq) — only the b=4 smalls can fuse),
   and ``ragged`` (width-free keys, token-budget admission — every small
   is a candidate). Per-task results must be identical in all three
   runs; ragged must strictly beat samekey on makespan AND effective
   utilization (same work area over G x makespan).

2. **Isolation check (real training).** Tasks with DIFFERENT per-adapter
   batch sizes fused on one real ``SharedBackboneExecutor`` vs each
   alone: loss histories must be bitwise identical and best-vals equal
   (the ragged loss-isolation property, tests/test_lora_isolation.py).

Emits BENCH_ragged.json. ``--smoke`` shrinks the mix (CI artifact).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys

import jax
import numpy as np

from repro.configs.base import TrainConfig
from repro.configs.registry import get_arch
from repro.core.early_exit import EarlyExitConfig
from repro.core.executor import (SharedBackboneExecutor, TaskLifecycle,
                                 run_colocated)
from repro.data.synthetic import SlotBatcher, make_task_dataset
from repro.models import model as M
from repro.sched import profiler
from repro.sched.cluster import (ElasticClusterRuntime, SimulatedTaskDriver,
                                 execute_static, sim_colo_spec,
                                 sim_task_spec)
from repro.sched.events import EventKind
from repro.sched.inter_task import solve
from repro.sched.intra_task import MemoryModel

FUSE_ARCH = "stablelm-3b"          # the shared-backbone family (1 GPU)
HOG_MIX = [("glm4-9b", 2), ("granite-8b", 1)]
SEQ = 1024
SMALL_WIDTHS = (8, 2, 4)           # the mixed-batch payload, cycling
RELAXED_KEY = (FUSE_ARCH, 1, "sft")


def build_workload(num_small: int, seed: int = 0):
    """(spec, factory, colo) triples. ``colo.fuse_key`` is the RELAXED
    (width-free) key; run_cluster rewrites it per mode."""
    rng = np.random.default_rng(seed)
    cfg = get_arch(FUSE_ARCH)
    st_host = profiler.profile_task(cfg, 8, 4, SEQ, 1).step_time_s
    # replica memory model: token-linear, wide enough that the slot
    # headroom — not memory — is usually the binding constraint, but
    # tight enough that admission is genuinely budgeted
    mem = MemoryModel(k0=0.0, k1=1.0, seq_len=SEQ, capacity=90_000,
                      safety_margin=0.9)
    tasks = []

    def sim(name, *, K, Z, total, warm, step_time, gpus, colo):
        spec = sim_task_spec(name, K=K, Z=Z, total_steps=total,
                             warmup_steps=warm, step_time_s=step_time,
                             gpus=gpus)

        def factory(name=name, K=K, Z=Z, total=total, warm=warm,
                    step_time=step_time):
            return SimulatedTaskDriver(name, K=K, Z=Z, total_steps=total,
                                       warmup_steps=warm,
                                       step_time_s=step_time)
        return (spec, factory, colo)

    # host: Z=8 slots, b=4; Pattern-3 keeps top 2 of 8, freeing 6 slots
    host_total = int(rng.integers(1100, 1400))
    host = sim("host", K=8, Z=8, total=host_total,
               warm=host_total // 20, step_time=st_host, gpus=1,
               colo=sim_colo_spec(RELAXED_KEY, K=8, Z=8,
                                  per_adapter_batch=4, seq_len=SEQ,
                                  mem=mem))
    tasks.append(host)
    host_dur = host[0].duration
    # hogs: other archs, exclusive, pin the remaining GPUs
    for arch, gpus in HOG_MIX:
        hcfg = get_arch(arch)
        st = profiler.profile_task(hcfg, 4, 4, SEQ, gpus).step_time_s
        warm = 50
        total = max(int(0.97 * host_dur / st) - 3 * warm, warm + 10)
        tasks.append(sim(f"hog-{arch}", K=16, Z=4, total=total, warm=warm,
                         step_time=st, gpus=gpus, colo=None))
    # small tasks: MIXED per-adapter batch sizes — the ragged payload
    for i in range(num_small):
        b = SMALL_WIDTHS[i % len(SMALL_WIDTHS)]
        st_small = profiler.profile_task(cfg, 2, b, SEQ, 1).step_time_s
        total = int(rng.integers(350, 850))
        tasks.append(sim(f"small-b{b}-{i}", K=2, Z=2, total=total,
                         warm=max(total // 20, 1), step_time=st_small,
                         gpus=1,
                         colo=sim_colo_spec(RELAXED_KEY, K=2, Z=2,
                                            per_adapter_batch=b,
                                            seq_len=SEQ)))
    return tasks


def _with_mode_keys(tasks, mode: str):
    """exclusive: drop colo; samekey: bake (b, seq) into the key (the
    pre-ragged fuse rule); ragged: relaxed keys as built."""
    out = []
    for spec, factory, colo in tasks:
        if colo is not None:
            if mode == "exclusive":
                colo = None
            elif mode == "samekey":
                colo = dataclasses.replace(
                    colo, fuse_key=RELAXED_KEY + (colo.per_adapter_batch,
                                                  colo.seq_len))
        out.append((spec, factory, colo))
    return out


def run_cluster(tasks, G: int) -> dict:
    specs = [s for s, _, _ in tasks]
    plan = solve(specs, G, "cp")
    plan.validate(G)
    static = execute_static(plan, G, {s.name: f for s, f, _ in tasks})

    out = {}
    for mode in ("exclusive", "samekey", "ragged"):
        rt = ElasticClusterRuntime(G, colocate=(mode != "exclusive"))
        for s, f, c in _with_mode_keys(tasks, mode):
            rt.submit(s, f, colo=c)
        rep = rt.run(initial=plan)
        assert rep.makespan <= static.makespan + 1e-9, \
            f"{mode} elastic regressed past the static plan"
        out[mode] = rep

    excl, same, ragg = out["exclusive"], out["samekey"], out["ragged"]
    # identical work, attributed identically, across all three strategies
    assert excl.results == same.results == ragg.results, \
        "fusion strategy changed task results"
    assert ragg.colocated, "ragged mode fused nothing"
    mixed = {n for n in ragg.colocated if n not in same.colocated}
    assert mixed, "no mixed-width task fused — the relaxation is idle"
    assert ragg.makespan < same.makespan - 1e-9, \
        "ragged fusion did not beat same-key-only fusion"
    assert same.makespan <= excl.makespan + 1e-9

    # effective utilization: identical per-task work area (realized solo
    # durations x gpus from the exclusive run) over G x makespan
    area = sum((excl.task_ends[s.name] - excl.task_starts[s.name]) * s.gpus
               for s, _, _ in tasks)

    def report(rep) -> dict:
        return {
            "makespan_s": rep.makespan,
            "utilization_effective": area / (len(rep.gpu_busy)
                                             * rep.makespan),
            "gpu_occupancy": rep.utilization,
            "replans": rep.replans,
            "fused_tasks": dict(rep.colocated),
            "fuse_events": sum(1 for e in rep.events
                               if e.kind is EventKind.TASK_FUSED),
            "task_starts": {k: round(v, 4)
                            for k, v in rep.task_starts.items()},
            "task_ends": {k: round(v, 4) for k, v in rep.task_ends.items()},
        }

    excl_r, same_r, ragg_r = report(excl), report(same), report(ragg)
    assert ragg_r["utilization_effective"] > \
        same_r["utilization_effective"] + 1e-9, \
        "ragged fusion did not lift effective utilization past same-key"
    return {
        "G": G,
        "num_tasks": len(tasks),
        "tasks": [{"name": s.name, "gpus": s.gpus,
                   "est_duration_s": round(s.duration, 4),
                   "per_adapter_batch": (c.per_adapter_batch
                                         if c is not None else None),
                   "fusable": c is not None} for s, _, c in tasks],
        "static_plan_makespan_s": static.makespan,
        "exclusive": excl_r,
        "samekey": same_r,
        "ragged": ragg_r,
        "speedup_vs_exclusive": excl.makespan / max(ragg.makespan, 1e-12),
        "speedup_vs_samekey": same.makespan / max(ragg.makespan, 1e-12),
    }


def run_isolation_check() -> dict:
    """Real training: mixed-width tasks (b=2 vs b=4) fused on one
    SharedBackboneExecutor vs each alone — loss histories bitwise
    identical, best-vals equal."""
    cfg = dataclasses.replace(
        get_arch("paper-llama-tiny").reduced(num_layers=2, d_model=64,
                                             vocab=128), dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    widths = {"A": 2, "B": 4}
    seeds = {"A": 3, "B": 4}
    datasets = {
        "A": make_task_dataset("rg-a", cfg.vocab_size, seq_len=16,
                               num_train=32, num_val=8, difficulty=0.2,
                               seed=1),
        "B": make_task_dataset("rg-b", cfg.vocab_size, seq_len=16,
                               num_train=32, num_val=8, difficulty=0.6,
                               seed=2),
    }

    def run(names):
        ex = SharedBackboneExecutor(cfg, params, Z=4, per_adapter_batch=4,
                                    eval_every=2, seed=0)
        lcs = []
        for name in names:
            jobs = {f"{name}/j{k}": TrainConfig(
                learning_rate=lr, lora_rank=4, max_steps=8,
                per_adapter_batch=widths[name])
                for k, lr in enumerate((3e-3, 1e-3))}
            lcs.append(TaskLifecycle(
                ex, name, jobs, 8,
                ee=EarlyExitConfig(warmup_ratio=0.25, select_ratio=1.0),
                max_slots=2,
                batcher=SlotBatcher(datasets[name], 2, widths[name],
                                    seed=seeds[name]),
                seed=seeds[name]))
        results = run_colocated(ex, lcs)
        hists = {lc.task_name: {j: (tuple(m.val_hist),
                                    tuple(m.raw_train_hist))
                                for j, m in lc.monitors.items()}
                 for lc in lcs}
        return results, hists

    fused, fused_h = run(["A", "B"])
    out = {}
    for name in ("A", "B"):
        solo, solo_h = run([name])
        bitwise = fused_h[name] == solo_h[name]
        identical = fused[name].best_val == solo[name].best_val
        out[name] = {"width": widths[name],
                     "solo_best_val": solo[name].best_val,
                     "fused_best_val": fused[name].best_val,
                     "losses_bitwise_identical": bitwise,
                     "best_val_identical": identical}
        assert bitwise, f"different-width guest perturbed {name}'s losses"
        assert identical, f"ragged fusion changed task {name}'s best-val"
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small instance (CI)")
    ap.add_argument("--gpus", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_ragged.json")
    args = ap.parse_args(argv)

    tasks = build_workload(num_small=6 if args.smoke else 12,
                           seed=args.seed)
    result = run_cluster(tasks, args.gpus)
    result["isolation"] = run_isolation_check()

    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    for mode in ("exclusive", "samekey", "ragged"):
        r = result[mode]
        print(f"{mode:9s} makespan : {r['makespan_s']:.3f}s "
              f"(eff util {r['utilization_effective']:.2%}, "
              f"{r['fuse_events']} fused)")
    print(f"speedup vs samekey  : {result['speedup_vs_samekey']:.2f}x "
          f"(vs exclusive {result['speedup_vs_exclusive']:.2f}x)")
    iso = result["isolation"]
    print("isolation           : " + ", ".join(
        f"{n}(b={v['width']}) best_val {v['fused_best_val']:.4f} "
        f"({'bitwise' if v['losses_bitwise_identical'] else 'DIFFERS'})"
        for n, v in iso.items()))
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
