"""Paper Fig. 9 (+ Fig. 11 DPO): end-to-end speedup of ALTO vs Sequential
and batched-only multi-LoRA on a REAL (tiny-model) tuning task.

Measured on CPU wall-clock with the actual jitted train steps:
  Sequential  — one adapter at a time (Z=1 executor per config, full budget)
  Batched     — all configs co-resident (grouped execution), no early exit
  ALTO        — batched + hierarchical early exit

Speedup = sequential_time / variant_time for completing the SAME search
space and returning a best adapter of equal-or-better val loss."""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.base import TrainConfig
from repro.configs.registry import get_arch
from repro.core.early_exit import EarlyExitConfig
from repro.core.executor import BatchedExecutor
from repro.data.synthetic import make_task_dataset
from repro.models import model as M

STEPS = 30


def build():
    cfg = dataclasses.replace(
        get_arch("paper-llama-tiny").reduced(num_layers=2, d_model=128,
                                             vocab=256), dtype="float32")
    ds = make_task_dataset("e2e", cfg.vocab_size, seq_len=32,
                           num_train=48, num_val=16, difficulty=0.25)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    jobs = {}
    for lr in (1e-3, 3e-3, 1e-2, 10.0):
        for rank in (4, 8):
            jobs[f"lr{lr:g}_r{rank}"] = TrainConfig(
                learning_rate=lr, lora_rank=rank, max_steps=STEPS,
                grad_clip=0.0 if lr >= 1.0 else 1.0)
    return cfg, ds, params, jobs


def run() -> None:
    cfg, ds, params, jobs = build()

    # --- Sequential: one slot, no early exit, every config to completion
    t0 = time.perf_counter()
    best_seq = np.inf
    ee_off = EarlyExitConfig(enabled=False, select_ratio=1.0,
                             warmup_ratio=0.01)
    for name, tc in jobs.items():
        ex = BatchedExecutor(cfg, params, ds, Z=1, per_adapter_batch=4,
                             ee=ee_off, eval_every=3, seed=0)
        r = ex.run_task("seq", {name: tc}, STEPS)
        best_seq = min(best_seq, r.best_val)
    t_seq = time.perf_counter() - t0

    # --- Batched multi-LoRA (no early exit)
    t0 = time.perf_counter()
    ex = BatchedExecutor(cfg, params, ds, Z=len(jobs), per_adapter_batch=4,
                         ee=ee_off, eval_every=3, seed=0)
    r_b = ex.run_task("batched", dict(jobs), STEPS)
    t_batched = time.perf_counter() - t0

    # --- ALTO: batched + early exit
    t0 = time.perf_counter()
    ex = BatchedExecutor(cfg, params, ds, Z=4, per_adapter_batch=4,
                         ee=EarlyExitConfig(warmup_ratio=0.15,
                                            select_ratio=0.3),
                         eval_every=3, seed=0)
    r_a = ex.run_task("alto", dict(jobs), STEPS)
    t_alto = time.perf_counter() - t0

    emit("fig9/sequential", t_seq, f"best_val={best_seq:.4f}")
    emit("fig9/batched", t_batched,
         f"best_val={r_b.best_val:.4f};speedup={t_seq / t_batched:.2f}x")
    emit("fig9/alto", t_alto,
         f"best_val={r_a.best_val:.4f};speedup={t_seq / t_alto:.2f}x;"
         f"quality_ratio={r_a.best_val / best_seq:.4f}")


if __name__ == "__main__":
    run()
