"""Kernel<->cost-model loop: tile-plan autotuning + the profile-fitted
step-time model, benchmarked end to end.

Two phases, one artifact (BENCH_autotune.json):

1. **Tile-plan autotune sweeps (real timings).** For each shape key
   ``(d_in, d_out, r_max, Z, tokens)``, ``autotune.sweep`` times every
   sublane/MXU-legal candidate block shape on the six rank-local kernels
   (fwd S=XA / Y=SB + four bwd) and crowns the fastest candidate that is
   BITWISE identical to the default constants (the default competes, so
   tuned throughput >= default throughput by construction — asserted
   anyway). The winner round-trips through ``ProfileStore`` persistence
   (save -> load -> get_spec) to prove later sessions skip the sweep.
   Interpret-mode harness note: timings are the CPU interpret loop (this
   container), so the tuned/default RATIO is the portable signal, not the
   absolute GFLOP/s; on TPU the same sweep times Mosaic lowerings.

2. **Fitted-vs-analytic step-time model (held-out sweep).** A simulated
   hardware ground truth — the analytic roofline's own linear structure
   with a fixed launch overhead, drifted per-token slope, and drifted
   per-rank-token slope, plus 1% noise (what real hardware does to a
   roofline: overhead the model omits and effective-MFU drift it cannot
   know) — generates fused-step observations over a training
   ``(Z, b, seq, rank)`` grid, recorded through the real
   ``ProfileStore.record_step`` -> ``fitted.fitted_step_model`` path. The
   fitted (k0, k1, k2) model and the analytic ``fused_step_time`` then
   both predict a DISJOINT held-out grid; the artifact reports both
   relative errors and asserts fitted <= analytic.

``--smoke`` shrinks the sweep set (CI artifact).
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

from repro.configs.registry import get_arch
from repro.kernels.grouped_lora import autotune as AT
from repro.sched import fitted as FT
from repro.sched import profiler

ARCH = "paper-llama-tiny"

# (d_in, d_out, r_max, Z, tokens) shape keys swept by the autotuner —
# adapter-projection shapes at bench scale (interpret mode runs the grid
# as a host loop; production dims would take hours without buying signal)
SMOKE_SWEEPS = [(128, 128, 32, 4, 64)]
FULL_SWEEPS = SMOKE_SWEEPS + [(256, 128, 64, 4, 128), (128, 256, 32, 8, 64)]


def run_kernel_sweeps(smoke: bool, tmp_profile: str) -> list:
    import os
    entries = []
    store = profiler.ProfileStore()
    for d_in, d_out, r_max, Z, tokens in (SMOKE_SWEEPS if smoke
                                          else FULL_SWEEPS):
        AT.clear_plan_cache()
        res = AT.sweep(d_in, d_out, r_max, Z=Z, tokens=tokens,
                       interpret=True,
                       max_candidates=6 if smoke else 12,
                       iters=1 if smoke else 2, repeats=2 if smoke else 3)
        winner_bitwise = next(c.bitwise_equal_default
                              for c in res.candidates if c.plan == res.plan)
        assert winner_bitwise, "winner is not bitwise-equal to default"
        assert res.best_s <= res.default_s + 1e-12, \
            "tuned plan slower than default (default competes in the sweep)"
        # persistence round-trip: winner -> durable spec -> save -> load
        store.put_spec(res.key, res.plan.to_json(), durable=True)
        entries.append({
            "d_in": d_in, "d_out": d_out, "r_max": r_max, "Z": Z,
            "tokens": tokens,
            "key": list(res.key),
            "winner_plan": res.plan.to_json(),
            "default_s": res.default_s,
            "tuned_s": res.best_s,
            "speedup": res.speedup,
            "flops": res.flops,
            "default_flops_per_s": res.default_flops_per_s,
            "tuned_flops_per_s": res.tuned_flops_per_s,
            "bitwise_equal": winner_bitwise,
            "candidates_timed": len(res.candidates),
            "candidates_bitwise": sum(c.bitwise_equal_default
                                      for c in res.candidates),
        })
    store.save(tmp_profile)
    reloaded = profiler.ProfileStore.load(tmp_profile)
    for e in entries:
        spec = reloaded.get_spec(tuple(e["key"]))
        plan = AT.TilePlan.from_json(spec) if spec is not None else None
        assert plan is not None and plan.to_json() == e["winner_plan"], \
            "tuned plan did not survive ProfileStore persistence"
        e["persistence_roundtrip"] = True
    os.remove(tmp_profile)
    return entries


def run_fitted_eval(smoke: bool, seed: int = 0) -> dict:
    cfg = get_arch(ARCH)
    gpus = 1
    rng = np.random.default_rng(seed)

    # simulated hardware: the roofline's linear structure plus what real
    # hardware adds — launch overhead and slope drift the analytic model
    # cannot see (coefficients derived FROM the analytic model so the
    # drift is relative, not arbitrary)
    base_tok = profiler.fused_step_time(cfg, [1024.0], [0.0], gpus) / 1024.0
    rank_tok = (profiler.fused_step_time(cfg, [1024.0], [1.0], gpus)
                - profiler.fused_step_time(cfg, [1024.0], [0.0], gpus)
                ) / 1024.0
    K0, K1, K2 = 3e-3, 1.3 * base_tok, 1.5 * rank_tok

    def observe(tokens: float, rtok: float) -> float:
        return ((K0 + K1 * tokens + K2 * rtok)
                * float(rng.normal(1.0, 0.01)))

    store = profiler.ProfileStore()
    key = (cfg.name, gpus)
    train_grid = [(Z, b, seq, r)
                  for Z in (2, 4) for b in (1, 2, 4)
                  for seq in (128, 256) for r in (4, 8, 16, 32)]
    if smoke:
        train_grid = train_grid[::2]
    for Z, b, seq, r in train_grid:
        tokens = float(Z * b * seq)
        FT.observe_fused_step(store, key, slot_tokens=[b * seq] * Z,
                              ranks=[r] * Z, wall_s=observe(tokens,
                                                            tokens * r))
    model = FT.fitted_step_model(store, key)
    assert model is not None, "fit did not clear the observation guard"

    # held-out: disjoint (Z, b, seq, rank) combos, including extrapolation
    heldout = [(3, 3, 192, 6), (8, 1, 160, 24), (5, 2, 320, 64),
               (6, 4, 96, 12), (2, 8, 224, 48)]
    errs_fit, errs_analytic = [], []
    for Z, b, seq, r in heldout:
        slot_tokens, ranks = [float(b * seq)] * Z, [float(r)] * Z
        tokens = float(Z * b * seq)
        truth = K0 + K1 * tokens + K2 * tokens * r     # noise-free target
        errs_fit.append(abs(model.step_time(slot_tokens, ranks) - truth)
                        / truth)
        errs_analytic.append(
            abs(profiler.fused_step_time(cfg, slot_tokens, ranks, gpus)
                - truth) / truth)
    fit_err = float(np.mean(errs_fit))
    analytic_err = float(np.mean(errs_analytic))
    assert fit_err <= analytic_err, \
        "fitted model lost to analytic on the held-out sweep"
    return {
        "arch": cfg.name, "gpus": gpus,
        "observations": len(train_grid),
        "heldout_points": len(heldout),
        "heldout_grid": [list(h) for h in heldout],
        "true_coeffs": {"k0": K0, "k1": K1, "k2": K2},
        "fitted_coeffs": {"k0": model.k0, "k1": model.k1, "k2": model.k2},
        "fitted_rel_error": fit_err,
        "analytic_rel_error": analytic_err,
        "error_ratio": fit_err / max(analytic_err, 1e-12),
    }


def main(argv=None) -> int:
    import jax
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small instance (CI)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_autotune.json")
    args = ap.parse_args(argv)

    result = {
        "backend": f"interpret-{jax.default_backend()}",
        "kernel_sweeps": run_kernel_sweeps(args.smoke,
                                           args.out + ".profile.tmp"),
        "fitted_model": run_fitted_eval(args.smoke, args.seed),
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    for e in result["kernel_sweeps"]:
        print(f"sweep d{e['d_in']}x{e['d_out']} r{e['r_max']} Z{e['Z']} "
              f"T{e['tokens']}: default {e['default_s']*1e3:.2f}ms -> tuned "
              f"{e['tuned_s']*1e3:.2f}ms (x{e['speedup']:.2f}, "
              f"{e['candidates_timed']} candidates, bitwise="
              f"{e['bitwise_equal']}, winner {e['winner_plan']})")
    fm = result["fitted_model"]
    print(f"fitted step model   : rel err {fm['fitted_rel_error']:.4f} vs "
          f"analytic {fm['analytic_rel_error']:.4f} on "
          f"{fm['heldout_points']} held-out points "
          f"(x{1/max(fm['error_ratio'], 1e-12):.0f} better)")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
