"""Paper Fig. 7 + Fig. 16: warmup-boundary ranking reliability.

Generates a family of synthetic-but-realistic loss trajectories (power-law
convergence with heterogeneous rates, plateaus, noise, a diverging tail),
then sweeps the warmup percentage and reports:
  * Spearman rank correlation between warmup-loss and final-loss ranking,
  * coverage of the true top-25% by the predicted top-25%,
  * whether the eventual best configuration lands in the predicted top-25%.
Paper: correlation stabilizes >0.7 at 5% warmup, best config always in the
top quartile at 5%."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit

K = 48          # configs
T = 400         # steps
TRIALS = 20


def spearman(a, b) -> float:
    ra = np.argsort(np.argsort(a)).astype(float)
    rb = np.argsort(np.argsort(b)).astype(float)
    ra -= ra.mean()
    rb -= rb.mean()
    denom = np.sqrt((ra ** 2).sum() * (rb ** 2).sum())
    return float((ra * rb).sum() / max(denom, 1e-12))


def sample_curves(rng) -> np.ndarray:
    t = np.arange(1, T + 1, dtype=float)
    curves = []
    for _ in range(K):
        floor = rng.uniform(0.3, 2.0)
        amp = rng.uniform(0.5, 3.0)
        rate = rng.uniform(0.1, 1.0)
        noise = rng.normal(0, 0.02 * amp, T)
        c = floor + amp * t ** (-rate) + noise
        if rng.random() < 0.15:     # diverging config
            c = c + np.maximum(t - rng.uniform(0.2, 0.8) * T, 0) * 0.01
        curves.append(c)
    return np.asarray(curves)


def run() -> None:
    rng = np.random.default_rng(0)
    warmups = [0.01, 0.02, 0.05, 0.10, 0.20]
    for w in warmups:
        rho, cov, best_in = [], [], []
        for _ in range(TRIALS):
            curves = sample_curves(rng)
            wi = max(int(w * T), 1)
            early = curves[:, :wi].min(axis=1)
            final = curves.min(axis=1)
            rho.append(spearman(early, final))
            k = max(int(np.ceil(0.25 * K)), 1)
            pred = set(np.argsort(early)[:k])
            true = set(np.argsort(final)[:k])
            cov.append(len(pred & true) / k)
            best_in.append(int(np.argmin(final)) in pred)
        emit(f"fig16/warmup{int(w * 100)}pct", 0.0,
             f"spearman={np.mean(rho):.3f};top25_cov={np.mean(cov):.3f};"
             f"best_in_top25={np.mean(best_in):.2f}")


if __name__ == "__main__":
    run()
