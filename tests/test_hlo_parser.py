"""Property tests for the trip-weighted HLO analyzer (roofline/hlo.py)."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.roofline import hlo as H


@settings(deadline=None, max_examples=50)
@given(dims=st.lists(st.integers(1, 64), min_size=0, max_size=4),
       dt=st.sampled_from(["f32", "bf16", "s32", "s8", "pred"]))
def test_shape_bytes(dims, dt):
    s = f"{dt}[{','.join(map(str, dims))}]"
    n = int(np.prod(dims)) if dims else 1
    expect = n * {"f32": 4, "bf16": 2, "s32": 4, "s8": 1, "pred": 1}[dt]
    assert H.shape_bytes(s) == expect


def test_tuple_shape_bytes():
    assert H.shape_bytes("(f32[2,2], bf16[4])") == 16 + 8


@settings(deadline=None, max_examples=25)
@given(trips=st.integers(1, 1000), m=st.integers(1, 16))
def test_trip_weighting_scales_linearly(trips, m):
    text = f"""HloModule t, is_scheduled=true

%body (p: (s32[], f32[{m},{m}])) -> (s32[], f32[{m},{m}]) {{
  %p = (s32[], f32[{m},{m}]) parameter(0)
  %g = f32[{m},{m}] get-tuple-element(%p), index=1
  %d = f32[{m},{m}] dot(%g, %g), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[{m},{m}]) tuple(%i, %d)
}}

%cond (p: (s32[], f32[{m},{m}])) -> pred[] {{
  %p = (s32[], f32[{m},{m}]) parameter(0)
  ROOT %c = pred[] constant(true)
}}

ENTRY %main (a: f32[{m},{m}]) -> f32[{m},{m}] {{
  %a = f32[{m},{m}] parameter(0)
  %init = (s32[], f32[{m},{m}]) tuple(%a, %a)
  %w = (s32[], f32[{m},{m}]) while(%init), condition=%cond, body=%body, backend_config={{"known_trip_count":{{"n":"{trips}"}}}}
  ROOT %r = f32[{m},{m}] get-tuple-element(%w), index=1
}}
"""
    res = H.analyze(text)
    assert res["flops"] == 2 * m * m * m * trips


def test_nested_while_multiplies():
    text = """HloModule t

%inner (p: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
  %p = (s32[], f32[4,4]) parameter(0)
  %g = f32[4,4] get-tuple-element(%p), index=1
  %d = f32[4,4] dot(%g, %g), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[4,4]) tuple(%i, %d)
}

%c1 (p: (s32[], f32[4,4])) -> pred[] {
  %p = (s32[], f32[4,4]) parameter(0)
  ROOT %c = pred[] constant(true)
}

%outer (p: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
  %p = (s32[], f32[4,4]) parameter(0)
  %w2 = (s32[], f32[4,4]) while(%p), condition=%c1, body=%inner, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %t = (s32[], f32[4,4]) tuple(%w2)
}

ENTRY %main (a: f32[4,4]) -> f32[4,4] {
  %a = f32[4,4] parameter(0)
  %init = (s32[], f32[4,4]) tuple(%a, %a)
  %w = (s32[], f32[4,4]) while(%init), condition=%c1, body=%outer, backend_config={"known_trip_count":{"n":"3"}}
  ROOT %r = f32[4,4] get-tuple-element(%w), index=1
}
"""
    res = H.analyze(text)
    # 3 outer x 5 inner = 15 dot executions
    assert res["flops"] == 2 * 4 * 4 * 4 * 15


def test_collective_ring_model():
    for kind, mult in (("all-gather", 0.5), ("all-reduce", 1.0),
                       ("reduce-scatter", 0.5), ("all-to-all", 0.5),
                       ("collective-permute", 1.0)):
        text = f"""HloModule t

ENTRY %main (a: f32[8,8]) -> f32[8,8] {{
  %a = f32[8,8] parameter(0)
  ROOT %c = f32[8,8] {kind}(%a), channel_id=1, replica_groups=[4,2]<=[8], dimensions={{0}}
}}
"""
        res = H.analyze(text)
        assert res["collective_traffic"] == pytest.approx(256 * mult), kind
