"""Partitioning rules: divisibility-aware spec selection on all archs.

Uses AbstractMesh so no fake devices are needed: the specs are pure
functions of (mesh shape, leaf shape, path)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.registry import ASSIGNED, get_arch
from repro.core import lora as LORA
from repro.launch import partitioning as PT
from repro.launch.mesh import abstract_mesh
from repro.models import model as M
from repro.optim import adamw

MESH = abstract_mesh((16, 16), ("data", "model"))
MESH3 = abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_pick_spec_divisibility_fallback():
    assert PT.pick_spec(MESH, (32, 64), [{0: "data", 1: "model"}]) == \
        P("data", "model")
    # 25 not divisible by 16 -> falls through
    assert PT.pick_spec(MESH, (25, 64), [{0: "model"}, {1: "model"}]) == \
        P(None, "model")
    assert PT.pick_spec(MESH, (25, 25), [{0: "model"}, {1: "model"}]) == P()


@pytest.mark.parametrize("arch", ASSIGNED)
@pytest.mark.parametrize("mesh", [MESH, MESH3], ids=["1pod", "2pod"])
def test_param_specs_cover_all_archs(arch, mesh):
    """Every leaf gets a legal spec: sharded dims divide the axis size."""
    cfg = get_arch(arch)
    params = jax.eval_shape(
        lambda k: M.init_params(k, cfg), jax.random.PRNGKey(0))
    specs = PT.base_param_specs(mesh, params)

    def check(leaf, spec):
        assert isinstance(spec, P)
        for dim, axes in enumerate(spec):
            if axes is None:
                continue
            names = axes if isinstance(axes, tuple) else (axes,)
            n = 1
            for a in names:
                n *= mesh.shape[a]
            assert leaf.shape[dim] % n == 0, (arch, leaf.shape, spec)

    jax.tree_util.tree_map(check, params, specs,
                           is_leaf=lambda x: isinstance(x, P))
    # big projection weights must actually be model-sharded
    q = specs["layers"]["q_proj"] if "q_proj" in specs["layers"] else \
        specs["layers"]["r_proj"]
    assert "model" in jax.tree_util.tree_leaves(
        [q], is_leaf=lambda s: isinstance(s, P))[0]


@pytest.mark.parametrize("arch", ["stablelm-3b", "rwkv6-3b",
                                  "granite-moe-1b-a400m"])
def test_lora_specs_are_slot_sharded_only(arch):
    """AP invariant: adapter leaves shard on Z ("data") and nothing else."""
    cfg = get_arch(arch)
    Z = 64
    lora = jax.eval_shape(
        lambda k: LORA.init_lora_tree(k, cfg, Z, jnp.zeros((Z,), jnp.int32),
                                      M.target_shapes(cfg)),
        jax.random.PRNGKey(0))
    specs = PT.lora_param_specs(MESH, lora)
    for s in jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P)):
        flat = [a for a in s if a is not None]
        assert flat in ([], ["data"]) or tuple(flat) == ("data",)
        if len(s) >= 2:
            assert s[1] == "data"      # the Z axis


def test_opt_state_follows_lora():
    cfg = get_arch("stablelm-3b")
    Z = 16
    lora = jax.eval_shape(
        lambda k: LORA.init_lora_tree(k, cfg, Z, jnp.zeros((Z,), jnp.int32),
                                      M.target_shapes(cfg)),
        jax.random.PRNGKey(0))
    opt = jax.eval_shape(lambda lt: adamw.init_state(lt, Z), lora)
    specs = PT.opt_state_specs(MESH, opt)
    assert specs.count == P("data")
    mu_leaf = jax.tree_util.tree_leaves(
        specs.mu, is_leaf=lambda x: isinstance(x, P))[0]
    assert mu_leaf[1] == "data"


def test_batch_and_cache_specs():
    cfg = get_arch("glm4-9b")
    batch = {"tokens": jax.ShapeDtypeStruct((16, 8, 4096), jnp.int32),
             "labels": jax.ShapeDtypeStruct((16, 8, 4096), jnp.int32)}
    bs = PT.batch_specs(MESH3, batch)
    assert bs["tokens"] == P("data", "pod")
    cache = jax.eval_shape(lambda: M.init_cache(cfg, 16, 8, 1024))
    cs = PT.cache_specs(MESH, cache)
    k_spec = cs["layers"]["attn"]["k"]
    # glm4 KV=2 (not divisible by 16) -> falls back to head_dim (128)
    assert k_spec[1] == "data" and ("model" in tuple(k_spec))
    assert cs["pos"] == P()
