"""Per-kernel allclose vs the pure-jnp oracle: shape/dtype/rank sweeps.

Every Pallas kernel runs in interpret mode (kernel body executed in Python
on CPU); tolerances reflect fp32 vs bf16 accumulation-order differences.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.grouped_lora import grouped_lora
from repro.kernels.grouped_lora import ops, ref

# the package __init__ re-exports the wrapper function under the module's
# name (shadowing it as a package attribute); grab the kernel MODULE via
# importlib
import importlib
K = importlib.import_module("repro.kernels.grouped_lora.grouped_lora")

KEY = jax.random.PRNGKey(42)


def make(Z, T, din, r, dout, dtype, with_base=True, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (Z, T, din), dtype)
    A = (0.1 * jax.random.normal(ks[1], (Z, din, r), jnp.float32)
         ).astype(dtype)
    B = (0.1 * jax.random.normal(ks[2], (Z, r, dout), jnp.float32)
         ).astype(dtype)
    scale = jnp.linspace(0.5, 2.0, Z)
    yb = (jax.random.normal(ks[3], (Z, T, dout), dtype)
          if with_base else None)
    return x, A, B, scale, yb


SHAPES = [
    # (Z, T, din, r, dout) — aligned and deliberately unaligned
    (1, 128, 256, 16, 256),
    (2, 64, 96, 8, 80),
    (3, 100, 130, 12, 200),
    (4, 256, 512, 64, 512),
    (8, 32, 64, 128, 64),
    (2, 7, 33, 4, 17),
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("with_base", [True, False])
def test_forward_matches_ref(shape, dtype, with_base):
    Z, T, din, r, dout = shape
    x, A, B, scale, yb = make(Z, T, din, r, dout, dtype, with_base)
    got = ops.grouped_lora(x, A, B, scale, yb, interpret=True)
    want = ref.grouped_lora_ref(x, A, B, scale, yb)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("shape", SHAPES[:4])
def test_gradients_match_ref(shape):
    Z, T, din, r, dout = shape
    x, A, B, scale, yb = make(Z, T, din, r, dout, jnp.float32, True)

    def loss_k(x, A, B, yb):
        return jnp.sum(jnp.tanh(
            ops.grouped_lora(x, A, B, scale, yb, interpret=True)))

    def loss_r(x, A, B, yb):
        return jnp.sum(jnp.tanh(ref.grouped_lora_ref(x, A, B, scale, yb)))

    gk = jax.grad(loss_k, argnums=(0, 1, 2, 3))(x, A, B, yb)
    gr = jax.grad(loss_r, argnums=(0, 1, 2, 3))(x, A, B, yb)
    for a, b, name in zip(gk, gr, ["dx", "dA", "dB", "dyb"]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4, err_msg=name)


def test_rank_padding_contributes_zero():
    """Paper §A.1: padded rank columns are masked out => identical output."""
    Z, T, din, r, dout = 2, 64, 128, 32, 96
    x, A, B, scale, _ = make(Z, T, din, r, dout, jnp.float32, False)
    ranks = jnp.array([8, 20])
    mask = (jnp.arange(r)[None, :] < ranks[:, None]).astype(jnp.float32)
    Am = A * mask[:, None, :]
    Bm = B * mask[:, :, None]
    full = ops.grouped_lora(x, Am, Bm, scale, interpret=True)
    # truncated computation per slot must agree
    for z, rk in enumerate([8, 20]):
        want = (x[z] @ Am[z, :, :rk]) @ Bm[z, :rk] * scale[z]
        np.testing.assert_allclose(np.asarray(full[z]), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


def test_padded_region_receives_zero_grad():
    Z, T, din, r, dout = 2, 32, 64, 16, 48
    x, A, B, scale, _ = make(Z, T, din, r, dout, jnp.float32, False)
    ranks = jnp.array([4, 12])
    mask = (jnp.arange(r)[None, :] < ranks[:, None]).astype(jnp.float32)
    Am, Bm = A * mask[:, None, :], B * mask[:, :, None]

    def loss(A_, B_):
        return jnp.sum(ops.grouped_lora(x, A_, B_, scale, interpret=True) ** 2)

    dA, dB = jax.grad(loss, argnums=(0, 1))(Am, Bm)
    # dA beyond rank is zero because B's padded rows are zero
    for z, rk in enumerate([4, 12]):
        assert float(jnp.abs(dA[z, :, rk:]).max()) == 0.0
        assert float(jnp.abs(dB[z, rk:, :]).max()) == 0.0


def test_individual_kernels_match_einsum():
    Z, T, din, r, dout = 2, 128, 256, 16, 128
    x, A, B, scale, yb = make(Z, T, din, r, dout, jnp.float32, True)
    s = K.xa(x, A, interpret=True)
    np.testing.assert_allclose(np.asarray(s),
                               np.asarray(ref.grouped_xa_ref(x, A)),
                               rtol=1e-5, atol=1e-5)
    dy = yb
    ds_ = K.ds(dy, B, scale, interpret=True)
    want_ds = jnp.einsum("zto,zro->ztr", dy * scale[:, None, None], B)
    np.testing.assert_allclose(np.asarray(ds_), np.asarray(want_ds),
                               rtol=1e-5, atol=1e-5)
    dx_ = K.dx(ds_, A, interpret=True)
    np.testing.assert_allclose(
        np.asarray(dx_), np.asarray(jnp.einsum("ztr,zdr->ztd", ds_, A)),
        rtol=1e-5, atol=1e-5)
    da_ = K.da(x, ds_, interpret=True)
    np.testing.assert_allclose(
        np.asarray(da_), np.asarray(jnp.einsum("ztd,ztr->zdr", x, ds_)),
        rtol=1e-4, atol=1e-4)
    db_ = K.db(s, dy, scale, interpret=True)
    want_db = jnp.einsum("ztr,zto->zro", s, dy * scale[:, None, None])
    np.testing.assert_allclose(np.asarray(db_), np.asarray(want_db),
                               rtol=1e-4, atol=1e-4)


def test_lora_backend_switch():
    """core.lora dispatches identically between jnp and pallas_interpret."""
    from repro.core import lora as L
    Z, T, din, r, dout = 2, 16, 32, 8, 24
    x, A, B, scale, _ = make(Z, T, din, r, dout, jnp.float32, False)
    y1 = L.lora_delta(x, A, B, scale)
    with L.backend("pallas_interpret"):
        y2 = L.lora_delta(x, A, B, scale)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)
