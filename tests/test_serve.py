"""Serving tier (src/repro/serve/): hot publish/retire, routing +
admission, cluster residency, and the tune-to-serve loop.

The bitwise decode-isolation properties (fused-vs-solo, hot publish
mid-decode) live with the other isolation invariants in
tests/test_lora_isolation.py; this file covers the subsystem mechanics:
AdapterPool slot bookkeeping, checkpoint round-trips, frontend queueing
and §A.3+k2 admission, the serving lease as a first-class planner
resident, and TuningService.submit() -> early exit -> served query.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import save_pytree
from repro.core import lora as LORA
from repro.models import model as M
from repro.sched.intra_task import MemoryModel
from repro.serve import (SPEC_VERSION, AdapterPool, AdmissionError,
                         PoolFull, ServingFrontend, ServingReplica)
from tests.conftest import reduced_f32


@pytest.fixture(scope="module")
def env():
    cfg = reduced_f32("paper-llama-tiny", num_layers=2, d_model=64,
                      vocab=128)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    ranks = [4, 8, 2]
    stack = LORA.init_lora_tree(key, cfg, 3, jnp.asarray(ranks),
                                M.target_shapes(cfg))
    stack = jax.tree_util.tree_map(
        lambda x: x + 0.01 * jax.random.normal(key, x.shape), stack)
    stack = LORA.mask_lora_tree(stack, jnp.asarray(ranks), cfg.lora.r_max)
    adapters = {z: jax.tree_util.tree_map(lambda x: np.asarray(x[:, z]),
                                          stack) for z in range(3)}
    return cfg, params, adapters, ranks


def _prompt(rng, cfg, n):
    return rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)


# ---------------------------------------------------------------------------
# AdapterPool
# ---------------------------------------------------------------------------

def test_pool_publish_retire_semantics(env):
    cfg, params, adapters, ranks = env
    pool = AdapterPool(cfg, 3)
    assert pool.free_slots() == [0, 1, 2]
    s0 = pool.publish("a0", adapters[0], ranks[0])
    s1 = pool.publish("a1", adapters[1], ranks[1])
    assert (s0, s1) == (0, 1)
    assert pool.resident() == {"a0": 0, "a1": 1}
    assert pool.slot_rank == [4, 8, 0]
    assert pool.mixed_rank()
    assert len(pool.publish_latencies_s) == 2
    # duplicate publish and occupied-slot publish are rejected
    with pytest.raises(AssertionError):
        pool.publish("a0", adapters[0], 4)
    with pytest.raises(AssertionError):
        pool.publish("a2", adapters[2], 2, slot=1)
    # retire zeroes the slot and frees it; resident slots untouched
    before = pool.adapter_at(1)
    pool.retire("a0")
    assert pool.free_slots() == [0, 2]
    for t, ab in pool.adapter_at(0).items():
        assert float(np.abs(ab["A"]).max()) == 0.0
        assert float(np.abs(ab["B"]).max()) == 0.0
    after = pool.adapter_at(1)
    for t in before:
        np.testing.assert_array_equal(before[t]["A"], after[t]["A"])
        np.testing.assert_array_equal(before[t]["B"], after[t]["B"])
    # freed slot is reusable; pool-full raises
    pool.publish("a2", adapters[2], ranks[2])
    pool.publish("b0", adapters[0], 4, slot=2)
    with pytest.raises(PoolFull):
        pool.publish("b1", adapters[1], 8)
    # published adapters keep the padded rank region exactly zero
    a2 = pool.adapter_at(pool.slot_of("a2"))
    for t, ab in a2.items():
        assert float(np.abs(ab["A"][:, :, 2:]).max()) == 0.0
        assert float(np.abs(ab["B"][:, 2:, :]).max()) == 0.0


def test_pool_checkpoint_roundtrip(env, tmp_path):
    """publish_checkpoint loads the durable artifact bitwise and honors /
    validates its metadata (rank, arch, spec_version)."""
    cfg, params, adapters, ranks = env
    path = str(tmp_path / "winner.npz")
    save_pytree(path, adapters[1],
                meta={"adapter_id": "ckpt-a", "rank": 8, "arch": cfg.name,
                      "fuse_key": [cfg.name, 1, "sft"],
                      "spec_version": SPEC_VERSION})
    pool = AdapterPool(cfg, 2)
    aid, slot = pool.publish_checkpoint(path)
    assert aid == "ckpt-a" and slot == 0
    assert pool.slot_rank[0] == 8
    assert pool.meta_of("ckpt-a")["fuse_key"] == [cfg.name, 1, "sft"]
    got = pool.adapter_at(0)
    for t in adapters[1]:
        np.testing.assert_array_equal(got[t]["A"],
                                      np.asarray(adapters[1][t]["A"]))
        np.testing.assert_array_equal(got[t]["B"],
                                      np.asarray(adapters[1][t]["B"]))
    # wrong arch / spec version are refused before touching the pool
    bad_arch = str(tmp_path / "bad_arch.npz")
    save_pytree(bad_arch, adapters[0],
                meta={"rank": 4, "arch": "other-arch",
                      "spec_version": SPEC_VERSION})
    with pytest.raises(AssertionError):
        pool.publish_checkpoint(bad_arch)
    bad_ver = str(tmp_path / "bad_ver.npz")
    save_pytree(bad_ver, adapters[0],
                meta={"rank": 4, "arch": cfg.name, "spec_version": -1})
    with pytest.raises(AssertionError):
        pool.publish_checkpoint(bad_ver)
    assert pool.resident() == {"ckpt-a": 0}


# ---------------------------------------------------------------------------
# ServingFrontend: routing, rounds, admission
# ---------------------------------------------------------------------------

def test_frontend_routing_multi_round_deterministic(env):
    """More requests than lanes: the frontend serves multiple rounds, every
    request completes with exactly max_new tokens, and re-serving the same
    prompt in a later round reproduces the same continuation (rounds are
    independent cache epochs)."""
    cfg, params, adapters, ranks = env
    pool = AdapterPool(cfg, 3)
    for z in range(3):
        pool.publish(f"a{z}", adapters[z], ranks[z])
    rep = ServingReplica(cfg, params, pool, lanes=2, max_len=24)
    fe = ServingFrontend(rep, mode="round")
    rng = np.random.default_rng(7)
    prompts = {z: [_prompt(rng, cfg, int(rng.integers(3, 9)))
                   for _ in range(3)] for z in range(3)}
    rids = {(z, i): fe.submit(f"a{z}", prompts[z][i], 6)
            for z in range(3) for i in range(3)}
    out = fe.drain()
    assert fe.queued() == 0 and rep.rounds == 2      # 3 reqs over 2 lanes
    assert all(len(out[r]) == 6 for r in rids.values())
    # replay determinism across rounds
    replay = fe.submit("a1", prompts[1][0], 6)
    fe.drain()
    assert fe.result(replay) == out[rids[(1, 0)]]
    # unknown adapter and over-length requests are refused
    with pytest.raises(AdmissionError):
        fe.submit("nope", prompts[0][0], 4)
    with pytest.raises(AdmissionError):
        fe.submit("a0", prompts[0][0], 99)


def test_frontend_publish_admission_memory_model(env):
    """Round-mode publish admission against the §A.3+k2 model: rank-tokens
    are billed at TRUE rank over the pessimistic ``lanes x max_len``
    working set, a publish over budget is refused, retiring an adapter
    frees its charge. (Continuous mode instead charges actual per-request
    footprints at join time — covered below.)"""
    cfg, params, adapters, ranks = env
    pool = AdapterPool(cfg, 3)
    rep = ServingReplica(cfg, params, pool, lanes=2, max_len=16)
    lane_toks = 2 * 16                      # lanes x max_len per adapter
    # capacity fits two adapters (rank 4 + rank 8), not a third rank-2
    cap = (2 * lane_toks * 1.0 + (4 + 8) * lane_toks * 0.5) / 0.9 + 1.0
    mem = MemoryModel(k0=0.0, k1=1.0, seq_len=16, capacity=cap,
                      k2=0.5, r_max=cfg.lora.r_max)
    fe = ServingFrontend(rep, mem=mem, mode="round")
    fe.publish("a0", adapters[0], 4)
    fe.publish("a1", adapters[1], 8)
    with pytest.raises(AdmissionError):
        fe.publish("a2", adapters[2], 2)
    assert "a2" not in pool.resident()      # refused before pool mutation
    fe.retire("a1")                         # rank-8 charge freed
    fe.publish("a2", adapters[2], 2)        # rank-2 now fits
    assert set(pool.resident()) == {"a0", "a2"}
    assert fe.publishes == 3


# ---------------------------------------------------------------------------
# Continuous batching: per-lane positions, sampling, batched publish
# ---------------------------------------------------------------------------

def test_continuous_matches_round_greedy(env):
    """Greedy continuous decode reproduces the round baseline token-for-
    token — homogeneous prompts joining at t=0 AND a ragged-length backlog
    whose round mode pads every stream to the slowest — while spending
    strictly fewer fused decode steps on the ragged set (the per-lane
    causal mask is exercised by every mid-decode lane reuse)."""
    cfg, params, adapters, ranks = env
    rng = np.random.default_rng(11)
    cases = [
        [5, 5, 5, 5, 5, 5],          # homogeneous, t=0 joiners
        [3, 9, 4, 7, 5, 6, 8, 3],    # ragged backlog, mid-decode joins
    ]
    ragged_steps = {}
    for lens in cases:
        prompts = [_prompt(rng, cfg, n) for n in lens]
        outs, steps = {}, {}
        for mode in ("round", "continuous"):
            pool = AdapterPool(cfg, 3)
            for z in range(3):
                pool.publish(f"a{z}", adapters[z], ranks[z])
            rep = ServingReplica(cfg, params, pool, lanes=2, max_len=24)
            fe = ServingFrontend(rep, mode=mode)
            rids = [fe.submit(f"a{i % 3}", p, 6)
                    for i, p in enumerate(prompts)]
            res = fe.drain()
            outs[mode] = [res[r] for r in rids]
            steps[mode] = rep.total_decode_steps
            if mode == "continuous":
                assert len(rep.records) == len(prompts)
                assert all(rec.new_tokens == 6 for rec in rep.records)
                assert all(rec.total_s >= rec.queue_s + rec.prefill_s
                           + rec.decode_s - 1e-6 for rec in rep.records)
        assert outs["round"] == outs["continuous"]
        ragged_steps = steps
    # the ragged case must save fused steps (the zero-barrier win)
    assert ragged_steps["continuous"] < ragged_steps["round"]


def test_continuous_ring_per_lane_mask(env):
    """Ring caches carry PER-LANE k_pos: a lane re-joined mid-decode on a
    wrapped ring must not see its previous occupant's K/V (the join
    resets k_pos so the window term masks stale slots). Continuous ring
    decode must match the round-mode ring baseline token-for-token."""
    cfg, params, adapters, ranks = env
    rng = np.random.default_rng(13)
    prompts = [_prompt(rng, cfg, n) for n in (3, 7, 4, 6, 5, 8)]
    outs = []
    for mode in ("round", "continuous"):
        pool = AdapterPool(cfg, 3)
        for z in range(3):
            pool.publish(f"a{z}", adapters[z], ranks[z])
        rep = ServingReplica(cfg, params, pool, lanes=2, max_len=24,
                             ring=True)
        assert rep.ring
        fe = ServingFrontend(rep, mode=mode)
        rids = [fe.submit(f"a{i % 3}", p, 6) for i, p in enumerate(prompts)]
        res = fe.drain()
        outs.append([res[r] for r in rids])
    assert outs[0] == outs[1]


def test_sampling_deterministic_under_fixed_seed(env):
    """Per-request temperature/top_k sampling keys off
    fold_in(fold_in(sample_seed, request.seed), token_index): two
    identically-seeded runs produce identical streams, and the greedy
    default stays independent of the replica's sample seed (the bitwise
    path)."""
    cfg, params, adapters, ranks = env
    rng = np.random.default_rng(5)
    prompt = _prompt(rng, cfg, 6)

    def run(sample_seed, temperature):
        pool = AdapterPool(cfg, 2)
        pool.publish("a0", adapters[0], ranks[0])
        pool.publish("a1", adapters[1], ranks[1])
        rep = ServingReplica(cfg, params, pool, lanes=2, max_len=16,
                             sample_seed=sample_seed)
        fe = ServingFrontend(rep)
        rids = [fe.submit(a, prompt, 8, temperature=temperature,
                          top_k=16, seed=3) for a in ("a0", "a1")]
        res = fe.drain()
        return [res[r] for r in rids]

    assert run(9, 0.7) == run(9, 0.7)           # deterministic
    assert run(0, 0.0) == run(42, 0.0)          # greedy ignores the seed


def test_continuous_join_admission_actual_tokens(env):
    """Continuous-mode admission charges a request's ACTUAL footprint
    (prompt + max_new tokens, rank-tokens at the adapter's charged rank)
    against the in-flight sum — not the pessimistic lanes x max_len
    reserve. A budget sized for one such request at a time still serves a
    3-deep backlog by deferring joins until charges release, and a
    request that can never fit is refused at submit."""
    cfg, params, adapters, ranks = env
    pool = AdapterPool(cfg, 3)
    pool.publish("a0", adapters[0], 4)
    rep = ServingReplica(cfg, params, pool, lanes=2, max_len=16)
    # budget 36.0: one 8-token request costs 8 + 0.5*4*8 = 24, two = 48
    mem = MemoryModel(k0=0.0, k1=1.0, seq_len=16, capacity=40.0,
                      k2=0.5, r_max=cfg.lora.r_max)
    fe = ServingFrontend(rep, mem=mem)
    rng = np.random.default_rng(3)
    rids = [fe.submit("a0", _prompt(rng, cfg, 4), 4) for _ in range(3)]
    out = fe.drain()
    assert all(len(out[r]) == 4 for r in rids)
    assert fe.deferred_joins > 0        # lanes were free, memory was not
    with pytest.raises(AdmissionError):  # 16 + 0.5*4*16 = 48 > 36: never fits
        fe.submit("a0", _prompt(rng, cfg, 8), 8)


def test_publish_many_batched(env):
    """publish_many lands N adapters with one fused slot update,
    bitwise-identical to N sequential publishes; an over-capacity batch
    is refused atomically (no partial landing)."""
    cfg, params, adapters, ranks = env
    seq = AdapterPool(cfg, 3)
    for z in range(3):
        seq.publish(f"a{z}", adapters[z], ranks[z])
    bat = AdapterPool(cfg, 3)
    slots = bat.publish_many(
        [(f"a{z}", adapters[z], ranks[z]) for z in range(3)])
    assert slots == [0, 1, 2]
    assert bat.resident() == seq.resident()
    assert bat.slot_rank == seq.slot_rank
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x),
                                                   np.asarray(y)),
        bat.lora, seq.lora)
    assert bat.version == 3
    assert len(bat.publish_latencies_s) == 3    # amortized, one per adapter
    with pytest.raises(PoolFull):
        bat.publish_many([("b0", adapters[0], 4)])
    part = AdapterPool(cfg, 2)
    with pytest.raises(PoolFull):
        part.publish_many([(f"c{z}", adapters[z], ranks[z])
                           for z in range(3)])
    assert part.resident() == {}        # refused before any mutation


def test_queue_publish_drains_between_steps(env):
    """queue_publish defers adapters to the next continuous step boundary
    and lands the burst as ONE batched publish_many; slot admission still
    fails fast at queue time, counting the pending burst."""
    cfg, params, adapters, ranks = env
    pool = AdapterPool(cfg, 3)
    rep = ServingReplica(cfg, params, pool, lanes=2, max_len=24)
    fe = ServingFrontend(rep)
    fe.publish("a0", adapters[0], ranks[0])
    fe.queue_publish("a1", adapters[1], ranks[1])
    fe.queue_publish("a2", adapters[2], ranks[2])
    with pytest.raises(AdmissionError):  # 1 resident + 2 pending = full
        fe.queue_publish("b0", adapters[0], 4)
    assert pool.resident() == {"a0": 0}  # nothing landed yet
    rng = np.random.default_rng(1)
    rid = fe.submit("a0", _prompt(rng, cfg, 5), 4)
    out = fe.drain()
    assert len(out[rid]) == 4
    assert set(pool.resident()) == {"a0", "a1", "a2"}
    assert fe.publishes == 3 and pool.version == 3
    rid2 = fe.submit("a2", _prompt(rng, cfg, 5), 4)  # fresh adapter serves
    assert len(fe.drain()[rid2]) == 4


# ---------------------------------------------------------------------------
# Serving replicas are first-class cluster residents
# ---------------------------------------------------------------------------

def test_serving_lease_holds_gpus_in_planner():
    """A serving lease occupies planner-visible GPUs: on a 2-GPU cluster
    with a 1-GPU lease of 100s, two 40s 1-GPU training tasks must
    serialize on the remaining GPU (makespan 100) instead of running in
    parallel (makespan 40) — the planner genuinely accounts the replica."""
    from repro.core.service import TuningService
    from repro.sched.cluster import SimulatedTaskDriver, sim_task_spec

    def sim(name):
        spec = sim_task_spec(name, K=1, Z=1, total_steps=40,
                             warmup_steps=1, step_time_s=1.0, gpus=1)

        def factory():
            return SimulatedTaskDriver(name, K=1, Z=1, total_steps=40,
                                       warmup_steps=1, step_time_s=1.0)
        return spec, factory

    svc = TuningService(total_gpus=2)
    sh = svc.attach_serving(None, gpus=1, horizon_s=100.0, chunk_s=10.0)
    handles = []
    for n in ("t1", "t2"):
        spec, fac = sim(n)
        handles.append(svc.submit_spec(spec, fac, scale_duration=False))
    report = svc.run_until_idle()
    ends = report.task_ends
    assert ends["serve/replica-0"] == pytest.approx(100.0)
    assert max(ends["t1"], ends["t2"]) >= 80.0 - 1e-6   # serialized
    assert report.makespan == pytest.approx(100.0)
    lease = sh.result()
    assert lease["kind"] == "serving_replica"
    # GPU-seconds: lease held one GPU its whole horizon
    assert sum(report.runtime.gpu_busy) >= 100.0 + 80.0 - 1e-6


def test_serving_lease_cancel_frees_gpus():
    """Retiring the replica early (cancel) releases its GPUs to pending
    training work — teardown needs no new runtime mechanics."""
    from repro.core.service import TuningService
    from repro.sched.cluster import SimulatedTaskDriver, sim_task_spec

    svc = TuningService(total_gpus=1)
    sh = svc.attach_serving(None, gpus=1, horizon_s=500.0, chunk_s=10.0)
    spec = sim_task_spec("t1", K=1, Z=1, total_steps=20, warmup_steps=1,
                         step_time_s=1.0, gpus=1)
    h = svc.submit_spec(
        spec, lambda: SimulatedTaskDriver("t1", K=1, Z=1, total_steps=20,
                                          warmup_steps=1, step_time_s=1.0),
        scale_duration=False)
    sh.cancel(at=50.0)
    h.result()
    report = svc.run_until_idle()
    assert report.task_starts["t1"] >= 50.0 - 1e-6     # waited on the lease
    assert report.task_ends["t1"] == pytest.approx(70.0)
    assert "serve/replica-0" in report.cancelled


# ---------------------------------------------------------------------------
# Tune-to-serve end to end
# ---------------------------------------------------------------------------

def test_tune_to_serve_end_to_end(env, tmp_path):
    """TuningService.submit() -> early exit -> winning adapter checkpointed
    (rank + fuse key + spec version) -> auto-published from the durable
    artifact -> a served query answers with the winner's continuation."""
    from repro.core import engine as alto
    from repro.core.early_exit import EarlyExitConfig
    from repro.core.service import TuningService
    from repro.data.synthetic import make_task_dataset
    from repro.sched.events import EventKind

    cfg, params, adapters, _ = env
    ds = make_task_dataset("t2s", cfg.vocab_size, seq_len=16, num_train=32,
                           num_val=8, difficulty=0.2)
    serve_dir = str(tmp_path / "serve")
    svc = TuningService(total_gpus=2, eval_every=2, serve_dir=serve_dir)
    pool = AdapterPool(cfg, 2)
    rep = ServingReplica(cfg, params, pool, lanes=2, max_len=16)
    fe = ServingFrontend(rep)
    svc.attach_serving(fe, gpus=1, horizon_s=10_000.0)
    task = alto.Task(model=cfg, dataset=ds, num_gpus=1, max_steps=6,
                     num_slots=2, name="tenant-a",
                     search_space={"lr": [1e-3, 3e-3], "rank": [4]})
    res = svc.submit(task, early_exit=EarlyExitConfig(
        warmup_ratio=0.2, select_ratio=0.5)).result()
    # early exit really happened (warmup selection dropped a job)
    assert res.samples_saved_frac > 0.0
    # durable artifact with full publish metadata
    path = svc._ckpt_paths["tenant-a"]
    data = np.load(path, allow_pickle=False)
    meta = json.loads(str(data["__meta__"]))
    jr = res.job_results[res.best_job]
    assert meta["rank"] == jr.config.lora_rank
    assert meta["spec_version"] == SPEC_VERSION
    assert meta["arch"] == cfg.name
    assert meta["fuse_key"] == [cfg.name, 1, "sft"]
    assert meta["job"] == res.best_job
    # hot-published (no replica restart) with an audit event
    assert pool.resident() == {"tenant-a": 0}
    assert pool.slot_rank[0] == jr.config.lora_rank
    evs = [e for e in svc._runtime_events()
           if e.kind is EventKind.ADAPTER_PUBLISHED]
    assert len(evs) == 1 and evs[0].reason == "published"
    assert "from=checkpoint" in evs[0].detail
    # the served query is answered by the WINNING adapter: publishing the
    # raw best-job adapter from the result into a fresh pool reproduces
    # the continuation token-for-token
    prompt = np.arange(5, dtype=np.int32) % cfg.vocab_size
    rid = fe.submit("tenant-a", prompt, 6)
    fe.drain()
    pool2 = AdapterPool(cfg, 2)
    pool2.publish("tenant-a", jr.adapter, jr.config.lora_rank)
    rep2 = ServingReplica(cfg, params, pool2, lanes=2, max_len=16)
    fe2 = ServingFrontend(rep2)
    rid2 = fe2.submit("tenant-a", prompt, 6)
    fe2.drain()
    assert fe.result(rid) == fe2.result(rid2)


def test_tune_to_serve_pool_full_keeps_artifact(env, tmp_path):
    """When the pool has no free slot the publish is refused (audit event,
    reason=refused) but the checkpoint artifact survives for a later
    publish — durable state outlives admission pressure."""
    from repro.core import engine as alto
    from repro.core.early_exit import EarlyExitConfig
    from repro.core.service import TuningService
    from repro.data.synthetic import make_task_dataset
    from repro.sched.events import EventKind

    cfg, params, adapters, ranks = env
    ds = make_task_dataset("t2s2", cfg.vocab_size, seq_len=16, num_train=32,
                           num_val=8, difficulty=0.3)
    serve_dir = str(tmp_path / "serve")
    svc = TuningService(total_gpus=2, eval_every=2, serve_dir=serve_dir)
    pool = AdapterPool(cfg, 1)
    pool.publish("squatter", adapters[0], 4)        # pool already full
    rep = ServingReplica(cfg, params, pool, lanes=1, max_len=16)
    fe = ServingFrontend(rep)
    svc.attach_serving(fe, gpus=1, horizon_s=10_000.0)
    task = alto.Task(model=cfg, dataset=ds, num_gpus=1, max_steps=4,
                     num_slots=1, name="tenant-b",
                     search_space={"lr": [1e-3]})
    svc.submit(task, early_exit=EarlyExitConfig(
        warmup_ratio=0.25, select_ratio=1.0)).result()
    evs = [e for e in svc._runtime_events()
           if e.kind is EventKind.ADAPTER_PUBLISHED]
    assert len(evs) == 1 and evs[0].reason == "refused"
    assert "tenant-b" not in pool.resident()
    # the durable artifact is still publishable once capacity frees up
    fe.retire("squatter")
    aid = fe.publish_checkpoint(svc._ckpt_paths["tenant-b"])
    assert aid == "tenant-b" and pool.resident() == {"tenant-b": 0}
