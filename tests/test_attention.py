"""Chunked flash-style attention vs naive reference; GQA; sliding window."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import attention


def naive(q, k, v, q_pos, k_pos, window=0, valid=None):
    Z, b, Sq, H, hd = q.shape
    KV = k.shape[3]
    G = H // KV
    kk = jnp.repeat(k, G, axis=3)
    vv = jnp.repeat(v, G, axis=3)
    scores = jnp.einsum("zbqhd,zbshd->zbhqs", q, kk) / np.sqrt(hd)
    vis = k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        vis &= k_pos[None, :] > (q_pos[:, None] - window)
    if valid is not None:
        vis &= (jnp.arange(k.shape[2]) < valid)[None, :]
    scores = jnp.where(vis, scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("zbhqs,zbshd->zbqhd", p, vv)


@pytest.mark.parametrize("H,KV", [(4, 4), (8, 2), (6, 1)])
@pytest.mark.parametrize("window", [0, 7])
def test_matches_naive(H, KV, window):
    Z, b, S, hd = 2, 2, 32, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (Z, b, S, H, hd))
    k = jax.random.normal(ks[1], (Z, b, S, KV, hd))
    v = jax.random.normal(ks[2], (Z, b, S, KV, hd))
    pos = jnp.arange(S)
    got = attention(q, k, v, pos, pos, window=window, q_chunk=8)
    want = naive(q, k, v, pos, pos, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_chunking_invariance():
    Z, b, S, H, hd = 1, 2, 64, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (Z, b, S, H, hd))
    k = jax.random.normal(ks[1], (Z, b, S, H, hd))
    v = jax.random.normal(ks[2], (Z, b, S, H, hd))
    pos = jnp.arange(S)
    outs = [attention(q, k, v, pos, pos, q_chunk=c)
            for c in (8, 16, 32, 64)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-6, atol=1e-6)


def test_decode_against_cache_with_valid_len():
    """One query vs a partially filled cache."""
    Z, b, Sc, H, hd = 1, 1, 16, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    pos_now = 9
    q = jax.random.normal(ks[0], (Z, b, 1, H, hd))
    k = jax.random.normal(ks[1], (Z, b, Sc, H, hd))
    v = jax.random.normal(ks[2], (Z, b, Sc, H, hd))
    got = attention(q, k, v, jnp.array([pos_now]), jnp.arange(Sc),
                    kv_valid_len=jnp.array(pos_now + 1))
    want = naive(q, k, v, jnp.array([pos_now]), jnp.arange(Sc),
                 valid=pos_now + 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_fully_masked_rows_are_finite():
    """Ring-buffer slots from the far past: no NaN from empty softmax rows."""
    Z, b, H, hd, Sc = 1, 1, 2, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (Z, b, 1, H, hd))
    k = jax.random.normal(ks[1], (Z, b, Sc, H, hd))
    v = jax.random.normal(ks[2], (Z, b, Sc, H, hd))
    k_pos = jnp.full((Sc,), -(1 << 30))
    out = attention(q, k, v, jnp.array([0]), k_pos, window=4)
    assert bool(jnp.all(jnp.isfinite(out)))
