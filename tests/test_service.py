"""TuningService (paper §4 service API): dynamic arrivals, cancellation,
status/result handles, the profiler feedback loop, and the release-aware
residual solver.

The makespan property mirrors online rigid-job scheduling theory: without
preemption or migration an online scheduler is 2-competitive against full
hindsight, so an arrival trace must realize

    service_mk <= t_last + 2 * hindsight_static_mk + chunk_slack

where the hindsight baseline solves ALL tasks at the last arrival time and
executes the static plan from an empty cluster, and chunk_slack accounts
for arrivals landing inside an atomic executor chunk. (The tighter
``<= t_last + hindsight_mk`` holds on the vast majority of traces but is
violated by genuine online packing losses — wide tasks serializing behind
early commitments — so it is not assertable.)"""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.early_exit import EarlyExitConfig
from repro.core.service import TaskCancelled, TaskState, TuningService
from repro.sched import profiler
from repro.sched.cluster import (SimulatedTaskDriver, execute_static,
                                 sim_task_spec)
from repro.sched.events import EventKind
from repro.sched.inter_task import TaskSpec, list_schedule, solve

CHUNK_STEPS = 5      # SimulatedTaskDriver default


def sim_task(name, *, K, Z, total, warm, step_time, gpus, exits=None):
    spec = sim_task_spec(name, K=K, Z=Z, total_steps=total,
                         warmup_steps=warm, step_time_s=step_time, gpus=gpus)

    def factory():
        return SimulatedTaskDriver(name, K=K, Z=Z, total_steps=total,
                                   warmup_steps=warm, step_time_s=step_time,
                                   exit_step=exits or {})
    return spec, factory


def random_arrival_workload(rng, G):
    """Heterogeneous mix with staggered arrivals (first task at t=0)."""
    n = int(rng.integers(2, 7))
    tasks = []
    for i in range(n):
        K = int(rng.integers(2, 20))
        Z = int(rng.integers(1, 6))
        total = int(rng.integers(10, 150))
        warm = int(rng.integers(1, max(total // 4, 2)))
        step_time = float(rng.uniform(0.005, 0.05))
        gpus = int(rng.integers(1, G + 1))
        n_exits = int(rng.integers(0, K + 1))
        exits = {int(j): int(rng.integers(1, total)) for j in
                 rng.choice(K, size=n_exits, replace=False)}
        at = float(rng.uniform(0.0, 5.0)) if i else 0.0
        spec, factory = sim_task(f"t{i}", K=K, Z=Z, total=total, warm=warm,
                                 step_time=step_time, gpus=gpus, exits=exits)
        tasks.append((spec, factory, at, CHUNK_STEPS * step_time))
    return tasks


# ---------------------------------------------------------------------------
# dynamic arrivals: the online-vs-hindsight makespan property
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=15, derandomize=True)
@given(seed=st.integers(0, 10_000), G=st.sampled_from([2, 4, 8]),
       delta=st.sampled_from([None, 1.0, 2.0]))
def test_property_arrival_trace_within_competitive_bound(seed, G, delta):
    rng = np.random.default_rng(seed)
    tasks = random_arrival_workload(rng, G)
    svc = TuningService(total_gpus=G, delay_delta=delta)
    handles = [svc.submit_spec(spec, fac, at=at)
               for spec, fac, at, _ in tasks]
    report = svc.run_until_idle()

    # hindsight baseline: solve everything at the last arrival, execute the
    # static plan from an empty cluster
    t_last = max(at for _, _, at, _ in tasks)
    plan = solve([s for s, _, _, _ in tasks], G, "cp")
    static = execute_static(plan, G, {s.name: f for s, f, _, _ in tasks})
    chunk_slack = sum(c for _, _, at, c in tasks if at > 0)
    assert report.makespan <= t_last + 2 * static.makespan + chunk_slack \
        + 1e-9
    # validity + terminal states + releases respected
    report.runtime.realized.validate(G)
    for h, (_, _, at, _) in zip(handles, tasks):
        assert h.status().state is TaskState.COMPLETED
        assert report.task_starts[h.name] >= at - 1e-9
    kinds = {e.kind for e in report.events}
    assert EventKind.TASK_ARRIVED in kinds


@settings(deadline=None, max_examples=10, derandomize=True)
@given(seed=st.integers(0, 10_000), G=st.sampled_from([4, 8]))
def test_property_cancellations_always_terminal(seed, G):
    rng = np.random.default_rng(seed)
    tasks = random_arrival_workload(rng, G)
    svc = TuningService(total_gpus=G)
    handles = [svc.submit_spec(spec, fac, at=at)
               for spec, fac, at, _ in tasks]
    # cancel a random subset at random virtual times
    n_cancel = int(rng.integers(1, len(tasks) + 1))
    for idx in rng.choice(len(tasks), size=n_cancel, replace=False):
        svc.cancel(tasks[int(idx)][0].name,
                   at=float(rng.uniform(0.0, 8.0)))
    report = svc.run_until_idle()
    report.runtime.realized.validate(G)
    for h in handles:
        st_ = h.status()
        assert st_.state.terminal, h.name
        if st_.state is TaskState.CANCELLED:
            assert h.name not in report.task_results
            with pytest.raises(TaskCancelled):
                h.result()
        else:
            assert report.task_results[h.name] is not None


# ---------------------------------------------------------------------------
# cancellation frees capacity that pending work reclaims
# ---------------------------------------------------------------------------

def test_cancel_frees_capacity_for_pending_task():
    G = 4
    big_spec, big_fac = sim_task("big", K=8, Z=4, total=400, warm=10,
                                 step_time=0.02, gpus=4)
    next_spec, next_fac = sim_task("next", K=4, Z=2, total=100, warm=5,
                                   step_time=0.02, gpus=4)

    def run(cancel_at):
        svc = TuningService(total_gpus=G)
        svc.submit_spec(big_spec, big_fac)
        svc.submit_spec(next_spec, next_fac)
        if cancel_at is not None:
            svc.cancel("big", at=cancel_at)
        return svc.run_until_idle()

    baseline = run(None)
    cancelled = run(1.0)
    assert "big" in cancelled.cancelled
    # the pending task reclaims the freed GPUs immediately (modulo the
    # in-flight chunk) instead of waiting for big's worst-case end
    assert cancelled.task_starts["next"] <= 1.0 + CHUNK_STEPS * 0.02 + 1e-9
    assert cancelled.task_starts["next"] < baseline.task_starts["next"] - 1e-9
    assert cancelled.makespan < baseline.makespan - 1e-9


def test_cancel_before_arrival_withdraws_task():
    svc = TuningService(total_gpus=2)
    spec, fac = sim_task("a", K=2, Z=2, total=20, warm=2, step_time=0.01,
                         gpus=1)
    spec_b, fac_b = sim_task("b", K=2, Z=2, total=20, warm=2, step_time=0.01,
                             gpus=1)
    ha = svc.submit_spec(spec, fac)
    hb = svc.submit_spec(spec_b, fac_b, at=5.0)
    hb.cancel(at=1.0)
    report = svc.run_until_idle()
    assert ha.status().state is TaskState.COMPLETED
    assert hb.status().state is TaskState.CANCELLED
    # b never ran: no start recorded, no work billed
    assert "b" not in report.task_starts
    assert hb.status().started_at is None


# ---------------------------------------------------------------------------
# handles: status transitions, event streams, late submissions
# ---------------------------------------------------------------------------

def test_handle_stream_and_session_reactivation():
    svc = TuningService(total_gpus=2)
    spec, fac = sim_task("a", K=4, Z=2, total=40, warm=4, step_time=0.01,
                         gpus=2)
    h = svc.submit_spec(spec, fac)
    assert h.status().state is TaskState.PENDING
    kinds = [e.kind for e in h.stream()]
    assert kinds[0] is EventKind.TASK_SUBMITTED
    assert EventKind.TASK_STARTED in kinds
    assert kinds[-1] is EventKind.TASK_COMPLETED
    assert h.status().state is TaskState.COMPLETED
    # the session stays open: a later submission re-activates the loop
    spec2, fac2 = sim_task("late", K=2, Z=2, total=20, warm=2,
                           step_time=0.01, gpus=1)
    h2 = svc.submit_spec(spec2, fac2, at=svc.now + 3.0)
    assert h2.status().state is TaskState.PENDING
    h2.result()
    assert h2.status().state is TaskState.COMPLETED
    assert svc.status("late").started_at >= svc.status("a").finished_at


# ---------------------------------------------------------------------------
# profiler feedback loop
# ---------------------------------------------------------------------------

def test_profile_store_record_scale_and_spec_cache():
    store = profiler.ProfileStore(ema=0.5)
    key = ("arch", 2)
    assert store.duration_scale(key) == 1.0
    assert store.wall_step_time(key) is None
    assert store.scaled_duration(key, 10.0) == 10.0
    store.put_spec(("t", "ee"), "SPEC")
    assert store.get_spec(("t", "ee")) == "SPEC"
    store.record(key, realized_duration=5.0, estimated_duration=10.0,
                 wall_step_time_s=0.7)
    assert store.duration_scale(key) == 0.5
    assert store.scaled_duration(key, 10.0) == 5.0
    assert store.wall_step_time(key) == 0.7
    # new observations invalidate cached specs (feedback must take effect)
    assert store.get_spec(("t", "ee")) is None
    # EMA moves toward the new observation; frac clamped to [0, 1]
    store.record(key, realized_duration=20.0, estimated_duration=10.0)
    assert store.duration_scale(key) == pytest.approx(0.75)
    assert store.wall_step_time(key) == 0.7      # None obs leaves the EMA
    assert store.observations(key) == 2


def test_feedback_shrinks_estimates_and_changes_schedule():
    """Two identical sessions sharing a ProfileStore: the second schedules
    from observed durations and realizes different (earlier) starts."""
    store = profiler.ProfileStore()
    key = ("archX", 2)

    def run_session():
        svc = TuningService(total_gpus=4, profile_store=store)
        # every job exits right after warmup: realized << worst case
        s1, f1 = sim_task("first", K=8, Z=4, total=200, warm=10,
                          step_time=0.02, gpus=2,
                          exits={j: 15 for j in range(8)})
        s2, f2 = sim_task("second", K=8, Z=4, total=200, warm=10,
                          step_time=0.02, gpus=2,
                          exits={j: 15 for j in range(8)})
        h1 = svc.submit_spec(s1, f1, at=0.0, profile_key=key)
        h2 = svc.submit_spec(s2, f2, at=0.5, profile_key=key)
        rep = svc.run_until_idle()
        est2 = svc._meta["second"].spec.duration
        return rep, est2, (h1, h2)

    analytic, est_analytic, _ = run_session()
    assert store.observations(key) == 2          # feedback recorded
    assert store.duration_scale(key) < 1.0
    store2_scale = store.duration_scale(key)
    fedback, est_fedback, handles = run_session()
    # the fed-back session plans "second" from observed durations
    assert est_fedback < est_analytic - 1e-9
    assert all(h.status().state is TaskState.COMPLETED for h in handles)
    assert store.duration_scale(key) <= store2_scale + 1e-9


# ---------------------------------------------------------------------------
# release-aware residual solver
# ---------------------------------------------------------------------------

def test_solver_respects_release_times():
    sched = list_schedule([TaskSpec("x", 1.0, 1, release=3.0)], 2)
    assert sched.placements[0].start == 3.0
    sched.validate(2)
    specs = [TaskSpec("a", 2.0, 2), TaskSpec("b", 1.0, 1, release=5.0)]
    s = solve(specs, 2, "cp")
    s.validate(2)
    by = {p.task.name: p for p in s.placements}
    assert by["a"].start == 0.0
    assert by["b"].start >= 5.0 - 1e-9
    # release violation trips validation
    bad = dataclasses.replace(s)
    bad.placements = [dataclasses.replace(by["b"], start=0.0)]
    with pytest.raises(AssertionError):
        bad.validate(2)


# ---------------------------------------------------------------------------
# real engine end-to-end (the acceptance scenario)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_env():
    from repro.data.synthetic import make_task_dataset
    from tests.conftest import reduced_f32
    cfg = reduced_f32("paper-llama-tiny", num_layers=2, d_model=128,
                      vocab=256)
    ds = make_task_dataset("svc", cfg.vocab_size, seq_len=32, num_train=64,
                           num_val=16, difficulty=0.2)
    return cfg, ds


def test_service_real_engine_dynamic_session(tiny_env):
    """Three heterogeneous tasks at staggered virtual times — one submitted
    mid-flight, one cancelled — all handles terminal with correct
    best-adapter results, and the feedback loop recorded."""
    from repro.core import engine as alto
    cfg, ds = tiny_env
    ee = EarlyExitConfig(warmup_ratio=0.2, select_ratio=0.5)
    svc = TuningService(total_gpus=4, eval_every=2)
    task_a = alto.Task(model=cfg, dataset=ds, num_gpus=2, max_steps=10,
                       num_slots=2, name="tenant-a",
                       search_space={"lr": [1e-3, 3e-3], "batch_size": [2]})
    task_b = alto.Task(model=cfg, dataset=ds, num_gpus=1, max_steps=10,
                       num_slots=2, name="tenant-b",
                       search_space={"lr": [1e-3], "rank": [4, 8]})
    task_c = alto.Task(model=cfg, dataset=ds, num_gpus=4, max_steps=10,
                       num_slots=2, name="tenant-c",
                       search_space={"lr": [3e-3], "rank": [4]})
    ha = svc.submit(task_a, at=0.0, early_exit=ee)
    # mid-flight: inside tenant-a's estimated run
    mid = 0.4 * svc._meta["tenant-a"].spec.duration
    hb = svc.submit(task_b, at=mid, early_exit=ee)
    hc = svc.submit(task_c, at=2 * svc._meta["tenant-a"].spec.duration,
                    early_exit=ee)
    hc.cancel(at=mid)                     # withdrawn before it ever runs
    report = svc.run_until_idle()

    assert ha.status().state is TaskState.COMPLETED
    assert hb.status().state is TaskState.COMPLETED
    assert hc.status().state is TaskState.CANCELLED
    for handle in (ha, hb):
        tr = handle.result()
        assert np.isfinite(tr.best_val)
        assert tr.best_job in tr.job_results
        assert tr.job_results[tr.best_job].adapter is not None
    with pytest.raises(TaskCancelled):
        hc.result()
    assert report.task_starts["tenant-b"] >= mid - 1e-9
    assert "tenant-c" in report.cancelled
    # feedback loop live: realized durations recorded for completed tasks,
    # including the realized host wall step time (separate clock from the
    # virtual timeline)
    key_a = svc.engine.profile_key(task_a)
    assert svc.profile_store.observations(key_a) >= 1
    assert svc.profile_store.wall_step_time(key_a) > 0.0
    kinds = {e.kind for e in report.events}
    assert EventKind.TASK_ARRIVED in kinds
    assert EventKind.TASK_CANCELLED in kinds


def test_engine_report_ergonomics_both_paths(tiny_env):
    """Satellite: events defaults to a list (not None) and utilization /
    replans are populated on both execution paths."""
    from repro.core import engine as alto
    cfg, ds = tiny_env
    engine = alto.Engine(total_gpus=2)
    tasks = [alto.Task(model=cfg, dataset=ds, num_gpus=1, max_steps=6,
                       num_slots=2, name="solo",
                       search_space={"lr": [1e-3, 3e-3]})]
    ee = EarlyExitConfig(warmup_ratio=0.2, select_ratio=0.5)
    schedule = engine.schedule(tasks, method="cp", early_exit=ee)
    static = engine.batched_execution(tasks, schedule, ee, strategy="static")
    elastic = engine.batched_execution(tasks, schedule, ee)
    assert static.events == [] and isinstance(static.events, list)
    assert static.utilization > 0.0
    assert static.replans == 0
    assert isinstance(elastic.events, list) and elastic.events
    assert elastic.utilization > 0.0
    for rep in (static, elastic):
        assert set(rep.task_results) == {"solo"}


# ---------------------------------------------------------------------------
# ProfileStore persistence + shared-replica routing
# ---------------------------------------------------------------------------

def test_profile_store_save_load_roundtrip(tmp_path):
    path = str(tmp_path / "profile.json")
    store = profiler.ProfileStore(ema=0.4)
    store.record(("arch-a", 2), realized_duration=30.0,
                 estimated_duration=100.0, wall_step_time_s=0.02,
                 wall_token_time_s=1e-4)
    store.record(("arch-a", 2), realized_duration=50.0,
                 estimated_duration=100.0)
    store.record(("arch-b", 1), realized_duration=80.0,
                 estimated_duration=100.0, wall_step_time_s=0.5)
    store.save(path)
    loaded = profiler.ProfileStore.load(path)
    assert loaded.ema == store.ema
    for key in (("arch-a", 2), ("arch-b", 1)):
        assert loaded.duration_scale(key) == store.duration_scale(key)
        assert loaded.wall_step_time(key) == store.wall_step_time(key)
        assert loaded.wall_token_time(key) == store.wall_token_time(key)
        assert loaded.observations(key) == store.observations(key)
    assert loaded.wall_token_time(("arch-a", 2)) == 1e-4
    assert profiler.ProfileStore.load_or_new(
        str(tmp_path / "absent.json")).observations(("arch-a", 2)) == 0


def test_service_persists_feedback_across_sessions(tmp_path):
    """ROADMAP service hardening: feedback observed by one service
    process seeds the next one's admissions (shorter estimates)."""
    path = str(tmp_path / "profile.json")
    spec, factory = sim_task("t0", K=8, Z=4, total=100, warm=5,
                             step_time=0.02, gpus=1,
                             exits={j: 10 for j in range(8)})

    svc1 = TuningService(total_gpus=2, profile_path=path)
    svc1.submit_spec(spec, factory, profile_key=("arch-a", 1))
    svc1.run_until_idle()                     # saves the store on idle
    assert svc1.profile_store.observations(("arch-a", 1)) == 1

    svc2 = TuningService(total_gpus=2, profile_path=path)
    assert svc2.profile_store.observations(("arch-a", 1)) == 1
    h = svc2.submit_spec(dataclasses.replace(spec, name="t1"), factory,
                         profile_key=("arch-a", 1))
    # admission consulted the loaded feedback: estimate shrank
    assert svc2._meta["t1"].spec.duration < spec.duration - 1e-9
    h.result()


def test_service_routes_small_tasks_onto_live_replicas():
    """A small fusable submission lands on a live shared replica instead
    of waiting for free GPUs (colocate defaults on)."""
    from repro.sched.cluster import sim_colo_spec

    key = ("arch-a", 1, 4, 64, "sft")
    host_spec, host_f = sim_task("host", K=8, Z=4, total=400, warm=20,
                                 step_time=0.01, gpus=1)
    hog_spec, hog_f = sim_task("hog", K=8, Z=4, total=400, warm=20,
                               step_time=0.01, gpus=1)
    small_spec, small_f = sim_task("small", K=2, Z=2, total=60, warm=3,
                                   step_time=0.01, gpus=1)

    def session(colocate):
        svc = TuningService(total_gpus=2, colocate=colocate)
        svc.submit_spec(host_spec, host_f,
                        colo=sim_colo_spec(key, K=8, Z=4))
        svc.submit_spec(hog_spec, hog_f)
        svc.submit_spec(small_spec, small_f, at=1.0,
                        colo=sim_colo_spec(key, K=2, Z=2))
        return svc.run_until_idle()

    fused = session(colocate=True)
    excl = session(colocate=False)
    assert fused.colocated == {"small": "host"}
    assert excl.colocated == {}
    assert fused.task_starts["small"] < excl.task_starts["small"] - 1e-9
    assert fused.makespan < excl.makespan - 1e-9
    assert set(fused.task_results) == {"host", "hog", "small"}


# ---------------------------------------------------------------------------
# per-tenant quotas (service hardening) + ragged routing / feedback
# ---------------------------------------------------------------------------

def test_per_tenant_quota_enforced_at_submit():
    """A tenant may hold at most max_tasks_per_tenant non-terminal tasks;
    submissions past the quota raise QuotaExceeded BEFORE admission, and
    capacity frees once the tenant's tasks finish (or are cancelled)."""
    from repro.core.service import QuotaExceeded

    svc = TuningService(total_gpus=2, max_tasks_per_tenant=2)
    mk = lambda n: sim_task(n, K=2, Z=2, total=20, warm=2,  # noqa: E731
                            step_time=0.01, gpus=1)
    s1, f1 = mk("a1")
    s2, f2 = mk("a2")
    s3, f3 = mk("a3")
    svc.submit_spec(s1, f1, tenant="alice")
    svc.submit_spec(s2, f2, tenant="alice")
    assert svc.active_tasks_of("alice") == 2
    with pytest.raises(QuotaExceeded):
        svc.submit_spec(s3, f3, tenant="alice")
    # another tenant is unaffected
    sb, fb = mk("b1")
    svc.submit_spec(sb, fb, tenant="bob")
    # drain: alice's tasks complete, freeing her quota
    svc.run_until_idle()
    assert svc.active_tasks_of("alice") == 0
    h = svc.submit_spec(s3, f3, tenant="alice")
    assert h.result()["task"] == "a3"


def test_quota_default_unlimited_and_cancel_frees():
    from repro.core.service import QuotaExceeded

    svc = TuningService(total_gpus=2, max_tasks_per_tenant=1)
    s1, f1 = sim_task("c1", K=2, Z=2, total=200, warm=2, step_time=0.01,
                      gpus=1)
    s2, f2 = sim_task("c2", K=2, Z=2, total=20, warm=2, step_time=0.01,
                      gpus=1)
    h1 = svc.submit_spec(s1, f1, tenant="t")
    with pytest.raises(QuotaExceeded):
        svc.submit_spec(s2, f2, tenant="t")
    h1.cancel()
    svc.run_until_idle()
    assert svc.status("c1").state is TaskState.CANCELLED
    svc.submit_spec(s2, f2, tenant="t")      # freed by cancellation
    # unlimited service never raises
    free = TuningService(total_gpus=2)
    for i in range(5):
        s, f = sim_task(f"u{i}", K=2, Z=2, total=10, warm=2,
                        step_time=0.01, gpus=1)
        free.submit_spec(s, f, tenant="t")


def test_feedback_records_wall_token_time(tiny_env):
    """Real-executor completions record per-TOKEN wall time (the
    width-calibrated profiler quantity) alongside per-step wall time."""
    from repro.core import engine as alto
    cfg, ds = tiny_env
    svc = TuningService(total_gpus=2, eval_every=2)
    task = alto.Task(model=cfg, dataset=ds, num_gpus=1, max_steps=6,
                     num_slots=2, name="tok-fb",
                     search_space={"lr": [1e-3], "batch_size": [2, 4]})
    h = svc.submit(task, early_exit=EarlyExitConfig(warmup_ratio=0.25,
                                                    select_ratio=1.0))
    h.result()
    key = svc.engine.profile_key(task)
    assert svc.profile_store.wall_step_time(key) is not None
    tok = svc.profile_store.wall_token_time(key)
    assert tok is not None and tok > 0.0
    # per-step wall time = per-token wall time * tokens-per-step (>1)
    assert tok < svc.profile_store.wall_step_time(key)
