"""Rank-local grouped-LoRA kernel parity vs the masked-jnp oracle.

The rank-local path (per-slot TRUE ranks as a compute dimension; dead
rank tiles skip the MXU) must be EXACT: the padded rank region
contributes nothing to any output and receives exactly zero gradient —
even when it holds garbage — and concrete full-rank calls reproduce the
dense kernels bitwise. Interpret mode on CPU is the CI harness.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lora as L
from repro.kernels.grouped_lora import ops, ref
from repro.kernels.grouped_lora import ranklocal as RL


def make(Z, T, din, r, dout, dtype=jnp.float32, with_base=True, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (Z, T, din), dtype)
    A = (0.1 * jax.random.normal(ks[1], (Z, din, r), jnp.float32)
         ).astype(dtype)
    B = (0.1 * jax.random.normal(ks[2], (Z, r, dout), jnp.float32)
         ).astype(dtype)
    scale = jnp.linspace(0.5, 2.0, Z)
    yb = (jax.random.normal(ks[3], (Z, T, dout), dtype)
          if with_base else None)
    return x, A, B, scale, yb


def dirty_pads(A, B, ranks):
    """Scribble garbage into the padded rank region — the rank-local path
    must mask it on load, so outputs cannot depend on it."""
    r = A.shape[2]
    keep = jnp.arange(r)[None, :] < jnp.asarray(ranks)[:, None]
    Ad = jnp.where(keep[:, None, :], A, 99.0)
    Bd = jnp.where(keep[:, :, None], B, -55.0)
    return Ad, Bd


# (Z, T, din, r, dout, ranks): aligned / odd shapes, rank-1, dead slots
CASES = [
    (1, 128, 256, 16, 256, (16,)),             # full (dense-degenerate)
    (2, 64, 96, 16, 80, (4, 11)),              # partial, odd boundary
    (3, 100, 130, 24, 200, (24, 1, 9)),        # rank-1 slot in the middle
    (4, 256, 512, 64, 512, (64, 32, 8, 4)),    # the rank-sweep mix
    (2, 7, 33, 5, 17, (1, 3)),                 # tiny unaligned everything
    (3, 40, 64, 8, 48, (0, 0, 0)),             # all slots rank-0 (dead)
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("with_base", [True, False])
def test_ranklocal_forward_matches_ref(case, dtype, with_base):
    Z, T, din, r, dout, ranks = case
    x, A, B, scale, yb = make(Z, T, din, r, dout, dtype, with_base)
    ranks = jnp.asarray(ranks, jnp.int32)
    got = ops.ranklocal_grouped_lora(x, A, B, scale, ranks, None, yb,
                                     interpret=True)
    want = ref.ranklocal_lora_ref(x, A, B, scale, ranks, None, yb)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("case", CASES[1:4])
def test_ranklocal_gradients_match_ref(case):
    Z, T, din, r, dout, ranks = case
    x, A, B, scale, yb = make(Z, T, din, r, dout, jnp.float32, True)
    ranks = jnp.asarray(ranks, jnp.int32)

    def loss_k(x, A, B, yb):
        return jnp.sum(jnp.tanh(ops.ranklocal_grouped_lora(
            x, A, B, scale, ranks, None, yb, interpret=True)))

    def loss_r(x, A, B, yb):
        return jnp.sum(jnp.tanh(ref.ranklocal_lora_ref(
            x, A, B, scale, ranks, None, yb)))

    gk = jax.grad(loss_k, argnums=(0, 1, 2, 3))(x, A, B, yb)
    gr = jax.grad(loss_r, argnums=(0, 1, 2, 3))(x, A, B, yb)
    for a, b, name in zip(gk, gr, ["dx", "dA", "dB", "dyb"]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4, err_msg=name)


def test_padded_rank_region_ignored_and_zero_grad():
    """Garbage beyond ranks[z] must not leak into any output, and the
    padded region's gradient must be EXACTLY zero (dead tiles never
    accumulate) — the invariant that makes the optimizer re-mask
    redundant on this path."""
    Z, T, din, r, dout = 3, 32, 64, 16, 48
    x, A, B, scale, yb = make(Z, T, din, r, dout)
    ranks = jnp.asarray([4, 16, 9], jnp.int32)
    Ad, Bd = dirty_pads(A, B, ranks)
    got = ops.ranklocal_grouped_lora(x, Ad, Bd, scale, ranks, None, yb,
                                     interpret=True)
    clean = ops.ranklocal_grouped_lora(x, A, B, scale, ranks, None, yb,
                                       interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(clean))

    def loss(A_, B_):
        return jnp.sum(ops.ranklocal_grouped_lora(
            x, A_, B_, scale, ranks, None, interpret=True) ** 2)

    dA_, dB_ = jax.grad(loss, argnums=(0, 1))(Ad, Bd)
    for z, rk in enumerate([4, 16, 9]):
        if rk >= r:
            continue
        assert float(jnp.abs(dA_[z, :, rk:]).max()) == 0.0
        assert float(jnp.abs(dB_[z, rk:, :]).max()) == 0.0
    # valid region matches the oracle on the dirty params
    want = jax.grad(
        lambda A_, B_: jnp.sum(ref.ranklocal_lora_ref(
            x, A_, B_, scale, ranks) ** 2), argnums=(0, 1))(Ad, Bd)
    np.testing.assert_allclose(np.asarray(dA_), np.asarray(want[0]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(dB_), np.asarray(want[1]),
                               rtol=2e-4, atol=2e-4)


def test_full_rank_bitwise_equal_dense():
    """Concrete ranks == r_max everywhere must reproduce the dense kernels
    bitwise — the executor's per-step rank dispatch relies on it."""
    Z, T, din, r, dout = 3, 64, 96, 8, 80
    x, A, B, scale, yb = make(Z, T, din, r, dout)
    full = jnp.full((Z,), r, jnp.int32)
    d = ops.grouped_lora(x, A, B, scale, yb, interpret=True)
    rl = ops.ranklocal_grouped_lora(x, A, B, scale, full, None, yb,
                                    interpret=True)
    np.testing.assert_array_equal(np.asarray(d), np.asarray(rl))
    # ... and with rows, the ragged path bitwise
    rows = jnp.asarray([64, 30, 0], jnp.int32)
    rg = ops.ragged_grouped_lora(x, A, B, scale, rows, yb, interpret=True)
    rl2 = ops.ranklocal_grouped_lora(x, A, B, scale, full, rows, yb,
                                     interpret=True)
    np.testing.assert_array_equal(np.asarray(rg), np.asarray(rl2))


def test_rank_one_degenerate():
    """rank-1 slots: the narrowest possible adapter — one rank tile,
    masked to a single column — must match the oracle and leave columns
    >= 1 at exactly zero gradient."""
    Z, T, din, r, dout = 2, 40, 64, 8, 48
    x, A, B, scale, yb = make(Z, T, din, r, dout)
    ranks = jnp.asarray([1, 1], jnp.int32)
    got = ops.ranklocal_grouped_lora(x, A, B, scale, ranks, None, yb,
                                     interpret=True)
    want = ref.ranklocal_lora_ref(x, A, B, scale, ranks, None, yb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    dA_ = jax.grad(lambda A_: jnp.sum(ops.ranklocal_grouped_lora(
        x, A_, B, scale, ranks, None, interpret=True) ** 2))(A)
    assert float(jnp.abs(dA_[:, :, 1:]).max()) == 0.0
    assert float(jnp.abs(dA_[:, :, :1]).max()) > 0.0


def test_ragged_rows_times_ranks_composition():
    """Both prefetch vectors live: slot z computes over only its first
    rows[z] token rows AND its first ranks[z] rank columns; fwd and VJP
    match the doubly-masked oracle, pads exactly zero on both axes."""
    Z, T, din, r, dout = 3, 48, 96, 16, 64
    x, A, B, scale, yb = make(Z, T, din, r, dout)
    ranks = jnp.asarray([4, 16, 7], jnp.int32)
    rows = jnp.asarray([48, 20, 0], jnp.int32)
    Ad, Bd = dirty_pads(A, B, ranks)
    got = ops.ranklocal_grouped_lora(x, Ad, Bd, scale, ranks, rows, yb,
                                     interpret=True)
    want = ref.ranklocal_lora_ref(x, Ad, Bd, scale, ranks, rows, yb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # padded token rows: y_base passthrough
    np.testing.assert_array_equal(np.asarray(got[1, 20:]),
                                  np.asarray(yb[1, 20:]))
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(yb[2]))

    def loss_k(x_, A_, B_, yb_):
        return jnp.sum(jnp.tanh(ops.ranklocal_grouped_lora(
            x_, A_, B_, scale, ranks, rows, yb_, interpret=True)))

    def loss_r(x_, A_, B_, yb_):
        return jnp.sum(jnp.tanh(ref.ranklocal_lora_ref(
            x_, A_, B_, scale, ranks, rows, yb_)))

    gk = jax.grad(loss_k, argnums=(0, 1, 2, 3))(x, Ad, Bd, yb)
    gr = jax.grad(loss_r, argnums=(0, 1, 2, 3))(x, Ad, Bd, yb)
    for a, b, name in zip(gk, gr, ["dx", "dA", "dB", "dyb"]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4, err_msg=name)
    # rank pads zero grad; row pads zero dX
    assert float(jnp.abs(gk[1][0, :, 4:]).max()) == 0.0
    assert float(jnp.abs(gk[2][2, 7:, :]).max()) == 0.0
    assert float(jnp.abs(gk[0][1, 20:]).max()) == 0.0


def test_individual_ranklocal_kernels_match_masked_einsum():
    Z, T, din, r, dout = 2, 128, 256, 16, 128
    x, A, B, scale, yb = make(Z, T, din, r, dout)
    ranks = jnp.asarray([16, 5], jnp.int32)
    rows = jnp.asarray([128, 37], jnp.int32)
    Am = ref._ranks_mask_A(A, ranks)
    Bm = ref._ranks_mask_B(B, ranks)
    xm = ref._rows_mask(x, rows)
    s = RL.xa(x, A, rows, ranks, interpret=True)
    np.testing.assert_allclose(np.asarray(s),
                               np.asarray(ref.grouped_xa_ref(xm, Am)),
                               rtol=1e-5, atol=1e-5)
    dy = yb
    dym = ref._rows_mask(dy, rows)
    ds_ = RL.ds(dy, B, scale, rows, ranks, interpret=True)
    want_ds = jnp.einsum("zto,zro->ztr", dym * scale[:, None, None], Bm)
    np.testing.assert_allclose(np.asarray(ds_), np.asarray(want_ds),
                               rtol=1e-5, atol=1e-5)
    dx_ = RL.dx(ds_, A, rows, ranks, interpret=True)
    np.testing.assert_allclose(
        np.asarray(dx_), np.asarray(jnp.einsum("ztr,zdr->ztd", ds_, Am)),
        rtol=1e-5, atol=1e-5)
    da_ = RL.da(x, ds_, rows, ranks, interpret=True)
    np.testing.assert_allclose(
        np.asarray(da_),
        np.asarray(ref._ranks_mask_A(
            jnp.einsum("ztd,ztr->zdr", xm, ds_), ranks)),
        rtol=1e-4, atol=1e-4)
    db_ = RL.db(s, dy, scale, rows, ranks, interpret=True)
    want_db = ref._ranks_mask_B(
        jnp.einsum("ztr,zto->zro", s, dym * scale[:, None, None]), ranks)
    np.testing.assert_allclose(np.asarray(db_), np.asarray(want_db),
                               rtol=1e-4, atol=1e-4)


def test_lora_delta_slot_ranks_dispatch():
    """core.lora: a slot_ranks binding routes lora_delta through the
    rank-local path on every backend — jnp masks A/B, pallas rides the
    rank-local kernels — and the two agree; composition with ragged_rows
    masks both axes."""
    Z, b, S, din, r, dout = 2, 4, 8, 32, 8, 24
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    x = jax.random.normal(ks[0], (Z, b, S, din))
    A = 0.1 * jax.random.normal(ks[1], (Z, din, r))
    B = 0.1 * jax.random.normal(ks[2], (Z, r, dout))
    scale = jnp.asarray([2.0, 0.5])
    ranks = jnp.asarray([3, 8], jnp.int32)
    Ad, Bd = dirty_pads(A, B, ranks)
    rows = jnp.asarray([b * S, 2 * S], jnp.int32)
    with L.slot_ranks(ranks):
        y_jnp = L.lora_delta(x, Ad, Bd, scale)
        with L.backend("pallas_interpret"):
            y_pal = L.lora_delta(x, Ad, Bd, scale)
        with L.ragged_rows(rows):
            y_jnp2 = L.lora_delta(x, Ad, Bd, scale)
            with L.backend("pallas_interpret"):
                y_pal2 = L.lora_delta(x, Ad, Bd, scale)
    np.testing.assert_allclose(np.asarray(y_jnp), np.asarray(y_pal),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y_jnp2), np.asarray(y_pal2),
                               rtol=1e-5, atol=1e-5)
    # garbage pads ignored under the binding: clean params, same delta
    with L.slot_ranks(ranks):
        y_clean = L.lora_delta(x, A, B, scale)
    np.testing.assert_array_equal(np.asarray(y_jnp), np.asarray(y_clean))
    # row pads zero on the composed path
    assert float(jnp.abs(y_jnp2[1, 2:]).max()) == 0.0
    # without the binding the jnp path USES the garbage pads (dense math)
    y_dense = L.lora_delta(x, Ad, Bd, scale)
    assert float(jnp.abs(np.asarray(y_dense) - np.asarray(y_jnp)).max()) > 0


def test_train_step_pad_region_stays_zero_without_remask():
    """Pallas-path train-step invariant: with slot_ranks bound, the
    padded rank region of A/B (and the optimizer moments) stays EXACTLY
    zero across AdamW steps with NO rank re-mask — the gradient there is
    structurally zero (dead tiles), so mask_lora_tree is redundant on
    this path."""
    from repro.core.losses import sft_loss
    from repro.models import model as M
    from repro.optim import adamw
    from tests.conftest import reduced_f32

    cfg = reduced_f32("paper-llama-tiny", num_layers=2, d_model=64,
                      vocab=128)
    r_max = cfg.lora.r_max
    Z = 2
    ranks = jnp.asarray([2, r_max], jnp.int32)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    lt = L.init_lora_tree(key, cfg, Z, ranks, M.target_shapes(cfg))
    # nonzero B within the true rank so gradients actually flow
    m = L.rank_mask(ranks, r_max)

    def warm(t, is_A):
        bump = 0.01 * (m[None, :, None, :] if is_A else m[None, :, :, None])
        return t + bump
    lt = {t: {"A": warm(ab["A"], True), "B": warm(ab["B"], False)}
          for t, ab in lt.items()}
    opt = adamw.init_state(lt, Z)
    hp = adamw.SlotHParams.broadcast(Z, lr=1e-2, wd=0.01)
    active = jnp.ones((Z,), jnp.int32)
    tokens = jax.random.randint(key, (Z, 2, 8), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}

    def loss(lora_):
        return sft_loss(cfg, params, lora_, batch, active, remat=False)[0]

    for _ in range(2):
        with L.backend("pallas_interpret"), L.slot_ranks(ranks):
            grads = jax.grad(loss)(lt)
        for t in grads:
            assert float(jnp.abs(grads[t]["A"][:, 0, :, 2:]).max()) == 0.0
            assert float(jnp.abs(grads[t]["B"][:, 0, 2:, :]).max()) == 0.0
        # NO rank_masker: the re-mask the rank-local path makes redundant
        lt, opt = adamw.apply_updates(lt, grads, opt, hp, active,
                                      rank_masker=None)
    for t in lt:
        assert float(jnp.abs(lt[t]["A"][:, 0, :, 2:]).max()) == 0.0
        assert float(jnp.abs(lt[t]["B"][:, 0, 2:, :]).max()) == 0.0
        assert float(jnp.abs(opt.mu[t]["A"][:, 0, :, 2:]).max()) == 0.0
        assert float(jnp.abs(opt.nu[t]["B"][:, 0, 2:, :]).max()) == 0.0
    # the adapters did train inside the true rank region
    assert any(float(jnp.abs(lt[t]["A"][:, 0, :, :2]).max()) > 0
               for t in lt)
